"""Simulator performance benchmark: events/sec + sweep wall time.

The event engine is the substrate every evaluation in this repo runs on
(workload sweeps, tenant interference, GC interference), so its own
throughput is a first-class, *tracked* deliverable.  This bench measures

* ``mix``  — two synthetic NDP tenants + a host I/O stream on one shared
  fabric (the shape of ``pressure_bench.tenant_interference``), and
* ``gc``   — the same tenants + a write-heavy Zipf host I/O stream through
  a preconditioned FTL with garbage collection (the shape of
  ``pressure_bench.gc_interference``), and
* ``serving`` — an open-loop session stream (Poisson arrivals over a
  weighted two-kind catalog, admission control, per-session Simulation
  churn — the shape of ``serving_bench.serving_curve``),

reporting processed events per second of wall time for each suite, plus
the end-to-end wall time of a small sweep loop.  Results are written to
``BENCH_sim_perf.json`` — the repo's perf-trajectory artifact.  The
committed JSON carries the *pre-optimization* baseline (measured on the
engine as of PR 2 with this same harness); ``--check`` fails the run if
the current engine falls more than ``REGRESSION_TOLERANCE`` below that
committed baseline, which catches "someone un-optimized the hot path"
while tolerating slower CI machines (the optimized engine clears the
baseline by >3x on equal hardware).

Measurement hygiene: traces are built outside the timed region, one
warm-up run populates the per-instruction static-feature caches (as any
sweep's first point would), the cyclic GC is disabled during timed runs
(jax registers a gc callback that would add unrelated noise), and the
best of ``--repeats`` runs is taken.

This bench doubles as the **telemetry-off overhead guard**: every
flight-recorder hook site (engine loop, pool acquires, dispatch, FTL
collector, serving driver — see :mod:`repro.sim.telemetry`) sits on the
measured path as a single ``is not None`` branch, and all three suites
run with telemetry off (the default).  A hook that grew real work on the
off path shows up as an events/sec regression against the committed
baseline and fails ``--check``.

Usage::

  PYTHONPATH=src python -m benchmarks.perf_bench            # full, writes JSON
  PYTHONPATH=src python -m benchmarks.perf_bench --smoke --check
  PYTHONPATH=src python -m benchmarks.perf_bench --json out.json
"""
from __future__ import annotations

import argparse
import gc
import json
import sys
import time
from typing import Callable, Dict, List, Tuple

#: fail --check when events/sec drops below (1 - tolerance) x committed
#: pre-optimization baseline
REGRESSION_TOLERANCE = 0.30

#: Per-suite multipliers on ``--repeats``.  Best-of-N is a *floor*
#: estimator: on a busy single-core host the run-to-run spread routinely
#: exceeds REGRESSION_TOLERANCE, and N=5 under-samples the floor by
#: 10-25%.  The serving suite is both the tracked headline of the
#: fast-path work and the longest per-run (~40 ms), so a missed floor
#: there is the most expensive to re-measure — it gets extra repeats.
REPEAT_SCALE = {"serving": 4}

#: The committed JSON's "baseline" block is the engine BEFORE the fast-path
#: PR (lazy-heap pools, slab events, cached cost features), measured with
#: this same harness on the same machine as the committed "current" block.
DEFAULT_JSON = "BENCH_sim_perf.json"

_OPS = ["and", "or", "xor", "add", "sub", "mul", "cmp", "max", "copy"]


def _synth_trace(op_ids, name="perf", n_arrays=4, pages_per_array=2):
    """Deterministic synthetic trace (mirrors tests/_synth.py, inlined so
    the bench has no test-tree or jax-workload dependency)."""
    from repro.core.isa import VectorInstr
    from repro.core.mapping import PageTable
    from repro.core.vectorize import Trace
    from repro.hw.ssd_spec import DEFAULT_SSD

    page = DEFAULT_SSD.page_size
    pt = PageTable(DEFAULT_SSD)
    arrays = [pt.alloc_array(pages_per_array * page, name=f"a{i}")
              for i in range(n_arrays)]
    flat = [p for a in arrays for p in a]
    instrs = []
    producer: Dict[int, int] = {}
    for i, oi in enumerate(op_ids):
        op = _OPS[oi % len(_OPS)]
        s1 = flat[(oi * 7 + i) % len(flat)]
        s2 = flat[(oi * 13 + 3 * i) % len(flat)]
        dst = flat[(oi * 5 + 2 * i + 1) % len(flat)]
        deps = tuple(sorted({producer[s] for s in (s1, s2, dst)
                             if s in producer}))
        instrs.append(VectorInstr(iid=i, op=op, vlen=page, elem_bytes=1,
                                  srcs=(s1, s2), dst=dst, deps=deps))
        producer[dst] = i
    return Trace(instrs=instrs, pages=pt, input_pages={"in0": arrays[0]},
                 output_pages=[arrays[-1]], name=name)


def _suites(smoke: bool) -> Dict[str, Callable]:
    """suite name -> zero-arg builder returning (engine, result)."""
    from repro.sim import (CatalogEntry, EventEngine, FTLConfig,
                          HostIOStream, PoissonArrivals, ServingConfig,
                          SessionCatalog, simulate_mix, simulate_serving)

    n_io = 96 if smoke else 256
    n_gc_io = 160 if smoke else 512
    n_sessions = 24 if smoke else 64
    ramp = list(range(40))
    mixed = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4
    a = _synth_trace(ramp, name="A")
    b = _synth_trace(mixed, name="B")

    def mix():
        eng = EventEngine()
        io = HostIOStream(rate_iops=80_000, n_requests=n_io, seed=7)
        simulate_mix([a, b], "conduit", io_stream=io,
                     compute_solo=False, engine=eng)
        return eng

    def gc_suite():
        eng = EventEngine()
        ftl = FTLConfig(blocks_per_die=4, pages_per_block=8,
                        prefill=0.9, op_ratio=0.28)
        io = HostIOStream(rate_iops=250_000, read_fraction=0.3,
                          n_requests=n_gc_io, zipf_theta=0.95,
                          n_logical_pages=ftl.logical_pages())
        simulate_mix([a, b], "conduit", io_stream=io, ftl=ftl,
                     compute_solo=False, engine=eng)
        return eng

    def serving_suite():
        # open-loop session churn at a deliberately saturating rate: the
        # admission queue and per-session Simulation setup are on the
        # measured path (that's the serving driver's own overhead)
        eng = EventEngine()
        catalog = SessionCatalog([CatalogEntry("A", a, 3.0),
                                  CatalogEntry("B", b, 1.0)], seed=5)
        arr = PoissonArrivals(rate_per_sec=8000, n_sessions=n_sessions,
                              seed=9)
        # little_law_warn_tol=inf: the saturating, untrimmed window is
        # the point here (timing the driver), not steady-state metrics
        simulate_serving(catalog, arr, "conduit",
                         serving=ServingConfig(
                             keep_session_results=False,
                             little_law_warn_tol=float("inf")),
                         engine=eng)
        return eng

    return {"mix": mix, "gc": gc_suite, "serving": serving_suite}


def _measure(build: Callable, repeats: int) -> Tuple[float, int, float]:
    """(best events/sec, events per run, total wall time of all runs)."""
    build()                       # warm-up: caches as in any sweep's 2nd point
    best = 0.0
    total = 0.0
    processed = 0
    gc_was_enabled = gc.isenabled()
    for _ in range(repeats):
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            eng = build()
            dt = time.perf_counter() - t0
        finally:
            if gc_was_enabled:
                gc.enable()
        total += dt
        processed = eng.processed
        best = max(best, eng.processed / dt)
    return best, processed, total


def run_perf(smoke: bool = False, repeats: int = 5,
             json_path: str = DEFAULT_JSON, check: bool = False,
             write_json: bool = True) -> List[str]:
    """Run the suites; print a table, write the JSON artifact, return the
    ``name,value,derived`` CSV rows (run.py suite protocol)."""
    rows: List[str] = []
    committed = _load_committed(json_path)
    baseline = (committed or {}).get("baseline", {})
    current: Dict[str, float] = {}
    print(f"\n== simulator perf ({'smoke' if smoke else 'full'}, "
          f"best of {repeats}, serving x{REPEAT_SCALE.get('serving', 1)})")
    sweep_t0 = time.perf_counter()
    for name, build in _suites(smoke).items():
        n_rep = repeats * REPEAT_SCALE.get(name, 1)
        evs, n_events, wall = _measure(build, n_rep)
        key = f"{name}_events_per_sec"
        current[key] = round(evs, 1)
        base = baseline.get(key)
        ratio = f" ({evs / base:4.2f}x baseline)" if base else ""
        print(f"  {name:4s} {n_events:6d} events  {evs:10,.0f} ev/s{ratio}  "
              f"({wall * 1e3 / n_rep:6.1f} ms/run, best of {n_rep})")
        rows.append(f"simperf/{name}/events_per_sec,{evs:.0f},"
                    f"baseline={base or 'n/a'}")
    current["sweep_wall_s"] = round(time.perf_counter() - sweep_t0, 3)
    rows.append(f"simperf/sweep_wall_s,{current['sweep_wall_s']},")

    if write_json:
        payload = {
            "schema": "sim-perf-trajectory/v1",
            "harness": {"repeats": repeats, "smoke": smoke,
                        "metric": "engine events per second of wall time, "
                                  "best of N, gc disabled, warm caches"},
            "baseline": baseline or current,
            "current": current,
        }
        if baseline:
            # events/sec only: sweep_wall_s depends on --repeats and is
            # informational, not a comparable trajectory metric
            payload["speedup"] = {
                k: round(current[k] / baseline[k], 2)
                for k in current
                if k.endswith("_per_sec") and baseline.get(k)}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"  wrote {json_path}")

    if check and not baseline:
        # a missing/corrupt committed artifact must not silently disable
        # the regression gate
        sys.exit(f"[perf_bench] --check requested but {json_path} has no "
                 "committed baseline — the regression gate cannot run")
    if check:
        floor = {k: v * (1.0 - REGRESSION_TOLERANCE)
                 for k, v in baseline.items() if k.endswith("_per_sec")}
        bad = {k: (current.get(k), f) for k, f in floor.items()
               if current.get(k, 0.0) < f}
        if bad:
            for k, (got, f) in bad.items():
                print(f"[perf_bench] REGRESSION {k}: {got:,.0f} ev/s < "
                      f"floor {f:,.0f} (committed baseline "
                      f"{baseline[k]:,.0f})", file=sys.stderr)
            sys.exit("[perf_bench] events/sec regressed below the "
                     "committed pre-optimization baseline")
        print(f"  check ok: all suites above {1 - REGRESSION_TOLERANCE:.0%} "
              f"of the committed baseline")
    return rows


def _load_committed(json_path: str) -> Dict:
    try:
        with open(json_path) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return {}


def perf_suite() -> List[str]:
    """run.py suite entry point (no JSON write: read-only CSV probe)."""
    return run_perf(smoke=True, repeats=3, write_json=False)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized configuration (smaller I/O streams, "
                         "still real measurements)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--json", default=DEFAULT_JSON,
                    help=f"trajectory artifact path (default {DEFAULT_JSON})")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if events/sec falls >"
                         f"{REGRESSION_TOLERANCE:.0%} below the committed "
                         "baseline in the JSON artifact")
    ap.add_argument("--no-write", action="store_true",
                    help="measure and check only; leave the JSON untouched")
    args = ap.parse_args()
    run_perf(smoke=args.smoke, repeats=args.repeats, json_path=args.json,
             check=args.check, write_json=not args.no_write)


if __name__ == "__main__":
    main()
