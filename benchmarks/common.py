"""Shared benchmark harness: run every (workload x policy) simulation once
and cache the SimResults for all figure benchmarks."""
from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

from repro.core.policies import ALL_POLICIES
from repro.sim import SimResult, simulate
from repro.workloads import PAPER_ORDER, get_trace, sim_config_for

# paper headline numbers we validate against (§1, §6)
PAPER = {
    "conduit_over_cpu": 4.2,
    "conduit_over_gpu": 1.8,
    "conduit_over_isp": 3.3,
    "conduit_over_pud": 2.2,
    "conduit_over_flash_cosmos": 3.3,
    "conduit_over_ares_flash": 2.3,
    "conduit_over_bw": 2.0,
    "conduit_over_dm": 1.8,
    "conduit_of_ideal": 0.62,
    "energy_vs_cpu": 0.218,          # -78.2%
    "energy_vs_dm": 0.532,           # -46.8%
    "gpu_over_cpu": 2.33,
    "overhead_avg_us": 3.77,
    "overhead_max_us": 33.0,
}


@functools.lru_cache(maxsize=4)
def full_matrix(scale: str = "paper") -> Dict[Tuple[str, str], SimResult]:
    out: Dict[Tuple[str, str], SimResult] = {}
    for wl in PAPER_ORDER:
        tr = get_trace(wl, scale)
        cfg = sim_config_for(wl, tr)
        for pol in ALL_POLICIES:
            t0 = time.time()
            out[(wl, pol)] = simulate(tr, pol, config=cfg)
    return out


def geomean(xs):
    import math
    xs = [x for x in xs if x > 0]
    return math.exp(sum(math.log(x) for x in xs) / max(1, len(xs)))


def speedups_vs_cpu(matrix) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for wl in PAPER_ORDER:
        base = matrix[(wl, "cpu")].makespan_ns
        out[wl] = {pol: base / matrix[(wl, pol)].makespan_ns
                   for pol in ALL_POLICIES}
    return out


def energies_vs_cpu(matrix) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for wl in PAPER_ORDER:
        base = matrix[(wl, "cpu")].total_energy_nj
        out[wl] = {pol: matrix[(wl, pol)].total_energy_nj / base
                   for pol in ALL_POLICIES}
    return out


def csv_row(name: str, value, derived="") -> str:
    return f"{name},{value},{derived}"
