"""Reproductions of the paper's tables/figures from the simulator.

One function per paper artifact (see DESIGN.md §9 index):
  fig5_fig7a_speedup      speedups of all policies vs CPU (Figs 5/7a)
  fig7b_energy            energy + movement/compute breakdown (Fig 7b)
  fig8_tail_latency       p99/p99.99 instruction latencies (Fig 8)
  fig9_decisions          per-resource offloading mix (Fig 9)
  fig10_timeline          instruction->resource timeline (Fig 10)
  table3_characterize     workload characterization (Table 3)
  overhead_analysis       §4.5 runtime decision overheads
Each returns CSV lines "name,value,derived" and prints a human table.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (PAPER, csv_row, energies_vs_cpu, full_matrix,
                               geomean, speedups_vs_cpu)
from repro.core.isa import Resource
from repro.core.policies import ALL_POLICIES
from repro.workloads import PAPER_ORDER, WORKLOADS, get_trace


def fig5_fig7a_speedup() -> List[str]:
    m = full_matrix()
    sp = speedups_vs_cpu(m)
    rows = []
    print("\n== Fig 5 / Fig 7a: speedup vs CPU (higher is better)")
    header = f"{'workload':14s} " + " ".join(f"{p:>12s}" for p in ALL_POLICIES)
    print(header)
    for wl in PAPER_ORDER:
        print(f"{wl:14s} " + " ".join(f"{sp[wl][p]:12.2f}"
                                      for p in ALL_POLICIES))
        for p in ALL_POLICIES:
            rows.append(csv_row(f"fig7a/{wl}/{p}", f"{sp[wl][p]:.3f}",
                                "speedup_vs_cpu"))
    gm = {p: geomean([sp[wl][p] for wl in PAPER_ORDER])
          for p in ALL_POLICIES}
    print(f"{'GEOMEAN':14s} " + " ".join(f"{gm[p]:12.2f}"
                                         for p in ALL_POLICIES))
    for p in ALL_POLICIES:
        rows.append(csv_row(f"fig7a/geomean/{p}", f"{gm[p]:.3f}",
                            "speedup_vs_cpu"))
    # paper-claim comparison
    claims = [
        ("conduit_over_cpu", gm["conduit"]),
        ("conduit_over_dm", gm["conduit"] / gm["dm"]),
        ("conduit_over_bw", gm["conduit"] / gm["bw"]),
        ("conduit_over_isp", gm["conduit"] / gm["isp"]),
        ("conduit_over_pud", gm["conduit"] / gm["pud"]),
        ("conduit_over_flash_cosmos", gm["conduit"] / gm["flash_cosmos"]),
        ("conduit_over_ares_flash", gm["conduit"] / gm["ares_flash"]),
        ("conduit_over_gpu", gm["conduit"] / gm["gpu"]),
        ("conduit_of_ideal", gm["conduit"] / gm["ideal"]),
        ("gpu_over_cpu", gm["gpu"]),
    ]
    print("\n   ours vs paper-claim:")
    for name, ours in claims:
        print(f"   {name:28s} ours={ours:6.2f}  paper={PAPER[name]:6.2f}")
        rows.append(csv_row(f"claims/{name}", f"{ours:.3f}",
                            f"paper={PAPER[name]}"))
    return rows


def fig7b_energy() -> List[str]:
    m = full_matrix()
    en = energies_vs_cpu(m)
    rows = []
    print("\n== Fig 7b: energy vs CPU (lower is better), movement share")
    for wl in PAPER_ORDER:
        parts = []
        for p in ALL_POLICIES:
            r = m[(wl, p)]
            mv = r.movement_energy_nj / max(1e-9, r.total_energy_nj)
            parts.append(f"{en[wl][p]:7.3f}({mv:4.0%})")
            rows.append(csv_row(f"fig7b/{wl}/{p}", f"{en[wl][p]:.4f}",
                                f"movement_share={mv:.2f}"))
        print(f"{wl:14s} " + " ".join(parts))
    gm = {p: geomean([en[wl][p] for wl in PAPER_ORDER]) for p in ALL_POLICIES}
    print(f"{'GEOMEAN':14s} " + " ".join(f"{gm[p]:13.3f}"
                                         for p in ALL_POLICIES))
    rows.append(csv_row("claims/energy_vs_cpu", f"{gm['conduit']:.3f}",
                        f"paper={PAPER['energy_vs_cpu']}"))
    rows.append(csv_row("claims/energy_vs_dm",
                        f"{gm['conduit'] / gm['dm']:.3f}",
                        f"paper={PAPER['energy_vs_dm']}"))
    return rows


def fig8_tail_latency() -> List[str]:
    m = full_matrix()
    rows = []
    print("\n== Fig 8: p99 / p99.99 instruction latency (us)")
    for wl in ("llama2_infer", "jacobi1d"):
        for p in ("ideal", "conduit", "bw", "dm"):
            r = m[(wl, p)]
            p99, p9999 = r.p(99) / 1e3, r.p(99.99) / 1e3
            print(f"  {wl:14s} {p:8s} p99={p99:10.1f}us "
                  f"p99.99={p9999:10.1f}us")
            rows.append(csv_row(f"fig8/{wl}/{p}/p99", f"{p99:.2f}", "us"))
            rows.append(csv_row(f"fig8/{wl}/{p}/p9999", f"{p9999:.2f}",
                                "us"))
    return rows


def fig9_decisions() -> List[str]:
    m = full_matrix()
    rows = []
    print("\n== Fig 9: fraction of instructions per compute resource")
    for wl in PAPER_ORDER:
        for p in ("ideal", "conduit", "dm", "bw"):
            mix = m[(wl, p)].decision_mix()
            s = " ".join(f"{r.value}:{f:.0%}" for r, f in sorted(
                mix.items(), key=lambda kv: kv[0].value) if f > 0.004)
            print(f"  {wl:14s} {p:8s} {s}")
            for r, f in mix.items():
                rows.append(csv_row(f"fig9/{wl}/{p}/{r.value}", f"{f:.4f}",
                                    "decision_fraction"))
    return rows


def fig10_timeline(n: int = 60) -> List[str]:
    """Instruction->resource mapping over the first N decisions of LLaMA2
    inference (the paper plots 12000; we print a compact strip)."""
    m = full_matrix()
    rows = []
    print("\n== Fig 10: llama2_infer instruction->resource strip "
          f"(first {n} instrs)")
    glyph = {"isp": "I", "pud": "D", "ifp": "F", "cpu": "c", "gpu": "g"}
    for p in ("bw", "dm", "conduit"):
        decs = m[("llama2_infer", p)].decisions[:n]
        strip = "".join(glyph[d.resource.value] for d in decs)
        print(f"  {p:8s} {strip}")
        rows.append(csv_row(f"fig10/llama2_infer/{p}", strip,
                            "I=isp D=pud F=ifp"))
    return rows


def table3_characterize() -> List[str]:
    rows = []
    print("\n== Table 3: workload characterization (ours vs paper)")
    print(f"{'workload':14s} {'vect%':>6s} {'(p)':>5s} {'reuse':>6s} "
          f"{'(p)':>5s} {'L/M/H':>12s} {'(paper L/M/H)':>14s} {'instrs':>8s}")
    for wl in PAPER_ORDER:
        tr = get_trace(wl, "paper")
        st = tr.characterize()
        r = st.as_row()
        meta = WORKLOADS[wl].META
        print(f"{wl:14s} {r['vectorizable_pct']:6.1f} "
              f"{meta['paper_vect']:5.0f} {r['avg_reuse']:6.1f} "
              f"{meta['paper_reuse']:5.1f} "
              f"{r['low_pct']:3.0f}/{r['medium_pct']:3.0f}/"
              f"{r['high_pct']:3.0f} "
              f"{meta['paper_low']:4.0f}/{meta['paper_med']:3.0f}/"
              f"{meta['paper_high']:3.0f} {r['instrs']:8d}")
        rows.append(csv_row(
            f"table3/{wl}",
            f"{r['vectorizable_pct']}/{r['avg_reuse']}",
            f"bands={r['low_pct']}/{r['medium_pct']}/{r['high_pct']}"))
    return rows


def overhead_analysis() -> List[str]:
    m = full_matrix()
    rows = []
    print("\n== §4.5: runtime decision overhead (dynamic policies)")
    worst = 0.0
    for wl in PAPER_ORDER:
        r = m[(wl, "conduit")]
        avg = r.avg_decision_overhead_ns / 1e3
        per = [d for d in r.decisions]
        worst = max(worst, avg)
        print(f"  {wl:14s} avg={avg:6.2f}us  "
              f"(paper avg {PAPER['overhead_avg_us']}us, "
              f"max {PAPER['overhead_max_us']}us)")
        rows.append(csv_row(f"overhead/{wl}", f"{avg:.3f}", "us_avg"))
    return rows
