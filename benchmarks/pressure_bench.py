"""Capacity-pressure sweep: exercises the eviction + lazy-coherence
machinery (the paper's "footprint exceeds capacity" regime, §5.4) and the
fault-replay path (§4.4 failure handling)."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row
from repro.sim import SimConfig, simulate
from repro.workloads import get_trace, sim_config_for


def pressure_sweep(workload: str = "aes") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    print(f"\n== capacity-pressure sweep ({workload}, conduit policy)")
    base = None
    for pressure in (0.0, 0.5, 0.8, 0.95):
        cfg = sim_config_for(workload, tr, pressure=pressure)
        r = simulate(tr, "conduit", config=cfg)
        if base is None:
            base = r.makespan_ns
        slow = r.makespan_ns / base
        print(f"  pressure={pressure:4.2f} makespan={r.makespan_ns/1e6:9.2f}ms "
              f"({slow:5.2f}x) evictions={r.evictions:6d} "
              f"coherence_syncs={r.coherence_syncs:5d}")
        rows.append(csv_row(f"pressure/{workload}/{pressure}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,evictions={r.evictions},"
                            f"syncs={r.coherence_syncs}"))
    return rows


def fault_replay(workload: str = "jacobi1d") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    cfg0 = sim_config_for(workload, tr)
    print(f"\n== transient-fault replay ({workload}, conduit policy)")
    base = simulate(tr, "conduit", config=cfg0).makespan_ns
    for rate in (0.0, 0.01, 0.05):
        cfg = sim_config_for(workload, tr, fail_rate=rate)
        r = simulate(tr, "conduit", config=cfg)
        print(f"  fail_rate={rate:5.2f} makespan={r.makespan_ns/1e6:8.2f}ms "
              f"({r.makespan_ns/base:5.2f}x) replays={r.replays}")
        rows.append(csv_row(f"fault/{workload}/{rate}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,replays={r.replays}"))
    return rows
