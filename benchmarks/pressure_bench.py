"""Capacity-pressure sweep: exercises the eviction + lazy-coherence
machinery (the paper's "footprint exceeds capacity" regime, §5.4), the
fault-replay path (§4.4 failure handling), and the multi-tenant
interference regime (several traces + host I/O sharing one fabric)."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row
from repro.sim import (HostIOStream, SimConfig, jain_fairness, simulate,
                       simulate_mix)
from repro.workloads import get_trace, sim_config_for


def pressure_sweep(workload: str = "aes") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    print(f"\n== capacity-pressure sweep ({workload}, conduit policy)")
    base = None
    for pressure in (0.0, 0.5, 0.8, 0.95):
        cfg = sim_config_for(workload, tr, pressure=pressure)
        r = simulate(tr, "conduit", config=cfg)
        if base is None:
            base = r.makespan_ns
        slow = r.makespan_ns / base
        print(f"  pressure={pressure:4.2f} makespan={r.makespan_ns/1e6:9.2f}ms "
              f"({slow:5.2f}x) evictions={r.evictions:6d} "
              f"coherence_syncs={r.coherence_syncs:5d}")
        rows.append(csv_row(f"pressure/{workload}/{pressure}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,evictions={r.evictions},"
                            f"syncs={r.coherence_syncs}"))
    return rows


def fault_replay(workload: str = "jacobi1d") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    cfg0 = sim_config_for(workload, tr)
    print(f"\n== transient-fault replay ({workload}, conduit policy)")
    base = simulate(tr, "conduit", config=cfg0).makespan_ns
    for rate in (0.0, 0.01, 0.05):
        cfg = sim_config_for(workload, tr, fail_rate=rate)
        r = simulate(tr, "conduit", config=cfg)
        print(f"  fail_rate={rate:5.2f} makespan={r.makespan_ns/1e6:8.2f}ms "
              f"({r.makespan_ns/base:5.2f}x) replays={r.replays}")
        rows.append(csv_row(f"fault/{workload}/{rate}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,replays={r.replays}"))
    return rows


def tenant_interference(workloads=("jacobi1d", "aes"),
                        policy: str = "conduit") -> List[str]:
    """Multi-tenant interference sweep: co-run the workloads on one shared
    fabric at increasing host-I/O intensity; report per-tenant slowdown
    vs. solo, Jain fairness, and host I/O p99."""
    rows = []
    traces = [get_trace(wl, "tiny") for wl in workloads]
    print(f"\n== multi-tenant interference ({'+'.join(workloads)}, "
          f"{policy} policy)")
    # the solo baselines are identical across iops levels: compute once
    solo = {f"t{i}:{wl}": simulate(tr, policy).makespan_ns
            for i, (wl, tr) in enumerate(zip(workloads, traces))}
    for iops in (0, 25_000, 100_000, 400_000):
        io = (HostIOStream(rate_iops=iops, n_requests=128)
              if iops else None)
        mix = simulate_mix(traces, policy, io_stream=io, compute_solo=False)
        slow = {k: mix.tenant(k).makespan_ns / v for k, v in solo.items()}
        fairness = jain_fairness(list(slow.values()))
        io_p99 = mix.host_io.p(99) / 1e3 if mix.host_io else 0.0
        sl_txt = " ".join(f"{k.split(':')[1]}={v:5.2f}x"
                          for k, v in slow.items())
        print(f"  io={iops:7d}iops {sl_txt} fairness={fairness:.3f} "
              f"io_p99={io_p99:8.1f}us")
        for k, v in slow.items():
            rows.append(csv_row(f"mix/{k.split(':')[1]}/{iops}",
                                f"{v:.4f}", "slowdown_x"))
        rows.append(csv_row(f"mix/fairness/{iops}", f"{fairness:.4f}", ""))
        if mix.host_io:
            rows.append(csv_row(f"mix/io_p99/{iops}", f"{io_p99:.1f}", "us"))
    return rows
