"""Capacity-pressure sweep: exercises the eviction + lazy-coherence
machinery (the paper's "footprint exceeds capacity" regime, §5.4), the
fault-replay path (§4.4 failure handling), the multi-tenant interference
regime (several traces + host I/O sharing one fabric), the FTL
garbage-collection interference sweep (write amplification vs.
over-provisioning under Zipf-skewed writes), and the GC *policy* sweep
(victim selection x hot/cold separation x suspend/throttle, plus the
saturation cost of collecting under open-loop serving)."""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import csv_row
from repro.hw.ssd_spec import FlashSpec, SSDSpec
from repro.sim import (CatalogEntry, FTLConfig, HostIOStream, ServingConfig,
                       SessionCatalog, SimConfig, drive_zipf_overwrites,
                       find_saturation, jain_fairness, simulate,
                       simulate_mix)
from repro.workloads import get_trace, sim_config_for


def pressure_sweep(workload: str = "aes") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    print(f"\n== capacity-pressure sweep ({workload}, conduit policy)")
    base = None
    for pressure in (0.0, 0.5, 0.8, 0.95):
        cfg = sim_config_for(workload, tr, pressure=pressure)
        r = simulate(tr, "conduit", config=cfg)
        if base is None:
            base = r.makespan_ns
        slow = r.makespan_ns / base
        print(f"  pressure={pressure:4.2f} makespan={r.makespan_ns/1e6:9.2f}ms "
              f"({slow:5.2f}x) evictions={r.evictions:6d} "
              f"coherence_syncs={r.coherence_syncs:5d}")
        rows.append(csv_row(f"pressure/{workload}/{pressure}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,evictions={r.evictions},"
                            f"syncs={r.coherence_syncs}"))
    return rows


def fault_replay(workload: str = "jacobi1d") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    cfg0 = sim_config_for(workload, tr)
    print(f"\n== transient-fault replay ({workload}, conduit policy)")
    base = simulate(tr, "conduit", config=cfg0).makespan_ns
    for rate in (0.0, 0.01, 0.05):
        cfg = sim_config_for(workload, tr, fail_rate=rate)
        r = simulate(tr, "conduit", config=cfg)
        print(f"  fail_rate={rate:5.2f} makespan={r.makespan_ns/1e6:8.2f}ms "
              f"({r.makespan_ns/base:5.2f}x) replays={r.replays}")
        rows.append(csv_row(f"fault/{workload}/{rate}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,replays={r.replays}"))
    return rows


def tenant_interference(workloads=("jacobi1d", "aes"),
                        policy: str = "conduit",
                        smoke: bool = False) -> List[str]:
    """Multi-tenant interference sweep: co-run the workloads on one shared
    fabric at increasing host-I/O intensity; report per-tenant slowdown
    vs. solo, Jain fairness, and host I/O p99.  ``smoke`` shrinks the
    sweep to a CI-sized configuration (entry-point rot check)."""
    rows = []
    n_req = 32 if smoke else 128
    levels = (0, 100_000) if smoke else (0, 25_000, 100_000, 400_000)
    traces = [get_trace(wl, "tiny") for wl in workloads]
    print(f"\n== multi-tenant interference ({'+'.join(workloads)}, "
          f"{policy} policy)")
    # the solo baselines are identical across iops levels: compute once
    solo = {f"t{i}:{wl}": simulate(tr, policy).makespan_ns
            for i, (wl, tr) in enumerate(zip(workloads, traces))}
    for iops in levels:
        io = (HostIOStream(rate_iops=iops, n_requests=n_req)
              if iops else None)
        mix = simulate_mix(traces, policy, io_stream=io, compute_solo=False)
        slow = {k: mix.tenant(k).makespan_ns / v for k, v in solo.items()}
        fairness = jain_fairness(list(slow.values()))
        io_p99 = mix.host_io.p(99) / 1e3 if mix.host_io else 0.0
        sl_txt = " ".join(f"{k.split(':')[1]}={v:5.2f}x"
                          for k, v in slow.items())
        print(f"  io={iops:7d}iops {sl_txt} fairness={fairness:.3f} "
              f"io_p99={io_p99:8.1f}us")
        for k, v in slow.items():
            rows.append(csv_row(f"mix/{k.split(':')[1]}/{iops}",
                                f"{v:.4f}", "slowdown_x"))
        rows.append(csv_row(f"mix/fairness/{iops}", f"{fairness:.4f}", ""))
        if mix.host_io:
            rows.append(csv_row(f"mix/io_p99/{iops}", f"{io_p99:.1f}", "us"))
    return rows


def gc_interference(workloads=("jacobi1d", "aes"),
                    policy: str = "conduit",
                    smoke: bool = False) -> List[str]:
    """FTL garbage-collection interference sweep.

    For each over-provisioning level, co-run the NDP workloads with a
    write-heavy Zipf-skewed host I/O stream on a preconditioned (90 %
    prefilled) drive, GC off vs. on: identical streams and placement, so
    the write-amplification / host-p99 / tenant-slowdown deltas are
    attributable purely to the collector's page copies and erases on the
    shared die/channel pools."""
    rows = []
    n_req = 160 if smoke else 512
    geometry = dict(blocks_per_die=4, pages_per_block=8, prefill=0.9)
    traces = [get_trace(wl, "tiny") for wl in workloads]
    print(f"\n== GC interference ({'+'.join(workloads)}, {policy} policy, "
          f"zipf 0.95 write-heavy host I/O)")
    for op in (0.45, 0.28, 0.12):
        on_cfg = FTLConfig(op_ratio=op, **geometry)
        off_cfg = dataclasses.replace(on_cfg, gc_enabled=False)
        io = HostIOStream(rate_iops=250_000, read_fraction=0.3,
                          n_requests=n_req, zipf_theta=0.95,
                          n_logical_pages=on_cfg.logical_pages())
        off = simulate_mix(traces, policy, io_stream=io, ftl=off_cfg,
                           compute_solo=False)
        on = simulate_mix(traces, policy, io_stream=io, ftl=on_cfg,
                          compute_solo=False)
        wa = on.ftl.write_amplification
        p99_off = off.host_io.p(99) / 1e3
        p99_on = on.host_io.p(99) / 1e3
        slow = {r.tenant: on.tenant(r.tenant).makespan_ns / r.makespan_ns
                for r in off.tenants}
        sl_txt = " ".join(f"{k.split(':')[1]}={v:5.2f}x"
                          for k, v in slow.items())
        print(f"  op={op:4.2f} WA={wa:5.2f} gc={on.ftl.gc_invocations:4d} "
              f"erases={on.ftl.blocks_erased:4d} "
              f"io_p99={p99_off:8.1f}->{p99_on:8.1f}us "
              f"(during_gc={on.ftl.p_during_gc(99)/1e3:8.1f}us) {sl_txt}")
        rows.append(csv_row(f"gc/wa/{op}", f"{wa:.4f}", "x"))
        rows.append(csv_row(f"gc/erases/{op}", f"{on.ftl.blocks_erased}", ""))
        rows.append(csv_row(f"gc/io_p99/{op}", f"{p99_on:.1f}",
                            f"us,baseline={p99_off:.1f}"))
        for k, v in slow.items():
            rows.append(csv_row(f"gc/slowdown/{k.split(':')[1]}/{op}",
                                f"{v:.4f}", "x_vs_gc_off"))
    return rows


#: scaled-down fabric for the victim-policy study: 4 dies concentrate the
#: per-die overwrite churn, so thousands of GC cycles (where victim choice
#: actually matters) simulate in seconds
_POLICY_SSD = SSDSpec(flash=FlashSpec(channels=2, dies_per_channel=2))


def _drive_policy(cfg: FTLConfig, n_writes: int):
    return drive_zipf_overwrites(cfg, _POLICY_SSD, n_writes)


def gc_policies(workloads=("jacobi1d", "aes"),
                policy: str = "conduit",
                smoke: bool = False) -> List[str]:
    """GC policy suite: victim selection x hot/cold x suspend, plus the
    sustainable-throughput cost of collecting under open-loop serving.

    Three studies, all hashed-seed deterministic (byte-identical across
    ``run.py --jobs`` values):

    1. **victim x hot/cold** — Zipf overwrite churn on a scaled 4-die
       drive: cost-benefit's age gate and the hot/cold append-point split
       each cut write amplification vs. the greedy baseline, and the
       wear-aware picker flattens the erase-count histogram;
    2. **suspend/throttle** — NDP tenants + write-heavy Zipf host I/O on
       the full drive, monolithic vs. per-page-copy collection: suspend
       cuts the host read p99 during collection;
    3. **serving under GC** — ``find_saturation`` with and without a
       preconditioned FTL: garbage collection measurably lowers the max
       sustainable sessions/sec under the p99 SLO."""
    rows: List[str] = []

    # -- study 1: victim policy x hot/cold (WA + wear) ------------------------
    # geometry calibrated so the multi-stream append points never exhaust
    # the OP slack: zero overflow growth, WA deltas are policy-only
    n_writes = 1500 if smoke else 6000
    base = FTLConfig(blocks_per_die=32, pages_per_block=8, op_ratio=0.28,
                     prefill=0.85, gc_reserve_blocks=1)
    print(f"\n== GC victim policy x hot/cold (zipf 0.99 overwrite churn, "
          f"{n_writes} writes, 4-die scaled drive)")
    print(f"  {'victim':>13s} {'hot_cold':>8s} {'WA':>6s} {'erases':>7s} "
          f"{'wear_flat':>10s} {'max_wear':>9s}")
    for vp in ("greedy", "cost_benefit", "wear_aware"):
        for hc in (False, True):
            cfg = dataclasses.replace(base, victim_policy=vp, hot_cold=hc)
            s = _drive_policy(cfg, n_writes)
            print(f"  {vp:>13s} {str(hc):>8s} {s.write_amplification:6.2f} "
                  f"{s.blocks_erased:7d} {s.wear_flatness:10.3f} "
                  f"{s.max_erase_count:9d}")
            tag = f"{vp}/{'hc' if hc else 'mixed'}"
            rows.append(csv_row(f"gcpolicy/wa/{tag}",
                                f"{s.write_amplification:.4f}", "x"))
            rows.append(csv_row(f"gcpolicy/wear_flatness/{tag}",
                                f"{s.wear_flatness:.4f}",
                                f"max_wear={s.max_erase_count}"))

    # -- study 2: GC suspend vs host tail latency -----------------------------
    n_req = 160 if smoke else 512
    # reserve held constant across the pair: the p99 delta is suspend-only
    geometry = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.12,
                         prefill=0.9, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=250_000, read_fraction=0.3, n_requests=n_req,
                      zipf_theta=0.95,
                      n_logical_pages=geometry.logical_pages())
    traces = [get_trace(wl, "tiny") for wl in workloads]
    print(f"\n== GC suspend/throttle ({'+'.join(workloads)}, {policy} "
          f"policy, zipf 0.95 write-heavy host I/O)")
    for suspend in (False, True):
        cfg = dataclasses.replace(geometry, gc_suspend=suspend)
        mix = simulate_mix(traces, policy, io_stream=io, ftl=cfg,
                           compute_solo=False)
        s = mix.ftl
        mode = "suspend" if suspend else "monolithic"
        print(f"  {mode:>10s} WA={s.write_amplification:5.2f} "
              f"io_p99={mix.host_io.p(99)/1e3:9.1f}us "
              f"during_gc_p99={s.p_during_gc(99)/1e3:9.1f}us "
              f"suspensions={s.gc_suspensions:6d}")
        rows.append(csv_row(f"gcpolicy/suspend_io_p99/{mode}",
                            f"{mix.host_io.p(99)/1e3:.1f}",
                            f"us,during_gc={s.p_during_gc(99)/1e3:.1f}"))

    # -- study 3: saturation on a collecting drive ----------------------------
    sat_iters = 2 if smoke else 4
    n_sessions = 24 if smoke else 48
    catalog = SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)
    serve_ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                          prefill=0.9, gc_suspend=True, gc_reserve_blocks=1)
    serve_io = HostIOStream(rate_iops=12_000, read_fraction=0.5,
                            n_requests=128, zipf_theta=0.95,
                            n_logical_pages=serve_ftl.logical_pages())
    kw = dict(slo_p99_ns=2.0e6, rate_lo=4000, rate_hi=16_000,
              iters=sat_iters, n_sessions=n_sessions, seed=9,
              io_stream=serve_io,
              serving=ServingConfig(keep_session_results=False,
                                    warmup_ns=1e5, cooldown_ns=1e5))
    print(f"\n== saturation under GC ({policy} policy, p99 SLO 2.0ms, "
          f"suspend collector, 28% OP, 90% prefill)")
    ideal = find_saturation(catalog, policy, **kw)
    collecting = find_saturation(catalog, policy, ftl=serve_ftl, **kw)
    stolen = ideal.rate_per_sec - collecting.rate_per_sec
    print(f"  idealized drive: {ideal.rate_per_sec:8.1f} sessions/s")
    print(f"  collecting:      {collecting.rate_per_sec:8.1f} sessions/s "
          f"(GC steals {stolen:.0f}/s)")
    rows.append(csv_row("gcpolicy/saturation/ideal",
                        f"{ideal.rate_per_sec:.1f}", "per_sec"))
    rows.append(csv_row("gcpolicy/saturation/collecting",
                        f"{collecting.rate_per_sec:.1f}",
                        f"per_sec,stolen={stolen:.1f}"))
    return rows
