"""Capacity-pressure sweep: exercises the eviction + lazy-coherence
machinery (the paper's "footprint exceeds capacity" regime, §5.4), the
fault-replay path (§4.4 failure handling), the multi-tenant interference
regime (several traces + host I/O sharing one fabric), and the FTL
garbage-collection interference sweep (write amplification vs.
over-provisioning under Zipf-skewed writes)."""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import csv_row
from repro.sim import (FTLConfig, HostIOStream, SimConfig, jain_fairness,
                       simulate, simulate_mix)
from repro.workloads import get_trace, sim_config_for


def pressure_sweep(workload: str = "aes") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    print(f"\n== capacity-pressure sweep ({workload}, conduit policy)")
    base = None
    for pressure in (0.0, 0.5, 0.8, 0.95):
        cfg = sim_config_for(workload, tr, pressure=pressure)
        r = simulate(tr, "conduit", config=cfg)
        if base is None:
            base = r.makespan_ns
        slow = r.makespan_ns / base
        print(f"  pressure={pressure:4.2f} makespan={r.makespan_ns/1e6:9.2f}ms "
              f"({slow:5.2f}x) evictions={r.evictions:6d} "
              f"coherence_syncs={r.coherence_syncs:5d}")
        rows.append(csv_row(f"pressure/{workload}/{pressure}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,evictions={r.evictions},"
                            f"syncs={r.coherence_syncs}"))
    return rows


def fault_replay(workload: str = "jacobi1d") -> List[str]:
    rows = []
    tr = get_trace(workload, "paper")
    cfg0 = sim_config_for(workload, tr)
    print(f"\n== transient-fault replay ({workload}, conduit policy)")
    base = simulate(tr, "conduit", config=cfg0).makespan_ns
    for rate in (0.0, 0.01, 0.05):
        cfg = sim_config_for(workload, tr, fail_rate=rate)
        r = simulate(tr, "conduit", config=cfg)
        print(f"  fail_rate={rate:5.2f} makespan={r.makespan_ns/1e6:8.2f}ms "
              f"({r.makespan_ns/base:5.2f}x) replays={r.replays}")
        rows.append(csv_row(f"fault/{workload}/{rate}",
                            f"{r.makespan_ns/1e3:.1f}",
                            f"us,replays={r.replays}"))
    return rows


def tenant_interference(workloads=("jacobi1d", "aes"),
                        policy: str = "conduit",
                        smoke: bool = False) -> List[str]:
    """Multi-tenant interference sweep: co-run the workloads on one shared
    fabric at increasing host-I/O intensity; report per-tenant slowdown
    vs. solo, Jain fairness, and host I/O p99.  ``smoke`` shrinks the
    sweep to a CI-sized configuration (entry-point rot check)."""
    rows = []
    n_req = 32 if smoke else 128
    levels = (0, 100_000) if smoke else (0, 25_000, 100_000, 400_000)
    traces = [get_trace(wl, "tiny") for wl in workloads]
    print(f"\n== multi-tenant interference ({'+'.join(workloads)}, "
          f"{policy} policy)")
    # the solo baselines are identical across iops levels: compute once
    solo = {f"t{i}:{wl}": simulate(tr, policy).makespan_ns
            for i, (wl, tr) in enumerate(zip(workloads, traces))}
    for iops in levels:
        io = (HostIOStream(rate_iops=iops, n_requests=n_req)
              if iops else None)
        mix = simulate_mix(traces, policy, io_stream=io, compute_solo=False)
        slow = {k: mix.tenant(k).makespan_ns / v for k, v in solo.items()}
        fairness = jain_fairness(list(slow.values()))
        io_p99 = mix.host_io.p(99) / 1e3 if mix.host_io else 0.0
        sl_txt = " ".join(f"{k.split(':')[1]}={v:5.2f}x"
                          for k, v in slow.items())
        print(f"  io={iops:7d}iops {sl_txt} fairness={fairness:.3f} "
              f"io_p99={io_p99:8.1f}us")
        for k, v in slow.items():
            rows.append(csv_row(f"mix/{k.split(':')[1]}/{iops}",
                                f"{v:.4f}", "slowdown_x"))
        rows.append(csv_row(f"mix/fairness/{iops}", f"{fairness:.4f}", ""))
        if mix.host_io:
            rows.append(csv_row(f"mix/io_p99/{iops}", f"{io_p99:.1f}", "us"))
    return rows


def gc_interference(workloads=("jacobi1d", "aes"),
                    policy: str = "conduit",
                    smoke: bool = False) -> List[str]:
    """FTL garbage-collection interference sweep.

    For each over-provisioning level, co-run the NDP workloads with a
    write-heavy Zipf-skewed host I/O stream on a preconditioned (90 %
    prefilled) drive, GC off vs. on: identical streams and placement, so
    the write-amplification / host-p99 / tenant-slowdown deltas are
    attributable purely to the collector's page copies and erases on the
    shared die/channel pools."""
    rows = []
    n_req = 160 if smoke else 512
    geometry = dict(blocks_per_die=4, pages_per_block=8, prefill=0.9)
    traces = [get_trace(wl, "tiny") for wl in workloads]
    print(f"\n== GC interference ({'+'.join(workloads)}, {policy} policy, "
          f"zipf 0.95 write-heavy host I/O)")
    for op in (0.45, 0.28, 0.12):
        on_cfg = FTLConfig(op_ratio=op, **geometry)
        off_cfg = dataclasses.replace(on_cfg, gc_enabled=False)
        io = HostIOStream(rate_iops=250_000, read_fraction=0.3,
                          n_requests=n_req, zipf_theta=0.95,
                          n_logical_pages=on_cfg.logical_pages())
        off = simulate_mix(traces, policy, io_stream=io, ftl=off_cfg,
                           compute_solo=False)
        on = simulate_mix(traces, policy, io_stream=io, ftl=on_cfg,
                          compute_solo=False)
        wa = on.ftl.write_amplification
        p99_off = off.host_io.p(99) / 1e3
        p99_on = on.host_io.p(99) / 1e3
        slow = {r.tenant: on.tenant(r.tenant).makespan_ns / r.makespan_ns
                for r in off.tenants}
        sl_txt = " ".join(f"{k.split(':')[1]}={v:5.2f}x"
                          for k, v in slow.items())
        print(f"  op={op:4.2f} WA={wa:5.2f} gc={on.ftl.gc_invocations:4d} "
              f"erases={on.ftl.blocks_erased:4d} "
              f"io_p99={p99_off:8.1f}->{p99_on:8.1f}us "
              f"(during_gc={on.ftl.p_during_gc(99)/1e3:8.1f}us) {sl_txt}")
        rows.append(csv_row(f"gc/wa/{op}", f"{wa:.4f}", "x"))
        rows.append(csv_row(f"gc/erases/{op}", f"{on.ftl.blocks_erased}", ""))
        rows.append(csv_row(f"gc/io_p99/{op}", f"{p99_on:.1f}",
                            f"us,baseline={p99_off:.1f}"))
        for k, v in slow.items():
            rows.append(csv_row(f"gc/slowdown/{k.split(':')[1]}/{op}",
                                f"{v:.4f}", "x_vs_gc_off"))
    return rows
