"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads dryrun_baseline.json (produced by ``python -m repro.launch.dryrun
--all``) and renders the per-(arch x shape) three-term table.  When the
JSON is absent (e.g. CI without the 512-device sweep) it falls back to the
analytic ConduitScheduler estimates, clearly labeled.
"""
from __future__ import annotations

import json
import os
from typing import List

from benchmarks.common import csv_row
from repro import configs
from repro.configs.shapes import SHAPES
from repro.distributed import ConduitScheduler
from repro.hw.tpu_spec import TPU_V5E

_ROOT = os.path.join(os.path.dirname(__file__), "..")
DRYRUN_JSON = os.path.join(_ROOT, "dryrun_optimized.json")
if not os.path.exists(DRYRUN_JSON):
    DRYRUN_JSON = os.path.join(_ROOT, "dryrun_baseline.json")
BASELINE_JSON = os.path.join(_ROOT, "dryrun_baseline.json")


def _fmt(rec) -> str:
    r = rec["roofline"]
    return (f"{rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:8s} "
            f"C={r['compute_s']*1e3:9.3f}ms M={r['memory_s']*1e3:9.3f}ms "
            f"X={r['collective_s']*1e3:9.3f}ms -> {r['dominant']:10s} "
            f"useful={100*(rec.get('useful_flop_ratio') or 0):5.1f}%")


def roofline_table(mesh: str = "16x16") -> List[str]:
    rows: List[str] = []
    if os.path.exists(DRYRUN_JSON):
        with open(DRYRUN_JSON) as f:
            recs = json.load(f)
        print(f"\n== §Roofline: measured dry-run terms ({mesh}, per chip)")
        for rec in recs:
            if rec.get("skipped"):
                if mesh == "16x16":
                    print(f"{rec['arch']:22s} {rec['shape']:12s} SKIP "
                          f"({rec['skipped'][:60]}...)")
                    rows.append(csv_row(
                        f"roofline/{rec['arch']}/{rec['shape']}", "skip",
                        "long_500k full-attention"))
                continue
            if rec.get("error") or rec.get("mesh") != mesh:
                continue
            print(_fmt(rec))
            r = rec["roofline"]
            rows.append(csv_row(
                f"roofline/{rec['arch']}/{rec['shape']}",
                f"{r['bound_s']*1e6:.1f}",
                f"us_bound,dominant={r['dominant']},"
                f"useful={(rec.get('useful_flop_ratio') or 0):.3f}"))
    else:
        print("\n== §Roofline: dryrun_baseline.json missing — analytic "
              "estimates (ConduitScheduler)")
        sched = ConduitScheduler()
        for arch in configs.ARCHS:
            cfg = configs.get(arch)
            for shape, spec in SHAPES.items():
                from repro.configs.shapes import applicable
                ok, _ = applicable(cfg, shape)
                if not ok:
                    continue
                best, _ = sched.choose(cfg, spec.kind, spec.global_batch,
                                       spec.seq_len, 256, 16, 16)
                rows.append(csv_row(f"roofline_est/{arch}/{shape}",
                                    f"{best.total_s*1e6:.1f}",
                                    "us_estimated"))
    return rows


def multi_pod_check() -> List[str]:
    """Multi-pod pass/fail summary (the MINIMUM deliverable)."""
    rows: List[str] = []
    if not os.path.exists(DRYRUN_JSON):
        print("  (dry-run JSON missing; run repro.launch.dryrun --all)")
        return rows
    with open(DRYRUN_JSON) as f:
        recs = json.load(f)
    ok = sum(1 for r in recs if r.get("mesh") == "2x16x16"
             and "roofline" in r)
    fail = sum(1 for r in recs if r.get("mesh") == "2x16x16"
               and r.get("error"))
    skip = sum(1 for r in recs if r.get("skipped"))
    print(f"\n== §Dry-run multi-pod (2x16x16, 512 chips): "
          f"{ok} compiled, {fail} failed, {skip} skipped cells")
    rows.append(csv_row("dryrun/multi_pod_ok", ok, f"fail={fail}"))
    single_ok = sum(1 for r in recs if r.get("mesh") == "16x16"
                    and "roofline" in r)
    rows.append(csv_row("dryrun/single_pod_ok", single_ok, ""))
    return rows


HILLCLIMB_CELLS = (("qwen3-4b", "decode_32k"),
                   ("deepseek-v2-236b", "train_4k"),
                   ("minicpm-2b", "train_4k"))


def perf_deltas() -> List[str]:
    """§Perf: baseline vs optimized roofline terms for the three
    hillclimbed cells (both sweeps committed)."""
    rows: List[str] = []
    if not (os.path.exists(BASELINE_JSON) and os.path.exists(DRYRUN_JSON)
            and BASELINE_JSON != DRYRUN_JSON):
        print("  (need both dryrun_baseline.json and dryrun_optimized.json)")
        return rows
    with open(BASELINE_JSON) as f:
        base = {(r["arch"], r.get("shape"), r.get("mesh")): r
                for r in json.load(f) if "roofline" in r}
    with open(DRYRUN_JSON) as f:
        opt = {(r["arch"], r.get("shape"), r.get("mesh")): r
               for r in json.load(f) if "roofline" in r}
    print("\n== §Perf: baseline -> optimized (16x16, bound term seconds)")
    for arch, shape in HILLCLIMB_CELLS:
        kb = base.get((arch, shape, "16x16"))
        ko = opt.get((arch, shape, "16x16"))
        if not kb or not ko:
            continue
        b, o = kb["roofline"]["bound_s"], ko["roofline"]["bound_s"]
        ub = (kb.get("useful_flop_ratio") or 0)
        uo = (ko.get("useful_flop_ratio") or 0)
        print(f"  {arch:22s} {shape:12s} bound {b:9.3f}s -> {o:9.3f}s "
              f"({b/max(o,1e-12):5.1f}x)  useful {100*ub:4.1f}% -> "
              f"{100*uo:4.1f}%")
        rows.append(csv_row(f"perf/{arch}/{shape}",
                            f"{b/max(o,1e-12):.2f}",
                            f"bound_speedup,useful={uo:.3f}"))
    return rows
