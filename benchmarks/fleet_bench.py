"""Fleet serving benchmark: placement, hedging and steering at rack scale.

Three studies of :mod:`repro.sim.fleet`, all hashed-seed deterministic
(byte-identical across ``benchmarks/run.py --jobs`` values):

1. **fleet saturation vs N with a straggler** — the headline scaling
   curve: :func:`~repro.sim.fleet.find_fleet_saturation` bisects fleet
   sessions/sec at a *fleet* p99 SLO (sample-merged across drives, never
   averaged) while drive 0 carries a write-heavy host stream on a tight
   FTL — it is mid-GC for the whole run, and a collecting drive cannot
   meet the SLO at *any* rate (every session it serves lands in the
   merged p99's tail).  With replication 2 and read steering on, the
   fleet routes around it: saturation scales like N-1 clean drives.
2. **placement x hedging grid** — one batched lockstep sweep
   (:func:`~repro.sim.sweep.batched_find_fleet_saturation`) over
   {hash, consistent, heat} x {hedging off, on} with replication 2 on
   the straggler fleet: what each routing mechanism buys in sustainable
   fleet sessions/sec at the same SLO.
3. **hedging vs steering vs neither** — fixed offered rate on the
   straggler fleet, replication 2: read steering sinks the collecting
   drive to the back of every preference order and recovers part of the
   fleet tail; hedging races the two best replicas and cancels the
   loser's queued twin.  The study reports fleet p99, the straggler's
   p99 and the mechanism counters side by side.
"""
from __future__ import annotations

from typing import List, Optional

from benchmarks.common import csv_row
from repro.sim import (CatalogEntry, DriveProfile, FleetConfig,
                       FleetSweepLane, FTLConfig, HostIOStream,
                       PoissonArrivals, ServingConfig, SessionCatalog,
                       batched_find_fleet_saturation, find_fleet_saturation,
                       simulate_fleet)
from repro.workloads import get_trace

#: fleet p99 session-latency SLO for the saturation finder (ns) — the
#: serving_bench calibration (a few x the uncontended single-drive p99)
SLO_P99_NS = 1.5e6
TRIM_FRACTION = 0.1


def _catalog() -> SessionCatalog:
    return SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)


def _scfg(rate_per_sec: float, n_sessions: int) -> ServingConfig:
    trim = TRIM_FRACTION * n_sessions / rate_per_sec * 1e9
    return ServingConfig(warmup_ns=trim, cooldown_ns=trim,
                         keep_session_results=False,
                         little_law_warn_tol=float("inf"))


def _straggler(smoke: bool) -> DriveProfile:
    """Drive-0 override: write-heavy churn on a tight FTL — the drive
    collects garbage for the whole serving window."""
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                    prefill=0.9, gc_suspend=True, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=150_000, read_fraction=0.1,
                      n_requests=600 if smoke else 2400,
                      zipf_theta=0.9, n_logical_pages=ftl.logical_pages(),
                      seed=11)
    return DriveProfile(io_stream=io, ftl=ftl)


def _fleet(n: int, prof: DriveProfile, placement: object = "hash",
           replication: int = 1, steering: bool = False,
           hedging: bool = False,
           max_inflight: Optional[int] = None) -> FleetConfig:
    return FleetConfig(n_drives=n, placement=placement,
                       replication=replication, steering=steering,
                       hedging=hedging, max_inflight=max_inflight,
                       profiles=((0, prof),))


def fleet_serving(policy: str = "conduit", smoke: bool = False) -> List[str]:
    """Fleet saturation vs N + placement x hedging grid + hedging vs
    steering vs neither, all with a mid-GC straggler on drive 0."""
    rows: List[str] = []
    catalog = _catalog()
    prof = _straggler(smoke)
    n_sessions = 32 if smoke else 96
    sat_iters = 2 if smoke else 5

    # -- study 1: fleet saturation vs N (drive 0 mid-GC, steered) -------------
    ns = (2, 3) if smoke else (2, 4, 8)
    print(f"\n== fleet saturation vs N ({policy} policy, hash placement, "
          f"replication=2 + read steering, drive 0 mid-GC straggler, "
          f"fleet p99 SLO {SLO_P99_NS / 1e3:.0f} us)")
    for n in ns:
        rate_hi = 18_000.0 * n
        # the offered burst must be long enough to saturate N-1 drives
        n_sess1 = n_sessions if smoke else max(n_sessions, 24 * n)
        scfg = _scfg(rate_hi, n_sess1)
        base = PoissonArrivals(rate_per_sec=100.0, n_sessions=n_sess1,
                               seed=9)
        sat = find_fleet_saturation(
            catalog, base, policy, slo_p99_ns=SLO_P99_NS, rate_lo=500.0,
            rate_hi=rate_hi, iters=sat_iters, serving=scfg,
            fleet=_fleet(n, prof, replication=2, steering=True))
        last = sat.probes[-1]
        print(f"  N={n}  saturation={sat.rate_per_sec:8.1f}/s  "
              f"per_survivor={sat.rate_per_sec / max(n - 1, 1):7.1f}/s  "
              f"avail={last.availability:5.3f} ({len(sat.probes)} probes)")
        rows.append(csv_row(f"fleet/saturation/n{n}",
                            f"{sat.rate_per_sec:.1f}",
                            f"per_sec,slo_p99_us={SLO_P99_NS / 1e3:.0f}"))

    # -- study 2: placement x hedging grid at the fleet p99 SLO ---------------
    n = 3 if smoke else 4
    rate_hi = 18_000.0 * n
    scfg = _scfg(rate_hi, n_sessions)
    placements = ("hash", "consistent", "heat")
    lanes = [FleetSweepLane(policy,
                            fleet=_fleet(n, prof, placement=pl,
                                         replication=2, hedging=hedge),
                            seed=9, n_sessions=n_sessions)
             for pl in placements for hedge in (False, True)]
    sats = batched_find_fleet_saturation(
        catalog, lanes, slo_p99_ns=SLO_P99_NS, rate_lo=500.0,
        rate_hi=rate_hi, iters=sat_iters, serving=scfg)
    print(f"\n== placement x hedging grid (N={n}, replication=2, drive 0 "
          f"mid-GC) — fleet sessions/sec at fleet p99 SLO")
    print(f"  {'placement':>12s} {'hedging':>7s} {'saturation':>12s}")
    for (pl, hedge), sat in zip(
            [(pl, h) for pl in placements for h in (False, True)], sats):
        tag = "on" if hedge else "off"
        print(f"  {pl:>12s} {tag:>7s} {sat.rate_per_sec:10.1f}/s")
        rows.append(csv_row(f"fleet/grid/{pl}/hedge_{tag}",
                            f"{sat.rate_per_sec:.1f}",
                            f"per_sec,n={n},replication=2"))

    # -- study 3: hedging vs steering vs neither at a fixed rate --------------
    rate = 2_000.0 * n
    arrivals = PoissonArrivals(rate_per_sec=rate, n_sessions=n_sessions,
                               seed=9)
    scfg3 = _scfg(rate, n_sessions)
    modes = (("neither", dict()),
             ("steering", dict(steering=True)),
             ("hedging", dict(hedging=True)))
    print(f"\n== hedging vs steering vs neither (N={n}, replication=2, "
          f"{rate:.0f}/s offered, drive 0 mid-GC)")
    print(f"  {'mode':>9s} {'fleet_p99_us':>12s} {'straggler_p99_us':>16s} "
          f"{'d0_done':>7s} {'steered':>7s} {'hedged':>6s} "
          f"{'cancelled':>9s}")
    p99_by_mode = {}
    for mode, kw in modes:
        res = simulate_fleet(catalog, arrivals, policy, serving=scfg3,
                             fleet=_fleet(n, prof, replication=2, **kw))
        p99 = res.p(99)
        d0_p99 = res.per_drive_p(99)[0]
        p99_by_mode[mode] = p99
        print(f"  {mode:>9s} {p99 / 1e3:12.1f} {d0_p99 / 1e3:16.1f} "
              f"{res.drives[0].n_completed:7d} {res.n_steered:7d} "
              f"{res.n_hedged:6d} {res.n_cancelled:9d}")
        rows.append(csv_row(f"fleet/straggler/{mode}/fleet_p99",
                            f"{p99 / 1e3:.1f}",
                            f"us,straggler_p99_us={d0_p99 / 1e3:.1f}"))
    if p99_by_mode["neither"] > 0:
        rec = 1.0 - p99_by_mode["steering"] / p99_by_mode["neither"]
        print(f"  steering recovers {rec:.1%} of the unsteered fleet p99")
        rows.append(csv_row("fleet/straggler/steering_recovery",
                            f"{rec:.4f}", "fraction_of_neither_p99"))
    return rows
