"""Open-loop serving benchmark: latency-throughput curves + saturation.

The serving regime's two headline artifacts, per offloading policy
(conduit vs. the BW/DM baselines):

* the **hockey-stick curve** — session p50/p99 latency and completed
  throughput at increasing offered load (flat, flat, knee, cliff), and
* the **saturation point** — :func:`repro.sim.serving.find_saturation`'s
  max sustainable sessions/sec under a p99 session-latency SLO with zero
  admission rejections.

Sessions are drawn from a weighted two-kind catalog of the seed workloads
(3x ``jacobi1d`` : 1x ``xor_filter``, the short-interactive vs.
long-batch mix) with Poisson arrivals; everything is hashed-seed
deterministic, so the suite's output is byte-identical across
``benchmarks/run.py --jobs`` values.  ``smoke`` shrinks the grid to a
CI-sized rot check."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row
from repro.sim import (CatalogEntry, PoissonArrivals, ServingConfig,
                       SessionCatalog, find_saturation, simulate_serving)
from repro.workloads import get_trace

#: p99 session-latency SLO for the saturation finder (ns).  Calibrated a
#: few x above the uncontended p99 so the knee — not the floor — decides.
SLO_P99_NS = 1.5e6

#: steady-state trimming: skip this fraction of the expected arrival span
#: at each end (absolute trims would swallow short high-rate spans)
TRIM_FRACTION = 0.1


def _scfg(rate_per_sec: float, n_sessions: int) -> ServingConfig:
    trim = TRIM_FRACTION * n_sessions / rate_per_sec * 1e9
    # The curve deliberately sweeps past the saturation knee, and all
    # points share one trim sized for the fastest rate, so the ragged
    # Little's-law ratio is expected here (the bench prints it as its
    # own column) — opt out of the per-run consistency warning.
    return ServingConfig(warmup_ns=trim, cooldown_ns=trim,
                         keep_session_results=False,
                         little_law_warn_tol=float("inf"))


def _catalog() -> SessionCatalog:
    return SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)


def serving_curve(policies=("conduit", "bw", "dm"),
                  smoke: bool = False) -> List[str]:
    """Latency-throughput curve + saturation point per policy."""
    rows: List[str] = []
    catalog = _catalog()
    n_sessions = 24 if smoke else 96
    rates = (1000, 8000) if smoke else (1000, 2000, 4000, 8000, 16000)
    sat_iters = 2 if smoke else 5
    # one trim config for the whole suite, sized for the shortest
    # (highest-rate) arrival span, so the curve points and the saturation
    # probes measure the same way and every window is non-empty
    scfg = _scfg(rates[-1], n_sessions)
    print(f"\n== open-loop serving ({'+'.join(e.name for e in catalog.entries)}"
          f" catalog, poisson arrivals, {n_sessions} sessions/point)")
    for policy in policies:
        print(f"  -- {policy}")
        for rate in rates:
            arr = PoissonArrivals(rate_per_sec=rate, n_sessions=n_sessions,
                                  seed=9)
            res = simulate_serving(catalog, arr, policy, serving=scfg)
            util = max(res.utilization.values(), default=0.0)
            print(f"     offered={rate:6d}/s completed="
                  f"{res.completed_rate_per_sec:8.1f}/s "
                  f"p50={res.p(50)/1e3:8.1f}us p99={res.p(99)/1e3:8.1f}us "
                  f"rej={res.n_rejected:3d} util={util:5.3f} "
                  f"little={res.little_law_ratio():5.3f}")
            rows.append(csv_row(f"serving/{policy}/{rate}/p99",
                                f"{res.p(99)/1e3:.1f}",
                                f"us,p50={res.p(50)/1e3:.1f}"))
            rows.append(csv_row(f"serving/{policy}/{rate}/completed",
                                f"{res.completed_rate_per_sec:.1f}",
                                f"per_sec,rejected={res.n_rejected}"))
        sat = find_saturation(catalog, policy, slo_p99_ns=SLO_P99_NS,
                              rate_lo=rates[0], rate_hi=rates[-1],
                              iters=sat_iters, n_sessions=n_sessions,
                              seed=9, serving=scfg)
        print(f"     saturation @ p99<={SLO_P99_NS/1e3:.0f}us: "
              f"{sat.rate_per_sec:8.1f} sessions/s "
              f"(bracket {sat.bracket[0]:.1f}..{sat.bracket[1]:.1f}, "
              f"{len(sat.probes)} probes)")
        rows.append(csv_row(f"serving/{policy}/saturation",
                            f"{sat.rate_per_sec:.1f}",
                            f"per_sec,slo_p99_us={SLO_P99_NS/1e3:.0f}"))
    return rows
