"""Benchmark entry point: one function per paper table/figure plus the
roofline/dry-run, pressure, fault-replay, kernel and simulator-perf benches.

Prints human-readable tables followed by a machine-readable
``name,value,derived`` CSV block.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig7a,table3
  PYTHONPATH=src python -m benchmarks.run --only mix,gc --jobs 4
  PYTHONPATH=src python -m benchmarks.run --only gc --profile

Parallelism: ``--jobs N`` farms the selected suites across N worker
processes.  Every simulation suite is internally seeded (hashed
pseudo-random streams, no global RNG), so the workers share nothing and
the output — both the per-suite tables and the CSV block — is printed in
the deterministic ``--only`` order regardless of completion order:
``--jobs 1`` and ``--jobs N`` produce identical suite output for every
deterministic suite.  (The wall-clock-measuring suites — ``simperf``,
``perf`` — print timings, which naturally vary run to run and are skewed
when siblings saturate the CPU; run those with ``--jobs 1`` when the
numbers matter.)

Profiling: ``--profile`` wraps the selected suites in cProfile and prints
the top-20 cumulative entries afterwards, so perf work starts from data.
It forces sequential execution (a profile of worker stubs is useless).
``--profile-out PATH`` (implies ``--profile``) additionally dumps the
full pstats file for offline digging (snakeviz, ``pstats.Stats(PATH)``).
"""
from __future__ import annotations

import argparse
import contextlib
import functools
import io
import json
import sys
import time
from typing import Dict, List, Optional, Tuple

#: suites whose signature takes a ``smoke`` kwarg (CI-sized shrink)
SMOKE_AWARE = {"mix", "gc", "gc_policies", "serving", "faults", "fleet"}


def _suite_table() -> Dict:
    from benchmarks import (faults_bench, fleet_bench, kernel_bench,
                            paper_figures, perf_bench, pressure_bench,
                            roofline_bench, serving_bench)

    return {
        "table3": paper_figures.table3_characterize,
        "fig7a": paper_figures.fig5_fig7a_speedup,
        "fig7b": paper_figures.fig7b_energy,
        "fig8": paper_figures.fig8_tail_latency,
        "fig9": paper_figures.fig9_decisions,
        "fig10": paper_figures.fig10_timeline,
        "overhead": paper_figures.overhead_analysis,
        "kernels": kernel_bench.kernel_microbench,
        "latmodel": kernel_bench.resource_latency_table,
        "pressure": pressure_bench.pressure_sweep,
        "fault": pressure_bench.fault_replay,
        "mix": pressure_bench.tenant_interference,
        "gc": pressure_bench.gc_interference,
        "gc_policies": pressure_bench.gc_policies,
        "serving": serving_bench.serving_curve,
        "faults": faults_bench.fault_injection,
        "fleet": fleet_bench.fleet_serving,
        "roofline": roofline_bench.roofline_table,
        "dryrun": roofline_bench.multi_pod_check,
        "perf": roofline_bench.perf_deltas,
        "simperf": perf_bench.perf_suite,
    }


def _run_one(name: str, smoke: bool) -> Tuple[str, List[str], str, Optional[str]]:
    """Run one suite with captured stdout.

    Top-level so it pickles for worker processes; returns
    ``(name, csv_rows, captured_output, error)``."""
    fn = _suite_table().get(name)
    if fn is None:
        return name, [f"error/{name},unknown suite,"], "", f"unknown suite {name}"
    if smoke and name in SMOKE_AWARE:
        fn = functools.partial(fn, smoke=True)
    buf = io.StringIO()
    try:
        with contextlib.redirect_stdout(buf):
            rows = fn()
        return name, rows, buf.getvalue(), None
    except Exception as e:  # pragma: no cover - exercised via failed suites
        return name, [f"error/{name},{e},"], buf.getvalue(), str(e)


def run_suites(wanted: List[str], smoke: bool = False, jobs: int = 1,
               profile: bool = False,
               profile_out: Optional[str] = None) -> Tuple[List[str], List[str]]:
    """Run ``wanted`` suites; returns ``(csv_rows, failed_names)``.

    Output (tables + CSV rows) is assembled in ``wanted`` order for any
    ``jobs`` value, so N=1 and N>1 runs are byte-identical."""
    wanted = [w.strip() for w in wanted]
    csv_rows = ["name,value,derived"]
    failed: List[str] = []

    profiler = None
    if profile_out is not None:
        profile = True
    if profile:
        import cProfile
        jobs = 1
        profiler = cProfile.Profile()
        profiler.enable()

    if jobs <= 1:
        results = [_run_one(name, smoke) for name in wanted]
    else:
        import multiprocessing
        from concurrent.futures import ProcessPoolExecutor
        # spawn, not fork: jax (imported by the workload suites) runs
        # background threads, and forking a threaded process can deadlock
        ctx = multiprocessing.get_context("spawn")
        with ProcessPoolExecutor(max_workers=jobs, mp_context=ctx) as pool:
            futures = [pool.submit(_run_one, name, smoke) for name in wanted]
            results = [f.result() for f in futures]   # wanted order

    if profiler is not None:
        profiler.disable()

    for name, rows, output, error in results:
        if output:
            print(output, end="")
        if error is not None:
            print(f"[benchmarks] suite {name} failed: {error}",
                  file=sys.stderr)
            failed.append(name)
        csv_rows.extend(rows)

    if profiler is not None:
        import pstats
        if profile_out is not None:
            profiler.dump_stats(profile_out)
            print(f"[benchmarks] full profile written to {profile_out}")
        print("\n===== cProfile (top 20 cumulative) =====")
        pstats.Stats(profiler).sort_stats("cumulative").print_stats(20)
    return csv_rows, failed


def write_run_report(path: str, csv_rows: List[str],
                     failed: List[str], smoke: bool) -> None:
    """Structured run report: the CSV metrics plus a tail-latency blame
    summary from one telemetry-on serving run, stamped with the git SHA
    and hardware-spec hash so reports join across commits and refuse
    joins across spec changes (``repro.sim.analysis diff``)."""
    import hashlib

    from repro.hw.ssd_spec import DEFAULT_SSD
    from repro.sim import (CatalogEntry, FTLConfig, HostIOStream,
                           PoissonArrivals, ServingConfig, SessionCatalog,
                           TelemetryConfig, simulate_serving)
    from repro.sim.analysis import _git_sha, build_report
    from repro.workloads import get_trace

    # one small serving-under-GC run with the recorder on: post-hoc
    # analysis only, so the benchmark numbers above are never perturbed
    catalog = SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"))], seed=7)
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                    prefill=0.9, gc_reserve_blocks=1)
    res = simulate_serving(
        catalog,
        PoissonArrivals(rate_per_sec=4000,
                        n_sessions=12 if smoke else 32, seed=11),
        "conduit",
        serving=ServingConfig(keep_session_results=False,
                              little_law_warn_tol=float("inf")),
        io_stream=HostIOStream(rate_iops=40_000, read_fraction=0.7,
                               n_requests=64 if smoke else 256,
                               n_logical_pages=ftl.logical_pages()),
        ftl=ftl,
        telemetry=TelemetryConfig(spans=True, audit=True,
                                  interval_ns=20_000.0))
    metrics = {}
    for row in csv_rows[1:]:
        parts = row.split(",")
        if len(parts) >= 2:
            metrics[parts[0]] = {"value": parts[1],
                                 "derived": ",".join(parts[2:])}
    report = {
        "schema": "conduit-bench-report/v1",
        "git_sha": _git_sha(),
        "spec_sha": hashlib.sha256(
            repr(DEFAULT_SSD).encode()).hexdigest()[:16],
        "smoke": smoke,
        "failed_suites": failed,
        "metrics": metrics,
        "analysis": res.analysis(),
    }
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"[benchmarks] run report written to {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig7a,fig7b,fig8,fig9,fig10,table3,"
                         "overhead,roofline,pressure,fault,mix,gc,"
                         "gc_policies,serving,faults,fleet,kernels,simperf")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized configurations for smoke-aware suites "
                         "(mix, gc, gc_policies, serving): tiny sweeps "
                         "that only check the entry points still run")
    ap.add_argument("--jobs", type=int, default=1,
                    help="worker processes for independent suites (output "
                         "is identical for any N on deterministic suites; "
                         "timing suites like simperf belong on --jobs 1)")
    ap.add_argument("--profile", action="store_true",
                    help="wrap the selected suites in cProfile and print "
                         "the top-20 cumulative entries (forces --jobs 1)")
    ap.add_argument("--profile-out", default=None, metavar="PATH",
                    help="write the full pstats dump to PATH for offline "
                         "analysis (implies --profile)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write a structured JSON run report: the CSV "
                         "metrics plus a tail-latency blame summary, git "
                         "SHA and spec hash (conduit-bench-report/v1)")
    args = ap.parse_args()

    wanted = (args.only.split(",") if args.only else list(_suite_table()))
    t0 = time.time()
    csv_rows, failed = run_suites(wanted, smoke=args.smoke, jobs=args.jobs,
                                  profile=args.profile,
                                  profile_out=args.profile_out)
    print(f"\n[benchmarks] completed in {time.time()-t0:.0f}s")
    print("\n===== CSV =====")
    for row in csv_rows:
        print(row)
    if args.report is not None:
        write_run_report(args.report, csv_rows, failed, args.smoke)
    if failed:  # nonzero exit so the CI bench-smoke step actually gates
        sys.exit(f"[benchmarks] failing suites: {', '.join(failed)}")


if __name__ == "__main__":
    main()
