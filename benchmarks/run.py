"""Benchmark entry point: one function per paper table/figure plus the
roofline/dry-run, pressure, fault-replay and kernel benches.

Prints human-readable tables followed by a machine-readable
``name,value,derived`` CSV block.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig7a,table3
"""
from __future__ import annotations

import argparse
import functools
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig7a,fig7b,fig8,fig9,fig10,table3,"
                         "overhead,roofline,pressure,fault,mix,gc,kernels")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized configurations for smoke-aware suites "
                         "(mix, gc): tiny sweeps that only check the "
                         "entry points still run")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_figures, pressure_bench
    from benchmarks import roofline_bench

    suites = {
        "table3": paper_figures.table3_characterize,
        "fig7a": paper_figures.fig5_fig7a_speedup,
        "fig7b": paper_figures.fig7b_energy,
        "fig8": paper_figures.fig8_tail_latency,
        "fig9": paper_figures.fig9_decisions,
        "fig10": paper_figures.fig10_timeline,
        "overhead": paper_figures.overhead_analysis,
        "kernels": kernel_bench.kernel_microbench,
        "latmodel": kernel_bench.resource_latency_table,
        "pressure": pressure_bench.pressure_sweep,
        "fault": pressure_bench.fault_replay,
        "mix": pressure_bench.tenant_interference,
        "gc": pressure_bench.gc_interference,
        "roofline": roofline_bench.roofline_table,
        "dryrun": roofline_bench.multi_pod_check,
        "perf": roofline_bench.perf_deltas,
    }
    smoke_aware = {"mix", "gc"}
    wanted = (args.only.split(",") if args.only else list(suites))
    csv_rows = ["name,value,derived"]
    failed: list = []
    t0 = time.time()
    for name in wanted:
        name = name.strip()
        fn = suites.get(name)
        if fn is None:
            print(f"unknown suite {name}", file=sys.stderr)
            failed.append(name)
            continue
        if args.smoke and name in smoke_aware:
            fn = functools.partial(fn, smoke=True)
        try:
            csv_rows.extend(fn())
        except Exception as e:  # pragma: no cover
            print(f"[benchmarks] suite {name} failed: {e}", file=sys.stderr)
            csv_rows.append(f"error/{name},{e},")
            failed.append(name)
    print(f"\n[benchmarks] completed in {time.time()-t0:.0f}s")
    print("\n===== CSV =====")
    for row in csv_rows:
        print(row)
    if failed:  # nonzero exit so the CI bench-smoke step actually gates
        sys.exit(f"[benchmarks] failing suites: {', '.join(failed)}")


if __name__ == "__main__":
    main()
