"""Kernel micro-benchmarks: wall time per call (interpret mode on CPU — a
correctness-path timing, NOT TPU performance; TPU perf comes from the
roofline analysis) plus the analytic per-op latency table the simulator's
resources implement (Table 2 constants)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core.isa import Resource, VectorInstr, compute_latency_ns
from repro.hw.ssd_spec import DEFAULT_SSD
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def kernel_microbench() -> List[str]:
    rng = np.random.default_rng(0)
    rows = []
    print("\n== kernel microbench (interpret-mode wall time per call)")
    stack = jnp.asarray(rng.integers(-2**31, 2**31, (8, 64, 512),
                                     dtype=np.int32))
    a = jnp.asarray(rng.integers(-2**20, 2**20, (64, 512), dtype=np.int32))
    b = jnp.asarray(rng.integers(-2**20, 2**20, (64, 512), dtype=np.int32))
    a8 = jnp.asarray(rng.integers(-128, 128, (128, 256), dtype=np.int8))
    b8 = jnp.asarray(rng.integers(-128, 128, (256, 128), dtype=np.int8))
    q = jnp.asarray(rng.normal(size=(4, 128, 64)).astype(np.float32))
    cases = [
        ("mws_and", lambda: ops.mws_bitwise(stack, "and")),
        ("bitserial_add", lambda: ops.bitserial_add(a, b)),
        ("bitserial_mul", lambda: ops.bitserial_mul(a, b)),
        ("shift_add_mul", lambda: ops.shift_add_mul(a, b)),
        ("int8_matmul", lambda: ops.int8_matmul(a8, b8)),
        ("flash_attention", lambda: ops.flash_attention(q, q, q)),
    ]
    for name, fn in cases:
        us = _time(fn)
        print(f"  {name:16s} {us:10.1f} us/call")
        rows.append(csv_row(f"kernel/{name}", f"{us:.1f}", "us_per_call"))
    return rows


def resource_latency_table() -> List[str]:
    """Analytic per-page-op latency of each SSD compute resource (the
    simulator's Table 2-derived model)."""
    rows = []
    spec = DEFAULT_SSD
    page = spec.page_size
    print("\n== per-page-op latency model (us), 16KiB INT8 vectors")
    print(f"  {'op':10s} {'ISP':>9s} {'PuD':>9s} {'IFP':>9s} "
          f"{'IFP(latched)':>13s} {'CPU':>9s} {'GPU':>9s}")
    for op in ("and", "xor", "add", "mul", "cmp"):
        ins = VectorInstr(iid=0, op=op, vlen=page, elem_bytes=1,
                          srcs=(0, 1), dst=2)
        vals = []
        for r in (Resource.ISP, Resource.PUD, Resource.IFP):
            vals.append(compute_latency_ns(ins, r, spec) / 1e3)
        latched = compute_latency_ns(ins, Resource.IFP, spec,
                                     operands_latched=True) / 1e3
        cpu = compute_latency_ns(ins, Resource.HOST_CPU, spec) / 1e3
        gpu = compute_latency_ns(ins, Resource.HOST_GPU, spec) / 1e3
        print(f"  {op:10s} {vals[0]:9.2f} {vals[1]:9.2f} {vals[2]:9.2f} "
              f"{latched:13.2f} {cpu:9.2f} {gpu:9.2f}")
        rows.append(csv_row(f"latmodel/{op}",
                            "/".join(f"{v:.2f}" for v in vals),
                            "isp/pud/ifp_us"))
    return rows
