"""Fault injection benchmark: reliability cost curves on a serving drive.

Three studies of :mod:`repro.sim.faults`, all hashed-seed deterministic
(byte-identical across ``benchmarks/run.py --jobs`` values):

1. **goodput-at-SLO vs error rate** — the headline curve:
   :func:`~repro.sim.serving.find_saturation` with the error model armed
   at escalating raw bit error rates.  A read-heavy host stream shares
   the dies/channels with the NDP sessions, so every recovery-ladder
   stage (retry re-senses, soft decodes, parity rebuilds) steals real
   bandwidth from compute.  Goodput degrades monotonically: flat while
   hard-decode ECC absorbs the errors, then a cliff as the soft/rebuild
   tiers engage.
2. **wear-coupled errors, greedy vs wear-aware GC** — the drive is
   preconditioned with ``prewear_writes`` of Zipf churn under each
   victim policy, then serves sessions + mixed host I/O with
   ``rber_per_pe`` armed: reads of high-wear blocks walk the ladder
   more often, so the wear-aware picker's flatter histogram measurably
   cuts hard-decode failures and recovery work vs. greedy.
3. **degradation endgame** — uncorrectable-grade errors on a tiny
   drive: blocks retire (survivors relocated through the GC machinery),
   the reserve drains, dies degrade to read-only, and every failed
   write/read is surfaced and counted — the conservation story under
   the worst case.
"""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import csv_row
from repro.sim import (CatalogEntry, FaultConfig, FTLConfig, HostIOStream,
                       PoissonArrivals, ServingConfig, SessionCatalog,
                       find_saturation, simulate_serving)
from repro.workloads import get_trace

#: p99 session-latency SLO for the saturation finder (ns) — the
#: serving_bench calibration (a few x the uncontended p99)
SLO_P99_NS = 1.5e6
TRIM_FRACTION = 0.1


def _catalog() -> SessionCatalog:
    return SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)


def _scfg(rate_per_sec: float, n_sessions: int) -> ServingConfig:
    trim = TRIM_FRACTION * n_sessions / rate_per_sec * 1e9
    return ServingConfig(warmup_ns=trim, cooldown_ns=trim,
                         keep_session_results=False,
                         little_law_warn_tol=float("inf"))


def fault_injection(policy: str = "conduit", smoke: bool = False) -> List[str]:
    """Saturation/goodput vs error rate + wear-aware GC payoff +
    degradation endgame."""
    rows: List[str] = []
    catalog = _catalog()

    # -- study 1: goodput-at-SLO vs injected error rate -----------------------
    n_sessions = 24 if smoke else 96
    sat_iters = 2 if smoke else 5
    rbers = (0.0, 1e-3) if smoke else (0.0, 4e-4, 7e-4, 1e-3)
    io = HostIOStream(rate_iops=80_000, read_fraction=1.0,
                      n_requests=1000 if smoke else 4000, seed=7)
    scfg = _scfg(16_000, n_sessions)
    print(f"\n== goodput-at-SLO vs raw bit error rate ({policy} policy, "
          f"read-heavy host stream sharing the drive)")
    for rber in rbers:
        fc = FaultConfig(rber_base=rber) if rber > 0.0 else None
        sat = find_saturation(catalog, policy, slo_p99_ns=SLO_P99_NS,
                              rate_lo=1000, rate_hi=16_000, iters=sat_iters,
                              n_sessions=n_sessions, seed=9, serving=scfg,
                              io_stream=io, faults=fc, min_availability=0.99)
        last = sat.probes[-1]
        print(f"  rber={rber:7.1e} saturation={sat.rate_per_sec:8.1f}/s "
              f"avail={last.availability:5.3f} ({len(sat.probes)} probes)")
        rows.append(csv_row(f"faults/saturation/rber_{rber:g}",
                            f"{sat.rate_per_sec:.1f}",
                            f"per_sec,slo_p99_us={SLO_P99_NS/1e3:.0f}"))

    # -- study 2: wear-coupled errors, greedy vs wear-aware GC ----------------
    prewear = 3000 if smoke else 8000
    wear_rbers = (5e-5,) if smoke else (5e-5, 1e-4)
    base = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                     prefill=0.9, gc_suspend=True, gc_reserve_blocks=1,
                     prewear_writes=prewear)
    wear_io = HostIOStream(rate_iops=12_000, read_fraction=0.5,
                           n_requests=1500 if smoke else 4000,
                           zipf_theta=0.95,
                           n_logical_pages=base.logical_pages())
    arr = PoissonArrivals(rate_per_sec=4000, n_sessions=n_sessions, seed=9)
    wcfg = ServingConfig(keep_session_results=False,
                         little_law_warn_tol=float("inf"))
    print(f"\n== wear-coupled errors after {prewear} prewear writes "
          f"(rber = base + per_pe x erase_count)")
    print(f"  {'victim':>12s} {'rber_per_pe':>11s} {'hard_fails':>10s} "
          f"{'recovered':>9s} {'io_p99_us':>9s} {'max_wear':>8s}")
    for e in wear_rbers:
        for vp in ("greedy", "wear_aware"):
            cfg = dataclasses.replace(base, victim_policy=vp)
            fc = FaultConfig(rber_base=1e-4, rber_per_pe=e)
            res = simulate_serving(catalog, arr, policy, io_stream=wear_io,
                                   ftl=cfg, serving=wcfg, faults=fc)
            st = res.faults
            print(f"  {vp:>12s} {e:11.1e} {st.n_hard_fails:10d} "
                  f"{st.recovered:9d} {res.host_io.p(99)/1e3:9.1f} "
                  f"{max(res.ftl.erase_counts):8d}")
            rows.append(csv_row(f"faults/wear/{vp}/{e:g}/hard_fails",
                                str(st.n_hard_fails),
                                f"recovered={st.recovered}"))
            rows.append(csv_row(f"faults/wear/{vp}/{e:g}/io_p99",
                                f"{res.host_io.p(99)/1e3:.1f}", "us"))

    # -- study 3: degradation endgame -----------------------------------------
    n_req = 200 if smoke else 400
    endgame_ftl = FTLConfig(blocks_per_die=3, pages_per_block=4, prefill=0.9,
                            op_ratio=0.34, gc_enabled=False)
    endgame_io = HostIOStream(rate_iops=400_000, read_fraction=0.5,
                              n_requests=n_req, zipf_theta=0.9,
                              n_logical_pages=endgame_ftl.logical_pages())
    res = simulate_serving(
        catalog, PoissonArrivals(rate_per_sec=4000, n_sessions=8, seed=9),
        policy, io_stream=endgame_io, ftl=endgame_ftl, serving=wcfg,
        faults=FaultConfig(rber_base=0.05, retire_after=1))
    st = res.faults
    hio = res.host_io
    n_ops = hio.n_reads + hio.n_writes
    io_avail = 1.0 - hio.n_failed / n_ops if n_ops else 1.0
    print(f"\n== degradation endgame (uncorrectable-grade errors, tiny "
          f"drive, retire_after=1)")
    print(f"  {st.summary()}")
    print(f"  host-I/O availability={io_avail:.4f} "
          f"({hio.n_failed}/{n_ops} ops failed, all surfaced)")
    assert len(hio.latencies_ns) + hio.n_failed == n_ops, \
        "conservation: every op completes or is surfaced as failed"
    rows.append(csv_row("faults/endgame/blocks_retired",
                        str(st.n_blocks_retired),
                        f"pages_relocated={st.n_pages_relocated}"))
    rows.append(csv_row("faults/endgame/read_only_dies",
                        str(st.n_read_only_dies),
                        f"failed_writes={st.n_failed_writes}"))
    rows.append(csv_row("faults/endgame/io_availability",
                        f"{io_avail:.4f}", f"failed={hio.n_failed}"))
    return rows
