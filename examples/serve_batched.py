"""Batched serving of a small model: continuous-batching decode over a
synthetic request queue with latency percentiles.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3-4b
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    res = serve(args.arch, args.requests, args.batch, prompt_len=16,
                max_new=args.max_new, reduced=True)
    print(f"[serve] {res['requests']} requests, {res['tokens']} tokens, "
          f"{res['tokens_per_s']:.1f} tok/s, "
          f"p50 {res['latency_ms_p50']:.0f}ms "
          f"p99 {res['latency_ms_p99']:.0f}ms")


if __name__ == "__main__":
    main()
