"""The paper's core study in miniature: one workload, every offloading
policy, with the decision timeline (Fig 10 style) and the six cost-function
features for a few instructions.

    PYTHONPATH=src python examples/ndp_offload_demo.py
"""
from repro.core import make_policy
from repro.core.cost import SystemView, features_for
from repro.core.isa import NDP_RESOURCES
from repro.hw.ssd_spec import DEFAULT_SSD
from repro.sim import simulate
from repro.workloads import get_trace, sim_config_for


def main():
    wl = "jacobi1d"
    tr = get_trace(wl, "tiny")
    cfg = sim_config_for(wl, tr)

    print(f"== {wl}: six cost-function features for the first instructions")
    view = SystemView(0.0, lambda r: 0.0, lambda i: 0.0,
                      tr.pages.location)
    for ins in tr.instrs[:4]:
        print(f"  instr {ins.iid} op={ins.op} ({ins.op_class.value})")
        feats = {r: features_for(ins, r, view, DEFAULT_SSD)
                 for r in NDP_RESOURCES}
        ok = [r for r in NDP_RESOURCES if feats[r].supported]
        best = min(ok, key=lambda r: feats[r].total) if ok else None
        for r in NDP_RESOURCES:
            f = feats[r]
            tag = ("  <- argmin" if r == best else
                   "" if f.supported else "  (unsupported)")
            print(f"    {r.value:4s} comp={f.latency_comp/1e3:9.2f}us "
                  f"dm={f.latency_dm/1e3:9.2f}us "
                  f"total={f.total/1e3:9.2f}us{tag}")

    print("\n== decision strips (first 64 instructions)")
    glyph = {"isp": "I", "pud": "D", "ifp": "F", "cpu": "c", "gpu": "g"}
    for pol in ("dm", "bw", "conduit"):
        r = simulate(tr, pol, config=cfg)
        strip = "".join(glyph[d.resource.value] for d in r.decisions[:64])
        print(f"  {pol:8s} {strip}  makespan={r.makespan_ns/1e6:.2f}ms")


if __name__ == "__main__":
    main()
