"""Garbage collection as a background tenant: a walkthrough.

The simulator's idealized drive used to accept host writes with no
logical-to-physical mapping and no firmware background work.  Real SSDs
remap every write through a flash translation layer, and once the
over-provisioned block pool runs low a garbage collector starts copying
valid pages and erasing blocks — on the *same* dies and channels the NDP
offloader and host I/O need.  This demo makes that interference visible:

1. precondition a low-OP drive (90 % of the logical space pre-written),
2. hammer it with Zipf-skewed, write-heavy host I/O (hot LBAs hash to hot
   dies, so a few dies cross the GC watermark quickly),
3. co-run two NDP tenants, GC off vs. on, over identical streams and
   placement — every latency delta is attributable to the collector.

    PYTHONPATH=src python examples/gc_interference.py
"""
import dataclasses

from repro.sim import FTLConfig, HostIOStream, simulate_mix
from repro.workloads import get_trace


def main():
    workloads = ("jacobi1d", "xor_filter")
    traces = [get_trace(wl, "tiny") for wl in workloads]

    print("== write amplification vs. over-provisioning "
          "(zipf 0.95, 70% writes, 90% prefill)")
    hdr = (f"  {'op':>5s} {'WA':>6s} {'gc':>4s} {'erases':>7s} "
           f"{'max_wear':>9s} {'io_p99 off':>11s} {'io_p99 on':>10s} "
           + "".join(f"{wl + '_slow':>15s}" for wl in workloads))
    print(hdr)
    for op in (0.45, 0.28, 0.12):
        on_cfg = FTLConfig(blocks_per_die=4, pages_per_block=8,
                           op_ratio=op, prefill=0.9)
        off_cfg = dataclasses.replace(on_cfg, gc_enabled=False)
        io = HostIOStream(rate_iops=250_000, read_fraction=0.3,
                          n_requests=512, zipf_theta=0.95,
                          n_logical_pages=on_cfg.logical_pages())
        off = simulate_mix(traces, "conduit", io_stream=io, ftl=off_cfg,
                           compute_solo=False)
        on = simulate_mix(traces, "conduit", io_stream=io, ftl=on_cfg,
                          compute_solo=False)
        slows = "".join(
            f"{on.tenant(r.tenant).makespan_ns / r.makespan_ns:>14.2f}x"
            for r in off.tenants)
        print(f"  {op:5.2f} {on.ftl.write_amplification:6.2f} "
              f"{on.ftl.gc_invocations:4d} {on.ftl.blocks_erased:7d} "
              f"{on.ftl.max_erase_count:9d} "
              f"{off.host_io.p(99)/1e3:9.1f}us {on.host_io.p(99)/1e3:8.1f}us"
              f"{slows}")

    print("\n== where the wear goes (op=0.12): erase-count histogram")
    on_cfg = FTLConfig(blocks_per_die=4, pages_per_block=8,
                       op_ratio=0.12, prefill=0.9)
    io = HostIOStream(rate_iops=250_000, read_fraction=0.3, n_requests=512,
                      zipf_theta=0.95,
                      n_logical_pages=on_cfg.logical_pages())
    on = simulate_mix(traces, "conduit", io_stream=io, ftl=on_cfg,
                      compute_solo=False)
    for erases, blocks in sorted(on.ftl.wear_histogram().items()):
        bar = "#" * min(60, blocks)
        print(f"  {erases:2d} erases: {blocks:4d} blocks {bar}")
    print(f"\n  hot-LBA skew concentrates wear: "
          f"{sum(1 for c in on.ftl.erase_counts if c > 0)} of "
          f"{len(on.ftl.erase_counts)} blocks ever erased, "
          f"max wear {on.ftl.max_erase_count} erases")
    n_gc = len(on.ftl.host_during_gc_ns)
    print(f"  host requests issued while a collector was active: {n_gc} "
          f"(p99 {on.ftl.p_during_gc(99)/1e3:.1f}us vs "
          f"{on.host_io.p(99)/1e3:.1f}us overall)")


if __name__ == "__main__":
    main()
