"""Multi-tenant NDP on one SSD: two workloads plus background host I/O.

The paper evaluates one offloaded program at a time; a shared cloud SSD
serves several tenants' NDP programs *and* ordinary read/write traffic at
once.  This demo co-runs two seed workloads under every realizable policy
on one shared fabric with a 100k-IOPS host I/O stream, and prints the
interference picture: per-tenant slowdown vs. running alone, Jain's
fairness index, and the host I/O latency distribution.

    PYTHONPATH=src python examples/multi_tenant_ndp.py
"""
from repro.sim import HostIOStream, jain_fairness, simulate, simulate_mix
from repro.workloads import get_trace


def main():
    workloads = ("jacobi1d", "xor_filter")
    traces = [get_trace(wl, "tiny") for wl in workloads]
    io = HostIOStream(rate_iops=100_000, n_requests=128, read_fraction=0.7)

    print(f"== tenants: {' + '.join(workloads)}  "
          f"+ host I/O {io.rate_iops:,.0f} IOPS ({io.n_requests} reqs)")
    hdr = (f"  {'policy':12s} {'makespan':>10s} "
           + "".join(f"{wl:>12s}" for wl in workloads)
           + f" {'fairness':>9s} {'io p50':>9s} {'io p99':>9s}")
    print(hdr)
    for pol in ("isp", "pud", "bw", "dm", "conduit"):
        mix = simulate_mix(traces, pol, io_stream=io)
        slow = mix.slowdowns
        cells = "".join(f"{slow[t]:>11.2f}x" for t in sorted(slow))
        print(f"  {pol:12s} {mix.makespan_ns/1e6:>8.2f}ms {cells} "
              f"{mix.fairness:>9.3f} "
              f"{mix.host_io.p(50)/1e3:>7.1f}us "
              f"{mix.host_io.p(99)/1e3:>7.1f}us")

    print("\n== interference vs. I/O intensity (conduit policy)")
    # solo baselines don't depend on the I/O level: compute them once
    solo = {f"t{i}:{wl}": simulate(tr, "conduit").makespan_ns
            for i, (wl, tr) in enumerate(zip(workloads, traces))}
    for iops in (0, 50_000, 200_000, 800_000):
        io = HostIOStream(rate_iops=iops, n_requests=128) if iops else None
        mix = simulate_mix(traces, "conduit", io_stream=io,
                           compute_solo=False)
        slow = {k: mix.tenant(k).makespan_ns / v for k, v in solo.items()}
        sl = " ".join(f"{k.split(':')[1]}={v:.2f}x"
                      for k, v in sorted(slow.items()))
        tail = (f" io_p99={mix.host_io.p(99)/1e3:.1f}us"
                if mix.host_io else "")
        print(f"  {iops:>7,d} IOPS  {sl}  "
              f"fairness={jain_fairness(list(slow.values())):.3f}{tail}")


if __name__ == "__main__":
    main()
