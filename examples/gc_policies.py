"""The GC policy suite: victim selection, hot/cold streams, GC suspend.

``examples/gc_interference.py`` shows that garbage collection interferes
with NDP offloading and host I/O.  This walkthrough shows what firmware
*policy* does about it — the three levers `sim/ftl.py` exposes:

1. **victim selection** — who gets reclaimed.  ``greedy`` (min valid
   pages) erases whatever looks cheapest right now; ``cost_benefit``
   (the classic age-weighted ``(1-u)/2u`` score, paired with the
   cleaner's age-sorting rewrite side: still-hot survivors rejoin the
   hot append point instead of re-polluting cold compaction blocks)
   cuts write amplification; ``wear_aware`` penalizes erase counts
   above the die minimum, trading a little WA for a flat wear
   histogram (device lifetime).
2. **hot/cold separation** — two host append points keyed on per-LBA
   write counts: hot pages die together, so victims are near-empty.
3. **GC suspend/throttle** — instead of booking a whole victim cycle in
   one go (every queued host read waits behind ~all of it), the
   collector books one page copy per event, yields the die/channel pools
   between copies, and backs off while the host queue is deep.
4. And the production question: how many sessions/sec does collection
   *cost* an open-loop serving drive (``find_saturation`` with ``ftl=``)?

    PYTHONPATH=src python examples/gc_policies.py
"""
import dataclasses

from repro.hw.ssd_spec import FlashSpec, SSDSpec
from repro.sim import (CatalogEntry, FTLConfig, HostIOStream, ServingConfig,
                       SessionCatalog, drive_zipf_overwrites,
                       find_saturation, simulate_mix)
from repro.workloads import get_trace

#: 4-die scaled drive: concentrates per-die churn so thousands of GC
#: cycles (where victim choice actually matters) simulate in seconds
POLICY_SSD = SSDSpec(flash=FlashSpec(channels=2, dies_per_channel=2))


def drive_zipf(cfg, n_writes=6000):
    """Precondition + Zipf-overwrite one FTL; return its stats."""
    return drive_zipf_overwrites(cfg, POLICY_SSD, n_writes)


def main():
    base = FTLConfig(blocks_per_die=32, pages_per_block=8, op_ratio=0.28,
                     prefill=0.85, gc_reserve_blocks=1)

    print("== who to reclaim: victim policy x hot/cold "
          "(zipf 0.99 churn, 6000 writes)")
    print(f"  {'victim':>13s} {'hot_cold':>8s} {'WA':>6s} "
          f"{'wear_flat':>10s} {'max_wear':>9s}")
    for vp in ("greedy", "cost_benefit", "wear_aware"):
        for hc in (False, True):
            s = drive_zipf(dataclasses.replace(base, victim_policy=vp,
                                               hot_cold=hc))
            print(f"  {vp:>13s} {str(hc):>8s} {s.write_amplification:6.2f} "
                  f"{s.wear_flatness:10.3f} {s.max_erase_count:9d}")
    print("  -> the cost-benefit cleaner (age-weighted victims + hot "
          "survivors re-joining\n     the hot stream) and the hot/cold "
          "host split each shave WA off greedy;\n     wear-aware "
          "flattens the histogram (lower max wear = longer device life)")

    print("\n== when to yield: GC suspend vs host tail latency "
          "(full 64-die drive)")
    # reserve held constant across the pair: the p99 delta is suspend-only
    geometry = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.12,
                         prefill=0.9, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=250_000, read_fraction=0.3, n_requests=512,
                      zipf_theta=0.95,
                      n_logical_pages=geometry.logical_pages())
    traces = [get_trace(wl, "tiny") for wl in ("jacobi1d", "xor_filter")]
    for suspend in (False, True):
        cfg = dataclasses.replace(geometry, gc_suspend=suspend)
        mix = simulate_mix(traces, "conduit", io_stream=io, ftl=cfg,
                           compute_solo=False)
        s = mix.ftl
        mode = "suspend" if suspend else "monolithic"
        print(f"  {mode:>10s}: host io p99 {mix.host_io.p(99)/1e3:9.1f}us "
              f"(during GC {s.p_during_gc(99)/1e3:9.1f}us, "
              f"{s.gc_suspensions} backoffs, WA {s.write_amplification:.2f})")
    print("  -> per-page-copy collection cuts the host tail several "
          "times over — and backing\n     off lets the host overwrite "
          "victim pages before they are copied, so WA drops too")

    print("\n== what GC costs a serving drive (p99 SLO 2 ms)")
    catalog = SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)
    serve_ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                          prefill=0.9, gc_suspend=True, gc_reserve_blocks=1)
    serve_io = HostIOStream(rate_iops=12_000, read_fraction=0.5,
                            n_requests=128, zipf_theta=0.95,
                            n_logical_pages=serve_ftl.logical_pages())
    kw = dict(slo_p99_ns=2.0e6, rate_lo=4000, rate_hi=16_000, iters=4,
              n_sessions=48, seed=9, io_stream=serve_io,
              serving=ServingConfig(keep_session_results=False,
                                    warmup_ns=1e5, cooldown_ns=1e5))
    ideal = find_saturation(catalog, "conduit", **kw)
    collecting = find_saturation(catalog, "conduit", ftl=serve_ftl, **kw)
    print(f"  idealized drive sustains {ideal.rate_per_sec:8,.0f} sessions/s")
    print(f"  collecting drive sustains {collecting.rate_per_sec:7,.0f} "
          f"sessions/s "
          f"(GC steals {ideal.rate_per_sec - collecting.rate_per_sec:,.0f}/s)")


if __name__ == "__main__":
    main()
