"""Quickstart: programmer-transparent NDP offloading of a plain JAX function.

You write ordinary JAX; Conduit's compile-time pass vectorizes it into
page-aligned SIMD instructions, and the runtime offloader schedules every
instruction across the SSD's three compute resources (controller cores,
in-DRAM compute, in-flash compute).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import vectorize
from repro.sim import simulate


def my_kernel(data, keys):
    """An ordinary JAX program: filter + checksum over a table."""
    mixed = (data ^ keys) + (data >> 3)
    mask = mixed > 0
    kept = jnp.where(mask, mixed, 0)
    return jnp.sum(kept), kept


def main():
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.integers(0, 2**30, size=(64, 16384),
                                    dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 2**30, size=(64, 16384),
                                    dtype=np.int32))

    # 1. compile-time preprocessing (the paper's LLVM pass analogue)
    trace = vectorize(my_kernel, data, keys, name="quickstart")
    st = trace.characterize()
    print(f"vectorized into {st.total_instrs} page-aligned SIMD instructions"
          f" ({100*st.vectorizable_pct:.0f}% vectorizable, "
          f"bands L/M/H = {st.as_row()['low_pct']}/"
          f"{st.as_row()['medium_pct']}/{st.as_row()['high_pct']}%)")

    # 2. runtime offloading under different policies
    print(f"\n{'policy':14s} {'makespan':>12s} {'energy':>10s}  mix")
    base = None
    for pol in ("cpu", "isp", "pud", "dm", "bw", "conduit", "ideal"):
        r = simulate(trace, pol)
        base = base or r.makespan_ns
        mix = " ".join(f"{k.value}:{100*v:.0f}%"
                       for k, v in r.decision_mix().items() if v > 0.01)
        print(f"{pol:14s} {r.makespan_ns/1e6:10.2f}ms "
              f"{r.total_energy_nj/1e6:8.2f}mJ  {mix}"
              f"   ({base/r.makespan_ns:.2f}x vs cpu)")


if __name__ == "__main__":
    main()
