"""Serving on a fleet of drives: finding the straggler in a merged trace.

``benchmarks/run.py --only fleet`` quantifies what placement, read
steering and hedging buy at rack scale; this walkthrough shows *how you
see the straggler*.  A three-drive fleet serves one open-loop session
stream behind hash placement with two replicas per session — and drive 0
carries a write-heavy host stream on a tight FTL, so it is collecting
garbage the whole run.  Every drive records its own flight-recorder
timeline; the per-drive traces are merged into one fleet trace
(:func:`repro.sim.telemetry.export_fleet_trace`) whose process tracks
carry ``d<drive>:`` prefixes (``d0:fabric``, ``d2:sessions``, ...).

The script then reads the story a human would read in the Perfetto UI —
*from the exported JSON file*, not from live objects:

1. :func:`repro.sim.telemetry.validate_trace` checks the merged
   envelope, the drive-prefixed process vocabulary, span balance;
2. :func:`repro.sim.analysis.fleet_blame` splits the merged trace back
   into per-drive timelines, computes the *sample-merged* fleet p99
   (never an average of per-drive p99s), and names the drive with the
   largest share of the fleet's tail sessions — plus the component
   (queueing, flash, GC stall...) that built that tail;
3. a second run with read steering on shows the same fleet routing
   around the collecting drive: the fleet p99 drops back to healthy.

    PYTHONPATH=src python examples/fleet_serving.py
    PYTHONPATH=src python examples/fleet_serving.py --smoke \\
        --out /tmp/fleet_trace.json

Open the exported JSON at https://ui.perfetto.dev: three stacked drive
timelines, and drive 0's ``d0:ftl-gc`` track solid with collection while
its ``d0:sessions`` spans stretch.
"""
import argparse
import json

from repro.sim import (CatalogEntry, FleetConfig, DriveProfile, FTLConfig,
                       HostIOStream, PoissonArrivals, ServingConfig,
                       SessionCatalog, TelemetryConfig, export_fleet_trace,
                       fleet_blame, simulate_fleet, validate_trace)
from repro.workloads import get_trace

N_DRIVES = 3


def _fleet(steering: bool, smoke: bool) -> FleetConfig:
    # drive 0 is the straggler: write-heavy churn on a tight FTL keeps
    # its garbage collector busy for the whole serving window
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                    prefill=0.9, gc_suspend=True, gc_reserve_blocks=1)
    churn = HostIOStream(rate_iops=150_000, read_fraction=0.1,
                         n_requests=400 if smoke else 1200,
                         zipf_theta=0.9, n_logical_pages=ftl.logical_pages(),
                         seed=11)
    return FleetConfig(n_drives=N_DRIVES, placement="hash", replication=2,
                       steering=steering,
                       profiles=((0, DriveProfile(io_stream=churn, ftl=ftl)),))


def run(steering: bool, smoke: bool, telemetry=None):
    catalog = SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)
    arrivals = PoissonArrivals(rate_per_sec=6000,
                               n_sessions=24 if smoke else 64, seed=9)
    return simulate_fleet(
        catalog, arrivals, "conduit",
        serving=ServingConfig(keep_session_results=False,
                              warmup_ns=1e5, cooldown_ns=1e5,
                              little_law_warn_tol=float("inf")),
        fleet=_fleet(steering, smoke), telemetry=telemetry)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer sessions / host requests)")
    ap.add_argument("--out", default="fleet_trace.json",
                    help="merged trace output path (default: %(default)s)")
    args = ap.parse_args()

    print(f"== {N_DRIVES}-drive fleet, hash placement, replication=2, "
          f"drive 0 mid-GC, recorders on")
    res = run(steering=False, smoke=args.smoke,
              telemetry=TelemetryConfig(spans=True, audit=True,
                                        interval_ns=20_000.0))
    print(f"  fleet p99 {res.p(99) / 1e3:8.1f} us   per-drive p99 "
          + "  ".join(f"d{d}={p / 1e3:.1f}us"
                      for d, p in enumerate(res.per_drive_p(99))))
    export_fleet_trace(res.telemetry, args.out)
    print(f"  merged trace written to {args.out} — open it at "
          f"https://ui.perfetto.dev")

    # everything below reads the exported FILE: the analysis layer needs
    # nothing but the JSON a colleague (or CI artifact) would hand you
    with open(args.out) as f:
        trace = json.load(f)
    errors = validate_trace(trace)
    print(f"\n== validate_trace: {len(errors)} errors"
          + ("" if not errors else f" — first: {errors[0]}"))
    assert not errors, errors

    blame = fleet_blame(trace)
    print(f"== fleet_blame (fleet p99 = sample-merged "
          f"{blame['fleet_p99_ns'] / 1e3:.1f} us)")
    for row in blame["per_drive"]:
        print(f"  drive {row['drive']}: {row['n_sessions']:3d} sessions  "
              f"p99={row['p99_ns'] / 1e3:8.1f}us  "
              f"tail={row['tail_sessions']:2d} "
              f"({row['tail_share']:.0%})  "
              f"dominant={row['dominant_component']}")
    s = blame["straggler"]
    print(f"  -> straggler: drive {s['drive']} with {s['tail_share']:.0%} "
          f"of the fleet tail, built by '{s['dominant_component']}'")

    print(f"\n== same fleet, read steering ON (collecting drive sinks to "
          f"the back of every preference order)")
    res2 = run(steering=True, smoke=args.smoke)
    print(f"  fleet p99 {res2.p(99) / 1e3:8.1f} us   "
          f"({res2.n_steered} sessions steered; was "
          f"{res.p(99) / 1e3:.1f} us unsteered)")


if __name__ == "__main__":
    main()
