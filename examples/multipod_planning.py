"""Conduit-for-TPU: the six-feature cost function planning distributed
execution of DeepSeek-V2-236B on the 512-chip production mesh.

    PYTHONPATH=src python examples/multipod_planning.py
"""
from repro import configs
from repro.distributed import ConduitScheduler, default_candidates


def main():
    cfg = configs.get("deepseek-v2-236b")
    sched = ConduitScheduler()
    print(f"== planning {cfg.name} train_4k on 2x16x16 (512 chips)")
    best, ests = sched.choose(cfg, "train", global_batch=256, seq_len=4096,
                              chips=512, data_par=16, model_par=16, pods=2)
    print(f"{'plan':20s} {'compute':>9s} {'memory':>9s} {'coll.':>9s} "
          f"{'exposed':>9s} {'HBM/chip':>9s} {'total':>9s} feasible")
    for e in sorted(ests, key=lambda e: e.total_s):
        mark = " <== chosen" if e.plan.name == best.plan.name else ""
        print(f"{e.plan.name:20s} {e.compute_s*1e3:8.1f}ms "
              f"{e.memory_s*1e3:8.1f}ms {e.collective_s*1e3:8.1f}ms "
              f"{e.exposed_collective_s*1e3:8.1f}ms {e.hbm_gb:8.1f}GB "
              f"{e.total_s*1e3:8.1f}ms {str(e.feasible):>5s}{mark}")


if __name__ == "__main__":
    main()
