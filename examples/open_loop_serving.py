"""Open-loop serving: from batch makespan to sustainable throughput.

The paper evaluates one offloaded program at a time, and `simulate_mix`
measures the makespan of a fixed tenant set.  A production drive is
judged differently: sessions keep arriving whether or not earlier ones
finished (open loop), and the question is how many sessions per second
the SSD sustains *while keeping tail latency bounded*.  This demo walks
the three pieces of the serving subsystem:

1. a weighted session catalog (3x short jacobi1d : 1x longer xor_filter)
   with Poisson arrivals — the latency-throughput "hockey stick" per
   offloading policy: flat at low load, a knee, then a queueing cliff;
2. the same load as ON/OFF bursts (a 2-state MMPP at the *same* mean
   rate) — burstiness alone inflates the tail;
3. `find_saturation` — a deterministic bisection for the max sustainable
   sessions/sec under a p99 latency SLO, per policy: one number that
   ranks conduit against the BW/DM baselines in the serving regime.

    PYTHONPATH=src python examples/open_loop_serving.py
"""
from repro.sim import (CatalogEntry, MMPPArrivals, PoissonArrivals,
                       ServingConfig, SessionCatalog, find_saturation,
                       simulate_serving)
from repro.workloads import get_trace


def main():
    catalog = SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)
    n = 96
    scfg = ServingConfig(warmup_ns=0.6e6, cooldown_ns=0.6e6,
                         keep_session_results=False)

    print("== the hockey stick (conduit policy, poisson arrivals)")
    print(f"  {'offered/s':>10s} {'completed/s':>12s} {'p50':>9s} "
          f"{'p99':>9s} {'rej':>4s} {'busiest util':>13s}")
    for rate in (1000, 2000, 4000, 8000, 16000, 24000):
        arr = PoissonArrivals(rate_per_sec=rate, n_sessions=n, seed=9)
        r = simulate_serving(catalog, arr, "conduit", serving=scfg)
        print(f"  {rate:>10,d} {r.completed_rate_per_sec:>12,.0f} "
              f"{r.p(50)/1e3:>7.1f}us {r.p(99)/1e3:>7.1f}us "
              f"{r.n_rejected:>4d} {max(r.utilization.values()):>13.3f}")

    print("\n== burstiness at the same mean rate (8k sessions/s)")
    smooth = PoissonArrivals(rate_per_sec=8000, n_sessions=n, seed=9)
    bursty = MMPPArrivals(rate_on_per_sec=32_000, rate_off_per_sec=0.0,
                          mean_on_ns=2e6, mean_off_ns=6e6,
                          n_sessions=n, seed=9)
    for name, arr in (("poisson", smooth), ("mmpp on/off", bursty)):
        r = simulate_serving(catalog, arr.at_rate(8000), "conduit",
                             serving=scfg)
        print(f"  {name:12s} p50={r.p(50)/1e3:7.1f}us "
              f"p99={r.p(99)/1e3:8.1f}us  "
              f"mean_in_system={r.mean_in_system:5.2f}  "
              f"little_ratio={r.little_law_ratio():5.3f}")

    print("\n== saturation point per policy (p99 SLO 1.5 ms, bisection)")
    for pol in ("conduit", "bw", "dm"):
        sat = find_saturation(catalog, pol, slo_p99_ns=1.5e6,
                              rate_lo=1000, rate_hi=24_000, iters=5,
                              n_sessions=n, seed=9, serving=scfg)
        print(f"  {pol:8s} sustains {sat.rate_per_sec:>9,.0f} sessions/s "
              f"(bracket {sat.bracket[0]:,.0f}..{sat.bracket[1]:,.0f}, "
              f"{len(sat.probes)} probes)")


if __name__ == "__main__":
    main()
