"""Reading a GC-induced tail with the flight recorder.

``examples/gc_policies.py`` quantifies what garbage collection costs a
serving drive; this walkthrough shows *how you see it happen*.  One
serving-under-GC run is re-executed with telemetry on
(``telemetry=TelemetryConfig(...)``), which produces, at zero change to
the simulated results (the telemetry-on golden law in
``tests/test_telemetry.py``):

1. a **Perfetto/Chrome trace** — one track per die/channel/compute unit,
   GC cycle/copy/erase spans per die, session and host-I/O lifecycle
   spans, and counter tracks from the interval sampler;
2. the **offload-decision audit stream** — per dispatch, the six cost
   features for every candidate resource and the chosen one;
3. **interval metrics** — utilization, queue depth, GC-busy dies,
   serving backlog, sliding p99.

The script exports the trace, then *programmatically* reads the story a
human would read in the Perfetto UI: host requests that land on a die
while its collector is mid-cycle wait behind the copies, so their
latencies spike — the GC-induced tail.  It ends by asking the audit
stream to explain one offloading decision end-to-end.

    PYTHONPATH=src python examples/tracing_walkthrough.py
    PYTHONPATH=src python examples/tracing_walkthrough.py --smoke \\
        --out /tmp/serving_gc_trace.json

Open the exported JSON at https://ui.perfetto.dev (or
``chrome://tracing``): the "ftl-gc" process holds the per-die GC tracks,
"fabric" the per-unit booking tracks, "host-io"/"sessions" the async
lifecycle spans, "metrics" the counter tracks.  Zoom to any ``gc-cycle``
span and look at the ``flash_dies`` track below it.
"""
import argparse

from repro.sim import (CatalogEntry, FTLConfig, HostIOStream,
                       PoissonArrivals, ServingConfig, SessionCatalog,
                       TelemetryConfig, simulate_serving, summarize_trace)
from repro.workloads import get_trace


def run(smoke: bool = False):
    catalog = SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)
    # the serving-drive geometry from examples/gc_policies.py: small
    # blocks on the full drive keep every die's collector busy
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                    prefill=0.9, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=60_000, read_fraction=0.3,
                      n_requests=96 if smoke else 384, zipf_theta=0.95,
                      n_logical_pages=ftl.logical_pages())
    arrivals = PoissonArrivals(rate_per_sec=6000,
                               n_sessions=16 if smoke else 48, seed=9)
    tele = TelemetryConfig(spans=True, audit=True, interval_ns=20_000.0)
    res = simulate_serving(
        catalog, arrivals, "conduit",
        serving=ServingConfig(keep_session_results=False,
                              warmup_ns=1e5, cooldown_ns=1e5,
                              # overlap, not steady state, is the subject
                              little_law_warn_tol=float("inf")),
        io_stream=io, ftl=ftl, telemetry=tele)
    return res


def gc_tail_story(trace) -> str:
    """Read the GC-induced tail out of the exported trace, per die: host
    requests whose lifetime overlaps a GC cycle on their die vs the rest."""
    from repro.sim.telemetry import PID_FTL

    pname = {}
    tname = {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            tname[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    gc_by_die = {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "X" and ev.get("pid") == PID_FTL \
                and ev["name"].startswith("gc-cycle"):
            die = int(tname[(ev["pid"], ev["tid"])][len("die"):])
            gc_by_die.setdefault(die, []).append(
                (ev["ts"], ev["ts"] + ev["dur"]))
    opens = {}
    ios = []                       # (die, t0, t1)
    for ev in trace["traceEvents"]:
        if ev.get("cat") != "host_io":
            continue
        if ev["ph"] == "b":
            opens[ev["id"]] = (ev["args"]["die"], ev["ts"])
        else:
            die, t0 = opens.pop(ev["id"])
            ios.append((die, t0, ev["ts"]))
    hit = []                       # (latency, die) — overlapped own-die GC
    clear = []
    for die, t0, t1 in ios:
        cycles = gc_by_die.get(die, ())
        if any(g0 < t1 and t0 < g1 for g0, g1 in cycles):
            hit.append((t1 - t0, die))
        else:
            clear.append((t1 - t0, die))
    if not hit or not clear:
        return "  (no GC/host-IO overlap in this run — rerun without --smoke)"
    lat, die = max(hit)
    mean = lambda xs: sum(x for x, _ in xs) / len(xs)
    lines = [
        f"  {len(hit)} of {len(hit) + len(clear)} host requests ran while "
        f"their die was collecting:",
        f"    mean latency {mean(clear):8.1f} us when the die was clear",
        f"    mean latency {mean(hit):8.1f} us when caught mid-GC "
        f"(worst {lat:.0f} us on die {die})",
        f"  -> in Perfetto, find the gc-cycle span on ftl-gc/die{die} and "
        f"the io:* span\n     stretched underneath it — that stretch IS "
        f"the GC-induced tail",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer sessions / host requests)")
    ap.add_argument("--out", default="serving_gc_trace.json",
                    help="trace output path (default: %(default)s)")
    args = ap.parse_args()

    print("== serving under GC, flight recorder on")
    res = run(smoke=args.smoke)
    rec = res.telemetry
    trace = rec.export(args.out)
    s = summarize_trace(trace)
    print(f"  {res.n_completed} sessions served, "
          f"{rec.event_counts.get('gc', 0)} GC cycles, "
          f"{s['n_events']} trace events "
          f"({s['spans_by_process'].get('fabric', 0)} fabric spans, "
          f"{s['spans_by_process'].get('ftl-gc', 0)} GC spans, "
          f"{s['n_audit']} audited decisions, "
          f"{s['n_intervals']} interval samples)")
    print(f"  trace written to {args.out} — open it at "
          f"https://ui.perfetto.dev\n")

    print("== the GC-induced tail, read from the trace (times in us)")
    print(gc_tail_story(trace))

    print("\n== one offloading decision, explained by the audit stream")
    # pick a dispatch that had a real choice: the widest total_ns spread
    # among supported candidates
    def spread(a):
        tot = [c.total_ns for c in a.candidates if c.supported]
        return (max(tot) - min(tot)) if len(tot) > 1 else -1.0
    audit = max(rec.audit, key=spread)
    print(audit.explain())

    print("\n== interval metrics: when GC was busiest")
    busiest = max(rec.intervals, key=lambda s: s.gc_active_dies)
    print(f"  t={busiest.t_ns/1e3:.0f}us: {busiest.gc_active_dies} dies "
          f"collecting, backlog={busiest.backlog}, "
          f"active={busiest.active_sessions}, "
          f"window p99={busiest.p99_op_ns/1e3:.1f}us")

    print("\n== tail-latency blame: the analysis layer names the tail")
    # no more eyeballing Perfetto: the attribution sweep decomposes every
    # session's wall time and the p99-vs-mean comparison names the
    # component the tail is built from (repro.sim.analysis)
    from repro.sim import blame_story
    report = res.analysis()
    print(blame_story(report))
    cp = report["critical_path"]
    if cp["n_hops"]:
        worst = max(cp["hops"],
                    key=lambda h: h["queue_ns"] + h["dep_wait_ns"])
        print(f"  critical path of the worst session ({cp['tenant']}): "
              f"{cp['n_hops']} hops; the longest wait sits at "
              f"#{worst['iid']} {worst['op']}@{worst['resource']} "
              f"({(worst['queue_ns'] + worst['dep_wait_ns'])/1e3:.1f} us "
              f"queued)")


if __name__ == "__main__":
    main()
