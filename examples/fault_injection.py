"""Watching the drive survive: the recovery ladder in the flight recorder.

``benchmarks/run.py --only faults`` quantifies what injected bit errors
cost a serving drive; this walkthrough shows *how you see the recovery
happen*.  One serving run is executed with the error model armed
(``FaultConfig``) and telemetry on: reads whose hard-decode fails climb
the recovery ladder — retry re-senses at escalating sense levels, a
soft-decode on the shared ECC engines, superpage-parity rebuild — and
every rung books real time on real resources, so it is all visible in
the exported Perfetto trace:

1. the **"reliability" process** — per-die ``recovery:<stage>`` spans
   (retry / soft-decode / rebuild), ``retire b<N>`` relocation spans,
   and instant markers where a die fails or degrades to read-only;
2. the **offload-decision audit stream** — decisions that landed on a
   die whose recovery ladder was still busy carry
   ``mid_recovery=True``: the queue-depth features the policy weighed
   included recovery work, not just useful work;
3. the usual fabric/session/host-I/O tracks underneath, so a recovery
   span sits directly above the host read it delayed.

Mid-run, one whole die is killed (``die_failures``) — every subsequent
read on it reconstructs from superpage parity, a rebuild fan-out you
can see as parallel sibling senses.

The script exports the trace, then *programmatically* reads the story a
human would read in the Perfetto UI: host reads that landed while their
die was mid-recovery wait behind the ladder, so their latencies spike —
the error-induced tail.

    PYTHONPATH=src python examples/fault_injection.py
    PYTHONPATH=src python examples/fault_injection.py --smoke \\
        --out /tmp/faults_trace.json

Open the exported JSON at https://ui.perfetto.dev: the "reliability"
process holds the per-die recovery tracks; zoom to any
``recovery:rebuild`` span and look at the parallel flash sense spans on
the sibling dies below it.
"""
import argparse

from repro.sim import (CatalogEntry, FaultConfig, FTLConfig, HostIOStream,
                       PoissonArrivals, ServingConfig, SessionCatalog,
                       TelemetryConfig, simulate_serving, summarize_trace)
from repro.workloads import get_trace

#: RBER at the hard-decode limit: most reads ladder but recover in the
#: retry/soft rungs — lots of visible recovery, few uncorrectables
LADDER_RBER = 1.2e-3
#: the die killed mid-run and the simulated time it dies at
DEAD_DIE, DIE_FAILS_AT_NS = 3, 2.0e5


def run(smoke: bool = False):
    catalog = SessionCatalog(
        [CatalogEntry("jacobi1d", get_trace("jacobi1d", "tiny"), weight=3.0),
         CatalogEntry("xor_filter", get_trace("xor_filter", "tiny"),
                      weight=1.0)],
        seed=5)
    # the serving-drive geometry from examples/tracing_walkthrough.py,
    # read-heavier so the error model gets plenty of sense operations
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                    prefill=0.9, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=60_000, read_fraction=0.7,
                      n_requests=96 if smoke else 384, zipf_theta=0.95,
                      n_logical_pages=ftl.logical_pages())
    arrivals = PoissonArrivals(rate_per_sec=6000,
                               n_sessions=16 if smoke else 48, seed=9)
    faults = FaultConfig(rber_base=LADDER_RBER,
                         die_failures=((DEAD_DIE, DIE_FAILS_AT_NS),))
    tele = TelemetryConfig(spans=True, audit=True, interval_ns=20_000.0)
    res = simulate_serving(
        catalog, arrivals, "conduit",
        serving=ServingConfig(keep_session_results=False,
                              warmup_ns=1e5, cooldown_ns=1e5,
                              little_law_warn_tol=float("inf")),
        io_stream=io, ftl=ftl, faults=faults, telemetry=tele)
    return res


def recovery_tail_story(trace) -> str:
    """Read the error-induced tail out of the exported trace, per die:
    host requests whose lifetime overlaps a recovery span on their die
    vs the rest."""
    from repro.sim.telemetry import PID_RELIABILITY

    tname = {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            tname[(ev["pid"], ev["tid"])] = ev["args"]["name"]
    rec_by_die = {}
    stages = {}
    for ev in trace["traceEvents"]:
        if ev.get("ph") == "X" and ev.get("pid") == PID_RELIABILITY \
                and ev["name"].startswith("recovery:"):
            die = int(tname[(ev["pid"], ev["tid"])][len("die"):])
            rec_by_die.setdefault(die, []).append(
                (ev["ts"], ev["ts"] + ev["dur"]))
            stage = ev["name"][len("recovery:"):]
            stages[stage] = stages.get(stage, 0) + 1
    opens = {}
    ios = []                       # (die, t0, t1)
    for ev in trace["traceEvents"]:
        if ev.get("cat") != "host_io":
            continue
        if ev["ph"] == "b":
            opens[ev["id"]] = (ev["args"]["die"], ev["ts"])
        else:
            die, t0 = opens.pop(ev["id"])
            ios.append((die, t0, ev["ts"]))
    hit = []                       # (latency, die) — overlapped a recovery
    clear = []
    for die, t0, t1 in ios:
        spans = rec_by_die.get(die, ())
        if any(r0 < t1 and t0 < r1 for r0, r1 in spans):
            hit.append((t1 - t0, die))
        else:
            clear.append((t1 - t0, die))
    if not hit or not clear:
        return "  (no recovery/host-IO overlap in this run)"
    lat, die = max(hit)
    mean = lambda xs: sum(x for x, _ in xs) / len(xs)
    by_stage = ", ".join(f"{n} {s}" for s, n in sorted(stages.items()))
    lines = [
        f"  {sum(stages.values())} recovery spans in the trace "
        f"({by_stage})",
        f"  {len(hit)} of {len(hit) + len(clear)} host requests ran while "
        f"their die was recovering:",
        f"    mean latency {mean(clear):8.1f} us when the die was clear",
        f"    mean latency {mean(hit):8.1f} us when caught mid-recovery "
        f"(worst {lat:.0f} us on die {die})",
        f"  -> in Perfetto, find the recovery span on reliability/die{die} "
        f"and the io:* span\n     stretched underneath it — that stretch "
        f"IS the error-induced tail",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer sessions / host requests)")
    ap.add_argument("--out", default="faults_trace.json",
                    help="trace output path (default: %(default)s)")
    args = ap.parse_args()

    print("== serving with the error model armed, flight recorder on")
    res = run(smoke=args.smoke)
    st = res.faults
    rec = res.telemetry
    trace = rec.export(args.out)
    s = summarize_trace(trace)
    print(f"  {res.n_completed} sessions served; {st.summary()}")
    print(f"  {s['n_events']} trace events "
          f"({s['spans_by_process'].get('reliability', 0)} reliability "
          f"spans, {s['n_audit']} audited decisions)")
    print(f"  trace written to {args.out} — open it at "
          f"https://ui.perfetto.dev\n")

    print("== the error-induced tail, read from the trace (times in us)")
    print(recovery_tail_story(trace))

    worst = max(range(len(st.errors_by_die)), key=st.errors_by_die.__getitem__)
    print(f"\n== per-die error counters: die {worst} leads with "
          f"{st.errors_by_die[worst]} hard fails "
          f"(die {DEAD_DIE} failed outright at "
          f"t={DIE_FAILS_AT_NS/1e3:.0f} us; its reads rebuild from parity)")

    mid = [a for a in rec.audit if a.mid_recovery]
    if mid:
        print(f"\n== {len(mid)} offload decisions landed mid-recovery; "
              f"the first, explained:")
        print(mid[0].explain())
    else:
        print("\n== no offload decision landed mid-recovery in this run")


if __name__ == "__main__":
    main()
