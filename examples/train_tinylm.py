"""End-to-end training driver: train a small LM for a few hundred steps on
CPU with checkpointing + restart; scale knobs reach ~100M params for real
hardware runs.

    PYTHONPATH=src python examples/train_tinylm.py --steps 300
    # ~100M-param config (for TPU-class hardware):
    PYTHONPATH=src python examples/train_tinylm.py --d-model 768 \
        --layers 12 --vocab 32000 --steps 300
"""
import argparse
import dataclasses

import jax

from repro.launch.train import train
from repro.models.config import ArchConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/tinylm_ckpt")
    args = ap.parse_args()

    cfg = ArchConfig(
        name="tinylm", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(2, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 64),
        d_ff=args.d_model * 4, vocab=args.vocab,
        remat=False, dtype="float32")
    print(f"[tinylm] params ~ {cfg.param_count()/1e6:.1f}M")

    # route through the production training driver with a custom config
    import repro.launch.train as T
    import repro.configs as C
    C._MODULES["tinylm"] = None
    orig_get = C.get
    C.get = lambda n: cfg if n == "tinylm" else orig_get(n)
    try:
        res = train("tinylm", steps=args.steps, batch=args.batch,
                    seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                    reduced=False, base_lr=3e-3)
    finally:
        C.get = orig_get
    print(f"[tinylm] loss {res['first_loss']:.3f} -> {res['final_loss']:.3f} "
          f"over {args.steps} steps")
    assert res['final_loss'] < res['first_loss'], "loss must decrease"


if __name__ == "__main__":
    main()
