"""Per-architecture smoke tests (assignment requirement): every assigned
arch instantiates a REDUCED config of the same family and runs one forward
+ train step + decode step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.launch.steps import build_serve_step, build_train_step
from repro.models import model as M
from repro.optim.adamw import adamw_init


def _extras(cfg, B, S, rng):
    kw = {}
    if cfg.frontend == "vision_patches":
        kw["extra_embeds"] = jnp.asarray(
            rng.normal(size=(B, 4, cfg.d_model)).astype(np.float32))
        kw["pos3"] = jnp.broadcast_to(jnp.arange(S + 4), (3, B, S + 4)
                                      ).astype(jnp.int32)
    if cfg.frontend == "audio_frames":
        kw["enc_feats"] = jnp.asarray(
            rng.normal(size=(B, 6, cfg.d_model)).astype(np.float32))
    return kw


# The per-arch matrix compiles ~10 reduced models (several minutes of XLA
# time): slow tier — even one reduced model compiles for 10+ s on a small
# CPU box.  The fast tier's model canary is
# test_models.py::test_decode_matches_full_forward[tinyllama-1.1b].
@pytest.mark.slow
@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_train_decode(arch):
    cfg = configs.get(arch).reduced()
    assert cfg.family == configs.get(arch).family
    rng = np.random.default_rng(0)
    B, S = 2, 8
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))
    kw = _extras(cfg, B, S, rng)

    # forward + loss
    loss = M.lm_loss(cfg, params, tokens, labels, **kw)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    # one full optimizer step
    batch = {"tokens": tokens, "labels": labels, **kw}
    step = build_train_step(cfg, total_steps=10)
    new_params, opt, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    changed = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(changed)) > 0, \
        f"{arch}: parameters did not change"

    # prefill + decode
    caches = M.init_cache(cfg, B, S + 4)
    logits, caches = M.prefill(cfg, params, tokens, caches, **kw)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    serve = build_serve_step(cfg)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    lg, caches = serve(params, caches, tok, jnp.int32(S))
    assert lg.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_exact_published_config(arch):
    """The full (non-reduced) config matches the assigned numbers."""
    cfg = configs.get(arch)
    expected = {
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_moe_configs_exact():
    dbrx = configs.get("dbrx-132b")
    assert dbrx.moe and dbrx.n_experts == 16 and dbrx.experts_per_tok == 4
    ds = configs.get("deepseek-v2-236b")
    assert ds.moe and ds.n_experts == 160 and ds.experts_per_tok == 6
    assert ds.n_shared_experts == 2
    assert ds.mla and ds.kv_lora_rank == 512


def test_shape_applicability_matrix():
    """40 cells total; long_500k applies only to sub-quadratic archs."""
    total = runnable = 0
    for arch in configs.ARCHS:
        cfg = configs.get(arch)
        for shape in SHAPES:
            total += 1
            ok, reason = applicable(cfg, shape)
            if ok:
                runnable += 1
            else:
                assert shape == "long_500k" and not cfg.sub_quadratic
                assert "sub-quadratic" in reason or "full-attention" in reason
    assert total == 40
    assert runnable == 32   # 8 full-attention archs skip long_500k
