"""Property-based simulator invariants (hypothesis).

This module needs the ``hypothesis`` package and skips cleanly when it is
absent (bare environments run the deterministic fallback suite in
``test_coherence_laws.py``, which checks the same laws on fixed examples;
CI installs hypothesis so the randomized versions run there).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed; deterministic "
                           "fallbacks live in test_coherence_laws.py")

import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.isa import Location  # noqa: E402
from repro.sim import SimConfig, simulate  # noqa: E402

from _synth import synth_trace  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=60))
def test_completion_monotone_and_conserved(op_ids):
    tr = synth_trace(op_ids)
    for pol in ("conduit", "dm", "bw"):
        r = simulate(tr, pol)
        assert r.n_instrs == len(op_ids)
        assert len(r.decisions) == len(op_ids)
        for d in r.decisions:
            assert d.t_decide <= d.t_start <= d.t_end
            assert np.isfinite(d.t_end)
        # queue conservation: every instruction executed exactly once
        assert sum(r.resource_counts.values()) == len(op_ids)
        assert r.makespan_ns >= max(d.t_end for d in r.decisions) - 1e-6


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=60))
def test_deps_respected(op_ids):
    tr = synth_trace(op_ids)
    r = simulate(tr, "conduit")
    end_by_iid = {d.iid: d.t_end for d in r.decisions}
    start_by_iid = {d.iid: d.t_start for d in r.decisions}
    for ins in tr.instrs:
        for dep in ins.deps:
            assert start_by_iid[ins.iid] >= end_by_iid[dep] - 1e-6, \
                "consumer started before producer finished"


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=40))
def test_single_owner_invariant(op_ids):
    """§4.4 coherence: exactly one owner per logical page at all times —
    checked at end state; versions bounded to one byte."""
    tr = synth_trace(op_ids)
    r = simulate(tr, "conduit")
    for ent in tr.pages.entries.values():
        assert ent.owner in (Location.FLASH, Location.DRAM, Location.CTRL,
                             Location.HOST)
        assert 0 <= ent.version <= 255
        if not ent.dirty:
            # clean pages: flash holds the authoritative copy
            assert ent.version == 0 or ent.owner != Location.FLASH or True


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=5, max_size=40),
       st.integers(1, 3))
def test_replay_on_fault(op_ids, seed):
    tr = synth_trace(op_ids)
    r = simulate(tr, "conduit",
                 config=SimConfig(fail_rate=0.3, seed=seed))
    assert r.replays >= 0
    assert sum(r.resource_counts.values()) == len(op_ids)
    assert r.makespan_ns > 0


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=2, max_size=40))
def test_energy_nonnegative_and_decomposed(op_ids):
    tr = synth_trace(op_ids)
    r = simulate(tr, "dm")
    assert r.compute_energy_nj >= 0
    assert r.movement_energy_nj >= 0
    assert r.total_energy_nj == pytest.approx(
        r.compute_energy_nj + r.movement_energy_nj)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=2, max_size=30))
def test_rerun_deterministic(op_ids):
    """Same trace + same policy => identical result (page reset works)."""
    tr = synth_trace(op_ids)
    r1 = simulate(tr, "conduit")
    r2 = simulate(tr, "conduit")
    assert r1.makespan_ns == pytest.approx(r2.makespan_ns)
    assert r1.total_energy_nj == pytest.approx(r2.total_energy_nj)
    assert r1.resource_counts == r2.resource_counts
