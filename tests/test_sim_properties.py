"""Property-based simulator invariants (hypothesis) + coherence laws."""
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core.isa import Location, Resource, VectorInstr
from repro.core.mapping import PageTable
from repro.core.vectorize import Trace
from repro.hw.ssd_spec import DEFAULT_SSD
from repro.sim import SimConfig, simulate

SPEC = DEFAULT_SSD
PAGE = SPEC.page_size
OPS = ["and", "or", "xor", "add", "sub", "mul", "cmp", "max", "copy"]


def synth_trace(op_ids, n_arrays=4, pages_per_array=2):
    """Deterministic synthetic trace from a list of op indices."""
    pt = PageTable(SPEC)
    arrays = [pt.alloc_array(pages_per_array * PAGE, name=f"a{i}")
              for i in range(n_arrays)]
    flat = [p for a in arrays for p in a]
    instrs = []
    producer = {}
    for i, oi in enumerate(op_ids):
        op = OPS[oi % len(OPS)]
        s1 = flat[(oi * 7 + i) % len(flat)]
        s2 = flat[(oi * 13 + 3 * i) % len(flat)]
        dst = flat[(oi * 5 + 2 * i + 1) % len(flat)]
        deps = tuple(sorted({producer[s] for s in (s1, s2, dst)
                             if s in producer}))
        instrs.append(VectorInstr(iid=i, op=op, vlen=PAGE, elem_bytes=1,
                                  srcs=(s1, s2), dst=dst, deps=deps))
        producer[dst] = i
    return Trace(instrs=instrs, pages=pt,
                 input_pages={"in0": arrays[0]},
                 output_pages=[arrays[-1]], name="synth")


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=60))
def test_completion_monotone_and_conserved(op_ids):
    tr = synth_trace(op_ids)
    for pol in ("conduit", "dm", "bw"):
        r = simulate(tr, pol)
        assert r.n_instrs == len(op_ids)
        assert len(r.decisions) == len(op_ids)
        for d in r.decisions:
            assert d.t_decide <= d.t_start <= d.t_end
            assert np.isfinite(d.t_end)
        # queue conservation: every instruction executed exactly once
        assert sum(r.resource_counts.values()) == len(op_ids)
        assert r.makespan_ns >= max(d.t_end for d in r.decisions) - 1e-6


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=60))
def test_deps_respected(op_ids):
    tr = synth_trace(op_ids)
    r = simulate(tr, "conduit")
    end_by_iid = {d.iid: d.t_end for d in r.decisions}
    start_by_iid = {d.iid: d.t_start for d in r.decisions}
    for ins in tr.instrs:
        for dep in ins.deps:
            assert start_by_iid[ins.iid] >= end_by_iid[dep] - 1e-6, \
                "consumer started before producer finished"


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=1, max_size=40))
def test_single_owner_invariant(op_ids):
    """§4.4 coherence: exactly one owner per logical page at all times —
    checked at end state; versions bounded to one byte."""
    tr = synth_trace(op_ids)
    r = simulate(tr, "conduit")
    for ent in tr.pages.entries.values():
        assert ent.owner in (Location.FLASH, Location.DRAM, Location.CTRL,
                             Location.HOST)
        assert 0 <= ent.version <= 255
        if not ent.dirty:
            # clean pages: flash holds the authoritative copy
            assert ent.version == 0 or ent.owner != Location.FLASH or True


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=5, max_size=40),
       st.integers(1, 3))
def test_replay_on_fault(op_ids, seed):
    tr = synth_trace(op_ids)
    r = simulate(tr, "conduit",
                 config=SimConfig(fail_rate=0.3, seed=seed))
    assert r.replays >= 0
    assert sum(r.resource_counts.values()) == len(op_ids)
    assert r.makespan_ns > 0


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=2, max_size=40))
def test_energy_nonnegative_and_decomposed(op_ids):
    tr = synth_trace(op_ids)
    r = simulate(tr, "dm")
    assert r.compute_energy_nj >= 0
    assert r.movement_energy_nj >= 0
    assert r.total_energy_nj == pytest.approx(
        r.compute_energy_nj + r.movement_energy_nj)


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 8), min_size=2, max_size=30))
def test_rerun_deterministic(op_ids):
    """Same trace + same policy => identical result (page reset works)."""
    tr = synth_trace(op_ids)
    r1 = simulate(tr, "conduit")
    r2 = simulate(tr, "conduit")
    assert r1.makespan_ns == pytest.approx(r2.makespan_ns)
    assert r1.total_energy_nj == pytest.approx(r2.total_energy_nj)
    assert r1.resource_counts == r2.resource_counts


def test_ideal_ignores_movement():
    tr = synth_trace(list(range(30)))
    ideal = simulate(tr, "ideal")
    assert ideal.movement_energy_nj == 0.0
    assert ideal.avg_decision_overhead_ns == 0.0


def test_pressure_increases_evictions():
    tr = synth_trace(list(range(40)), n_arrays=8, pages_per_array=8)
    roomy = simulate(tr, "conduit",
                     config=SimConfig(dram_capacity_pages=10_000,
                                      host_capacity_pages=10_000))
    tight = simulate(tr, "conduit",
                     config=SimConfig(dram_capacity_pages=33,
                                      host_capacity_pages=33))
    assert tight.evictions >= roomy.evictions


# -- PageTable unit laws -------------------------------------------------------

def test_coherence_owner_transitions():
    pt = PageTable(SPEC)
    pid = pt.alloc_array(PAGE)[0]
    assert pt[pid].owner == Location.FLASH and not pt[pid].dirty
    pt.record_write(pid, Location.DRAM)
    assert pt[pid].owner == Location.DRAM and pt[pid].dirty
    v1 = pt[pid].version
    pt.record_write(pid, Location.DRAM)     # same owner: version bump only
    assert pt[pid].version == v1 + 1
    assert pt.commit(pid) is True
    assert pt[pid].owner == Location.FLASH and not pt[pid].dirty
    assert pt[pid].version == 0
    assert pt.commit(pid) is False          # idempotent


def test_colocate_idempotent():
    pt = PageTable(SPEC)
    a = pt.alloc_array(2 * PAGE)
    b = pt.alloc_array(2 * PAGE)
    pids = [a[0], b[0]]
    assert not pt.same_block(pids)
    moved = pt.co_locate(pids)
    assert moved == 1
    assert pt.same_block(pids)
    assert pt.co_locate(pids) == 0
