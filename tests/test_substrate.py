"""Substrate tests: optimizer, schedules, compression, data, checkpoints,
elasticity, scheduler."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.distributed import ConduitScheduler, default_candidates
from repro.launch.elastic import (SimulatedFailure, StragglerMonitor,
                                  run_elastic)
from repro.optim import (adamw_init, adamw_update, compress_int8,
                         decompress_int8, error_feedback_update,
                         make_schedule, wsd_schedule)
from repro.optim.compress import init_residuals


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = adamw_update(params, grads, state, lr=0.05,
                                        weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.3
    assert int(state.step) == 200


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    grads = {"w": jnp.full((4,), 1e9)}
    new_params, state, m = adamw_update(params, grads, state, lr=0.1,
                                        clip_norm=1.0, weight_decay=0.0)
    assert float(m["grad_norm"]) > 1e8
    assert float(jnp.abs(new_params["w"]).max()) < 1.0


def test_wsd_schedule_phases():
    lr = lambda s: float(wsd_schedule(s, 1.0, warmup=10, stable=80, decay=10))
    assert lr(0) == pytest.approx(0.1)   # warmup starts at (step+1)/warmup
    assert lr(4) == pytest.approx(0.5)
    assert lr(50) == pytest.approx(1.0)
    assert lr(95) < 1.0
    assert lr(100) == pytest.approx(0.1)
    cos = make_schedule("cosine", 1.0, 100)
    assert float(cos(100)) == pytest.approx(0.1, abs=0.02)


def test_int8_compression_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = compress_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_steps():
    """Residual carrying: the SUM of dequantized grads converges to the sum
    of true grads (error feedback's defining property)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * 1e-3
    grads = {"w": g_true}
    residuals = init_residuals(grads)
    acc = np.zeros(64)
    steps = 50
    for _ in range(steps):
        deq, residuals = error_feedback_update(grads, residuals)
        acc += np.asarray(deq["w"])
    total_err = np.abs(acc - steps * np.asarray(g_true)).max()
    # residual bounded => cumulative error bounded by one quantization step
    assert total_err <= float(np.abs(np.asarray(g_true)).max()) * 2 + 1e-4


def test_data_determinism_and_sharding():
    pipe = SyntheticLM(vocab=128, seq_len=16, global_batch=8, seed=7)
    b1, b2 = pipe.batch(3), pipe.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(pipe.batch(4)["tokens"], b1["tokens"])
    # shards partition the global batch
    parts = [pipe.shard_for(3, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_checkpoint_roundtrip_and_validation(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "s": jnp.asarray(3, jnp.int32)}}
    d = str(tmp_path)
    save_checkpoint(d, 7, tree, extra={"note": "x"})
    restored, manifest = load_checkpoint(d, tree)
    assert manifest["step"] == 7
    for l1, l2 in zip(jax.tree_util.tree_leaves(tree),
                      jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                      np.asarray(l2, np.float32))
    # corruption detection
    import numpy as _np
    npz = os.path.join(d, "step_000000007", "arrays.npz")
    data = dict(_np.load(npz, allow_pickle=False))
    data["leaf_0"] = data["leaf_0"] + 1
    _np.savez(npz, **data)
    with pytest.raises(IOError):
        load_checkpoint(d, tree)


def test_checkpoint_manager_async_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((3,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    steps = sorted(x for x in os.listdir(tmp_path) if x.startswith("step_"))
    assert len(steps) == 2
    assert mgr.latest_step() == 4


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, min_samples=3)
    for _ in range(5):
        assert not mon.observe(1.0)
    assert mon.observe(5.0)
    assert mon.flagged == 1
    assert not mon.observe(1.1)
    assert mon.rescale_factor(16, 1) == pytest.approx(16 / 15)


def test_run_elastic_restarts():
    calls = {"n": 0}

    def fn(resume):
        calls["n"] += 1
        if calls["n"] < 3:
            raise SimulatedFailure("boom")
        return 42

    assert run_elastic(fn, max_restarts=5) == 42
    assert calls["n"] == 3
    calls["n"] = 0
    with pytest.raises(SimulatedFailure):
        run_elastic(fn, max_restarts=1)


def test_conduit_scheduler_prefers_feasible_plans():
    cfg = configs.get("deepseek-v2-236b")
    sched = ConduitScheduler()
    best, ests = sched.choose(cfg, "train", global_batch=256, seq_len=4096,
                              chips=256, data_par=16, model_par=16)
    assert best.feasible
    by_name = {e.plan.name: e for e in ests}
    # replicating 236B of weights cannot fit 16 GB HBM
    assert not by_name["replicated-weights"].feasible
    # INT8 gradient compression strictly reduces collective time
    assert by_name["compressed-grads"].collective_s < \
        by_name["baseline"].collective_s


def test_conduit_scheduler_estimates_positive():
    cfg = configs.get("tinyllama-1.1b")
    sched = ConduitScheduler()
    for kind in ("train", "prefill", "decode"):
        best, ests = sched.choose(cfg, kind, 32, 2048, 256, 16, 16)
        for e in ests:
            assert e.compute_s >= 0 and e.memory_s > 0
            assert e.total_s >= e.exposed_collective_s


@pytest.mark.slow
def test_microbatched_step_matches_full_batch():
    """Gradient accumulation over 4 microbatches == single-shot step."""
    import repro.models.model as M
    from repro.launch.steps import build_train_step
    cfg = configs.get("xlstm-125m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16),
                                                dtype=np.int32)),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16),
                                                dtype=np.int32))}
    full = build_train_step(cfg, 10)(params, adamw_init(params), batch)
    micro = build_train_step(cfg, 10, microbatches=4)(
        params, adamw_init(params), batch)
    np.testing.assert_allclose(float(full[2]["loss"]),
                               float(micro[2]["loss"]), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(full[0]),
                    jax.tree_util.tree_leaves(micro[0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)
