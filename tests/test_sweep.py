"""Batched-sweep laws (:mod:`repro.sim.sweep`).

The batch layer's one promise: batching never changes an answer.

(a) backend — the array module is numpy unless JAX runs in 64-bit mode
    (the bisection must stay IEEE double for the bit-identity law);
(b) vectorized arrivals — each row of the batched Poisson grid matches
    the scalar ``PoissonArrivals`` loop (the integer hash exactly, the
    float tail to tight tolerance);
(c) lockstep bisection — ``batched_find_saturation`` is bit-identical to
    sequential ``find_saturation`` calls per lane: same probes, same
    rates, same brackets, because the probe body is shared and float64
    midpoints are the same arithmetic either way.
"""
import numpy as np
import pytest

from repro.sim import (CatalogEntry, PoissonArrivals, SessionCatalog,
                       SweepLane, array_backend, batched_find_saturation,
                       batched_poisson_arrival_times_ns, find_saturation)

from _synth import synth_trace

OPS = [1, 4, 7, 2, 5, 0, 3, 6]


def small_catalog():
    return SessionCatalog([CatalogEntry("A", synth_trace(OPS, name="A"))],
                          seed=3)


# -- (a) backend ---------------------------------------------------------------

def test_array_backend_is_double_precision():
    xp = array_backend()
    # numpy by default; jax.numpy only if x64 was explicitly enabled —
    # either way the backend must carry real float64
    assert xp.asarray([0.5], dtype=xp.float64).dtype == np.float64
    try:
        import jax
        if not getattr(jax.config, "jax_enable_x64", False):
            assert xp is np
    except ImportError:
        assert xp is np


# -- (b) vectorized arrivals ---------------------------------------------------

def test_batched_poisson_rows_match_scalar_loop():
    rates = [500.0, 2000.0, 8000.0, 50_000.0]
    grid = batched_poisson_arrival_times_ns(rates, 48, seed=77, start_ns=5.0)
    assert grid.shape == (4, 48)
    for row, rate in zip(grid, rates):
        ref = PoissonArrivals(rate_per_sec=rate, n_sessions=48, seed=77,
                              start_ns=5.0).arrival_times_ns()
        np.testing.assert_allclose(np.asarray(row), ref, rtol=1e-12)


def test_batched_poisson_rows_are_increasing_and_rate_ordered():
    grid = np.asarray(batched_poisson_arrival_times_ns(
        [1000.0, 4000.0], 32, seed=9))
    assert (np.diff(grid, axis=1) > 0).all()      # gaps strictly positive
    # same uniforms => the faster row is a pure time compression
    assert (grid[1] < grid[0]).all()


def test_batched_poisson_validation():
    with pytest.raises(ValueError, match="non-empty"):
        batched_poisson_arrival_times_ns([], 8)
    with pytest.raises(ValueError, match="> 0"):
        batched_poisson_arrival_times_ns([1000.0, -1.0], 8)
    with pytest.raises(ValueError, match="n_sessions"):
        batched_poisson_arrival_times_ns([1000.0], 0)


# -- (c) lockstep bisection ----------------------------------------------------

def _probe_key(probes):
    return [(p.rate_per_sec, p.p99_ns, p.n_rejected, p.sustainable)
            for p in probes]


def test_lockstep_bisection_bit_identical_to_sequential():
    """The central law: a batched sweep's every lane — probes included —
    equals the standalone search with the same (policy, seed).  The lane
    mix is deliberate: two lanes that bisect the full ``iters`` rounds
    next to one that dies at ``rate_lo`` (its SLO is unreachable), so the
    live-lane bookkeeping is exercised alongside an endpoint dropout."""
    cat = small_catalog()
    slo, lo, hi, iters = 1.5e5, 50.0, 200_000.0, 3
    lanes = [SweepLane("cpu", seed=11, n_sessions=10),
             SweepLane("cpu", seed=77, n_sessions=10),
             SweepLane("conduit", seed=11, n_sessions=10)]
    batched = batched_find_saturation(cat, lanes, slo, lo, hi, iters=iters)
    for lane, got in zip(lanes, batched):
        ref = find_saturation(cat, lane.policy, slo, lo, hi, iters=iters,
                              n_sessions=lane.n_sessions, seed=lane.seed)
        assert got.rate_per_sec == ref.rate_per_sec
        assert got.bracket == ref.bracket
        assert _probe_key(got.probes) == _probe_key(ref.probes)
    # the cpu lanes genuinely bisected (endpoints + iters midpoints);
    # the conduit lane dropped out at the first endpoint probe
    assert len(batched[0].probes) == 2 + iters
    assert len(batched[1].probes) == 2 + iters
    assert batched[2].rate_per_sec == 0.0
    assert len(batched[2].probes) == 1


def test_lockstep_endpoint_lanes_resolve_without_bisection():
    """A lane that fails at rate_lo (impossible SLO) or holds at rate_hi
    (infinite SLO) resolves in the endpoint round — 0.0 / rate_hi with
    one / two probes — exactly as the scalar search does."""
    cat = small_catalog()
    lanes = [SweepLane("conduit", seed=11, n_sessions=6)]
    dead = batched_find_saturation(cat, lanes, 1.0, 50.0, 1000.0, iters=4)[0]
    assert dead.rate_per_sec == 0.0 and dead.bracket == (0.0, 50.0)
    assert len(dead.probes) == 1
    easy = batched_find_saturation(cat, lanes, 1e12, 50.0, 1000.0,
                                   iters=4)[0]
    assert easy.rate_per_sec == 1000.0 and easy.bracket == (1000.0, 1000.0)
    assert len(easy.probes) == 2


def test_batched_find_saturation_validation():
    cat = small_catalog()
    lane = SweepLane("conduit")
    with pytest.raises(ValueError, match="rate_lo"):
        batched_find_saturation(cat, [lane], 1e6, 100.0, 50.0)
    with pytest.raises(ValueError, match="iters"):
        batched_find_saturation(cat, [lane], 1e6, 50.0, 100.0, iters=0)
    with pytest.raises(ValueError, match="SweepLane"):
        batched_find_saturation(cat, [], 1e6, 50.0, 100.0)
