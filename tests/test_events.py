"""Event-engine laws: determinism, single-tenant equivalence, conservation.

The three acceptance properties of the discrete-event core:

(a) determinism — identical ``SimResult``/``MixResult`` across repeated
    runs and across I/O-stream seeds being held fixed;
(b) equivalence — ``simulate_mix([trace])`` with no host I/O reproduces
    ``simulate(trace)`` makespan/energy for every policy in
    ``make_policy`` (the event engine's single-source degeneration);
(c) conservation — per-tenant instruction counts are preserved, pool busy
    time never exceeds units x schedule horizon, and the processed event
    timeline is monotone in time.
"""
import pytest

from repro.core.policies import ALL_POLICIES
from repro.hw.ssd_spec import DEFAULT_SSD
from repro.sim import (EventEngine, EventKind, HostIOStream, SimConfig,
                       simulate, simulate_mix)
from repro.workloads import get_trace

from _synth import synth_trace

RAMP = list(range(40))
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4


# -- the engine itself ---------------------------------------------------------

def test_engine_orders_events_and_breaks_ties_fifo():
    eng = EventEngine(record=True)
    seen = []
    eng.schedule(5.0, EventKind.TIMER, seen.append, payload="late")
    eng.schedule(1.0, EventKind.TIMER, seen.append, payload="early")
    eng.schedule(5.0, EventKind.TIMER, seen.append, payload="late2")
    eng.run()
    assert seen == ["early", "late", "late2"]   # time order, FIFO on ties
    assert eng.processed == 3
    assert eng.now == 5.0


def test_engine_rejects_time_travel():
    eng = EventEngine()
    eng.schedule(100.0, EventKind.TIMER, lambda _: None)
    eng.run()
    with pytest.raises(ValueError):
        eng.schedule(10.0, EventKind.TIMER, lambda _: None)


def test_engine_handlers_can_chain():
    eng = EventEngine()
    ticks = []

    def tick(_):
        ticks.append(eng.now)
        if len(ticks) < 5:
            eng.schedule(eng.now + 10.0, EventKind.TIMER, tick)

    eng.schedule(0.0, EventKind.TIMER, tick)
    eng.run()
    assert ticks == [0.0, 10.0, 20.0, 30.0, 40.0]


# -- (a) determinism -----------------------------------------------------------

def test_mix_deterministic_across_runs():
    io = HostIOStream(rate_iops=80_000, n_requests=64, seed=7)
    results = []
    for _ in range(2):
        a = synth_trace(RAMP, name="A")
        b = synth_trace(MIXED, name="B")
        results.append(simulate_mix([a, b], "conduit", io_stream=io))
    r1, r2 = results
    assert r1.makespan_ns == pytest.approx(r2.makespan_ns, rel=1e-12)
    assert r1.total_energy_nj == pytest.approx(r2.total_energy_nj, rel=1e-12)
    for t1, t2 in zip(r1.tenants, r2.tenants):
        assert t1.makespan_ns == pytest.approx(t2.makespan_ns, rel=1e-12)
        assert t1.resource_counts == t2.resource_counts
    assert r1.host_io.latencies_ns == pytest.approx(r2.host_io.latencies_ns)


def test_io_stream_seed_changes_arrivals_deterministically():
    s1 = HostIOStream(n_requests=32, seed=1)
    s2 = HostIOStream(n_requests=32, seed=2)
    assert s1.arrival_times_ns() == s1.arrival_times_ns()
    assert s1.arrival_times_ns() != s2.arrival_times_ns()
    times = s1.arrival_times_ns()
    assert all(b > a for a, b in zip(times, times[1:]))


# -- (b) equivalence -----------------------------------------------------------

@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_single_trace_mix_matches_simulate_synth(policy):
    tr = synth_trace(MIXED)
    solo = simulate(tr, policy)
    mix = simulate_mix([tr], policy, compute_solo=False)
    assert len(mix.tenants) == 1
    got = mix.tenants[0]
    assert got.makespan_ns == pytest.approx(solo.makespan_ns, rel=1e-9)
    assert got.total_energy_nj == pytest.approx(solo.total_energy_nj, rel=1e-9)
    assert got.resource_counts == solo.resource_counts


@pytest.mark.parametrize("workload", ["jacobi1d", "aes"])
@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_single_trace_mix_matches_simulate_workloads(workload, policy):
    tr = get_trace(workload, "tiny")
    solo = simulate(tr, policy)
    mix = simulate_mix([tr], policy, compute_solo=False)
    got = mix.tenants[0]
    assert got.makespan_ns == pytest.approx(solo.makespan_ns, rel=1e-6)
    assert got.total_energy_nj == pytest.approx(solo.total_energy_nj, rel=1e-6)


# -- (c) conservation ----------------------------------------------------------

def test_empty_trace_still_flushes_outputs():
    """A trace with no instructions still runs the §4.4 epilogue (output
    pages move to the host) — the seed simulator's behavior."""
    tr = synth_trace([], name="empty")
    r = simulate(tr, "conduit")
    assert r.n_instrs == 0
    assert r.makespan_ns > 0
    assert r.movement_energy_nj > 0


def test_mix_conserves_instruction_counts():
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    mix = simulate_mix([a, b], "conduit",
                       io_stream=HostIOStream(n_requests=32),
                       compute_solo=False)
    by_tenant = {r.tenant: r for r in mix.tenants}
    assert sum(by_tenant["t0:A"].resource_counts.values()) == len(RAMP)
    assert sum(by_tenant["t1:B"].resource_counts.values()) == len(MIXED)
    assert mix.host_io.n_requests == 32
    assert len(mix.host_io.latencies_ns) == 32


def test_busy_time_bounded_by_schedule_horizon():
    """No pool can be busier than units x the end of its booked work."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    engine = EventEngine()
    from repro.sim.servers import Fabric
    from repro.sim.machine import Simulation
    from repro.core.policies import make_policy
    fabric = Fabric(DEFAULT_SSD)
    sims = [Simulation(a, make_policy("conduit", DEFAULT_SSD),
                       fabric=fabric, tenant="A"),
            Simulation(b, make_policy("conduit", DEFAULT_SSD),
                       fabric=fabric, tenant="B")]
    for s in sims:
        s.bind(engine)
    engine.run()
    horizon = fabric.horizon_ns
    for pool in fabric.all_pools():
        assert pool.busy_ns <= pool.units * horizon + 1e-6, pool.name
    for s in sims:
        assert s.result().makespan_ns <= horizon + 1e-6


def test_event_timeline_monotone():
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    eng = EventEngine(record=True)
    simulate_mix([a, b], "conduit",
                 io_stream=HostIOStream(n_requests=48),
                 compute_solo=False, engine=eng)
    times = [t for t, _ in eng.log]
    assert times, "engine recorded no events"
    assert all(b >= a for a, b in zip(times, times[1:]))
    kinds = {k for _, k in eng.log}
    assert EventKind.DISPATCH in kinds
    assert EventKind.IO_ARRIVAL in kinds
    assert EventKind.IO_COMPLETE in kinds
    assert EventKind.EPILOGUE in kinds


def test_decision_timestamps_monotone_per_tenant():
    """In-order issue per tenant: decision times never regress even though
    completions are out of order across resources/tenants."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    mix = simulate_mix([a, b], "conduit", compute_solo=False)
    for r in mix.tenants:
        decides = [d.t_decide for d in r.decisions]
        assert decides == sorted(decides)
        iids = [d.iid for d in r.decisions]
        assert iids == sorted(iids)
