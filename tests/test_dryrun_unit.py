"""Dry-run machinery unit tests (no 512-device sweep needed): HLO
collective parsing, roofline math, mesh construction, sharding rules."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.hw.tpu_spec import TPU_V5E
from repro.launch.costing import _result_bytes, collective_bytes
from repro.launch import sharding as SH

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[8,128]{1,0} parameter(0)
  %ar = f32[16,4096]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[256,512]{1,0} all-gather(%ar), dimensions={0}
  %rs = f32[8,8]{1,0} reduce-scatter(%ag), dimensions={0}
  %cp = bf16[4,4]{1,0} collective-permute(%rs)
  %a2a = f32[2,2]{1,0} all-to-all(%cp)
  %ars = f32[16,16]{1,0} all-reduce-start(%a2a)
  %mult = f32[16,16]{1,0} multiply(%ars, %ars)
}
"""


def test_result_bytes():
    assert _result_bytes("%x = f32[16,4096]{1,0} all-reduce(%y)") == \
        16 * 4096 * 4
    assert _result_bytes("%x = bf16[8,128]{1,0} parameter(0)") == 8 * 128 * 2
    # tuple result
    line = "%t = (f32[4]{0}, bf16[2,2]{1,0}) all-reduce(%a, %b)"
    assert _result_bytes(line) == 4 * 4 + 2 * 2 * 2


def test_collective_bytes_parser():
    out = collective_bytes(HLO)
    assert out["all-reduce"] == 16 * 4096 * 4 + 16 * 16 * 4  # incl -start
    assert out["all-gather"] == 256 * 512 * 2
    assert out["reduce-scatter"] == 8 * 8 * 4
    assert out["collective-permute"] == 4 * 4 * 2
    assert out["all-to-all"] == 2 * 2 * 4
    assert out["ops"] == 6
    assert out["total"] == sum(out[k] for k in (
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_roofline_terms():
    t = TPU_V5E.roofline_terms(flops=197e12, hbm_bytes=819e9,
                               collective_bytes=100e9, chips=1)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    t2 = TPU_V5E.roofline_terms(1e12, 819e9, 0, chips=1)
    assert t2["dominant"] == "memory"


def test_mesh_is_function_not_constant():
    import importlib
    import repro.launch.mesh as mesh_mod
    importlib.reload(mesh_mod)   # importing must not touch device state
    assert callable(mesh_mod.make_production_mesh)


def test_fit_drops_nondivisible_axes():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    # axis size 1 always divides
    spec = SH._fit(mesh, (7, 13), ["data", "model"])
    assert spec == P("data", "model")


def test_param_spec_rules():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(dev, ("data", "model"))
    from jax.tree_util import DictKey
    # column-parallel
    spec = SH.param_spec_for((DictKey("attn"), DictKey("wq")),
                             (4, 64, 64), mesh, ("data",), "model")
    assert spec[-1] == "model"
    # row-parallel
    spec = SH.param_spec_for((DictKey("mlp"), DictKey("w2")),
                             (4, 64, 64), mesh, ("data",), "model")
    assert spec[-2] == "model"
    # experts: EP over model at dim -3
    spec = SH.param_spec_for((DictKey("moe"), DictKey("experts"),
                              DictKey("w1")), (2, 4, 8, 8), mesh,
                             ("data",), "model")
    assert spec[1] == "model"
    # norms replicate
    spec = SH.param_spec_for((DictKey("ln1"),), (64,), mesh,
                             ("data",), "model")
    assert all(e is None for e in spec)
