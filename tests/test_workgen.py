"""Arrival-process and session-catalog laws (:mod:`repro.sim.workgen`).

Every generator must be (a) fully seeded — identical inputs replay
identical arrival streams, (b) well-ordered — times non-decreasing (and
strictly increasing where gaps are continuous draws), and (c) rescalable
— ``at_rate`` preserves the process shape while hitting the new mean
rate, which is what the saturation finder bisects over.
"""
import pytest

from repro.sim import (CatalogEntry, DeterministicArrivals, MMPPArrivals,
                       PoissonArrivals, SessionCatalog, SuperposedArrivals,
                       TraceReplayArrivals)

from _synth import synth_trace


# -- determinism ---------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda seed: PoissonArrivals(rate_per_sec=2000, n_sessions=64, seed=seed),
    lambda seed: MMPPArrivals(rate_on_per_sec=4000, mean_on_ns=5e6,
                              mean_off_ns=5e6, n_sessions=64, seed=seed),
])
def test_same_seed_replays_identically(make):
    assert make(7).arrival_times_ns() == make(7).arrival_times_ns()
    assert make(7).arrival_times_ns() != make(8).arrival_times_ns()


def test_arrival_times_are_ordered_and_nonnegative():
    for proc in (PoissonArrivals(rate_per_sec=5000, n_sessions=48),
                 DeterministicArrivals(rate_per_sec=5000, n_sessions=48),
                 MMPPArrivals(rate_on_per_sec=8000, n_sessions=48),
                 TraceReplayArrivals(times_ns=(0.0, 1.0, 1.0, 5.0))):
        ts = proc.arrival_times_ns()
        assert len(ts) >= 4
        assert all(t >= 0.0 for t in ts)
        assert all(b >= a for a, b in zip(ts, ts[1:]))


# -- rate semantics ------------------------------------------------------------

def test_deterministic_rate_is_exact():
    proc = DeterministicArrivals(rate_per_sec=1000, n_sessions=10)
    ts = proc.arrival_times_ns()
    gaps = [b - a for a, b in zip(ts, ts[1:])]
    assert all(g == pytest.approx(1e6) for g in gaps)
    assert proc.mean_rate_per_sec == 1000


def test_poisson_empirical_rate_matches_nominal():
    proc = PoissonArrivals(rate_per_sec=10_000, n_sessions=256, seed=3)
    ts = proc.arrival_times_ns()
    rate = (len(ts) - 1) / ((ts[-1] - ts[0]) / 1e9)
    assert rate == pytest.approx(10_000, rel=0.25)


def test_at_rate_rescales_every_process():
    procs = [PoissonArrivals(rate_per_sec=1000, n_sessions=64),
             DeterministicArrivals(rate_per_sec=1000, n_sessions=64),
             MMPPArrivals(rate_on_per_sec=2000, rate_off_per_sec=500,
                          n_sessions=64),
             TraceReplayArrivals(times_ns=tuple(
                 float(i * 100 + i * 7 % 50) for i in range(64)))]
    for proc in procs:
        scaled = proc.at_rate(2 * proc.mean_rate_per_sec)
        assert scaled.mean_rate_per_sec == \
            pytest.approx(2 * proc.mean_rate_per_sec)


def test_trace_replay_at_rate_preserves_gap_structure():
    proc = TraceReplayArrivals(times_ns=(0.0, 10.0, 30.0, 100.0))
    fast = proc.at_rate(2 * proc.mean_rate_per_sec)
    ts = fast.arrival_times_ns()
    gaps = [b - a for a, b in zip(ts, ts[1:])]
    # relative gap ratios survive the time compression
    assert gaps[1] / gaps[0] == pytest.approx(2.0)
    assert gaps[2] / gaps[0] == pytest.approx(7.0)


def test_mmpp_off_state_is_burstier_than_poisson():
    """ON/OFF modulated arrivals at the same mean rate are burstier: the
    inter-arrival coefficient of variation clearly exceeds the Poisson
    process's (which sits near 1)."""
    def cv(ts):
        gaps = [b - a for a, b in zip(ts, ts[1:])]
        mean = sum(gaps) / len(gaps)
        var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
        return var ** 0.5 / mean

    mm = MMPPArrivals(rate_on_per_sec=50_000, rate_off_per_sec=0.0,
                      mean_on_ns=0.2e6, mean_off_ns=10e6, n_sessions=64,
                      seed=3)
    po = PoissonArrivals(rate_per_sec=mm.mean_rate_per_sec, n_sessions=64,
                         seed=3)
    assert cv(mm.arrival_times_ns()) > 2.0 > cv(po.arrival_times_ns())
    assert mm.mean_rate_per_sec == pytest.approx(50_000 * 0.2 / 10.2)


def test_superpose_merges_and_sums_rates():
    a = PoissonArrivals(rate_per_sec=1000, n_sessions=32, seed=1)
    b = DeterministicArrivals(rate_per_sec=500, n_sessions=16)
    sup = SuperposedArrivals((a, b))
    ts = sup.arrival_times_ns()
    assert len(ts) == 48
    assert all(y >= x for x, y in zip(ts, ts[1:]))
    assert sorted(a.arrival_times_ns() + b.arrival_times_ns()) == ts
    assert sup.mean_rate_per_sec == pytest.approx(1500)
    half = sup.at_rate(750)
    assert half.mean_rate_per_sec == pytest.approx(750)


# -- validation ----------------------------------------------------------------

def test_process_validation_errors():
    with pytest.raises(ValueError):
        PoissonArrivals(rate_per_sec=0.0)
    with pytest.raises(ValueError):
        PoissonArrivals(n_sessions=0)
    with pytest.raises(ValueError):
        DeterministicArrivals(rate_per_sec=-1.0)
    with pytest.raises(ValueError):
        MMPPArrivals(rate_on_per_sec=0.0)
    with pytest.raises(ValueError):
        MMPPArrivals(mean_on_ns=0.0)
    with pytest.raises(ValueError):
        TraceReplayArrivals(times_ns=())
    with pytest.raises(ValueError):
        TraceReplayArrivals(times_ns=(5.0, 1.0))   # not sorted
    with pytest.raises(ValueError):
        TraceReplayArrivals(times_ns=(-1.0, 1.0))
    with pytest.raises(ValueError):
        # a zero-span log has no rate: rescaling would emit NaN times
        TraceReplayArrivals(times_ns=(100.0,)).at_rate(1000)
    with pytest.raises(ValueError):
        SuperposedArrivals(())


# -- session catalog -----------------------------------------------------------

def test_catalog_draw_is_deterministic_and_weighted():
    heavy = synth_trace([1, 2], name="heavy")
    light = synth_trace([3], name="light")
    cat = SessionCatalog([CatalogEntry("heavy", heavy, weight=9.0),
                          CatalogEntry("light", light, weight=1.0)], seed=5)
    counts = cat.kind_counts(200)
    assert counts == SessionCatalog(cat.entries, seed=5).kind_counts(200)
    assert counts["heavy"] + counts["light"] == 200
    assert counts["heavy"] > counts["light"] * 3    # 9:1 weights dominate
    # a different seed permutes the kind sequence
    seq = [cat.draw(i).name for i in range(64)]
    other = [SessionCatalog(cat.entries, seed=6).draw(i).name
             for i in range(64)]
    assert seq != other


def test_catalog_validation_errors():
    tr = synth_trace([1], name="t")
    with pytest.raises(ValueError):
        SessionCatalog([])
    with pytest.raises(ValueError):
        SessionCatalog([CatalogEntry("a", tr), CatalogEntry("a", tr)])
    with pytest.raises(ValueError):
        CatalogEntry("bad", tr, weight=0.0)


# -- at_rate invariants (what find_saturation's rescaling relies on) -----------

def _realized_rate_per_sec(times_ns):
    """Empirical arrival rate over a stream's span (first arrival opens
    the observation window)."""
    span_s = (times_ns[-1] - times_ns[0]) / 1e9
    return (len(times_ns) - 1) / span_s


def test_superposed_at_rate_preserves_part_proportions():
    """Rescaling a superposition must scale every component by the same
    factor: each part's share of the total — nominal *and* realized —
    is invariant under ``at_rate``.  (A rescale that fed the whole delta
    to one part would change the traffic mix mid-bisection.)"""
    base = SuperposedArrivals((
        PoissonArrivals(rate_per_sec=2000, n_sessions=48, seed=1),
        PoissonArrivals(rate_per_sec=6000, n_sessions=48, seed=2)))
    scaled = base.at_rate(2.5 * base.mean_rate_per_sec)

    # nominal shares: exact
    tot_b = base.mean_rate_per_sec
    tot_s = scaled.mean_rate_per_sec
    for pb, ps in zip(base.parts, scaled.parts):
        assert ps.mean_rate_per_sec / tot_s == \
            pytest.approx(pb.mean_rate_per_sec / tot_b, rel=1e-12)

    # realized shares: Poisson parts reuse the same hashed uniforms, so
    # their streams scale exactly and the empirical mix is preserved
    rb = [_realized_rate_per_sec(p.arrival_times_ns()) for p in base.parts]
    rs = [_realized_rate_per_sec(p.arrival_times_ns()) for p in scaled.parts]
    for b, s in zip(rb, rs):
        assert s / sum(rs) == pytest.approx(b / sum(rb), rel=1e-9)


def test_mmpp_at_rate_realized_rate_tracks_nominal():
    """``at_rate`` on an MMPP scales both state rates (dwell structure
    untouched); the realized rate of the rescaled stream must track the
    requested nominal rate — not just the dataclass field."""
    base = MMPPArrivals(rate_on_per_sec=8000, rate_off_per_sec=2000,
                        mean_on_ns=5e6, mean_off_ns=5e6, n_sessions=400,
                        seed=3)
    for factor in (0.5, 1.0, 3.0):
        target = factor * base.mean_rate_per_sec
        p = base.at_rate(target)
        assert p.mean_rate_per_sec == pytest.approx(target, rel=1e-12)
        realized = _realized_rate_per_sec(p.arrival_times_ns())
        assert realized == pytest.approx(target, rel=0.25)
