"""Model-zoo correctness: cache-consistency (prefill+decode == full forward),
MoE routing laws, shapes/finiteness per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models.config import ArchConfig


def _mk(arch):
    cfg = configs.get(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


# one cheap arch stays in the fast tier; the rest of the cache-consistency
# grid runs nightly
@pytest.mark.parametrize("arch", [
    "tinyllama-1.1b",
    pytest.param("qwen3-4b", marks=pytest.mark.slow),
    pytest.param("deepseek-v2-236b", marks=pytest.mark.slow),
    pytest.param("zamba2-1.2b", marks=pytest.mark.slow),
    pytest.param("xlstm-125m", marks=pytest.mark.slow),
])
def test_decode_matches_full_forward(arch):
    """Prefill(s-1 tokens) + decode(token s-1) must reproduce the logits of
    a full forward over s tokens — validates KV caches, MLA latent caches,
    Mamba/xLSTM recurrent states and position handling."""
    cfg, params = _mk(arch)
    B, S = 2, 8
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S), dtype=np.int32))

    # full forward logits at the last position
    x = M.embed(cfg, params, tokens)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, _ = M.forward(cfg, params, x, pos)
    full_logits = M.logits_of(cfg, params, h)[:, -1]

    # prefill on the first S-1, then one decode step
    caches = M.init_cache(cfg, B, S + 4)
    _, caches = M.prefill(cfg, params, tokens[:, :-1], caches)
    dec_logits, _ = M.decode_step(cfg, params, tokens[:, -1], S - 1, caches)

    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               atol=0.15, rtol=0.05)


@pytest.mark.slow
def test_moe_capacity_and_routing():
    cfg = configs.get("dbrx-132b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    from repro.models import layers as L
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 8, cfg.d_model)).astype(np.float32))
    moe_p = params["segments"][0]["moe"]
    one = jax.tree_util.tree_map(lambda a: a[0], moe_p)
    y = L.moe_apply(one, cfg, x.astype(jnp.bfloat16))
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()


@pytest.mark.slow
def test_moe_grads_flow():
    cfg = configs.get("deepseek-v2-236b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, 8), jnp.int32)
    labels = jnp.ones((1, 8), jnp.int32)

    g = jax.grad(lambda p: M.lm_loss(cfg, p, tokens, labels))(params)
    moe_g = g["segments"][0]["moe"]["experts"]["w1"]
    assert np.isfinite(np.asarray(moe_g, np.float32)).all()
    router_g = g["segments"][0]["moe"]["router"]
    assert float(jnp.abs(router_g.astype(jnp.float32)).sum()) > 0.0


def test_mla_cache_is_compressed():
    """The MLA cache stores the low-rank latent, not full K/V heads."""
    cfg = configs.get("deepseek-v2-236b").reduced()
    caches = M.init_cache(cfg, batch=1, max_seq=16)
    leaf_names = set()
    jax.tree_util.tree_map_with_path(
        lambda p, l: leaf_names.add(str(p[-1].key)), caches[0])
    assert "latent" in leaf_names and "k" not in leaf_names
    latent = caches[0]["latent"]
    assert latent.shape[-1] == cfg.kv_lora_rank


@pytest.mark.slow
def test_zamba2_shared_attention_params_are_shared():
    cfg = configs.get("zamba2-1.2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    assert "shared_attn" in params
    # sattn segments carry no parameters of their own
    segs = M.segments_of(cfg)
    for seg_p, (kind, _) in zip(params["segments"], segs):
        if kind == "sattn":
            assert seg_p is None


def test_sub_quadratic_flags():
    assert configs.get("zamba2-1.2b").sub_quadratic
    assert configs.get("xlstm-125m").sub_quadratic
    for a in ("tinyllama-1.1b", "qwen3-4b", "dbrx-132b",
              "deepseek-v2-236b", "seamless-m4t-medium", "qwen2-vl-2b"):
        assert not configs.get(a).sub_quadratic


@pytest.mark.slow
def test_qk_norm_changes_attention():
    cfg = configs.get("qwen3-4b").reduced()
    assert cfg.qk_norm
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    seg = params["segments"][0]
    assert "q_norm" in seg["attn"] and "k_norm" in seg["attn"]


@pytest.mark.slow
def test_encdec_uses_encoder():
    cfg = configs.get("seamless-m4t-medium").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 6
    tokens = jnp.zeros((B, S), jnp.int32)
    labels = jnp.ones((B, S), jnp.int32)
    feats = jnp.asarray(np.random.default_rng(0).normal(
        size=(B, 4, cfg.d_model)), jnp.float32)
    l_with = M.lm_loss(cfg, params, tokens, labels, enc_feats=feats)
    l_without = M.lm_loss(cfg, params, tokens, labels,
                          enc_feats=jnp.zeros_like(feats))
    assert np.isfinite(float(l_with)) and np.isfinite(float(l_without))
    assert abs(float(l_with) - float(l_without)) > 1e-6


@pytest.mark.slow
def test_mrope_position_streams_matter():
    cfg = configs.get("qwen2-vl-2b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 6
    tokens = jnp.zeros((B, S), jnp.int32)
    labels = jnp.ones((B, S), jnp.int32)
    emb = jnp.asarray(np.random.default_rng(0).normal(
        size=(B, 2, cfg.d_model)), jnp.float32)
    p1 = jnp.zeros((3, B, S + 2), jnp.int32)
    p2 = jnp.stack([jnp.arange(S + 2)[None].repeat(B, 0)] * 3)
    l1 = M.lm_loss(cfg, params, tokens, labels, extra_embeds=emb, pos3=p1)
    l2 = M.lm_loss(cfg, params, tokens, labels, extra_embeds=emb, pos3=p2)
    assert abs(float(l1) - float(l2)) > 1e-6
