"""Flight-recorder laws (:mod:`repro.sim.telemetry`).

The acceptance properties:

(a) non-perturbation — with telemetry FULLY enabled (spans + audit +
    interval sampler) every golden digest in
    ``tests/test_golden_equivalence.py`` is bit-identical: the recorder
    observes, never perturbs;
(b) off by default — no recorder object exists unless asked for
    (the zero-overhead-off discipline; the wall-clock side is gated by
    ``benchmarks/perf_bench.py --check``, whose measured path runs with
    telemetry off);
(c) audit fidelity — the audit stream agrees 1:1 with the always-on
    DecisionRecord slice, and every candidate's Eqn-1 total re-derives
    from its six features;
(d) breakdown accounting — per-(op, resource) phase sums are
    non-negative and the counts add up to the run's instruction count;
(e) round trip — ``validate_trace`` accepts every trace the recorder
    exports and everything ``summarize`` accepts, and rejects corrupted
    traces loudly (the CLI exit codes pin the same contract);
(f) the serving Little's-law consistency warning fires on
    edge-dominated windows and stays quiet on stable ones.
"""
import io
import json
import warnings

import pytest

from repro.sim import (CatalogEntry, FTLConfig, FlightRecorder,
                       HostIOStream, PoissonArrivals, ServingConfig,
                       SessionCatalog, TelemetryConfig, simulate,
                       simulate_mix, simulate_serving)
from repro.sim.telemetry import (PID_FABRIC, PID_FTL, SCHEMA, as_recorder,
                                 main as telemetry_main, summarize,
                                 validate_trace)

import _golden
from _synth import synth_trace
from test_golden_equivalence import GOLDEN

#: everything on, sampler included — the config the golden law runs under
FULL = TelemetryConfig(spans=True, audit=True, interval_ns=50_000.0)

RAMP = list(range(40))
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4


def small_catalog():
    return SessionCatalog(
        [CatalogEntry("A", synth_trace(RAMP, name="A"))])


def _gc_mix(telemetry):
    """The golden GC scenario's exact configuration, recorder attached."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, prefill=0.9,
                    op_ratio=0.28)
    io = HostIOStream(rate_iops=250_000, read_fraction=0.3, n_requests=160,
                      zipf_theta=0.95, n_logical_pages=ftl.logical_pages())
    return simulate_mix([a, b], "conduit", io_stream=io, ftl=ftl,
                        compute_solo=False, telemetry=telemetry)


@pytest.fixture(scope="module")
def gc_recorder():
    return _gc_mix(FULL).telemetry


@pytest.fixture(scope="module")
def gc_trace(gc_recorder):
    return gc_recorder.chrome_trace()


# -- (a) the recorder never perturbs the simulation ----------------------------

@pytest.mark.parametrize("policy", _golden.GOLDEN_POLICIES)
def test_single_digest_bit_identical_with_telemetry_on(policy):
    assert _golden.scenario_single(policy, telemetry=FULL) \
        == GOLDEN[f"single/{policy}"]


def test_pressure_fault_digest_bit_identical_with_telemetry_on():
    assert _golden.scenario_pressure(telemetry=FULL) \
        == GOLDEN["pressure_fault"]


def test_mix_digest_bit_identical_with_telemetry_on():
    assert _golden.scenario_mix(telemetry=FULL) == GOLDEN["mix_2tenant_io"]


def test_gc_ftl_digest_bit_identical_with_telemetry_on():
    assert _golden.scenario_gc(telemetry=FULL) == GOLDEN["gc_ftl"]


# -- (b) off by default, normalization at the entry points ---------------------

def test_telemetry_is_off_by_default():
    res = simulate(synth_trace(MIXED), "conduit")
    assert res.telemetry is None


def test_as_recorder_normalization():
    assert as_recorder(None) is None
    assert as_recorder(False) is None
    rec = as_recorder(True)
    assert isinstance(rec, FlightRecorder)
    cfg = TelemetryConfig(spans=False)
    assert as_recorder(cfg).cfg is cfg
    assert as_recorder(rec) is rec
    with pytest.raises(TypeError, match="telemetry must be"):
        as_recorder(3)


def test_config_validation_is_loud():
    with pytest.raises(ValueError):
        TelemetryConfig(interval_ns=-1.0)
    with pytest.raises(ValueError):
        TelemetryConfig(sliding_window=0)
    with pytest.raises(ValueError):
        TelemetryConfig(max_spans=0)


# -- (c) audit fidelity --------------------------------------------------------

def test_audit_agrees_with_decision_records():
    res = simulate(synth_trace(MIXED), "conduit", telemetry=FULL)
    rec = res.telemetry
    assert len(rec.audit) == len(res.decisions) == res.n_instrs
    for a, d in zip(rec.audit, res.decisions):
        assert a.iid == d.iid
        assert a.op == d.op
        assert a.chosen == d.resource.value
        assert a.t_decide_ns == d.t_decide
        assert a.replayed == d.replayed


def test_audit_candidate_totals_rederive_from_features():
    """Eqn 1: total = comp + dm + max(dd, queue) for every candidate the
    policy considered; the chosen resource is one of the candidates."""
    res = simulate(synth_trace(MIXED), "conduit", telemetry=FULL)
    checked = 0
    for a in res.telemetry.audit:
        names = {c.resource for c in a.candidates}
        assert a.chosen in names
        for c in a.candidates:
            if c.supported:
                want = c.latency_comp_ns + c.latency_dm_ns \
                    + max(c.delay_dd_ns, c.delay_queue_ns)
                assert c.total_ns == pytest.approx(want)
                checked += 1
    assert checked > 0


def test_audit_explain_renders_the_decision():
    res = simulate(synth_trace(MIXED), "conduit", telemetry=FULL)
    a = res.telemetry.audit[0]
    text = a.explain()
    assert f"iid={a.iid}" in text
    assert "->" in text                 # the chosen row is marked
    assert f"chosen: {a.chosen}" in text
    for c in a.candidates:
        assert c.resource in text


def test_audit_off_still_fills_breakdown():
    cfg = TelemetryConfig(spans=True, audit=False)
    res = simulate(synth_trace(MIXED), "conduit", telemetry=cfg)
    rec = res.telemetry
    assert rec.audit == []
    assert sum(r["count"] for r in rec.breakdown_rows()) == res.n_instrs


# -- (d) breakdown accounting --------------------------------------------------

def test_breakdown_counts_sum_to_instruction_count():
    res = simulate(synth_trace(MIXED), "conduit", telemetry=FULL)
    rows = res.telemetry.breakdown_rows()
    assert sum(r["count"] for r in rows) == res.n_instrs
    for r in rows:
        for field in ("decide_ns", "dm_ns", "queue_ns", "compute_ns",
                      "total_ns"):
            assert r[field] >= -1e-9, (r["op"], r["resource"], field)
        # each phase is a slice of dispatch->completion, never more
        assert r["total_ns"] + 1e-9 >= max(r["dm_ns"], r["queue_ns"],
                                           r["compute_ns"])


# -- spans, sampler, GC overlap ------------------------------------------------

def test_engine_event_counts_cover_the_run(gc_recorder):
    counts = gc_recorder.event_counts
    assert counts.get("dispatch", 0) > 0
    assert counts.get("io_arrival", 0) > 0
    assert counts.get("gc", 0) > 0
    assert counts.get("timer", 0) > 0       # the sampler's own events


def test_interval_samples_are_monotone_and_sane(gc_recorder):
    samples = gc_recorder.intervals
    assert len(samples) >= 2
    times = [s.t_ns for s in samples]
    assert times == sorted(times)
    for s in samples:
        assert s.gc_active_dies >= 0
        assert s.p99_op_ns >= 0.0
        for pool, u in s.utilization.items():
            assert u >= 0.0, pool
        for pool, q in s.queue_depth_ns.items():
            assert q >= 0.0, pool


def test_gc_spans_overlap_host_io_spans(gc_trace):
    """The headline observability claim: the exported trace shows GC
    activity on a die concurrent with in-flight host requests."""
    gc_spans = [(e["ts"], e["ts"] + e["dur"])
                for e in gc_trace["traceEvents"]
                if e.get("ph") == "X" and e.get("pid") == PID_FTL]
    assert gc_spans, "no GC spans in a GC-enabled run"
    opens = {}
    io_spans = []
    for e in gc_trace["traceEvents"]:
        if e.get("cat") != "host_io":
            continue
        if e["ph"] == "b":
            opens[e["id"]] = e["ts"]
        elif e["ph"] == "e":
            io_spans.append((opens.pop(e["id"]), e["ts"]))
    assert io_spans, "no host-I/O spans in a host-I/O run"
    assert any(g0 < i1 and i0 < g1
               for g0, g1 in gc_spans for i0, i1 in io_spans), \
        "no GC span overlaps any host-I/O request"


def test_fabric_spans_carry_attribution(gc_trace):
    names = {e["name"] for e in gc_trace["traceEvents"]
             if e.get("ph") == "X" and e.get("pid") == PID_FABRIC}
    assert any(n.startswith("gc:die") for n in names)
    assert any(n.startswith("io#") for n in names)
    assert any("#" in n and ":" in n and not n.startswith(("gc", "io"))
               for n in names), "no tenant dispatch spans"
    assert "?" not in names, "unattributed pool booking"


def test_span_cap_truncates_loudly():
    cfg = TelemetryConfig(spans=True, audit=True, max_spans=10,
                          max_audit=5)
    res = simulate(synth_trace(MIXED), "conduit", telemetry=cfg)
    rec = res.telemetry
    assert len(rec.spans) == 10 and rec.dropped_spans > 0
    assert len(rec.audit) == 5 and rec.dropped_audit > 0
    other = rec.chrome_trace()["otherData"]
    assert other["dropped_spans"] == rec.dropped_spans
    assert other["dropped_audit"] == rec.dropped_audit


# -- (e) export round trip + CLI -----------------------------------------------

def test_exported_trace_validates_and_summarizes(gc_trace):
    assert validate_trace(gc_trace) == []
    s = summarize(gc_trace)
    assert s["schema"] == SCHEMA
    assert s["n_events"] == len(gc_trace["traceEvents"])
    assert s["spans_by_process"].get("ftl-gc", 0) > 0
    assert s["spans_by_process"].get("fabric", 0) > 0
    assert s["n_audit"] == len(gc_trace["otherData"]["audit"])
    assert s["n_intervals"] > 0


def test_export_json_round_trips(gc_recorder, tmp_path):
    path = tmp_path / "trace.json"
    obj = gc_recorder.export(str(path))
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(obj))
    assert validate_trace(loaded) == []


@pytest.mark.parametrize("corrupt, expect", [
    (lambda t: t["otherData"].pop("schema"), "schema"),
    (lambda t: t["traceEvents"].append({"ph": "Q", "ts": 0, "pid": 1}),
     "illegal ph"),
    (lambda t: t["traceEvents"].append(
        {"ph": "b", "cat": "session", "id": 999_999, "pid": 3, "tid": 0,
         "name": "x", "ts": 0}), "unmatched begin"),
    (lambda t: t["traceEvents"].append(
        {"ph": "e", "cat": "session", "id": 888_888, "pid": 3, "tid": 0,
         "name": "x", "ts": 0}), "unmatched end"),
    (lambda t: t["traceEvents"].append(
        {"ph": "X", "pid": 1, "tid": 1, "name": "x", "ts": 0.0,
         "dur": -1.0}), "bad dur"),
    (lambda t: t.__setitem__("traceEvents", {}), "traceEvents"),
    # a counter track running backwards in time
    (lambda t: t["traceEvents"].extend(
        [{"ph": "C", "pid": 5, "tid": 0, "name": "zz", "ts": 2.0,
          "args": {"x": 1}},
         {"ph": "C", "pid": 5, "tid": 0, "name": "zz", "ts": 1.0,
          "args": {"x": 1}}]), "non-monotonic counter"),
    # a counter sample going negative (busy deltas/queue depths cannot)
    (lambda t: t["traceEvents"].append(
        {"ph": "C", "pid": 5, "tid": 0, "name": "drive", "ts": 1e12,
         "args": {"backlog": -3}}), "negative counter"),
    # the reliability process only carries recovery/retire spans ...
    (lambda t: t["traceEvents"].append(
        {"ph": "X", "pid": 6, "tid": 1, "name": "bogus-span", "ts": 0.0,
         "dur": 1.0}), "unknown reliability span"),
    # ... and die-failure / read-only instants
    (lambda t: t["traceEvents"].append(
        {"ph": "i", "pid": 6, "tid": 1, "name": "weird", "ts": 0.0,
         "s": "t"}), "unknown reliability instant"),
    # the per-dispatch ops stream: list of records with the join keys
    (lambda t: t["otherData"].__setitem__("ops", 5), "must be a list"),
    (lambda t: t["otherData"]["ops"].append({"nope": 1}), "ops #"),
])
def test_corrupt_traces_are_rejected(gc_recorder, corrupt, expect):
    """The round-trip law: whatever validate rejects, summarize raises."""
    trace = json.loads(json.dumps(gc_recorder.chrome_trace()))
    corrupt(trace)
    errors = validate_trace(trace)
    assert errors and any(expect in e for e in errors), errors
    with pytest.raises(ValueError, match="invalid trace"):
        summarize(trace)


def test_cli_summarize_and_validate(gc_recorder, tmp_path):
    path = tmp_path / "trace.json"
    gc_recorder.export(str(path))

    buf = io.StringIO()
    assert telemetry_main(["validate", str(path)], out=buf) == 0
    assert "OK" in buf.getvalue()

    buf = io.StringIO()
    assert telemetry_main(["summarize", str(path)], out=buf) == 0
    assert json.loads(buf.getvalue())["schema"] == SCHEMA


def test_cli_exit_codes_on_bad_input(gc_recorder, tmp_path):
    buf = io.StringIO()
    assert telemetry_main(["validate", str(tmp_path / "missing.json")],
                          out=buf) == 2

    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    assert telemetry_main(["validate", str(garbage)], out=io.StringIO()) == 2

    bad = json.loads(json.dumps(gc_recorder.chrome_trace()))
    del bad["otherData"]["schema"]
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(bad))
    buf = io.StringIO()
    assert telemetry_main(["validate", str(p)], out=buf) == 1
    assert "INVALID" in buf.getvalue()
    assert telemetry_main(["summarize", str(p)], out=io.StringIO()) == 1


# -- serving: lifecycle spans + (f) the Little's-law warning -------------------

def test_serving_trace_validates_with_rejections():
    """Rejected sessions still close their async spans (b/e balance)."""
    res = simulate_serving(
        small_catalog(),
        PoissonArrivals(rate_per_sec=50_000, n_sessions=40, seed=3),
        "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=2,
                              little_law_warn_tol=float("inf")),
        telemetry=FULL)
    assert res.n_rejected > 0
    rec = res.telemetry
    trace = rec.chrome_trace()
    assert validate_trace(trace) == []
    rejects = [e for e in trace["traceEvents"]
               if e.get("ph") == "i" and e["name"].startswith("reject")]
    assert len(rejects) == res.n_rejected
    assert rec.event_counts.get("session_arrival", 0) == res.n_offered
    assert any(s.backlog > 0 or s.active_sessions > 0
               for s in rec.intervals)


def test_little_law_quiet_on_a_stable_trimmed_run():
    catalog = SessionCatalog(
        [CatalogEntry("A", synth_trace(RAMP, name="A"), weight=3.0),
         CatalogEntry("B", synth_trace(MIXED, name="B"), weight=1.0)],
        seed=11)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        res = simulate_serving(
            catalog,
            PoissonArrivals(rate_per_sec=2000, n_sessions=64, seed=9),
            "conduit",
            serving=ServingConfig(warmup_ns=3e6, cooldown_ns=3e6))
    assert abs(res.little_law_ratio() - 1.0) \
        <= ServingConfig().little_law_warn_tol


def test_little_law_warns_on_an_edge_dominated_window():
    with pytest.warns(RuntimeWarning, match="little_law_ratio"):
        res = simulate_serving(
            small_catalog(),
            PoissonArrivals(rate_per_sec=50_000, n_sessions=40, seed=3),
            "conduit")
    assert abs(res.little_law_ratio() - 1.0) \
        > ServingConfig().little_law_warn_tol


# -- ops stream + run meta (the analysis layer's raw material) -----------------

def test_ops_stream_carries_ordered_phase_boundaries(gc_trace):
    ops = gc_trace["otherData"]["ops"]
    assert len(ops) > 0
    for o in ops:
        assert o["t_decide_ns"] <= o["decide_end_ns"] <= o["ready_ns"] \
            <= o["move_end_ns"] <= o["start_ns"] <= o["end_ns"], o
        assert isinstance(o["deps"], list)
    # joinable against the fabric spans: structured args on bookings
    args = [e.get("args") for e in gc_trace["traceEvents"]
            if e.get("ph") == "X" and e.get("pid") == PID_FABRIC]
    assert any(a and "iid" in a for a in args), \
        "no structured dispatch attribution on fabric spans"
    assert any(a and "gc_die" in a for a in args), \
        "no structured GC attribution on fabric spans"


def test_ops_cap_truncates_loudly():
    cfg = TelemetryConfig(spans=True, audit=False, max_spans=10)
    res = simulate(synth_trace(MIXED), "conduit", telemetry=cfg)
    rec = res.telemetry
    assert len(rec.ops) == 10 and rec.dropped_ops > 0
    assert rec.chrome_trace()["otherData"]["dropped_ops"] == rec.dropped_ops


def test_run_meta_fingerprints_the_run(gc_trace):
    meta = gc_trace["otherData"]["meta"]
    assert meta["entry"] == "simulate_mix"
    assert meta["policy"] == "conduit"
    assert len(meta["spec_sha"]) == 16
    assert meta["telemetry"]["spans"] is True


def test_op_timeout_retry_trace_keeps_io_spans_balanced():
    """Every timed-out attempt closes its async span before the retry
    opens a fresh one for the same request id — the exported trace from
    an op-timeout run stays b/e balanced and validate-clean."""
    from repro.sim import FaultConfig, simulate_mix as smix
    io = HostIOStream(rate_iops=10_000, read_fraction=1.0, n_requests=1,
                      seed=11)
    m = smix([synth_trace([], outputs=False)], "conduit", io_stream=io,
             compute_solo=False, telemetry=FULL,
             faults=FaultConfig(op_timeout_ns=1.0, max_op_retries=2,
                                op_retry_backoff_ns=10_000.0))
    assert m.faults.n_op_retries == 2          # the recipe really retried
    trace = m.telemetry.chrome_trace()
    assert validate_trace(trace) == []
    timeouts = [e for e in trace["traceEvents"] if e.get("ph") == "i"
                and e.get("name", "").startswith("io-timeout")]
    assert len(timeouts) == 2                  # one instant per re-issue


def test_little_law_tolerance_is_configurable():
    with pytest.warns(RuntimeWarning, match="little_law_ratio"):
        simulate_serving(
            small_catalog(),
            PoissonArrivals(rate_per_sec=2000, n_sessions=24, seed=9),
            "conduit",
            serving=ServingConfig(warmup_ns=3e6, cooldown_ns=3e6,
                                  little_law_warn_tol=1e-9))
    with pytest.raises(ValueError, match="little_law_warn_tol"):
        ServingConfig(little_law_warn_tol=0.0)
