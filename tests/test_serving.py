"""Open-loop serving laws (:mod:`repro.sim.serving`).

The subsystem's acceptance properties:

(a) equivalence — one session, no churn, no admission pressure reproduces
    ``simulate_mix([trace])`` bit-for-bit (serving strictly generalizes
    the batch entry points);
(b) determinism — identical inputs replay identical serving runs;
(c) conservation — offered == completed + rejected + in-flight, with
    in-flight == 0 after a drained run, under any admission pressure;
(d) steady state — Little's law holds within tolerance on a stable run,
    and warm-up/cool-down trimming excludes edge sessions;
(e) saturation — the bisection is deterministic, brackets its answer,
    and is monotone in the SLO.

Plus the ``record_decisions=False`` fast mode: identical timing, no
DecisionRecord allocation, per-op latencies still available.
"""
import dataclasses
import math

import pytest

from repro.sim import (CatalogEntry, EventEngine, EventKind, FTLConfig,
                       HostIOStream, MMPPArrivals, PoissonArrivals,
                       ServingConfig, SessionCatalog, SimConfig,
                       TraceReplayArrivals, find_saturation, simulate,
                       simulate_mix, simulate_serving)

from _synth import synth_trace

# Most fixtures here run tiny, untrimmed or deliberately-overloaded
# windows where the Little's-law ratio is meaningless by construction;
# the warning itself is pinned (quiet + loud) in test_telemetry.py.
pytestmark = pytest.mark.filterwarnings("ignore:little_law_ratio")

RAMP = list(range(40))
SHORT = [2, 4, 6] * 3


def one_trace_catalog(name="A", ops=RAMP):
    return SessionCatalog([CatalogEntry(name, synth_trace(ops, name=name))])


def two_kind_catalog():
    return SessionCatalog(
        [CatalogEntry("A", synth_trace(RAMP, name="A"), weight=3.0),
         CatalogEntry("B", synth_trace(SHORT, name="B"), weight=1.0)],
        seed=5)


# -- (a) equivalence -----------------------------------------------------------

def test_single_session_reproduces_simulate_mix_exactly():
    """The acceptance law: a no-churn ServingConfig run == simulate_mix."""
    tr = synth_trace(RAMP, name="A")
    ser = simulate_serving(SessionCatalog([CatalogEntry("A", tr)]),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit")
    mix = simulate_mix([tr], "conduit", compute_solo=False)
    got, want = ser.session_results[0], mix.tenants[0]
    assert got.makespan_ns == want.makespan_ns            # bit-exact
    assert got.total_energy_nj == want.total_energy_nj
    assert got.resource_counts == want.resource_counts
    assert got.coherence_syncs == want.coherence_syncs
    assert ser.makespan_ns == mix.makespan_ns
    assert ser.n_completed == 1 and ser.n_rejected == 0


def test_session_arrival_events_on_the_timeline():
    eng = EventEngine(record=True)
    simulate_serving(one_trace_catalog(),
                     PoissonArrivals(rate_per_sec=4000, n_sessions=8, seed=2),
                     "conduit", engine=eng)
    kinds = {k for _, k in eng.log}
    assert EventKind.SESSION_ARRIVAL in kinds
    assert EventKind.DISPATCH in kinds
    times = [t for t, _ in eng.log]
    assert all(b >= a for a, b in zip(times, times[1:]))


# -- (b) determinism -----------------------------------------------------------

def test_same_inputs_replay_identically():
    mk = lambda: simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=6000, n_sessions=24, seed=9),
        "conduit", serving=ServingConfig(max_active_sessions=4))
    r1, r2 = mk(), mk()
    assert r1.makespan_ns == r2.makespan_ns
    assert r1.session_latencies_ns == r2.session_latencies_ns
    assert [s.done_ns for s in r1.sessions] == [s.done_ns for s in r2.sessions]
    assert r1.utilization == r2.utilization


def test_arrival_seed_changes_the_run():
    mk = lambda seed: simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=6000, n_sessions=24, seed=seed),
        "conduit")
    assert mk(1).makespan_ns != mk(2).makespan_ns


# -- (c) conservation ----------------------------------------------------------

def test_session_conservation_under_admission_pressure():
    """offered == completed + rejected (+ inflight == 0 after drain), with
    a tiny admission cap and backlog forcing real rejections."""
    res = simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=50_000, n_sessions=40, seed=9),
        "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=2))
    assert res.n_rejected > 0
    assert res.n_inflight == 0
    assert res.n_offered == res.n_completed + res.n_rejected == 40
    assert res.n_admitted == res.n_completed
    rejected = [s for s in res.sessions if s.rejected]
    assert len(rejected) == res.n_rejected
    assert all(not s.completed for s in rejected)
    # admitted work all ran: one result per completed session
    assert len(res.session_results) == res.n_completed


def test_zero_backlog_rejects_everything_beyond_active_cap():
    res = simulate_serving(
        one_trace_catalog(ops=SHORT),
        TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0, 3.0)), "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=0))
    # sessions 1-3 arrive while session 0 still runs and bounce
    assert res.n_completed == 1
    assert res.n_rejected == 3


def test_backlog_defers_but_never_drops():
    """With a roomy backlog the same burst completes in full, FIFO."""
    res = simulate_serving(
        one_trace_catalog(ops=SHORT),
        TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0, 3.0)), "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=8))
    assert res.n_completed == 4 and res.n_rejected == 0
    admits = [s.admit_ns for s in res.sessions]
    assert admits == sorted(admits)                    # FIFO admission
    assert all(s.queue_wait_ns >= 0.0 for s in res.sessions)
    # serialized: each session admitted no earlier than its predecessor
    # completed its last event (epilogue frees the slot)
    for prev, nxt in zip(res.sessions, res.sessions[1:]):
        assert nxt.admit_ns >= prev.admit_ns


def test_queueing_under_cap_inflates_latency():
    arr = PoissonArrivals(rate_per_sec=20_000, n_sessions=24, seed=9)
    wide = simulate_serving(two_kind_catalog(), arr, "conduit",
                            serving=ServingConfig(max_active_sessions=16,
                                                  max_backlog=64))
    narrow = simulate_serving(two_kind_catalog(), arr, "conduit",
                              serving=ServingConfig(max_active_sessions=1,
                                                    max_backlog=64))
    assert narrow.p(50) > wide.p(50)
    assert narrow.mean_in_system > wide.mean_in_system


# -- (d) steady state ----------------------------------------------------------

def test_littles_law_on_a_stable_run():
    """L ≈ λ·W over the measured window at moderate, sustainable load."""
    res = simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=2000, n_sessions=64, seed=9),
        "conduit",
        serving=ServingConfig(warmup_ns=3e6, cooldown_ns=3e6))
    assert res.n_rejected == 0
    ratio = res.little_law_ratio()
    assert 0.7 < ratio < 1.3, f"Little's law violated: L/(lambda W)={ratio:.3f}"
    assert res.mean_in_system > 0.0


def test_warmup_cooldown_trim_excludes_edge_sessions():
    arr = DeterministicArrivals = PoissonArrivals(rate_per_sec=4000,
                                                  n_sessions=32, seed=9)
    trimmed = simulate_serving(
        two_kind_catalog(), arr, "conduit",
        serving=ServingConfig(warmup_ns=2e6, cooldown_ns=2e6))
    full = simulate_serving(two_kind_catalog(), arr, "conduit")
    n_meas = len(trimmed.measured_sessions)
    assert 0 < n_meas < trimmed.n_offered
    assert len(full.measured_sessions) == full.n_completed
    lo, hi = trimmed.window_ns
    for s in trimmed.sessions:
        assert s.measured == (lo <= s.arrival_ns <= hi)
    # the timing itself is untouched by where the window sits
    assert trimmed.makespan_ns == full.makespan_ns


def test_utilization_grows_with_offered_load():
    mk = lambda rate: simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=rate, n_sessions=32, seed=9),
        "conduit", serving=ServingConfig(warmup_ns=1e5, cooldown_ns=1e5))
    quiet, loud = mk(1000), mk(12_000)
    assert set(quiet.utilization) == set(loud.utilization)
    assert all(v >= 0.0 for v in quiet.utilization.values())
    assert max(loud.utilization.values()) > max(quiet.utilization.values())


def test_host_io_stream_contends_with_sessions():
    arr = PoissonArrivals(rate_per_sec=4000, n_sessions=16, seed=9)
    io = HostIOStream(rate_iops=100_000, n_requests=64)
    with_io = simulate_serving(two_kind_catalog(), arr, "conduit",
                               io_stream=io)
    without = simulate_serving(two_kind_catalog(), arr, "conduit")
    assert with_io.host_io is not None and without.host_io is None
    assert with_io.host_io.n_requests == 64
    # host traffic can only slow sessions down (FIFO pools, superset load)
    for a, b in zip(without.session_latencies_ns,
                    with_io.session_latencies_ns):
        assert b >= a - 1e-6


def test_mmpp_burst_traffic_serves():
    res = simulate_serving(
        two_kind_catalog(),
        MMPPArrivals(rate_on_per_sec=16_000, mean_on_ns=2e6, mean_off_ns=2e6,
                     n_sessions=24, seed=4),
        "conduit")
    assert res.n_offered == 24
    assert res.n_inflight == 0


# -- record_decisions fast mode ------------------------------------------------

def test_record_decisions_off_is_bit_identical_and_lighter():
    tr = synth_trace(RAMP, name="A")
    full = simulate(tr, "conduit")
    fast = simulate(synth_trace(RAMP, name="A"), "conduit",
                    record_decisions=False)
    assert fast.makespan_ns == full.makespan_ns
    assert fast.total_energy_nj == full.total_energy_nj
    assert fast.decisions == []
    assert len(full.decisions) == len(RAMP)
    # per-op latencies survive the fast mode, and match the records
    assert fast.latencies_ns == full.latencies_ns
    assert fast.p(99) == full.p(99)


def test_record_decisions_off_in_mix():
    mk = lambda: [synth_trace(RAMP, name="A"), synth_trace(SHORT, name="B")]
    full = simulate_mix(mk(), "conduit", compute_solo=False)
    fast = simulate_mix(mk(), "conduit", compute_solo=False,
                        record_decisions=False)
    assert fast.makespan_ns == full.makespan_ns
    for f, g in zip(fast.tenants, full.tenants):
        assert f.decisions == []
        assert f.latencies_ns == g.latencies_ns


def test_serving_defaults_to_fast_mode():
    res = simulate_serving(one_trace_catalog(),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit")
    r = res.session_results[0]
    assert r.decisions == []
    assert len(r.latencies_ns) == len(RAMP)
    assert res.op_latencies_ns       # aggregated for measured sessions


def test_serving_fast_mode_survives_an_explicit_sim_config():
    """ServingConfig.record_decisions governs even when a SimConfig is
    passed (e.g. to tune capacities) — serving must not silently fall
    back to unbounded per-dispatch DecisionRecord logging."""
    res = simulate_serving(one_trace_catalog(),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit",
                           config=SimConfig(pud_units=8))
    assert res.session_results[0].decisions == []
    full = simulate_serving(one_trace_catalog(),
                            TraceReplayArrivals(times_ns=(0.0,)), "conduit",
                            serving=ServingConfig(record_decisions=True))
    assert len(full.session_results[0].decisions) == len(RAMP)


# -- (e) saturation finder -----------------------------------------------------

SAT_KW = dict(slo_p99_ns=1.5e6, rate_lo=1000, rate_hi=24_000, iters=4,
              n_sessions=32, seed=9,
              serving=ServingConfig(keep_session_results=False,
                                    warmup_ns=1e5, cooldown_ns=1e5))


def test_saturation_brackets_and_is_deterministic():
    cat = two_kind_catalog()
    sat = find_saturation(cat, "conduit", **SAT_KW)
    again = find_saturation(cat, "conduit", **SAT_KW)
    assert sat.rate_per_sec == again.rate_per_sec
    assert [p.rate_per_sec for p in sat.probes] == \
        [p.rate_per_sec for p in again.probes]
    lo, hi = sat.bracket
    assert sat.rate_per_sec == lo <= hi
    assert 1000 <= lo and hi <= 24_000
    assert len(sat.probes) <= 2 + SAT_KW["iters"]
    # the bracket is genuinely decided: lo sustained, hi (if distinct) not
    by_rate = {p.rate_per_sec: p for p in sat.probes}
    assert by_rate[lo].sustainable
    if hi != lo:
        assert not by_rate[hi].sustainable


def test_saturation_monotone_in_slo():
    """A tighter SLO can only lower the sustainable rate."""
    cat = two_kind_catalog()
    loose = find_saturation(cat, "conduit", **SAT_KW)
    tight = find_saturation(cat, "conduit",
                            **{**SAT_KW, "slo_p99_ns": 0.8e6})
    assert tight.rate_per_sec <= loose.rate_per_sec


def test_saturation_validation():
    cat = two_kind_catalog()
    with pytest.raises(ValueError):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=0,
                        rate_hi=100)
    with pytest.raises(ValueError):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=100,
                        rate_hi=100)
    with pytest.raises(ValueError):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=100,
                        rate_hi=200, iters=0)
    # warmup/cooldown that swallow the arrival span fail loudly at the
    # simulate_serving entry point instead of making every rate look
    # sustainable
    with pytest.raises(ValueError, match="empty measurement window"):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=1000,
                        rate_hi=2000, n_sessions=8,
                        serving=ServingConfig(warmup_ns=1e12,
                                              cooldown_ns=1e12))


def test_saturation_treats_all_rejected_probe_as_unsustainable():
    """A probe where admission pressure rejects the in-window arrivals is
    unsustainable by the rejections alone — it must not crash on the
    empty latency list."""
    cat = two_kind_catalog()
    sat = find_saturation(
        cat, "conduit", slo_p99_ns=1e9, rate_lo=100, rate_hi=1_000_000,
        iters=2, n_sessions=16,
        serving=ServingConfig(max_active_sessions=1, max_backlog=0,
                              warmup_ns=3e4, cooldown_ns=0.0,
                              keep_session_results=False))
    assert any(p.n_rejected > 0 and not p.sustainable for p in sat.probes)
    assert sat.rate_per_sec < 1_000_000


# -- satellite bugfixes --------------------------------------------------------

def test_latency_ns_raises_on_incomplete_records():
    """A rejected / never-completed session has no latency: reading it
    must raise instead of returning a negative number that would poison
    percentile assembly."""
    res = simulate_serving(
        one_trace_catalog(ops=SHORT),
        TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0, 3.0)), "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=0))
    rejected = [s for s in res.sessions if s.rejected]
    assert rejected
    for s in rejected:
        assert not s.completed
        with pytest.raises(ValueError, match="never completed"):
            s.latency_ns
        with pytest.raises(ValueError, match="never admitted"):
            s.queue_wait_ns
    # percentile assembly filters on .completed, so it still works
    assert res.p(99) >= 0.0
    assert len(res.session_latencies_ns) == res.n_completed


def test_all_bounced_probe_records_nan_p99_and_is_unsustainable():
    """A probe where every in-window arrival bounced has no measured
    latency at all: the rejected branch must not crash on the empty list
    (ServingResult.p returns 0.0 there — recording that would fake a
    perfect tail), and it records NaN instead."""
    cat = two_kind_catalog()
    # cap 1 + zero backlog + warmup past session 0's arrival: session 0
    # (pre-window) occupies the only slot, every in-window arrival bounces
    sat = find_saturation(
        cat, "conduit", slo_p99_ns=1e9, rate_lo=50_000_000,
        rate_hi=100_000_000, iters=1, n_sessions=8,
        serving=ServingConfig(max_active_sessions=1, max_backlog=0,
                              warmup_ns=10.0, cooldown_ns=0.0,
                              keep_session_results=False))
    assert sat.rate_per_sec == 0.0
    bounced = [p for p in sat.probes if p.n_rejected > 0]
    assert bounced
    assert any(math.isnan(p.p99_ns) for p in bounced)
    assert all(not p.sustainable for p in bounced)


class _CountingCatalog(SessionCatalog):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.draws = 0

    def draw(self, sid):
        self.draws += 1
        return super().draw(sid)


def test_catalog_drawn_exactly_once_per_session():
    """The driver draws each session's kind once and reuses the entry at
    admission — the record's kind always names the executed trace."""
    cat = _CountingCatalog(
        [CatalogEntry("kindA", synth_trace(RAMP, name="traceA"), weight=3.0),
         CatalogEntry("kindB", synth_trace(SHORT, name="traceB"))], seed=5)
    res = simulate_serving(
        cat, PoissonArrivals(rate_per_sec=6000, n_sessions=12, seed=9),
        "conduit")
    assert cat.draws == 12                   # one draw per offered session
    # record kind == executed kind: the session result's workload is the
    # trace of the drawn entry, entry names map 1:1 onto trace names
    trace_of = {"kindA": "traceA", "kindB": "traceB"}
    by_sid = {r.tenant: r for r in res.session_results}
    for s in res.sessions:
        r = by_sid[f"s{s.sid}:{s.kind}"]
        assert r.workload == trace_of[s.kind]


# -- steady-state window edges -------------------------------------------------

def test_window_measurement_is_inclusive_at_both_edges():
    """Arrivals exactly at lo and exactly at hi are measured."""
    res = simulate_serving(
        one_trace_catalog(ops=SHORT),
        TraceReplayArrivals(times_ns=(0.0, 1e6, 2e6)), "conduit",
        serving=ServingConfig(warmup_ns=1e6, cooldown_ns=0.0))
    assert res.window_ns == (1e6, 2e6)
    assert [s.measured for s in res.sessions] == [False, True, True]
    lo, hi = res.window_ns
    for s in res.sessions:
        assert s.measured == (lo <= s.arrival_ns <= hi)


def test_busy_snapshot_precedes_same_time_arrival():
    """The closing utilization snapshot is scheduled before the arrivals,
    so a session arriving exactly at the window edge books its work after
    the snapshot — its load never leaks into the measured interval."""
    eng = EventEngine(record=True)
    res = simulate_serving(
        one_trace_catalog(ops=SHORT),
        TraceReplayArrivals(times_ns=(0.0, 1e6, 2e6)), "conduit",
        serving=ServingConfig(warmup_ns=1e6, cooldown_ns=0.0), engine=eng)
    hi = res.window_ns[1]
    at_hi = [k for t, k in eng.log if t == hi
             and k in (EventKind.TIMER, EventKind.SESSION_ARRIVAL)]
    assert EventKind.TIMER in at_hi and EventKind.SESSION_ARRIVAL in at_hi
    assert at_hi.index(EventKind.TIMER) \
        < at_hi.index(EventKind.SESSION_ARRIVAL)


def test_zero_length_window_is_rejected_at_entry():
    """Pinning test: warmup past the arrival span used to collapse the
    window to a point and silently return all-zero steady-state metrics
    (rates, percentiles, occupancy, utilization) that a sweep would
    happily compare.  simulate_serving now rejects the configuration
    loudly at the entry point."""
    with pytest.raises(ValueError, match="empty measurement window"):
        simulate_serving(
            one_trace_catalog(ops=SHORT),
            TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0)), "conduit",
            serving=ServingConfig(warmup_ns=1e9))
    # cooldown alone swallowing the span is rejected the same way
    with pytest.raises(ValueError, match="empty measurement window"):
        simulate_serving(
            one_trace_catalog(ops=SHORT),
            TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0)), "conduit",
            serving=ServingConfig(cooldown_ns=5.0))
    # zero trim stays legal even with a degenerate (single-point) span:
    # that is the batch-equivalence configuration
    res = simulate_serving(one_trace_catalog(ops=SHORT),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit")
    assert res.n_completed == 1


# -- FTL / GC under serving ----------------------------------------------------

GC_FTL = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                   prefill=0.9)


def serving_io(n_requests=256, iops=25_000):
    return HostIOStream(rate_iops=iops, read_fraction=0.5,
                        n_requests=n_requests, zipf_theta=0.95,
                        n_logical_pages=GC_FTL.logical_pages())


def test_serving_without_ftl_is_unchanged_by_the_ftl_plumbing():
    """ftl=None must leave the serving path bit-identical (the law the
    golden serving numbers below also pin): explicit None == omitted."""
    arr = PoissonArrivals(rate_per_sec=6000, n_sessions=12, seed=9)
    a = simulate_serving(two_kind_catalog(), arr, "conduit")
    b = simulate_serving(two_kind_catalog(), arr, "conduit", ftl=None)
    assert a.makespan_ns == b.makespan_ns
    assert a.session_latencies_ns == b.session_latencies_ns
    assert a.ftl is None and b.ftl is None


def test_serving_with_ftl_runs_gc_and_reports_stats():
    arr = PoissonArrivals(rate_per_sec=6000, n_sessions=24, seed=9)
    res = simulate_serving(two_kind_catalog(), arr, "conduit",
                           io_stream=serving_io(), ftl=GC_FTL)
    assert res.ftl is not None
    assert res.ftl.gc_invocations > 0
    assert res.ftl.write_amplification > 1.0
    assert res.n_inflight == 0               # conservation still holds
    assert "write_amp" in res.summary()


def test_serving_ftl_gc_disabled_is_bit_identical_to_no_ftl():
    """The batch equivalence law lifts to serving: gc_enabled=False is
    the idealized drive, indistinguishable from running without an FTL."""
    arr = PoissonArrivals(rate_per_sec=6000, n_sessions=16, seed=9)
    io = serving_io(n_requests=128)
    base = simulate_serving(two_kind_catalog(), arr, "conduit", io_stream=io)
    off = simulate_serving(two_kind_catalog(), arr, "conduit", io_stream=io,
                           ftl=dataclasses.replace(GC_FTL, gc_enabled=False))
    assert off.makespan_ns == base.makespan_ns
    assert off.session_latencies_ns == base.session_latencies_ns
    assert off.host_io.latencies_ns == base.host_io.latencies_ns
    assert off.ftl is not None and off.ftl.write_amplification == 1.0


def test_serving_with_ftl_is_deterministic():
    mk = lambda: simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=6000, n_sessions=16, seed=9),
        "conduit", io_stream=serving_io(n_requests=128), ftl=GC_FTL)
    a, b = mk(), mk()
    assert a.makespan_ns == b.makespan_ns
    assert a.session_latencies_ns == b.session_latencies_ns
    assert a.ftl.erase_counts == b.ftl.erase_counts


def test_gc_inflates_serving_session_tail():
    """GC page copies and erases on the shared die/channel pools make
    session p99 strictly worse than the same run on an idealized drive."""
    arr = PoissonArrivals(rate_per_sec=6000, n_sessions=24, seed=9)
    io = serving_io()
    off = simulate_serving(two_kind_catalog(), arr, "conduit", io_stream=io,
                           ftl=dataclasses.replace(GC_FTL, gc_enabled=False))
    on = simulate_serving(two_kind_catalog(), arr, "conduit", io_stream=io,
                          ftl=GC_FTL)
    assert on.ftl.gc_invocations > 0
    assert on.p(99) > off.p(99)


def test_saturation_with_ftl_is_lower_and_finite():
    """The acceptance law: a drive that is actively collecting sustains
    measurably fewer sessions/sec than the idealized drive — and with the
    suspend collector the FTL point is finite (the monolithic collector's
    victim cycles blow the SLO outright)."""
    cat = two_kind_catalog()
    io = serving_io()
    susp = dataclasses.replace(GC_FTL, gc_suspend=True, gc_reserve_blocks=1)
    kw = dict(slo_p99_ns=6.5e6, rate_lo=2000, rate_hi=24_000, iters=3,
              n_sessions=48, seed=9, io_stream=io,
              serving=ServingConfig(keep_session_results=False,
                                    warmup_ns=1e5, cooldown_ns=1e5))
    ideal = find_saturation(cat, "conduit", **kw)
    collecting = find_saturation(cat, "conduit", ftl=susp, **kw)
    assert ideal.rate_per_sec == 24_000      # idealized drive: SLO met at hi
    assert 0.0 < collecting.rate_per_sec < ideal.rate_per_sec
    assert math.isfinite(collecting.rate_per_sec)


def test_saturation_with_ftl_is_deterministic():
    cat = two_kind_catalog()
    kw = dict(slo_p99_ns=6.5e6, rate_lo=2000, rate_hi=24_000, iters=2,
              n_sessions=24, seed=9, io_stream=serving_io(n_requests=128),
              ftl=GC_FTL,
              serving=ServingConfig(keep_session_results=False,
                                    warmup_ns=1e5, cooldown_ns=1e5))
    a = find_saturation(cat, "conduit", **kw)
    b = find_saturation(cat, "conduit", **kw)
    assert a.rate_per_sec == b.rate_per_sec
    assert [p.rate_per_sec for p in a.probes] == \
        [p.rate_per_sec for p in b.probes]


# -- config validation ---------------------------------------------------------

def test_serving_config_validation():
    with pytest.raises(ValueError):
        ServingConfig(max_active_sessions=0)
    with pytest.raises(ValueError):
        ServingConfig(max_backlog=-1)
    with pytest.raises(ValueError):
        ServingConfig(warmup_ns=-1.0)
    with pytest.raises(ValueError):
        simulate_serving(one_trace_catalog(),
                         TraceReplayArrivals(times_ns=(0.0,), start_ns=-5.0),
                         "conduit")


@pytest.mark.slow
def test_saturation_grid_across_policies():
    """Nightly: the full policy comparison at benchmark scale — conduit
    sustains at least as much load as the DM baseline under the same SLO."""
    cat = two_kind_catalog()
    kw = dict(SAT_KW, iters=6, n_sessions=96)
    rates = {pol: find_saturation(cat, pol, **kw).rate_per_sec
             for pol in ("conduit", "bw", "dm")}
    assert rates["conduit"] >= rates["dm"]
    assert rates["conduit"] > 0


# -- serving-layer bugfix pins + pooling laws ----------------------------------


def test_makespan_includes_the_gc_tail_in_serving():
    """Pin for the GC-tail makespan bug: the collector's trailing
    copy/erase bookings can outlive every session and host request, and
    the pre-fix makespan fold (sessions + host I/O only) silently
    truncated them — shrinking reported wall time and inflating the perf
    harness's events/sec.  Here GC provably outlives the last session."""
    arr = PoissonArrivals(rate_per_sec=6000, n_sessions=16, seed=9)
    res = simulate_serving(two_kind_catalog(), arr, "conduit",
                           io_stream=serving_io(), ftl=GC_FTL)
    assert res.ftl.gc_invocations > 0
    last_session = max(r.done_ns for r in res.sessions if r.completed)
    assert res.ftl.last_booked_ns > last_session    # the tail is real
    assert res.makespan_ns == res.ftl.last_booked_ns


def test_makespan_includes_the_gc_tail_in_mix():
    """The same pin for the batch entry point: MixResult.makespan_ns
    must cover GC bookings past the last tenant completion."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(SHORT, name="B")
    io = HostIOStream(rate_iops=250_000, read_fraction=0.3, n_requests=256,
                      zipf_theta=0.95, n_logical_pages=GC_FTL.logical_pages())
    mix = simulate_mix([a, b], "conduit", io_stream=io, ftl=GC_FTL,
                       compute_solo=False)
    assert mix.ftl.gc_invocations > 0
    last_tenant = max(r.makespan_ns for r in mix.tenants)
    assert mix.ftl.last_booked_ns > last_tenant
    assert mix.makespan_ns == mix.ftl.last_booked_ns


def test_percentile_rejects_out_of_range_p():
    """Pin for the percentile clamp bug: ``p(-5)`` returned the min and
    ``p(250)`` the max — a typo for ``p(25)`` masqueraded as a plausible
    tail.  Every percentile-bearing surface must validate."""
    from repro.sim import percentile
    from repro.sim.stats import FTLStats, HostIOStats

    assert percentile([1.0, 2.0, 3.0], 0) == 1.0     # endpoints stay legal
    assert percentile([1.0, 2.0, 3.0], 100) == 3.0
    for bad in (-5, -0.001, 100.001, 250, math.nan):
        with pytest.raises(ValueError, match="out of range"):
            percentile([1.0, 2.0], bad)

    # the result-object callers all route through the same validation
    host = HostIOStats(n_reads=1, n_writes=0, latencies_ns=[5.0])
    ftl = FTLStats(gc_enabled=True, n_logical_pages=0, n_physical_pages=0,
                   host_pages_written=0, gc_pages_copied=0, blocks_erased=0,
                   gc_invocations=0, overflow_blocks=0, gc_energy_nj=0.0,
                   erase_counts=[], host_during_gc_ns=[1.0])
    res = simulate_serving(one_trace_catalog(ops=SHORT),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit")
    sim_res = res.session_results[0]
    for call in (host.p, ftl.p_during_gc, res.p, res.op_p, sim_res.p):
        with pytest.raises(ValueError, match="out of range"):
            call(101)


def _serving_fingerprint(res):
    """Every timing-visible surface of a ServingResult, for bit-identity
    laws (session lifecycles, per-op latencies, utilization, and the
    retained per-session SimResults)."""
    return (res.makespan_ns,
            [(r.kind, r.arrival_ns, r.admit_ns, r.done_ns, r.rejected)
             for r in res.sessions],
            res.op_latencies_ns,
            res.mean_in_system,
            sorted(res.utilization.items()),
            [(sr.makespan_ns, sr.n_instrs, sr.compute_energy_nj,
              sr.movement_energy_nj, sr.evictions, sr.coherence_syncs)
             for sr in (res.session_results or [])])


@pytest.mark.parametrize("policy", ["conduit", "bw", "cpu"])
def test_pooled_sessions_bit_identical_to_fresh_clones(policy):
    """Pooling law: recycling completed Simulation objects across
    admissions (``pool_sessions=True``, the default) must reproduce the
    fresh-clone-per-admission run bit-for-bit, for any policy.  The cap
    is far below the session count, so pooled objects are provably
    re-admitted many times back-to-back."""
    arr = PoissonArrivals(rate_per_sec=8000, n_sessions=24, seed=9)
    mk = lambda pooled: simulate_serving(
        two_kind_catalog(), arr, policy,
        serving=ServingConfig(max_active_sessions=4, pool_sessions=pooled))
    pooled, fresh = mk(True), mk(False)
    assert pooled.n_admitted > 4          # reuse actually happened
    assert _serving_fingerprint(pooled) == _serving_fingerprint(fresh)


def test_pool_reuse_back_to_back_on_one_catalog_entry():
    """The sharpest reuse shape: one catalog entry, concurrency cap 1 —
    every admission after the first resets the same pooled Simulation.
    Still bit-identical to fresh clones."""
    cat = one_trace_catalog(ops=SHORT)
    arr = PoissonArrivals(rate_per_sec=4000, n_sessions=10, seed=3)
    mk = lambda pooled: simulate_serving(
        cat, arr, "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=16,
                              pool_sessions=pooled))
    pooled, fresh = mk(True), mk(False)
    assert pooled.n_completed == fresh.n_completed
    assert pooled.n_completed + pooled.n_rejected == 10
    assert _serving_fingerprint(pooled) == _serving_fingerprint(fresh)
