"""Open-loop serving laws (:mod:`repro.sim.serving`).

The subsystem's acceptance properties:

(a) equivalence — one session, no churn, no admission pressure reproduces
    ``simulate_mix([trace])`` bit-for-bit (serving strictly generalizes
    the batch entry points);
(b) determinism — identical inputs replay identical serving runs;
(c) conservation — offered == completed + rejected + in-flight, with
    in-flight == 0 after a drained run, under any admission pressure;
(d) steady state — Little's law holds within tolerance on a stable run,
    and warm-up/cool-down trimming excludes edge sessions;
(e) saturation — the bisection is deterministic, brackets its answer,
    and is monotone in the SLO.

Plus the ``record_decisions=False`` fast mode: identical timing, no
DecisionRecord allocation, per-op latencies still available.
"""
import pytest

from repro.sim import (CatalogEntry, EventEngine, EventKind, HostIOStream,
                       MMPPArrivals, PoissonArrivals, ServingConfig,
                       SessionCatalog, SimConfig, TraceReplayArrivals,
                       find_saturation, simulate, simulate_mix,
                       simulate_serving)

from _synth import synth_trace

RAMP = list(range(40))
SHORT = [2, 4, 6] * 3


def one_trace_catalog(name="A", ops=RAMP):
    return SessionCatalog([CatalogEntry(name, synth_trace(ops, name=name))])


def two_kind_catalog():
    return SessionCatalog(
        [CatalogEntry("A", synth_trace(RAMP, name="A"), weight=3.0),
         CatalogEntry("B", synth_trace(SHORT, name="B"), weight=1.0)],
        seed=5)


# -- (a) equivalence -----------------------------------------------------------

def test_single_session_reproduces_simulate_mix_exactly():
    """The acceptance law: a no-churn ServingConfig run == simulate_mix."""
    tr = synth_trace(RAMP, name="A")
    ser = simulate_serving(SessionCatalog([CatalogEntry("A", tr)]),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit")
    mix = simulate_mix([tr], "conduit", compute_solo=False)
    got, want = ser.session_results[0], mix.tenants[0]
    assert got.makespan_ns == want.makespan_ns            # bit-exact
    assert got.total_energy_nj == want.total_energy_nj
    assert got.resource_counts == want.resource_counts
    assert got.coherence_syncs == want.coherence_syncs
    assert ser.makespan_ns == mix.makespan_ns
    assert ser.n_completed == 1 and ser.n_rejected == 0


def test_session_arrival_events_on_the_timeline():
    eng = EventEngine(record=True)
    simulate_serving(one_trace_catalog(),
                     PoissonArrivals(rate_per_sec=4000, n_sessions=8, seed=2),
                     "conduit", engine=eng)
    kinds = {k for _, k in eng.log}
    assert EventKind.SESSION_ARRIVAL in kinds
    assert EventKind.DISPATCH in kinds
    times = [t for t, _ in eng.log]
    assert all(b >= a for a, b in zip(times, times[1:]))


# -- (b) determinism -----------------------------------------------------------

def test_same_inputs_replay_identically():
    mk = lambda: simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=6000, n_sessions=24, seed=9),
        "conduit", serving=ServingConfig(max_active_sessions=4))
    r1, r2 = mk(), mk()
    assert r1.makespan_ns == r2.makespan_ns
    assert r1.session_latencies_ns == r2.session_latencies_ns
    assert [s.done_ns for s in r1.sessions] == [s.done_ns for s in r2.sessions]
    assert r1.utilization == r2.utilization


def test_arrival_seed_changes_the_run():
    mk = lambda seed: simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=6000, n_sessions=24, seed=seed),
        "conduit")
    assert mk(1).makespan_ns != mk(2).makespan_ns


# -- (c) conservation ----------------------------------------------------------

def test_session_conservation_under_admission_pressure():
    """offered == completed + rejected (+ inflight == 0 after drain), with
    a tiny admission cap and backlog forcing real rejections."""
    res = simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=50_000, n_sessions=40, seed=9),
        "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=2))
    assert res.n_rejected > 0
    assert res.n_inflight == 0
    assert res.n_offered == res.n_completed + res.n_rejected == 40
    assert res.n_admitted == res.n_completed
    rejected = [s for s in res.sessions if s.rejected]
    assert len(rejected) == res.n_rejected
    assert all(not s.completed for s in rejected)
    # admitted work all ran: one result per completed session
    assert len(res.session_results) == res.n_completed


def test_zero_backlog_rejects_everything_beyond_active_cap():
    res = simulate_serving(
        one_trace_catalog(ops=SHORT),
        TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0, 3.0)), "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=0))
    # sessions 1-3 arrive while session 0 still runs and bounce
    assert res.n_completed == 1
    assert res.n_rejected == 3


def test_backlog_defers_but_never_drops():
    """With a roomy backlog the same burst completes in full, FIFO."""
    res = simulate_serving(
        one_trace_catalog(ops=SHORT),
        TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0, 3.0)), "conduit",
        serving=ServingConfig(max_active_sessions=1, max_backlog=8))
    assert res.n_completed == 4 and res.n_rejected == 0
    admits = [s.admit_ns for s in res.sessions]
    assert admits == sorted(admits)                    # FIFO admission
    assert all(s.queue_wait_ns >= 0.0 for s in res.sessions)
    # serialized: each session admitted no earlier than its predecessor
    # completed its last event (epilogue frees the slot)
    for prev, nxt in zip(res.sessions, res.sessions[1:]):
        assert nxt.admit_ns >= prev.admit_ns


def test_queueing_under_cap_inflates_latency():
    arr = PoissonArrivals(rate_per_sec=20_000, n_sessions=24, seed=9)
    wide = simulate_serving(two_kind_catalog(), arr, "conduit",
                            serving=ServingConfig(max_active_sessions=16,
                                                  max_backlog=64))
    narrow = simulate_serving(two_kind_catalog(), arr, "conduit",
                              serving=ServingConfig(max_active_sessions=1,
                                                    max_backlog=64))
    assert narrow.p(50) > wide.p(50)
    assert narrow.mean_in_system > wide.mean_in_system


# -- (d) steady state ----------------------------------------------------------

def test_littles_law_on_a_stable_run():
    """L ≈ λ·W over the measured window at moderate, sustainable load."""
    res = simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=2000, n_sessions=64, seed=9),
        "conduit",
        serving=ServingConfig(warmup_ns=3e6, cooldown_ns=3e6))
    assert res.n_rejected == 0
    ratio = res.little_law_ratio()
    assert 0.7 < ratio < 1.3, f"Little's law violated: L/(lambda W)={ratio:.3f}"
    assert res.mean_in_system > 0.0


def test_warmup_cooldown_trim_excludes_edge_sessions():
    arr = DeterministicArrivals = PoissonArrivals(rate_per_sec=4000,
                                                  n_sessions=32, seed=9)
    trimmed = simulate_serving(
        two_kind_catalog(), arr, "conduit",
        serving=ServingConfig(warmup_ns=2e6, cooldown_ns=2e6))
    full = simulate_serving(two_kind_catalog(), arr, "conduit")
    n_meas = len(trimmed.measured_sessions)
    assert 0 < n_meas < trimmed.n_offered
    assert len(full.measured_sessions) == full.n_completed
    lo, hi = trimmed.window_ns
    for s in trimmed.sessions:
        assert s.measured == (lo <= s.arrival_ns <= hi)
    # the timing itself is untouched by where the window sits
    assert trimmed.makespan_ns == full.makespan_ns


def test_utilization_grows_with_offered_load():
    mk = lambda rate: simulate_serving(
        two_kind_catalog(),
        PoissonArrivals(rate_per_sec=rate, n_sessions=32, seed=9),
        "conduit", serving=ServingConfig(warmup_ns=1e5, cooldown_ns=1e5))
    quiet, loud = mk(1000), mk(12_000)
    assert set(quiet.utilization) == set(loud.utilization)
    assert all(v >= 0.0 for v in quiet.utilization.values())
    assert max(loud.utilization.values()) > max(quiet.utilization.values())


def test_host_io_stream_contends_with_sessions():
    arr = PoissonArrivals(rate_per_sec=4000, n_sessions=16, seed=9)
    io = HostIOStream(rate_iops=100_000, n_requests=64)
    with_io = simulate_serving(two_kind_catalog(), arr, "conduit",
                               io_stream=io)
    without = simulate_serving(two_kind_catalog(), arr, "conduit")
    assert with_io.host_io is not None and without.host_io is None
    assert with_io.host_io.n_requests == 64
    # host traffic can only slow sessions down (FIFO pools, superset load)
    for a, b in zip(without.session_latencies_ns,
                    with_io.session_latencies_ns):
        assert b >= a - 1e-6


def test_mmpp_burst_traffic_serves():
    res = simulate_serving(
        two_kind_catalog(),
        MMPPArrivals(rate_on_per_sec=16_000, mean_on_ns=2e6, mean_off_ns=2e6,
                     n_sessions=24, seed=4),
        "conduit")
    assert res.n_offered == 24
    assert res.n_inflight == 0


# -- record_decisions fast mode ------------------------------------------------

def test_record_decisions_off_is_bit_identical_and_lighter():
    tr = synth_trace(RAMP, name="A")
    full = simulate(tr, "conduit")
    fast = simulate(synth_trace(RAMP, name="A"), "conduit",
                    record_decisions=False)
    assert fast.makespan_ns == full.makespan_ns
    assert fast.total_energy_nj == full.total_energy_nj
    assert fast.decisions == []
    assert len(full.decisions) == len(RAMP)
    # per-op latencies survive the fast mode, and match the records
    assert fast.latencies_ns == full.latencies_ns
    assert fast.p(99) == full.p(99)


def test_record_decisions_off_in_mix():
    mk = lambda: [synth_trace(RAMP, name="A"), synth_trace(SHORT, name="B")]
    full = simulate_mix(mk(), "conduit", compute_solo=False)
    fast = simulate_mix(mk(), "conduit", compute_solo=False,
                        record_decisions=False)
    assert fast.makespan_ns == full.makespan_ns
    for f, g in zip(fast.tenants, full.tenants):
        assert f.decisions == []
        assert f.latencies_ns == g.latencies_ns


def test_serving_defaults_to_fast_mode():
    res = simulate_serving(one_trace_catalog(),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit")
    r = res.session_results[0]
    assert r.decisions == []
    assert len(r.latencies_ns) == len(RAMP)
    assert res.op_latencies_ns       # aggregated for measured sessions


def test_serving_fast_mode_survives_an_explicit_sim_config():
    """ServingConfig.record_decisions governs even when a SimConfig is
    passed (e.g. to tune capacities) — serving must not silently fall
    back to unbounded per-dispatch DecisionRecord logging."""
    res = simulate_serving(one_trace_catalog(),
                           TraceReplayArrivals(times_ns=(0.0,)), "conduit",
                           config=SimConfig(pud_units=8))
    assert res.session_results[0].decisions == []
    full = simulate_serving(one_trace_catalog(),
                            TraceReplayArrivals(times_ns=(0.0,)), "conduit",
                            serving=ServingConfig(record_decisions=True))
    assert len(full.session_results[0].decisions) == len(RAMP)


# -- (e) saturation finder -----------------------------------------------------

SAT_KW = dict(slo_p99_ns=1.5e6, rate_lo=1000, rate_hi=24_000, iters=4,
              n_sessions=32, seed=9,
              serving=ServingConfig(keep_session_results=False,
                                    warmup_ns=1e5, cooldown_ns=1e5))


def test_saturation_brackets_and_is_deterministic():
    cat = two_kind_catalog()
    sat = find_saturation(cat, "conduit", **SAT_KW)
    again = find_saturation(cat, "conduit", **SAT_KW)
    assert sat.rate_per_sec == again.rate_per_sec
    assert [p.rate_per_sec for p in sat.probes] == \
        [p.rate_per_sec for p in again.probes]
    lo, hi = sat.bracket
    assert sat.rate_per_sec == lo <= hi
    assert 1000 <= lo and hi <= 24_000
    assert len(sat.probes) <= 2 + SAT_KW["iters"]
    # the bracket is genuinely decided: lo sustained, hi (if distinct) not
    by_rate = {p.rate_per_sec: p for p in sat.probes}
    assert by_rate[lo].sustainable
    if hi != lo:
        assert not by_rate[hi].sustainable


def test_saturation_monotone_in_slo():
    """A tighter SLO can only lower the sustainable rate."""
    cat = two_kind_catalog()
    loose = find_saturation(cat, "conduit", **SAT_KW)
    tight = find_saturation(cat, "conduit",
                            **{**SAT_KW, "slo_p99_ns": 0.8e6})
    assert tight.rate_per_sec <= loose.rate_per_sec


def test_saturation_validation():
    cat = two_kind_catalog()
    with pytest.raises(ValueError):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=0,
                        rate_hi=100)
    with pytest.raises(ValueError):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=100,
                        rate_hi=100)
    with pytest.raises(ValueError):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=100,
                        rate_hi=200, iters=0)
    # warmup/cooldown that swallow the arrival span fail loudly instead of
    # making every rate look sustainable
    with pytest.raises(ValueError, match="no measured sessions"):
        find_saturation(cat, "conduit", slo_p99_ns=1e6, rate_lo=1000,
                        rate_hi=2000, n_sessions=8,
                        serving=ServingConfig(warmup_ns=1e12,
                                              cooldown_ns=1e12))


def test_saturation_treats_all_rejected_probe_as_unsustainable():
    """A probe where admission pressure rejects the in-window arrivals is
    unsustainable by the rejections alone — it must not crash on the
    empty latency list."""
    cat = two_kind_catalog()
    sat = find_saturation(
        cat, "conduit", slo_p99_ns=1e9, rate_lo=100, rate_hi=1_000_000,
        iters=2, n_sessions=16,
        serving=ServingConfig(max_active_sessions=1, max_backlog=0,
                              warmup_ns=3e4, cooldown_ns=0.0,
                              keep_session_results=False))
    assert any(p.n_rejected > 0 and not p.sustainable for p in sat.probes)
    assert sat.rate_per_sec < 1_000_000


# -- config validation ---------------------------------------------------------

def test_serving_config_validation():
    with pytest.raises(ValueError):
        ServingConfig(max_active_sessions=0)
    with pytest.raises(ValueError):
        ServingConfig(max_backlog=-1)
    with pytest.raises(ValueError):
        ServingConfig(warmup_ns=-1.0)
    with pytest.raises(ValueError):
        simulate_serving(one_trace_catalog(),
                         TraceReplayArrivals(times_ns=(0.0,), start_ns=-5.0),
                         "conduit")


@pytest.mark.slow
def test_saturation_grid_across_policies():
    """Nightly: the full policy comparison at benchmark scale — conduit
    sustains at least as much load as the DM baseline under the same SLO."""
    cat = two_kind_catalog()
    kw = dict(SAT_KW, iters=6, n_sessions=96)
    rates = {pol: find_saturation(cat, pol, **kw).rate_per_sec
             for pol in ("conduit", "bw", "dm")}
    assert rates["conduit"] >= rates["dm"]
    assert rates["conduit"] > 0
