"""Deterministic fallback for the simulator coherence/conservation laws.

Runs in bare environments (no ``hypothesis``): the same invariants as
``test_sim_properties.py`` but over a small fixed family of synthetic
traces instead of randomized examples, plus the PageTable unit laws.
"""
import numpy as np
import pytest

from repro.core.isa import Location
from repro.core.mapping import PageTable
from repro.hw.ssd_spec import DEFAULT_SSD
from repro.sim import SimConfig, simulate

from _synth import synth_trace

SPEC = DEFAULT_SSD
PAGE = SPEC.page_size

# A fixed family standing in for the hypothesis-generated op-id lists:
# short, long, repetitive, and skewed-mix cases.
FIXED_EXAMPLES = [
    [0],
    list(range(40)),
    [3] * 25,
    [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 3,
]


@pytest.mark.parametrize("op_ids", FIXED_EXAMPLES, ids=["one", "ramp",
                                                        "repeat", "mixed"])
def test_completion_monotone_and_conserved(op_ids):
    tr = synth_trace(op_ids)
    for pol in ("conduit", "dm", "bw"):
        r = simulate(tr, pol)
        assert r.n_instrs == len(op_ids)
        assert len(r.decisions) == len(op_ids)
        for d in r.decisions:
            assert d.t_decide <= d.t_start <= d.t_end
            assert np.isfinite(d.t_end)
        assert sum(r.resource_counts.values()) == len(op_ids)
        assert r.makespan_ns >= max(d.t_end for d in r.decisions) - 1e-6


@pytest.mark.parametrize("op_ids", FIXED_EXAMPLES[1:], ids=["ramp", "repeat",
                                                            "mixed"])
def test_deps_respected(op_ids):
    tr = synth_trace(op_ids)
    r = simulate(tr, "conduit")
    end_by_iid = {d.iid: d.t_end for d in r.decisions}
    start_by_iid = {d.iid: d.t_start for d in r.decisions}
    for ins in tr.instrs:
        for dep in ins.deps:
            assert start_by_iid[ins.iid] >= end_by_iid[dep] - 1e-6, \
                "consumer started before producer finished"


def test_single_owner_invariant():
    """§4.4 coherence: one owner per logical page, one-byte versions."""
    tr = synth_trace(list(range(40)))
    simulate(tr, "conduit")
    for ent in tr.pages.entries.values():
        assert ent.owner in (Location.FLASH, Location.DRAM, Location.CTRL,
                             Location.HOST)
        assert 0 <= ent.version <= 255


def test_replay_on_fault():
    tr = synth_trace(list(range(5, 45)))
    r = simulate(tr, "conduit", config=SimConfig(fail_rate=0.3, seed=2))
    assert r.replays > 0
    assert sum(r.resource_counts.values()) == 40
    assert r.makespan_ns > 0


def test_energy_nonnegative_and_decomposed():
    tr = synth_trace(FIXED_EXAMPLES[3])
    r = simulate(tr, "dm")
    assert r.compute_energy_nj >= 0
    assert r.movement_energy_nj >= 0
    assert r.total_energy_nj == pytest.approx(
        r.compute_energy_nj + r.movement_energy_nj)


def test_ideal_ignores_movement():
    tr = synth_trace(list(range(30)))
    ideal = simulate(tr, "ideal")
    assert ideal.movement_energy_nj == 0.0
    assert ideal.avg_decision_overhead_ns == 0.0


def test_pressure_increases_evictions():
    tr = synth_trace(list(range(40)), n_arrays=8, pages_per_array=8)
    roomy = simulate(tr, "conduit",
                     config=SimConfig(dram_capacity_pages=10_000,
                                      host_capacity_pages=10_000))
    tight = simulate(tr, "conduit",
                     config=SimConfig(dram_capacity_pages=33,
                                      host_capacity_pages=33))
    assert tight.evictions >= roomy.evictions


# -- PageTable unit laws -------------------------------------------------------

def test_coherence_owner_transitions():
    pt = PageTable(SPEC)
    pid = pt.alloc_array(PAGE)[0]
    assert pt[pid].owner == Location.FLASH and not pt[pid].dirty
    pt.record_write(pid, Location.DRAM)
    assert pt[pid].owner == Location.DRAM and pt[pid].dirty
    v1 = pt[pid].version
    pt.record_write(pid, Location.DRAM)     # same owner: version bump only
    assert pt[pid].version == v1 + 1
    assert pt.commit(pid) is True
    assert pt[pid].owner == Location.FLASH and not pt[pid].dirty
    assert pt[pid].version == 0
    assert pt.commit(pid) is False          # idempotent


def test_colocate_idempotent():
    pt = PageTable(SPEC)
    a = pt.alloc_array(2 * PAGE)
    b = pt.alloc_array(2 * PAGE)
    pids = [a[0], b[0]]
    assert not pt.same_block(pids)
    moved = pt.co_locate(pids)
    assert moved == 1
    assert pt.same_block(pids)
    assert pt.co_locate(pids) == 0
