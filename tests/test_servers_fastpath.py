"""ServerPool fast-path laws: the lazy min-heap and the incremental
pending-work counter agree with the brute-force O(k) definitions, and
pools that never saw a job are well-behaved."""
import pytest

from repro.sim.machine import _hash01
from repro.sim.servers import Acquisition, ServerPool


def brute_queue_delay(pool, now):
    return min(max(0.0, f - now) for f in pool.free)


def brute_pending(pool, now):
    return sum(max(0.0, f - now) for f in pool.free)


def drive(pool, n, seed=1, query_every=3):
    """Deterministic pseudo-random acquire workload with monotone query
    times; asserts the incremental features against brute force at every
    step."""
    now = 0.0
    for i in range(n):
        ready = now + 500.0 * _hash01(i, seed)
        dur = 1000.0 * _hash01(i, seed ^ 0xABCD)
        unit = None
        if _hash01(i, seed ^ 0x77) < 0.5:
            unit = int(_hash01(i, seed ^ 0x99) * pool.units) % pool.units
        if i % 2 == 0:
            pool.acquire(ready, dur, unit=unit)
        else:
            pool.acquire_end(ready, dur, unit=unit)
        if i % query_every == 0:
            now += 300.0 * _hash01(i, seed ^ 0x1234)
            assert pool.queue_delay_ns(now) == brute_queue_delay(pool, now)
            assert pool.pending_work_ns(now) == pytest.approx(
                brute_pending(pool, now), rel=1e-12, abs=1e-6)
            # the maintained counter is the sum of booked free times
            assert pool._pending_work == pytest.approx(
                sum(pool.free), rel=1e-12, abs=1e-6)


@pytest.mark.parametrize("units", [1, 3, 8, 64])
def test_pending_work_counter_matches_brute_force(units):
    pool = ServerPool("p", units)
    drive(pool, 300, seed=units)


def test_acquire_matches_linear_scan_tie_breaking():
    """The heap picks the earliest-free unit, lowest index on ties —
    exactly the old ``min(range(units), key=free.__getitem__)``."""
    pool = ServerPool("p", 4)
    # all free at 0.0: ties broken by lowest unit index, FIFO
    assert pool.acquire(0.0, 10.0).unit == 0
    assert pool.acquire(0.0, 10.0).unit == 1
    assert pool.acquire(0.0, 10.0).unit == 2
    assert pool.acquire(0.0, 10.0).unit == 3
    # unit 1 frees earliest after a targeted re-book of unit 0
    pool.acquire(0.0, 50.0, unit=0)
    a = pool.acquire(0.0, 1.0)
    assert a.unit == 1
    assert a.start == 10.0
    assert a.end == 11.0


def test_acquire_end_equals_acquire():
    p1 = ServerPool("a", 3)
    p2 = ServerPool("b", 3)
    for i in range(50):
        ready = 100.0 * _hash01(i, 5)
        dur = 250.0 * _hash01(i, 6)
        unit = i % 3 if i % 4 == 0 else None
        assert p2.acquire_end(ready, dur, unit=unit) == \
            p1.acquire(ready, dur, unit=unit).end
    assert p1.free == p2.free
    assert p1.busy_ns == p2.busy_ns
    assert p1.jobs == p2.jobs


def test_zero_job_pool_is_well_behaved():
    """A pool that never saw a job: no max()-on-empty, no stale lazy
    entries, all features zero."""
    pool = ServerPool("idle", 3)
    assert pool.horizon_ns == 0.0
    assert pool.utilization(0.0) == 0.0
    assert pool.utilization(1e9) == 0.0
    assert pool.queue_delay_ns(0.0) == 0.0
    assert pool.queue_delay_ns(5_000.0) == 0.0
    assert pool.pending_work_ns(0.0) == 0.0
    assert pool.pending_work_ns(7_500.0) == 0.0
    assert pool.peek_start(123.0) == 123.0
    assert pool.jobs == 0 and pool.busy_ns == 0.0


def test_pending_work_probes_exact_in_any_time_order():
    pool = ServerPool("p", 2)
    pool.acquire(0.0, 100.0)
    pool.acquire(0.0, 40.0)
    assert pool.pending_work_ns(50.0) == brute_pending(pool, 50.0)
    # probing backwards in time still gives the exact sum
    assert pool.pending_work_ns(10.0) == brute_pending(pool, 10.0)
    assert pool.pending_work_ns(60.0) == brute_pending(pool, 60.0)
    assert pool._pending_work == pytest.approx(sum(pool.free))


def test_fabric_pools_pending_counter_after_real_run():
    """After a full simulation, every pool's maintained counter equals the
    brute-force sum at the horizon and beyond."""
    from repro.core.policies import make_policy
    from repro.hw.ssd_spec import DEFAULT_SSD
    from repro.sim.machine import Simulation
    from _synth import synth_trace

    sim = Simulation(synth_trace([3, 1, 4, 1, 5, 9, 2, 6] * 3),
                     make_policy("conduit", DEFAULT_SSD))
    sim.run()
    for pool in sim.fabric.all_pools():
        for now in (0.0, sim.fabric.horizon_ns / 2, sim.fabric.horizon_ns):
            assert pool.pending_work_ns(now) == pytest.approx(
                brute_pending(pool, now), rel=1e-12, abs=1e-6), pool.name


def test_acquisition_namedtuple_shape():
    a = Acquisition(unit=2, start=1.0, end=3.0)
    assert (a.unit, a.start, a.end) == (2, 1.0, 3.0)
