"""Fleet serving laws (:mod:`repro.sim.fleet`, :mod:`repro.sim.placement`).

The subsystem's acceptance properties:

(a) N=1 equivalence — a 1-drive hash-placement fleet is *bit-identical*
    to ``simulate_serving`` (same DriveActor code path), with or without
    host-I/O churn, an FTL and the error model;
(b) seed lineage — ``derive_drive_seed`` is the identity for drive 0,
    distinct per drive/salt, and per-drive pure: adding drive k+1 to a
    fleet never perturbs the streams (or results) of drives 0..k;
(c) regime agreement — the lockstep driver reproduces the static
    pre-partitioned driver exactly when health is uniform (this also
    pins the advance-to-time seam against host-I/O burst batching);
(d) percentile law — fleet percentiles are sample-merged across drives,
    never averages of per-drive percentiles;
(e) conservation + determinism — offered sessions are all accounted for
    under steering, hedging, admission caps and retirement, and every
    configuration replays identically;
(f) mechanisms — steering and hedging recover a mid-GC straggler's
    tail; hedged sessions resolve to the fastest copy and the loser's
    queued twin is cancelled; retirement drains a drive and survivors
    absorb the rebuild stream;
(g) observability — merged fleet traces validate (including the
    ``d<k>:`` process vocabulary), split back into valid per-drive
    traces, and ``fleet_blame`` names the straggler.
"""
import copy
import dataclasses
import json

import pytest

from repro.sim import (CatalogEntry, ConsistentHashPlacement, DriveProfile,
                       FaultConfig, FleetConfig, FleetSweepLane, FTLConfig,
                       HashPlacement, HeatAwarePlacement, HostIOStream,
                       PoissonArrivals, PlacementPolicy, ServingConfig,
                       SessionCatalog, batched_find_fleet_saturation,
                       derive_drive_seed, fleet_blame, find_fleet_saturation,
                       make_placement, merge_fleet_trace, merged_percentile,
                       percentile, simulate_fleet, simulate_serving,
                       split_fleet_trace, validate_trace)
from repro.sim.drive import DriveHealth

from _synth import synth_trace

pytestmark = pytest.mark.filterwarnings("ignore:little_law_ratio")

RAMP = list(range(40))
SHORT = [2, 4, 6] * 3


def two_kind_catalog():
    return SessionCatalog(
        [CatalogEntry("A", synth_trace(RAMP, name="A"), weight=3.0),
         CatalogEntry("B", synth_trace(SHORT, name="B"), weight=1.0)],
        seed=5)


def quiet():
    return ServingConfig(little_law_warn_tol=float("inf"))


def arrivals(rate=6000, n=24, seed=9):
    return PoissonArrivals(rate_per_sec=rate, n_sessions=n, seed=seed)


def straggler_profile(n_requests=300):
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                    prefill=0.9, gc_suspend=True, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=150_000, read_fraction=0.1,
                      n_requests=n_requests, zipf_theta=0.9,
                      n_logical_pages=ftl.logical_pages(), seed=11)
    return DriveProfile(io_stream=io, ftl=ftl)


def serving_tuple(res):
    return (res.makespan_ns, res.n_completed, res.n_rejected,
            res.n_failed, res.n_timed_out,
            tuple(res.session_latencies_ns))


# -- (a) the N=1 equivalence law -----------------------------------------------

def test_one_drive_fleet_reproduces_simulate_serving_exactly():
    cat, arr = two_kind_catalog(), arrivals()
    ser = simulate_serving(cat, arr, "conduit", serving=quiet())
    flt = simulate_fleet(cat, arr, "conduit", serving=quiet(),
                         fleet=FleetConfig(n_drives=1))
    assert serving_tuple(flt.drives[0]) == serving_tuple(ser)   # bit-exact
    assert flt.p(99) == ser.p(99)
    assert flt.n_completed == ser.n_completed
    assert [(r.state, r.done_ns) for r in flt.sessions] == \
           [(r.state, r.done_ns) for r in ser.sessions]


def test_one_drive_fleet_equivalence_with_ftl_io_and_faults():
    cat, arr = two_kind_catalog(), arrivals(rate=4000, n=16)
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                    prefill=0.9, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=40_000, read_fraction=0.7, n_requests=200,
                      n_logical_pages=ftl.logical_pages(), seed=7)
    fc = FaultConfig(rber_base=5e-4)
    kw = dict(serving=quiet(), io_stream=io, ftl=ftl, faults=fc)
    ser = simulate_serving(cat, arr, "conduit", **kw)
    flt = simulate_fleet(cat, arr, "conduit",
                         fleet=FleetConfig(n_drives=1), **kw)
    d0 = flt.drives[0]
    assert serving_tuple(d0) == serving_tuple(ser)
    assert d0.host_io.latencies_ns == ser.host_io.latencies_ns
    assert d0.ftl.gc_pages_copied == ser.ftl.gc_pages_copied
    assert d0.faults.summary() == ser.faults.summary()


# -- (b) seed lineage ----------------------------------------------------------

def test_derive_drive_seed_identity_and_distinctness():
    assert derive_drive_seed(12345, 0) == 12345          # the N=1 anchor
    seeds = [derive_drive_seed(12345, d) for d in range(16)]
    assert len(set(seeds)) == 16
    # salts separate stream kinds on one drive
    assert derive_drive_seed(12345, 3, salt=0) != \
        derive_drive_seed(12345, 3, salt=1)
    # and drive 0 with a nonzero salt is NOT the raw seed (no cross-talk
    # between the io stream and the fault stream of drive 0)
    assert derive_drive_seed(12345, 0, salt=1) != 12345
    # pure function of (seed, drive, salt)
    assert derive_drive_seed(12345, 7, 1) == derive_drive_seed(12345, 7, 1)


class _PinnedPlacement(PlacementPolicy):
    """Routes sid -> sid % 2 regardless of fleet size, so growing the
    fleet cannot re-route sessions — isolating the RNG-lineage law."""

    name = "pinned"

    def replicas(self, sid, r):
        return (sid % 2,)


def test_adding_a_drive_never_perturbs_existing_drives():
    cat, arr = two_kind_catalog(), arrivals(rate=4000, n=20)
    io = HostIOStream(rate_iops=30_000, read_fraction=0.6, n_requests=150,
                      seed=21)
    mk = lambda n: simulate_fleet(
        cat, arr, "conduit", serving=quiet(), io_stream=io,
        fleet=FleetConfig(n_drives=n, placement=_PinnedPlacement(n)))
    small, big = mk(2), mk(3)
    for d in range(2):
        assert serving_tuple(big.drives[d]) == \
            serving_tuple(small.drives[d])
        assert big.drives[d].host_io.latencies_ns == \
            small.drives[d].host_io.latencies_ns
    # the new drive served nothing but still drew its own io stream
    assert big.drives[2].n_completed == 0
    assert big.drives[2].host_io.n_reads + big.drives[2].host_io.n_writes > 0


# -- (c) regime agreement (lockstep == static when health is uniform) ---------

def test_lockstep_driver_matches_static_partition():
    """steering=True forces the lockstep loop (advance_before + health
    reads per arrival) but with uniform health it must route exactly
    like the static pre-partitioned driver — including under host-I/O
    burst batching, which must stop at the advance horizon."""
    cat, arr = two_kind_catalog(), arrivals(rate=6000, n=32)
    io = HostIOStream(rate_iops=50_000, read_fraction=0.7, n_requests=300,
                      seed=13)
    static = simulate_fleet(cat, arr, "conduit", serving=quiet(),
                            io_stream=io,
                            fleet=FleetConfig(n_drives=3, replication=2))
    lockstep = simulate_fleet(cat, arr, "conduit", serving=quiet(),
                              io_stream=io,
                              fleet=FleetConfig(n_drives=3, replication=2,
                                                steering=True))
    assert lockstep.n_steered == 0          # nothing to steer around
    for d in range(3):
        assert serving_tuple(lockstep.drives[d]) == \
            serving_tuple(static.drives[d])
    assert [(r.state, r.done_ns, r.winner) for r in lockstep.sessions] == \
           [(r.state, r.done_ns, r.winner) for r in static.sessions]


# -- (d) the percentile law ----------------------------------------------------

def test_fleet_percentiles_are_sample_merged_not_averaged():
    # asymmetric groups where averaging per-group p99s is wildly wrong:
    # one drive holds ALL of the fleet's slow samples
    groups = [[10_000.0] * 10, [100.0] * 90]
    merged = merged_percentile(groups, 99)
    flat = sorted(x for g in groups for x in g)
    assert merged == percentile(flat, 99)                # the definition
    assert merged == 10_000.0     # the tail survives the merge untouched
    avg_of_p99s = sum(percentile(g, 99) for g in groups) / len(groups)
    assert merged != avg_of_p99s                         # the bug to ban
    assert avg_of_p99s < 0.6 * merged    # averaging halves the real tail


def test_fleet_result_p99_equals_percentile_of_pooled_latencies():
    res = simulate_fleet(two_kind_catalog(), arrivals(), "conduit",
                         serving=quiet(), fleet=FleetConfig(n_drives=3))
    lats = res.session_latencies_ns
    assert lats
    assert res.p(99) == percentile(sorted(lats), 99)
    assert res.p(99) == merged_percentile(res.latency_groups(), 99)


# -- (e) conservation + determinism --------------------------------------------

@pytest.mark.parametrize("fcfg", [
    FleetConfig(n_drives=3),
    FleetConfig(n_drives=3, placement="consistent", replication=2),
    FleetConfig(n_drives=3, placement="heat", replication=2),
    FleetConfig(n_drives=3, replication=2, steering=True),
    FleetConfig(n_drives=3, replication=2, hedging=True),
    FleetConfig(n_drives=3, replication=2, max_inflight=2),
    FleetConfig(n_drives=3, replication=2, retire=(1, 2.0e6)),
], ids=["hash", "consistent", "heat", "steering", "hedging",
        "max_inflight", "retire"])
def test_fleet_conservation_and_determinism(fcfg):
    mk = lambda: simulate_fleet(two_kind_catalog(),
                                arrivals(rate=8000, n=30), "conduit",
                                serving=quiet(), fleet=fcfg)
    res, res2 = mk(), mk()
    assert res.n_offered == (res.n_completed + res.n_rejected
                             + res.n_failed + res.n_timed_out)
    assert res.n_inflight == 0
    # replay is exact
    assert [(r.state, r.done_ns, r.winner, r.drives) for r in res.sessions] \
        == [(r.state, r.done_ns, r.winner, r.drives) for r in res2.sessions]
    assert res.summary() == res2.summary()


def test_fleet_front_door_backpressure():
    res = simulate_fleet(two_kind_catalog(),
                         arrivals(rate=100_000, n=40), "conduit",
                         serving=quiet(),
                         fleet=FleetConfig(n_drives=2, replication=2,
                                           max_inflight=1))
    assert res.n_fleet_rejected > 0
    assert res.n_rejected >= res.n_fleet_rejected
    assert res.n_offered == (res.n_completed + res.n_rejected
                             + res.n_failed + res.n_timed_out)
    # rejected-at-the-door sessions never touched a drive
    assert sum(d.n_offered for d in res.drives) < res.n_offered


# -- (f) mechanisms ------------------------------------------------------------

def test_steering_recovers_straggler_tail():
    cat, arr = two_kind_catalog(), arrivals(rate=6000, n=24)
    mk = lambda steer: simulate_fleet(
        cat, arr, "conduit", serving=quiet(),
        fleet=FleetConfig(n_drives=3, replication=2, steering=steer,
                          profiles=((0, straggler_profile()),)))
    plain, steered = mk(False), mk(True)
    assert steered.n_steered > 0
    assert steered.p(99) < plain.p(99)


def test_hedging_takes_fastest_copy_and_cancels_the_twin():
    cat, arr = two_kind_catalog(), arrivals(rate=6000, n=24)
    res = simulate_fleet(
        cat, arr, "conduit", serving=quiet(),
        fleet=FleetConfig(n_drives=3, replication=2, hedging=True,
                          profiles=((0, straggler_profile()),)))
    assert res.n_hedged > 0
    hedged_done = [r for r in res.sessions if r.hedged and r.completed]
    assert hedged_done
    for rec in hedged_done:
        assert rec.winner in rec.drives
    # every cancel is a revoked queued twin, visible in the drive counts
    assert res.n_cancelled == sum(d.n_cancelled for d in res.drives)
    # and hedging beats leaving the straggler in the route order
    plain = simulate_fleet(
        cat, arr, "conduit", serving=quiet(),
        fleet=FleetConfig(n_drives=3, replication=2,
                          profiles=((0, straggler_profile()),)))
    assert res.p(99) < plain.p(99)


def test_retirement_drains_drive_and_survivors_absorb_rebuild():
    cat = two_kind_catalog()
    arr = arrivals(rate=4000, n=30)
    t_retire = 3.0e6
    res = simulate_fleet(
        cat, arr, "conduit", serving=quiet(),
        fleet=FleetConfig(n_drives=3, replication=2, retire=(1, t_retire),
                          rebuild_read_iops=4_000.0, rebuild_reads=128))
    base = simulate_fleet(cat, arr, "conduit", serving=quiet(),
                          fleet=FleetConfig(n_drives=3, replication=2))
    # the retiree took no sessions after the retirement instant
    for rec in res.sessions:
        if rec.arrival_ns > t_retire:
            assert 1 not in (rec.winner,)
    # survivors served the rebuild reads as a background tenant: the
    # reconstruction traffic keeps them busy past their last session
    assert max(res.drives[d].makespan_ns for d in (0, 2)) > \
        max(base.drives[d].makespan_ns for d in (0, 2))
    assert res.n_offered == (res.n_completed + res.n_rejected
                             + res.n_failed + res.n_timed_out)


# -- placement unit laws -------------------------------------------------------

@pytest.mark.parametrize("cls", [HashPlacement, ConsistentHashPlacement,
                                 HeatAwarePlacement])
def test_replica_sets_are_distinct_and_stable(cls):
    p = cls(5)
    for sid in range(50):
        reps = p.replicas(sid, 3)
        assert len(reps) == len(set(reps)) == 3
        assert all(0 <= d < 5 for d in reps)
        assert reps == p.replicas(sid, 3)                # pure
    assert len(p.replicas(7, 99)) == 5                   # r clamps to N


def test_consistent_hash_minimizes_remapping():
    small, big = ConsistentHashPlacement(4), ConsistentHashPlacement(5)
    moved = sum(small.replicas(sid, 1)[0] != big.replicas(sid, 1)[0]
                for sid in range(1000))
    # ideal is ~1/5 of sessions; plain mod-hash remaps ~4/5
    assert moved < 450


def _health(d, **kw):
    base = dict(drive_id=d, t_ns=0.0, active=0, backlog=0, gc_busy=False,
                gc_active_dies=0, read_only_dies=0, failed_dies=0,
                recovering=False, retired=False)
    base.update(kw)
    return DriveHealth(**base)


def test_heat_aware_route_orders_by_load():
    p = HeatAwarePlacement(3)
    health = {0: _health(0, gc_busy=True, gc_active_dies=2),
              1: _health(1), 2: _health(2, active=1)}
    assert p.route(0, (0, 1, 2), health) == (1, 2, 0)
    # ties preserve placement (primary-first) order
    health = {0: _health(0), 1: _health(1), 2: _health(2)}
    assert p.route(0, (2, 0, 1), health) == (2, 0, 1)
    # retired drives sink below everything
    health = {0: _health(0, retired=True), 1: _health(1, gc_busy=True),
              2: _health(2, recovering=True)}
    assert p.route(0, (0, 1, 2), health)[-1] == 0


def test_make_placement_registry():
    assert make_placement("hash", 4).name == "hash"
    assert make_placement("consistent", 4).name == "consistent"
    assert make_placement("heat", 4).name == "heat"
    inst = HashPlacement(2)
    assert make_placement(inst, 4) is inst
    with pytest.raises(ValueError, match="unknown placement"):
        make_placement("roundrobin", 4)


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="hedging needs replication"):
        FleetConfig(n_drives=3, hedging=True)
    with pytest.raises(ValueError, match="steering needs replication"):
        FleetConfig(n_drives=3, steering=True)
    with pytest.raises(ValueError, match="replication"):
        FleetConfig(n_drives=2, replication=3)
    with pytest.raises(ValueError, match="retire"):
        FleetConfig(n_drives=2, retire=(5, 1.0))
    with pytest.raises(ValueError, match="only drive"):
        FleetConfig(n_drives=1, retire=(0, 1.0))


# -- saturation ----------------------------------------------------------------

def test_find_fleet_saturation_deterministic_and_bracketed():
    cat = two_kind_catalog()
    base = arrivals(rate=100, n=24)
    mk = lambda: find_fleet_saturation(
        cat, base, "conduit", slo_p99_ns=2e6, rate_lo=500.0,
        rate_hi=40_000.0, iters=2, serving=quiet(),
        fleet=FleetConfig(n_drives=2))
    s1, s2 = mk(), mk()
    assert s1.rate_per_sec == s2.rate_per_sec
    assert [p.rate_per_sec for p in s1.probes] == \
           [p.rate_per_sec for p in s2.probes]
    assert s1.bracket[0] <= s1.rate_per_sec <= s1.bracket[1]
    assert s1.policy == "conduit[hashx2]"


def test_batched_fleet_saturation_matches_scalar():
    cat = two_kind_catalog()
    fcfgs = [FleetConfig(n_drives=2),
             FleetConfig(n_drives=2, placement="heat", replication=2)]
    lanes = [FleetSweepLane("conduit", fleet=f, seed=9, n_sessions=24)
             for f in fcfgs]
    batched = batched_find_fleet_saturation(
        cat, lanes, slo_p99_ns=2e6, rate_lo=500.0, rate_hi=40_000.0,
        iters=2, serving=quiet())
    for lane, got in zip(lanes, batched):
        want = find_fleet_saturation(
            cat, lane.base_process(500.0), "conduit", slo_p99_ns=2e6,
            rate_lo=500.0, rate_hi=40_000.0, iters=2, serving=quiet(),
            fleet=lane.fleet)
        assert got.rate_per_sec == want.rate_per_sec
        assert got.policy == want.policy
        assert [p.rate_per_sec for p in got.probes] == \
               [p.rate_per_sec for p in want.probes]


# -- (g) observability ---------------------------------------------------------

@pytest.fixture(scope="module")
def traced_fleet():
    res = simulate_fleet(
        two_kind_catalog(), arrivals(rate=6000, n=18), "conduit",
        serving=quiet(), telemetry=True,
        fleet=FleetConfig(n_drives=3, replication=2, hedging=True,
                          profiles=((0, straggler_profile(150)),)))
    return res, merge_fleet_trace(res.telemetry)


def test_merged_fleet_trace_validates(traced_fleet):
    res, trace = traced_fleet
    assert validate_trace(trace) == []
    meta = trace["otherData"]["meta"]
    assert meta["entry"] == "simulate_fleet"
    assert meta["n_drives"] == 3
    pnames = {(ev["args"] or {}).get("name")
              for ev in trace["traceEvents"]
              if ev.get("ph") == "M" and ev.get("name") == "process_name"}
    assert any(n and n.startswith("d0:") for n in pnames)
    assert any(n and n.startswith("d2:") for n in pnames)


def test_validate_trace_rejects_malformed_drive_prefixes(traced_fleet):
    _res, trace = traced_fleet
    for bad in ("dx:fabric", "d1:bogus", "d01x:sessions"):
        t = copy.deepcopy(trace)
        for ev in t["traceEvents"]:
            if ev.get("ph") == "M" and ev.get("name") == "process_name":
                ev["args"]["name"] = bad
                break
        errs = validate_trace(t)
        assert any("malformed drive-prefixed process name" in e
                   for e in errs), bad


def test_split_fleet_trace_round_trips(traced_fleet, tmp_path):
    res, trace = traced_fleet
    # through the file format, as a CI artifact consumer would see it
    path = tmp_path / "fleet.json"
    with open(path, "w") as f:
        json.dump(trace, f)
    with open(path) as f:
        per = split_fleet_trace(json.load(f))
    assert sorted(per) == [0, 1, 2]
    for k, t in per.items():
        assert validate_trace(t) == [], k
        assert t["otherData"]["meta"]["drive"] == k
        pids = {ev["pid"] for ev in t["traceEvents"]
                if isinstance(ev.get("pid"), int)}
        assert pids and all(p < 10 for p in pids)         # base pids restored


def test_fleet_blame_names_the_straggler(traced_fleet):
    _res, trace = traced_fleet
    blame = fleet_blame(trace)
    assert blame["schema"] == "conduit-fleet-analysis/v1"
    assert len(blame["per_drive"]) == 3
    assert blame["fleet_p99_ns"] > 0
    assert blame["straggler"]["drive"] == 0


def test_simulate_fleet_rejects_single_flight_recorder():
    from repro.sim import FlightRecorder, TelemetryConfig
    with pytest.raises(ValueError, match="one recorder per drive"):
        simulate_fleet(two_kind_catalog(), arrivals(), "conduit",
                       telemetry=FlightRecorder(TelemetryConfig()))
