"""Cost function (Eqns 1-2), §4.5 overheads, and policy behaviors."""
import numpy as np
import pytest

from repro.core.cost import (HOME, SystemView, decision_overhead_ns,
                             dm_latency_ns, features_for)
from repro.core.isa import (Location, OpClass, Resource, VectorInstr,
                            compute_latency_ns, supports)
from repro.core.policies import make_policy
from repro.hw.ssd_spec import DEFAULT_SSD

SPEC = DEFAULT_SSD
PAGE = SPEC.page_size


def mk_instr(op="add", srcs=(0, 1), dst=2, vlen=PAGE, iid=0):
    return VectorInstr(iid=iid, op=op, vlen=vlen, elem_bytes=1,
                       srcs=tuple(srcs), dst=dst)


def mk_view(loc=Location.FLASH, queue=0.0, dep=0.0):
    return SystemView(
        now_ns=0.0,
        queue_delay_ns=lambda r: queue,
        dep_ready_ns=lambda i: dep,
        location_of=lambda p: loc,
    )


def test_eqn1_total():
    """total = comp + dm + max(dd, queue) — the paper's Eqn 1."""
    ins = mk_instr()
    f = features_for(ins, Resource.PUD, mk_view(queue=500.0, dep=2000.0),
                     SPEC)
    assert f.total == pytest.approx(
        f.latency_comp + f.latency_dm + max(f.delay_dd, f.delay_queue))
    assert f.delay_dd == 2000.0
    assert f.delay_queue == 500.0


def test_dm_latency_program_cost_into_flash():
    """Moving data INTO flash pays the SLC program (§4.4)."""
    into = dm_latency_ns(Location.DRAM, Location.FLASH, PAGE, SPEC)
    outof = dm_latency_ns(Location.FLASH, Location.DRAM, PAGE, SPEC)
    assert into > outof
    assert into >= SPEC.flash.t_prog_ns


def test_dm_latency_zero_when_home():
    assert dm_latency_ns(Location.DRAM, Location.DRAM, PAGE, SPEC) == 0.0


def test_overhead_within_paper_bounds():
    """§4.5: average ~3.77us, worst ~33us."""
    ins = mk_instr()
    avg = decision_overhead_ns(ins, SPEC, has_pending_deps=True)
    assert 1_000 <= avg <= 5_000
    worst = decision_overhead_ns(
        ins, SPEC, l2p_lookup=lambda p: SPEC.l2p_lookup_flash_ns,
        has_pending_deps=True)
    assert worst <= 70_000
    assert worst >= SPEC.l2p_lookup_flash_ns


def test_conduit_is_argmin():
    pol = make_policy("conduit", SPEC)
    ins = mk_instr(op="and")
    view = mk_view(loc=Location.FLASH)
    d = pol.select(ins, view)
    feats = d.features
    best = min((r for r in feats if feats[r].supported),
               key=lambda r: feats[r].total)
    assert d.resource == best


def test_control_goes_to_isp():
    for name in ("conduit", "bw", "dm", "pud", "flash_cosmos"):
        pol = make_policy(name, SPEC)
        ins = VectorInstr(iid=0, op="scalar", vlen=PAGE, elem_bytes=1,
                          srcs=(0,), dst=1, vectorizable=False)
        assert pol.select(ins, mk_view()).resource == Resource.ISP


def test_dm_prefers_resident_resource():
    pol = make_policy("dm", SPEC)
    ins = mk_instr(op="and")
    assert pol.select(ins, mk_view(Location.FLASH)).resource == Resource.IFP
    assert pol.select(ins, mk_view(Location.DRAM)).resource in (
        Resource.PUD, Resource.ISP)


def test_bw_prefers_idle_queue():
    pol = make_policy("bw", SPEC)
    ins = mk_instr(op="add")
    busy_isp = SystemView(
        0.0, lambda r: 1e9 if r == Resource.IFP else 0.0,
        lambda i: 0.0, lambda p: Location.DRAM)
    assert pol.select(ins, busy_isp).resource != Resource.IFP


def test_static_policies_restrict_ops():
    fc = make_policy("flash_cosmos", SPEC)
    # mul unsupported by Flash-Cosmos -> ISP fallback
    assert fc.select(mk_instr(op="mul"), mk_view()).resource == Resource.ISP
    assert fc.select(mk_instr(op="and"),
                     mk_view(Location.FLASH)).resource == Resource.IFP
    ares = make_policy("ares_flash", SPEC)
    assert ares.select(mk_instr(op="mul"),
                       mk_view(Location.FLASH)).resource == Resource.IFP


def test_static_ifp_requires_flash_residency():
    fc = make_policy("flash_cosmos", SPEC)
    assert fc.select(mk_instr(op="and"),
                     mk_view(Location.DRAM)).resource == Resource.ISP


def test_host_policies():
    cpu = make_policy("cpu", SPEC)
    assert cpu.select(mk_instr(), mk_view()).resource == Resource.HOST_CPU
    gpu = make_policy("gpu", SPEC)
    assert gpu.select(mk_instr(), mk_view()).resource == Resource.HOST_GPU
    ctrl = VectorInstr(iid=0, op="scalar", vlen=8, elem_bytes=1, srcs=(0,),
                       dst=1, vectorizable=False)
    assert gpu.select(ctrl, mk_view()).resource == Resource.HOST_CPU


def test_latency_model_orderings():
    """Structural facts the paper relies on."""
    bitand = mk_instr(op="and")
    mul = mk_instr(op="mul")
    # PuD bitwise is far faster than PuD mul (bit-serial)
    assert compute_latency_ns(bitand, Resource.PUD, SPEC) * 10 < \
        compute_latency_ns(mul, Resource.PUD, SPEC)
    # IFP mul pays the controller<->chip staging the paper describes (§6.4)
    assert compute_latency_ns(mul, Resource.IFP, SPEC) > \
        compute_latency_ns(bitand, Resource.IFP, SPEC)
    # latched IFP ops skip the sense
    assert compute_latency_ns(bitand, Resource.IFP, SPEC,
                              operands_latched=True) < \
        SPEC.flash.t_read_ns


def test_supported_sets():
    gather = mk_instr(op="gather")
    assert supports(Resource.ISP, gather)
    assert not supports(Resource.PUD, gather)
    assert not supports(Resource.IFP, gather)
    pred = mk_instr(op="cmp")
    assert supports(Resource.PUD, pred)
    # §7 extensibility: IFP gained predication via match lines (search) and
    # bit-serial latch compares — now supported, priced by the cost model
    assert supports(Resource.IFP, pred)
