"""End-to-end training integration: loss decreases; crash+restart resumes
bitwise-deterministically; serve driver produces tokens."""
import numpy as np
import pytest

from repro.launch.elastic import SimulatedFailure
from repro.launch.serve import serve
from repro.launch.train import train


@pytest.mark.slow
def test_loss_decreases():
    res = train("tinyllama-1.1b", steps=40, batch=8, seq=32,
                ckpt_dir=None, reduced=True, base_lr=3e-3, log_every=100)
    assert res["final_loss"] < res["first_loss"] * 0.8


@pytest.mark.slow
def test_restart_is_deterministic(tmp_path):
    """train 30 straight vs train 30 with a crash at 25 + resume: the
    checkpointed stream replays identically."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    full = train("xlstm-125m", steps=30, batch=4, seq=16, ckpt_dir=d1,
                 ckpt_every=10, reduced=True, log_every=100)
    with pytest.raises(SimulatedFailure):
        train("xlstm-125m", steps=30, batch=4, seq=16, ckpt_dir=d2,
              ckpt_every=10, reduced=True, fail_at=25, log_every=100)
    resumed = train("xlstm-125m", steps=30, batch=4, seq=16, ckpt_dir=d2,
                    ckpt_every=10, reduced=True, log_every=100)
    assert resumed["final_loss"] == pytest.approx(full["final_loss"],
                                                  rel=1e-5)


@pytest.mark.slow
def test_serve_produces_tokens():
    res = serve("xlstm-125m", n_requests=4, batch=2, prompt_len=8,
                max_new=4, reduced=True)
    assert res["requests"] == 4
    assert res["tokens"] == 16
    assert res["tokens_per_s"] > 0
