"""Shared synthetic-trace builder for the simulator test modules.

Lives outside any test module so both the hypothesis property suite
(`test_sim_properties.py`, skipped when hypothesis is absent) and the
always-on fallback/event-engine suites can use it.
"""
from repro.core.isa import VectorInstr
from repro.core.mapping import PageTable
from repro.core.vectorize import Trace
from repro.hw.ssd_spec import DEFAULT_SSD

SPEC = DEFAULT_SSD
PAGE = SPEC.page_size
OPS = ["and", "or", "xor", "add", "sub", "mul", "cmp", "max", "copy"]


def synth_trace(op_ids, n_arrays=4, pages_per_array=2, name="synth",
                outputs=True):
    """Deterministic synthetic trace from a list of op indices.

    ``outputs=False`` emits no output pages — with an empty ``op_ids`` that
    yields a trace that books no resources at all (a pure-I/O baseline)."""
    pt = PageTable(SPEC)
    arrays = [pt.alloc_array(pages_per_array * PAGE, name=f"a{i}")
              for i in range(n_arrays)]
    flat = [p for a in arrays for p in a]
    instrs = []
    producer = {}
    for i, oi in enumerate(op_ids):
        op = OPS[oi % len(OPS)]
        s1 = flat[(oi * 7 + i) % len(flat)]
        s2 = flat[(oi * 13 + 3 * i) % len(flat)]
        dst = flat[(oi * 5 + 2 * i + 1) % len(flat)]
        deps = tuple(sorted({producer[s] for s in (s1, s2, dst)
                             if s in producer}))
        instrs.append(VectorInstr(iid=i, op=op, vlen=PAGE, elem_bytes=1,
                                  srcs=(s1, s2), dst=dst, deps=deps))
        producer[dst] = i
    return Trace(instrs=instrs, pages=pt,
                 input_pages={"in0": arrays[0]},
                 output_pages=[arrays[-1]] if outputs else [], name=name)
