"""Attribution-layer laws (:mod:`repro.sim.analysis`).

The acceptance properties:

(a) accounting identity — every session's blame components sum to its
    recorded wall time within 1e-6 relative tolerance (float telescoping
    is the only slack; the sweep is exact by construction), on serving,
    GC-heavy and fault-injected traces alike — property-tested over
    every session of every scenario, not spot-checked;
(b) round trip — every trace ``validate_trace`` accepts is analyzable
    (``build_report`` raises only on invalid traces), and the report
    survives JSON serialization;
(c) critical paths are causal — hops are time-ordered, phase breakdowns
    non-negative, dependency hops only where the op actually waited;
(d) diff refuses apples-to-oranges comparisons (hardware spec / policy /
    entry mismatches) loudly, and the CLI exit codes pin the CI gate:
    0 ok, 1 invalid-or-breach, 2 unreadable-or-refused;
(e) one percentile implementation — ``telemetry._p99`` is
    ``stats.percentile`` at p=99, pinned on empty/small windows;
(f) fault coverage — ``mid_recovery`` decisions render in ``explain()``
    and the breakdown stays sane with the error model armed.
"""
import io
import json
import types

import pytest

from repro.sim import (CatalogEntry, FaultConfig, FTLConfig,
                       FlightRecorder, HostIOStream, PoissonArrivals,
                       ServingConfig, SessionCatalog, TelemetryConfig,
                       build_report, critical_path, diff_reports,
                       pool_rankings, session_blame, simulate,
                       simulate_mix, simulate_serving, validate_trace)
from repro.core.isa import Resource
from repro.sim.analysis import (COMPONENTS, REPORT_SCHEMA, blame_story,
                                main as analysis_main)
from repro.sim.stats import percentile
from repro.sim.telemetry import _p99

from _synth import synth_trace

FULL = TelemetryConfig(spans=True, audit=True, interval_ns=20_000.0)

RAMP = list(range(40))
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4

#: serving-drive geometry that keeps every die's collector busy
GC_FTL = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.28,
                   prefill=0.9, gc_reserve_blocks=1)


def _serving_run(faults=None):
    catalog = SessionCatalog(
        [CatalogEntry("A", synth_trace(RAMP, name="A"), weight=3.0),
         CatalogEntry("B", synth_trace(MIXED, name="B"), weight=1.0)],
        seed=5)
    io = HostIOStream(rate_iops=60_000, read_fraction=0.7, n_requests=64,
                      zipf_theta=0.95,
                      n_logical_pages=GC_FTL.logical_pages())
    return simulate_serving(
        catalog,
        PoissonArrivals(rate_per_sec=6000, n_sessions=12, seed=9),
        "conduit",
        serving=ServingConfig(keep_session_results=False,
                              warmup_ns=1e5, cooldown_ns=1e5,
                              little_law_warn_tol=float("inf")),
        io_stream=io, ftl=GC_FTL, faults=faults, telemetry=FULL)


@pytest.fixture(scope="module")
def serving_trace():
    """Serving under GC: sessions, host I/O, GC spans, sampler on."""
    return _serving_run().telemetry.chrome_trace()


@pytest.fixture(scope="module")
def mix_trace():
    """Multi-tenant GC run without a session stream (pseudo-sessions)."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    io = HostIOStream(rate_iops=250_000, read_fraction=0.3,
                      n_requests=160, zipf_theta=0.95,
                      n_logical_pages=GC_FTL.logical_pages())
    m = simulate_mix([a, b], "conduit", io_stream=io, ftl=GC_FTL,
                     compute_solo=False, telemetry=FULL)
    return m.telemetry.chrome_trace()


@pytest.fixture(scope="module")
def faulted_result():
    """Serving with the recovery ladder climbing (examples recipe)."""
    return _serving_run(
        faults=FaultConfig(rber_base=1.2e-3, die_failures=((3, 2.0e5),)))


@pytest.fixture(scope="module")
def faulted_trace(faulted_result):
    return faulted_result.telemetry.chrome_trace()


# -- (a) the accounting identity, property-tested ------------------------------

@pytest.mark.parametrize("which", ["serving", "mix", "faulted"])
def test_blame_components_sum_to_session_latency(which, request):
    trace = request.getfixturevalue(f"{which}_trace")
    rows = session_blame(trace)
    assert rows, f"no analyzable sessions in the {which} trace"
    for r in rows:
        total = sum(r["components"].values())
        assert total == pytest.approx(r["latency_ns"], rel=1e-6), \
            (which, r["tenant"])
        for comp, v in r["components"].items():
            assert v >= -1e-9, (which, r["tenant"], comp)
        assert set(r["components"]) == set(COMPONENTS)
        # the per-pool split never exceeds the queue component
        assert sum(r["queue_by_pool_ns"].values()) \
            <= r["components"]["queue"] + 1e-6


def test_gc_interference_is_attributed(serving_trace):
    """Serving under constant GC: the gc component must show up — the
    walkthrough's 'the tail is gc-built' claim rests on it."""
    rows = session_blame(serving_trace)
    assert sum(r["components"]["gc"] for r in rows) > 0.0


def test_recovery_is_attributed_on_faulted_traces(faulted_trace):
    """The reliability process's ladder spans reach the blame sweep."""
    rel = [e for e in faulted_trace["traceEvents"]
           if e.get("ph") == "X" and e.get("pid") == 6]
    assert rel, "fault recipe produced no recovery spans"
    rows = session_blame(faulted_trace)
    assert all(r["components"]["recovery"] >= 0.0 for r in rows)


# -- (b) report round trip -----------------------------------------------------

@pytest.mark.parametrize("which", ["serving", "mix", "faulted"])
def test_every_valid_trace_is_analyzable(which, request):
    trace = request.getfixturevalue(f"{which}_trace")
    assert validate_trace(trace) == []
    rep = build_report(trace, git_sha="pinned")
    assert rep["schema"] == REPORT_SCHEMA
    assert rep["meta"]["git_sha"] == "pinned"
    assert rep["sessions"]["n"] > 0
    # survives JSON (the CLI writes it; diff reads it back)
    again = json.loads(json.dumps(rep))
    assert again["blame"]["share"] == rep["blame"]["share"]


def test_empty_recorder_yields_empty_report():
    """A trace with no spans (audit-only config) still analyzes."""
    res = simulate(synth_trace(MIXED), "conduit",
                   telemetry=TelemetryConfig(spans=False, audit=True))
    trace = res.telemetry.chrome_trace()
    assert validate_trace(trace) == []
    rep = build_report(trace, git_sha="x")
    assert rep["sessions"]["n"] == 0
    assert rep["critical_path"]["n_hops"] == 0
    assert rep["decisions"]["n"] > 0           # the audit stream is there


def test_build_report_rejects_invalid_traces(serving_trace):
    bad = json.loads(json.dumps(serving_trace))
    del bad["otherData"]["schema"]
    with pytest.raises(ValueError, match="invalid trace"):
        build_report(bad)


def test_report_names_the_gc_tail(serving_trace):
    rep = build_report(serving_trace, git_sha="x")
    story = blame_story(rep)
    assert "gc" in story
    p99 = rep["blame"]["p99_cohort"]
    assert 0 < p99["n"] <= rep["sessions"]["n"]
    assert rep["sessions"]["p99_ns"] == p99["threshold_ns"]


def test_pool_rankings_degrade_without_sampler():
    res = simulate(synth_trace(MIXED), "conduit",
                   telemetry=TelemetryConfig(spans=True, audit=False,
                                             interval_ns=0.0))
    assert pool_rankings(res.telemetry) == []


def test_pool_rankings_are_sorted_by_queue_depth(serving_trace):
    rows = pool_rankings(serving_trace)
    assert rows
    depths = [r["queue_depth_ns_tw"] for r in rows]
    assert depths == sorted(depths, reverse=True)
    for r in rows:
        assert r["util_mean"] >= 0.0 and r["util_at_p99"] >= 0.0


# -- (c) critical paths --------------------------------------------------------

@pytest.mark.parametrize("which", ["serving", "mix"])
def test_critical_path_is_causal(which, request):
    trace = request.getfixturevalue(f"{which}_trace")
    cp = critical_path(trace)
    assert cp["n_hops"] > 0
    iids = [h["iid"] for h in cp["hops"]]
    assert iids == sorted(iids)                # walked back, reported fwd
    for h in cp["hops"]:
        for ph in ("decide_ns", "dep_wait_ns", "dm_ns", "queue_ns",
                   "compute_ns"):
            assert h[ph] >= -1e-9, (h["iid"], ph)
        if h["dep_gated"]:
            assert h["dep_wait_ns"] > 0.0
    # the path's wall span covers at least its own hops' busy time
    busy = sum(h["compute_ns"] for h in cp["hops"])
    assert cp["latency_ns"] + 1e-6 >= busy


def test_critical_path_unknown_tenant_is_empty(serving_trace):
    cp = critical_path(serving_trace, tenant="s999:nope")
    assert cp["n_hops"] == 0 and cp["hops"] == []


# -- (d) diff + CLI exit codes -------------------------------------------------

@pytest.fixture()
def report_file(serving_trace, tmp_path):
    p = tmp_path / "trace.json"
    p.write_text(json.dumps(serving_trace))
    out = tmp_path / "report.json"
    assert analysis_main(["report", str(p), "--out", str(out)],
                         out=io.StringIO()) == 0
    return out


def test_self_diff_is_comparable_and_breach_free(report_file):
    buf = io.StringIO()
    code = analysis_main(["diff", str(report_file), str(report_file),
                          "--tol-rel", "0.01"], out=buf)
    assert code == 0, buf.getvalue()


def test_diff_accepts_raw_traces(serving_trace, tmp_path, report_file):
    p = tmp_path / "trace2.json"
    p.write_text(json.dumps(serving_trace))
    assert analysis_main(["diff", str(report_file), str(p),
                          "--tol-rel", "0.01"], out=io.StringIO()) == 0


def test_diff_refuses_apples_to_oranges(report_file, tmp_path):
    """Reproducibility metadata gates the comparison — a different
    policy (or spec hash, or entry point) is refused with exit 2."""
    other = json.loads(report_file.read_text())
    other["meta"]["policy"] = "bw"
    p = tmp_path / "other.json"
    p.write_text(json.dumps(other))
    buf = io.StringIO()
    assert analysis_main(["diff", str(report_file), str(p)], out=buf) == 2
    assert "meta.policy differs" in buf.getvalue()
    assert "refusing apples-to-oranges" in buf.getvalue()
    # --force downgrades the refusal and compares anyway
    assert analysis_main(["diff", str(report_file), str(p), "--force"],
                         out=io.StringIO()) == 0
    d = diff_reports(json.loads(report_file.read_text()), other)
    assert not d["comparable"] and d["refusals"]


def test_diff_breach_gates_with_exit_1(report_file, tmp_path):
    moved = json.loads(report_file.read_text())
    moved["sessions"]["p99_ns"] *= 1.5
    p = tmp_path / "moved.json"
    p.write_text(json.dumps(moved))
    buf = io.StringIO()
    assert analysis_main(["diff", str(report_file), str(p),
                          "--tol-rel", "0.1"], out=buf) == 1
    assert "BREACH" in buf.getvalue()
    # report-only mode (no --tol-rel) never gates
    assert analysis_main(["diff", str(report_file), str(p)],
                         out=io.StringIO()) == 0


def test_report_cli_exit_codes(tmp_path, serving_trace):
    assert analysis_main(["report", str(tmp_path / "missing.json")],
                         out=io.StringIO()) == 2
    bad = json.loads(json.dumps(serving_trace))
    del bad["otherData"]["schema"]
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(bad))
    assert analysis_main(["report", str(p)], out=io.StringIO()) == 1
    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    assert analysis_main(["diff", str(garbage), str(garbage)],
                         out=io.StringIO()) == 2


def test_serving_result_analysis_entry_point():
    res = _serving_run()
    rep = res.analysis(git_sha="x")
    assert rep["schema"] == REPORT_SCHEMA
    assert rep["sessions"]["n"] > 0
    bare = simulate_serving(
        SessionCatalog([CatalogEntry("A", synth_trace(RAMP, name="A"))]),
        PoissonArrivals(rate_per_sec=2000, n_sessions=4, seed=1),
        "conduit",
        serving=ServingConfig(little_law_warn_tol=float("inf")))
    with pytest.raises(ValueError, match="no flight recorder"):
        bare.analysis()


# -- (e) one percentile implementation -----------------------------------------

@pytest.mark.parametrize("window", [
    [], [5.0], [3.0, 1.0], [3.0, 1.0, 2.0], list(map(float, range(10))),
    [7.0] * 512,
])
def test_p99_is_stats_percentile(window):
    assert _p99(window) == percentile(list(window), 99.0)
    from collections import deque
    assert _p99(deque(window)) == percentile(list(window), 99.0)


def test_percentile_edge_behavior_is_pinned():
    assert percentile([], 99.0) == 0.0
    assert percentile([42.0], 99.0) == 42.0
    assert percentile([1.0, 2.0], 50.0) == 1.0
    with pytest.raises(ValueError, match="out of range"):
        percentile([1.0], 990.0)


# -- (f) audit + breakdown under active faults ---------------------------------

class _Feat:
    supported = True
    latency_comp = 1.0
    latency_dm = 2.0
    delay_dd = 0.0
    delay_queue = 3.0
    total = 6.0


def test_mid_recovery_decisions_render_in_explain():
    """A decision landing on a die whose recovery ladder is still busy
    carries mid_recovery=True and says so in explain()."""
    rec = FlightRecorder(TelemetryConfig(spans=False, audit=True))
    rec._faults = types.SimpleNamespace(recovery_until=[0.0, 5_000.0])
    instr = types.SimpleNamespace(iid=0, op="add", deps=())
    feats = {Resource.IFP: _Feat()}
    args = ("t0", "conduit", instr, Resource.IFP, feats,
            1_000.0, 1_100.0, 1_100.0, 1_200.0, 1_300.0, 2_000.0, 50.0)
    rec.on_dispatch(*args, unit=1)             # ladder drains at t=5000
    rec.on_dispatch(*args, unit=0)             # die 0 was never recovering
    mid, clear = rec.audit
    assert mid.mid_recovery and not clear.mid_recovery
    assert "landed mid-recovery" in mid.explain()
    assert "landed mid-recovery" not in clear.explain()


def test_faulted_run_audit_and_breakdown_stay_sane(faulted_result):
    rec = faulted_result.telemetry
    rows = rec.breakdown_rows()
    assert rows and sum(r["count"] for r in rows) > 0
    for r in rows:
        for field in ("decide_ns", "dm_ns", "queue_ns", "compute_ns",
                      "total_ns"):
            assert r[field] >= -1e-9, (r["op"], r["resource"], field)
    for a in rec.audit:
        text = a.explain()
        assert ("landed mid-recovery" in text) == a.mid_recovery
