"""Benchmark-runner laws: the process-parallel sweep runner is
deterministic (``--jobs 1`` == ``--jobs N``) and the perf bench produces
a well-formed trajectory artifact."""
import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))   # import the benchmarks package


def test_parallel_runner_is_deterministic(capsys):
    """Suite output (tables + CSV rows) is identical for 1 and 2 workers —
    including the open-loop serving curve + saturation suite."""
    from benchmarks.run import run_suites

    rows1, failed1 = run_suites(["mix", "serving", "gc_policies"],
                                smoke=True, jobs=1)
    out1 = capsys.readouterr().out
    rows2, failed2 = run_suites(["mix", "serving", "gc_policies"],
                                smoke=True, jobs=2)
    out2 = capsys.readouterr().out
    assert failed1 == failed2 == []
    assert rows1 == rows2
    assert out1 == out2
    assert any(r.startswith("mix/") for r in rows1)
    assert any(r.startswith("serving/") and "/saturation," in r
               for r in rows1)
    assert any(r.startswith("gcpolicy/wa/") for r in rows1)
    assert any(r.startswith("gcpolicy/saturation/") for r in rows1)


def test_runner_reports_unknown_suite():
    from benchmarks.run import run_suites

    rows, failed = run_suites(["nope"], jobs=1)
    assert failed == ["nope"]
    assert any(r.startswith("error/nope") for r in rows)


def test_perf_bench_writes_trajectory_artifact(tmp_path):
    from benchmarks import perf_bench

    path = tmp_path / "BENCH_sim_perf.json"
    rows = perf_bench.run_perf(smoke=True, repeats=1,
                               json_path=str(path), check=False)
    data = json.loads(path.read_text())
    assert data["schema"] == "sim-perf-trajectory/v1"
    assert data["current"]["mix_events_per_sec"] > 0
    assert data["current"]["gc_events_per_sec"] > 0
    assert data["current"]["serving_events_per_sec"] > 0
    assert any(r.startswith("simperf/mix/") for r in rows)
    assert any(r.startswith("simperf/serving/") for r in rows)


def test_committed_perf_artifact_records_speedup():
    """The committed BENCH_sim_perf.json is the perf-trajectory artifact:
    baseline (pre fast-path engine) + current + >=3x speedup on mix+gc."""
    data = json.loads((REPO_ROOT / "BENCH_sim_perf.json").read_text())
    assert data["schema"] == "sim-perf-trajectory/v1"
    if data.get("harness", {}).get("smoke"):
        pytest.skip("artifact was locally rewritten by a --smoke probe; "
                    "the committed version is a full run")
    for key in ("mix_events_per_sec", "gc_events_per_sec"):
        assert data["baseline"][key] > 0
        assert data["current"][key] > 0
        assert data["speedup"][key] >= 3.0
    # the serving suite (PR 4) is tracked from its introduction: current
    # only — it has no pre-fast-path baseline to speed up against
    assert data["current"]["serving_events_per_sec"] > 0
