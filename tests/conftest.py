import os

# Smoke tests and benches must see 1 CPU device (the dry-run sets its own
# flag before any import) — never force the 512-device fake platform here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
