"""End-to-end behaviour tests for the paper's system (tiny scale, fast).

Full-pipeline: JAX function -> compile-time vectorization -> runtime
offloading simulation -> paper-structure assertions.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vectorize
from repro.core.isa import Resource
from repro.sim import SimConfig, simulate
from repro.workloads import (PAPER_ORDER, WORKLOADS, get_trace, run_numeric,
                             sim_config_for)


@pytest.fixture(scope="module")
def tiny_traces():
    return {wl: get_trace(wl, "tiny") for wl in PAPER_ORDER}


def test_all_workloads_trace_and_simulate(tiny_traces):
    for wl, tr in tiny_traces.items():
        assert len(tr.instrs) > 10, wl
        r = simulate(tr, "conduit", config=sim_config_for(wl, tr))
        assert r.makespan_ns > 0
        assert sum(r.resource_counts.values()) == len(tr.instrs)


def test_workloads_run_numerically():
    """The traced programs are real JAX programs with finite outputs."""
    for wl in ("aes", "xor_filter", "heat3d", "jacobi1d"):
        out = run_numeric(wl, "tiny")
        for leaf in jax.tree_util.tree_leaves(out):
            assert np.isfinite(np.asarray(leaf, np.float64)).all(), wl


@pytest.mark.slow
def test_conduit_never_worst_realizable(tiny_traces):
    """Conduit must not be the worst realizable in-SSD policy on any
    workload (the paper's core robustness claim) — 7 policies x 6
    workloads, the module's heavy grid (nightly tier)."""
    for wl, tr in tiny_traces.items():
        cfg = sim_config_for(wl, tr)
        spans = {p: simulate(tr, p, config=cfg).makespan_ns
                 for p in ("isp", "pud", "flash_cosmos", "ares_flash",
                           "bw", "dm", "conduit")}
        worst = max(spans, key=spans.get)
        assert worst != "conduit", (wl, spans)


def test_ideal_is_fastest_in_ssd(tiny_traces):
    """Ideal (zero movement, no overhead) bounds the realizable policies."""
    for wl, tr in tiny_traces.items():
        cfg = sim_config_for(wl, tr)
        ideal = simulate(tr, "ideal", config=cfg).makespan_ns
        for p in ("bw", "dm", "conduit"):
            real = simulate(tr, p, config=cfg).makespan_ns
            assert ideal <= real * 1.001, (wl, p)


def test_memory_bound_workloads_avoid_isp(tiny_traces):
    """Fig 9: AES uses ISP sparingly (paper: 0.4%)."""
    tr = tiny_traces["aes"]
    r = simulate(tr, "conduit", config=sim_config_for("aes", tr))
    mix = r.decision_mix()
    assert mix.get(Resource.ISP, 0.0) < 0.15


def test_decision_overhead_only_for_dynamic_policies(tiny_traces):
    tr = tiny_traces["jacobi1d"]
    cfg = sim_config_for("jacobi1d", tr)
    dyn = simulate(tr, "conduit", config=cfg)
    stat = simulate(tr, "isp", config=cfg)
    assert dyn.avg_decision_overhead_ns > 1_000
    assert stat.avg_decision_overhead_ns < 1_000


def test_end_to_end_custom_function():
    """Programmer transparency: an arbitrary user function goes through the
    whole pipeline with zero annotations."""
    def user_fn(a, b, table):
        h = (a * 31 + b) ^ (a >> 2)
        picked = jnp.take(table, jnp.abs(h) % table.shape[0])
        return jnp.where(picked > a, picked - a, a - picked).sum()

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 1000, (4, 16384), dtype=np.int32))
    b = jnp.asarray(rng.integers(0, 1000, (4, 16384), dtype=np.int32))
    t = jnp.asarray(rng.integers(0, 1000, (16384,), dtype=np.int32))
    tr = vectorize(user_fn, a, b, t, name="user")
    st = tr.characterize()
    assert st.total_instrs > 5
    r = simulate(tr, "conduit")
    assert r.makespan_ns > 0
    assert len({d.resource for d in r.decisions}) >= 2, \
        "heterogeneous workload should use multiple resources"
