"""FTL + garbage-collection laws (fast tier).

The invariants the flash translation layer must uphold:

* mapping — every live logical page maps to exactly one physical page,
  and the reverse map agrees (L2P injectivity);
* conservation — the valid-page population equals the live mapping size
  before, during and after GC cycles;
* amplification — write amplification is >= 1 always, and exactly 1 with
  GC disabled (infinite over-provisioning);
* equivalence — an FTL with GC disabled is bit-identical to no FTL at
  all (the idealized-drive behavior the seed simulator had), and the
  default GC policy suite (greedy victims, no hot/cold, no suspend, no
  reserve) is bit-identical to the pre-policy collector;
* determinism — same-seed runs replay bit-identically, for every policy;
* interference — with Zipf write skew and low OP, GC produces WA > 1 and
  a measurable host-I/O p99 increase attributable to GC traffic;
* policy suite — cost-benefit beats greedy on write amplification under
  Zipf skew, hot/cold separation lowers WA, wear-aware victim selection
  flattens the erase-count histogram, GC suspend cuts the host p99
  during collection, and the block reserve keeps the collector's append
  point out of silent overflow growth.
"""
import dataclasses
import itertools

import pytest

from repro.hw.ssd_spec import DEFAULT_SSD, FlashSpec, SSDSpec
from repro.sim import (EventEngine, EventKind, Fabric, FTLConfig, FTLModel,
                       HostIOStream, drive_zipf_overwrites,
                       make_victim_policy, simulate_mix)
from repro.sim.ftl import _DieFTL
from repro.sim.tenancy import DEFAULT_IO_SEED, _die_of_lpn

from _synth import synth_trace

RAMP = list(range(40))
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4

SMALL = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.12,
                  prefill=0.9)
TOTAL_DIES = DEFAULT_SSD.flash.total_dies

#: scaled-down fabric for the GC-policy comparisons: 4 dies concentrate
#: per-die write pressure so thousands of GC cycles stay fast to simulate
TINY_SSD = SSDSpec(flash=FlashSpec(channels=2, dies_per_channel=2))

#: regime where the GC policies measurably differ (empirically calibrated:
#: deep per-die churn for thousands of GC cycles, and enough blocks per
#: die that the multi-stream append points — host, hot, cold, GC — don't
#: by themselves exhaust the over-provisioning slack; seed-robust, zero
#: overflow growth, so WA deltas are attributable to policy alone)
POLICY_CFG = FTLConfig(blocks_per_die=32, pages_per_block=8, op_ratio=0.28,
                       prefill=0.85, gc_reserve_blocks=1)


def make_model(cfg=SMALL, engine=None, spec=DEFAULT_SSD):
    engine = engine or EventEngine()
    fabric = Fabric(spec)
    model = FTLModel(cfg, spec, fabric, engine,
                     die_of=lambda lpn: _die_of_lpn(lpn, DEFAULT_IO_SEED,
                                                    spec.flash.total_dies))
    return model, engine, fabric


def write(model, engine, lpn):
    die = model.die_of(lpn)
    model.host_write(lpn, die)
    model.maybe_start_gc(die)
    engine.run()


_DRIVE_CACHE = {}


def drive_zipf(cfg, n_writes=6000, theta=0.99, seed=7):
    """Memoized :func:`repro.sim.ftl.drive_zipf_overwrites` on TINY_SSD —
    runs are pure functions of the arguments, so the policy comparisons
    reuse one greedy baseline instead of re-simulating it (invariants
    are checked inside the shared driver)."""
    key = (cfg, n_writes, theta, seed)
    hit = _DRIVE_CACHE.get(key)
    if hit is None:
        hit = drive_zipf_overwrites(cfg, TINY_SSD, n_writes, theta, seed)
        _DRIVE_CACHE[key] = hit
    return hit


def gc_io(cfg, n_requests=256):
    """Write-heavy Zipf stream sized to the config's logical space."""
    return HostIOStream(rate_iops=400_000, read_fraction=0.25,
                        n_requests=n_requests, zipf_theta=0.95,
                        n_logical_pages=cfg.logical_pages())


# -- mapping + conservation invariants ----------------------------------------

def test_l2p_injective_and_conserved_after_prefill():
    model, _, _ = make_model()
    model.check_invariants()
    assert len(model.l2p) == int(0.9 * model.n_logical)


def test_l2p_injective_and_conserved_across_gc_cycles():
    """Drive enough skewed overwrites to force GC; the mapping stays
    injective and the valid-page count equals the live-LPN count."""
    model, engine, _ = make_model()
    live_before = len(model.l2p)
    for i, lpn in enumerate(itertools.islice(
            itertools.cycle(range(60)), 600)):
        write(model, engine, lpn)
        if i % 97 == 0:
            model.check_invariants()      # invariants hold mid-run too
    model.check_invariants()
    assert model.blocks_erased > 0, "GC never ran: test is vacuous"
    # overwrites of already-live LPNs change no live count; the first 60
    # writes may add mappings for LPNs the prefill did not cover
    assert len(model.l2p) >= live_before
    total_valid = sum(d.valid_count[b] for d in model.dies
                      for b in range(len(d.state)))
    assert total_valid == len(model.l2p)


def test_gc_cycle_frees_a_block_and_counts_wear():
    model, engine, _ = make_model()
    for lpn in itertools.islice(itertools.cycle(range(30)), 400):
        write(model, engine, lpn)
    assert model.blocks_erased > 0
    assert sum(model.stats().erase_counts) == model.blocks_erased
    assert model.stats().max_erase_count >= 1
    assert model.gc_invocations > 0


def test_write_amplification_bounds():
    """WA >= 1 with GC on; WA == 1 exactly with GC off."""
    on, eng_on, _ = make_model()
    off, eng_off, _ = make_model(dataclasses.replace(SMALL,
                                                     gc_enabled=False))
    for lpn in itertools.islice(itertools.cycle(range(30)), 400):
        write(on, eng_on, lpn)
        write(off, eng_off, lpn)
    assert on.stats().write_amplification >= 1.0
    assert on.stats().write_amplification > 1.0   # skew forced copies
    assert off.stats().write_amplification == 1.0
    assert off.blocks_erased == 0 and off.gc_invocations == 0


def test_read_die_follows_the_mapping():
    model, engine, _ = make_model()
    lpn = 7
    write(model, engine, lpn)
    die = model.die_of(lpn)
    assert model.read_die(lpn, default=999) == die   # die-local GC: stable
    assert model.read_die(10**9, default=42) == 42   # never-written LPN


# -- equivalence + determinism (acceptance criteria) ---------------------------

def test_gc_disabled_is_bit_identical_to_no_ftl():
    """The pre-FTL idealized drive is the gc_enabled=False special case."""
    cfg = dataclasses.replace(SMALL, gc_enabled=False)
    io = gc_io(cfg, n_requests=128)
    mk = lambda: [synth_trace(RAMP, name="A"), synth_trace(MIXED, name="B")]
    base = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False)
    ftl = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                       ftl=cfg)
    assert ftl.makespan_ns == base.makespan_ns
    assert ftl.host_io.latencies_ns == base.host_io.latencies_ns
    assert ftl.fabric_busy_ns == base.fabric_busy_ns
    for a, b in zip(base.tenants, ftl.tenants):
        assert a.makespan_ns == b.makespan_ns
        assert a.total_energy_nj == b.total_energy_nj
        assert a.resource_counts == b.resource_counts
    assert base.ftl is None and ftl.ftl is not None
    assert ftl.ftl.write_amplification == 1.0


def test_same_seed_runs_are_bit_identical():
    io = gc_io(SMALL)
    runs = []
    for _ in range(2):
        mk = [synth_trace(RAMP, name="A"), synth_trace(MIXED, name="B")]
        runs.append(simulate_mix(mk, "conduit", io_stream=io,
                                 compute_solo=False, ftl=SMALL))
    r1, r2 = runs
    assert r1.makespan_ns == r2.makespan_ns
    assert r1.host_io.latencies_ns == r2.host_io.latencies_ns
    assert r1.ftl.write_amplification == r2.ftl.write_amplification
    assert r1.ftl.blocks_erased == r2.ftl.blocks_erased
    assert r1.ftl.erase_counts == r2.ftl.erase_counts
    assert r1.ftl.host_during_gc_ns == r2.ftl.host_during_gc_ns


def test_gc_inflates_wa_and_host_tail_latency():
    """Acceptance: Zipf write skew + low OP => WA > 1 and a host-I/O p99
    increase attributable to GC (identical streams + placement, GC the
    only difference)."""
    io = gc_io(SMALL)
    mk = lambda: [synth_trace(RAMP, name="A")]
    off = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                       ftl=dataclasses.replace(SMALL, gc_enabled=False))
    on = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                      ftl=SMALL)
    assert on.ftl.write_amplification > 1.0
    assert on.ftl.gc_invocations > 0
    assert on.host_io.p(99) > off.host_io.p(99)
    assert on.host_io.mean_ns > off.host_io.mean_ns
    # requests issued while a collector was active carry the tail
    assert on.ftl.host_during_gc_ns
    assert on.ftl.p_during_gc(99) >= off.host_io.p(99)


def test_gc_traffic_shows_up_in_fabric_busy_time():
    """GC page reads/programs/erases occupy the shared die pool, so die
    busy time strictly exceeds the GC-off run's."""
    io = gc_io(SMALL)
    mk = lambda: [synth_trace(RAMP, name="A")]
    off = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                       ftl=dataclasses.replace(SMALL, gc_enabled=False))
    on = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                      ftl=SMALL)
    assert on.fabric_busy_ns["ifp_die"] > off.fabric_busy_ns["ifp_die"]
    assert on.fabric_busy_ns["flash_chan"] > off.fabric_busy_ns["flash_chan"]


def test_gc_events_appear_in_the_timeline():
    eng = EventEngine(record=True)
    io = gc_io(SMALL)
    simulate_mix([synth_trace(RAMP, name="A")], "conduit", io_stream=io,
                 compute_solo=False, ftl=SMALL, engine=eng)
    kinds = {k for _, k in eng.log}
    assert EventKind.GC in kinds
    times = [t for t, _ in eng.log]
    assert all(b >= a for a, b in zip(times, times[1:]))


def test_saturated_die_overflows_instead_of_deadlocking():
    """A footprint GC cannot compact (all victims fully valid) must not
    hang: allocation overflow-grows and is visible in the stats."""
    cfg = FTLConfig(blocks_per_die=2, pages_per_block=4, op_ratio=0.02,
                    prefill=0.98)
    model, engine, _ = make_model(cfg)
    for lpn in itertools.islice(itertools.cycle(range(4)), 200):
        write(model, engine, lpn)
    model.check_invariants()
    stats = model.stats()
    assert stats.host_pages_written == 200
    assert stats.overflow_blocks > 0


def test_ftl_summary_is_json_friendly():
    io = gc_io(SMALL, n_requests=96)
    mix = simulate_mix([synth_trace(RAMP, name="A")], "conduit",
                       io_stream=io, compute_solo=False, ftl=SMALL)
    s = mix.summary()
    assert "write_amp" in s and s["write_amp"] >= 1.0
    assert "gc_invocations" in s
    assert s["victim_policy"] == "greedy"
    import json
    json.dumps(s)


# -- GC policy suite: victim selection -----------------------------------------

def test_victim_policy_registry_and_validation():
    for name in ("greedy", "cost_benefit", "wear_aware"):
        assert make_victim_policy(name, wear_alpha=4.0).name == name
    with pytest.raises(ValueError):
        make_victim_policy("lru", wear_alpha=4.0)
    with pytest.raises(ValueError):
        FTLConfig(victim_policy="nope")
    with pytest.raises(ValueError):
        FTLConfig(gc_reserve_blocks=-1)
    with pytest.raises(ValueError):
        FTLConfig(blocks_per_die=4, gc_reserve_blocks=4)


def test_default_policy_suite_is_bit_identical_to_legacy_collector():
    """greedy + no hot/cold + no suspend + no reserve must reproduce the
    pre-policy collector exactly (the golden digests assert the same law
    against the committed pre-PR engine)."""
    io = gc_io(SMALL)
    mk = lambda: [synth_trace(RAMP, name="A")]
    legacy = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                          ftl=SMALL)
    explicit = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                            ftl=dataclasses.replace(
                                SMALL, victim_policy="greedy", hot_cold=False,
                                gc_suspend=False, gc_reserve_blocks=0))
    assert legacy.makespan_ns == explicit.makespan_ns
    assert legacy.host_io.latencies_ns == explicit.host_io.latencies_ns
    assert legacy.ftl.erase_counts == explicit.ftl.erase_counts
    assert legacy.ftl.gc_pages_copied == explicit.ftl.gc_pages_copied


@pytest.mark.parametrize("vp", ["greedy", "cost_benefit", "wear_aware"])
@pytest.mark.parametrize("hc", [False, True])
def test_policy_invariants_hold_under_churn(vp, hc):
    """Mapping injectivity + conservation survive every victim policy and
    the hot/cold append-point split (drive_zipf checks invariants)."""
    cfg = dataclasses.replace(POLICY_CFG, victim_policy=vp, hot_cold=hc)
    s = drive_zipf(cfg, n_writes=2500)
    assert s.blocks_erased > 0, "GC never ran: test is vacuous"
    assert s.write_amplification >= 1.0
    assert s.victim_policy == vp and s.hot_cold == hc
    if hc:
        assert s.hot_pages_written > 0 and s.cold_pages_written > 0
        assert (s.hot_pages_written + s.cold_pages_written
                == s.host_pages_written)


def test_cost_benefit_beats_greedy_on_wa_under_zipf():
    """The acceptance law: the cost-benefit *cleaner* — age-weighted
    victim scoring plus its age-sorting rewrite side (hot survivors
    rejoin the hot stream instead of re-polluting cold compaction
    blocks) — cuts write amplification vs. greedy under Zipf skew.
    The margin is moderate but seed-robust and artifact-free: the run
    must not overflow-grow, or extra silently-granted over-provisioning
    (not the policy) would explain the delta."""
    greedy = drive_zipf(POLICY_CFG)
    cb = drive_zipf(dataclasses.replace(POLICY_CFG,
                                        victim_policy="cost_benefit"))
    assert greedy.blocks_erased > 50          # real GC pressure
    assert greedy.overflow_blocks == cb.overflow_blocks == 0
    assert cb.write_amplification < greedy.write_amplification


def test_hot_cold_separation_lowers_wa_under_zipf():
    """Two host append points keyed on LBA heat make hot pages die
    together: victims are near-empty, so WA drops."""
    mixed = drive_zipf(POLICY_CFG)
    split = drive_zipf(dataclasses.replace(POLICY_CFG, hot_cold=True))
    assert split.write_amplification < mixed.write_amplification


def test_wear_aware_flattens_erase_counts():
    """Erase-count-penalized victim choice rotates reclamation, driving
    the wear histogram toward flatness (higher mean/max) and a lower
    peak erase count than greedy's hot-block cycling."""
    greedy = drive_zipf(POLICY_CFG)
    wear = drive_zipf(dataclasses.replace(POLICY_CFG,
                                          victim_policy="wear_aware"))
    assert wear.wear_flatness > greedy.wear_flatness
    assert wear.max_erase_count <= greedy.max_erase_count
    assert wear.blocks_erased > 0


def test_policy_runs_are_deterministic():
    cfg = dataclasses.replace(POLICY_CFG, victim_policy="cost_benefit",
                              hot_cold=True)
    a = drive_zipf(cfg, n_writes=1500)
    b = drive_zipf(cfg, n_writes=1500)
    assert a.write_amplification == b.write_amplification
    assert a.erase_counts == b.erase_counts
    assert a.blocks_erased == b.blocks_erased


# -- GC policy suite: suspend/throttle -----------------------------------------

def test_gc_suspend_cuts_host_tail_latency_during_gc():
    """Per-page-copy events yield the die/channel pools between copies
    and back off while the host queue is deep, so host requests stop
    FIFO-blocking behind whole victim cycles."""
    io = gc_io(SMALL)
    mk = lambda: [synth_trace(RAMP, name="A")]
    # reserve held constant: the observed delta is suspend-only
    reserved = dataclasses.replace(SMALL, gc_reserve_blocks=1)
    mono = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                        ftl=reserved)
    susp = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                        ftl=dataclasses.replace(reserved, gc_suspend=True))
    assert susp.ftl.gc_suspend and not mono.ftl.gc_suspend
    assert susp.ftl.gc_suspensions > 0        # the throttle actually fired
    assert susp.host_io.p(99) < mono.host_io.p(99)
    assert susp.ftl.p_during_gc(99) < mono.ftl.p_during_gc(99)
    # the collector still reclaims: conservation + forward progress
    assert susp.ftl.blocks_erased > 0
    assert susp.ftl.write_amplification >= 1.0


def test_gc_suspend_invariants_and_determinism():
    cfg = dataclasses.replace(POLICY_CFG, gc_suspend=True)
    a = drive_zipf(cfg, n_writes=1500)       # drive_zipf checks invariants
    b = drive_zipf(cfg, n_writes=1500)
    assert a.blocks_erased == b.blocks_erased > 0
    assert a.erase_counts == b.erase_counts


def test_suspended_collector_skips_pages_invalidated_mid_cycle():
    """A victim page overwritten by the host while the collector was
    between copies must not be copied (its copy would be pure WA) — the
    suspend path re-checks validity at each copy event, so its copy count
    never exceeds the monolithic collector's for the same stream."""
    io = gc_io(SMALL)
    mk = lambda: [synth_trace(RAMP, name="A")]
    mono = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                        ftl=SMALL)
    susp = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                        ftl=dataclasses.replace(SMALL, gc_suspend=True))
    assert susp.ftl.gc_pages_copied <= mono.ftl.gc_pages_copied


# -- GC policy suite: block reserve --------------------------------------------

def test_reserve_protects_gc_append_point_from_host_pressure():
    """With a reserve, a host append-point open never drains the last
    free block mid-collection — it overflow-grows instead, and the GC
    append point gets the reserved block without growing."""
    d = _DieFTL(blocks=4, pages_per_block=4)
    d.reserve = 1
    d.gc_running = True
    # host fills blocks until only the reserved one is left
    lpn = 0
    while len(d.free) > 1:
        d.alloc(lpn, _DieFTL.HOST)
        lpn += 1
    grown_before = d.grown_blocks
    # next host open must grow, not steal the reserve
    for _ in range(d.ppb):                   # spend the current append point
        d.alloc(lpn, _DieFTL.HOST)
        lpn += 1
    assert d.grown_blocks == grown_before + 1
    assert len(d.free) == 1                  # the reserve is intact
    # ... and the collector claims it without growth — via either of its
    # streams (cold compaction or hot-survivor routing)
    d.alloc(10_000, _DieFTL.GC, gc=True)
    assert d.gc_grown_blocks == 0
    assert len(d.free) == 0
    # a collector-side hot-survivor allocation is also reserve-eligible:
    # it must never be starved into host-side growth mid-collection
    d2 = _DieFTL(blocks=4, pages_per_block=4)
    d2.reserve = 1
    d2.gc_running = True
    lpn = 0
    while len(d2.free) > 1:
        d2.alloc(lpn, _DieFTL.HOST)
        lpn += 1
    d2.alloc(20_000, _DieFTL.HOST_HOT, gc=True)
    assert d2.gc_grown_blocks == 0 and len(d2.free) == 0


@pytest.mark.parametrize("vp", ["greedy", "cost_benefit", "wear_aware"])
def test_reserved_run_never_overflow_grows_with_gc_on(vp):
    """The satellite's law on a sanely-provisioned drive, for *every*
    victim policy: with the reserve enabled, overflow growth happens
    only with gc_enabled=False (the collector always keeps up; nothing
    silently inflates OP).  Non-greedy policies must never declare a die
    saturated while reclaimable blocks exist — a policy preferring a
    fully-valid block would put the collector to sleep spuriously and
    overflow-grow, inflating effective OP and confounding the WA
    comparisons."""
    on = drive_zipf(dataclasses.replace(POLICY_CFG, victim_policy=vp),
                    n_writes=2500)
    assert on.overflow_blocks == 0
    assert on.gc_overflow_blocks == 0
    assert on.blocks_erased > 0
    off = drive_zipf(dataclasses.replace(POLICY_CFG, gc_enabled=False,
                                         gc_reserve_blocks=0),
                     n_writes=2500)
    assert off.overflow_blocks > 0           # infinite-OP fallback grows
    assert off.write_amplification == 1.0


def test_score_policies_never_pick_fully_valid_over_reclaimable():
    """The VictimPolicy contract, directly: with one fully-valid old
    block and one sparse young block, cost-benefit and wear-aware must
    pick the reclaimable one (greedy does by construction)."""
    for vp in ("cost_benefit", "wear_aware"):
        d = _DieFTL(blocks=4, pages_per_block=4)
        for lpn in range(4):
            d.alloc(lpn, _DieFTL.HOST)       # block 0: fully valid, oldest
        for lpn in range(4, 8):
            d.alloc(lpn, _DieFTL.HOST)       # block 1: young...
        d.invalidate(1, 0)                   # ...but reclaimable
        pol = make_victim_policy(vp, wear_alpha=4.0)
        assert pol.select(d) == 1


def test_suspend_knob_validation():
    """qd 0 is always-suspended and zero backoff re-queues at a frozen
    timestamp: both would livelock the throttled collector."""
    with pytest.raises(ValueError, match="gc_suspend_qd"):
        FTLConfig(gc_suspend_qd=0)
    with pytest.raises(ValueError, match="gc_backoff_ns"):
        FTLConfig(gc_backoff_ns=0.0)
    bad_spec = dataclasses.replace(
        DEFAULT_SSD, ftl=dataclasses.replace(DEFAULT_SSD.ftl,
                                             gc_suspend_qd=0))
    with pytest.raises(ValueError, match="livelock"):
        make_model(FTLConfig(gc_suspend=True), spec=bad_spec)


def test_hot_threshold_validation():
    """threshold 1 means every write is hot — no split, and the prefill
    append point would be stranded; rejected loudly."""
    with pytest.raises(ValueError, match="hot_threshold"):
        FTLConfig(hot_threshold=1)
    with pytest.raises(ValueError, match="hot_threshold"):
        make_model(FTLConfig(hot_cold=True),
                   spec=dataclasses.replace(
                       DEFAULT_SSD,
                       ftl=dataclasses.replace(DEFAULT_SSD.ftl,
                                               hot_threshold=1)))


def test_free_list_is_o1_and_order_preserving():
    """The deque free list pops in exactly the old list.pop(0) FIFO
    order (erased blocks re-enter at the tail)."""
    d = _DieFTL(blocks=3, pages_per_block=2)
    assert list(d.free) == [0, 1, 2]
    b0 = d.alloc(0, _DieFTL.HOST)[0]
    assert b0 == 0 and list(d.free) == [1, 2]
    d.alloc(1, _DieFTL.HOST)                 # fills block 0 -> USED
    d.invalidate(0, 0)
    d.invalidate(0, 1)
    d.erase(0)
    assert list(d.free) == [1, 2, 0]         # re-enters at the tail
