"""FTL + garbage-collection laws (fast tier).

The invariants the flash translation layer must uphold:

* mapping — every live logical page maps to exactly one physical page,
  and the reverse map agrees (L2P injectivity);
* conservation — the valid-page population equals the live mapping size
  before, during and after GC cycles;
* amplification — write amplification is >= 1 always, and exactly 1 with
  GC disabled (infinite over-provisioning);
* equivalence — an FTL with GC disabled is bit-identical to no FTL at
  all (the idealized-drive behavior the seed simulator had);
* determinism — same-seed runs replay bit-identically;
* interference — with Zipf write skew and low OP, GC produces WA > 1 and
  a measurable host-I/O p99 increase attributable to GC traffic.
"""
import dataclasses
import itertools

import pytest

from repro.hw.ssd_spec import DEFAULT_SSD
from repro.sim import (EventEngine, EventKind, Fabric, FTLConfig, FTLModel,
                       HostIOStream, simulate_mix)
from repro.sim.tenancy import DEFAULT_IO_SEED, _die_of_lpn

from _synth import synth_trace

RAMP = list(range(40))
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4

SMALL = FTLConfig(blocks_per_die=4, pages_per_block=8, op_ratio=0.12,
                  prefill=0.9)
TOTAL_DIES = DEFAULT_SSD.flash.total_dies


def make_model(cfg=SMALL, engine=None):
    engine = engine or EventEngine()
    fabric = Fabric(DEFAULT_SSD)
    model = FTLModel(cfg, DEFAULT_SSD, fabric, engine,
                     die_of=lambda lpn: _die_of_lpn(lpn, DEFAULT_IO_SEED,
                                                    TOTAL_DIES))
    return model, engine, fabric


def write(model, engine, lpn):
    die = model.die_of(lpn)
    model.host_write(lpn, die)
    model.maybe_start_gc(die)
    engine.run()


def gc_io(cfg, n_requests=256):
    """Write-heavy Zipf stream sized to the config's logical space."""
    return HostIOStream(rate_iops=400_000, read_fraction=0.25,
                        n_requests=n_requests, zipf_theta=0.95,
                        n_logical_pages=cfg.logical_pages())


# -- mapping + conservation invariants ----------------------------------------

def test_l2p_injective_and_conserved_after_prefill():
    model, _, _ = make_model()
    model.check_invariants()
    assert len(model.l2p) == int(0.9 * model.n_logical)


def test_l2p_injective_and_conserved_across_gc_cycles():
    """Drive enough skewed overwrites to force GC; the mapping stays
    injective and the valid-page count equals the live-LPN count."""
    model, engine, _ = make_model()
    live_before = len(model.l2p)
    for i, lpn in enumerate(itertools.islice(
            itertools.cycle(range(60)), 600)):
        write(model, engine, lpn)
        if i % 97 == 0:
            model.check_invariants()      # invariants hold mid-run too
    model.check_invariants()
    assert model.blocks_erased > 0, "GC never ran: test is vacuous"
    # overwrites of already-live LPNs change no live count; the first 60
    # writes may add mappings for LPNs the prefill did not cover
    assert len(model.l2p) >= live_before
    total_valid = sum(d.valid_count[b] for d in model.dies
                      for b in range(len(d.state)))
    assert total_valid == len(model.l2p)


def test_gc_cycle_frees_a_block_and_counts_wear():
    model, engine, _ = make_model()
    for lpn in itertools.islice(itertools.cycle(range(30)), 400):
        write(model, engine, lpn)
    assert model.blocks_erased > 0
    assert sum(model.stats().erase_counts) == model.blocks_erased
    assert model.stats().max_erase_count >= 1
    assert model.gc_invocations > 0


def test_write_amplification_bounds():
    """WA >= 1 with GC on; WA == 1 exactly with GC off."""
    on, eng_on, _ = make_model()
    off, eng_off, _ = make_model(dataclasses.replace(SMALL,
                                                     gc_enabled=False))
    for lpn in itertools.islice(itertools.cycle(range(30)), 400):
        write(on, eng_on, lpn)
        write(off, eng_off, lpn)
    assert on.stats().write_amplification >= 1.0
    assert on.stats().write_amplification > 1.0   # skew forced copies
    assert off.stats().write_amplification == 1.0
    assert off.blocks_erased == 0 and off.gc_invocations == 0


def test_read_die_follows_the_mapping():
    model, engine, _ = make_model()
    lpn = 7
    write(model, engine, lpn)
    die = model.die_of(lpn)
    assert model.read_die(lpn, default=999) == die   # die-local GC: stable
    assert model.read_die(10**9, default=42) == 42   # never-written LPN


# -- equivalence + determinism (acceptance criteria) ---------------------------

def test_gc_disabled_is_bit_identical_to_no_ftl():
    """The pre-FTL idealized drive is the gc_enabled=False special case."""
    cfg = dataclasses.replace(SMALL, gc_enabled=False)
    io = gc_io(cfg, n_requests=128)
    mk = lambda: [synth_trace(RAMP, name="A"), synth_trace(MIXED, name="B")]
    base = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False)
    ftl = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                       ftl=cfg)
    assert ftl.makespan_ns == base.makespan_ns
    assert ftl.host_io.latencies_ns == base.host_io.latencies_ns
    assert ftl.fabric_busy_ns == base.fabric_busy_ns
    for a, b in zip(base.tenants, ftl.tenants):
        assert a.makespan_ns == b.makespan_ns
        assert a.total_energy_nj == b.total_energy_nj
        assert a.resource_counts == b.resource_counts
    assert base.ftl is None and ftl.ftl is not None
    assert ftl.ftl.write_amplification == 1.0


def test_same_seed_runs_are_bit_identical():
    io = gc_io(SMALL)
    runs = []
    for _ in range(2):
        mk = [synth_trace(RAMP, name="A"), synth_trace(MIXED, name="B")]
        runs.append(simulate_mix(mk, "conduit", io_stream=io,
                                 compute_solo=False, ftl=SMALL))
    r1, r2 = runs
    assert r1.makespan_ns == r2.makespan_ns
    assert r1.host_io.latencies_ns == r2.host_io.latencies_ns
    assert r1.ftl.write_amplification == r2.ftl.write_amplification
    assert r1.ftl.blocks_erased == r2.ftl.blocks_erased
    assert r1.ftl.erase_counts == r2.ftl.erase_counts
    assert r1.ftl.host_during_gc_ns == r2.ftl.host_during_gc_ns


def test_gc_inflates_wa_and_host_tail_latency():
    """Acceptance: Zipf write skew + low OP => WA > 1 and a host-I/O p99
    increase attributable to GC (identical streams + placement, GC the
    only difference)."""
    io = gc_io(SMALL)
    mk = lambda: [synth_trace(RAMP, name="A")]
    off = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                       ftl=dataclasses.replace(SMALL, gc_enabled=False))
    on = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                      ftl=SMALL)
    assert on.ftl.write_amplification > 1.0
    assert on.ftl.gc_invocations > 0
    assert on.host_io.p(99) > off.host_io.p(99)
    assert on.host_io.mean_ns > off.host_io.mean_ns
    # requests issued while a collector was active carry the tail
    assert on.ftl.host_during_gc_ns
    assert on.ftl.p_during_gc(99) >= off.host_io.p(99)


def test_gc_traffic_shows_up_in_fabric_busy_time():
    """GC page reads/programs/erases occupy the shared die pool, so die
    busy time strictly exceeds the GC-off run's."""
    io = gc_io(SMALL)
    mk = lambda: [synth_trace(RAMP, name="A")]
    off = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                       ftl=dataclasses.replace(SMALL, gc_enabled=False))
    on = simulate_mix(mk(), "conduit", io_stream=io, compute_solo=False,
                      ftl=SMALL)
    assert on.fabric_busy_ns["ifp_die"] > off.fabric_busy_ns["ifp_die"]
    assert on.fabric_busy_ns["flash_chan"] > off.fabric_busy_ns["flash_chan"]


def test_gc_events_appear_in_the_timeline():
    eng = EventEngine(record=True)
    io = gc_io(SMALL)
    simulate_mix([synth_trace(RAMP, name="A")], "conduit", io_stream=io,
                 compute_solo=False, ftl=SMALL, engine=eng)
    kinds = {k for _, k in eng.log}
    assert EventKind.GC in kinds
    times = [t for t, _ in eng.log]
    assert all(b >= a for a, b in zip(times, times[1:]))


def test_saturated_die_overflows_instead_of_deadlocking():
    """A footprint GC cannot compact (all victims fully valid) must not
    hang: allocation overflow-grows and is visible in the stats."""
    cfg = FTLConfig(blocks_per_die=2, pages_per_block=4, op_ratio=0.02,
                    prefill=0.98)
    model, engine, _ = make_model(cfg)
    for lpn in itertools.islice(itertools.cycle(range(4)), 200):
        write(model, engine, lpn)
    model.check_invariants()
    stats = model.stats()
    assert stats.host_pages_written == 200
    assert stats.overflow_blocks > 0


def test_ftl_summary_is_json_friendly():
    io = gc_io(SMALL, n_requests=96)
    mix = simulate_mix([synth_trace(RAMP, name="A")], "conduit",
                       io_stream=io, compute_solo=False, ftl=SMALL)
    s = mix.summary()
    assert "write_amp" in s and s["write_amp"] >= 1.0
    assert "gc_invocations" in s
    import json
    json.dumps(s)
