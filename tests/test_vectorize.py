"""Compile-time vectorizer: page alignment, strip-mining, SSA deps,
liveness compaction, characterization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vectorize
from repro.core.isa import OpClass
from repro.hw.ssd_spec import DEFAULT_SSD

LANES = DEFAULT_SSD.page_size  # 16 KiB pages / INT8 lanes


def test_page_aligned_vlen():
    def f(a, b):
        return a + b
    a = jnp.ones((2 * LANES,), jnp.int32)
    tr = vectorize(f, a, a)
    adds = [i for i in tr.instrs if i.op == "add"]
    assert len(adds) == 2
    assert all(i.vlen == LANES for i in adds)
    assert all(i.nbytes == DEFAULT_SSD.page_size for i in adds)


def test_strip_mining_tail():
    """Partial vectorization: the tail instruction gets a shorter vlen."""
    def f(a, b):
        return a * b
    n = LANES + 1000
    a = jnp.ones((n,), jnp.int32)
    tr = vectorize(f, a, a)
    muls = [i for i in tr.instrs if i.op == "mul"]
    assert len(muls) == 2
    assert muls[0].vlen == LANES
    assert muls[1].vlen == 1000


def test_ssa_deps_ordering():
    def f(a):
        b = a + a
        c = b * b
        return c - a
    a = jnp.ones((LANES,), jnp.int32)
    tr = vectorize(f, a)
    for ins in tr.instrs:
        for d in ins.deps:
            assert d < ins.iid, "producer must precede consumer"
    # the mul must depend on the add, the sub on the mul
    ops = {i.op: i for i in tr.instrs}
    assert ops["add"].iid in ops["mul"].deps
    assert ops["mul"].iid in ops["sub"].deps


def test_control_fallback_for_while():
    def f(x):
        def cond(c):
            return c[0] < 3

        def body(c):
            return c[0] + 1, c[1] * 2
        return jax.lax.while_loop(cond, body, (0, x))[1]
    x = jnp.ones((LANES,), jnp.int32)
    tr = vectorize(f, x)
    assert any(not i.vectorizable for i in tr.instrs)
    ctrl = [i for i in tr.instrs if not i.vectorizable]
    assert all(i.op_class is OpClass.CONTROL for i in ctrl)


def test_compaction_recycles_pages():
    """A long chain of elementwise ops must not allocate O(chain) pages."""
    def f(a):
        for _ in range(50):
            a = a + 1
        return a
    a = jnp.ones((4 * LANES,), jnp.int32)
    tr = vectorize(f, a)
    # 4 input + 4 output + small recycled pool << 50*4
    assert len(tr.pages) < 30


def test_outputs_preserved_by_compaction():
    def f(a, b):
        return a + b, a * b
    a = jnp.ones((LANES,), jnp.int32)
    tr = vectorize(f, a, a)
    for pl in tr.output_pages:
        assert pl, "every output must keep pages after compaction"
    all_pids = set(tr.pages.entries)
    for pl in tr.output_pages:
        assert set(pl) <= all_pids


def test_matmul_decomposition_mix():
    def f(a, b):
        return a @ b
    a = jnp.ones((64, 256), jnp.float32)
    b = jnp.ones((256, 128), jnp.float32)
    tr = vectorize(f, a, b)
    ops = {i.op for i in tr.instrs}
    assert "mul" in ops and "add" in ops
    st = tr.characterize()
    assert abs(st.band_mix["high"] - st.band_mix["medium"]) < 0.2


def test_characterization_bands():
    def f(a, b):
        c = a & b          # low
        d = a + b          # medium
        e = a * b          # high
        return c, d, e
    a = jnp.ones((LANES,), jnp.int32)
    st = vectorize(f, a, a).characterize()
    assert 0.2 < st.band_mix["low"] < 0.5
    assert 0.2 < st.band_mix["medium"] < 0.5
    assert 0.2 < st.band_mix["high"] < 0.5


def test_trace_budget_guard():
    def f(a, b):
        return a @ b
    a = jnp.ones((64, 64), jnp.float32)
    with pytest.raises(vectorize.__globals__["TraceBudgetExceeded"]
                       if False else Exception):
        vectorize(f, a, a, max_instrs=3)


def test_slice_aliases_pages():
    """Vectorized offset loads read source pages in place (no copies)."""
    def f(a):
        return a[:-LANES] + a[LANES:]
    a = jnp.ones((4 * LANES,), jnp.int32)
    tr = vectorize(f, a)
    assert not any(i.op == "copy" for i in tr.instrs)
    in_pages = set(tr.input_pages["in0"])
    adds = [i for i in tr.instrs if i.op == "add"]
    for ins in adds:
        assert set(ins.srcs) <= in_pages
