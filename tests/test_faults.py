"""Fault injection & error recovery (:mod:`repro.sim.faults`).

The subsystem's acceptance properties:

(a) invariance — the all-off default ``FaultConfig()`` is treated
    exactly like ``faults=None``: every golden digest suite reproduces
    bit-identically with the subsystem wired but disabled;
(b) determinism — a seeded fault run replays bit-identically (same
    digests, same FaultStats) across repeated invocations;
(c) accounting — recovery-ladder work is booked into the recorded op
    latency exactly (retry re-senses, soft decodes, parity rebuilds),
    and the ladder counters balance (every hard fail recovers at some
    rung or is counted uncorrectable; every uncorrectable rebuilds or
    surfaces as a failed op — nothing is silently dropped);
(d) conservation — retirement relocates every surviving page (FTL and
    fault counters agree), a drained reserve degrades the die to
    read-only where every write fails loudly, and the host-I/O latency
    population plus failed ops equals the offered ops;
(e) robustness — serving windows where every session times out stay
    analyzable: states are explicit, availability is 0, and the
    saturation bisection reports unsustainable instead of raising.

Plus loud validation for every config surface the subsystem touches.
"""
import dataclasses
import math

import pytest

from repro.hw.ssd_spec import DEFAULT_SSD, ReliabilitySpec
from repro.sim import (CatalogEntry, FaultConfig, FTLConfig, HostIOStream,
                       ServingConfig, SessionCatalog, SessionState,
                       TraceReplayArrivals, find_saturation, simulate,
                       simulate_mix, simulate_serving)

import _golden
from _synth import synth_trace
from test_golden_equivalence import GOLDEN

pytestmark = pytest.mark.filterwarnings("ignore:little_law_ratio")

REL = DEFAULT_SSD.reliability
FLASH = DEFAULT_SSD.flash
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4

#: RBER right at the hard-decode limit: every checked read enters the
#: ladder (p_fail == 1) but recovers within it (retry/soft rungs shrink
#: the effective RBER well below the limit)
LADDER_RBER = REL.ecc_hard_rber
#: RBER so far past the limit that every rung fails too: every checked
#: read is uncorrectable (rebuild with parity, a failed op without)
UNCORRECTABLE_RBER = 0.05


def io_catalog():
    return SessionCatalog([CatalogEntry("A", synth_trace([2, 4, 6] * 3,
                                                         name="A"))])


# -- (a) faults-off invariance -------------------------------------------------

def test_all_off_config_is_bit_identical_to_no_faults():
    """The acceptance law: FaultConfig() (inactive) threaded through
    every golden scenario reproduces the pinned digests exactly —
    wiring the subsystem in cost nothing when it is off."""
    cfg = FaultConfig()
    assert not cfg.active
    assert _golden.all_digests(faults=cfg) == GOLDEN


# -- (b) determinism -----------------------------------------------------------

def _faulty_gc_mix(faults):
    a = synth_trace(MIXED, name="A")
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, prefill=0.9,
                    op_ratio=0.28, gc_reserve_blocks=1)
    io = HostIOStream(rate_iops=250_000, read_fraction=0.5, n_requests=160,
                      zipf_theta=0.95, n_logical_pages=ftl.logical_pages())
    return simulate_mix([a], "conduit", io_stream=io, ftl=ftl,
                        compute_solo=False, faults=faults)


def test_same_seed_fault_run_is_deterministic():
    cfg = FaultConfig(rber_base=5e-4, rber_per_pe=2e-4, rber_retention=1e-4,
                      retire_after=1)
    m1 = _faulty_gc_mix(cfg)
    m2 = _faulty_gc_mix(cfg)
    assert _golden.digest_mix(m1) == _golden.digest_mix(m2)
    assert m1.faults == m2.faults
    assert m1.faults.n_reads_checked > 0


def test_different_seed_changes_the_error_pattern():
    base = FaultConfig(rber_base=8e-4)
    m1 = _faulty_gc_mix(base)
    m2 = _faulty_gc_mix(dataclasses.replace(base, seed=base.seed + 1))
    assert m1.faults.n_reads_checked == m2.faults.n_reads_checked
    assert m1.faults.n_hard_fails != m2.faults.n_hard_fails


# -- (c) ladder accounting -----------------------------------------------------

def _single_read_latency(faults):
    """One host read, empty compute trace, empty fabric: the recorded
    latency is exactly the booked path (no queueing anywhere)."""
    io = HostIOStream(rate_iops=10_000, read_fraction=1.0, n_requests=1,
                      seed=11)
    m = simulate_mix([synth_trace([], outputs=False)], "conduit",
                     io_stream=io, compute_solo=False, faults=faults)
    return m


def test_ladder_work_sums_into_the_recorded_latency():
    """The booked recovery time is additive and exact: faulted latency
    == clean latency + every ladder stage the counters say ran."""
    clean = _single_read_latency(None)
    fm = FaultConfig(rber_base=LADDER_RBER)
    faulty = _single_read_latency(fm)
    st = faulty.faults
    assert st.n_reads_checked == 1 and st.n_hard_fails == 1
    assert st.recovered == 1 and st.n_failed_reads == 0
    xfer = FLASH.t_dma_ns + DEFAULT_SSD.page_size * FLASH.channel_ns_per_byte
    added = 0.0
    for k in range(st.n_retry_reads):          # re-senses, escalating
        added += FLASH.t_read_ns + REL.read_retry_ns * (k + 1) + xfer
    added += st.n_soft_decodes * REL.soft_decode_ns
    if st.n_rebuilds:                          # parallel sibling senses
        added += (FLASH.t_read_ns + xfer
                  + REL.rebuild_xor_ns_per_page * st.n_rebuild_reads)
    assert added > 0.0
    got = faulty.host_io.latencies_ns[0]
    want = clean.host_io.latencies_ns[0] + added
    assert got == pytest.approx(want)


def test_ladder_counters_balance():
    """Every hard fail recovers at some rung or is uncorrectable; every
    uncorrectable rebuilds or surfaces as a failed read."""
    m = _faulty_gc_mix(FaultConfig(rber_base=2e-3, retire_after=2))
    st = m.faults
    assert st.n_hard_fails > 0
    assert st.n_hard_fails == (st.n_retry_recovered + st.n_soft_recovered
                               + st.n_uncorrectable)
    assert st.n_uncorrectable == st.n_rebuilds + st.n_failed_reads


def test_uncorrectable_without_parity_is_a_failed_op_not_a_hang():
    m = _single_read_latency(FaultConfig(rber_base=UNCORRECTABLE_RBER,
                                         parity=False))
    st = m.faults
    assert st.n_failed_reads == 1 and st.n_rebuilds == 0
    assert m.host_io.n_failed == 1
    assert m.host_io.latencies_ns == []        # excluded, not poisoned
    # conservation: offered == measured latencies + failed
    assert (len(m.host_io.latencies_ns) + m.host_io.n_failed
            == m.host_io.n_reads + m.host_io.n_writes)


def test_operand_sense_failure_surfaces_on_the_sim_result():
    """A tenant whose flash operand senses are unrecoverable finishes
    with failed=True — the error status reaches the compute result.
    The host policy stages every operand through the explicit read
    path, so each sense rolls the error model; true in-array IFP
    compute never issues a discrete sense and is out of scope."""
    cfg = FaultConfig(rber_base=UNCORRECTABLE_RBER, parity=False)
    r = simulate(synth_trace(MIXED), "cpu", faults=cfg)
    assert r.failed
    assert r.faults.n_failed_reads > 0
    clean = simulate(synth_trace(MIXED), "cpu")
    assert not clean.failed and clean.faults is None


# -- (d) retirement / read-only conservation -----------------------------------

def test_retirement_relocates_every_survivor_and_counters_agree():
    cfg = FaultConfig(rber_base=UNCORRECTABLE_RBER, retire_after=1)
    m = _faulty_gc_mix(cfg)
    st = m.faults
    assert st.n_blocks_retired > 0
    assert m.ftl.blocks_retired == st.n_blocks_retired
    assert m.ftl.pages_relocated == st.n_pages_relocated
    # parity on, no dead dies: every uncorrectable read was rebuilt
    assert st.n_rebuilds > 0 and st.n_failed_reads == 0


def test_reserve_exhaustion_degrades_to_read_only_and_writes_fail_loudly():
    """retire_after=1 + a tiny drive: retirement drains the physical
    pool, dies go read-only, and every subsequent write is surfaced as
    a failed op (counted, never silently dropped)."""
    a = synth_trace([], outputs=False)
    ftl = FTLConfig(blocks_per_die=3, pages_per_block=4, prefill=0.9,
                    op_ratio=0.34, gc_enabled=False)
    io = HostIOStream(rate_iops=400_000, read_fraction=0.5, n_requests=400,
                      zipf_theta=0.9, n_logical_pages=ftl.logical_pages())
    m = simulate_mix([a], "conduit", io_stream=io, ftl=ftl,
                     compute_solo=False,
                     faults=FaultConfig(rber_base=UNCORRECTABLE_RBER,
                                        retire_after=1))
    st = m.faults
    assert st.n_read_only_dies > 0
    assert st.n_failed_writes > 0
    assert m.host_io.n_failed >= st.n_failed_writes
    assert (len(m.host_io.latencies_ns) + m.host_io.n_failed
            == m.host_io.n_reads + m.host_io.n_writes)


def test_whole_die_failure_rejects_writes_and_rebuilds_reads():
    a = synth_trace([], outputs=False)
    io = HostIOStream(rate_iops=100_000, read_fraction=0.5, n_requests=600,
                      seed=3)
    m = simulate_mix([a], "conduit", io_stream=io, compute_solo=False,
                     faults=FaultConfig(die_failures=((0, 0.0),)))
    st = m.faults
    assert st.n_dies_failed == 1
    assert st.n_failed_writes > 0              # writes to the dead die
    assert st.n_rebuilds > 0                   # its reads rebuilt via parity
    assert st.n_failed_reads == 0
    assert (len(m.host_io.latencies_ns) + m.host_io.n_failed
            == m.host_io.n_reads + m.host_io.n_writes)


def test_host_op_timeout_retries_then_fails_with_bounded_budget():
    """op_timeout_ns below the floor latency: every attempt times out,
    the op is retried exactly max_op_retries times, then failed."""
    cfg = FaultConfig(op_timeout_ns=1.0, max_op_retries=2,
                      op_retry_backoff_ns=10_000.0)
    assert cfg.active                          # timeout alone arms it
    m = _single_read_latency(cfg)
    st = m.faults
    assert st.n_op_retries == 2
    assert st.n_op_timeouts == 3               # initial + both retries
    assert st.n_failed_ops == 1
    assert m.host_io.n_failed == 1
    assert m.host_io.n_reads == 1              # retries don't double-count
    assert m.host_io.latencies_ns == []


# -- (e) serving-layer timeouts ------------------------------------------------

def test_window_where_every_session_times_out_stays_analyzable():
    """Regression for the completed-bool era: a 100%-timeout window used
    to leave dangling records; now every state is explicit and the
    result's conservation law still closes."""
    res = simulate_serving(
        io_catalog(), TraceReplayArrivals(times_ns=(0.0, 1.0, 2.0, 3.0)),
        "conduit", serving=ServingConfig(session_timeout_ns=10.0))
    assert res.n_offered == 4
    assert res.n_timed_out == 4
    assert res.n_completed == 0 and res.n_rejected == 0 and res.n_failed == 0
    assert res.availability == 0.0
    assert res.session_latencies_ns == []
    for s in res.sessions:
        assert s.state is SessionState.TIMED_OUT
        assert s.timed_out and not s.completed and not s.rejected
        with pytest.raises(ValueError, match="never completed"):
            s.latency_ns


def test_per_entry_timeout_overrides_the_serving_default():
    cat = SessionCatalog([CatalogEntry("A", synth_trace([2, 4, 6] * 3,
                                                        name="A"),
                                       timeout_ns=10.0)])
    res = simulate_serving(cat, TraceReplayArrivals(times_ns=(0.0,)),
                           "conduit", serving=ServingConfig())
    assert res.n_timed_out == 1


def test_completed_sessions_under_a_generous_timeout_are_unaffected():
    res = simulate_serving(
        io_catalog(), TraceReplayArrivals(times_ns=(0.0,)), "conduit",
        serving=ServingConfig(session_timeout_ns=1e15))
    base = simulate_serving(io_catalog(),
                            TraceReplayArrivals(times_ns=(0.0,)), "conduit")
    assert res.n_completed == 1 and res.availability == 1.0
    assert (res.sessions[0].latency_ns
            == pytest.approx(base.sessions[0].latency_ns))


def test_saturation_probe_reports_total_timeout_as_unsustainable():
    """find_saturation over an all-timeout window must bisect to 0, not
    raise on an empty latency population (the old NaN-p99 path)."""
    sat = find_saturation(io_catalog(), "conduit", slo_p99_ns=1e9,
                          rate_lo=10.0, rate_hi=100.0, iters=2,
                          n_sessions=8,
                          serving=ServingConfig(session_timeout_ns=10.0))
    assert sat.rate_per_sec == 0.0
    assert sat.probes and all(not p.sustainable for p in sat.probes)
    assert all(p.availability == 0.0 for p in sat.probes)
    assert all(math.isnan(p.p99_ns) for p in sat.probes)


def test_min_availability_gates_saturation_under_faults():
    """An error-free drive saturates somewhere; the same drive whose
    every op fails (no parity, hopeless RBER) has availability 0 and
    must bisect to 0 under any availability floor."""
    kw = dict(slo_p99_ns=1e9, rate_lo=5.0, rate_hi=50.0, iters=2,
              n_sessions=6)
    clean = find_saturation(io_catalog(), "conduit", **kw)
    assert clean.rate_per_sec > 0.0
    broken = find_saturation(
        io_catalog(), "conduit",
        faults=FaultConfig(rber_base=UNCORRECTABLE_RBER, parity=False),
        min_availability=0.99, **kw)
    assert broken.rate_per_sec == 0.0


# -- validation ----------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(rber_base=-0.1), dict(rber_base=1.0), dict(rber_per_pe=-1e-9),
    dict(rber_retention=2.0), dict(retention_scale_ns=0.0),
    dict(retire_after=0), dict(die_failures=((-1, 0.0),)),
    dict(die_failures=((0, -5.0),)), dict(die_failures=((1.5, 0.0),)),
    dict(op_timeout_ns=0.0), dict(op_timeout_ns=-1.0),
    dict(max_op_retries=-1), dict(op_retry_backoff_ns=-1.0),
])
def test_fault_config_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        FaultConfig(**kw)


def test_die_failures_must_name_a_real_die():
    with pytest.raises(ValueError, match="die_failures"):
        simulate(synth_trace([2]), "conduit",
                 faults=FaultConfig(die_failures=((10_000, 0.0),)))


@pytest.mark.parametrize("kw", [
    dict(op_ratio=0.0), dict(op_ratio=-0.1),
    dict(gc_low_watermark=0.5, gc_high_watermark=0.4),
    dict(gc_low_watermark=-0.1), dict(gc_high_watermark=1.5),
    dict(hot_threshold=1), dict(wear_alpha=-1.0),
])
def test_ftl_spec_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        dataclasses.replace(DEFAULT_SSD.ftl, **kw)


@pytest.mark.parametrize("kw", [
    dict(ecc_hard_rber=0.0), dict(ecc_steepness=0.0),
    dict(read_retry_ns=-1.0), dict(max_read_retries=-1),
    dict(retry_rber_factor=0.0), dict(soft_decode_ns=-1.0),
    dict(soft_rber_factor=0.0), dict(ecc_engines=0),
    dict(rebuild_xor_ns_per_page=-1.0),
])
def test_reliability_spec_rejects_bad_knobs(kw):
    with pytest.raises(ValueError):
        ReliabilitySpec(**kw)


def test_faults_on_a_gc_ftl_without_reserve_blocks_is_rejected():
    """Retirement shrinks the physical pool; a GC'd drive with no
    reserve would wedge on the first retired block — rejected loudly
    at wiring time, not discovered mid-run."""
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8,
                    gc_reserve_blocks=0)
    io = HostIOStream(rate_iops=100_000, n_requests=8,
                      n_logical_pages=ftl.logical_pages())
    with pytest.raises(ValueError, match="gc_reserve_blocks"):
        simulate_mix([synth_trace([], outputs=False)], "conduit",
                     io_stream=io, ftl=ftl, compute_solo=False,
                     faults=FaultConfig(rber_base=1e-4))


def test_serving_config_rejects_bad_session_timeout():
    with pytest.raises(ValueError, match="session_timeout_ns"):
        ServingConfig(session_timeout_ns=0.0)


def test_catalog_entry_rejects_bad_timeout():
    with pytest.raises(ValueError, match="timeout_ns"):
        CatalogEntry("A", synth_trace([2]), timeout_ns=-1.0)


def test_inactive_fault_model_construction_is_rejected():
    from repro.sim import EventEngine, Fabric, FaultModel
    eng = EventEngine()
    with pytest.raises(ValueError, match="active"):
        FaultModel(FaultConfig(), DEFAULT_SSD, Fabric(DEFAULT_SSD), eng)


# -- wear preconditioning (the substrate for wear-dependent errors) ----------

def _prewear_model(writes: int, key=None, **kw):
    from repro.sim import EventEngine, Fabric
    from repro.sim.ftl import FTLModel
    cfg = FTLConfig(blocks_per_die=4, pages_per_block=8, prefill=0.9,
                    op_ratio=0.28, gc_reserve_blocks=1,
                    prewear_writes=writes, **kw)
    return FTLModel(cfg, DEFAULT_SSD, Fabric(DEFAULT_SSD), EventEngine(),
                    lambda lpn: lpn % DEFAULT_SSD.flash.total_dies,
                    prefill_key=key)


def test_prewear_builds_a_policy_shaped_wear_histogram():
    worn = _prewear_model(4000)
    fresh = _prewear_model(0)
    assert max(max(d.erase_count) for d in worn.dies) > \
        max(max(d.erase_count) for d in fresh.dies) + 5
    worn.check_invariants()


def test_prewear_replays_bit_identically_and_cache_is_isolated():
    a = _prewear_model(2000, key=("t", 1))
    b = _prewear_model(2000, key=("t", 1))      # memoized path
    c = _prewear_model(2000, key=None)          # uncached path
    for m in (b, c):
        assert [d.erase_count for d in a.dies] == [d.erase_count for d in m.dies]
        assert a.l2p == m.l2p
    # the cache hands out clones: churning one model must not leak into
    # a sibling built from the same snapshot
    die = a.dies[0]
    before = list(b.dies[0].erase_count)
    for lpn, _ in list(a.l2p.items())[:64]:
        a.host_write(lpn, 0)
        a.maybe_start_gc(0)
        a.engine.run()
    assert b.dies[0].erase_count == before


def test_prewear_respects_the_victim_policy():
    greedy = _prewear_model(4000, key=None)
    aware = _prewear_model(4000, key=None, victim_policy="wear_aware")
    g = sorted(e for d in greedy.dies for e in d.erase_count)
    w = sorted(e for d in aware.dies for e in d.erase_count)
    assert g != w, "policies must shape the histogram differently"


@pytest.mark.parametrize("kw", [dict(prewear_writes=-1),
                                dict(prewear_theta=0.0),
                                dict(prewear_theta=-1.0)])
def test_prewear_knob_validation(kw):
    with pytest.raises(ValueError):
        FTLConfig(blocks_per_die=4, pages_per_block=8, **kw)
