"""Golden-equivalence scenarios + digests for the simulator fast path.

The perf work on the event engine (lazy-heap server pools, slab events,
cached cost features, hoisted dispatch structures) must keep results
**bit-identical**.  This module defines a fixed set of scenarios spanning
every hot path — single-trace simulate, multi-tenant mix with host I/O,
GC-enabled FTL, capacity pressure + fault replay — and a canonical digest
over the full result (every decision record, every host latency, every
FTL counter), so ``tests/test_golden_equivalence.py`` can assert the
optimized engine reproduces the pre-optimization outputs exactly.

Run ``PYTHONPATH=src:tests python tests/_golden.py`` to (re)print the
digest table — only ever regenerate it from a commit whose engine is
known-good.
"""
from __future__ import annotations

import hashlib
from typing import Dict

from repro.sim import (FTLConfig, HostIOStream, SimConfig, simulate,
                       simulate_mix)

from _synth import synth_trace

RAMP = list(range(40))
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4

#: policies covering the dynamic (conduit/bw/dm), static (isp/ares_flash),
#: contention-free (ideal) and host (cpu) select/dispatch paths
GOLDEN_POLICIES = ("conduit", "bw", "dm", "ideal", "ares_flash", "cpu")


def _f(x: float) -> str:
    """Exact float text (repr round-trips IEEE doubles bit-for-bit)."""
    return repr(float(x))


def digest_sim(r) -> str:
    parts = [r.policy, r.workload, r.tenant, _f(r.makespan_ns),
             str(r.n_instrs), _f(r.compute_energy_nj),
             _f(r.movement_energy_nj), _f(r.decision_overhead_ns_total),
             str(r.coherence_syncs), str(r.evictions), str(r.replays),
             str(r.colocations), _f(r.start_ns)]
    parts += [f"{res.value}={n}" for res, n in sorted(
        r.resource_counts.items(), key=lambda kv: kv[0].value)]
    parts += [f"{k}={_f(v)}" for k, v in sorted(r.resource_busy_ns.items())]
    for d in r.decisions:
        parts.append("|".join([str(d.iid), d.op, d.resource.value,
                               _f(d.t_decide), _f(d.t_start), _f(d.t_end),
                               _f(d.dm_ns), str(d.replayed)]))
    return hashlib.sha256(";".join(parts).encode()).hexdigest()[:16]


def digest_mix(m) -> str:
    parts = [_f(m.makespan_ns)]
    parts += [digest_sim(t) for t in m.tenants]
    parts += [f"{k}={_f(v)}" for k, v in sorted(m.fabric_busy_ns.items())]
    if m.host_io is not None:
        parts += [str(m.host_io.n_reads), str(m.host_io.n_writes)]
        parts += [_f(x) for x in m.host_io.latencies_ns]
    if m.ftl is not None:
        ftl = m.ftl
        parts += [str(ftl.gc_enabled), str(ftl.n_logical_pages),
                  str(ftl.n_physical_pages), str(ftl.host_pages_written),
                  str(ftl.gc_pages_copied), str(ftl.blocks_erased),
                  str(ftl.gc_invocations), str(ftl.overflow_blocks),
                  _f(ftl.gc_energy_nj)]
        parts += [str(c) for c in ftl.erase_counts]
        parts += [_f(x) for x in ftl.host_during_gc_ns]
    return hashlib.sha256(";".join(parts).encode()).hexdigest()[:16]


# -- scenarios -----------------------------------------------------------------

def scenario_single(policy: str, telemetry=None, faults=None) -> str:
    """simulate() on the synthetic mixed-op trace."""
    return digest_sim(simulate(synth_trace(MIXED), policy,
                               telemetry=telemetry, faults=faults))


def scenario_pressure(telemetry=None, faults=None) -> str:
    """Capacity pressure + transient faults: evictions, coherence syncs
    and the replay path all fire."""
    tr = synth_trace(MIXED, n_arrays=6, pages_per_array=4)
    cfg = SimConfig(dram_capacity_pages=32, host_capacity_pages=48,
                    fail_rate=0.05)
    return digest_sim(simulate(tr, "conduit", config=cfg,
                               telemetry=telemetry, faults=faults))


def scenario_mix(telemetry=None, faults=None) -> str:
    """Two tenants + host I/O on one shared fabric."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    io = HostIOStream(rate_iops=80_000, n_requests=64, seed=7,
                      queue_depth=16)
    return digest_mix(simulate_mix([a, b], "conduit", io_stream=io,
                                   compute_solo=False, telemetry=telemetry,
                                   faults=faults))


def scenario_gc(telemetry=None, faults=None) -> str:
    """GC-enabled FTL run: write-heavy Zipf host I/O on a preconditioned
    drive, collector contending on the shared die/channel pools."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    ftl = FTLConfig(blocks_per_die=4, pages_per_block=8, prefill=0.9,
                    op_ratio=0.28)
    io = HostIOStream(rate_iops=250_000, read_fraction=0.3, n_requests=160,
                      zipf_theta=0.95, n_logical_pages=ftl.logical_pages())
    return digest_mix(simulate_mix([a, b], "conduit", io_stream=io,
                                   ftl=ftl, compute_solo=False,
                                   telemetry=telemetry, faults=faults))


def all_digests(telemetry=None, faults=None) -> Dict[str, str]:
    out = {f"single/{p}": scenario_single(p, telemetry=telemetry,
                                          faults=faults)
           for p in GOLDEN_POLICIES}
    out["pressure_fault"] = scenario_pressure(telemetry=telemetry,
                                              faults=faults)
    out["mix_2tenant_io"] = scenario_mix(telemetry=telemetry, faults=faults)
    out["gc_ftl"] = scenario_gc(telemetry=telemetry, faults=faults)
    return out


if __name__ == "__main__":
    for name, dig in all_digests().items():
        print(f'    "{name}": "{dig}",')
