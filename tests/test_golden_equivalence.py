"""Golden-equivalence: the fast-path engine is bit-identical to the
pre-optimization engine.

The digests below were captured from the simulator BEFORE the perf work
(O(log k) server pools, slab events, cached cost features, hoisted
dispatch structures) landed — commit 18ca3a2 — over scenarios covering
every hot path: single-trace ``simulate`` under dynamic/static/ideal/host
policies, capacity pressure + fault replay, a two-tenant ``simulate_mix``
with host I/O, and a GC-enabled FTL run.  Each digest hashes the *full*
result — every decision record timestamp, every host-I/O latency, every
FTL counter — so any float-level divergence fails loudly.

Re-baselining procedure — a digest may ONLY move with an intended
*semantic* fix, never a perf change:

1. Reproduce the committed digest with the old semantics: recompute the
   digest substituting the pre-fix value of the field that changed
   (everything else from the NEW engine) and check it equals the old
   table entry bit-for-bit.  That proves the delta is confined to the
   intended fix.
2. Regenerate (``PYTHONPATH=src:tests python tests/_golden.py``), update
   the entry, and record the equivalence run in the commit message.

History: ``gc_ftl`` was re-baselined from ``11dba99233a79831`` when
Mix/Serving makespans learned to include the FTL's GC tail (collector
bookings that outlive the last tenant/host completion); substituting the
tail-free makespan into the new engine's digest reproduced the old entry
exactly — every other hashed field was bit-identical.
"""
import pytest

import _golden

GOLDEN = {
    "single/conduit": "6c8ea53f6dfaa662",
    "single/bw": "f6b07e682d92748b",
    "single/dm": "7652b53696544eb5",
    "single/ideal": "8211e712142e24d4",
    "single/ares_flash": "4563808e0a5c02d2",
    "single/cpu": "526355789be10689",
    "pressure_fault": "26c5e7184d8756f0",
    "mix_2tenant_io": "ca2380aa9083c8b9",
    "gc_ftl": "5cb8130621b6a2fd",
}


@pytest.mark.parametrize("policy", _golden.GOLDEN_POLICIES)
def test_simulate_matches_pre_optimization_engine(policy):
    assert _golden.scenario_single(policy) == GOLDEN[f"single/{policy}"]


def test_pressure_and_fault_replay_match_pre_optimization_engine():
    assert _golden.scenario_pressure() == GOLDEN["pressure_fault"]


def test_mix_with_host_io_matches_pre_optimization_engine():
    assert _golden.scenario_mix() == GOLDEN["mix_2tenant_io"]


def test_gc_ftl_run_matches_pre_optimization_engine():
    assert _golden.scenario_gc() == GOLDEN["gc_ftl"]


def test_digests_stable_across_repeated_runs():
    """The digest itself is deterministic (same-process repeat)."""
    assert _golden.scenario_mix() == _golden.scenario_mix()
