"""Multi-tenant interference regressions.

The shared-SSD laws simulate_mix must uphold: contention can only hurt a
tenant relative to running solo, and background host I/O must show up as
extra busy time on the channels/dies it occupies (plus measurable host
tail latency).
"""
import pytest

from repro.core.policies import ALL_POLICIES
from repro.sim import HostIOStream, simulate_mix

from _synth import synth_trace

RAMP = list(range(40))
MIXED = [8, 0, 5, 5, 2, 7, 1, 4, 6, 3] * 4
SHORT = [2, 4, 6] * 5


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_every_policy_runs_concurrent_traces_with_io(policy):
    """Acceptance: >=2 concurrent traces + a host I/O stream under every
    policy in make_policy, with work conserved per tenant."""
    a = synth_trace(RAMP, name="A")
    b = synth_trace(SHORT, name="B")
    mix = simulate_mix([a, b], policy,
                       io_stream=HostIOStream(rate_iops=80_000,
                                              n_requests=24),
                       compute_solo=False)
    assert len(mix.tenants) == 2
    by = {r.tenant: r for r in mix.tenants}
    assert sum(by["t0:A"].resource_counts.values()) == len(RAMP)
    assert sum(by["t1:B"].resource_counts.values()) == len(SHORT)
    assert mix.host_io.n_requests == 24
    assert mix.makespan_ns > 0


def test_tenants_never_faster_than_solo():
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    c = synth_trace(SHORT, name="C")
    mix = simulate_mix([a, b, c], "conduit")
    assert len(mix.slowdowns) == 3
    for tenant, slowdown in mix.slowdowns.items():
        assert slowdown >= 1.0 - 1e-9, \
            f"{tenant} ran faster under contention ({slowdown:.3f}x)"
    assert 0.0 < mix.fairness <= 1.0 + 1e-12


def test_host_io_strictly_increases_channel_and_die_busy():
    mk = lambda: [synth_trace(RAMP, name="A"), synth_trace(MIXED, name="B")]
    quiet = simulate_mix(mk(), "conduit", compute_solo=False)
    loud = simulate_mix(mk(), "conduit", compute_solo=False,
                        io_stream=HostIOStream(rate_iops=100_000,
                                               n_requests=64))
    assert loud.fabric_busy_ns["flash_chan"] > quiet.fabric_busy_ns["flash_chan"]
    assert loud.fabric_busy_ns["ifp_die"] > quiet.fabric_busy_ns["ifp_die"]
    assert loud.host_io is not None and quiet.host_io is None
    assert loud.host_io.p(99) >= loud.host_io.p(50) > 0.0


def test_more_tenants_more_interference():
    """Adding a co-runner cannot speed up an existing tenant."""
    solo_pair = simulate_mix([synth_trace(RAMP, name="A"),
                              synth_trace(MIXED, name="B")], "conduit",
                             compute_solo=False)
    trio = simulate_mix([synth_trace(RAMP, name="A"),
                         synth_trace(MIXED, name="B"),
                         synth_trace(MIXED, name="C")], "conduit",
                        compute_solo=False)
    a2 = solo_pair.tenant("t0:A").makespan_ns
    a3 = trio.tenant("t0:A").makespan_ns
    assert a3 >= a2 - 1e-6


def test_duplicate_trace_objects_are_isolated():
    """Passing the same Trace object twice must not share page state."""
    tr = synth_trace(RAMP, name="A")
    mix = simulate_mix([tr, tr], "conduit", compute_solo=False)
    r0, r1 = mix.tenants
    assert sum(r0.resource_counts.values()) == len(RAMP)
    assert sum(r1.resource_counts.values()) == len(RAMP)
    # symmetric tenants on a symmetric fabric: same work issued
    assert r0.n_instrs == r1.n_instrs


def test_per_tenant_policies():
    a = synth_trace(RAMP, name="A")
    b = synth_trace(MIXED, name="B")
    mix = simulate_mix([a, b], ["conduit", "isp"], compute_solo=False)
    by = {r.tenant: r for r in mix.tenants}
    assert by["t0:A"].policy == "conduit"
    assert by["t1:B"].policy == "isp"


def test_io_only_latency_is_baseline_for_interference():
    """NDP traffic inflates host I/O latency vs. an idle SSD.

    The baseline tenant is an empty trace with no output pages (no
    instructions, nothing for the epilogue to flush), so the idle run's
    resource bookings are exactly the I/O stream's — the busy run is a
    superset and FIFO queues preserve request order, hence per-request
    latency can only grow."""
    io = HostIOStream(rate_iops=60_000, n_requests=96, seed=11)
    idle = simulate_mix([synth_trace([], name="empty", outputs=False)],
                        "conduit", io_stream=io, compute_solo=False)
    busy = simulate_mix([synth_trace(RAMP, name="A"),
                         synth_trace(MIXED, name="B")], "conduit",
                        io_stream=io, compute_solo=False)
    assert busy.host_io.mean_ns >= idle.host_io.mean_ns - 1e-6
    for fast, slow in zip(idle.host_io.latencies_ns, busy.host_io.latencies_ns):
        assert slow >= fast - 1e-6


def test_mix_rejects_empty_and_mismatched_inputs():
    with pytest.raises(ValueError):
        simulate_mix([], "conduit")
    with pytest.raises(ValueError):
        simulate_mix([synth_trace(SHORT)], ["conduit", "isp"])
    with pytest.raises(ValueError):
        simulate_mix([synth_trace(SHORT)], "conduit", start_ns=[0.0, 1.0])
    with pytest.raises(ValueError):
        simulate_mix([synth_trace(SHORT)], "conduit", start_ns=[-1.0])


# -- staggered tenant arrivals -------------------------------------------------

def test_start_ns_defers_a_tenant():
    """An offset tenant issues nothing before its arrival, and its
    slowdown compares elapsed time (not absolute makespan) to solo."""
    offset = 5e6
    mix = simulate_mix([synth_trace(RAMP, name="A"),
                        synth_trace(MIXED, name="B")], "conduit",
                       start_ns=[0.0, offset])
    rb = mix.tenant("t1:B")
    assert rb.start_ns == offset
    assert all(d.t_decide >= offset for d in rb.decisions)
    assert rb.elapsed_ns == rb.makespan_ns - offset
    assert mix.slowdowns["t1:B"] >= 1.0 - 1e-9


def test_zero_offsets_match_default_exactly():
    mk = lambda: [synth_trace(RAMP, name="A"), synth_trace(MIXED, name="B")]
    a = simulate_mix(mk(), "conduit", compute_solo=False)
    b = simulate_mix(mk(), "conduit", compute_solo=False,
                     start_ns=[0.0, 0.0])
    assert a.makespan_ns == b.makespan_ns
    assert a.fabric_busy_ns == b.fabric_busy_ns


def test_staggering_reduces_interference():
    """Pushing tenant B past tenant A's solo window cannot slow A down
    more than co-starting does."""
    mk = lambda: [synth_trace(RAMP, name="A"), synth_trace(MIXED, name="B")]
    co = simulate_mix(mk(), "conduit")
    apart = simulate_mix(mk(), "conduit",
                         start_ns=[0.0, 10 * co.makespan_ns])
    assert apart.tenant("t0:A").makespan_ns \
        <= co.tenant("t0:A").makespan_ns + 1e-6
    assert apart.slowdowns["t1:B"] <= co.slowdowns["t1:B"] + 1e-9


# -- host I/O realism: Zipf LBAs, bursts, NVMe queue depth ---------------------

def test_zipf_skew_concentrates_die_traffic():
    """Skewed LBAs hash to a hot set of dies: the busiest die absorbs
    strictly more traffic than under uniform addressing."""
    from repro.sim.servers import Fabric
    from repro.sim.tenancy import _HostIOModel
    from repro.hw.ssd_spec import DEFAULT_SSD
    from repro.sim import EventEngine

    def max_die_share(theta):
        io = HostIOStream(rate_iops=100_000, n_requests=256,
                          read_fraction=1.0, zipf_theta=theta,
                          n_logical_pages=4096)
        engine = EventEngine()
        fabric = Fabric(DEFAULT_SSD)
        model = _HostIOModel(io, fabric, DEFAULT_SSD, engine)
        hits = {}
        for _, _, _, die in model.plan:   # (arrival, lpn, is_read, die)
            hits[die] = hits.get(die, 0) + 1
        return max(hits.values()) / io.n_requests

    assert max_die_share(1.2) > max_die_share(0.0)


def test_burst_duty_preserves_mean_rate_and_creates_gaps():
    smooth = HostIOStream(n_requests=64).arrival_times_ns()
    bursty = HostIOStream(n_requests=64, burst_duty=0.25,
                          burst_len=8).arrival_times_ns()
    assert len(bursty) == 64
    assert all(b > a for a, b in zip(bursty, bursty[1:]))
    # same mean rate within the on+off accounting (span comparable)...
    assert bursty[-1] == pytest.approx(smooth[-1], rel=0.35)
    # ...but arrivals cluster: the largest silence is strictly longer
    gap = lambda ts: max(b - a for a, b in zip(ts, ts[1:]))
    assert gap(bursty) > gap(smooth)
    # duty=1 is bit-identical to the pre-burst arithmetic
    assert HostIOStream(n_requests=64, burst_duty=1.0).arrival_times_ns() \
        == smooth


def test_queue_depth_cap_defers_but_never_drops():
    mk = lambda: [synth_trace([], name="e", outputs=False)]
    free = simulate_mix(mk(), "conduit", compute_solo=False,
                        io_stream=HostIOStream(rate_iops=300_000,
                                               n_requests=96))
    capped = simulate_mix(mk(), "conduit", compute_solo=False,
                          io_stream=HostIOStream(rate_iops=300_000,
                                                 n_requests=96,
                                                 queue_depth=2))
    assert capped.host_io.n_requests == 96
    assert len(capped.host_io.latencies_ns) == 96
    # deferral only delays: per-request latency dominates the uncapped run
    for f, c in zip(free.host_io.latencies_ns, capped.host_io.latencies_ns):
        assert c >= f - 1e-6
    assert capped.host_io.mean_ns > free.host_io.mean_ns
