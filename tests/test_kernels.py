"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)

INT_SHAPES = [(8, 128), (16, 256), (8, 512), (24, 384), (64, 128)]
INT_DTYPES = [np.int32, np.int8]


def _rand(shape, dtype):
    if dtype == np.int8:
        return jnp.asarray(RNG.integers(-128, 128, size=shape, dtype=dtype))
    return jnp.asarray(RNG.integers(-2**30, 2**30, size=shape, dtype=dtype))


@pytest.mark.parametrize("n_ops", [2, 3, 7,
                                   pytest.param(48, marks=pytest.mark.slow)])
@pytest.mark.parametrize("op", ["and", "or", "xor", "nand", "nor"])
def test_mws_sweep(n_ops, op):
    stack = _rand((n_ops, 16, 256), np.int32)
    got = ops.mws_bitwise(stack, op)
    want = ref.ref_mws(stack, op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", INT_SHAPES)
@pytest.mark.parametrize("dtype", INT_DTYPES)
def test_bitserial_add_sweep(shape, dtype):
    a, b = _rand(shape, dtype), _rand(shape, dtype)
    np.testing.assert_array_equal(
        np.asarray(ops.bitserial_add(a, b)),
        np.asarray(ref.ref_bitserial_add(a, b)))


@pytest.mark.parametrize("shape", INT_SHAPES[:3])
@pytest.mark.parametrize("dtype", INT_DTYPES)
def test_bitserial_mul_sweep(shape, dtype):
    a, b = _rand(shape, dtype), _rand(shape, dtype)
    np.testing.assert_array_equal(
        np.asarray(ops.bitserial_mul(a, b)),
        np.asarray(ref.ref_bitserial_mul(a, b)))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("shape", INT_SHAPES[:3])
def test_shift_add_sweep(bits, shape):
    a, b = _rand(shape, np.int32), _rand(shape, np.int32)
    np.testing.assert_array_equal(
        np.asarray(ops.shift_add_mul(a, b, bits=bits)),
        np.asarray(ref.ref_shift_add_mul(a, b, bits)))


@pytest.mark.parametrize("m,k,n", [
    (32, 64, 32),
    pytest.param(128, 128, 128, marks=pytest.mark.slow),
    pytest.param(64, 96, 160, marks=pytest.mark.slow),
    (16, 32, 48)])
def test_int8_matmul_sweep(m, k, n):
    a = jnp.asarray(RNG.integers(-128, 128, size=(m, k), dtype=np.int8))
    b = jnp.asarray(RNG.integers(-128, 128, size=(k, n), dtype=np.int8))
    np.testing.assert_array_equal(
        np.asarray(ops.int8_matmul(a, b)),
        np.asarray(ref.ref_int8_matmul(a, b)))


@pytest.mark.parametrize("h,s,d", [(2, 64, 32), (1, 128, 64), (4, 32, 16)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [np.float32])
def test_attention_sweep(h, s, d, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(h, s, d)).astype(dtype))
    k = jnp.asarray(RNG.normal(size=(h, s, d)).astype(dtype))
    v = jnp.asarray(RNG.normal(size=(h, s, d)).astype(dtype))
    got = ops.flash_attention(q, k, v, causal=causal)
    want = ref.ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_attention_cross_lengths():
    q = jnp.asarray(RNG.normal(size=(2, 32, 32)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 128, 32)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 128, 32)).astype(np.float32))
    got = ops.flash_attention(q, k, v, causal=False)
    want = ref.ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("wpr", [1, 2, 4])
@pytest.mark.parametrize("rows", [8, 24])
def test_search_kernel_sweep(wpr, rows):
    """§7 extensibility: in-flash exact-match search vs oracle."""
    words = 32
    stack = _rand((rows, words), np.int32)
    # plant known matches
    stack = stack.at[3, 0:wpr].set(jnp.arange(wpr))
    query = jnp.arange(wpr, dtype=jnp.int32)
    got = ops.search_pages(stack, query)
    want = ref.ref_search(stack, query)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert bool(np.asarray(want)[3, 0])


def test_search_routes_to_ifp():
    """The new 'search' op is first-class: the cost function routes
    flash-resident searches to the in-flash match primitive."""
    from repro.core.cost import SystemView
    from repro.core.isa import Location, Resource, VectorInstr
    from repro.core.policies import make_policy
    from repro.hw.ssd_spec import DEFAULT_SSD
    pol = make_policy("conduit", DEFAULT_SSD)
    ins = VectorInstr(iid=0, op="search", vlen=DEFAULT_SSD.page_size,
                      elem_bytes=1, srcs=(0,), dst=1)
    view = SystemView(0.0, lambda r: 0.0, lambda i: 0.0,
                      lambda p: Location.FLASH)
    d = pol.select(ins, view)
    assert d.resource == Resource.IFP
    assert ins.native(Resource.IFP) == "ifp.mws_match"
