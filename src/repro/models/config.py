"""Architecture configuration schema covering all 10 assigned families.

One :class:`ArchConfig` describes any supported architecture: dense GQA
transformers (with optional qk-norm), MoE (standard top-k and DeepSeek-V2
style MLA + shared experts), Mamba2/attention hybrids, xLSTM stacks,
encoder-decoder (audio) and VLM backbones with M-RoPE.  ``reduced()``
returns the family-preserving small config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None      # default d_model // n_heads

    # normalization / attention details
    qk_norm: bool = False             # qwen3-style per-head q/k RMSNorm
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    mrope: bool = False               # qwen2-vl multimodal rotary (3D pos)

    # MoE
    moe: bool = False
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert FFN width (fine-grained)

    # MLA (deepseek-v2)
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64

    # hybrid / ssm
    block_pattern: Tuple[str, ...] = ()   # per-layer: attn|moe|mamba|mlstm|slstm
    ssm_state: int = 0
    ssm_expand: int = 2
    conv_kernel: int = 4
    shared_attn_every: int = 0        # zamba2: shared attn block cadence

    # encoder-decoder (seamless-m4t)
    enc_layers: int = 0               # 0 => decoder-only
    frontend: str = "none"            # none | audio_frames | vision_patches

    # training
    schedule: str = "cosine"          # wsd | cosine
    remat: bool = True
    dtype: str = "bfloat16"

    # provenance
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        kind = "moe" if self.moe else "attn"
        return tuple([kind] * self.n_layers)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k decode shape (SSM/hybrid/linear)."""
        return any(b in ("mamba", "mlstm", "slstm") for b in self.pattern)

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs decode (seamless is enc-dec)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, dh = self.d_model, self.head_dim
        n = self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        for blk in self.pattern:
            if blk in ("attn", "moe"):
                if self.mla:
                    n += d * (self.kv_lora_rank + self.rope_head_dim)
                    n += self.kv_lora_rank * self.n_heads * (dh + self.rope_head_dim)
                    if self.q_lora_rank:
                        n += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * dh
                    else:
                        n += d * self.n_heads * dh
                    n += self.n_heads * dh * d
                else:
                    n += d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh
                    n += self.n_heads * dh * d
                if blk == "moe":
                    ff = self.moe_d_ff or self.d_ff
                    n += self.n_experts * 3 * d * ff
                    n += self.n_shared_experts * 3 * d * ff
                    n += d * self.n_experts          # router
                else:
                    n += 3 * d * self.d_ff
            elif blk == "mamba":
                di = self.ssm_expand * d
                n += d * 2 * di + di * d + di * (2 * self.ssm_state + 2)
            elif blk in ("mlstm", "slstm"):
                n += 4 * d * d + 2 * d * self.d_ff if self.d_ff else 5 * d * d
        if self.enc_layers:
            # encoder blocks + cross-attention in decoder
            n += self.enc_layers * (4 * d * d + 3 * d * self.d_ff)
            n += self.n_layers * 4 * d * d
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        total = self.param_count()
        inactive = (self.n_experts - self.experts_per_tok) * 3 * d * ff
        inactive *= sum(1 for b in self.pattern if b == "moe")
        return total - inactive

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        def cut(v, lo, f=8):
            return max(lo, v // f)
        pat = self.pattern[: max(2, min(4, len(self.pattern)))]
        n_heads = max(2, self.n_heads // 8)
        n_kv = max(1, min(n_heads, self.n_kv_heads // 8 or 1))
        return dataclasses.replace(
            self,
            n_layers=len(pat),
            block_pattern=pat,
            d_model=max(64, self.d_model // 16),
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=max(16, self.head_dim // 4),
            d_ff=max(128, self.d_ff // 16) if self.d_ff else 0,
            vocab=512,
            n_experts=min(4, self.n_experts) if self.moe else 0,
            experts_per_tok=min(2, self.experts_per_tok) if self.moe else 0,
            n_shared_experts=min(1, self.n_shared_experts),
            moe_d_ff=max(64, self.moe_d_ff // 8) if self.moe_d_ff else 0,
            kv_lora_rank=64 if self.mla else 0,
            q_lora_rank=64 if (self.mla and self.q_lora_rank) else 0,
            rope_head_dim=16 if self.mla else 64,
            ssm_state=min(16, self.ssm_state) if self.ssm_state else 0,
            enc_layers=min(2, self.enc_layers),
            remat=False,
        )
