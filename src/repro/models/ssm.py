"""Sub-quadratic sequence blocks: Mamba2 (zamba2) and xLSTM (sLSTM/mLSTM).

These blocks carry O(1)-per-token recurrent state, which is what makes the
``long_500k`` decode shape feasible: one decode step updates the state in
place instead of attending over a 524k-token cache.

The implementations are compact but real: Mamba2's selective state-space
recurrence with input-dependent (Δ, B, C) and a short causal conv; xLSTM's
exponentially-gated scalar (sLSTM) and matrix (mLSTM) memories per head.
Sequence processing uses ``jax.lax.scan`` over time (TPU-friendly: the
per-step body is dense einsums on the VPU/MXU).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import Params, dense_init, rmsnorm, rmsnorm_init


# -- Mamba2 -------------------------------------------------------------------

def mamba_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": dense_init(ks[0], d, 2 * di, dtype),        # -> (u, z)
        "w_bc": dense_init(ks[1], d, 2 * n, dtype),         # -> (B, C)
        "w_dt": dense_init(ks[2], d, di, dtype, scale=0.01),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_kernel, di), jnp.float32)
                   * 0.1).astype(dtype),
        "a_log": jnp.zeros((di,), jnp.float32),             # A = -exp(a_log)
        "d_skip": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[4], di, d, dtype),
        "norm": rmsnorm_init(d, dtype),
    }


def _causal_conv(u, w, state: Optional[jnp.ndarray] = None):
    """u [B,S,di], w [K,di]; returns conv + final (K-1)-tap state."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    padded = jnp.concatenate([state, u], axis=1)
    out = sum(padded[:, i:i + u.shape[1], :] * w[i] for i in range(k))
    new_state = padded[:, -(k - 1):, :] if k > 1 else state
    return out, new_state


def mamba_apply(p: Params, cfg: ArchConfig, x,
                state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    """Selective SSM.  state = {"h" [B,di,N], "conv" [B,K-1,di]}."""
    b, s, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    uz = xn @ p["w_in"]
    u, z = uz[..., :di], uz[..., di:]
    bc = xn @ p["w_bc"]
    bmat, cmat = bc[..., :n], bc[..., n:]                    # [B,S,N]
    dt = jax.nn.softplus((xn @ p["w_dt"]).astype(jnp.float32))  # [B,S,di]
    u, conv_state = _causal_conv(u, p["conv_w"],
                                 state["conv"] if state else None)
    u = jax.nn.silu(u)
    a = -jnp.exp(p["a_log"])                                 # [di]

    h0 = (state["h"] if state else
          jnp.zeros((b, di, n), jnp.float32))

    def step(h, inp):
        u_t, b_t, c_t, dt_t = inp                            # [B,di],[B,N],[B,N],[B,di]
        decay = jnp.exp(dt_t * a)                            # [B,di]
        h = h * decay[..., None] + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = (h * c_t[:, None, :]).sum(-1)                    # [B,di]
        return h, y

    xs = (u.transpose(1, 0, 2).astype(jnp.float32),
          bmat.transpose(1, 0, 2).astype(jnp.float32),
          cmat.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)                # [B,S,di]
    y = y + u * p["d_skip"]
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"]
    return x + out, {"h": h_final, "conv": conv_state}


def mamba_state(cfg: ArchConfig, batch: int):
    di = cfg.ssm_expand * cfg.d_model
    return {"h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, di),
                              jnp.dtype(cfg.dtype))}


# -- xLSTM --------------------------------------------------------------------

def mlstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d, d, dtype),
        "wk": dense_init(ks[1], d, d, dtype),
        "wv": dense_init(ks[2], d, d, dtype),
        "w_if": dense_init(ks[3], d, 2 * h, dtype, scale=0.02),
        "wo": dense_init(ks[4], d, d, dtype),
        "norm": rmsnorm_init(d, dtype),
        "out_norm": rmsnorm_init(dh, dtype),
    }


def mlstm_apply(p: Params, cfg: ArchConfig, x,
                state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    """Matrix-memory LSTM: C_t = f C + i v k^T;  y = C q / max(|n.q|, 1).

    state = {"c" [B,H,dh,dh], "n" [B,H,dh], "m" [B,H]} (m = log-stabilizer).
    """
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = (xn @ p["wq"]).reshape(b, s, h, dh).astype(jnp.float32)
    k = (xn @ p["wk"]).reshape(b, s, h, dh).astype(jnp.float32) / np.sqrt(dh)
    v = (xn @ p["wv"]).reshape(b, s, h, dh).astype(jnp.float32)
    gi, gf = jnp.split((xn @ p["w_if"]).astype(jnp.float32), 2, -1)  # [B,S,H]

    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.zeros((b, h), jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def step(carry, inp):
        c, n, m = carry
        q_t, k_t, v_t, i_t, f_t = inp
        logf = -jax.nn.softplus(-f_t)                        # log sigmoid(f)
        m_new = jnp.maximum(logf + m, i_t)
        fgate = jnp.exp(logf + m - m_new)                    # [B,H]
        igate = jnp.exp(i_t - m_new)
        c = c * fgate[..., None, None] + \
            igate[..., None, None] * (v_t[..., :, None] * k_t[..., None, :])
        n = n * fgate[..., None] + igate[..., None] * k_t
        denom = jnp.maximum(jnp.abs((n * q_t).sum(-1)), 1.0)  # [B,H]
        y = (c * q_t[..., None, :]).sum(-1) / denom[..., None]
        return (c, n, m_new), y

    xs = tuple(a.transpose(1, 0, 2, 3) if a.ndim == 4 else a.transpose(1, 0, 2)
               for a in (q, k, v, gi, gf))
    (cF, nF, mF), ys = jax.lax.scan(step, (c0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3)                             # [B,S,H,dh]
    y = rmsnorm(y.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    out = y.reshape(b, s, d) @ p["wo"]
    return x + out, {"c": cF, "n": nF, "m": mF}


def mlstm_state(cfg: ArchConfig, batch: int):
    h = cfg.n_heads
    dh = cfg.d_model // h
    return {"c": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.zeros((batch, h), jnp.float32)}


def slstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),       # i, f, z, o
        "r_gates": dense_init(ks[1], d, 4 * d, dtype, scale=0.02),
        "wo": dense_init(ks[2], d, d, dtype),
        "norm": rmsnorm_init(d, dtype),
    }


def slstm_apply(p: Params, cfg: ArchConfig, x,
                state: Optional[Dict] = None) -> Tuple[jnp.ndarray, Dict]:
    """Scalar-memory LSTM with exponential gating and recurrent connection.

    state = {"c","n","hid" [B,D], "m" [B,D]}.
    """
    b, s, d = x.shape
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    wx = (xn @ p["w_gates"]).astype(jnp.float32)             # [B,S,4D]

    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        st = (z, z, z, z)
    else:
        st = (state["c"], state["n"], state["hid"], state["m"])

    r_gates = p["r_gates"].astype(jnp.float32)

    def step(carry, wx_t):
        c, n, hid, m = carry
        g = wx_t + hid @ r_gates
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        logf = -jax.nn.softplus(-gf)
        m_new = jnp.maximum(logf + m, gi)
        fgate = jnp.exp(logf + m - m_new)
        igate = jnp.exp(gi - m_new)
        c = fgate * c + igate * jnp.tanh(gz)
        n = fgate * n + igate
        hid = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
        return (c, n, hid, m_new), hid

    (cF, nF, hF, mF), ys = jax.lax.scan(step, st, wx.transpose(1, 0, 2))
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    out = y @ p["wo"]
    return x + out, {"c": cF, "n": nF, "hid": hF, "m": mF}


def slstm_state(cfg: ArchConfig, batch: int):
    z = jnp.zeros((batch, cfg.d_model), jnp.float32)
    return {"c": z, "n": z, "hid": z, "m": z}
