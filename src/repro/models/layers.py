"""Transformer building blocks shared by the 10 architectures.

Everything is a pure function over a params dict so layer stacks can be
``jax.lax.scan``-ed over stacked parameters (O(1) HLO size in depth) and
``jax.checkpoint``-ed for remat.  Sharding is expressed with
``with_sharding_constraint`` hints on the canonical axes:

  batch/tokens -> ("pod","data")     heads / ffn / experts -> "model"

GSPMD propagates the rest and inserts the collectives the roofline
analysis measures.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig

Params = Dict[str, Any]

# Mesh axis names used by the sharding hints; the launcher rebinds these to
# the active mesh (("pod","data") on the multi-pod mesh, ("data",) on the
# single-pod mesh, () when running unsharded smoke tests on CPU).
_MESH_AXES = {"data": (), "model": None}


def set_mesh_axes(data_axes: Tuple[str, ...], model_axis: Optional[str]):
    _MESH_AXES["data"] = tuple(data_axes)
    _MESH_AXES["model"] = model_axis


def data_axes() -> Tuple[str, ...]:
    return _MESH_AXES["data"]


def model_axis() -> Optional[str]:
    return _MESH_AXES["model"]


def _maybe_shard(x, spec):
    """Sharding hint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, KeyError, TypeError):
        return x


def shard_tokens(x):
    da = data_axes()
    if not da:
        return x
    if x.ndim >= 3 and model_axis():
        # activation sharding: batch over data axes, features over model
        return _maybe_shard(x, P(da, *([None] * (x.ndim - 2)), model_axis()))
    if x.ndim >= 2:
        return _maybe_shard(x, P(da, *([None] * (x.ndim - 1))))
    return x


def shard_model_last(x):
    da = data_axes()
    if not da or not model_axis():
        return x
    return _maybe_shard(x, P(da, *([None] * (x.ndim - 2)), model_axis()))


# -- init ---------------------------------------------------------------------

def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def rmsnorm_init(d, dtype):
    return jnp.ones((d,), dtype)


# -- norms --------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-5):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


# -- rotary embeddings --------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta=10_000.0):
    """x [..., S, H, dh]; positions [..., S] (int)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, dh/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta=10_000.0, sections=(2, 1, 1)):
    """Qwen2-VL multimodal RoPE: the rotary dim is split into (t, h, w)
    sections, each rotated by its own position stream.

    x [B, S, H, dh]; positions3 [3, B, S].
    """
    dh = x.shape[-1]
    total = sum(sections)
    cuts = [dh * s // total for s in sections]
    cuts[-1] = dh - sum(cuts[:-1])
    outs = []
    off = 0
    for sec, width in enumerate(cuts):
        seg = x[..., off:off + width]
        outs.append(apply_rope(seg, positions3[sec], theta))
        off += width
    return jnp.concatenate(outs, axis=-1)


# -- attention ----------------------------------------------------------------

def gqa_init(key, cfg: ArchConfig, dtype) -> Params:
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * dh, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, dtype)
        p["k_norm"] = rmsnorm_init(dh, dtype)
    return p


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


SDPA_CHUNK = 512   # q-block size for chunked attention (long sequences)


def _sdpa_block(q, k, v, causal: bool, q_offset):
    # perf iteration T2: bf16 contraction with fp32 accumulation — operand
    # astype(f32) would materialize q/k/v at double width.
    dh = q.shape[-1]
    scale = 1.0 / np.sqrt(dh)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        logits = jnp.where((qpos >= kpos)[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _sdpa(q, k, v, causal: bool, q_offset=0):
    """q [B,Sq,H,dh], k/v [B,Sk,H,dh] -> [B,Sq,H,dh]; fp32 softmax.

    Long sequences are processed in q-row blocks (scan) so the [Sq, Sk]
    score matrix never materializes — O(Sq/C) blocks of [B,H,C,Sk].  (On
    real TPU the repro.kernels.attention Pallas kernel replaces this path;
    the chunked form keeps the CPU dry-run/interpret path identical in
    FLOPs and memory-bounded.)
    """
    b, sq, h, dh = q.shape
    if sq <= SDPA_CHUNK or sq % SDPA_CHUNK != 0:
        return _sdpa_block(q, k, v, causal, q_offset)
    nblk = sq // SDPA_CHUNK
    qb = q.reshape(b, nblk, SDPA_CHUNK, h, dh).transpose(1, 0, 2, 3, 4)

    def blk(carry, inp):
        i, qq = inp
        out = _sdpa_block(qq, k, v, causal, q_offset + i * SDPA_CHUNK)
        return carry, out

    _, outs = jax.lax.scan(blk, (), (jnp.arange(nblk), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)


def gqa_attention(p: Params, cfg: ArchConfig, x, positions,
                  cache: Optional[Dict] = None, pos3=None,
                  causal: bool = True,
                  kv_source: Optional[jnp.ndarray] = None,
                  kv_positions=None):
    """GQA self-attention (or cross-attention when kv_source is given).

    ``cache``: {"k","v" [B,Smax,Hkv,dh], "index" scalar} — decode appends
    the new token at ``index`` and attends over the valid prefix.
    """
    b, s, d = x.shape
    dh = cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, dh)
    src = kv_source if kv_source is not None else x
    sk = src.shape[1]
    k = (src @ p["wk"]).reshape(b, sk, cfg.n_kv_heads, dh)
    v = (src @ p["wv"]).reshape(b, sk, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if kv_source is None:             # self-attention: rotary on q and k
        if cfg.mrope and pos3 is not None:
            q = apply_mrope(q, pos3, cfg.rope_theta)
            k = apply_mrope(k, pos3, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_model_last(q.reshape(b, s, -1)).reshape(b, s, cfg.n_heads, dh)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    if cache is not None:
        idx = cache["index"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "index": idx + s}
        # GQA without materializing repeated K/V (perf iteration D1,
        # EXPERIMENTS.md §Perf): fold the group dim into q instead of
        # jnp.repeat-ing the cache n_rep times — the cache is read once.
        qg = q.reshape(b, s, cfg.n_kv_heads, n_rep, dh)
        smax = ck.shape[1]
        kpos = jax.lax.broadcasted_iota(jnp.int32, (s, smax), 1)
        qpos = idx + jax.lax.broadcasted_iota(jnp.int32, (s, smax), 0)
        mask = kpos <= qpos          # causal over the filled prefix
        # perf iteration D3: contract the cache in bf16 with fp32
        # accumulation — upcasting ck/cv with astype would materialize the
        # whole KV cache in fp32 (2x its bytes) before the einsum.
        logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, ck,
                            preferred_element_type=jnp.float32) / np.sqrt(dh)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(x.dtype), cv,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, s, cfg.n_heads, dh).astype(x.dtype)
    else:
        kk = _repeat_kv(k, n_rep)
        vv = _repeat_kv(v, n_rep)
        out = _sdpa(q, kk, vv, causal=causal and kv_source is None)
        new_cache = None
    out = out.reshape(b, s, cfg.n_heads * dh)
    return out @ p["wo"], new_cache


# -- MLA (DeepSeek-V2 multi-head latent attention) ----------------------------

def mla_init(key, cfg: ArchConfig, dtype) -> Params:
    d, dh, r = cfg.d_model, cfg.head_dim, cfg.kv_lora_rank
    rd = cfg.rope_head_dim
    ks = jax.random.split(key, 8)
    p = {
        # compressed KV path: d -> r (+ decoupled rope key)
        "w_dkv": dense_init(ks[0], d, r + rd, dtype),
        "kv_norm": rmsnorm_init(r, dtype),
        "w_uk": dense_init(ks[1], r, cfg.n_heads * dh, dtype),
        "w_uv": dense_init(ks[2], r, cfg.n_heads * dh, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], d, cfg.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["w_uq"] = dense_init(ks[5], cfg.q_lora_rank,
                               cfg.n_heads * (dh + rd), dtype)
    else:
        p["w_q"] = dense_init(ks[5], d, cfg.n_heads * (dh + rd), dtype)
    return p


def mla_attention(p: Params, cfg: ArchConfig, x, positions,
                  cache: Optional[Dict] = None):
    """Multi-head latent attention: KV compressed to ``kv_lora_rank`` (the
    cache stores only the r+rope_dim latent — the paper's 93% KV memory
    saving) and up-projected per head at attention time."""
    b, s, d = x.shape
    dh, r, rd = cfg.head_dim, cfg.kv_lora_rank, cfg.rope_head_dim
    h = cfg.n_heads

    if cfg.q_lora_rank:
        q = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps) @ p["w_uq"]
    else:
        q = x @ p["w_q"]
    q = q.reshape(b, s, h, dh + rd)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"]                       # [b, s, r+rd]
    latent, k_rope = dkv[..., :r], dkv[..., r:]
    latent = rmsnorm(latent, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    if cache is not None:
        idx = cache["index"]
        cl = jax.lax.dynamic_update_slice(cache["latent"], latent, (0, idx, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"],
                                          k_rope[:, :, 0, :], (0, idx, 0))
        new_cache = {"latent": cl, "k_rope": cr, "index": idx + s}
        latent_all, k_rope_all = cl, cr[:, :, None, :]
        q_base = idx
    else:
        new_cache = None
        latent_all, k_rope_all = latent, k_rope
        q_base = None

    k_nope = (latent_all @ p["w_uk"]).reshape(b, -1, h, dh)
    v = (latent_all @ p["w_uv"]).reshape(b, -1, h, dh)
    sk = k_nope.shape[1]
    scale = 1.0 / np.sqrt(dh + rd)
    k_rope_flat = k_rope_all[:, :, 0, :]

    def block(qn, qr, offset):
        lg = (jnp.einsum("bqhd,bkhd->bhqk", qn, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bkd->bhqk", qr, k_rope_flat,
                           preferred_element_type=jnp.float32)) * scale
        sq = qn.shape[1]
        base = offset if cache is None else q_base + offset
        qpos = base + jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        lg = jnp.where((qpos >= kpos)[None, None], lg, -1e30)
        probs = jax.nn.softmax(lg, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(x.dtype), v,
                          preferred_element_type=jnp.float32)

    if s > SDPA_CHUNK and s % SDPA_CHUNK == 0 and cache is None:
        nblk = s // SDPA_CHUNK
        qn_b = q_nope.reshape(b, nblk, SDPA_CHUNK, h, dh
                              ).transpose(1, 0, 2, 3, 4)
        qr_b = q_rope.reshape(b, nblk, SDPA_CHUNK, h, rd
                              ).transpose(1, 0, 2, 3, 4)

        def scan_blk(_, inp):
            i, qn, qr = inp
            return (), block(qn, qr, i * SDPA_CHUNK)
        _, outs = jax.lax.scan(scan_blk, (),
                               (jnp.arange(nblk), qn_b, qr_b))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    else:
        out = block(q_nope, q_rope, 0)
    out = out.astype(x.dtype).reshape(b, s, h * dh)
    return out @ p["wo"], new_cache


# -- MLPs ---------------------------------------------------------------------

def mlp_init(key, d, d_ff, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {"w1": dense_init(ks[0], d, d_ff, dtype),
            "w3": dense_init(ks[1], d, d_ff, dtype),
            "w2": dense_init(ks[2], d_ff, d, dtype)}


def mlp_apply(p: Params, x):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    h = shard_model_last(h)
    return h @ p["w2"]


# -- MoE ----------------------------------------------------------------------

def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    e = cfg.n_experts

    def expert(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"w1": dense_init(k1, d, ff, dtype),
                "w3": dense_init(k2, d, ff, dtype),
                "w2": dense_init(k3, ff, d, dtype)}

    p = {
        "router": dense_init(ks[0], d, e, dtype, scale=0.02),
        "experts": jax.vmap(expert)(jax.random.split(ks[1], e)),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[2], d, ff * cfg.n_shared_experts, dtype)
    return p


def _moe_dispatch_local(cfg: ArchConfig, capacity_factor: float,
                        model_ax: str):
    """Per-device MoE dispatch body for shard_map (perf iteration T1).

    Each (data, model) device holds its data-shard's tokens (replicated
    along the model axis) and E/model_size experts: the token->expert
    assignment is computed locally, expert GEMMs run on local buffers, and
    one psum over the model axis combines contributions — replacing the
    GSPMD-replicated scatter/gather (which all-gathered the full [T*k, D]
    dispatch tensor per layer) with a single [T_local, D] reduction.
    """
    e_total = cfg.n_experts
    k = cfg.experts_per_tok

    def body(xf, top_idx, probs, w1, w3, w2):
        e_loc = w1.shape[0]
        t_loc, d = xf.shape
        ax = jax.lax.axis_index(model_ax)
        e_start = ax * e_loc
        cap = max(8, int(capacity_factor * t_loc * k / e_total))
        flat_e = top_idx.reshape(-1) - e_start
        mine = (flat_e >= 0) & (flat_e < e_loc)
        fe = jnp.where(mine, flat_e, 0)
        onehot = jax.nn.one_hot(fe, e_loc, dtype=jnp.int32) * mine[:, None]
        incl = jax.lax.associative_scan(jnp.add, onehot, axis=0)
        slot = jnp.take_along_axis(incl - onehot, fe[:, None], axis=1)[:, 0]
        keep = mine & (slot < cap)
        slot = jnp.where(keep, slot, cap - 1)
        x_rep = jnp.broadcast_to(xf[:, None, :], (t_loc, k, d)
                                 ).reshape(t_loc * k, d)
        buf = jnp.zeros((e_loc, cap, d), xf.dtype)
        buf = buf.at[fe, slot].add(jnp.where(keep[:, None], x_rep, 0))
        h = jnp.einsum("ecd,edf->ecf", buf, w1)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, w3)
        out_e = jnp.einsum("ecf,efd->ecd", h, w2)
        y = out_e[fe, slot] * jnp.where(keep[:, None], 1, 0)
        y = (y.reshape(t_loc, k, d)
             * probs.reshape(t_loc, k)[..., None].astype(y.dtype)).sum(1)
        return jax.lax.psum(y, model_ax)

    return body


def _moe_routed_sharded(p, cfg, xf, top_idx, probs,
                        capacity_factor) -> Optional[jnp.ndarray]:
    """shard_map expert-parallel path; None if inapplicable (no mesh /
    non-divisible experts) — caller falls back to the dense path."""
    model_ax = model_axis()
    da = data_axes()
    if not model_ax or not da:
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or model_ax not in mesh.shape:
            return None
        msize = mesh.shape[model_ax]
        dsize = 1
        for a in da:
            dsize *= mesh.shape[a]
    except Exception:
        return None
    if cfg.n_experts % msize or xf.shape[0] % max(1, dsize):
        return None
    from jax.experimental.shard_map import shard_map
    body = _moe_dispatch_local(cfg, capacity_factor, model_ax)
    f = shard_map(
        body, mesh=mesh,
        in_specs=(P(da, None), P(da, None), P(da, None),
                  P(model_ax, None, None), P(model_ax, None, None),
                  P(model_ax, None, None)),
        out_specs=P(da, None))
    return f(xf, top_idx, probs, p["experts"]["w1"], p["experts"]["w3"],
             p["experts"]["w2"])


def moe_apply(p: Params, cfg: ArchConfig, x, capacity_factor: float = 1.25):
    """Top-k token-choice MoE with capacity-bounded dispatch.

    Routing (router GEMM + top-k) runs data-parallel; the routed-expert
    compute uses the shard_map expert-parallel path when a mesh is active
    (see ``_moe_dispatch_local``), else a dense scatter/gather fallback
    (single-device smoke tests).
    """
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_tok
    e = cfg.n_experts
    xf = x.reshape(t, d)

    gates = (xf @ p["router"]).astype(jnp.float32)          # [T, E]
    top_vals, top_idx = jax.lax.top_k(gates, k)             # [T, k]
    probs = jax.nn.softmax(top_vals, axis=-1).astype(x.dtype)

    y = _moe_routed_sharded(p, cfg, xf, top_idx, probs, capacity_factor)
    if y is not None:
        if cfg.n_shared_experts:
            y = y + mlp_apply(p["shared"], xf)
        return y.reshape(b, s, d)

    # tiny batches (CPU tests/examples) run drop-free so prefill+decode and
    # full-forward routing agree exactly; at scale the standard capacity
    # bound applies
    cap = t * k if t * k <= 1024 else max(8, int(capacity_factor * t * k / e))
    flat_e = top_idx.reshape(-1)                            # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    # log-depth prefix sum (associative_scan) — a plain cumsum lowers to a
    # quadratic reduce-window on some backends
    incl = jax.lax.associative_scan(jnp.add, onehot, axis=0)
    slot = jnp.take_along_axis(incl - onehot,
                               flat_e[:, None], axis=1)[:, 0]   # [T*k]
    keep = slot < cap
    slot = jnp.where(keep, slot, cap - 1)

    x_rep = jnp.broadcast_to(xf[:, None, :], (t, k, d)).reshape(t * k, d)
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[flat_e, slot].add(jnp.where(keep[:, None], x_rep, 0))
    if model_axis():
        buf = _maybe_shard(buf, P(model_axis(), data_axes() or None, None))

    h = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w1"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w3"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["experts"]["w2"])

    y = out_e[flat_e, slot] * jnp.where(keep[:, None], 1, 0)
    y = (y.reshape(t, k, d) * probs[..., None]).sum(axis=1)
    if cfg.n_shared_experts:
        y = y + mlp_apply(p["shared"], xf)
    return y.reshape(b, s, d)
