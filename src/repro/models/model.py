"""Model assembly: any ArchConfig -> init / loss / prefill / decode.

Layers with identical block kinds are grouped into *segments*; each segment
stacks its parameters along a leading layer axis and executes under
``jax.lax.scan`` — HLO size is O(#segments), not O(depth), which keeps the
236B-parameter dry-run compiles fast.  Heterogeneous patterns (zamba2's
mamba blocks + shared attention, xLSTM's mlstm/slstm alternation) become
short segment lists.  ``jax.checkpoint`` wraps the block body when
``cfg.remat`` (activation rematerialization for training).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S

Params = Dict[str, Any]


# -- pattern segmentation ------------------------------------------------------

def segments_of(cfg: ArchConfig) -> List[Tuple[str, int]]:
    segs: List[Tuple[str, int]] = []
    for kind in cfg.pattern:
        if segs and segs[-1][0] == kind:
            segs[-1] = (kind, segs[-1][1] + 1)
        else:
            segs.append((kind, 1))
    return segs


# -- per-block init -------------------------------------------------------------

def _attn_init(key, cfg, dtype):
    if cfg.mla:
        return L.mla_init(key, cfg, dtype)
    return L.gqa_init(key, cfg, dtype)


def _block_init(kind: str, key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    if kind == "attn":
        return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
                "attn": _attn_init(ks[0], cfg, dtype),
                "ln2": L.rmsnorm_init(cfg.d_model, dtype),
                "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)}
    if kind == "moe":
        return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
                "attn": _attn_init(ks[0], cfg, dtype),
                "ln2": L.rmsnorm_init(cfg.d_model, dtype),
                "moe": L.moe_init(ks[1], cfg, dtype)}
    if kind == "xdec":   # encoder-decoder decoder block (self + cross + mlp)
        return {"ln1": L.rmsnorm_init(cfg.d_model, dtype),
                "attn": L.gqa_init(ks[0], cfg, dtype),
                "lnx": L.rmsnorm_init(cfg.d_model, dtype),
                "xattn": L.gqa_init(ks[1], cfg, dtype),
                "ln2": L.rmsnorm_init(cfg.d_model, dtype),
                "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)}
    if kind == "mamba":
        return S.mamba_init(key, cfg, dtype)
    if kind == "mlstm":
        return S.mlstm_init(key, cfg, dtype)
    if kind == "slstm":
        return S.slstm_init(key, cfg, dtype)
    raise ValueError(kind)


def init_params(cfg: ArchConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: Params = {
        "emb": L.dense_init(keys[0], cfg.vocab, cfg.d_model, dtype, scale=0.02),
        "ln_f": L.rmsnorm_init(cfg.d_model, dtype),
        "segments": [],
    }
    if not cfg.tie_embeddings:
        p["unemb"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    seg_keys = jax.random.split(keys[2], max(1, len(segments_of(cfg))))
    for (kind, count), sk in zip(segments_of(cfg), seg_keys):
        if kind == "sattn":   # shared block: parameters stored once
            p["segments"].append(None)
            continue
        stacked = jax.vmap(
            lambda k: _block_init(kind, k, cfg, dtype))(
                jax.random.split(sk, count))
        p["segments"].append(stacked)
    if cfg.shared_attn_every:
        p["shared_attn"] = _block_init("attn", keys[3], cfg, dtype)
    if cfg.enc_layers:
        enc = jax.vmap(
            lambda k: _block_init("attn", k, cfg, dtype))(
                jax.random.split(keys[4], cfg.enc_layers))
        p["encoder"] = enc
    return p


# -- per-block apply -------------------------------------------------------------

def _attention(p, cfg, x, positions, cache, pos3):
    if cfg.mla:
        return L.mla_attention(p, cfg, x, positions, cache)
    return L.gqa_attention(p, cfg, x, positions, cache, pos3=pos3)


def block_apply(kind: str, cfg: ArchConfig, p: Params, x, positions,
                cache=None, pos3=None, enc_out=None):
    """Returns (x, new_cache)."""
    if kind in ("attn", "moe", "xdec"):
        h, new_cache = _attention(p["attn"], cfg,
                                  L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                                  positions, cache, pos3)
        x = x + h
        if kind == "xdec" and enc_out is not None:
            h, _ = L.gqa_attention(p["xattn"], cfg,
                                   L.rmsnorm(x, p["lnx"], cfg.norm_eps),
                                   positions, None, kv_source=enc_out)
            x = x + h
        xin = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            x = x + L.moe_apply(p["moe"], cfg, xin)
        else:
            x = x + L.mlp_apply(p["mlp"], xin)
        return x, new_cache
    if kind == "mamba":
        return S.mamba_apply(p, cfg, x, cache)
    if kind == "mlstm":
        return S.mlstm_apply(p, cfg, x, cache)
    if kind == "slstm":
        return S.slstm_apply(p, cfg, x, cache)
    raise ValueError(kind)


# -- caches / states ---------------------------------------------------------

def _block_cache(kind: str, cfg: ArchConfig, batch: int, max_seq: int):
    dtype = jnp.dtype(cfg.dtype)
    if kind in ("attn", "moe", "xdec"):
        if cfg.mla:
            return {"latent": jnp.zeros((batch, max_seq, cfg.kv_lora_rank),
                                        dtype),
                    "k_rope": jnp.zeros((batch, max_seq, cfg.rope_head_dim),
                                        dtype)}
        return {"k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                               dtype)}
    if kind == "mamba":
        return S.mamba_state(cfg, batch)
    if kind == "mlstm":
        return S.mlstm_state(cfg, batch)
    if kind == "slstm":
        return S.slstm_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int) -> List[Any]:
    caches = []
    for kind, count in segments_of(cfg):
        one = _block_cache("attn" if kind == "sattn" else kind,
                           cfg, batch, max_seq)
        caches.append(jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (count,) + a.shape).copy(), one))
    return caches


# -- forward -------------------------------------------------------------------

def _with_index(cache, idx):
    if cache is None:
        return None
    if "k" in cache or "latent" in cache:
        return dict(cache, index=idx)
    return cache


def _strip_index(cache):
    if cache is None:
        return None
    return {k: v for k, v in cache.items() if k != "index"}


def forward(cfg: ArchConfig, params: Params, x, positions,
            caches: Optional[List] = None, index=None, pos3=None,
            enc_out=None):
    """Backbone forward. ``x`` [B,S,D] embeddings; returns (h, new_caches)."""
    new_caches: List[Any] = []
    shared_count = 0
    for si, (seg_params, (kind, count)) in enumerate(
            zip(params["segments"], segments_of(cfg))):
        seg_cache = caches[si] if caches is not None else None

        if kind == "sattn":
            cache_in = None
            if seg_cache is not None:
                cache_in = _with_index(jax.tree_util.tree_map(
                    lambda a: a[0], seg_cache), index)
            x, nc = block_apply("attn", cfg, params["shared_attn"], x,
                                positions, cache_in, pos3, enc_out)
            if seg_cache is not None:
                nc = _strip_index(nc)
                new_caches.append(jax.tree_util.tree_map(
                    lambda a: a[None], nc))
            else:
                new_caches.append(None)
            shared_count += 1
            continue

        body_kind = kind

        if seg_cache is None:
            def run_block(p_l, xh):
                out, _ = block_apply(body_kind, cfg, p_l, xh, positions,
                                     None, pos3, enc_out)
                return out
            if cfg.remat:
                run_block = jax.checkpoint(run_block)
            x, _ = jax.lax.scan(
                lambda c, p_l: (run_block(p_l, c), None), x, seg_params)
            new_caches.append(None)
        else:
            def body(carry, xs):
                p_l, c_l = xs
                out, nc = block_apply(body_kind, cfg, p_l, carry, positions,
                                      _with_index(c_l, index), pos3, enc_out)
                return out, _strip_index(nc)
            x, ncs = jax.lax.scan(body, x, (seg_params, seg_cache))
            new_caches.append(ncs)
    return x, new_caches


def encode(cfg: ArchConfig, params: Params, feats, positions):
    """Bidirectional encoder over (stubbed) frontend features [B,S,D]."""
    def body(x, p_l):
        h, _ = L.gqa_attention(p_l["attn"], cfg,
                               L.rmsnorm(x, p_l["ln1"], cfg.norm_eps),
                               positions, None, causal=False)
        x = x + h
        x = x + L.mlp_apply(p_l["mlp"],
                            L.rmsnorm(x, p_l["ln2"], cfg.norm_eps))
        return x, None
    out, _ = jax.lax.scan(body, feats, params["encoder"])
    return out


def embed(cfg: ArchConfig, params: Params, tokens):
    return jnp.take(params["emb"], tokens, axis=0)


def logits_of(cfg: ArchConfig, params: Params, h, pad_vocab: bool = False):
    """Final projection.  ``pad_vocab`` (perf iteration M2): odd vocabularies
    (e.g. minicpm's 122753) cannot shard over a 16-way model axis, leaving
    the [B,S,V] fp32 logits replicated along it; padding the output dim to a
    512-multiple makes the largest activation of the training step
    model-shardable.  Padded columns are -inf so logsumexp is unchanged."""
    h = L.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    unemb = params["emb"].T if cfg.tie_embeddings else params["unemb"]
    pad = (-cfg.vocab) % 512 if pad_vocab else 0
    if pad:
        unemb = jnp.pad(unemb, ((0, 0), (0, pad)))
    logits = h @ unemb
    if pad:
        neg = jnp.full((pad,), -1e30, logits.dtype)
        logits = logits.at[..., cfg.vocab:].set(neg)
    return logits


# -- task-level functions --------------------------------------------------------

def lm_loss(cfg: ArchConfig, params: Params, tokens, labels,
            extra_embeds=None, pos3=None, enc_feats=None):
    """Causal-LM cross entropy.  ``extra_embeds`` (VLM patch stubs) are
    prepended; ``enc_feats`` (audio stubs) drive the encoder of enc-dec
    architectures."""
    x = embed(cfg, params, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    x = L.shard_tokens(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    enc_out = None
    if cfg.enc_layers and enc_feats is not None:
        enc_pos = jnp.broadcast_to(jnp.arange(enc_feats.shape[1]),
                                   enc_feats.shape[:2])
        enc_out = encode(cfg, params, enc_feats.astype(x.dtype), enc_pos)
    h, _ = forward(cfg, params, x, positions, pos3=pos3, enc_out=enc_out)
    logits = logits_of(cfg, params, h, pad_vocab=bool(L.model_axis()))
    if extra_embeds is not None:
        logits = logits[:, extra_embeds.shape[1]:]
    logits = logits.astype(jnp.float32)
    # logits shard vocab over the model axis (the [B,S,V] fp32 tensor is by
    # far the largest activation; see EXPERIMENTS.md §Perf)
    logits = L.shard_tokens(logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def prefill(cfg: ArchConfig, params: Params, tokens, caches,
            extra_embeds=None, pos3=None, enc_feats=None):
    """Run the prompt through the model, filling caches; returns
    (last-token logits, caches)."""
    x = embed(cfg, params, tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    x = L.shard_tokens(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    enc_out = None
    if cfg.enc_layers and enc_feats is not None:
        enc_pos = jnp.broadcast_to(jnp.arange(enc_feats.shape[1]),
                                   enc_feats.shape[:2])
        enc_out = encode(cfg, params, enc_feats.astype(x.dtype), enc_pos)
    h, caches = forward(cfg, params, x, positions, caches=caches, index=0,
                        pos3=pos3, enc_out=enc_out)
    return logits_of(cfg, params, h[:, -1:]), caches


def decode_step(cfg: ArchConfig, params: Params, token, index, caches,
                enc_out=None):
    """One decode step: ``token`` [B] at position ``index`` (scalar)."""
    x = embed(cfg, params, token[:, None])
    b = x.shape[0]
    positions = jnp.full((b, 1), index, jnp.int32)
    pos3 = (jnp.broadcast_to(positions, (3, b, 1))
            if cfg.mrope else None)
    h, caches = forward(cfg, params, x, positions, caches=caches,
                        index=index, pos3=pos3, enc_out=enc_out)
    return logits_of(cfg, params, h)[:, 0], caches
