from repro.data.pipeline import SyntheticLM, batch_for_step

__all__ = ["SyntheticLM", "batch_for_step"]
