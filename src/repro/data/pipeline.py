"""Deterministic, stateless synthetic-token pipeline.

Every batch is a pure function of (seed, step) — the property fault
tolerance needs: after a restart from step N the pipeline replays the
identical stream with no persisted iterator state.  Tokens follow a Zipfian
marginal with short-range Markov structure so cross-entropy training has
learnable signal (examples/train_tinylm.py drives loss well below the
uniform entropy).

Host sharding: ``shard_for`` slices the global batch for a data-parallel
host, matching the (pod, data) mesh axes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.2

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch(self, step: int) -> dict:
        """Global batch for ``step``: tokens/labels [B, S] int32."""
        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len, self.vocab
        ranks = rng.zipf(self.zipf_a, size=(b, s + 1)).astype(np.int64)
        base = (ranks - 1) % v
        # short-range Markov structure: with p=0.35 copy prev token + 1
        copy = rng.random((b, s + 1)) < 0.35
        toks = base.copy()
        for t in range(1, s + 1):
            toks[:, t] = np.where(copy[:, t], (toks[:, t - 1] + 1) % v,
                                  toks[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def shard_for(self, step: int, shard: int, num_shards: int) -> dict:
        assert self.global_batch % num_shards == 0
        per = self.global_batch // num_shards
        full = self.batch(step)
        sl = slice(shard * per, (shard + 1) * per)
        return {k: v[sl] for k, v in full.items()}


def batch_for_step(vocab: int, seq_len: int, global_batch: int, step: int,
                   seed: int = 1234) -> dict:
    return SyntheticLM(vocab, seq_len, global_batch, seed).batch(step)
