"""Conduit-for-TPU: the paper's six-feature cost function lifted to
distributed execution planning (DESIGN.md §4b — the beyond-paper layer).

A TPU pod is also a set of heterogeneous compute/memory resources (MXU,
VPU, HBM, host tier, ICI/DCN links).  For a (model, shape, mesh) the
scheduler scores *candidate execution plans* — sharding layout choices,
remat policy, logits chunking, gradient compression — with the same
feature structure Conduit applies per instruction:

  operation type        -> FLOP class mix (matmul vs elementwise vs gather)
  operand location      -> resident vs needs-all-gather vs host-offloaded
  data dependence delay -> non-overlappable fraction of collectives
  resource queueing     -> per-resource occupancy (MXU / HBM / ICI / DCN)
  data movement cost    -> reshard + offload bytes over link bandwidth
  computation latency   -> analytic roofline terms per resource

  total_latency(plan) = max(compute, memory) + exposed_collectives        (1')
  plan* = argmin_plan total_latency                                        (2')

Eqn (1') is the pipelined analogue of the paper's Eqn 1: compute and
memory overlap on-chip (max), while the non-overlapped collective fraction
adds like the paper's movement term.  The dry-run's measured roofline
terms calibrate the estimates; §Perf logs predicted-vs-measured per
hillclimb iteration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.hw.tpu_spec import TPU_V5E, TPUSpec
from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class CandidatePlan:
    name: str
    # sharding knobs
    fsdp_weights: bool = True          # shard weights over data axis
    seq_shard_cache: bool = True       # KV caches sharded over sequence
    vocab_shard_logits: bool = True    # logits sharded over model axis
    # schedule knobs
    remat: bool = True
    logits_chunk: int = 0              # 0 = no chunking
    grad_compression: bool = False     # INT8 + error feedback on pod axis
    microbatches: int = 1
    activation_shard_model: bool = True

    def describe(self) -> str:
        on = [k for k, v in dataclasses.asdict(self).items()
              if v and k != "name"]
        return f"{self.name}: " + ", ".join(on)


@dataclasses.dataclass
class PlanEstimate:
    plan: CandidatePlan
    compute_s: float
    memory_s: float
    collective_s: float
    exposed_collective_s: float
    hbm_gb: float
    total_s: float
    feasible: bool
    notes: str = ""


def default_candidates() -> List[CandidatePlan]:
    return [
        CandidatePlan("baseline"),
        CandidatePlan("no-remat", remat=False),
        CandidatePlan("chunked-logits", logits_chunk=8),
        CandidatePlan("compressed-grads", grad_compression=True),
        CandidatePlan("replicated-weights", fsdp_weights=False),
        CandidatePlan("micro4", microbatches=4),
        CandidatePlan("act-replicated", activation_shard_model=False),
    ]


class ConduitScheduler:
    """Analytic planner; napkin math per candidate, argmin per Eqn (2')."""

    def __init__(self, tpu: TPUSpec = TPU_V5E):
        self.tpu = tpu

    def estimate(self, cfg: ArchConfig, kind: str, global_batch: int,
                 seq_len: int, chips: int, data_par: int, model_par: int,
                 pods: int, plan: CandidatePlan) -> PlanEstimate:
        t = self.tpu
        n_active = cfg.active_param_count()
        tokens = global_batch * (seq_len if kind != "decode" else 1)

        # (6) computation latency: model FLOPs + remat recompute
        flops = (6 if kind == "train" else 2) * n_active * tokens
        if kind == "train" and plan.remat:
            flops *= 4.0 / 3.0
        compute_s = flops / (chips * t.peak_bf16_flops)

        # memory term: weight + activation traffic per chip
        weight_bytes = 2 * cfg.param_count() / (model_par *
                                                (data_par if plan.fsdp_weights
                                                 else 1))
        act_bytes_chip = (2 * tokens * cfg.d_model * len(cfg.pattern)
                          / (data_par * pods)
                          / (model_par if plan.activation_shard_model else 1))
        passes = 3 if kind == "train" else 1
        memory_s = passes * (weight_bytes + act_bytes_chip) / t.hbm_bw

        # (2,5) operand location / movement: weight all-gather (FSDP) +
        # gradient reduce-scatter + MoE all-to-all + logits collectives
        coll_bytes = 0.0
        if plan.fsdp_weights:
            coll_bytes += passes * weight_bytes * (data_par - 1) / data_par
        if kind == "train":
            grad_bytes = 2 * cfg.param_count() / (model_par * data_par)
            if plan.grad_compression:
                grad_bytes *= 0.25
            coll_bytes += 2 * grad_bytes
        if cfg.moe:
            coll_bytes += (4 * tokens * cfg.d_model * 2
                           * cfg.experts_per_tok / chips)
        if not plan.vocab_shard_logits and kind == "train":
            coll_bytes += 4 * tokens * cfg.d_model / (data_par * pods)
        if plan.activation_shard_model:
            # per-layer activation all-gathers over the model axis
            coll_bytes += (passes * len(cfg.pattern) * 2 * tokens
                           * cfg.d_model / (data_par * pods)
                           * (model_par - 1) / model_par)
        collective_s = coll_bytes / t.ici_bw

        # (3) dependence: fraction of collectives on the critical path that
        # cannot overlap compute (micro-batching overlaps gradient comms)
        overlap = 0.6 if plan.microbatches > 1 else 0.3
        exposed = collective_s * (1 - overlap)

        # HBM feasibility
        hbm = weight_bytes
        if kind == "train":
            hbm += 5 * weight_bytes          # fp32 master-ish + moments
            hbm += act_bytes_chip * (1 if plan.remat else len(cfg.pattern))
        if kind == "decode":
            kv_per_tok = (2 * cfg.n_kv_heads * cfg.head_dim
                          if not cfg.mla else
                          cfg.kv_lora_rank + cfg.rope_head_dim)
            hbm += (2 * global_batch * seq_len * kv_per_tok
                    * len([b for b in cfg.pattern if b in
                           ("attn", "moe", "xdec")]) / chips)
        if plan.logits_chunk == 0 and kind == "train":
            hbm += 4 * tokens * cfg.vocab / chips / \
                (model_par if plan.vocab_shard_logits else 1)
        feasible = hbm < 0.9 * t.hbm_bytes

        total = max(compute_s, memory_s) + exposed
        return PlanEstimate(plan, compute_s, memory_s, collective_s,
                            exposed, hbm / 1e9, total, feasible)

    def choose(self, cfg: ArchConfig, kind: str, global_batch: int,
               seq_len: int, chips: int, data_par: int, model_par: int,
               pods: int = 1,
               candidates: Optional[List[CandidatePlan]] = None
               ) -> Tuple[PlanEstimate, List[PlanEstimate]]:
        cands = candidates or default_candidates()
        ests = [self.estimate(cfg, kind, global_batch, seq_len, chips,
                              data_par, model_par, pods, c) for c in cands]
        ok = [e for e in ests if e.feasible] or ests
        best = min(ok, key=lambda e: e.total_s)
        return best, ests
