from repro.distributed.scheduler import (CandidatePlan, ConduitScheduler,
                                         PlanEstimate, default_candidates)

__all__ = ["CandidatePlan", "ConduitScheduler", "PlanEstimate",
           "default_candidates"]
