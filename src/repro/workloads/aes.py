"""AES-256 encryption workload (§5.4 workload 1).

Structure: counter-mode (CTR) encryption is embarrassingly parallel and
auto-vectorizes (bitsliced round function: AddRoundKey XOR, a bitsliced
SubBytes fragment built from AND/XOR/NOT/shifts, ShiftRows/MixColumns as
shift+XOR "xtime" chains).  A fraction of blocks is encrypted in *CBC* mode
— an inherently sequential chain the auto-vectorizer cannot handle (§7),
emitted as a non-vectorizable control region — and the S-box for a slice of
the state uses a table lookup (gather), which only the ISP cores support.

Table 3 targets: 65% vectorizable, reuse 15.2, 87% low / 13% medium / 0% high.
The 14 encryption rounds re-read the state and round keys (reuse ~15),
and the round function is almost entirely bitwise (low latency).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

SCALES = {
    # elements (INT8 lanes); one logical page = 4096 lanes
    "tiny": dict(n=8 * 4096, cbc_blocks=2, rounds=6),
    "paper": dict(n=96 * 4096, cbc_blocks=8, rounds=14),
}


def _round(state, rk, sbox):
    state = state ^ rk                                    # AddRoundKey (broadcast)
    # bitsliced SubBytes fragment (affine + inversion approximation)
    r1 = (state << 1) ^ (state >> 7)
    r2 = state & r1
    r3 = ~state
    state = r2 ^ r3 ^ (r1 | state)
    # ShiftRows + MixColumns: xtime chains
    xt = (state << 1) ^ ((state >> 31) & 27)
    state = xt ^ r1
    return state


def _cbc_chain(blocks, rk0):
    """Sequential CBC chaining over pages — non-vectorizable (§7)."""
    n = blocks.shape[0]

    def cond(c):
        i, prev, out = c
        return i < n

    def body(c):
        i, prev, out = c
        x = out[i] ^ prev
        x = x ^ rk0[i % rk0.shape[0]]
        out = out.at[i].set(x)
        return i + 1, x, out

    _, _, out = jax.lax.while_loop(cond, body, (0, blocks[0], blocks))
    return out


def make_fn(scale: str = "paper"):
    p = SCALES[scale]
    rounds = p["rounds"]

    def aes(state, round_keys, sbox_table, cbc_blocks, checksum_seed):
        # CTR-mode parallel encryption (vectorizable)
        for r in range(rounds):
            state = _round(state, round_keys[r], sbox_table)
        # table-lookup S-box pass on a slice (gather; ISP-class)
        idx = state[: state.shape[0] // 8] & 255
        subbed = jnp.take(sbox_table, idx)
        # integrity checksum (medium-latency add/cmp mix)
        csum = (state + checksum_seed)
        flags = csum > 0
        csum = jnp.where(flags, csum, -csum)
        # CBC region (sequential; control fallback)
        cbc = _cbc_chain(cbc_blocks, round_keys)
        return state, subbed, jnp.sum(csum), cbc

    return aes


def make_inputs(scale: str = "paper", seed: int = 0):
    p = SCALES[scale]
    rng = np.random.default_rng(seed)
    n = p["n"]
    state = jnp.asarray(rng.integers(0, 2**31, size=(n // 4096, 4096),
                                     dtype=np.int32))
    keys = jnp.asarray(rng.integers(0, 2**31, size=(p["rounds"], 4096),
                                    dtype=np.int32))
    sbox = jnp.asarray(rng.integers(0, 256, size=(256,), dtype=np.int32))
    cbc = jnp.asarray(rng.integers(0, 2**31, size=(p["cbc_blocks"], 4096),
                                   dtype=np.int32))
    seed_v = jnp.asarray(rng.integers(0, 127, size=(n // 4096, 4096),
                                      dtype=np.int32))
    return (state, keys, sbox, cbc, seed_v)


# simulator pressure knobs: AES has high reuse -> modest DRAM suffices
SIM = dict(dram_frac=0.6, host_frac=0.6)
META = dict(paper_vect=65, paper_reuse=15.2, paper_low=87, paper_med=13,
            paper_high=0, kind="io_intensive")
