"""Reduced-dimension LLaMA2-architecture model used by the LLM workloads.

The paper evaluates INT8 LLaMA2-7B inference and training (llama2.c [308]);
full-scale traces would be billions of page-ops, so — like the paper's own
12,000-instruction execution windows (Fig. 10) — we trace a
dimension-reduced model with the identical architecture (RMSNorm, RoPE,
multi-head attention with causal mask, SwiGLU MLP, weight-tied logits).
The vectorizer quantizes every tensor to INT8 lanes (§5.4).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def init_params(rng: np.random.Generator, d: int, n_layers: int, n_heads: int,
                d_ff: int, vocab: int) -> Dict:
    def w(*shape):
        return jnp.asarray(rng.normal(0, 0.02, size=shape).astype(np.float32))

    layers = []
    for _ in range(n_layers):
        layers.append(dict(
            wq=w(d, d), wk=w(d, d), wv=w(d, d), wo=w(d, d),
            w1=w(d, d_ff), w2=w(d_ff, d), w3=w(d, d_ff),
            ln1=jnp.ones((d,), jnp.float32), ln2=jnp.ones((d,), jnp.float32),
        ))
    return dict(emb=w(vocab, d), lnf=jnp.ones((d,), jnp.float32),
                layers=layers)


def rmsnorm(x, g):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-5) * g


def rope(x, cos, sin):
    h = x.shape[-1] // 2
    x1, x2 = x[..., :h], x[..., h:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def attention(x, layer, n_heads, cos, sin, mask):
    seq, d = x.shape
    dh = d // n_heads
    q = (x @ layer["wq"]).reshape(seq, n_heads, dh).transpose(1, 0, 2)
    k = (x @ layer["wk"]).reshape(seq, n_heads, dh).transpose(1, 0, 2)
    v = (x @ layer["wv"]).reshape(seq, n_heads, dh).transpose(1, 0, 2)
    q = rope(q, cos, sin)
    k = rope(k, cos, sin)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / np.sqrt(dh)
    scores = jnp.where(mask, scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hqk,hkd->hqd", probs, v)
    out = out.transpose(1, 0, 2).reshape(seq, d)
    return out @ layer["wo"]


def mlp(x, layer):
    return (jax.nn.silu(x @ layer["w1"]) * (x @ layer["w3"])) @ layer["w2"]


def forward(params, tokens, cos, sin, mask, n_heads: int):
    x = jnp.take(params["emb"], tokens, axis=0)
    for layer in params["layers"]:
        x = x + attention(rmsnorm(x, layer["ln1"]), layer, n_heads, cos, sin,
                          mask)
        x = x + mlp(rmsnorm(x, layer["ln2"]), layer)
    x = rmsnorm(x, params["lnf"])
    return x @ params["emb"].T          # weight-tied logits


def make_rope_tables(rng, seq: int, dh: int):
    t = np.arange(seq)[:, None]
    freqs = 1.0 / (10000 ** (np.arange(dh // 2)[None, :] / (dh // 2)))
    ang = t * freqs
    return (jnp.asarray(np.cos(ang), jnp.float32),
            jnp.asarray(np.sin(ang), jnp.float32))


def causal_mask(seq: int):
    return jnp.asarray(np.tril(np.ones((seq, seq), bool)))[None, :, :]
