"""LLM training workload (§5.4 workload 6).

One causal-LM training step (forward, cross-entropy loss, full backward via
``jax.grad``, SGD update) on the reduced-dimension LLaMA2 architecture.
The backward pass and the weight update contribute large volumes of
medium-latency adds/muls and write traffic to every weight page — Table 3:
60% vectorizable, reuse 5.2, 88% medium / 12% high; bandwidth-intensive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.workloads import _llama

SCALES = {
    "tiny": dict(d=128, n_layers=1, n_heads=2, d_ff=256, vocab=512, seq=8),
    "paper": dict(d=768, n_layers=3, n_heads=8, d_ff=2048, vocab=8192,
                  seq=48),
}


def make_fn(scale: str = "paper"):
    p = SCALES[scale]

    def loss_fn(params, tokens, labels, cos, sin, mask):
        logits = _llama.forward(params, tokens, cos, sin, mask, p["n_heads"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def train_step(params, tokens, labels, cos, sin, mask):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, labels,
                                                  cos, sin, mask)
        new_params = jax.tree_util.tree_map(
            lambda w, g: w - 0.01 * g, params, grads)
        return loss, new_params

    return train_step


def make_inputs(scale: str = "paper", seed: int = 0):
    p = SCALES[scale]
    rng = np.random.default_rng(seed)
    params = _llama.init_params(rng, p["d"], p["n_layers"], p["n_heads"],
                                p["d_ff"], p["vocab"])
    tokens = jnp.asarray(rng.integers(0, p["vocab"], size=(p["seq"],),
                                      dtype=np.int32))
    labels = jnp.asarray(rng.integers(0, p["vocab"], size=(p["seq"],),
                                      dtype=np.int32))
    cos, sin = _llama.make_rope_tables(rng, p["seq"], p["d"] // p["n_heads"])
    mask = _llama.causal_mask(p["seq"])
    return (params, tokens, labels, cos, sin, mask)


SIM = dict(dram_frac=0.35, host_frac=0.3)
META = dict(paper_vect=60, paper_reuse=5.2, paper_low=0, paper_med=88,
            paper_high=12, kind="compute_intensive")

VECTORIZE_KW = dict(matmul_k_steps=16)
