"""XOR-filter membership workload (§5.4 workload 2).

Construction of an XOR filter is the classic *peeling* algorithm — a
data-dependent loop the auto-vectorizer cannot touch (§7): we express it as
a ``while_loop`` that lands in the control (ISP-only) region.  Queries are
the vectorizable part: three xorshift-style hash mixes (shift/xor/add — no
multiplies, matching Table 3's 1% high-latency ops), three table gathers,
an XOR-fold, and a fingerprint comparison (predication).

Table 3 targets: 16% vectorizable, reuse 2.0, 1% low / 98% medium / 1% high.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SCALES = {
    "tiny": dict(n_keys=4 * 4096, slots=2 * 4096, peel_iters=4),
    "paper": dict(n_keys=192 * 4096, slots=48 * 4096, peel_iters=24),
}


def _hash3(keys):
    """Three add/compare-mixing hashes (medium-latency arithmetic —
    Table 3: XOR filter queries are 98% medium-latency ops)."""
    h = keys + (keys >> 16)
    h = h + (h + 12345)
    h = jnp.where(h > 0, h, h + 2147483647)
    h1 = h + (h + 1013904223)
    h2 = h1 + jnp.where(h1 > keys, keys, h1 - keys)
    h3 = h2 + jnp.maximum(h1, keys) + jnp.minimum(h2, h1)
    return h1, h2, h3


def make_fn(scale: str = "paper"):
    p = SCALES[scale]
    slots = p["slots"]
    peel_iters = p["peel_iters"]

    def xor_filter(keys, table, fingerprints):
        # --- construction: peeling loop (non-vectorizable control) ---------
        def cond(c):
            i, t = c
            return i < peel_iters

        def body(c):
            i, t = c
            # peel: subtract a key's fingerprint from its three slots
            t = t ^ ((t >> 9) + i)
            return i + 1, t

        _, built = jax.lax.while_loop(cond, body, (0, table))

        # --- queries: hash + gather + fold + compare (vectorizable) --------
        h1, h2, h3 = _hash3(keys)
        i1 = jnp.abs(h1) % slots
        i2 = jnp.abs(h2) % slots
        i3 = jnp.abs(h3) % slots
        f = jnp.take(built, i1) ^ jnp.take(built, i2) ^ jnp.take(built, i3)
        member = (f & 255) == (fingerprints & 255)
        hits = jnp.where(member, 1, 0)
        return jnp.sum(hits), built

    return xor_filter


def make_inputs(scale: str = "paper", seed: int = 0):
    p = SCALES[scale]
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 2**31, size=(p["n_keys"],),
                                    dtype=np.int32))
    table = jnp.asarray(rng.integers(0, 2**31, size=(p["slots"],),
                                     dtype=np.int32))
    fp = jnp.asarray(rng.integers(0, 256, size=(p["n_keys"],),
                                  dtype=np.int32))
    return (keys, table, fp)


SIM = dict(dram_frac=0.3, host_frac=0.3)
META = dict(paper_vect=16, paper_reuse=2.0, paper_low=1, paper_med=98,
            paper_high=1, kind="io_intensive")
