"""LLaMA2 INT8 inference workload (§5.4 workload 5).

Prefill over a prompt plus greedy decode steps on the reduced-dimension
LLaMA2 architecture (see :mod:`repro.workloads._llama`).  Matmuls dominate
(mul+add pairs after decomposition), softmax contributes exp (high-latency),
RMSNorm/residuals contribute medium-latency adds, embedding lookups are
gathers (ISP-class) — Table 3: 70% vectorizable, reuse 1.8, 53% medium /
47% high.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.workloads import _llama

SCALES = {
    "tiny": dict(d=128, n_layers=1, n_heads=2, d_ff=256, vocab=512,
                 seq=8, decode_steps=1),
    "paper": dict(d=1024, n_layers=3, n_heads=8, d_ff=2816, vocab=8192,
                  seq=48, decode_steps=2),
}


def make_fn(scale: str = "paper"):
    p = SCALES[scale]

    def infer(params, tokens, cos, sin, mask):
        # prefill
        logits = _llama.forward(params, tokens, cos, sin, mask, p["n_heads"])
        nxt = jnp.argmax(logits[-1])
        outs = [nxt]
        # greedy decode (full-context recompute per emitted token)
        for _ in range(p["decode_steps"]):
            tokens = jnp.concatenate([tokens[1:], nxt[None]])
            logits = _llama.forward(params, tokens, cos, sin, mask,
                                    p["n_heads"])
            nxt = jnp.argmax(logits[-1])
            outs.append(nxt)
        return jnp.stack(outs)

    return infer


def make_inputs(scale: str = "paper", seed: int = 0):
    p = SCALES[scale]
    rng = np.random.default_rng(seed)
    params = _llama.init_params(rng, p["d"], p["n_layers"], p["n_heads"],
                                p["d_ff"], p["vocab"])
    tokens = jnp.asarray(rng.integers(0, p["vocab"], size=(p["seq"],),
                                      dtype=np.int32))
    cos, sin = _llama.make_rope_tables(rng, p["seq"], p["d"] // p["n_heads"])
    mask = _llama.causal_mask(p["seq"])
    return (params, tokens, cos, sin, mask)


SIM = dict(dram_frac=0.35, host_frac=0.3)
META = dict(paper_vect=70, paper_reuse=1.8, paper_low=0, paper_med=53,
            paper_high=47, kind="compute_intensive")

VECTORIZE_KW = dict(matmul_k_steps=16)
