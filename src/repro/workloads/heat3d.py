"""heat-3d stencil workload (Polybench, §5.4 workload 3).

Three-dimensional 7-point heat-equation stencil iterated over time steps.
Fully auto-vectorizable (95% per Table 3); high data reuse across time
steps (reuse ~16); 60% medium (adds) / 40% high (multiplies) latency mix.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SCALES = {
    "tiny": dict(n=16, tsteps=2),
    "paper": dict(n=64, tsteps=10),
}


def _step(u):
    c = u[1:-1, 1:-1, 1:-1]
    ddx = u[2:, 1:-1, 1:-1] - c * 2 + u[:-2, 1:-1, 1:-1]
    ddy = u[1:-1, 2:, 1:-1] - c * 2 + u[1:-1, :-2, 1:-1]
    ddz = u[1:-1, 1:-1, 2:] - c * 2 + u[1:-1, 1:-1, :-2]
    upd = c + ddx * 41 + ddy * 41 + ddz * 41   # INT8-quantized 0.125-scale
    return jax.lax.pad(upd, jnp.array(0, u.dtype),
                       [(1, 1, 0), (1, 1, 0), (1, 1, 0)])


def make_fn(scale: str = "paper"):
    p = SCALES[scale]

    def heat3d(u):
        for _ in range(p["tsteps"]):
            u = _step(u)
        return u

    return heat3d


def make_inputs(scale: str = "paper", seed: int = 0):
    p = SCALES[scale]
    rng = np.random.default_rng(seed)
    n = p["n"]
    u = jnp.asarray(rng.integers(-64, 64, size=(n, n, n), dtype=np.int32))
    return (u,)


SIM = dict(dram_frac=0.5, host_frac=0.4)
META = dict(paper_vect=95, paper_reuse=16, paper_low=0, paper_med=60,
            paper_high=40, kind="compute_intensive")
