"""jacobi-1d stencil workload (Polybench, §5.4 workload 4).

One-dimensional 3-point Jacobi smoother.  Table 3: 95% vectorizable,
reuse 3, 67% medium / 33% high — exactly two adds and one multiply per
point per sweep.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

SCALES = {
    "tiny": dict(n=16 * 4096, tsteps=2),
    "paper": dict(n=160 * 4096, tsteps=3),
}


def make_fn(scale: str = "paper"):
    p = SCALES[scale]

    def jacobi1d(a, b):
        for _ in range(p["tsteps"]):
            b = (a[:-2] + a[1:-1] + a[2:]) * 85          # INT8 1/3-scale
            a = jnp.concatenate([a[:1], b, a[-1:]])
        return a

    return jacobi1d


def make_inputs(scale: str = "paper", seed: int = 0):
    p = SCALES[scale]
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-64, 64, size=(p["n"],), dtype=np.int32))
    b = jnp.asarray(rng.integers(-64, 64, size=(p["n"] - 2,), dtype=np.int32))
    return (a, b)


SIM = dict(dram_frac=0.4, host_frac=0.35)
META = dict(paper_vect=95, paper_reuse=3, paper_low=0, paper_med=67,
            paper_high=33, kind="compute_intensive")
