"""The six evaluated workloads (§5.4, Table 3) as traceable JAX programs.

Each workload module exposes ``make_fn(scale)`` (the JAX program),
``make_inputs(scale, seed)`` (its inputs), ``SIM`` (simulator pressure
knobs) and ``META`` (the paper's Table 3 characterization for comparison).

``get_trace`` runs Conduit's compile-time preprocessing on the workload;
``sim_config_for`` derives the per-workload capacity pressure (the paper
sizes footprints beyond capacity to induce movement, §5.4).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

from repro.core.vectorize import Trace, vectorize
from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.machine import SimConfig
from repro.workloads import (aes, heat3d, jacobi1d, llama2_infer, llm_train,
                             xor_filter)

WORKLOADS = {
    "aes": aes,
    "xor_filter": xor_filter,
    "heat3d": heat3d,
    "jacobi1d": jacobi1d,
    "llama2_infer": llama2_infer,
    "llm_train": llm_train,
}

PAPER_ORDER = ("aes", "xor_filter", "heat3d", "jacobi1d", "llama2_infer",
               "llm_train")


@functools.lru_cache(maxsize=32)
def get_trace(name: str, scale: str = "paper",
              spec: SSDSpec = DEFAULT_SSD) -> Trace:
    mod = WORKLOADS[name]
    fn = mod.make_fn(scale)
    args = mod.make_inputs(scale)
    kw = getattr(mod, "VECTORIZE_KW", {})
    return vectorize(fn, *args, spec=spec, name=name, **kw)


def sim_config_for(name: str, trace: Trace, pressure: float = 0.0,
                   **kw) -> SimConfig:
    """Simulator config for a workload.

    ``pressure=0`` (default): capacities fit the reduced-scale footprint —
    the paper's capacity effects exist at TB scale and adding artificial
    thrash cliffs at MB scale only injects noise.  ``pressure>0`` shrinks
    SSD-DRAM/host capacity to ``(1-pressure)`` of the footprint to exercise
    the eviction + lazy-coherence machinery (see the pressure benchmark).
    """
    mod = WORKLOADS[name]
    npages = len(trace.pages)
    keep = max(0.02, 1.0 - pressure)
    return SimConfig(
        dram_capacity_pages=max(32, int(keep * mod.SIM["dram_frac"] * npages)
                                if pressure else npages + 64),
        host_capacity_pages=max(32, int(keep * mod.SIM["host_frac"] * npages)
                                if pressure else npages + 64),
        **kw)


def run_numeric(name: str, scale: str = "tiny"):
    """Execute the workload numerically (unquantized) — sanity oracle."""
    mod = WORKLOADS[name]
    fn = mod.make_fn(scale)
    args = mod.make_inputs(scale)
    return fn(*args)
