"""Batched saturation sweeps: the vectorized driver over the event engine.

:func:`~repro.sim.serving.find_saturation` answers one question — the
sustainable rate of one (catalog, policy, seed) configuration.  The
fleet/grid studies the ROADMAP targets ask it many times over: every
offloading policy x several arrival seeds x whole rate grids.  This module
is the batch layer for those sweeps:

* :func:`array_backend` — ``jax.numpy`` when JAX is importable *and*
  ``jax_enable_x64`` is on, plain ``numpy`` otherwise.  The gate is about
  correctness, not taste: the lockstep bisection below promises bit-identity
  with the scalar search, whose ``mid = 0.5 * (lo + hi)`` is IEEE double —
   32-bit jnp defaults would silently probe different rates.  JAX is never
  required; everything here runs on numpy alone.
* :func:`batched_poisson_arrival_times_ns` — the arrival times of a whole
  probe grid (``n_rates x n_sessions``) in one vectorized expression: the
  integer hash of :func:`repro.sim.machine._hash01`, the inverse-CDF
  exponential gaps and the running sum are all array ops.  Each row matches
  the scalar ``PoissonArrivals.at_rate(r).arrival_times_ns()`` loop
  (tolerance-tested; the integer hash is exact by construction, the float
  tail can differ by accumulation ulps across backends).
* :func:`batched_find_saturation` — many saturation searches in lockstep.
  Each bisection round computes *every* live lane's midpoint as one array
  op, then runs the serving probes (the event-driven core is inherently
  scalar — that is what it models).  Results are bit-identical to calling
  ``find_saturation`` per lane (tested law in ``tests/test_serving.py``):
  the probe body is shared verbatim
  (:func:`repro.sim.serving._saturation_probe`) and float64 midpoint
  arithmetic is associativity-free, so batching cannot change any probe.

Lanes, not loops: a :class:`SweepLane` is one (policy, seed, base-process)
configuration; the batch dimension is the lane list.  Per-lane engine runs
stay independent — a lane that brackets early (both endpoints decided)
drops out of the lockstep rounds without perturbing its neighbours.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.ftl import FTLConfig
from repro.sim.machine import SimConfig
from repro.sim.serving import (SaturationProbe, SaturationResult,
                               ServingConfig, _saturation_probe)
from repro.sim.tenancy import HostIOStream
from repro.sim.workgen import ArrivalProcess, PoissonArrivals, SessionCatalog

PolicyLike = Union[str, object]


def array_backend():
    """The sweep layer's array module: ``jax.numpy`` iff JAX is present
    with 64-bit mode enabled (the bisection must run in IEEE double to
    keep the bit-identity law with the scalar search), else ``numpy``."""
    try:
        import jax
        if getattr(jax.config, "jax_enable_x64", False):
            import jax.numpy as jnp
            return jnp
    except ImportError:
        pass
    import numpy as np
    return np


# -- vectorized arrival generation ---------------------------------------------

def batched_poisson_arrival_times_ns(rates_per_sec: Sequence[float],
                                     n_sessions: int,
                                     seed: int = 0x0A11,
                                     start_ns: float = 0.0,
                                     xp=None):
    """Arrival-time matrix (``len(rates) x n_sessions``) for a Poisson
    probe grid, fully vectorized.

    Row ``i`` reproduces ``PoissonArrivals(rate_per_sec=rates[i],
    n_sessions=n_sessions, seed=seed, start_ns=start_ns)
    .arrival_times_ns()``: same hashed uniforms (exact — the hash is pure
    integer arithmetic), same inverse-CDF gaps, same accumulation order
    (per-row gap scaling *then* the running sum, matching the scalar
    ``t += gap`` loop).  One expression replaces ``n_rates`` Python loops
    when a sweep wants the whole offered-load grid up front."""
    xp = xp or array_backend()
    import numpy as np                   # integer hash stays in numpy:
    rates = np.asarray(rates_per_sec, dtype=np.float64)
    if rates.ndim != 1 or rates.size == 0:
        raise ValueError("rates_per_sec must be a non-empty 1-D sequence")
    if (rates <= 0.0).any():
        raise ValueError("rates_per_sec must be > 0")
    if n_sessions < 1:
        raise ValueError("n_sessions must be >= 1")
    # _hash01, vectorized: uint64 holds iid * 2654435761 exactly and every
    # step masks back to 32 bits, so this is the scalar hash bit-for-bit
    x = (np.arange(n_sessions, dtype=np.uint64) * 2654435761
         + np.uint64(seed & 0xFFFFFFFF)) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x45D9F3B) & 0xFFFFFFFF
    x ^= x >> 16
    u = np.clip(x / 2**32, 1e-9, 0.999999)        # _exp_gap's clamp
    unit_gaps = -np.log(1.0 - u)                  # exponential(1) gaps,
    # spelled exactly as _exp_gap spells it (log(1-u), not the closer
    # log1p) so rows match the scalar loop to the ulp on one platform
    mean_gap = xp.asarray(1e9 / rates)[:, None]
    # scale each gap to its row's mean first, then accumulate — the same
    # op order as the scalar loop's ``t += -mean * log(1 - u)``
    return start_ns + xp.cumsum(mean_gap * xp.asarray(unit_gaps), axis=1)


# -- lockstep saturation search ------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepLane:
    """One lane of a batched saturation sweep: a (policy, arrivals)
    configuration searched independently of its neighbours.  ``base``
    overrides the default Poisson process (e.g. an MMPP burst lane);
    ``seed``/``n_sessions`` only apply to the default."""

    policy: PolicyLike
    seed: int = 0xA117
    n_sessions: int = 64
    base: Optional[ArrivalProcess] = None

    def base_process(self, rate_lo: float) -> ArrivalProcess:
        return self.base or PoissonArrivals(rate_per_sec=rate_lo,
                                            n_sessions=self.n_sessions,
                                            seed=self.seed)


def batched_find_saturation(catalog: SessionCatalog,
                            lanes: Sequence[SweepLane],
                            slo_p99_ns: float,
                            rate_lo: float,
                            rate_hi: float,
                            iters: int = 6,
                            spec: SSDSpec = DEFAULT_SSD,
                            config: Optional[SimConfig] = None,
                            serving: Optional[ServingConfig] = None,
                            io_stream: Optional[HostIOStream] = None,
                            ftl: Optional[FTLConfig] = None,
                            xp=None) -> List[SaturationResult]:
    """Run one saturation search per lane, bisections in lockstep.

    Bit-identical to ``[find_saturation(catalog, lane.policy, ...) for
    lane in lanes]`` — the probe body is shared
    (:func:`repro.sim.serving._saturation_probe`) and each round's
    midpoints ``0.5 * (lo + hi)`` are one float64 array op, which per
    element is exactly the scalar expression.  The batch layer buys the
    sweep shape (one call, results in lane order, lanes that resolve at
    the endpoints drop out of later rounds) without perturbing any
    individual search."""
    if rate_lo <= 0.0 or rate_hi <= rate_lo:
        raise ValueError("need 0 < rate_lo < rate_hi")
    if iters < 1:
        raise ValueError("iters must be >= 1")
    if not lanes:
        raise ValueError("need at least one SweepLane")
    xp = xp or array_backend()
    scfg = serving or ServingConfig(keep_session_results=False)

    n = len(lanes)
    bases = [lane.base_process(rate_lo) for lane in lanes]
    names = [lane.policy if isinstance(lane.policy, str)
             else lane.policy.name for lane in lanes]
    probes: List[List[SaturationProbe]] = [[] for _ in range(n)]
    results: List[Optional[SaturationResult]] = [None] * n

    def probe(i: int, rate: float) -> bool:
        return _saturation_probe(catalog, bases[i], lanes[i].policy, rate,
                                 slo_p99_ns, scfg, spec, config, io_stream,
                                 ftl, probes[i])

    # endpoint rounds: lanes where even rate_lo fails (result 0.0) or
    # rate_hi holds (result rate_hi) resolve here and leave the lockstep
    live: List[int] = []
    for i in range(n):
        if not probe(i, rate_lo):
            results[i] = SaturationResult(names[i], slo_p99_ns, 0.0,
                                          (0.0, rate_lo), probes[i])
        elif probe(i, rate_hi):
            results[i] = SaturationResult(names[i], slo_p99_ns, rate_hi,
                                          (rate_hi, rate_hi), probes[i])
        else:
            live.append(i)

    if live:
        lo = xp.full(len(live), float(rate_lo), dtype=xp.float64)
        hi = xp.full(len(live), float(rate_hi), dtype=xp.float64)
        for _ in range(iters):
            mid = 0.5 * (lo + hi)          # every live lane, one array op
            ok = xp.asarray([probe(i, float(m))
                             for i, m in zip(live, mid)], dtype=bool)
            lo = xp.where(ok, mid, lo)
            hi = xp.where(ok, hi, mid)
        for k, i in enumerate(live):
            results[i] = SaturationResult(names[i], slo_p99_ns,
                                          float(lo[k]),
                                          (float(lo[k]), float(hi[k])),
                                          probes[i])
    return results  # type: ignore[return-value]


# -- lockstep fleet saturation search ------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetSweepLane:
    """One lane of a batched *fleet* saturation sweep: a (policy, fleet
    topology, arrivals) configuration — e.g. one placement x hedging
    cell of the fleet bench grid.  ``fleet=None`` uses the caller's
    shared default."""

    policy: PolicyLike
    fleet: Optional[object] = None       # FleetConfig override
    seed: int = 0xA117
    n_sessions: int = 64
    base: Optional[ArrivalProcess] = None

    def base_process(self, rate_lo: float) -> ArrivalProcess:
        return self.base or PoissonArrivals(rate_per_sec=rate_lo,
                                            n_sessions=self.n_sessions,
                                            seed=self.seed)


def batched_find_fleet_saturation(catalog: SessionCatalog,
                                  lanes: Sequence[FleetSweepLane],
                                  slo_p99_ns: float,
                                  rate_lo: float,
                                  rate_hi: float,
                                  iters: int = 6,
                                  spec: SSDSpec = DEFAULT_SSD,
                                  config: Optional[SimConfig] = None,
                                  serving: Optional[ServingConfig] = None,
                                  fleet=None,
                                  io_stream: Optional[HostIOStream] = None,
                                  ftl: Optional[FTLConfig] = None,
                                  faults=None,
                                  min_availability: float = 1.0,
                                  xp=None) -> List[SaturationResult]:
    """Fleet saturation searches in lockstep, one per lane.

    The fleet analogue of :func:`batched_find_saturation`, with the same
    bit-identity law against the scalar search: the probe body is shared
    verbatim (:func:`repro.sim.fleet._fleet_saturation_probe`) and every
    round's midpoints are one float64 array op.  Lanes carry their own
    :class:`~repro.sim.fleet.FleetConfig` so a placement x hedging grid
    is one call."""
    from repro.sim.fleet import FleetConfig, _fleet_saturation_probe
    from repro.sim.placement import make_placement
    if rate_lo <= 0.0 or rate_hi <= rate_lo:
        raise ValueError("need 0 < rate_lo < rate_hi")
    if iters < 1:
        raise ValueError("iters must be >= 1")
    if not lanes:
        raise ValueError("need at least one FleetSweepLane")
    xp = xp or array_backend()
    scfg = serving or ServingConfig(keep_session_results=False)
    default_fleet = fleet or FleetConfig()

    n = len(lanes)
    bases = [lane.base_process(rate_lo) for lane in lanes]
    fleets = [lane.fleet or default_fleet for lane in lanes]
    names = []
    for lane, fcfg in zip(lanes, fleets):
        pol = (lane.policy if isinstance(lane.policy, str)
               else lane.policy.name)
        pl = make_placement(fcfg.placement, fcfg.n_drives).name
        names.append(f"{pol}[{pl}x{fcfg.n_drives}]")
    probes: List[List[SaturationProbe]] = [[] for _ in range(n)]
    results: List[Optional[SaturationResult]] = [None] * n

    def probe(i: int, rate: float) -> bool:
        return _fleet_saturation_probe(
            catalog, bases[i], lanes[i].policy, rate, slo_p99_ns, scfg,
            fleets[i], spec, config, io_stream, ftl, probes[i],
            faults=faults, min_availability=min_availability)

    live: List[int] = []
    for i in range(n):
        if not probe(i, rate_lo):
            results[i] = SaturationResult(names[i], slo_p99_ns, 0.0,
                                          (0.0, rate_lo), probes[i])
        elif probe(i, rate_hi):
            results[i] = SaturationResult(names[i], slo_p99_ns, rate_hi,
                                          (rate_hi, rate_hi), probes[i])
        else:
            live.append(i)

    if live:
        lo = xp.full(len(live), float(rate_lo), dtype=xp.float64)
        hi = xp.full(len(live), float(rate_hi), dtype=xp.float64)
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            ok = xp.asarray([probe(i, float(m))
                             for i, m in zip(live, mid)], dtype=bool)
            lo = xp.where(ok, mid, lo)
            hi = xp.where(ok, hi, mid)
        for k, i in enumerate(live):
            results[i] = SaturationResult(names[i], slo_p99_ns,
                                          float(lo[k]),
                                          (float(lo[k]), float(hi[k])),
                                          probes[i])
    return results  # type: ignore[return-value]
