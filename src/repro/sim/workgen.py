"""Open-loop session workload generators (the serving-regime front end).

The batch entry points (:func:`~repro.sim.machine.simulate`,
:func:`~repro.sim.tenancy.simulate_mix`) measure the makespan of a *fixed*
tenant set.  The regime the ROADMAP targets — a drive serving heavy traffic
from millions of users — is open loop: sessions keep arriving whether or
not earlier ones have finished, and the question becomes *sustainable
throughput at bounded tail latency*.  This module generates those arrivals:

* :class:`SessionCatalog` — a weighted catalog of vectorized traces
  (optionally with a per-kind policy override).  Each arriving session
  deterministically draws one catalog entry, so a serving run is a seeded
  mixture of workload kinds, not one trace repeated.
* Arrival processes, all frozen/hashable and fully seeded (the same
  inverse-CDF hashed-uniform discipline as :class:`HostIOStream`, so
  identical seeds replay identical workloads):

  - :class:`PoissonArrivals`       — memoryless open-loop arrivals, the
    canonical serving model;
  - :class:`MMPPArrivals`          — a 2-state Markov-modulated Poisson
    process (ON/OFF dwell times, different rates per state) for bursty,
    correlated traffic;
  - :class:`DeterministicArrivals` — fixed inter-arrival gap (closed-form
    offered load, useful for calibration);
  - :class:`TraceReplayArrivals`   — explicit timestamps replayed verbatim
    (production arrival logs);
  - :class:`SuperposedArrivals`    — the merge of several processes (e.g.
    a Poisson base load plus an MMPP burst source).

Every process exposes ``mean_rate_per_sec`` and ``at_rate(rate)`` — a
rescaled copy with the same shape (burstiness, replay pattern) at a new
offered load — which is what :func:`repro.sim.serving.find_saturation`
bisects over.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core.vectorize import Trace
from repro.sim.machine import _hash01


def _exp_gap(mean_ns: float, u: float) -> float:
    """Inverse-CDF exponential gap from one uniform draw (always > 0)."""
    u = min(0.999999, max(1e-9, u))
    return -mean_ns * math.log(1.0 - u)


# -- arrival processes ---------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base class: a finite, seeded stream of session arrival times."""

    def arrival_times_ns(self) -> List[float]:
        raise NotImplementedError

    @property
    def mean_rate_per_sec(self) -> float:
        """Nominal offered load (sessions per second)."""
        raise NotImplementedError

    def at_rate(self, rate_per_sec: float) -> "ArrivalProcess":
        """A copy rescaled to a new mean rate, preserving the process
        shape (burst structure, replay pattern) and the seed."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless session arrivals at ``rate_per_sec`` (open-loop)."""

    rate_per_sec: float = 1000.0
    n_sessions: int = 64
    seed: int = 0x0A11
    start_ns: float = 0.0

    def __post_init__(self) -> None:
        if self.rate_per_sec <= 0.0:
            raise ValueError("rate_per_sec must be > 0")
        if self.n_sessions < 1:
            raise ValueError("n_sessions must be >= 1")

    def arrival_times_ns(self) -> List[float]:
        mean_gap = 1e9 / self.rate_per_sec
        t = self.start_ns
        out = []
        for i in range(self.n_sessions):
            t += _exp_gap(mean_gap, _hash01(i, self.seed))
            out.append(t)
        return out

    @property
    def mean_rate_per_sec(self) -> float:
        return self.rate_per_sec

    def at_rate(self, rate_per_sec: float) -> "PoissonArrivals":
        return dataclasses.replace(self, rate_per_sec=rate_per_sec)


@dataclasses.dataclass(frozen=True)
class DeterministicArrivals(ArrivalProcess):
    """Fixed inter-arrival gap: exactly ``rate_per_sec`` offered load."""

    rate_per_sec: float = 1000.0
    n_sessions: int = 64
    start_ns: float = 0.0

    def __post_init__(self) -> None:
        if self.rate_per_sec <= 0.0:
            raise ValueError("rate_per_sec must be > 0")
        if self.n_sessions < 1:
            raise ValueError("n_sessions must be >= 1")

    def arrival_times_ns(self) -> List[float]:
        gap = 1e9 / self.rate_per_sec
        return [self.start_ns + (i + 1) * gap for i in range(self.n_sessions)]

    @property
    def mean_rate_per_sec(self) -> float:
        return self.rate_per_sec

    def at_rate(self, rate_per_sec: float) -> "DeterministicArrivals":
        return dataclasses.replace(self, rate_per_sec=rate_per_sec)


@dataclasses.dataclass(frozen=True)
class MMPPArrivals(ArrivalProcess):
    """2-state Markov-modulated Poisson process (ON/OFF burst traffic).

    The modulating chain alternates ON and OFF states with exponentially
    distributed dwell times (``mean_on_ns`` / ``mean_off_ns``); within a
    state, arrivals are Poisson at that state's rate.  ``rate_off_per_sec
    = 0`` gives classic ON/OFF bursts; a nonzero OFF rate models a base
    load with periodic surges.  The long-run mean rate is the dwell-time-
    weighted average of the two state rates."""

    rate_on_per_sec: float = 4000.0
    rate_off_per_sec: float = 0.0
    mean_on_ns: float = 10e6
    mean_off_ns: float = 10e6
    n_sessions: int = 64
    seed: int = 0x0A11
    start_ns: float = 0.0

    def __post_init__(self) -> None:
        if self.rate_on_per_sec <= 0.0:
            raise ValueError("rate_on_per_sec must be > 0")
        if self.rate_off_per_sec < 0.0:
            raise ValueError("rate_off_per_sec must be >= 0")
        if self.mean_on_ns <= 0.0 or self.mean_off_ns <= 0.0:
            raise ValueError("dwell times must be > 0")
        if self.n_sessions < 1:
            raise ValueError("n_sessions must be >= 1")

    def arrival_times_ns(self) -> List[float]:
        out: List[float] = []
        t = self.start_ns
        on = True
        dwell_i = 0          # counter for dwell-time draws
        gap_i = 0            # counter for arrival-gap draws
        dwell_seed = self.seed ^ 0xD3E11
        gap_seed = self.seed ^ 0x6A99
        while len(out) < self.n_sessions:
            mean_dwell = self.mean_on_ns if on else self.mean_off_ns
            rate = self.rate_on_per_sec if on else self.rate_off_per_sec
            dwell = _exp_gap(mean_dwell, _hash01(dwell_i, dwell_seed))
            dwell_i += 1
            if rate > 0.0:
                mean_gap = 1e9 / rate
                tau = t
                while len(out) < self.n_sessions:
                    tau += _exp_gap(mean_gap, _hash01(gap_i, gap_seed))
                    gap_i += 1
                    if tau > t + dwell:
                        break
                    out.append(tau)
            t += dwell
            on = not on
        return out

    @property
    def mean_rate_per_sec(self) -> float:
        span = self.mean_on_ns + self.mean_off_ns
        return (self.rate_on_per_sec * self.mean_on_ns
                + self.rate_off_per_sec * self.mean_off_ns) / span

    def at_rate(self, rate_per_sec: float) -> "MMPPArrivals":
        f = rate_per_sec / self.mean_rate_per_sec
        return dataclasses.replace(
            self, rate_on_per_sec=self.rate_on_per_sec * f,
            rate_off_per_sec=self.rate_off_per_sec * f)


@dataclasses.dataclass(frozen=True)
class TraceReplayArrivals(ArrivalProcess):
    """Replay an explicit arrival-time log (ns, non-decreasing)."""

    times_ns: Tuple[float, ...] = ()
    start_ns: float = 0.0

    def __post_init__(self) -> None:
        if not self.times_ns:
            raise ValueError("times_ns must be non-empty")
        if any(t < 0 for t in self.times_ns):
            raise ValueError("times_ns must be >= 0")
        if any(b < a for a, b in zip(self.times_ns, self.times_ns[1:])):
            raise ValueError("times_ns must be non-decreasing")

    def arrival_times_ns(self) -> List[float]:
        return [self.start_ns + t for t in self.times_ns]

    @property
    def mean_rate_per_sec(self) -> float:
        span = self.times_ns[-1] - self.times_ns[0]
        if span <= 0.0:
            return float("inf")
        # n arrivals over the log's span (first arrival opens the window)
        return (len(self.times_ns) - 1) / (span / 1e9)

    def at_rate(self, rate_per_sec: float) -> "TraceReplayArrivals":
        """Time-compress/stretch the log to a new mean rate (the replay
        pattern — relative gap structure — is preserved exactly)."""
        mean = self.mean_rate_per_sec
        if not math.isfinite(mean):
            # a zero-span log has no rate to rescale: f would be inf and
            # the rescaled times NaN, which float-compares its way past
            # every downstream validation
            raise ValueError("cannot rescale a zero-span replay log")
        f = mean / rate_per_sec
        base = self.times_ns[0]
        return dataclasses.replace(
            self, times_ns=tuple(base + (t - base) * f for t in self.times_ns))


@dataclasses.dataclass(frozen=True)
class SuperposedArrivals(ArrivalProcess):
    """The merge of several arrival processes (sorted interleave)."""

    parts: Tuple[ArrivalProcess, ...] = ()

    def __post_init__(self) -> None:
        if not self.parts:
            raise ValueError("superposition needs at least one process")

    def arrival_times_ns(self) -> List[float]:
        return sorted(t for p in self.parts for t in p.arrival_times_ns())

    @property
    def mean_rate_per_sec(self) -> float:
        return sum(p.mean_rate_per_sec for p in self.parts)

    def at_rate(self, rate_per_sec: float) -> "SuperposedArrivals":
        f = rate_per_sec / self.mean_rate_per_sec
        return dataclasses.replace(
            self, parts=tuple(p.at_rate(p.mean_rate_per_sec * f)
                              for p in self.parts))


# -- session catalog -----------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CatalogEntry:
    """One session kind: a vectorized trace template plus its draw weight.

    The trace is a *template*: the serving driver clones it per admitted
    session (a Trace owns mutable PageTable residency state, so concurrent
    sessions must never share one).  ``policy`` optionally overrides the
    run-wide offloading policy for sessions of this kind; ``timeout_ns``
    optionally overrides ``ServingConfig.session_timeout_ns`` — the
    host-side deadline after which an admitted session of this kind is
    abandoned (marked timed-out, slot freed)."""

    name: str
    trace: Trace
    weight: float = 1.0
    policy: Optional[str] = None
    timeout_ns: Optional[float] = None

    def __post_init__(self) -> None:
        if self.weight <= 0.0:
            raise ValueError(f"catalog entry {self.name!r}: weight must be > 0")
        if self.timeout_ns is not None and self.timeout_ns <= 0.0:
            raise ValueError(f"catalog entry {self.name!r}: timeout_ns must "
                             f"be > 0 (or None), got {self.timeout_ns}")


class SessionCatalog:
    """Weighted catalog of session kinds with a deterministic draw.

    ``draw(session_id)`` hashes the session id against the catalog seed
    into the cumulative-weight table, so the kind sequence is a pure
    function of ``(entries, seed)`` — independent of arrival times, policy
    and engine state, which keeps serving runs replayable and lets
    saturation probes at different rates serve the *same* kind sequence.
    """

    def __init__(self, entries: Sequence[CatalogEntry], seed: int = 0x5E55):
        entries = tuple(entries)
        if not entries:
            raise ValueError("session catalog needs at least one entry")
        names = [e.name for e in entries]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate catalog entry names: {names}")
        self.entries = entries
        self.seed = seed
        acc, cum = 0.0, []
        for e in entries:
            acc += e.weight
            cum.append(acc)
        self._cum = cum
        self._total = acc

    def __len__(self) -> int:
        return len(self.entries)

    def draw(self, session_id: int) -> CatalogEntry:
        """The catalog entry session ``session_id`` executes."""
        u = _hash01(session_id, self.seed ^ 0xCA7) * self._total
        return self.entries[min(len(self.entries) - 1,
                                bisect.bisect_right(self._cum, u))]

    def kind_counts(self, n_sessions: int) -> dict:
        """Kind -> draw count over the first ``n_sessions`` ids (what a
        serving run of that length will execute)."""
        out = {e.name: 0 for e in self.entries}
        for sid in range(n_sessions):
            out[self.draw(sid).name] += 1
        return out
