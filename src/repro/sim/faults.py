"""Fault injection and error recovery: the reliability subsystem.

The engine's flash was perfect: every sense decoded on the first try and
the wear histogram the GC policy suite flattens had no downstream
consequence.  Real NAND pays for reliability in *latency* — a read whose
raw bit error rate (RBER) exceeds the hard-decode ECC limit escalates
through a recovery ladder that books real time on the same contended
pools every other tenant uses — and in *capacity*: blocks that keep
producing uncorrectable reads are retired, draining the per-die reserve
until the drive degrades to read-only.  This module models both sides.

Error model (per read)
----------------------
The raw bit error rate of a page is additive in the three classic
stressors, each scaled by a :class:`FaultConfig` knob::

    rber = rber_base                                   (intrinsic)
         + rber_per_pe * erase_count(block)            (P/E wear)
         + rber_retention * age_ns / retention_scale_ns  (retention)

``erase_count`` is the FTL's real per-block wear counter, so a
wear-aware victim policy that flattens the histogram *measurably* lowers
the drive's error rate — the first quantitative payoff for wear leveling
in this repo.  ``age_ns`` is the time since the page's last program
(tracked by :meth:`FaultModel.on_program`; pages never programmed in-run
age from t=0).  The hard decoder corrects up to
``ReliabilitySpec.ecc_hard_rber``; the decode-failure probability is the
sharp threshold curve ``p_fail(e) = min(1, (e / ecc_hard_rber) **
ecc_steepness)``.

Recovery ladder (every stage is real contention)
------------------------------------------------
A failed hard decode escalates, booking each stage on the live pools:

1. **Read-retry** — up to ``max_read_retries`` re-senses at shifted
   reference voltages.  Step ``k`` books ``t_read_ns + (k+1) *
   read_retry_ns`` on the page's die plus a channel transfer, and shrinks
   the effective RBER by ``retry_rber_factor`` per step.
2. **Soft decode** — one LDPC soft-decode of ``soft_decode_ns`` on the
   controller's ECC engines (a :class:`~repro.sim.servers.ServerPool` of
   ``ecc_engines`` units that exists only while faults are active), at
   ``soft_rber_factor`` times the raw RBER.
3. **Superpage-parity rebuild** — the read is *uncorrectable*: the page
   is reconstructed by reading every sibling die of its stripe (the dies
   sharing ``die // channels`` — one per channel, so the senses run in
   parallel on distinct channels) and XORing them on the ECC engines.
   With ``parity=False``, or when a stripe sibling has failed, the data
   is gone and the read is surfaced as a **failed op** — never silently
   dropped.

Uncorrectable reads count against their block; at ``retire_after`` the
block is **retired**: surviving valid pages are relocated through the GC
machinery (real read/transfer/program bookings), the block leaves the
pool forever, and the die's free list shrinks.  While faults are active
the FTL's infinite-over-provisioning escape hatch is disabled, so a die
that runs out of physical blocks enters **read-only mode**: its writes
fail loudly (counted, surfaced) instead of hanging or silently growing.
A whole-die failure (``die_failures``) makes every read on the die a
rebuild and every write/GC a no-op from its failure time onward.

Determinism contract
--------------------
One uniform draw decides each checked read via the engine-wide
:func:`~repro.sim.machine._hash01` counter hash: draw ``i`` is a pure
function of ``(i, seed)``, and the counter advances in event order, so a
seeded run replays bit-identically.  The *same* uniform is compared
against every rung's (monotonically shrinking) failure probability, so a
read recovers at the earliest rung that can hold it — the ladder depth
is monotone in the page's RBER.  With the all-off default
``FaultConfig()`` (``.active == False``) the subsystem is never even
constructed and the engine is bit-identical to a build without this
module (pinned by the golden digests in
``tests/test_golden_equivalence.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.hw.ssd_spec import SSDSpec
from repro.sim.servers import Fabric, ServerPool


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Error-injection knobs (the *rate* side; hardware recovery costs
    live in :class:`~repro.hw.ssd_spec.ReliabilitySpec`).

    The default is all-off: ``active`` is False and the simulate wiring
    skips the subsystem entirely, bit-identical to no fault support at
    all.  ``die_failures`` is a tuple of ``(die, t_ns)`` pairs: die
    ``die`` fails hard at simulated time ``t_ns``.  ``op_timeout_ns``
    arms the host-I/O timeout/retry machinery (bounded retries with
    exponential backoff) independent of the error sources."""

    rber_base: float = 0.0            # intrinsic RBER of a fresh page
    rber_per_pe: float = 0.0          # RBER added per block erase (wear)
    rber_retention: float = 0.0       # RBER added per retention_scale_ns
    retention_scale_ns: float = 1e9   # retention-age unit
    parity: bool = True               # superpage parity rebuild available
    retire_after: int = 2             # uncorrectables before block retirement
    die_failures: Tuple[Tuple[int, float], ...] = ()
    seed: int = 0xFA17
    op_timeout_ns: Optional[float] = None  # host op timeout (None: off)
    max_op_retries: int = 2           # host op retries after a timeout
    op_retry_backoff_ns: float = 50_000.0  # base backoff, doubles per retry

    def __post_init__(self) -> None:
        for name in ("rber_base", "rber_per_pe", "rber_retention"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v}")
        if self.retention_scale_ns <= 0.0:
            raise ValueError(
                f"retention_scale_ns must be > 0, got {self.retention_scale_ns}")
        if self.retire_after < 1:
            raise ValueError(
                f"retire_after must be >= 1, got {self.retire_after}")
        for pair in self.die_failures:
            if (not isinstance(pair, tuple) or len(pair) != 2
                    or int(pair[0]) != pair[0] or pair[0] < 0
                    or pair[1] < 0.0):
                raise ValueError(
                    "die_failures entries must be (die >= 0, t_ns >= 0) "
                    f"pairs, got {pair!r}")
        if self.op_timeout_ns is not None and self.op_timeout_ns <= 0.0:
            raise ValueError(
                f"op_timeout_ns must be > 0 (or None), got {self.op_timeout_ns}")
        if self.max_op_retries < 0:
            raise ValueError(
                f"max_op_retries must be >= 0, got {self.max_op_retries}")
        if self.op_retry_backoff_ns < 0.0:
            raise ValueError("op_retry_backoff_ns must be >= 0, got "
                             f"{self.op_retry_backoff_ns}")

    @property
    def active(self) -> bool:
        """Whether any fault source (or the op-timeout machinery) is on.
        Inactive configs are treated exactly like ``faults=None``."""
        return bool(self.rber_base > 0.0 or self.rber_per_pe > 0.0
                    or self.rber_retention > 0.0 or self.die_failures
                    or self.op_timeout_ns is not None)


@dataclasses.dataclass
class FaultStats:
    """Snapshot of the fault subsystem's counters after a run."""

    n_reads_checked: int = 0          # reads that rolled the error model
    n_hard_fails: int = 0             # hard-decode failures (ladder entries)
    n_retry_reads: int = 0            # read-retry re-senses booked
    n_retry_recovered: int = 0        # reads recovered by a retry step
    n_soft_decodes: int = 0           # LDPC soft decodes booked
    n_soft_recovered: int = 0         # reads recovered by soft decode
    n_uncorrectable: int = 0          # reads past soft decode (rebuild/fail)
    n_rebuilds: int = 0               # parity reconstructions completed
    n_rebuild_reads: int = 0          # stripe-sibling senses booked
    n_failed_reads: int = 0           # unrecoverable (no parity / dead stripe)
    n_blocks_retired: int = 0
    n_pages_relocated: int = 0        # survivor pages moved by retirement
    n_failed_writes: int = 0          # writes rejected (read-only / dead die)
    n_dies_failed: int = 0            # die_failures that took effect
    n_read_only_dies: int = 0         # dies degraded to read-only
    n_op_timeouts: int = 0            # host ops past op_timeout_ns
    n_op_retries: int = 0             # host op re-issues (bounded backoff)
    n_failed_ops: int = 0             # host ops failed after the last retry
    errors_by_die: List[int] = dataclasses.field(default_factory=list)

    @property
    def recovered(self) -> int:
        return (self.n_retry_recovered + self.n_soft_recovered
                + self.n_rebuilds)

    def summary(self) -> str:
        return (f"reads checked={self.n_reads_checked} "
                f"hard-fails={self.n_hard_fails} "
                f"(retry={self.n_retry_recovered} "
                f"soft={self.n_soft_recovered} rebuild={self.n_rebuilds} "
                f"failed={self.n_failed_reads}) "
                f"retired={self.n_blocks_retired} blocks "
                f"({self.n_pages_relocated} pages relocated), "
                f"{self.n_read_only_dies} read-only dies, "
                f"{self.n_failed_writes} failed writes, "
                f"op timeouts={self.n_op_timeouts} "
                f"retries={self.n_op_retries} failed={self.n_failed_ops}")


class FaultModel:
    """Binds a :class:`FaultConfig` to one fabric: the per-read error
    roll, the recovery ladder, retirement and die-failure bookkeeping.

    Construction registers the ECC soft-decode engines as an extra
    :class:`~repro.sim.servers.ServerPool` on the fabric and sets
    ``fabric.faults`` so the host I/O model and tenant Simulations find
    the ladder.  One model serves one run (the uniform-draw counter and
    retention clocks are run state); build a fresh one per run."""

    def __init__(self, cfg: FaultConfig, spec: SSDSpec, fabric: Fabric,
                 engine) -> None:
        if not cfg.active:
            raise ValueError("FaultModel needs an active FaultConfig; "
                             "pass faults=None (or an all-off config) to "
                             "run without fault injection")
        f = spec.flash
        for die, _t in cfg.die_failures:
            if die >= f.total_dies:
                raise ValueError(
                    f"die_failures names die {die}, but the drive has "
                    f"{f.total_dies} dies")
        self.cfg = cfg
        self.rel = spec.reliability
        self.spec = spec
        self.fabric = fabric
        self.engine = engine
        self.n_dies = f.total_dies
        self.n_channels = f.channels
        # one-way page transfer (DMA + channel streaming), the ladder's
        # per-re-sense channel cost — same formula as every other reader
        self._chan_xfer_ns = f.t_dma_ns + spec.page_size * f.channel_ns_per_byte
        self.ecc = ServerPool("ecc", self.rel.ecc_engines)
        fabric.extra.append(self.ecc)
        fabric.faults = self
        # seeded-uniform draw counter: advances once per checked read, in
        # event order (the determinism contract in the module docstring)
        self._n_draws = 0
        # retention clocks: (die, blk, pg) -> last program time
        self.prog_ns: Dict[Tuple[int, int, int], float] = {}
        # uncorrectable-read counts per (die, blk) — retirement trigger
        self.uncorrectable: Dict[Tuple[int, int], int] = {}
        # per-die recovery horizon: latest completion of any ladder work,
        # read by the offload audit to flag decisions landing mid-recovery
        self.recovery_until: List[float] = [0.0] * self.n_dies
        self.dies_read_only: List[bool] = [False] * self.n_dies
        self._die_fail_ns: Dict[int, float] = {
            int(d): float(t) for d, t in cfg.die_failures}
        self._dies_failed: set = set()
        self.stats_ = FaultStats(errors_by_die=[0] * self.n_dies)
        # attached collaborators (optional)
        self.ftl = None                # FTLModel: wear counts + retirement
        self.telemetry = None          # FlightRecorder: spans + instants

    # -- attachment ------------------------------------------------------------

    def attach_ftl(self, ftl) -> None:
        """Register the FTL whose wear counters feed the error model and
        whose machinery performs block retirement."""
        self.ftl = ftl

    # -- error model -----------------------------------------------------------

    def _u(self) -> float:
        from repro.sim.machine import _hash01
        u = _hash01(self._n_draws, self.cfg.seed)
        self._n_draws += 1
        return u

    def page_rber(self, die: int, blk: int, pg: int, now: float) -> float:
        """Raw bit error rate of one physical page at time ``now``."""
        cfg = self.cfg
        rber = cfg.rber_base
        if blk >= 0 and cfg.rber_per_pe > 0.0 and self.ftl is not None:
            d = self.ftl.dies[die]
            if blk < len(d.erase_count):
                rber += cfg.rber_per_pe * d.erase_count[blk]
        if cfg.rber_retention > 0.0:
            age = now - self.prog_ns.get((die, blk, pg), 0.0)
            if age > 0.0:
                rber += cfg.rber_retention * age / cfg.retention_scale_ns
        return rber

    def _p_fail(self, rber: float) -> float:
        """Hard/soft decode failure probability at effective RBER ``rber``:
        a sharp threshold curve around the ECC correction limit."""
        if rber <= 0.0:
            return 0.0
        p = (rber / self.rel.ecc_hard_rber) ** self.rel.ecc_steepness
        return p if p < 1.0 else 1.0

    def die_dead(self, die: int, now: float) -> bool:
        t = self._die_fail_ns.get(die)
        if t is None or now < t:
            return False
        if die not in self._dies_failed:
            self._dies_failed.add(die)
            self.stats_.n_dies_failed += 1
            if self.telemetry is not None:
                self.telemetry.on_die_failure(die, t)
        return True

    def write_ok(self, die: int, now: float) -> bool:
        """Whether a host write to ``die`` can be accepted at ``now``."""
        return not (self.dies_read_only[die] or self.die_dead(die, now))

    def note_failed_write(self, die: int) -> None:
        self.stats_.n_failed_writes += 1

    def mark_read_only(self, die: int) -> None:
        """Degrade ``die`` to read-only (its physical blocks ran out)."""
        if not self.dies_read_only[die]:
            self.dies_read_only[die] = True
            self.stats_.n_read_only_dies += 1
            if self.telemetry is not None:
                self.telemetry.on_read_only(die, self.engine.now)

    @property
    def read_only(self) -> bool:
        """Whether any die has degraded to read-only mode."""
        return any(self.dies_read_only)

    # -- program/erase bookkeeping (retention clocks) --------------------------

    def on_program(self, die: int, blk: int, pg: int, t_ns: float) -> None:
        self.prog_ns[(die, blk, pg)] = t_ns

    def on_erase(self, die: int, blk: int) -> None:
        # drop every retention clock of the erased block
        prog = self.prog_ns
        stale = [k for k in prog if k[0] == die and k[1] == blk]
        for k in stale:
            del prog[k]
        # a fresh erase also clears the block's uncorrectable history
        self.uncorrectable.pop((die, blk), None)

    # -- the read-recovery ladder ----------------------------------------------

    def check_read(self, t: float, die: int, blk: int = -1,
                   pg: int = -1) -> Tuple[float, bool]:
        """Roll the error model for a page read completing at ``t``; on
        hard-decode failure, walk the recovery ladder booking real time.

        Returns ``(t_end, ok)``: the completion time including any
        recovery work, and whether the data was obtained.  ``ok=False``
        means the read is unrecoverable — the caller must surface a
        failed op.  ``blk/pg = -1`` marks reads the FTL does not map
        (NDP operand senses): they see ``rber_base`` + retention of an
        untracked page, and a lost page cannot be retired."""
        st = self.stats_
        now = self.engine.now
        if self.die_dead(die, now):
            # the die is gone: no sense possible, straight to rebuild
            st.errors_by_die[die] += 1
            return self._rebuild(t, die, blk, count_uncorrectable=False)
        rber = self.page_rber(die, blk, pg, t)
        if rber <= 0.0:
            return t, True
        st.n_reads_checked += 1
        p = self._p_fail(rber)
        if p <= 0.0:
            return t, True
        u = self._u()
        if u >= p:
            return t, True
        # hard decode failed: escalate.  The same uniform is compared to
        # each rung's shrinking failure probability (monotone ladder).
        st.n_hard_fails += 1
        st.errors_by_die[die] += 1
        rel = self.rel
        f = self.spec.flash
        dies_pool = self.fabric.dies
        chan_pool = self.fabric.channels
        chan = die % self.n_channels
        t0 = t
        eff = rber
        for k in range(rel.max_read_retries):
            eff *= rel.retry_rber_factor
            t = dies_pool.acquire_end(
                t, f.t_read_ns + rel.read_retry_ns * (k + 1), unit=die)
            t = chan_pool.acquire_end(t, self._chan_xfer_ns, unit=chan)
            st.n_retry_reads += 1
            if u >= self._p_fail(eff):
                st.n_retry_recovered += 1
                self._note_recovery(die, "read-retry", t0, t)
                return t, True
        # soft decode on the controller ECC engines
        t = self.ecc.acquire_end(t, rel.soft_decode_ns)
        st.n_soft_decodes += 1
        if u >= self._p_fail(rber * rel.soft_rber_factor):
            st.n_soft_recovered += 1
            self._note_recovery(die, "soft-decode", t0, t)
            return t, True
        # uncorrectable: parity rebuild or a failed op
        st.n_uncorrectable += 1
        self._note_recovery(die, "uncorrectable", t0, t)
        return self._rebuild(t, die, blk)

    def _note_recovery(self, die: int, stage: str, t0: float,
                       t1: float) -> None:
        if t1 > self.recovery_until[die]:
            self.recovery_until[die] = t1
        if self.telemetry is not None:
            self.telemetry.on_recovery(die, stage, t0, t1)

    def _rebuild(self, t: float, die: int, blk: int,
                 count_uncorrectable: bool = True) -> Tuple[float, bool]:
        """Superpage-parity reconstruction: read the stripe's sibling
        dies (one per channel, in parallel) and XOR on the ECC engines.
        Falls through to a failed op when parity is off or a sibling die
        is dead.  Feeds the block's retirement counter either way."""
        st = self.stats_
        now = self.engine.now
        t0 = t
        ok = False
        if self.cfg.parity:
            group = die // self.n_channels
            siblings = [group * self.n_channels + c
                        for c in range(self.n_channels)]
            siblings = [s for s in siblings if s != die and s < self.n_dies]
            if siblings and not any(self.die_dead(s, now) for s in siblings):
                f = self.spec.flash
                dies_pool = self.fabric.dies
                chan_pool = self.fabric.channels
                end = t
                for s in siblings:
                    e = dies_pool.acquire_end(t, f.t_read_ns, unit=s)
                    e = chan_pool.acquire_end(e, self._chan_xfer_ns,
                                              unit=s % self.n_channels)
                    if e > end:
                        end = e
                t = self.ecc.acquire_end(
                    end, self.rel.rebuild_xor_ns_per_page * len(siblings))
                st.n_rebuilds += 1
                st.n_rebuild_reads += len(siblings)
                ok = True
        if not ok:
            st.n_failed_reads += 1
        self._note_recovery(die, "rebuild" if ok else "read-failed", t0, t)
        if count_uncorrectable and blk >= 0:
            t = self._note_uncorrectable(die, blk, t)
        return t, ok

    def _note_uncorrectable(self, die: int, blk: int, t: float) -> float:
        """Count an uncorrectable read against its block; retire the
        block through the FTL once ``retire_after`` is reached."""
        key = (die, blk)
        n = self.uncorrectable.get(key, 0) + 1
        self.uncorrectable[key] = n
        if (n >= self.cfg.retire_after and self.ftl is not None):
            t = self.ftl.retire_block(die, blk, t)
        return t

    # -- host op timeout/retry knobs (read by the host I/O model) --------------

    def op_deadline_exceeded(self, latency_ns: float) -> bool:
        to = self.cfg.op_timeout_ns
        return to is not None and latency_ns > to

    def op_backoff_ns(self, attempt: int) -> float:
        """Exponential backoff before re-issuing a timed-out op."""
        return self.cfg.op_retry_backoff_ns * (2.0 ** attempt)

    # -- results ---------------------------------------------------------------

    def stats(self) -> FaultStats:
        return dataclasses.replace(
            self.stats_, errors_by_die=list(self.stats_.errors_by_die))
