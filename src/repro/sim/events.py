"""Discrete-event core of the SSD NDP simulator (MQSim/FTL-SIM style).

The simulator is organised around a single time-ordered event heap
(:class:`EventEngine`) plus FIFO resource queues (:class:`ServerPool` /
:class:`~repro.sim.servers.Fabric`).  Every concurrent activity in the
machine — a tenant's offloader dispatching its next vector instruction, a
host I/O request arriving at the NVMe front end, a trace's epilogue flush —
is an :class:`Event` with a typed :class:`EventKind`; handlers book time on
the contended server pools and schedule their own follow-on events.

Semantics:

* Events pop in (time, sequence) order; the sequence counter breaks ties
  deterministically, so identical inputs always replay identically.
* Timestamps are monotone: a handler may only schedule events at or after
  the engine's current time (asserted), so the global timeline never runs
  backwards — the invariant `tests/test_events.py` checks.
* Resource occupancy uses the *lazy-acquire* discipline of
  :class:`~repro.sim.servers.ServerPool`: a handler processed at time *t*
  books a unit from the unit's free time onwards, which serialises work in
  event (== dispatch) order per unit — the FIFO queue of an event-driven
  SSD simulator without materialising one pending-job list per unit.
  Caveat: a dispatch whose operands are not ready yet still reserves its
  unit *now* for a start in the future, so a later arrival (another
  tenant, a host I/O request) queues behind work that has not physically
  started even if the unit is idle in between.  This keeps single-trace
  results identical to the pre-event-engine simulator and is conservative
  (pessimistic) for cross-tenant interference; operand-ready re-queueing
  is a ROADMAP follow-on.

Single-trace runs degenerate to a single event source processed in program
order, which is why :func:`repro.sim.tenancy.simulate_mix` with one trace
reproduces :func:`repro.sim.machine.simulate` exactly.
"""
from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
from typing import Any, Callable, List, Optional, Tuple


class EventKind(enum.Enum):
    """Typed events of the NDP simulation (§5.1 simulator structure)."""

    DISPATCH = "dispatch"        # offloader decides + issues one instruction
    EPILOGUE = "epilogue"        # end-of-trace result flush to host (§4.4 ii)
    IO_ARRIVAL = "io_arrival"    # host read/write request enters the SSD
    IO_COMPLETE = "io_complete"  # host request leaves (latency accounting)
    GC = "gc"                    # FTL garbage-collection cycle (background tenant)
    TIMER = "timer"              # generic callback (tests, future policies)


@dataclasses.dataclass(frozen=True)
class Event:
    time: float
    seq: int
    kind: EventKind
    handler: Callable[["Event"], None] = dataclasses.field(compare=False)
    payload: Any = dataclasses.field(default=None, compare=False)

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class EventEngine:
    """Time-ordered event heap with deterministic tie-breaking.

    ``record=True`` keeps a ``(time, kind)`` log of every processed event —
    used by the monotonicity tests and handy for debugging interleavings.
    """

    #: tolerance for the monotone-schedule assertion (float round-off)
    EPS = 1e-6

    def __init__(self, record: bool = False):
        self.now: float = 0.0
        self.processed: int = 0
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self.record = record
        self.log: List[Tuple[float, EventKind]] = []

    def schedule(self, time: float, kind: EventKind,
                 handler: Callable[[Event], None],
                 payload: Any = None) -> Event:
        """Schedule ``handler`` at ``time`` (>= now: time cannot run back)."""
        if time < self.now - self.EPS:
            raise ValueError(
                f"event {kind} scheduled at {time} < now {self.now}")
        ev = Event(time=max(time, self.now), seq=next(self._seq),
                   kind=kind, handler=handler, payload=payload)
        heapq.heappush(self._heap, ev)
        return ev

    def empty(self) -> bool:
        return not self._heap

    def run(self, until: Optional[float] = None) -> float:
        """Process events in time order; returns the final clock value."""
        while self._heap:
            if until is not None and self._heap[0].time > until:
                break
            ev = heapq.heappop(self._heap)
            self.now = max(self.now, ev.time)
            self.processed += 1
            if self.record:
                self.log.append((self.now, ev.kind))
            ev.handler(ev)
        return self.now
