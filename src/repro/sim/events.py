"""Discrete-event core of the SSD NDP simulator (MQSim/FTL-SIM style).

The simulator is organised around a single time-ordered event heap
(:class:`EventEngine`) plus FIFO resource queues (:class:`ServerPool` /
:class:`~repro.sim.servers.Fabric`).  Every concurrent activity in the
machine — a tenant's offloader dispatching its next vector instruction, a
host I/O request arriving at the NVMe front end, a trace's epilogue flush —
is a scheduled ``(kind, handler, payload)`` record; handlers book time on
the contended server pools and schedule their own follow-on events.

Semantics:

* Events pop in (time, sequence) order; the sequence counter breaks ties
  deterministically, so identical inputs always replay identically.
* Timestamps are monotone: a handler may only schedule events at or after
  the engine's current time (asserted), so the global timeline never runs
  backwards — the invariant `tests/test_events.py` checks.
* Resource occupancy uses the *lazy-acquire* discipline of
  :class:`~repro.sim.servers.ServerPool`: a handler processed at time *t*
  books a unit from the unit's free time onwards, which serialises work in
  event (== dispatch) order per unit — the FIFO queue of an event-driven
  SSD simulator without materialising one pending-job list per unit.
  Caveat: a dispatch whose operands are not ready yet still reserves its
  unit *now* for a start in the future, so a later arrival (another
  tenant, a host I/O request) queues behind work that has not physically
  started even if the unit is idle in between.  This keeps single-trace
  results identical to the pre-event-engine simulator and is conservative
  (pessimistic) for cross-tenant interference; operand-ready re-queueing
  is a ROADMAP follow-on.

Performance notes:

* An event IS its heap entry: a plain ``(time, seq, kind, handler,
  payload)`` tuple.  Ordering is decided entirely by the ``(time, seq)``
  prefix — ``seq`` is unique, so tuple comparison never reaches the
  ``kind``/``handler``/``payload`` elements — and no per-event object or
  side-table record is ever allocated.
* Handlers take the event's *payload* directly (``handler(payload)``) —
  there is no event object to pass.  Keep them allocation-light: booking
  time on pools costs O(log k) heap pushes (see :mod:`repro.sim.servers`);
  anything that allocates per event (list comprehensions over units,
  per-call closures, rebuilding latency tables) shows up directly in
  events/sec — ``benchmarks/perf_bench.py`` tracks the trajectory in
  ``BENCH_sim_perf.json``.

Single-trace runs degenerate to a single event source processed in program
order, which is why :func:`repro.sim.tenancy.simulate_mix` with one trace
reproduces :func:`repro.sim.machine.simulate` exactly.
"""
from __future__ import annotations

import enum
from heapq import heappop, heappush
from typing import Any, Callable, List, Optional, Tuple


class EventKind(enum.Enum):
    """Typed events of the NDP simulation (§5.1 simulator structure)."""

    DISPATCH = "dispatch"        # offloader decides + issues one instruction
    EPILOGUE = "epilogue"        # end-of-trace result flush to host (§4.4 ii)
    IO_ARRIVAL = "io_arrival"    # host read/write request enters the SSD
    IO_COMPLETE = "io_complete"  # host request leaves (latency accounting)
    GC = "gc"                    # FTL garbage-collection cycle (background tenant)
    SESSION_ARRIVAL = "session_arrival"  # open-loop session enters admission
    TIMER = "timer"              # generic callback (tests, snapshots, policies)


class EventEngine:
    """Time-ordered event heap with deterministic tie-breaking.

    ``record=True`` keeps a ``(time, kind)`` log of every processed event —
    used by the monotonicity tests and handy for debugging interleavings.
    """

    #: tolerance for the monotone-schedule assertion (float round-off)
    EPS = 1e-6

    def __init__(self, record: bool = False):
        self.now: float = 0.0
        self.processed: int = 0
        # heap of (time, seq, kind, handler, payload); (time, seq) is a
        # unique sort key, the trailing elements are never compared
        self._heap: List[tuple] = []
        self._seq: int = 0
        self.record = record
        self.log: List[Tuple[float, EventKind]] = []
        # optional pure-observer flight recorder (repro.sim.telemetry);
        # attach before run() — the loop hoists it once
        self.telemetry = None
        # clock bound of the innermost run()/run_before() call, or None
        # when running to quiescence.  Event sources that batch work
        # inline past the heap (see tenancy._HostIOModel._on_arrival)
        # must not advance ``now`` to or beyond the horizon: the caller
        # may inject new events there (the fleet's advance-to-time seam).
        self.horizon: Optional[float] = None

    def schedule(self, time: float, kind: EventKind,
                 handler: Callable[[Any], None],
                 payload: Any = None) -> None:
        """Schedule ``handler(payload)`` at ``time`` (>= now: time cannot
        run back)."""
        now = self.now
        if time < now:
            if time < now - self.EPS:
                raise ValueError(
                    f"event {kind} scheduled at {time} < now {now}")
            time = now
        seq = self._seq
        self._seq = seq + 1
        heappush(self._heap, (time, seq, kind, handler, payload))

    def empty(self) -> bool:
        return not self._heap

    def next_time(self) -> Optional[float]:
        """Timestamp of the next pending event, or None if the heap is
        empty — lets arrival sources batch work that cannot interleave
        with anything (see :mod:`repro.sim.tenancy`)."""
        heap = self._heap
        return heap[0][0] if heap else None

    def run(self, until: Optional[float] = None) -> float:
        """Process events in time order; returns the final clock value."""
        heap = self._heap
        record = self.record
        tele = self.telemetry
        pop = heappop
        prev_horizon = self.horizon
        self.horizon = until
        try:
            while heap:
                time = heap[0][0]
                if until is not None and time > until:
                    break
                ev = pop(heap)
                if time > self.now:
                    self.now = time
                self.processed += 1
                if record:
                    self.log.append((self.now, ev[2]))
                if tele is not None:
                    tele.on_event(self.now, ev[2])
                ev[3](ev[4])
        finally:
            self.horizon = prev_horizon
        return self.now

    def run_before(self, t: float) -> float:
        """Process events strictly before ``t``; returns the clock.

        The advance-to-time seam of a :class:`~repro.sim.drive.DriveActor`:
        ``run(until=t)`` would also pop events at exactly ``t``, but a
        fleet front-end that is about to inject a session *at* ``t`` must
        leave same-instant events pending so their relative order against
        the injected arrival is decided by the heap's ``(time, seq)`` key,
        not by who called ``run`` first.  Bookkeeping mirrors :meth:`run`."""
        heap = self._heap
        record = self.record
        tele = self.telemetry
        pop = heappop
        prev_horizon = self.horizon
        self.horizon = t
        try:
            while heap and heap[0][0] < t:
                ev = pop(heap)
                if ev[0] > self.now:
                    self.now = ev[0]
                self.processed += 1
                if record:
                    self.log.append((self.now, ev[2]))
                if tele is not None:
                    tele.on_event(self.now, ev[2])
                ev[3](ev[4])
        finally:
            self.horizon = prev_horizon
        return self.now
