"""Open-loop serving: session churn on a live event engine (§5 scaled out).

:func:`~repro.sim.tenancy.simulate_mix` measures batch makespan of a fixed
tenant set; this module measures what a *serving* SSD is judged on —
sustainable session throughput at bounded tail latency.  Sessions drawn
from a :class:`~repro.sim.workgen.SessionCatalog` arrive according to an
open-loop :class:`~repro.sim.workgen.ArrivalProcess` and are injected into
a live :class:`~repro.sim.events.EventEngine` mid-run: each admitted
session is a fresh :class:`~repro.sim.machine.Simulation` bound to the
shared fabric at its arrival time, so late sessions contend with the
tail of early ones exactly as staggered tenants do in ``simulate_mix``.

Admission control bounds the open loop: at most
``ServingConfig.max_active_sessions`` sessions execute concurrently,
at most ``max_backlog`` wait in the admission queue, and arrivals beyond
both are *rejected* (counted, never silently dropped) — so overload
degrades into rejections and queueing delay instead of unbounded memory
growth.  Completed work frees a slot via the Simulation ``on_done`` hook
and the backlog drains FIFO.

Steady-state measurement trims warm-up and cool-down: only sessions
arriving inside ``[warmup_ns, last_arrival - cooldown_ns]`` count toward
the offered/completed rates, latency percentiles, the time-averaged
in-system occupancy (Little's L) and the interval utilization per
resource pool (busy-time deltas between two snapshot events at the window
edges; note the engine's lazy booking accrues busy time at decision time,
so near saturation a window's utilization can exceed 1.0).

:func:`find_saturation` bisects the arrival rate — deterministically, the
same probe sequence for the same inputs — for the maximum sustainable
sessions/sec under a p99 session-latency SLO with zero rejections: the
knee of the latency-throughput hockey stick, per offloading policy.

The drive under the sessions can be a *real* drive: passing
``ftl=FTLConfig(...)`` (with an ``io_stream`` whose writes feed it) runs
the page-mapping FTL of :mod:`repro.sim.ftl` underneath the session
churn, so garbage collection contends with dispatches on the shared
die/channel pools exactly as in ``simulate_mix`` — and
:func:`find_saturation` then reports the sustainable rate of a drive
that is actively collecting.

Equivalence laws (tested): one session, no churn, no admission pressure
reproduces ``simulate_mix([trace])`` bit-for-bit, and serving without an
``ftl`` is bit-identical to the pre-FTL serving subsystem — serving is a
strict generalization of the batch entry points.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple, Union

from repro.core.policies import Policy, shared_policy
from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.events import EventEngine, EventKind
from repro.sim.ftl import FTLConfig, FTLModel
from repro.sim.machine import SimConfig, Simulation
from repro.sim.servers import Fabric
from repro.sim.stats import ServingResult, SessionRecord, SessionState
from repro.sim.telemetry import TelemetryLike
from repro.sim.tenancy import HostIOStream, _HostIOModel, clone_trace
from repro.sim.workgen import ArrivalProcess, PoissonArrivals, SessionCatalog

PolicyLike = Union[str, Policy]


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Admission control + steady-state measurement knobs.

    ``max_active_sessions`` is the concurrency cap (admitted sessions
    executing on the fabric); ``max_backlog`` bounds the admission queue —
    arrivals beyond both are rejected.  ``warmup_ns``/``cooldown_ns`` trim
    the measurement window at both ends of the arrival span.
    ``record_decisions`` defaults to the fast mode (serving runs dispatch
    far too many instructions to keep one DecisionRecord each);
    ``keep_session_results`` retains one :class:`SimResult` per completed
    session (disable for large saturation sweeps).  ``pool_sessions``
    recycles completed :class:`Simulation` objects per catalog entry
    (reset instead of re-cloned — the dominant per-admission allocation);
    the pooled path is bit-identical to fresh construction (tested law),
    the flag exists as an escape hatch / for the equivalence tests.

    ``little_law_warn_tol`` bounds how far the run's Little's-law
    consistency check (:meth:`~repro.sim.stats.ServingResult.little_law_ratio`,
    L / λW ≈ 1.0 on a clean steady-state measurement) may drift before
    :func:`simulate_serving` emits a ``RuntimeWarning``.  Deviations come
    from window edge effects — sessions straddling the warm-up/cool-down
    trim, a window too short relative to session latency — and from the
    engine's lazy booking; the default 0.35 stays quiet on stable,
    properly-trimmed configurations while flagging windows that are
    measuring mostly transients.  Runs that probe overload on purpose
    (the saturation bisection, past-the-knee bench sweeps) suppress or
    opt out of the warning — pass ``float("inf")`` to disable it."""

    max_active_sessions: int = 8
    max_backlog: int = 64
    warmup_ns: float = 0.0
    cooldown_ns: float = 0.0
    record_decisions: bool = False
    keep_session_results: bool = True
    pool_sessions: bool = True
    little_law_warn_tol: float = 0.35
    # host-side session deadline: an admitted session still running this
    # long after admission is marked TIMED_OUT, its slot freed and the
    # backlog drained (the in-flight work is not revoked — the drive
    # finishes it; the *host* stopped waiting).  Catalog entries may
    # override per kind via CatalogEntry.timeout_ns.  None = no deadline.
    session_timeout_ns: Optional[float] = None

    def __post_init__(self) -> None:
        if self.max_active_sessions < 1:
            raise ValueError("max_active_sessions must be >= 1")
        if self.max_backlog < 0:
            raise ValueError("max_backlog must be >= 0")
        if self.warmup_ns < 0.0 or self.cooldown_ns < 0.0:
            raise ValueError("warmup_ns/cooldown_ns must be >= 0")
        if self.little_law_warn_tol <= 0.0:
            raise ValueError("little_law_warn_tol must be > 0")
        if self.session_timeout_ns is not None and self.session_timeout_ns <= 0.0:
            raise ValueError("session_timeout_ns must be > 0 (or None), "
                             f"got {self.session_timeout_ns}")


class _ServingDriver:
    """Binds catalog + arrivals to one engine/fabric and tracks sessions.

    This is the *drive-local* half of the serving loop: admission
    control, the backlog, session records, the occupancy integral and
    the window snapshots.  Who decides which sessions arrive is the
    *driver loop's* business — either the pre-scheduled arrival list of
    :func:`simulate_serving` (``plan=None``) or a fleet front-end
    (:mod:`repro.sim.fleet`) injecting routed sessions one at a time
    through :meth:`submit`.  Both paths share every line below, which is
    what keeps the N=1 fleet equivalence law bit-exact."""

    def __init__(self, catalog: SessionCatalog, arrival_times: List[float],
                 policy: PolicyLike, spec: SSDSpec, cfg: SimConfig,
                 scfg: ServingConfig, fabric: Fabric, engine: EventEngine,
                 window: Optional[Tuple[float, float]] = None,
                 plan: Optional[List[tuple]] = None):
        self.catalog = catalog
        self.spec = spec
        self.cfg = cfg
        self.scfg = scfg
        self.fabric = fabric
        self.engine = engine
        self.default_policy = (shared_policy(policy, spec)
                               if isinstance(policy, str) else policy)

        self.active = 0
        # optional flight recorder (repro.sim.telemetry): session spans
        self.telemetry = None
        self.backlog: Deque[int] = deque()
        self.n_rejected = 0
        self.n_admitted = 0
        self.n_completed = 0
        self.n_failed = 0
        self.n_timed_out = 0
        self.n_cancelled = 0
        # fleet seam: called (local_index, record) whenever a session
        # reaches a terminal state — None (the default) costs one branch
        self.on_terminal = None
        self.results: List = []
        self.op_latencies: List[float] = []

        # steady-state window over the arrival span; a fleet passes the
        # fleet-global window explicitly so every drive measures the same
        # steady-state span regardless of which sessions it was routed
        if window is None:
            lo = scfg.warmup_ns
            hi = max(lo, (arrival_times[-1] - scfg.cooldown_ns)
                     if arrival_times else lo)
        else:
            lo, hi = window
        self.window = (lo, hi)
        # time-averaged in-system occupancy (arrival-accepted .. done):
        # Little's L, integrated over the window only
        self._in_system = 0
        self._last_ns = 0.0
        self._area = 0.0
        # interval utilization: busy-time snapshots at the window edges
        # (scheduled before the arrivals so same-time arrivals book after
        # the opening snapshot)
        self._busy_lo: Dict[str, float] = {}
        self._busy_hi: Dict[str, float] = {}
        engine.schedule(lo, EventKind.TIMER,
                        lambda _: self._busy_lo.update(fabric.busy_ns()))
        engine.schedule(hi, EventKind.TIMER,
                        lambda _: self._busy_hi.update(fabric.busy_ns()))
        # recycled Simulation objects, keyed by catalog entry name: every
        # session of one kind shares the entry's trace/policy, so a
        # completed session's Simulation can be reset and re-admitted
        # instead of re-cloning the page table and re-allocating all the
        # per-run state (the dominant admission cost at high churn)
        self._sim_pool: Dict[str, List[Simulation]] = {}

        if plan is None:
            # one catalog draw per session, shared by the record and the
            # admission path (drawing again at admit time would double the
            # draw count and let the two diverge if a catalog were stateful)
            self.entries = [catalog.draw(i) for i in range(len(arrival_times))]
            self.records = [
                SessionRecord(sid=i, kind=e.name, arrival_ns=t,
                              measured=lo <= t <= hi)
                for i, (t, e) in enumerate(zip(arrival_times, self.entries))]
            for i, t in enumerate(arrival_times):
                engine.schedule(t, EventKind.SESSION_ARRIVAL,
                                self._on_arrival, payload=i)
        else:
            # fleet path: the placement layer drew the catalog fleet-wide
            # and routed this drive a subset — same scheduling order as
            # the default path (window snapshots first, then arrivals)
            self.entries = []
            self.records = []
            for t, entry, sid, measured in plan:
                self.submit(t, entry, sid, measured)

    # -- Little's-law occupancy integral --------------------------------------

    def _mark(self, now: float, delta: int) -> None:
        lo, hi = self.window
        seg_lo = self._last_ns if self._last_ns > lo else lo
        seg_hi = now if now < hi else hi
        if seg_hi > seg_lo:
            self._area += self._in_system * (seg_hi - seg_lo)
        if now > self._last_ns:
            self._last_ns = now
        self._in_system += delta

    # -- session lifecycle ----------------------------------------------------

    def submit(self, t_ns: float, entry, sid: int, measured: bool) -> int:
        """Fleet submit seam: enqueue one routed session arriving at
        ``t_ns``.  ``sid`` is the caller's (fleet-global) session id;
        the returned local index is what :meth:`cancel` takes.  Callable
        both at construction (the ``plan`` path) and mid-run from a
        lockstep fleet loop — the only requirement is ``t_ns >= now``."""
        i = len(self.records)
        self.entries.append(entry)
        self.records.append(SessionRecord(sid=sid, kind=entry.name,
                                          arrival_ns=t_ns,
                                          measured=measured))
        self.engine.schedule(t_ns, EventKind.SESSION_ARRIVAL,
                             self._on_arrival, payload=i)
        return i

    def cancel(self, i: int) -> bool:
        """Fleet hedging seam (cancel-on-first-win): cancel the copy at
        local index ``i`` if it is still *queued*.  Work already
        dispatched cannot be revoked — it drains on the fabric, exactly
        the session-timeout semantics — so an executing copy returns
        False and simply completes (the fleet deduplicates at its own
        record level)."""
        rec = self.records[i]
        if rec.state is not SessionState.PENDING or rec.admit_ns >= 0.0:
            return False
        try:
            self.backlog.remove(i)
        except ValueError:
            return False        # arrival not processed yet / not queued
        rec.state = SessionState.CANCELLED
        self.n_cancelled += 1
        now = self.engine.now
        self._mark(now, -1)     # a queued session was in-system
        if self.telemetry is not None:
            self.telemetry.on_session_cancel(rec.sid, rec.kind, now)
        self._terminal(i, rec)
        return True

    def _terminal(self, i: int, rec: SessionRecord) -> None:
        if self.on_terminal is not None:
            self.on_terminal(i, rec)

    def _on_arrival(self, i: int) -> None:
        now = self.engine.now
        rec = self.records[i]
        tele = self.telemetry
        if tele is not None:
            tele.on_session_arrival(rec.sid, self.entries[i].name, now)
        if self.active < self.scfg.max_active_sessions:
            self._mark(now, +1)
            self._admit(i)
        elif len(self.backlog) < self.scfg.max_backlog:
            self._mark(now, +1)             # queued sessions are in-system
            self.backlog.append(i)
        else:
            rec.state = SessionState.REJECTED
            self.n_rejected += 1
            if tele is not None:
                tele.on_session_reject(rec.sid, self.entries[i].name, now)
            self._terminal(i, rec)

    def _admit(self, i: int) -> None:
        rec = self.records[i]
        entry = self.entries[i]
        pol = (shared_policy(entry.policy, self.spec)
               if entry.policy is not None else self.default_policy)
        now = self.engine.now
        rec.admit_ns = now
        self.active += 1
        self.n_admitted += 1
        if self.telemetry is not None:
            self.telemetry.on_session_admit(rec.sid, now)
        pooled = self._sim_pool.get(entry.name)
        if pooled:
            sim = pooled.pop()
            sim.reset(f"s{rec.sid}:{entry.name}", now)
        else:
            sim = Simulation(clone_trace(entry.trace), pol, self.spec,
                             self.cfg, fabric=self.fabric,
                             tenant=f"s{rec.sid}:{entry.name}", start_ns=now)
        sim.on_done = lambda s, i=i: self._on_done(s, i)
        sim.bind(self.engine)
        timeout = (entry.timeout_ns if entry.timeout_ns is not None
                   else self.scfg.session_timeout_ns)
        if timeout is not None:
            self.engine.schedule(now + timeout, EventKind.TIMER,
                                 self._on_timeout, payload=i)

    def _on_timeout(self, i: int) -> None:
        """Host-side session deadline fired: if the session is still
        running, the host stops waiting — the slot frees and the backlog
        drains, while the in-flight work drains on the fabric (its
        completion is then a bookkeeping no-op)."""
        rec = self.records[i]
        if rec.state is not SessionState.PENDING:
            return                      # already done / failed / rejected
        rec.state = SessionState.TIMED_OUT
        self.n_timed_out += 1
        self.active -= 1
        now = self.engine.now
        self._mark(now, -1)
        if self.telemetry is not None:
            self.telemetry.on_session_timeout(rec.sid, rec.kind, now)
        self._terminal(i, rec)
        if self.backlog:
            self._admit(self.backlog.popleft())

    def _on_done(self, sim: Simulation, i: int) -> None:
        rec = self.records[i]
        rec.done_ns = sim._makespan
        if rec.state is SessionState.TIMED_OUT:
            # the host already gave up on this session: the drained work
            # only gets repooled — slot/occupancy freed at timeout time
            if self.scfg.pool_sessions:
                self._sim_pool.setdefault(
                    self.entries[i].name, []).append(sim)
            return
        if sim.failed:
            # an operand read came back unrecoverable mid-run: the
            # session drained (timing honest) but its result is garbage
            rec.state = SessionState.FAILED
            self.n_failed += 1
        else:
            rec.state = SessionState.COMPLETED
            self.n_completed += 1
        if self.telemetry is not None:
            self.telemetry.on_session_done(rec.sid, rec.kind, rec.done_ns)
        self.active -= 1
        self._mark(self.engine.now, -1)
        if rec.measured and rec.state is SessionState.COMPLETED:
            self.op_latencies.extend(sim.op_latencies)
        if self.scfg.keep_session_results:
            self.results.append(sim.result())
        self._terminal(i, rec)
        # repool AFTER every read above: reset() replaces the mutable
        # lists, so retained SimResults keep their own references
        if self.scfg.pool_sessions:
            self._sim_pool.setdefault(self.entries[i].name, []).append(sim)
        if self.backlog:
            self._admit(self.backlog.popleft())  # FIFO admission

    # -- result assembly ------------------------------------------------------

    def result(self, policy_name: str, io: Optional[_HostIOModel],
               ftl_model: Optional[FTLModel] = None) -> ServingResult:
        lo, hi = self.window
        self._mark(hi, 0)                   # close the occupancy integral
        span = hi - lo
        mean_in_system = self._area / span if span > 0.0 else 0.0
        util: Dict[str, float] = {}
        if span > 0.0 and self._busy_hi:
            units = {p.name: p.units for p in self.fabric.all_pools()}
            for name, busy in self._busy_hi.items():
                delta = busy - self._busy_lo.get(name, 0.0)
                util[name] = delta / (span * units[name])
        # the makespan is when the *drive* goes quiet, not just the last
        # session: background GC booked past the final completion (the
        # FTL tail) counts — same fold as simulate_mix.  Failed and
        # timed-out sessions drained real work, so their done times count.
        makespan = max([r.done_ns for r in self.records
                        if r.done_ns >= 0.0]
                       + ([io.last_complete_ns] if io else [])
                       + ([ftl_model.last_booked_ns]
                          if ftl_model is not None else []) + [0.0])
        fm = self.fabric.faults
        return ServingResult(
            policy=policy_name,
            sessions=self.records,
            n_offered=len(self.records),
            n_admitted=self.n_admitted,
            n_rejected=self.n_rejected,
            n_completed=self.n_completed,
            window_ns=self.window,
            mean_in_system=mean_in_system,
            op_latencies_ns=self.op_latencies,
            utilization=util,
            makespan_ns=makespan,
            host_io=io.stats() if io else None,
            session_results=(self.results
                             if self.scfg.keep_session_results else None),
            ftl=ftl_model.stats() if ftl_model is not None else None,
            n_failed=self.n_failed,
            n_timed_out=self.n_timed_out,
            faults=fm.stats() if fm is not None else None,
            n_cancelled=self.n_cancelled)


def simulate_serving(catalog: SessionCatalog,
                     arrivals: ArrivalProcess,
                     policy: PolicyLike = "conduit",
                     spec: SSDSpec = DEFAULT_SSD,
                     config: Optional[SimConfig] = None,
                     serving: Optional[ServingConfig] = None,
                     io_stream: Optional[HostIOStream] = None,
                     ftl: Optional[FTLConfig] = None,
                     engine: Optional[EventEngine] = None,
                     telemetry: TelemetryLike = None,
                     faults=None) -> ServingResult:
    """Serve an open-loop session stream on one SSD; see module docstring.

    ``policy`` is the run-wide offloading policy (catalog entries may
    override per kind); ``io_stream`` adds the same background host I/O
    as ``simulate_mix``, and ``ftl`` routes that stream's writes through
    the flash translation layer of :mod:`repro.sim.ftl` (preconditioned
    via the prefill snapshot cache) so sessions churn while the drive
    collects garbage — the full production picture.  Pass a
    ``record=True`` engine to capture the event timeline.  The run always
    drains: every admitted session reaches a terminal state, so the
    conservation law ``offered == completed + rejected + failed +
    timed_out`` holds on the result (failed and timed-out sessions exist
    only under fault injection / session timeouts — see ``faults`` and
    ``ServingConfig.session_timeout_ns``).
    ``ServingConfig.record_decisions`` governs the per-session
    DecisionRecord logging even when a ``config`` is passed (serving
    admits far too many sessions to default to full logging).
    ``telemetry`` attaches a :class:`~repro.sim.telemetry.FlightRecorder`
    across the engine, fabric, FTL, host-I/O model and session lifecycle;
    the recorder comes back on ``result.telemetry``.

    When the run's Little's-law consistency ratio deviates from 1.0 by
    more than ``ServingConfig.little_law_warn_tol``, a ``RuntimeWarning``
    is emitted: the steady-state numbers are then dominated by window
    edge effects and should not be trusted as sustained-load metrics."""
    scfg = serving or ServingConfig()
    cfg = dataclasses.replace(config or SimConfig(),
                              record_decisions=scfg.record_decisions)
    arrival_times = arrivals.arrival_times_ns()
    if any(t < 0 for t in arrival_times):
        raise ValueError("arrival times must be >= 0")
    if any(b < a for a, b in zip(arrival_times, arrival_times[1:])):
        raise ValueError("arrival times must be non-decreasing")
    # an over-long warmup/cooldown trim leaves a zero-length measurement
    # window: every steady-state metric (rates, percentiles, occupancy,
    # utilization) silently reads 0.0 — fail loudly at the entry point
    # instead.  Zero trim with a degenerate span (single arrival at 0.0)
    # stays legal: that is the batch-equivalence configuration.
    if arrival_times and (scfg.warmup_ns > 0.0 or scfg.cooldown_ns > 0.0):
        if arrival_times[-1] - scfg.cooldown_ns <= scfg.warmup_ns:
            raise ValueError(
                f"empty measurement window: warmup_ns={scfg.warmup_ns:g} + "
                f"cooldown_ns={scfg.cooldown_ns:g} swallow the arrival span "
                f"(last arrival at {arrival_times[-1]:g} ns) — every "
                "steady-state metric would silently read zero")

    # the whole one-drive wiring (engine, fabric, fault model, telemetry,
    # driver, FTL, host I/O) lives in DriveActor: simulate_serving IS a
    # one-actor run driven to quiescence, which is what makes the N=1
    # fleet equivalence law hold by construction rather than by parallel
    # maintenance of two wiring orders.  Lazy import: drive.py imports
    # this module for the driver/config types.
    from repro.sim.drive import DriveActor
    actor = DriveActor(catalog, policy, spec, cfg, scfg,
                       arrival_times=arrival_times, io_stream=io_stream,
                       ftl=ftl, faults=faults, engine=engine,
                       telemetry=telemetry)
    actor.drain()
    res = actor.result()
    if res.session_latencies_ns:
        ratio = res.little_law_ratio()
        tol = scfg.little_law_warn_tol
        if not (abs(ratio - 1.0) <= tol):
            warnings.warn(
                f"little_law_ratio {ratio:.3f} deviates from 1.0 beyond "
                f"tolerance {tol:g}: the measurement window is dominated "
                "by edge effects (sessions straddling warmup/cooldown, or "
                "a window short relative to session latency) — widen the "
                "window before trusting the steady-state metrics",
                RuntimeWarning, stacklevel=2)
    return res


# -- saturation-point finder ---------------------------------------------------

@dataclasses.dataclass
class SaturationProbe:
    """One bisection probe: the serving run at one offered rate.

    ``completed_rate_per_sec`` is the *goodput* — only sessions that ran
    to completion count, so under fault injection it diverges from the
    admitted rate.  ``p99_ns`` is NaN when no session latency could be
    measured (every in-window arrival bounced, failed or timed out);
    ``availability`` then carries the verdict instead."""

    rate_per_sec: float
    p99_ns: float
    n_rejected: int
    completed_rate_per_sec: float
    sustainable: bool
    availability: float = 1.0        # completed / (completed+failed+timed out)
    n_failed: int = 0
    n_timed_out: int = 0


@dataclasses.dataclass
class SaturationResult:
    """Output of :func:`find_saturation` for one policy.

    ``rate_per_sec`` is the highest probed rate that met the SLO with
    zero rejections (0.0 if even ``rate_lo`` was unsustainable);
    ``bracket`` is the final (sustainable, unsustainable) rate pair the
    bisection narrowed to."""

    policy: str
    slo_p99_ns: float
    rate_per_sec: float
    bracket: Tuple[float, float]
    probes: List[SaturationProbe]

    def summary(self) -> Dict[str, object]:
        return {
            "policy": self.policy,
            "slo_p99_us": self.slo_p99_ns / 1e3,
            "saturation_per_sec": round(self.rate_per_sec, 1),
            "bracket_per_sec": (round(self.bracket[0], 1),
                                round(self.bracket[1], 1)),
            "probes": len(self.probes),
        }


def _saturation_probe(catalog: SessionCatalog, base: ArrivalProcess,
                      policy: PolicyLike, rate: float, slo_p99_ns: float,
                      scfg: ServingConfig, spec: SSDSpec,
                      config: Optional[SimConfig],
                      io_stream: Optional[HostIOStream],
                      ftl: Optional[FTLConfig],
                      probes: List[SaturationProbe],
                      faults=None,
                      min_availability: float = 1.0) -> bool:
    """One bisection probe: serve ``base.at_rate(rate)``, append the
    :class:`SaturationProbe`, return sustainability.  Shared verbatim by
    :func:`find_saturation` and the batched lockstep search in
    :mod:`repro.sim.sweep` so the two can never drift apart."""
    # the bisection probes unsustainable rates on purpose: past the knee
    # the Little's-law ratio always degrades, so the edge-effect warning
    # carries no information here — sustainability is judged on
    # rejections, availability and the p99 directly
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message="little_law_ratio",
                                category=RuntimeWarning)
        res = simulate_serving(catalog, base.at_rate(rate), policy,
                               spec=spec, config=config, serving=scfg,
                               io_stream=io_stream, ftl=ftl, faults=faults)
    avail = res.availability
    # a measured-but-uncompleted window (every session failed/timed out/
    # bounced) is a legitimate *unsustainable* verdict — distinguish it
    # via the session terminal states instead of the old NaN-p99-only
    # convention; p99 stays NaN when nothing completed in-window
    p99 = res.p(99) if res.session_latencies_ns else float("nan")
    if res.n_rejected > 0:
        # rejections alone prove the rate unsustainable — even when
        # every in-window arrival bounced and no latency was measured
        # (then there is no p99 to report: record NaN, not the
        # empty-percentile 0.0 that would masquerade as a great tail)
        probes.append(SaturationProbe(
            rate, p99, res.n_rejected, res.completed_rate_per_sec, False,
            availability=avail, n_failed=res.n_failed,
            n_timed_out=res.n_timed_out))
        return False
    if not any(s.measured for s in res.sessions):
        raise ValueError(
            f"no measured sessions at rate {rate:.1f}/s: warmup/cooldown "
            f"trim ({scfg.warmup_ns:.0f}+{scfg.cooldown_ns:.0f} ns) "
            "swallows the arrival span — an empty window would make "
            "every rate look sustainable")
    ok = (avail >= min_availability
          and bool(res.session_latencies_ns) and p99 <= slo_p99_ns)
    probes.append(SaturationProbe(rate, p99, 0,
                                  res.completed_rate_per_sec, ok,
                                  availability=avail, n_failed=res.n_failed,
                                  n_timed_out=res.n_timed_out))
    return ok


def find_saturation(catalog: SessionCatalog,
                    policy: PolicyLike,
                    slo_p99_ns: float,
                    rate_lo: float,
                    rate_hi: float,
                    base_process: Optional[ArrivalProcess] = None,
                    iters: int = 6,
                    n_sessions: int = 64,
                    seed: int = 0xA117,
                    spec: SSDSpec = DEFAULT_SSD,
                    config: Optional[SimConfig] = None,
                    serving: Optional[ServingConfig] = None,
                    io_stream: Optional[HostIOStream] = None,
                    ftl: Optional[FTLConfig] = None,
                    faults=None,
                    min_availability: float = 1.0
                    ) -> SaturationResult:
    """Bisect the offered rate for the max sustainable sessions/sec.

    A rate is *sustainable* iff the serving run rejects nothing and its
    measured p99 session latency meets ``slo_p99_ns``.  The bisection is
    deterministic: probes are a pure function of the inputs (the arrival
    process is rescaled via ``at_rate``, preserving seed and shape), so
    repeated calls — and parallel benchmark workers — produce identical
    results.  ``base_process`` defaults to Poisson arrivals with
    ``n_sessions``/``seed``; pass an MMPP or replay process to find the
    saturation point under bursty traffic instead.  ``ftl`` (with an
    ``io_stream`` whose writes drive the collector) finds the saturation
    point of a drive that is actively collecting garbage — GC steals
    sustainable session throughput, measurably.

    ``faults`` threads a :class:`~repro.sim.faults.FaultConfig` through
    every probe, and sustainability then additionally requires
    ``availability >= min_availability`` — the bisection reports the max
    rate at which the drive still delivers its *goodput* SLO while
    walking recovery ladders and retiring blocks."""
    if rate_lo <= 0.0 or rate_hi <= rate_lo:
        raise ValueError("need 0 < rate_lo < rate_hi")
    if iters < 1:
        raise ValueError("iters must be >= 1")
    if not 0.0 < min_availability <= 1.0:
        raise ValueError(
            f"min_availability must be in (0, 1], got {min_availability}")
    base = base_process or PoissonArrivals(rate_per_sec=rate_lo,
                                           n_sessions=n_sessions, seed=seed)
    scfg = serving or ServingConfig(keep_session_results=False)
    probes: List[SaturationProbe] = []

    def probe(rate: float) -> bool:
        return _saturation_probe(catalog, base, policy, rate, slo_p99_ns,
                                 scfg, spec, config, io_stream, ftl, probes,
                                 faults=faults,
                                 min_availability=min_availability)

    name = policy if isinstance(policy, str) else policy.name
    if not probe(rate_lo):
        return SaturationResult(name, slo_p99_ns, 0.0, (0.0, rate_lo), probes)
    if probe(rate_hi):
        return SaturationResult(name, slo_p99_ns, rate_hi,
                                (rate_hi, rate_hi), probes)
    lo, hi = rate_lo, rate_hi
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if probe(mid):
            lo = mid
        else:
            hi = mid
    return SaturationResult(name, slo_p99_ns, lo, (lo, hi), probes)
