"""Flight recorder: engine tracing, offload-decision audit, interval metrics.

The simulator so far only reports *aggregate* outcomes (makespans,
percentiles, counters).  This module adds a :class:`FlightRecorder` that
hooks into the event engine, the server pools, the dispatch loop, the FTL
collector and the serving driver as a **pure observer** — zero overhead
when off (the default: every hook site is one ``is not None`` branch),
and bit-identical simulation results when on (the recorder never books
time, never mutates simulation state, and its sampler events carry
pure-read handlers; ``tests/test_telemetry.py`` pins the golden digests
with telemetry fully enabled).

Three products from one hook layer:

1. **Chrome-trace / Perfetto spans** — one track per pool unit (every
   die, channel, compute core, the DRAM bus, PCIe, the offloader), GC
   cycle/copy/erase spans per die, session-lifecycle async spans, and
   host-I/O request spans.  Drop the exported JSON into
   ``chrome://tracing`` or https://ui.perfetto.dev.
2. **Offload-decision audit stream** — per dispatch, the six cost
   features (Table 1) for *every* candidate resource, each candidate's
   Eqn-1 total, and the chosen resource; :meth:`OffloadAudit.explain`
   renders one decision end-to-end.  This stream subsumes the legacy
   ``DecisionRecord`` logging: the record type now lives here (re-exported
   by :mod:`repro.sim.stats` for compatibility) and
   ``SimConfig.record_decisions`` keeps its exact semantics as the thin
   always-available slice of the audit stream.
3. **Interval time-series metrics** — sampled on TIMER events every
   ``TelemetryConfig.interval_ns``: per-pool utilization (busy-time delta
   over the interval), queue depth (pending booked work), GC-busy die
   count, serving backlog/active sessions, and a sliding-window p99 of
   per-op latency; plus a per-instruction latency breakdown (decide vs
   data movement vs queue wait vs compute) aggregated by (op, resource).

Trace schema (``conduit-flight-recorder/v1``)
--------------------------------------------

The export is standard Chrome Trace Event JSON (object form)::

    {
      "traceEvents": [...],          # ts/dur in MICROseconds
      "displayTimeUnit": "ns",
      "otherData": {
        "schema": "conduit-flight-recorder/v1",
        "event_counts": {kind: n},           # engine events by EventKind
        "audit": [ {tenant, iid, op, policy, t_decide_ns, chosen,
                    chosen_total_ns, replayed, candidates: [
                      {resource, supported, latency_comp_ns,
                       latency_dm_ns, delay_dd_ns, delay_queue_ns,
                       total_ns} ]} ],
        "intervals": [ {t_ns, utilization: {pool: x}, queue_depth_ns:
                        {pool: ns}, gc_active_dies, backlog,
                        active_sessions, p99_op_ns} ],
        "breakdown": [ {op, resource, count, decide_ns, dm_ns,
                        queue_ns, compute_ns, total_ns} ],   # sums
        "ops": [ {tenant, iid, op, resource, unit, deps, t_decide_ns,
                  decide_end_ns, ready_ns, move_end_ns, start_ns,
                  end_ns, dm_ns, replayed} ],   # per-dispatch phase record
        "meta": {spec_sha, policy, seed, entry, telemetry: {...}},
        "dropped_spans": n, "dropped_audit": n,  # loud truncation counts
        "dropped_ops": n
      }
    }

The ``ops`` stream (one record per dispatched instruction, with the
exact phase boundaries ``t_decide <= decide_end <= ready <= move_end <=
start <= end`` and the instruction's dependency iids) is what
:mod:`repro.sim.analysis` joins against the session/GC/reliability spans
for tail-latency blame and critical-path extraction; ``meta`` carries
the reproducibility fingerprint (spec hash, policy, seed, telemetry
config) that lets ``analysis diff`` refuse apples-to-oranges
comparisons.  Both are additive to schema v1: traces without them stay
valid, and consumers degrade gracefully.

``traceEvents`` uses five phases: ``"X"`` complete spans (pool bookings
on pid 1 "fabric", GC activity on pid 2 "ftl-gc"), ``"b"``/``"e"`` async
spans (sessions on pid 3, host I/O on pid 4 — every ``b`` has a matching
``e``, including rejected sessions), ``"i"`` instants (admissions,
rejections, GC suspends), ``"C"`` counters (pid 5 "metrics": the interval
samples, rendered as counter tracks by Perfetto), and ``"M"`` metadata
naming processes/threads.  :func:`validate_trace` checks all of this
structurally; the ``summarize``/``validate`` CLI::

    python -m repro.sim.telemetry summarize trace.json
    python -m repro.sim.telemetry validate  trace.json

Wiring: pass ``telemetry=TelemetryConfig(...)`` (or a ``FlightRecorder``)
to :func:`repro.sim.machine.simulate`,
:func:`repro.sim.tenancy.simulate_mix` or
:func:`repro.sim.serving.simulate_serving`; the recorder comes back on
``result.telemetry``.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import math
import re
import sys
from collections import deque
from typing import (Any, Callable, Deque, Dict, List, Optional, TextIO,
                    Tuple, Union)

from repro.core.isa import Resource
from repro.sim.events import EventEngine, EventKind

SCHEMA = "conduit-flight-recorder/v1"

# fixed Chrome-trace process ids (named via "M" metadata on export)
PID_FABRIC = 1      # one thread per (pool, unit): every booking is a span
PID_FTL = 2         # one thread per die: GC cycle / copy / erase spans
PID_SESSIONS = 3    # async b/e per session (arrival -> done/reject)
PID_HOST_IO = 4     # async b/e per host request (arrival -> complete)
PID_METRICS = 5     # "C" counter tracks fed by the interval sampler
PID_RELIABILITY = 6  # per-die recovery/rebuild spans, retirement events

_NS_TO_US = 1e-3    # Chrome-trace ts/dur are microseconds


@dataclasses.dataclass
class DecisionRecord:
    """One dispatch outcome — the always-available slice of the audit
    stream (:class:`OffloadAudit` is the telemetry-enabled superset with
    per-candidate costs).  ``SimConfig.record_decisions`` governs whether
    the simulator keeps one of these per dispatch; re-exported by
    :mod:`repro.sim.stats` for existing callers."""

    iid: int
    op: str
    resource: Resource
    t_decide: float
    t_start: float
    t_end: float
    dm_ns: float
    replayed: bool = False


@dataclasses.dataclass(frozen=True)
class CandidateCost:
    """One candidate resource's six-feature cost vector at decision time
    (Table 1 / Eqn 1): what the policy saw, per resource it considered."""

    resource: str
    supported: bool
    latency_comp_ns: float
    latency_dm_ns: float
    delay_dd_ns: float
    delay_queue_ns: float
    total_ns: float          # latency_comp + latency_dm + max(dd, queue)

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class OffloadAudit:
    """One offloading decision end-to-end: the six cost features per
    candidate, every candidate's Eqn-1 total, and the chosen resource."""

    tenant: str
    iid: int
    op: str
    policy: str
    t_decide_ns: float
    chosen: str
    chosen_total_ns: float
    candidates: Tuple[CandidateCost, ...]
    replayed: bool = False
    # fault injection: the decision sent work to a die whose recovery
    # ladder (retry/soft-decode/rebuild) was still draining at decide
    # time — the queue features the policy saw included recovery work
    mid_recovery: bool = False

    def explain(self) -> str:
        """Render the decision as a table: features -> costs -> choice."""
        lines = [
            f"dispatch iid={self.iid} op={self.op!r} tenant={self.tenant!r}"
            f" policy={self.policy} at t={self.t_decide_ns:.0f} ns",
            f"  {'resource':<10} {'sup':<4} {'comp_ns':>12} {'dm_ns':>12}"
            f" {'dd_ns':>12} {'queue_ns':>12} {'total_ns':>12}",
        ]
        for c in self.candidates:
            mark = "->" if c.resource == self.chosen else "  "
            total = "inf" if math.isinf(c.total_ns) else f"{c.total_ns:.0f}"
            comp = "inf" if math.isinf(c.latency_comp_ns) \
                else f"{c.latency_comp_ns:.0f}"
            lines.append(
                f"{mark}{c.resource:<10} {str(c.supported):<4} {comp:>12}"
                f" {c.latency_dm_ns:>12.0f} {c.delay_dd_ns:>12.0f}"
                f" {c.delay_queue_ns:>12.0f} {total:>12}")
        lines.append(
            f"  chosen: {self.chosen}"
            f" (total {self.chosen_total_ns:.0f} ns"
            f"{', replayed on fault' if self.replayed else ''}"
            f"{', landed mid-recovery' if self.mid_recovery else ''})")
        return "\n".join(lines)

    def as_dict(self) -> Dict[str, object]:
        return {
            "tenant": self.tenant, "iid": self.iid, "op": self.op,
            "policy": self.policy, "t_decide_ns": self.t_decide_ns,
            "chosen": self.chosen, "chosen_total_ns": self.chosen_total_ns,
            "replayed": self.replayed, "mid_recovery": self.mid_recovery,
            "candidates": [c.as_dict() for c in self.candidates],
        }


@dataclasses.dataclass
class IntervalSample:
    """One sampler tick: the drive's state over the last interval."""

    t_ns: float
    utilization: Dict[str, float]      # pool -> busy delta / interval
    queue_depth_ns: Dict[str, float]   # pool -> pending booked work
    gc_active_dies: int
    backlog: int
    active_sessions: int
    p99_op_ns: float                   # sliding-window per-op p99

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """What the flight recorder captures.

    ``spans`` drives product (1) (pool/GC/session/IO spans), ``audit``
    product (2) (per-candidate cost vectors — recomputed read-only from
    the policy's own feature derivation, so enabling it cannot perturb
    the decision), ``interval_ns > 0`` product (3) (the TIMER sampler;
    0 disables sampling).  ``sliding_window`` sizes the p99 window;
    ``max_spans`` / ``max_audit`` cap memory with *loud* truncation —
    the export carries ``dropped_spans`` / ``dropped_audit`` counts and
    ``summarize`` reports them, never silently."""

    spans: bool = True
    audit: bool = True
    interval_ns: float = 0.0
    sliding_window: int = 512
    max_spans: int = 200_000
    max_audit: int = 100_000

    def __post_init__(self) -> None:
        if self.interval_ns < 0.0:
            raise ValueError("interval_ns must be >= 0 (0 = sampler off)")
        if self.sliding_window < 1:
            raise ValueError("sliding_window must be >= 1")
        if self.max_spans < 1 or self.max_audit < 1:
            raise ValueError("max_spans/max_audit must be >= 1")


TelemetryLike = Union[None, bool, TelemetryConfig, "FlightRecorder"]


def as_recorder(telemetry: TelemetryLike) -> Optional["FlightRecorder"]:
    """Normalize the ``telemetry=`` argument of the simulate entry points:
    ``None``/``False`` -> no recorder, ``True`` -> default config,
    a :class:`TelemetryConfig` -> fresh recorder, a recorder -> itself."""
    if telemetry is None or telemetry is False:
        return None
    if telemetry is True:
        return FlightRecorder()
    if isinstance(telemetry, TelemetryConfig):
        return FlightRecorder(telemetry)
    if isinstance(telemetry, FlightRecorder):
        return telemetry
    raise TypeError(f"telemetry must be None/bool/TelemetryConfig/"
                    f"FlightRecorder, got {type(telemetry).__name__}")


class FlightRecorder:
    """Pure-observer recorder for one simulation run.

    Attach with :meth:`attach` (fabric and/or engine), plus
    :meth:`attach_ftl` / :meth:`attach_host_io` / :meth:`attach_serving`
    for the optional subsystems; the entry points in
    :mod:`repro.sim.machine` / :mod:`repro.sim.tenancy` /
    :mod:`repro.sim.serving` do all of this when given ``telemetry=``.

    Invariants the hook sites rely on (and the golden tests pin):

    * no method ever books pool time or mutates engine/simulation state —
      sampler TIMER events only *read* (pool busy/pending probes and the
      registered lambdas), so interleaving them shifts event sequence
      numbers without changing any simulated timestamp;
    * ``ctx`` is written by the handler that is about to book pool time
      (dispatch, epilogue, GC, host I/O) and read by the pool tracer to
      attribute the booking's span — it never feeds back into simulation.
    """

    def __init__(self, config: Optional[TelemetryConfig] = None):
        self.cfg = config or TelemetryConfig()
        #: attribution label for the next pool booking (set by handlers)
        self.ctx: Optional[str] = None
        #: structured attribution for the next pool booking — a dict the
        #: handler shares across every booking of one dispatch/GC step
        #: (lossless join key: the span name alone would need parsing)
        self.ctx_args: Optional[dict] = None

        # product 1: spans
        self.spans: List[dict] = []          # "X" on fabric/ftl pids
        self.async_events: List[dict] = []   # "b"/"e"/"i"
        self.counters: List[dict] = []       # "C" from the sampler
        self.dropped_spans = 0
        self._meta: List[dict] = []
        self._tids: Dict[Tuple[int, str], int] = {}

        # product 2: audit + breakdown
        self.audit: List[OffloadAudit] = []
        self.dropped_audit = 0
        # (op, resource) -> [count, decide, dm, queue, compute, total] sums
        self.breakdown: Dict[Tuple[str, str], List[float]] = {}

        # per-dispatch phase records for post-hoc analysis (blame /
        # critical path): plain dicts, exported under otherData["ops"]
        self.ops: List[dict] = []
        self.dropped_ops = 0
        # reproducibility fingerprint, filled by the simulate entry
        # points (policy, seed, entry) and at export time (spec hash)
        self.run_meta: Dict[str, object] = {}

        # product 3: interval samples
        self.intervals: List[IntervalSample] = []
        self.sample_probes: Dict[str, Callable[[], float]] = {}
        self._latwin: Deque[float] = deque(maxlen=self.cfg.sliding_window)

        self.event_counts: Dict[str, int] = {}
        self._engine: Optional[EventEngine] = None
        self._fabric = None
        self._faults = None
        self._prev_busy: Dict[str, float] = {}
        self._prev_t = 0.0
        self._sampler_on = False

    # -- attachment -----------------------------------------------------------

    def attach(self, fabric=None, engine: Optional[EventEngine] = None
               ) -> "FlightRecorder":
        """Hook into a fabric (pool-booking tracer) and/or engine (event
        counts + interval sampler).  Idempotent; returns self."""
        if fabric is not None:
            self._fabric = fabric
            fabric.telemetry = self
            if self.cfg.spans:
                tracer = self._on_booking
                for p in fabric.all_pools():
                    p.tracer = tracer
        if engine is not None:
            self._engine = engine
            engine.telemetry = self
        self._start_sampler()
        return self

    def attach_ftl(self, ftl_model) -> None:
        """Register the FTL: GC span hooks plus the gc-busy sampler probe."""
        ftl_model.telemetry = self
        self.sample_probes["gc_active_dies"] = \
            lambda: ftl_model.gc_active_dies

    def attach_host_io(self, io_model) -> None:
        """Register the host I/O model for request-lifecycle spans."""
        io_model.telemetry = self

    def attach_faults(self, fault_model) -> None:
        """Register the fault subsystem: recovery/retirement spans, die
        failure / read-only instants, and the mid-recovery flag on the
        offload audit.  The ECC pool is created after :meth:`attach` has
        already set the pool tracers, so it is wired here."""
        fault_model.telemetry = self
        self._faults = fault_model
        if self.cfg.spans:
            fault_model.ecc.tracer = self._on_booking

    def attach_serving(self, driver) -> None:
        """Register the serving driver: session-lifecycle spans plus the
        backlog / active-session sampler probes."""
        driver.telemetry = self
        self.sample_probes["backlog"] = lambda: len(driver.backlog)
        self.sample_probes["active_sessions"] = lambda: driver.active

    def _start_sampler(self) -> None:
        eng = self._engine
        if (self._sampler_on or eng is None or self._fabric is None
                or self.cfg.interval_ns <= 0.0):
            return
        self._sampler_on = True
        self._prev_busy = {p.name: p.busy_ns
                           for p in self._fabric.all_pools()}
        self._prev_t = eng.now
        eng.schedule(eng.now + self.cfg.interval_ns, EventKind.TIMER,
                     self._on_sample)

    # -- engine hook ----------------------------------------------------------

    def on_event(self, t: float, kind: EventKind) -> None:
        """Called by the engine run loop (and the host-I/O burst batcher,
        which mirrors the loop's bookkeeping) for every processed event."""
        c = self.event_counts
        k = kind.value
        c[k] = c.get(k, 0) + 1

    # -- pool-booking tracer (product 1) --------------------------------------

    def _tid(self, pid: int, name: str) -> int:
        key = (pid, name)
        t = self._tids.get(key)
        if t is None:
            t = len(self._tids) + 1
            self._tids[key] = t
            self._meta.append({"ph": "M", "name": "thread_name",
                               "pid": pid, "tid": t,
                               "args": {"name": name}})
        return t

    def _on_booking(self, pool_name: str, unit: int, start: float,
                    end: float) -> None:
        """ServerPool tracer: one "X" span per acquire on the unit's
        track, named by the current ``ctx`` attribution."""
        if len(self.spans) >= self.cfg.max_spans:
            self.dropped_spans += 1
            return
        ev = {
            "ph": "X", "pid": PID_FABRIC,
            "tid": self._tid(PID_FABRIC, f"{pool_name}/{unit}"),
            "name": self.ctx or "?",
            "ts": start * _NS_TO_US, "dur": (end - start) * _NS_TO_US,
        }
        if self.ctx_args is not None:
            # shared by reference across one dispatch's bookings — the
            # handlers build one dict per dispatch, not per booking
            ev["args"] = self.ctx_args
        self.spans.append(ev)

    def _gc_span(self, die: int, name: str, t0: float, t1: float,
                 args: Optional[dict] = None) -> None:
        if len(self.spans) >= self.cfg.max_spans:
            self.dropped_spans += 1
            return
        ev = {"ph": "X", "pid": PID_FTL,
              "tid": self._tid(PID_FTL, f"die{die}"),
              "name": name, "ts": t0 * _NS_TO_US,
              "dur": (t1 - t0) * _NS_TO_US}
        if args:
            ev["args"] = args
        self.spans.append(ev)

    # -- dispatch hook (products 2 + 3) ---------------------------------------

    def on_dispatch(self, tenant: str, policy: str, instr, resource,
                    feats, t_decide: float, decide_end: float,
                    ready: float, move_end: float, start: float,
                    end: float, dm_ns: float,
                    replayed: bool = False,
                    unit: Optional[int] = None) -> None:
        """Called once per dispatched instruction, after all bookings.

        ``feats`` is the per-candidate :class:`~repro.core.cost.Features`
        dict (None when the audit product is off) — computed by the
        policy's own read-only ``_feats`` derivation right after the
        selection, before any booking mutated pool state, so it is the
        exact decision-time view.  ``unit`` is the die an IFP decision
        executed on (None otherwise): under fault injection the audit
        flags decisions that landed on a die whose recovery ladder was
        still draining at decide time."""
        lat = end - t_decide
        self._latwin.append(lat)
        rname = resource.value
        key = (instr.op, rname)
        row = self.breakdown.get(key)
        if row is None:
            row = self.breakdown[key] = [0, 0.0, 0.0, 0.0, 0.0, 0.0]
        row[0] += 1
        row[1] += decide_end - t_decide      # decision overhead window
        row[2] += move_end - ready           # operand data movement
        row[3] += start - move_end           # queue wait at the exec pool
        row[4] += end - start                # compute occupancy
        row[5] += lat
        if self.cfg.spans:
            # per-dispatch phase record for the analysis layer (blame /
            # critical path) — the aggregated breakdown above cannot be
            # joined back to a session or a dependency chain
            if len(self.ops) >= self.cfg.max_spans:
                self.dropped_ops += 1
            else:
                self.ops.append({
                    "tenant": tenant, "iid": instr.iid, "op": instr.op,
                    "resource": rname, "unit": unit,
                    "deps": list(instr.deps),
                    "t_decide_ns": t_decide, "decide_end_ns": decide_end,
                    "ready_ns": ready, "move_end_ns": move_end,
                    "start_ns": start, "end_ns": end, "dm_ns": dm_ns,
                    "replayed": replayed})
        if feats is None:
            return
        if len(self.audit) >= self.cfg.max_audit:
            self.dropped_audit += 1
            return
        cands = tuple(
            CandidateCost(r.value, f.supported, f.latency_comp,
                          f.latency_dm, f.delay_dd, f.delay_queue, f.total)
            for r, f in feats.items())
        chosen = feats.get(resource)
        fm = self._faults
        mid_recovery = (fm is not None and unit is not None
                        and fm.recovery_until[unit] > t_decide)
        self.audit.append(OffloadAudit(
            tenant=tenant, iid=instr.iid, op=instr.op, policy=policy,
            t_decide_ns=t_decide, chosen=rname,
            chosen_total_ns=(chosen.total if chosen is not None
                             else float("nan")),
            candidates=cands, replayed=replayed,
            mid_recovery=mid_recovery))

    # -- GC hooks (product 1) -------------------------------------------------

    def on_gc_cycle(self, die: int, victim: int, t0: float, t1: float,
                    pages_copied: int) -> None:
        if self.cfg.spans:
            self._gc_span(die, f"gc-cycle b{victim}", t0, t1,
                          {"die": die, "victim": victim,
                           "pages_copied": pages_copied})

    def on_gc_copy(self, die: int, t0: float, t1: float,
                   kind: str = "copy") -> None:
        if self.cfg.spans:
            self._gc_span(die, f"gc-{kind}", t0, t1, {"die": die})

    def on_gc_suspend(self, die: int, t: float) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "i", "pid": PID_FTL,
                "tid": self._tid(PID_FTL, f"die{die}"),
                "name": "gc-suspend", "ts": t * _NS_TO_US, "s": "t"})

    # -- reliability hooks (product 1, fault injection) -----------------------

    def _rel_span(self, die: int, name: str, t0: float, t1: float,
                  args: Optional[dict] = None) -> None:
        if len(self.spans) >= self.cfg.max_spans:
            self.dropped_spans += 1
            return
        ev = {"ph": "X", "pid": PID_RELIABILITY,
              "tid": self._tid(PID_RELIABILITY, f"die{die}"),
              "name": name, "ts": t0 * _NS_TO_US,
              "dur": (t1 - t0) * _NS_TO_US}
        if args:
            ev["args"] = args
        self.spans.append(ev)

    def on_recovery(self, die: int, stage: str, t0: float,
                    t1: float) -> None:
        """One recovery-ladder stage on a die: read-retry, soft-decode,
        uncorrectable, rebuild or read-failed — span on the die's track."""
        if self.cfg.spans:
            self._rel_span(die, f"recovery:{stage}", t0, t1,
                           {"die": die, "stage": stage})

    def on_retirement(self, die: int, blk: int, t0: float, t1: float,
                      relocated: int) -> None:
        """Bad-block retirement: the survivor-relocation span."""
        if self.cfg.spans:
            self._rel_span(die, f"retire b{blk}", t0, t1,
                           {"die": die, "pages_relocated": relocated})

    def on_die_failure(self, die: int, t: float) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "i", "pid": PID_RELIABILITY,
                "tid": self._tid(PID_RELIABILITY, f"die{die}"),
                "name": "die-failure", "ts": t * _NS_TO_US, "s": "t"})

    def on_read_only(self, die: int, t: float) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "i", "pid": PID_RELIABILITY,
                "tid": self._tid(PID_RELIABILITY, f"die{die}"),
                "name": "read-only", "ts": t * _NS_TO_US, "s": "t"})

    # -- session hooks (product 1) --------------------------------------------

    def on_session_arrival(self, sid: int, kind: str, t: float) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "b", "cat": "session", "id": sid,
                "pid": PID_SESSIONS, "tid": 0,
                "name": f"session:{kind}", "ts": t * _NS_TO_US})

    def on_session_admit(self, sid: int, t: float) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "i", "pid": PID_SESSIONS, "tid": 0,
                "name": f"admit s{sid}", "ts": t * _NS_TO_US, "s": "t"})

    def on_session_done(self, sid: int, kind: str, t: float) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "e", "cat": "session", "id": sid,
                "pid": PID_SESSIONS, "tid": 0,
                "name": f"session:{kind}", "ts": t * _NS_TO_US})

    def on_session_timeout(self, sid: int, kind: str, t: float) -> None:
        # close the async span at abandonment time (the in-flight work
        # drains unobserved) and mark the deadline miss
        if self.cfg.spans:
            ts = t * _NS_TO_US
            self.async_events.append({
                "ph": "e", "cat": "session", "id": sid,
                "pid": PID_SESSIONS, "tid": 0,
                "name": f"session:{kind}", "ts": ts,
                "args": {"timed_out": True}})
            self.async_events.append({
                "ph": "i", "pid": PID_SESSIONS, "tid": 0,
                "name": f"timeout s{sid}", "ts": ts, "s": "t"})

    def on_session_cancel(self, sid: int, kind: str, t: float) -> None:
        # a hedged twin lost the race while still queued: close the
        # async span (b/e balance) and mark the revocation
        if self.cfg.spans:
            ts = t * _NS_TO_US
            self.async_events.append({
                "ph": "e", "cat": "session", "id": sid,
                "pid": PID_SESSIONS, "tid": 0,
                "name": f"session:{kind}", "ts": ts,
                "args": {"cancelled": True}})
            self.async_events.append({
                "ph": "i", "pid": PID_SESSIONS, "tid": 0,
                "name": f"cancel s{sid}", "ts": ts, "s": "t"})

    def on_session_reject(self, sid: int, kind: str, t: float) -> None:
        # close the async span so b/e stay balanced, and mark the bounce
        if self.cfg.spans:
            ts = t * _NS_TO_US
            self.async_events.append({
                "ph": "e", "cat": "session", "id": sid,
                "pid": PID_SESSIONS, "tid": 0,
                "name": f"session:{kind}", "ts": ts,
                "args": {"rejected": True}})
            self.async_events.append({
                "ph": "i", "pid": PID_SESSIONS, "tid": 0,
                "name": f"reject s{sid}", "ts": ts, "s": "t"})

    # -- host-I/O hooks (product 1) -------------------------------------------

    def on_io_issue(self, req: int, arrival_ns: float, is_read: bool,
                    die: int) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "b", "cat": "host_io", "id": req,
                "pid": PID_HOST_IO, "tid": 0,
                "name": f"io:{'read' if is_read else 'write'}",
                "ts": arrival_ns * _NS_TO_US, "args": {"die": die}})

    def on_io_complete(self, req: int, is_read: bool, t: float) -> None:
        if self.cfg.spans:
            self.async_events.append({
                "ph": "e", "cat": "host_io", "id": req,
                "pid": PID_HOST_IO, "tid": 0,
                "name": f"io:{'read' if is_read else 'write'}",
                "ts": t * _NS_TO_US})

    def on_io_timeout(self, req: int, is_read: bool, t: float) -> None:
        """Op-timeout detected: close the attempt's async span (the retry
        re-issues a fresh ``b`` for the same id) and mark the deadline."""
        if self.cfg.spans:
            ts = t * _NS_TO_US
            self.async_events.append({
                "ph": "e", "cat": "host_io", "id": req,
                "pid": PID_HOST_IO, "tid": 0,
                "name": f"io:{'read' if is_read else 'write'}",
                "ts": ts, "args": {"timed_out": True}})
            self.async_events.append({
                "ph": "i", "pid": PID_HOST_IO, "tid": 0,
                "name": f"io-timeout r{req}", "ts": ts, "s": "t"})

    # -- interval sampler (product 3) -----------------------------------------

    def _on_sample(self, _payload=None) -> None:
        """TIMER handler: sample, emit counters, re-arm while work remains.

        Pure reads only — pool busy/pending probes and the registered
        lambdas never mutate simulation state, so the extra TIMER events
        shift sequence numbers without changing any simulated timestamp
        (the telemetry-on golden-digest law)."""
        eng = self._engine
        now = eng.now
        dt = now - self._prev_t
        util: Dict[str, float] = {}
        qdepth: Dict[str, float] = {}
        prev = self._prev_busy
        for p in self._fabric.all_pools():
            busy = p.busy_ns
            if dt > 0.0:
                # busy time accrues at (lazy) booking time, so a heavily
                # booked interval can read > 1.0 — same caveat as the
                # serving window utilization
                util[p.name] = (busy - prev.get(p.name, 0.0)) \
                    / (dt * p.units)
            prev[p.name] = busy
            qdepth[p.name] = p.pending_work_ns(now)
        self._prev_t = now
        probes = self.sample_probes
        gc_dies = int(probes["gc_active_dies"]()) \
            if "gc_active_dies" in probes else 0
        backlog = int(probes["backlog"]()) if "backlog" in probes else 0
        active = int(probes["active_sessions"]()) \
            if "active_sessions" in probes else 0
        p99 = _p99(self._latwin)
        self.intervals.append(IntervalSample(
            t_ns=now, utilization=util, queue_depth_ns=qdepth,
            gc_active_dies=gc_dies, backlog=backlog,
            active_sessions=active, p99_op_ns=p99))
        ts = now * _NS_TO_US
        counters = self.counters
        if util:
            counters.append({"ph": "C", "pid": PID_METRICS, "tid": 0,
                             "name": "utilization", "ts": ts,
                             "args": {k: round(v, 4)
                                      for k, v in util.items()}})
        counters.append({"ph": "C", "pid": PID_METRICS, "tid": 0,
                         "name": "queue_depth_ns", "ts": ts,
                         "args": {k: round(v, 1)
                                  for k, v in qdepth.items()}})
        counters.append({"ph": "C", "pid": PID_METRICS, "tid": 0,
                         "name": "drive", "ts": ts,
                         "args": {"gc_active_dies": gc_dies,
                                  "backlog": backlog,
                                  "active_sessions": active,
                                  "p99_op_ns": p99}})
        # re-arm only while the run is live: the sampler must not keep an
        # otherwise-drained engine spinning (runs end when the heap does)
        if not eng.empty():
            eng.schedule(now + self.cfg.interval_ns, EventKind.TIMER,
                         self._on_sample)

    # -- export ---------------------------------------------------------------

    def breakdown_rows(self) -> List[Dict[str, object]]:
        """Per-(op, resource) latency breakdown — summed ns per phase."""
        rows = []
        for (op, res), row in sorted(self.breakdown.items()):
            rows.append({"op": op, "resource": res, "count": int(row[0]),
                         "decide_ns": row[1], "dm_ns": row[2],
                         "queue_ns": row[3], "compute_ns": row[4],
                         "total_ns": row[5]})
        return rows

    def chrome_trace(self) -> Dict[str, object]:
        """Assemble the full Chrome-trace object (see module docstring)."""
        events: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": PID_FABRIC,
             "args": {"name": "fabric"}},
            {"ph": "M", "name": "process_name", "pid": PID_FTL,
             "args": {"name": "ftl-gc"}},
            {"ph": "M", "name": "process_name", "pid": PID_SESSIONS,
             "args": {"name": "sessions"}},
            {"ph": "M", "name": "process_name", "pid": PID_HOST_IO,
             "args": {"name": "host-io"}},
            {"ph": "M", "name": "process_name", "pid": PID_METRICS,
             "args": {"name": "metrics"}},
            {"ph": "M", "name": "process_name", "pid": PID_RELIABILITY,
             "args": {"name": "reliability"}},
        ]
        events += self._meta
        events += self.spans
        events += self.async_events
        events += self.counters
        # reproducibility fingerprint: entry-point facts (policy, seed,
        # entry) stamped into run_meta by the simulate_* wrappers, plus a
        # hash of the hardware spec and the telemetry knobs — computed at
        # export time only, never on the hot path
        meta: Dict[str, object] = dict(self.run_meta)
        if self._fabric is not None:
            meta["spec_sha"] = hashlib.sha256(
                repr(self._fabric.spec).encode()).hexdigest()[:16]
        meta["telemetry"] = dataclasses.asdict(self.cfg)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ns",
            "otherData": {
                "schema": SCHEMA,
                "event_counts": dict(self.event_counts),
                "audit": [a.as_dict() for a in self.audit],
                "intervals": [s.as_dict() for s in self.intervals],
                "breakdown": self.breakdown_rows(),
                "ops": self.ops,
                "meta": meta,
                "dropped_spans": self.dropped_spans,
                "dropped_audit": self.dropped_audit,
                "dropped_ops": self.dropped_ops,
            },
        }

    def export(self, path: str) -> Dict[str, object]:
        """Write the Chrome-trace JSON to ``path``; returns the object."""
        obj = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj


def _p99(values) -> float:
    """Nearest-rank p99 over the sliding window (0.0 when empty).

    Thin delegate to :func:`repro.sim.stats.percentile` — one validated
    percentile implementation everywhere (the import is deferred because
    ``stats`` imports :class:`DecisionRecord` from this module)."""
    from repro.sim.stats import percentile
    return percentile(list(values), 99.0)


# -- fleet trace merging -------------------------------------------------------

def merge_fleet_trace(traces: List[Any]) -> Dict[str, object]:
    """Merge per-drive traces into one fleet Chrome-trace timeline.

    ``traces`` is the ``FleetResult.telemetry`` list (one
    :class:`FlightRecorder` or exported trace dict per drive, index =
    drive id; ``None`` entries are skipped).  Merge arithmetic, reversed
    by :func:`repro.sim.analysis.split_fleet_trace`:

    * pids: drive ``k``'s process ``p`` becomes ``10*k + p`` (the six
      base pids stay < 10, so ``pid // 10`` recovers the drive and
      ``pid % 10`` the base process);
    * process names gain a ``d{k}:`` prefix (``d0:fabric``,
      ``d3:reliability``, ...) — the vocabulary
      :func:`validate_trace` checks;
    * async span ids gain a ``d{k}/`` prefix so hedged twins of one
      fleet session (same sid on two drives) stay distinct spans;
    * ``otherData`` record streams (audit / intervals / breakdown /
      ops) are concatenated with a ``"drive": k`` tag on every record;
      ``meta`` keeps drive 0's keys plus ``n_drives`` and the per-drive
      ``drives`` list."""
    events: List[dict] = []
    event_counts: Dict[str, int] = {}
    streams: Dict[str, List[dict]] = {
        "audit": [], "intervals": [], "breakdown": [], "ops": []}
    metas: List[dict] = []
    dropped = {"dropped_spans": 0, "dropped_audit": 0, "dropped_ops": 0}
    for k, t in enumerate(traces):
        if t is None:
            continue
        if hasattr(t, "chrome_trace"):
            t = t.chrome_trace()
        for ev in t.get("traceEvents", []):
            ev = dict(ev)
            pid = ev.get("pid")
            if isinstance(pid, int):
                ev["pid"] = 10 * k + pid
            if ev.get("ph") == "M" and ev.get("name") == "process_name":
                ev["args"] = {"name": f"d{k}:{ev['args']['name']}"}
            if ev.get("ph") in ("b", "e") and "id" in ev:
                ev["id"] = f"d{k}/{ev['id']}"
            events.append(ev)
        other = t.get("otherData", {})
        for kind, cnt in (other.get("event_counts") or {}).items():
            event_counts[kind] = event_counts.get(kind, 0) + cnt
        for name, acc in streams.items():
            for rec in other.get(name) or []:
                rec = dict(rec)
                rec["drive"] = k
                acc.append(rec)
        metas.append(dict(other.get("meta") or {}))
        for dk in dropped:
            dropped[dk] += other.get(dk, 0)
    meta: Dict[str, object] = dict(metas[0]) if metas else {}
    meta["entry"] = "simulate_fleet"
    meta["n_drives"] = len(traces)
    meta["drives"] = metas
    out: Dict[str, object] = {
        "traceEvents": events,
        "displayTimeUnit": "ns",
        "otherData": {"schema": SCHEMA, "event_counts": event_counts,
                      "meta": meta, **streams, **dropped},
    }
    return out


def export_fleet_trace(traces: List[Any], path: str) -> Dict[str, object]:
    """Merge (:func:`merge_fleet_trace`) and write to ``path``."""
    obj = merge_fleet_trace(traces)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


# -- validation / summary ------------------------------------------------------

_LEGAL_PH = frozenset("XMbeiC")

#: legal drive-prefixed process names in a merged fleet trace — exactly
#: the six base processes behind a ``d<number>:`` prefix
_DRIVE_PROC_RE = re.compile(
    r"^d\d+:(fabric|ftl-gc|sessions|host-io|metrics|reliability)$")


def validate_trace(obj: Any) -> List[str]:
    """Structural validation of an exported trace; returns error strings
    (empty = valid).  Checks the envelope, the schema tag, every event's
    phase/timestamps, non-negative span durations, b/e balance per
    (cat, id), per-track counter monotonicity and non-negative counter
    values, and the reliability process's span/instant vocabulary —
    everything :func:`summarize` and :mod:`repro.sim.analysis` rely on."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return [f"trace must be a JSON object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        errors.append("missing/invalid traceEvents list")
        events = []
    other = obj.get("otherData")
    if not isinstance(other, dict):
        errors.append("missing/invalid otherData object")
        other = {}
    schema = other.get("schema")
    if schema != SCHEMA:
        errors.append(f"otherData.schema is {schema!r}, expected {SCHEMA!r}")
    # pid -> process name, so the reliability checks below don't depend on
    # metadata/event ordering in the list
    pname: Dict[Any, str] = {}
    for ev in events:
        if isinstance(ev, dict) and ev.get("ph") == "M" \
                and ev.get("name") == "process_name":
            args = ev.get("args")
            if isinstance(args, dict):
                pname[ev.get("pid")] = args.get("name")
    # merged fleet traces prefix every process with "d<drive>:"; anything
    # that *looks* drive-prefixed but doesn't resolve to a known base
    # process is a malformed merge, not a new vocabulary
    for pid, name in sorted(pname.items(), key=lambda kv: str(kv[0])):
        if isinstance(name, str) and name.startswith("d") and ":" in name \
                and not _DRIVE_PROC_RE.match(name):
            errors.append(
                f"process {pid}: malformed drive-prefixed process name "
                f"{name!r} (expected d<drive>:<fabric|ftl-gc|sessions|"
                f"host-io|metrics|reliability>)")
    open_async: Dict[Tuple[str, Any], int] = {}
    last_counter_ts: Dict[Tuple[Any, Any, Any], float] = {}
    for n, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event #{n}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _LEGAL_PH:
            errors.append(f"event #{n}: illegal ph {ph!r}")
            continue
        if ph != "M":
            ts = ev.get("ts")
            if not isinstance(ts, (int, float)):
                errors.append(f"event #{n} ({ph}): non-numeric ts {ts!r}")
            if "pid" not in ev:
                errors.append(f"event #{n} ({ph}): missing pid")
        proc = pname.get(ev.get("pid"))
        if isinstance(proc, str) and _DRIVE_PROC_RE.match(proc):
            # per-drive track of a merged fleet trace: the base
            # process's vocabulary rules apply unchanged
            proc = proc.split(":", 1)[1]
        if proc == "reliability":
            name = ev.get("name", "")
            if ph == "X" and not (name.startswith("recovery:")
                                  or name.startswith("retire b")):
                errors.append(f"event #{n}: unknown reliability span "
                              f"{name!r}")
            elif ph == "i" and name not in ("die-failure", "read-only"):
                errors.append(f"event #{n}: unknown reliability instant "
                              f"{name!r}")
        if ph == "C":
            key = (ev.get("pid"), ev.get("tid"), ev.get("name"))
            ts = ev.get("ts")
            if isinstance(ts, (int, float)):
                prev = last_counter_ts.get(key)
                if prev is not None and ts < prev:
                    errors.append(
                        f"event #{n} (C): non-monotonic counter track "
                        f"{key[2]!r} (ts {ts} < {prev})")
                else:
                    last_counter_ts[key] = ts
            args = ev.get("args")
            if isinstance(args, dict):
                for k, v in args.items():
                    if isinstance(v, (int, float)) and v < 0:
                        errors.append(f"event #{n} (C): negative counter "
                                      f"value {k}={v}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event #{n} (X): bad dur {dur!r}")
        elif ph in "be":
            key = (ev.get("cat"), ev.get("id"))
            if key[0] is None or key[1] is None:
                errors.append(f"event #{n} ({ph}): missing cat/id")
                continue
            if ph == "b":
                open_async[key] = open_async.get(key, 0) + 1
            else:
                cnt = open_async.get(key, 0)
                if cnt <= 0:
                    errors.append(f"event #{n} (e): unmatched end {key}")
                else:
                    open_async[key] = cnt - 1
    for key, cnt in open_async.items():
        if cnt != 0:
            errors.append(f"async span {key}: {cnt} unmatched begin(s)")
    for field in ("audit", "intervals", "breakdown", "ops"):
        val = other.get(field)
        if val is not None and not isinstance(val, list):
            errors.append(f"otherData.{field} must be a list")
    for i, a in enumerate(other.get("audit") or []):
        if not isinstance(a, dict) or "chosen" not in a \
                or "candidates" not in a:
            errors.append(f"audit #{i}: missing chosen/candidates")
            break
    ops = other.get("ops")
    if isinstance(ops, list):
        required = ("tenant", "iid", "t_decide_ns", "end_ns")
        for i, o in enumerate(ops):
            if not isinstance(o, dict) \
                    or any(k not in o for k in required):
                errors.append(f"ops #{i}: missing one of {required}")
                break
    return errors


def summarize(obj: Any) -> Dict[str, object]:
    """Condense a validated trace: span counts per process, engine event
    counts, audit/interval sizes, and the heaviest (op, resource) rows.
    Raises ``ValueError`` on an invalid trace — the round-trip law is
    that ``validate`` accepts everything ``summarize`` accepts."""
    errors = validate_trace(obj)
    if errors:
        raise ValueError("invalid trace: " + "; ".join(errors[:5]))
    events = obj["traceEvents"]
    other = obj.get("otherData", {})
    pname: Dict[Any, str] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            pname[ev.get("pid")] = ev["args"]["name"]
    spans_by_proc: Dict[str, int] = {}
    phases: Dict[str, int] = {}
    for ev in events:
        ph = ev["ph"]
        phases[ph] = phases.get(ph, 0) + 1
        if ph == "X":
            name = pname.get(ev.get("pid"), str(ev.get("pid")))
            spans_by_proc[name] = spans_by_proc.get(name, 0) + 1
    rows = sorted(other.get("breakdown") or [],
                  key=lambda r: -r.get("total_ns", 0.0))
    return {
        "schema": other.get("schema"),
        "n_events": len(events),
        "phases": phases,
        "spans_by_process": spans_by_proc,
        "engine_event_counts": other.get("event_counts", {}),
        "n_audit": len(other.get("audit") or []),
        "n_intervals": len(other.get("intervals") or []),
        "n_ops": len(other.get("ops") or []),
        "dropped_spans": other.get("dropped_spans", 0),
        "dropped_audit": other.get("dropped_audit", 0),
        "dropped_ops": other.get("dropped_ops", 0),
        "top_breakdown": rows[:5],
    }


def main(argv: Optional[List[str]] = None,
         out: TextIO = sys.stdout) -> int:
    """``python -m repro.sim.telemetry summarize|validate <trace.json>``"""
    ap = argparse.ArgumentParser(
        prog="repro.sim.telemetry",
        description="Inspect flight-recorder traces "
                    f"(schema {SCHEMA})")
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, hlp in (("summarize", "print a condensed trace summary"),
                      ("validate", "structurally validate a trace")):
        p = sub.add_parser(name, help=hlp)
        p.add_argument("trace", help="path to an exported trace JSON")
        if name == "summarize":
            p.add_argument("--json", action="store_true",
                           help="emit one compact machine-readable JSON "
                                "line (sorted keys) instead of the "
                                "pretty-printed summary")
    args = ap.parse_args(argv)
    try:
        with open(args.trace) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read {args.trace}: {e}", file=out)
        return 2
    errors = validate_trace(obj)
    if args.cmd == "validate":
        if errors:
            for e in errors:
                print(f"INVALID: {e}", file=out)
            return 1
        print(f"OK: {args.trace} is a valid {SCHEMA} trace "
              f"({len(obj['traceEvents'])} events)", file=out)
        return 0
    if errors:
        print(f"error: invalid trace ({errors[0]})", file=out)
        return 1
    s = summarize(obj)
    if getattr(args, "json", False):
        print(json.dumps(s, sort_keys=True, separators=(",", ":")),
              file=out)
    else:
        print(json.dumps(s, indent=2), file=out)
    return 0


if __name__ == "__main__":                       # pragma: no cover
    sys.exit(main())
