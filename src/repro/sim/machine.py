"""Discrete-event SSD NDP simulator (§5.1-§5.2).

Inherits MQSim's structural model — channels/dies as contended units, L2P
mapping with a DFTL-style cache, per-resource execution queues — and adds
the five Conduit NDP extensions (§5.1): (1) an internal DRAM model,
(2) compute models for ISP / PuD-SSD / IFP, (3) dedicated execution queues
per compute resource, (4) offloader-coupled scheduling of operand movement,
(5) NDP-aware page placement (same-block constraint for MWS ops).

Execution is driven by the time-ordered event heap in
:mod:`repro.sim.events`: each trace's offloader core emits ``DISPATCH``
events (in-order issue, pipelined across offloader cores, charging the §4.5
overhead); the handler decides a target resource, books operand movement
over the contended links, books execution on the resource's FIFO queue, and
schedules the next dispatch.  Instruction *completion* is therefore
out-of-order — across resources within one trace, and across tenants when
several traces share one :class:`~repro.sim.servers.Fabric` (see
:func:`repro.sim.tenancy.simulate_mix`).  A single trace degenerates to one
event source processed in program order, so :func:`simulate` is the exact
single-tenant special case of the event engine.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cost import (HOME, HOME_BY_INDEX, SystemView, dm_energy_nj,
                             exec_energy_nj, exec_latency_ns)
from repro.core.isa import Location, Resource, VectorInstr
from repro.core.policies import Policy, make_policy
from repro.core.vectorize import Trace
from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.events import EventEngine, EventKind
from repro.sim.servers import Fabric, ServerPool
from repro.sim.stats import SimResult
from repro.sim.telemetry import DecisionRecord, TelemetryLike, as_recorder


@dataclasses.dataclass
class SimConfig:
    dram_capacity_pages: Optional[int] = None    # default: footprint/8
    host_capacity_pages: Optional[int] = None    # default: footprint/4
    fail_rate: float = 0.0                       # transient-fault injection
    move_outputs_to_host: bool = True            # epilogue (§4.4 trigger ii)
    pud_units: int = 8                           # per-bank bbop engines
    seed: int = 0x5AFA11
    # False = fast mode: skip allocating one DecisionRecord per dispatch
    # (open-loop serving runs at high arrival rates would otherwise
    # accumulate unbounded per-dispatch records).  Timing/energy results
    # are bit-identical either way; per-op latencies stay available via
    # SimResult.op_latencies_ns, which is a plain float list.
    record_decisions: bool = True


STATIC_DISPATCH_NS = 200.0   # queue-push cost for compile-time-mapped policies
BUFFER_DEPTH = 4             # pages buffered per plane (S/A/B/C data latches)

# hot-loop constants (module-level load beats enum-class attribute chain)
_DISPATCH = EventKind.DISPATCH
_EPILOGUE = EventKind.EPILOGUE


def _hash01(iid: int, seed: int) -> float:
    x = (iid * 2654435761 + seed) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x45D9F3B) & 0xFFFFFFFF
    x ^= x >> 16
    return x / 2**32


def _zero_queue(r: Resource) -> float:
    """Queue feature of the contention-free Ideal policy view."""
    return 0.0


class Simulation:
    """One trace executing on one (possibly shared) SSD fabric.

    ``fabric=None`` builds a private :class:`Fabric` — the single-tenant
    case.  :func:`repro.sim.tenancy.simulate_mix` passes a shared fabric
    plus a shared :class:`EventEngine` so several Simulations interleave on
    the same channels/dies/buses in global time order.
    """

    def __init__(self, trace: Trace, policy: Policy,
                 spec: SSDSpec = DEFAULT_SSD,
                 config: Optional[SimConfig] = None,
                 fabric: Optional[Fabric] = None,
                 tenant: str = "",
                 start_ns: float = 0.0):
        self.trace = trace
        self.policy = policy
        self.spec = spec
        self.cfg = config or SimConfig()
        self.tenant = tenant or trace.name
        self.start_ns = start_ns      # arrival offset (staggered tenants)
        self.fabric = fabric or Fabric(spec, pud_units=self.cfg.pud_units)
        self.pools: Dict[Resource, ServerPool] = self.fabric.pools
        self._pools_by_index = self.fabric.pools_by_index
        self.offloader = self.fabric.offloader
        self.channels = self.fabric.channels
        self.dies = self.fabric.dies
        self.dram_bus = self.fabric.dram_bus
        self.pcie = self.fabric.pcie

        self.pages = trace.pages
        if not self.pages._initial:
            self.pages.snapshot_initial()
        self.pages.reset()
        npages = len(self.pages)
        self.dram_cap = self.cfg.dram_capacity_pages or max(32, npages // 8)
        self.host_cap = self.cfg.host_capacity_pages or max(32, npages // 4)
        # plain dicts as LRUs: insertion order is the recency order
        # (pop + reinsert moves to back, next(iter(...)) is the victim)
        self.dram_lru: Dict[int, float] = {}
        self.host_lru: Dict[int, float] = {}

        # completion times indexed by iid (the Trace builder numbers iids
        # 0..n-1 in emit order, so a flat list replaces dict hashing on
        # the dependency scan; None = not yet dispatched)
        self._comp_size = 1 + max(
            (ins.iid for ins in trace.instrs), default=-1)
        self.completion: List[Optional[float]] = [None] * self._comp_size
        # IFP page buffers: each channel-unit holds up to BUFFER_DEPTH pages
        # in its planes' S/D latches; page -> unit map gives latch affinity.
        self.unit_buffers: Dict[int, List[int]] = {}
        self.buffered: Dict[int, int] = {}             # page -> unit
        # Static per-version liveness (compile-time metadata): a page is
        # live at instruction i iff its next event after i is a READ; if the
        # next event is a WRITE (the value is dead — the physical page gets
        # recycled) it can be discarded from latches/caches without a
        # write-back.
        self.page_events: Dict[int, List[Tuple[int, bool]]] = {}
        for ins in trace.instrs:
            for s in ins.srcs:
                self.page_events.setdefault(s, []).append((ins.iid, True))
            self.page_events.setdefault(ins.dst, []).append((ins.iid, False))
        self.out_pages_set = {p for pl in trace.output_pages for p in pl}
        self._cursor_iid = 0

        # event-driven dispatch state
        self.engine: Optional[EventEngine] = None
        # flight recorder routed via the fabric (bind() re-reads it): the
        # dispatch loop's hooks collapse to one branch when unset
        self._tele = None
        self._idx = 0                       # next instruction to dispatch
        self._prev_decide_end = start_ns    # offloader pipeline cursor
        self._makespan = start_ns
        self.done = False
        # an NDP operand sense came back unrecoverable (fault injection):
        # the trace still drains — timing stays honest — but the result is
        # marked failed and the serving layer surfaces it as a failed op
        self.failed = False
        # fault subsystem, if one is attached to the fabric (re-read in
        # bind(): tenancy/serving construct the FaultModel after the sims)
        self._faults = None
        self._last_ifp_unit: Optional[int] = None
        # completion hook: the open-loop serving driver uses this to free
        # an admission slot / record session latency the moment a trace
        # drains (set before bind(); never affects simulation timing)
        self.on_done: Optional[Callable[["Simulation"], None]] = None

        # -- hoisted per-dispatch structures (perf) ---------------------------
        # Link-latency constants (page-sized transfers; float addition is
        # commutative, so one constant serves both operand directions).
        f, d, h = spec.flash, spec.dram, spec.host
        nb = spec.page_size
        self._chan_xfer_ns = f.t_dma_ns + nb * f.channel_ns_per_byte
        self._bus_ns = nb * d.bus_ns_per_byte
        self._pcie_ns = nb * h.pcie_ns_per_byte + h.pcie_latency_ns
        self._pcie_nolat_ns = nb * h.pcie_ns_per_byte
        # Movement-path queue feature: pool lists per location pair live on
        # the (possibly shared) fabric — computed once per SSD, not per
        # tenant.  Flat int-indexed form: see Fabric.path_pools_by_index.
        self._path_pools_flat = self.fabric.path_pools_by_index
        self._n_locations = self.fabric.n_locations
        # Persistent SystemViews: the offloader's runtime snapshot reuses
        # bound methods reading the cursor fields below instead of building
        # a dataclass plus three closures per dispatch.
        self._view_now = 0.0
        self._cur_deps_ready = start_ns
        self._view = SystemView(
            now_ns=0.0,
            queue_delay_ns=self._queue_feature,
            dep_ready_ns=self._dep_feature,
            location_of=self.pages.location,
            move_queue_ns=self._move_queue_feature,
            tenant=self.tenant,
            # fast-path mirrors: select_fast probes these directly
            # (pages.reset() mutates entries in place, so the dict
            # reference stays valid across pooled re-admissions)
            pools_by_index=self._pools_by_index,
            path_pools_flat=self._path_pools_flat,
            n_locations=self._n_locations,
            page_entries=self.pages.entries)
        self._ideal_view = SystemView(
            0.0, _zero_queue, self._dep_feature, self.pages.location,
            tenant=self.tenant)

        # accounting
        self.compute_energy = 0.0
        self.movement_energy = 0.0
        self.overhead_total = 0.0
        self.coherence_syncs = 0
        self.evictions = 0
        self.replays = 0
        self.colocations = 0
        self.decisions: List[DecisionRecord] = []
        # per-op dispatch-to-completion latencies, kept even when full
        # DecisionRecord logging is off (floats only — the cheap part)
        self.op_latencies: List[float] = []
        self._record_decisions = self.cfg.record_decisions
        # fault replay is the only consumer of the full per-candidate
        # feature dict; without it the dispatch loop can take the
        # allocation-free select_fast path (bit-identical argmin)
        self._fast_select = self.cfg.fail_rate == 0.0
        # dispatch-loop hoists: per-dispatch reads of immutable state
        self._instrs = trace.instrs
        self._n_instrs = len(trace.instrs)
        self._policy_dynamic = policy.dynamic
        self._ignores_contention = policy.ignores_contention
        self._select_fast_fn = policy.select_fast
        # list-backed by Resource.index (enum hashing off the hot path);
        # result() rebuilds the public Dict[Resource, int] form
        self._resource_counts: List[int] = [0] * len(Resource)
        # §4.5 decision-overhead constants that do not depend on the
        # instruction: folded once (decision_overhead_ns inlined in
        # _on_dispatch; equivalence pinned in test_cost_and_policies)
        self._decide_const_ns = (spec.queue_delay_track_ns
                                 + spec.dm_latency_lookup_ns
                                 + spec.comp_latency_lookup_ns
                                 + spec.translation_lookup_ns)
        self._l2p_dram_ns = spec.l2p_lookup_dram_ns
        self._l2p_flash_ns = spec.l2p_lookup_flash_ns
        self._dep_track_ns = spec.dep_delay_track_ns
        self._inject_faults = self.cfg.fail_rate > 0.0

    def reset(self, tenant: str = "", start_ns: float = 0.0) -> None:
        """Rewind for a fresh admission of the same trace.

        The open-loop serving driver pools Simulations per catalog entry:
        re-admitting a session reuses the trace clone, the PageTable and
        every hoisted per-trace structure, restoring only the state a run
        mutates.  Equivalent to constructing a new Simulation over a fresh
        ``clone_trace`` (pinned by the pooling-law tests).  ``decisions``
        and ``op_latencies`` get NEW lists — a previously returned
        ``result()`` keeps references to the old ones."""
        self.tenant = tenant or self.trace.name
        self.start_ns = start_ns
        self.pages.reset()
        self.dram_lru.clear()
        self.host_lru.clear()
        self.completion = [None] * self._comp_size
        self.unit_buffers.clear()
        self.buffered.clear()
        self._cursor_iid = 0
        self.engine = None
        self._tele = None
        self._idx = 0
        self._prev_decide_end = start_ns
        self._makespan = start_ns
        self.done = False
        self.failed = False
        self._faults = None
        self._last_ifp_unit = None
        self.on_done = None
        self._view_now = 0.0
        self._cur_deps_ready = start_ns
        self._view.tenant = self.tenant
        self._ideal_view.tenant = self.tenant
        self.compute_energy = 0.0
        self.movement_energy = 0.0
        self.overhead_total = 0.0
        self.coherence_syncs = 0
        self.evictions = 0
        self.replays = 0
        self.colocations = 0
        self.decisions = []
        self.op_latencies = []
        counts = self._resource_counts
        for i in range(len(counts)):
            counts[i] = 0

    # -- data movement --------------------------------------------------------

    def _move_page(self, pid: int, to: Location, ready: float) -> float:
        """Move one page; returns completion time.  Occupies the interconnect
        servers on the path and performs the §4.4 lazy-coherence updates."""
        ent = self.pages[pid]
        src = ent.location
        if src == to:
            self._touch(pid, to, ready)
            return ready
        f = self.spec.flash
        nb = self.spec.page_size
        t = ready
        if ent.dirty and ent.owner not in (Location.FLASH, to):
            self.coherence_syncs += 1      # cross-resource request on dirty page

        if src == Location.FLASH:
            if pid not in self.buffered:   # latched pages skip the sense
                t = self.dies.acquire_end(t, f.t_read_ns, unit=ent.die)
                fm = self._faults
                if fm is not None:
                    # NDP operand senses are unmapped by the FTL
                    # (blk/pg = -1): base + retention error rate only
                    t, ok = fm.check_read(t, ent.die)
                    if not ok:
                        self.failed = True
            t = self.channels.acquire_end(
                t, self._chan_xfer_ns, unit=ent.channel)
            if to in (Location.DRAM, Location.CTRL):
                t = self.dram_bus.acquire_end(t, self._bus_ns)
            elif to == Location.HOST:
                t = self.pcie.acquire_end(t, self._pcie_ns)
        elif src in (Location.DRAM, Location.CTRL):
            t = self.dram_bus.acquire_end(t, self._bus_ns)
            if to == Location.FLASH:
                t = self.channels.acquire_end(
                    t, self._chan_xfer_ns, unit=ent.channel)
                t = self.dies.acquire_end(t, f.t_prog_ns, unit=ent.die)
            elif to == Location.HOST:
                t = self.pcie.acquire_end(t, self._pcie_ns)
        elif src == Location.HOST:
            t = self.pcie.acquire_end(t, self._pcie_ns)
            if to == Location.FLASH:
                t = self.channels.acquire_end(
                    t, self._chan_xfer_ns, unit=ent.channel)
                t = self.dies.acquire_end(t, f.t_prog_ns, unit=ent.die)
            elif to in (Location.DRAM, Location.CTRL):
                t = self.dram_bus.acquire_end(t, self._bus_ns)
        self.movement_energy += dm_energy_nj(src, to, nb, self.spec)
        if pid in self.buffered:
            u = self.buffered.pop(pid)
            if pid in self.unit_buffers.get(u, []):
                self.unit_buffers[u].remove(pid)
        if to == Location.FLASH:
            ent.owner = Location.FLASH
            ent.dirty = False
            ent.version = 0                 # commit (§4.4)
        self.pages.move(pid, to)
        self._touch(pid, to, t)
        return t

    def _touch(self, pid: int, loc: Location, now: float) -> None:
        if loc in (Location.DRAM, Location.CTRL):
            lru, cap = self.dram_lru, self.dram_cap
        elif loc == Location.HOST:
            lru, cap = self.host_lru, self.host_cap
        else:
            self.dram_lru.pop(pid, None)
            self.host_lru.pop(pid, None)
            return
        lru.pop(pid, None)
        lru[pid] = now
        while len(lru) > cap:
            victim = next(iter(lru))
            del lru[victim]
            self._evict(victim, now)

    def _evict(self, pid: int, now: float) -> None:
        """Capacity eviction — sync trigger (iii) of §4.4.

        Dead pages (no future reader, not a trace output) are scratch the
        runtime can discard; only live data pays the flash commit."""
        ent = self.pages[pid]
        self.evictions += 1
        if not self._is_live(pid, self._cursor_iid - 1):
            ent.owner = Location.FLASH
            ent.dirty = False
            self.pages.move(pid, Location.FLASH)
            return
        if ent.owner in (Location.DRAM, Location.CTRL, Location.HOST):
            # latest version off-flash -> commit asynchronously
            f = self.spec.flash
            t = self.dram_bus.acquire_end(now, self._bus_ns) \
                if ent.location != Location.HOST else \
                self.pcie.acquire_end(now, self._pcie_nolat_ns)
            t = self.channels.acquire_end(
                t, self._chan_xfer_ns, unit=ent.channel)
            self.dies.acquire_end(t, f.t_prog_ns, unit=ent.die)
            self.movement_energy += dm_energy_nj(
                ent.location, Location.FLASH, self.spec.page_size, self.spec)
            self.coherence_syncs += 1
        ent.owner = Location.FLASH
        ent.dirty = False
        ent.version = 0
        self.pages.move(pid, Location.FLASH)

    def _is_live(self, pid: int, after_iid: int) -> bool:
        """True iff the page's current value will be read again (its next
        trace event strictly after ``after_iid`` is a read), or it is a
        trace output."""
        ev = self.page_events.get(pid)
        if ev is not None:
            k = bisect.bisect_right(ev, (after_iid, True))
            if k < len(ev):
                return ev[k][1]
        return pid in self.out_pages_set

    def _path_queue_ns(self, src: Location, dst: Location, now: float) -> float:
        """Queueing delay along the movement path src->dst (feature 4
        generalized: the instruction waits on these queues too).  The pool
        list per location pair is precomputed in ``__init__``."""
        best = 0.0
        pools = self._path_pools_flat[src.index * self._n_locations
                                      + dst.index]
        for p in pools:
            q = p.queue_delay_ns(now)
            if q > best:
                best = q
        return best

    # -- SystemView feature callbacks (bound once, read the dispatch cursor) --

    def _queue_feature(self, r: Resource) -> float:
        return self._pools_by_index[r.index].queue_delay_ns(self._view_now)

    def _dep_feature(self, instr: VectorInstr) -> float:
        return self._cur_deps_ready

    def _move_queue_feature(self, src: Location, dst: Location) -> float:
        # _path_queue_ns inlined: probed per off-home operand per candidate
        now = self._view_now
        best = 0.0
        for p in self._path_pools_flat[src.index * self._n_locations
                                       + dst.index]:
            q = p.queue_delay_ns(now)
            if q > best:
                best = q
        return best

    # -- execution ------------------------------------------------------------

    def _exec_on(self, instr: VectorInstr, r: Resource, ready: float,
                 allow_contention: bool = True) -> Tuple[float, float]:
        """Run ``instr`` on resource ``r``; returns (start, end)."""
        latched = False
        if r is Resource.IFP:
            flash_srcs = [s for s in instr.srcs
                          if self.pages.location(s) == Location.FLASH
                          and s not in self.buffered]   # latched pages are
                          # in the peripheral latches, not the array: MWS
                          # same-block placement does not apply to them
            # Flash-Cosmos same-block layout constraint for MWS ops
            if instr.op in ("and", "or", "nand", "nor") and len(flash_srcs) > 1:
                if not self.pages.same_block(flash_srcs):
                    moved = self.pages.co_locate(flash_srcs)
                    self.colocations += moved
                    f = self.spec.flash
                    for s in flash_srcs[1:1 + moved]:
                        t0 = self.dies.acquire_end(
                            ready, f.t_read_ns, unit=self.pages[s].die)
                        t0 = self.channels.acquire_end(
                            t0, self.spec.page_size * f.channel_ns_per_byte,
                            unit=self.pages[s].channel)
                        ready = self.dies.acquire_end(
                            t0, f.t_prog_ns, unit=self.pages[s].die)
                        self.movement_energy += (
                            f.e_read_nj_per_channel * 0.3 + f.e_prog_nj_per_channel)
            # latch affinity: prefer the unit already buffering an operand
            unit = None
            for s in instr.srcs:
                if s in self.buffered:
                    unit = self.buffered[s]
                    latched = True
                    break
            if unit is None:
                unit = (self.pages[instr.srcs[0]].die
                        if instr.srcs else 0)
            self._last_ifp_unit = unit   # audit: which die executed
        else:
            unit = None
        if r is Resource.PUD:
            # ACT/PRE command issue serializes on the DRAM command/data bus
            # even though banks execute bbops concurrently (MIMDRAM model).
            issue = 0.18 * exec_latency_ns(instr, r, self.spec)
            ready = self.dram_bus.acquire_end(ready, issue)

        lat = exec_latency_ns(instr, r, self.spec, operands_latched=latched)
        pool = self._pools_by_index[r.index]
        if allow_contention:
            start, end = pool.acquire_se(ready, lat, unit=unit)
        else:
            start, end = ready, ready + lat
            pool.busy_ns += lat
            pool.jobs += 1
        self.compute_energy += exec_energy_nj(instr, r, self.spec, lat)

        home = HOME_BY_INDEX[r.index]
        self.pages.record_write(instr.dst, home)
        if r is Resource.IFP:
            # Result lands in the plane's page buffer (S/D latches hold up to
            # BUFFER_DEPTH pages per unit).  Displacing a buffered page
            # triggers its (pipelined) SLC program write-back — but only if
            # that page is still LIVE (future reader or trace output); dead
            # latch intermediates are discarded, as in Flash-Cosmos chaining.
            buf = self.unit_buffers.setdefault(unit, [])
            if instr.dst in buf:
                buf.remove(instr.dst)
            buf.append(instr.dst)
            self.buffered[instr.dst] = unit
            self.pages[instr.dst].die = unit           # affinity follows data
            self.pages[instr.dst].channel = unit % self.spec.flash.channels
            while len(buf) > BUFFER_DEPTH:
                prev = buf.pop(0)
                self.buffered.pop(prev, None)
                if self._is_live(prev, instr.iid):
                    # live result flows UP the hierarchy: DMA out of the
                    # page buffer to SSD DRAM (a program back into the
                    # array would cost 400us; the controller drains hot
                    # data through the normal read path instead).
                    t = self.channels.acquire_end(
                        end, self._chan_xfer_ns,
                        unit=self.pages[prev].channel)
                    t = self.dram_bus.acquire_end(t, self._bus_ns)
                    self.movement_energy += dm_energy_nj(
                        Location.FLASH, Location.DRAM,
                        self.spec.page_size, self.spec)
                    self.pages[prev].owner = Location.DRAM
                    self.pages[prev].dirty = True
                    self.pages.move(prev, Location.DRAM)
                    self._touch(prev, Location.DRAM, t)
                else:
                    self.pages[prev].dirty = False
                    self.pages[prev].owner = Location.FLASH
        else:
            self._touch(instr.dst, home, end)
        return start, end

    # -- event-driven dispatch -------------------------------------------------

    def bind(self, engine: EventEngine) -> None:
        """Attach this trace to an event engine and schedule its first
        dispatch.  Several Simulations sharing one engine + fabric
        interleave their dispatches in global time order."""
        self.engine = engine
        self._tele = self.fabric.telemetry
        self._faults = self.fabric.faults
        self._idx = 0
        self._prev_decide_end = self.start_ns
        self._makespan = self.start_ns
        self.done = False
        if self.trace.instrs:
            engine.schedule(self.start_ns, EventKind.DISPATCH,
                            self._on_dispatch)
        elif (self.cfg.move_outputs_to_host
              and not self.policy.ignores_contention):
            # degenerate empty trace: the epilogue flush still runs
            engine.schedule(self.start_ns, EventKind.EPILOGUE,
                            self._on_epilogue)
        else:
            self._finish()

    def _finish(self) -> None:
        """Mark the trace drained and fire the completion hook."""
        self.done = True
        if self.on_done is not None:
            self.on_done(self)

    def _deps_ready(self, instr: VectorInstr) -> float:
        # hand-rolled max-over-present: no generator frame on the hot path
        completion = self.completion
        best = None
        for d in instr.deps:
            c = completion[d]
            if c is not None and (best is None or c > best):
                best = c
        return self.start_ns if best is None else best

    def _after_instr(self, instr_end: float) -> None:
        """Schedule the next dispatch (or the epilogue) after one
        instruction has been issued."""
        if instr_end > self._makespan:
            self._makespan = instr_end
        self._idx += 1
        engine = self.engine
        if self._idx < self._n_instrs:
            if self._ignores_contention:
                nxt = self._deps_ready(self._instrs[self._idx])
                when = max(engine.now, nxt)
            else:
                # in-order issue, pipelined across the offloader cores: the
                # next decision may start once this one occupies its core.
                now = engine.now
                prev = self._prev_decide_end
                when = now if now > prev else prev
            engine.schedule(when, EventKind.DISPATCH, self._on_dispatch)
        elif self.cfg.move_outputs_to_host and not self.policy.ignores_contention:
            engine.schedule(max(engine.now, self._makespan),
                            EventKind.EPILOGUE, self._on_epilogue)
        else:
            self._finish()

    def _on_dispatch(self, _payload=None) -> None:
        """Offloader core picks up the next instruction in program order:
        decide (§4.5 overhead), move operands, book execution."""
        spec = self.spec
        instr = self._instrs[self._idx]
        self._cursor_iid = instr.iid
        deps_ready = self._deps_ready(instr)
        tele = self._tele
        if tele is not None:
            # attribution for every pool booking this dispatch performs;
            # ctx_args carries the structured join key (the span name
            # alone would need parsing in the analysis layer)
            tele.ctx = f"{self.tenant}:{instr.op}#{instr.iid}"
            tele.ctx_args = {"tenant": self.tenant, "iid": instr.iid}

        if self._ignores_contention:
            # Ideal (§5.3): zero data-movement latency, zero decision
            # overhead, fastest resource per instruction.  Execution
            # still occupies the (contention-free scheduled) compute
            # units — an upper bound on realizable offloading.
            self._cur_deps_ready = deps_ready
            r = self.policy.select_fast(instr, self._ideal_view)
            lat = exec_latency_ns(instr, r, spec)
            start, end = self._pools_by_index[r.index].acquire_se(
                deps_ready, lat)
            self.compute_energy += exec_energy_nj(instr, r, spec, lat)
            self.pages.record_write(instr.dst, HOME_BY_INDEX[r.index])
            self.completion[instr.iid] = end
            self._resource_counts[r.index] += 1
            self.op_latencies.append(end - start)
            if self._record_decisions:
                self.decisions.append(DecisionRecord(
                    instr.iid, instr.op, r, start, start, end, 0.0))
            if tele is not None:
                feats = self.policy._feats(instr, self._ideal_view) \
                    if tele.cfg.audit else None
                tele.on_dispatch(self.tenant, self.policy.name, instr, r,
                                 feats, start, start, start, start, start,
                                 end, 0.0)
            self._after_instr(end)
            return

        if self._policy_dynamic:
            # decision_overhead_ns inlined (§4.5): per-operand L2P lookups
            # plus the constant tracking/lookup terms folded in __init__.
            # ``deps_ready`` is the max completion over present deps and
            # ``_prev_decide_end`` is monotone from start_ns, so "any dep
            # completes after the pipeline cursor" == deps_ready > cursor.
            overhead = self._decide_const_ns
            if deps_ready > self._prev_decide_end:
                overhead += self._dep_track_ns
            dram_ns = self._l2p_dram_ns
            flash_ns = self._l2p_flash_ns
            entries = self.pages.entries
            for s in instr.srcs:
                ent = entries[s]
                if ent.l2p_cached:
                    overhead += dram_ns
                else:
                    ent.l2p_cached = True
                    overhead += flash_ns
        else:
            # compile-time-mapped policy: queue push only
            overhead = STATIC_DISPATCH_NS
        now, decide_end = self.offloader.acquire_se(
            self._prev_decide_end, overhead)
        self._prev_decide_end = now
        self.overhead_total += overhead

        self._view_now = now
        self._cur_deps_ready = deps_ready
        view = self._view
        view.now_ns = now
        view.dep_ready_abs = deps_ready
        if self._fast_select:
            r = self._select_fast_fn(instr, view)
        else:
            decision = self.policy.select(instr, view)
            r = decision.resource
        feats = None
        if tele is not None and tele.cfg.audit:
            # decision-time candidate costs for the audit stream: _feats
            # is the policy's own read-only derivation, taken here —
            # after the selection, before any booking mutates pool state
            feats = decision.features if not self._fast_select \
                else self.policy._feats(instr, view)

        # operand movement to the resource's home (overlapped per page)
        ready = max(decide_end, deps_ready)
        home = HOME_BY_INDEX[r.index]
        # recency bookkeeping for on-home operands: the LRU is a function
        # of ``home`` alone, so hoist _touch's branch out of the loop
        # (home is FLASH only for IFP — that shape keeps the _touch call)
        if home is Location.DRAM or home is Location.CTRL:
            lru, cap = self.dram_lru, self.dram_cap
        elif home is Location.HOST:
            lru, cap = self.host_lru, self.host_cap
        else:
            lru = None
        move_end = ready
        dm_ns = 0.0
        entries = self.pages.entries
        for s in instr.srcs:
            if entries[s].location is not home:
                t = self._move_page(s, home, ready)
                dm_ns += t - ready
                if t > move_end:
                    move_end = t
            elif lru is None:
                self._touch(s, home, ready)
            else:
                lru.pop(s, None)
                lru[s] = ready
                while len(lru) > cap:
                    victim = next(iter(lru))
                    del lru[victim]
                    self._evict(victim, ready)

        if r is Resource.IFP:
            start, end = self._exec_on(instr, r, move_end)
        else:
            # _exec_on inlined for the ISP/PUD/host resources: no latch
            # affinity, no same-block constraint — book and account.
            lat = exec_latency_ns(instr, r, spec)
            if r is Resource.PUD:
                move_end = self.dram_bus.acquire_end(move_end, 0.18 * lat)
            start, end = self._pools_by_index[r.index].acquire_se(
                move_end, lat)
            self.compute_energy += exec_energy_nj(instr, r, spec, lat)
            # record_write inlined (enum __eq__ is identity, so ``is``)
            ent = entries[instr.dst]
            if not (ent.owner is home and ent.dirty):
                ent.owner = home
                ent.dirty = True
            ent.bump_version()
            ent.location = home
            if lru is None:
                self._touch(instr.dst, home, end)
            else:
                dst = instr.dst
                lru.pop(dst, None)
                lru[dst] = end
                while len(lru) > cap:
                    victim = next(iter(lru))
                    del lru[victim]
                    self._evict(victim, end)

        # transient-fault injection (§4.4 failure handling): replay on
        # another resource using the latest data version.
        if self._inject_faults and \
                _hash01(instr.iid, self.cfg.seed) < self.cfg.fail_rate:
            self.replays += 1
            alts = [x for x in self.policy.candidates
                    if x != r and decision.features.get(x) is not None
                    and decision.features[x].supported] or [Resource.ISP]
            alt = min(alts, key=lambda x: decision.features[x].latency_comp
                      if x in decision.features else float("inf"))
            ready2 = end
            for s in instr.srcs:
                if self.pages.location(s) != HOME[alt]:
                    ready2 = max(ready2, self._move_page(s, HOME[alt], end))
            _, end = self._exec_on(instr, alt, ready2)
            r = alt

        self.completion[instr.iid] = end
        self._resource_counts[r.index] += 1
        self.op_latencies.append(end - now)
        if self._record_decisions:
            self.decisions.append(DecisionRecord(
                instr.iid, instr.op, r, now, start, end, dm_ns,
                replayed=self._inject_faults
                and _hash01(instr.iid, self.cfg.seed) < self.cfg.fail_rate))
        if tele is not None:
            tele.on_dispatch(
                self.tenant, self.policy.name, instr, r, feats,
                now, decide_end, ready, move_end, start, end, dm_ns,
                replayed=self._inject_faults
                and _hash01(instr.iid, self.cfg.seed) < self.cfg.fail_rate,
                unit=self._last_ifp_unit if r is Resource.IFP else None)
        # _after_instr inlined (this branch never ignores contention)
        if end > self._makespan:
            self._makespan = end
        idx = self._idx + 1
        self._idx = idx
        engine = self.engine
        if idx < self._n_instrs:
            # in-order issue, pipelined across the offloader cores: the
            # next decision may start once this one occupies its core.
            enow = engine.now
            prev = self._prev_decide_end
            engine.schedule(enow if enow > prev else prev,
                            _DISPATCH, self._on_dispatch)
        elif self.cfg.move_outputs_to_host:
            engine.schedule(max(engine.now, self._makespan),
                            _EPILOGUE, self._on_epilogue)
        else:
            self._finish()

    def _on_epilogue(self, _payload=None) -> None:
        """End of trace: results become visible to the host (§4.4 ii)."""
        if self._tele is not None:
            self._tele.ctx = f"{self.tenant}:epilogue"
            self._tele.ctx_args = {"tenant": self.tenant, "epilogue": True}
        makespan = self._makespan
        for pl in self.trace.output_pages:
            for pid in pl:
                if self.pages.location(pid) != Location.HOST:
                    makespan = max(
                        makespan, self._move_page(pid, Location.HOST, makespan))
        self._makespan = makespan
        self._finish()

    def result(self) -> SimResult:
        """Collect the per-trace result (call after the engine drained)."""
        return SimResult(
            policy=self.policy.name, workload=self.trace.name,
            makespan_ns=self._makespan, n_instrs=len(self.trace.instrs),
            compute_energy_nj=self.compute_energy,
            movement_energy_nj=self.movement_energy,
            decision_overhead_ns_total=self.overhead_total,
            decisions=self.decisions,
            op_latencies_ns=self.op_latencies,
            resource_counts={r: self._resource_counts[r.index]
                             for r in Resource if self._resource_counts[r.index]},
            resource_busy_ns=self.fabric.busy_ns(),
            coherence_syncs=self.coherence_syncs, evictions=self.evictions,
            replays=self.replays, colocations=self.colocations,
            tenant=self.tenant, start_ns=self.start_ns,
            failed=self.failed)

    def run(self) -> SimResult:
        """Single-tenant convenience: drive a private event loop to empty."""
        engine = EventEngine()
        self.bind(engine)
        engine.run()
        return self.result()


def simulate(trace: Trace, policy: str | Policy,
             spec: SSDSpec = DEFAULT_SSD,
             config: Optional[SimConfig] = None,
             record_decisions: Optional[bool] = None,
             telemetry: TelemetryLike = None,
             faults=None) -> SimResult:
    """Run one workload trace under one offloading policy.

    The single-tenant special case of the event engine; for concurrent
    traces sharing the SSD see :func:`repro.sim.tenancy.simulate_mix`.
    ``record_decisions=False`` is the fast mode (no per-dispatch
    DecisionRecord allocation, identical timing) — overrides the same
    flag on ``config``.  ``telemetry`` takes a
    :class:`~repro.sim.telemetry.TelemetryConfig` (or a prepared
    :class:`~repro.sim.telemetry.FlightRecorder`); the recorder observes
    without perturbing timing and comes back on ``result.telemetry``.
    ``faults`` takes a :class:`~repro.sim.faults.FaultConfig`: an active
    config arms the error model on the private fabric (NDP operand
    senses roll the RBER model and walk the recovery ladder); ``None``
    or an all-off config is bit-identical to a build without the fault
    subsystem.
    """
    if isinstance(policy, str):
        policy = make_policy(policy, spec)
    if record_decisions is not None:
        config = dataclasses.replace(config or SimConfig(),
                                     record_decisions=record_decisions)
    sim = Simulation(trace, policy, spec, config)
    tele = as_recorder(telemetry)
    fault_on = faults is not None and faults.active
    if tele is None and not fault_on:
        return sim.run()
    engine = EventEngine()
    if fault_on:
        from repro.sim.faults import FaultModel
        FaultModel(faults, spec, sim.fabric, engine)
    if tele is not None:
        tele.attach(fabric=sim.fabric, engine=engine)
        if sim.fabric.faults is not None:
            tele.attach_faults(sim.fabric.faults)
        tele.run_meta.setdefault("entry", "simulate")
        tele.run_meta.setdefault("policy", policy.name)
        tele.run_meta.setdefault("workload", trace.name)
    sim.bind(engine)
    engine.run()
    res = sim.result()
    if tele is not None:
        res.telemetry = tele
    if sim.fabric.faults is not None:
        res.faults = sim.fabric.faults.stats()
    return res
