"""Drive-as-actor: one SSD behind three seams (ISSUE 10 tentpole).

A :class:`DriveActor` owns everything that used to be wired inline in
:func:`repro.sim.serving.simulate_serving` — one
:class:`~repro.sim.events.EventEngine`, one
:class:`~repro.sim.servers.Fabric`, optionally an FTL
(:mod:`repro.sim.ftl`), a fault model (:mod:`repro.sim.faults`), a host
I/O stream and the serving loop — and exposes exactly three message
points to whoever drives it:

* **submit** (:meth:`DriveActor.submit`): inject one session arriving at
  a future instant.  Returns a local index usable for
  :meth:`schedule_cancel` (hedging's cancel-on-first-win).
* **poll** (:meth:`DriveActor.poll`): drain completions that terminated
  since the last poll, plus a :class:`DriveHealth` snapshot (GC
  activity, read-only/failed dies, recovery windows, queue depths) — the
  signals a placement layer steers on.
* **advance-to-time** (:meth:`DriveActor.advance_before`): process this
  drive's events strictly before ``t`` and stop.  A fleet loop
  (:mod:`repro.sim.fleet`) alternates advance/submit across N actors in
  arrival order, which is time-accurate: no actor's clock passes an
  arrival that could still be routed to it.

Nothing *inside* the seams changed: the actor's constructor performs the
same wiring, in the same order, as ``simulate_serving`` always did — in
fact ``simulate_serving`` is now implemented as a one-actor run driven
to quiescence, so the N=1 fleet equivalence law
(``tests/test_fleet.py``) holds by construction: a 1-drive fleet under
hash placement and a plain serving run execute literally the same code.

Actors never share state.  Each owns a private engine/fabric/FTL/fault
model and a private RNG lineage
(:func:`repro.sim.placement.derive_drive_seed`), so a fleet is
embarrassingly parallel in the static-placement regime and lockstep-
deterministic in the dynamic one.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

from repro.hw.ssd_spec import SSDSpec
from repro.sim.events import EventEngine, EventKind
from repro.sim.ftl import FTLConfig
from repro.sim.machine import SimConfig
from repro.sim.servers import Fabric
from repro.sim.serving import PolicyLike, ServingConfig, _ServingDriver
from repro.sim.stats import ServingResult, SessionRecord
from repro.sim.telemetry import TelemetryLike, as_recorder
from repro.sim.tenancy import (HostIOStream, _HostIOModel, build_ftl_model)
from repro.sim.workgen import SessionCatalog


@dataclasses.dataclass(frozen=True)
class DriveHealth:
    """Point-in-time health snapshot — what :meth:`DriveActor.poll`
    reports and what read steering / heat-aware placement consume.

    ``recovering`` means at least one die sits inside a fault-recovery
    window (read-retry ladder / relocation in progress); ``retired``
    drives accept no new sessions (fleet-level rebuild is routing their
    load elsewhere)."""

    drive_id: int
    t_ns: float
    active: int                      # admitted sessions executing now
    backlog: int                     # sessions queued for admission
    gc_busy: bool                    # any die currently collecting
    gc_active_dies: int
    read_only_dies: int
    failed_dies: int
    recovering: bool
    retired: bool

    @property
    def inflight(self) -> int:
        return self.active + self.backlog

    @property
    def healthy(self) -> bool:
        """Fit to take unsteered traffic: not retired, not collecting,
        not recovering, no degraded dies."""
        return not (self.retired or self.gc_busy or self.recovering
                    or self.read_only_dies or self.failed_dies)


@dataclasses.dataclass(frozen=True)
class DrivePoll:
    """One :meth:`DriveActor.poll` result: completions since the last
    poll (terminal :class:`~repro.sim.stats.SessionRecord` objects, in
    termination order) plus the health snapshot at poll time."""

    completions: Tuple[SessionRecord, ...]
    health: DriveHealth


class DriveActor:
    """One SSD as an actor; see the module docstring for the seams.

    The constructor is the former body of ``simulate_serving`` verbatim
    (engine → fabric → fault model → telemetry attach → serving driver →
    FTL → host I/O → telemetry attach) — do not reorder it, the golden
    digest suites pin the resulting event interleavings bit-for-bit.

    Exactly one of ``arrival_times`` (self-scheduled, the single-drive
    entry point) or ``plan``/neither (fleet-routed) is the intended use;
    a fleet passes ``window`` explicitly so every drive measures the
    same fleet-global steady-state span."""

    def __init__(self, catalog: SessionCatalog, policy: PolicyLike,
                 spec: SSDSpec, cfg: SimConfig, scfg: ServingConfig,
                 arrival_times: Optional[List[float]] = None,
                 plan: Optional[List[tuple]] = None,
                 window: Optional[Tuple[float, float]] = None,
                 io_stream: Optional[HostIOStream] = None,
                 ftl: Optional[FTLConfig] = None,
                 faults=None,
                 engine: Optional[EventEngine] = None,
                 telemetry: TelemetryLike = None,
                 drive_id: int = 0,
                 entry_name: str = "simulate_serving"):
        self.drive_id = drive_id
        self.spec = spec
        self.cfg = cfg
        self.scfg = scfg
        self.policy_name = policy if isinstance(policy, str) else policy.name
        engine = engine or EventEngine()
        self.engine = engine
        fabric = Fabric(spec, pud_units=cfg.pud_units)
        self.fabric = fabric
        fm = None
        if faults is not None and faults.active:
            from repro.sim.faults import FaultModel
            fm = FaultModel(faults, spec, fabric, engine)
        self.fault_model = fm
        tele = as_recorder(telemetry)
        self.telemetry = tele
        if tele is not None:
            tele.attach(fabric=fabric, engine=engine)
            if fm is not None:
                tele.attach_faults(fm)
            tele.run_meta.setdefault("entry", entry_name)
            tele.run_meta.setdefault("policy", self.policy_name)
            tele.run_meta.setdefault("seed", catalog.seed)
        self.driver = _ServingDriver(
            catalog, arrival_times if arrival_times is not None else [],
            policy, spec, cfg, scfg, fabric, engine,
            window=window, plan=plan)
        self.ftl_model = (build_ftl_model(ftl, spec, fabric, engine,
                                          io_stream)
                          if ftl is not None else None)
        if self.ftl_model is not None and fm is not None:
            self.ftl_model.attach_faults(fm)
        self.io = (_HostIOModel(io_stream, fabric, spec, engine,
                                ftl=self.ftl_model)
                   if io_stream is not None else None)
        if tele is not None:
            tele.attach_serving(self.driver)
            if self.ftl_model is not None:
                tele.attach_ftl(self.ftl_model)
            if self.io is not None:
                tele.attach_host_io(self.io)
        # -- actor state on top of the classic wiring ------------------------
        self.retired = False
        self._completions: List[SessionRecord] = []
        # fleet seam: fires (drive_id, record) on every terminal session
        self.on_session_terminal: Optional[Callable] = None
        self.driver.on_terminal = self._terminal
        # rebuild / extra background streams injected mid-run
        self._extra_io: List[_HostIOModel] = []

    # -- seam 1: submit --------------------------------------------------------

    def submit(self, t_ns: float, entry, sid: int, measured: bool) -> int:
        """Inject one routed session arriving at ``t_ns`` (>= now);
        returns the drive-local index (see :meth:`schedule_cancel`)."""
        if self.retired:
            raise RuntimeError(
                f"drive {self.drive_id} is retired: the placement layer "
                "must not route sessions to it")
        return self.driver.submit(t_ns, entry, sid, measured)

    def schedule_cancel(self, i: int, t_ns: float) -> None:
        """Hedging's cancel-on-first-win: revoke local copy ``i`` at
        ``t_ns`` *drive time*.  Scheduled as an event (never applied
        retroactively — this drive's clock may trail the winner's), and
        only a still-queued copy actually cancels; an executing copy
        drains, exactly like a timed-out session's in-flight work."""
        self.engine.schedule(max(t_ns, self.engine.now), EventKind.TIMER,
                             lambda _i: self.driver.cancel(_i), payload=i)

    # -- seam 2: poll ----------------------------------------------------------

    def _terminal(self, i: int, rec: SessionRecord) -> None:
        self._completions.append(rec)
        if self.on_session_terminal is not None:
            self.on_session_terminal(self.drive_id, rec)

    def health(self) -> DriveHealth:
        now = self.engine.now
        fm = self.fault_model
        read_only = failed = 0
        recovering = False
        if fm is not None:
            read_only = sum(1 for ro in fm.dies_read_only if ro)
            failed = sum(1 for d in range(fm.n_dies) if fm.die_dead(d, now))
            recovering = any(t > now for t in fm.recovery_until)
        ftl = self.ftl_model
        return DriveHealth(
            drive_id=self.drive_id, t_ns=now,
            active=self.driver.active, backlog=len(self.driver.backlog),
            gc_busy=bool(ftl is not None and ftl.gc_busy),
            gc_active_dies=ftl.gc_active_dies if ftl is not None else 0,
            read_only_dies=read_only, failed_dies=failed,
            recovering=recovering, retired=self.retired)

    def poll(self) -> DrivePoll:
        """Completions since the last poll + a health snapshot."""
        done = tuple(self._completions)
        self._completions.clear()
        return DrivePoll(completions=done, health=self.health())

    # -- seam 3: advance-to-time ----------------------------------------------

    def advance_before(self, t: float) -> float:
        """Process this drive's events strictly before ``t``; events at
        exactly ``t`` stay pending so an arrival submitted *at* ``t``
        interleaves by the engine's (time, seq) order, not call order."""
        return self.engine.run_before(t)

    def drain(self) -> float:
        """Run this drive to quiescence (no pending events)."""
        return self.engine.run()

    # -- fleet-level management ------------------------------------------------

    def retire(self) -> None:
        """Stop accepting sessions.  Already-queued and executing work
        drains normally — retirement is an admission decision, not a
        power cut; the fleet rebuilds the drive's share elsewhere."""
        self.retired = True

    def add_io_stream(self, stream: HostIOStream) -> None:
        """Attach an extra background host-I/O stream mid-fleet — the
        rebuild read traffic a surviving replica serves while a retired
        drive's data is reconstructed.  Folded into this drive's
        makespan and contention but kept out of its serving stats."""
        self._extra_io.append(
            _HostIOModel(stream, self.fabric, self.spec, self.engine,
                         ftl=self.ftl_model))

    # -- result ----------------------------------------------------------------

    def result(self) -> ServingResult:
        res = self.driver.result(self.policy_name, self.io, self.ftl_model)
        for extra in self._extra_io:
            # rebuild traffic keeps the drive busy past its last session
            res.makespan_ns = max(res.makespan_ns, extra.last_complete_ns)
        res.telemetry = self.telemetry
        return res
