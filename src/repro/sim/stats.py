"""Simulation results: makespan, energy breakdown, latency percentiles,
offloading-decision logs (Figs. 7-10 raw data)."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.isa import Resource


def percentile(values: List[float], p: float) -> float:
    """Nearest-rank percentile (p in [0,100])."""
    if not values:
        return 0.0
    s = sorted(values)
    k = max(0, min(len(s) - 1, math.ceil(p / 100.0 * len(s)) - 1))
    return s[k]


@dataclasses.dataclass
class DecisionRecord:
    iid: int
    op: str
    resource: Resource
    t_decide: float
    t_start: float
    t_end: float
    dm_ns: float
    replayed: bool = False


@dataclasses.dataclass
class SimResult:
    policy: str
    workload: str
    makespan_ns: float
    n_instrs: int
    compute_energy_nj: float
    movement_energy_nj: float
    decision_overhead_ns_total: float
    decisions: List[DecisionRecord]
    resource_counts: Dict[Resource, int]
    resource_busy_ns: Dict[str, float]
    coherence_syncs: int
    evictions: int
    replays: int
    colocations: int

    @property
    def total_energy_nj(self) -> float:
        return self.compute_energy_nj + self.movement_energy_nj

    @property
    def latencies_ns(self) -> List[float]:
        return [d.t_end - d.t_decide for d in self.decisions]

    def p(self, pct: float) -> float:
        return percentile(self.latencies_ns, pct)

    @property
    def avg_decision_overhead_ns(self) -> float:
        return self.decision_overhead_ns_total / max(1, self.n_instrs)

    def decision_mix(self) -> Dict[Resource, float]:
        total = max(1, sum(self.resource_counts.values()))
        return {r: c / total for r, c in self.resource_counts.items()}

    def summary(self) -> Dict[str, object]:
        mix = self.decision_mix()
        return {
            "policy": self.policy,
            "workload": self.workload,
            "makespan_ms": self.makespan_ns / 1e6,
            "energy_mj": self.total_energy_nj / 1e6,
            "movement_energy_pct": round(
                100 * self.movement_energy_nj / max(1e-9, self.total_energy_nj), 1),
            "p99_us": self.p(99) / 1e3,
            "p9999_us": self.p(99.99) / 1e3,
            "mix": {r.value: round(100 * f, 1) for r, f in mix.items()},
            "avg_overhead_us": self.avg_decision_overhead_ns / 1e3,
            "instrs": self.n_instrs,
        }
