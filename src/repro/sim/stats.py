"""Simulation results: makespan, energy breakdown, latency percentiles,
offloading-decision logs (Figs. 7-10 raw data).

Multi-tenant additions: :class:`MixResult` bundles one :class:`SimResult`
per tenant plus the fairness / interference metrics of the shared-SSD
regime — per-tenant slowdown vs. a solo run, Jain's fairness index over
the slowdowns, and host-I/O tail latency (:class:`HostIOStats`)."""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional, Tuple

from repro.core.isa import Resource
# DecisionRecord's definition lives with the rest of the decision-audit
# machinery in repro.sim.telemetry; re-exported here so existing callers
# (`from repro.sim.stats import DecisionRecord`) keep working.
from repro.sim.telemetry import DecisionRecord


def percentile(values: List[float], p: float) -> float:
    """Nearest-rank percentile; ``p`` must lie in [0, 100].

    Out-of-range ``p`` raises instead of silently clamping to the
    min/max sample — ``p(990)`` is a typo for ``p(99)``, not a request
    for the largest value, and clamping would let it masquerade as a
    plausible tail percentile."""
    if not 0.0 <= p <= 100.0:
        raise ValueError(f"percentile p={p!r} out of range [0, 100]")
    if not values:
        return 0.0
    s = sorted(values)
    k = max(0, min(len(s) - 1, math.ceil(p / 100.0 * len(s)) - 1))
    return s[k]


def merged_percentile(sample_groups: List[List[float]], p: float) -> float:
    """Percentile over the *union* of per-group samples.

    This is the only correct way to aggregate latency percentiles across
    drives: the fleet p99 is the 99th percentile of every session the
    fleet served, pooled.  Averaging per-drive p99s is a classic
    aggregation bug — it weights a 10-session straggler drive equally
    with a 10 000-session healthy one and *understates* the fleet tail
    whenever the tail is concentrated on few drives (the straggler
    scenario this repo exists to study).  ``FleetResult`` routes every
    percentile through here; ``tests/test_fleet.py`` pins the
    merged-vs-averaged gap on an asymmetric fixture."""
    merged: List[float] = []
    for g in sample_groups:
        merged.extend(g)
    return percentile(merged, p)


@dataclasses.dataclass
class SimResult:
    policy: str
    workload: str
    makespan_ns: float
    n_instrs: int
    compute_energy_nj: float
    movement_energy_nj: float
    decision_overhead_ns_total: float
    decisions: List[DecisionRecord]
    resource_counts: Dict[Resource, int]
    resource_busy_ns: Dict[str, float]
    coherence_syncs: int
    evictions: int
    replays: int
    colocations: int
    tenant: str = ""                 # tenant id in a simulate_mix run
    start_ns: float = 0.0            # arrival offset in a simulate_mix run
    # per-op dispatch-to-completion latencies (floats, always cheap);
    # richer per-dispatch detail lives in the telemetry audit stream
    op_latencies_ns: Optional[List[float]] = None
    # FlightRecorder when the run was invoked with telemetry=...
    telemetry: Optional[object] = None
    # fault injection: an NDP operand sense came back unrecoverable
    # somewhere in the run (timing stayed honest; data did not)
    failed: bool = False
    # FaultStats snapshot when the run was invoked with faults=...
    faults: Optional[object] = None

    @property
    def total_energy_nj(self) -> float:
        return self.compute_energy_nj + self.movement_energy_nj

    @property
    def elapsed_ns(self) -> float:
        """Wall time from this tenant's arrival to its last completion —
        what slowdown-vs-solo compares when tenants arrive staggered."""
        return self.makespan_ns - self.start_ns

    @property
    def latencies_ns(self) -> List[float]:
        if self.op_latencies_ns is not None:
            return self.op_latencies_ns
        return [d.t_end - d.t_decide for d in self.decisions]

    def p(self, pct: float) -> float:
        return percentile(self.latencies_ns, pct)

    @property
    def avg_decision_overhead_ns(self) -> float:
        return self.decision_overhead_ns_total / max(1, self.n_instrs)

    def decision_mix(self) -> Dict[Resource, float]:
        total = max(1, sum(self.resource_counts.values()))
        return {r: c / total for r, c in self.resource_counts.items()}

    def summary(self) -> Dict[str, object]:
        mix = self.decision_mix()
        return {
            "policy": self.policy,
            "workload": self.workload,
            "makespan_ms": self.makespan_ns / 1e6,
            "energy_mj": self.total_energy_nj / 1e6,
            "movement_energy_pct": round(
                100 * self.movement_energy_nj / max(1e-9, self.total_energy_nj), 1),
            "p99_us": self.p(99) / 1e3,
            "p9999_us": self.p(99.99) / 1e3,
            "mix": {r.value: round(100 * f, 1) for r, f in mix.items()},
            "avg_overhead_us": self.avg_decision_overhead_ns / 1e3,
            "instrs": self.n_instrs,
        }


@dataclasses.dataclass
class HostIOStats:
    """Latency accounting for the synthetic host read/write I/O stream
    competing with NDP traffic for channels, dies and the PCIe link."""

    n_reads: int
    n_writes: int
    latencies_ns: List[float]
    # ops surfaced as failed under fault injection (unrecoverable reads,
    # rejected writes, timeout-retry budgets spent) — excluded from the
    # latency population above, never silently dropped
    n_failed: int = 0

    @property
    def n_requests(self) -> int:
        return self.n_reads + self.n_writes

    @property
    def mean_ns(self) -> float:
        if not self.latencies_ns:
            return 0.0
        return sum(self.latencies_ns) / len(self.latencies_ns)

    def p(self, pct: float) -> float:
        return percentile(self.latencies_ns, pct)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "io_requests": self.n_requests,
            "io_reads": self.n_reads,
            "io_mean_us": self.mean_ns / 1e3,
            "io_p50_us": self.p(50) / 1e3,
            "io_p99_us": self.p(99) / 1e3,
            "io_p999_us": self.p(99.9) / 1e3,
        }
        if self.n_failed:
            out["io_failed"] = self.n_failed
        return out


@dataclasses.dataclass
class FTLStats:
    """FTL + garbage-collection accounting for one simulate_mix run.

    ``write_amplification`` is (host + GC copy writes) / host writes —
    exactly 1.0 with GC disabled (infinite over-provisioning).
    ``erase_counts`` is the per-block wear histogram (flattened across
    dies); ``host_during_gc_ns`` the latencies of host requests issued
    while any die's collector was active, isolating the tail-latency cost
    attributable to GC traffic.

    The policy fields record which GC policy suite produced the run:
    ``victim_policy`` (greedy / cost_benefit / wear_aware), ``hot_cold``
    (plus the hot/cold write split), and ``gc_suspend`` with
    ``gc_suspensions`` — how often the throttled collector backed off to
    a deep host queue instead of booking a copy."""

    gc_enabled: bool
    n_logical_pages: int
    n_physical_pages: int
    host_pages_written: int
    gc_pages_copied: int
    blocks_erased: int
    gc_invocations: int
    overflow_blocks: int
    gc_energy_nj: float
    erase_counts: List[int]
    host_during_gc_ns: List[float]
    victim_policy: str = "greedy"
    hot_cold: bool = False
    gc_suspend: bool = False
    gc_suspensions: int = 0
    hot_pages_written: int = 0
    cold_pages_written: int = 0
    # overflow grows taken on the GC append point itself (pool exhausted
    # before the block reserve could be honored) — 0 on healthy
    # reserve-enabled runs, a subset of ``overflow_blocks``
    gc_overflow_blocks: int = 0
    # end of the last die/channel booking the collector made — the GC
    # tail that can outlive every tenant and host request, folded into
    # MixResult/ServingResult makespans (0.0 if GC never booked)
    last_booked_ns: float = 0.0
    # bad-block retirement (fault injection; see repro.sim.faults):
    # blocks permanently removed from the pool and the surviving valid
    # pages relocated through the GC machinery on the way out
    blocks_retired: int = 0
    pages_relocated: int = 0

    @property
    def write_amplification(self) -> float:
        if self.host_pages_written == 0:
            return 1.0
        return (self.host_pages_written + self.gc_pages_copied) \
            / self.host_pages_written

    @property
    def max_erase_count(self) -> int:
        return max(self.erase_counts, default=0)

    @property
    def mean_erase_count(self) -> float:
        if not self.erase_counts:
            return 0.0
        return sum(self.erase_counts) / len(self.erase_counts)

    @property
    def wear_flatness(self) -> float:
        """Mean/max erase count: 1.0 = perfectly level wear, -> 0 as a few
        blocks absorb all erases (the metric wear-aware victim selection
        drives toward 1.0).  1.0 on a drive that never erased."""
        m = self.max_erase_count
        if m == 0:
            return 1.0
        return self.mean_erase_count / m

    def wear_histogram(self) -> Dict[int, int]:
        """erase count -> number of blocks (the wear distribution)."""
        out: Dict[int, int] = {}
        for c in self.erase_counts:
            out[c] = out.get(c, 0) + 1
        return out

    def p_during_gc(self, pct: float) -> float:
        """Host-I/O latency percentile over requests issued during GC."""
        return percentile(self.host_during_gc_ns, pct)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "ftl_gc": self.gc_enabled,
            "victim_policy": self.victim_policy,
            "hot_cold": self.hot_cold,
            "gc_suspend": self.gc_suspend,
            "write_amp": round(self.write_amplification, 3),
            "host_pages_written": self.host_pages_written,
            "gc_pages_copied": self.gc_pages_copied,
            "gc_invocations": self.gc_invocations,
            "gc_suspensions": self.gc_suspensions,
            "blocks_erased": self.blocks_erased,
            "max_erase": self.max_erase_count,
            "wear_flatness": round(self.wear_flatness, 3),
            "io_during_gc": len(self.host_during_gc_ns),
            "io_p99_during_gc_us": self.p_during_gc(99) / 1e3,
        }
        if self.blocks_retired:
            out["blocks_retired"] = self.blocks_retired
            out["pages_relocated"] = self.pages_relocated
        return out


class SessionState(enum.Enum):
    """Terminal state of an open-loop session (:mod:`repro.sim.serving`).

    ``PENDING`` is the only non-terminal state: a session still queued or
    executing when the record is inspected mid-run (a drained run leaves
    none).  The terminal states are mutually exclusive — the explicit
    enum replaces the old ``completed`` bool + NaN-p99 convention, under
    which a window where every session timed out was indistinguishable
    from one that measured nothing at all."""

    PENDING = "pending"
    COMPLETED = "completed"          # ran to completion, counted in goodput
    REJECTED = "rejected"            # bounced off the full admission backlog
    FAILED = "failed"                # an unrecoverable fault inside the run
    TIMED_OUT = "timed_out"          # exceeded the session timeout
    CANCELLED = "cancelled"          # revoked while queued (hedging twin lost)


@dataclasses.dataclass
class SessionRecord:
    """One open-loop session's lifecycle (:mod:`repro.sim.serving`).

    ``latency_ns`` is arrival-to-completion — it includes time spent in
    the admission backlog, which is exactly what an open-loop client
    observes.  It is only defined for completed sessions: reading it on a
    rejected / failed / timed-out record raises instead of returning the
    nonsense negative ``-1.0 - arrival_ns`` (consumers must filter on
    :attr:`completed` first, as :attr:`ServingResult.measured_sessions`
    does).  ``measured`` marks sessions whose *arrival* falls inside the
    steady-state window (after warm-up, before cool-down)."""

    sid: int
    kind: str
    arrival_ns: float
    admit_ns: float = -1.0          # admission time (-1: never admitted)
    done_ns: float = -1.0           # end of the session's last booking
    state: SessionState = SessionState.PENDING
    measured: bool = False

    @property
    def completed(self) -> bool:
        return self.state is SessionState.COMPLETED

    @property
    def rejected(self) -> bool:
        """Back-compat view of the admission-rejection terminal state."""
        return self.state is SessionState.REJECTED

    @property
    def failed(self) -> bool:
        return self.state is SessionState.FAILED

    @property
    def timed_out(self) -> bool:
        return self.state is SessionState.TIMED_OUT

    @property
    def latency_ns(self) -> float:
        """Arrival-to-completion, including admission-queue wait."""
        if self.state is not SessionState.COMPLETED or self.done_ns < 0.0:
            raise ValueError(
                f"session {self.sid} never completed "
                f"(state={self.state.value}): latency_ns is undefined — "
                "filter on .completed before reading latencies")
        return self.done_ns - self.arrival_ns

    @property
    def queue_wait_ns(self) -> float:
        """Time spent queued for admission before a slot freed; raises
        on never-admitted (e.g. rejected) records, like latency_ns."""
        if self.admit_ns < 0.0:
            raise ValueError(
                f"session {self.sid} was never admitted "
                f"(state={self.state.value}): queue_wait_ns is undefined")
        return self.admit_ns - self.arrival_ns


@dataclasses.dataclass
class ServingResult:
    """Result of an open-loop serving run (:func:`repro.sim.serving.simulate_serving`).

    Steady-state metrics are computed over the measurement window
    ``window_ns`` (arrivals after warm-up and before cool-down), so ramp-up
    and drain transients don't pollute the sustained-load numbers.
    ``mean_in_system`` is the time-averaged number of sessions between
    arrival and completion over that window — the L of Little's law;
    :meth:`little_law_ratio` checks L ≈ λ·W as a consistency law."""

    policy: str
    sessions: List[SessionRecord]
    n_offered: int                   # sessions the arrival process generated
    n_admitted: int
    n_rejected: int
    n_completed: int
    window_ns: Tuple[float, float]   # steady-state measurement window
    mean_in_system: float            # time-avg sessions in system (window)
    op_latencies_ns: List[float]     # measured sessions' per-op latencies
    utilization: Dict[str, float]    # pool -> busy fraction within window
    makespan_ns: float
    host_io: Optional[HostIOStats] = None
    session_results: Optional[List[SimResult]] = None  # per-session detail
    ftl: Optional[FTLStats] = None   # present when an FTL was configured
    # FlightRecorder when the run was invoked with telemetry=...
    telemetry: Optional[object] = None
    n_failed: int = 0                # unrecoverable fault inside the session
    n_timed_out: int = 0             # exceeded the session timeout
    # FaultStats when the run was invoked with faults=...
    faults: Optional[object] = None
    # hedged twins revoked while still queued (fleet runs only; always 0
    # for single-drive simulate_serving, which never cancels)
    n_cancelled: int = 0

    # -- conservation ---------------------------------------------------------

    @property
    def n_inflight(self) -> int:
        """Sessions with no terminal state (0 after a drained run);
        offered == completed + rejected + failed + timed-out + cancelled
        + inflight is the conservation law."""
        return (self.n_offered - self.n_completed - self.n_rejected
                - self.n_failed - self.n_timed_out - self.n_cancelled)

    # -- robustness -----------------------------------------------------------

    @property
    def availability(self) -> float:
        """Fraction of *admitted, terminal* sessions that completed
        successfully: ``completed / (completed + failed + timed-out)``.
        Rejections are admission control, not failures, and stay out of
        the denominator (they gate saturation separately).  1.0 on a run
        where nothing was admitted."""
        den = self.n_completed + self.n_failed + self.n_timed_out
        if den == 0:
            return 1.0
        return self.n_completed / den

    @property
    def goodput_per_sec(self) -> float:
        """*Successful* sessions per second inside the measurement
        window — what a degraded drive actually delivers.  Identical to
        :attr:`completed_rate_per_sec` (which only ever counts
        successfully completed sessions), named for the
        availability-aware saturation search."""
        return self.completed_rate_per_sec

    # -- steady-state window --------------------------------------------------

    @property
    def window_span_ns(self) -> float:
        lo, hi = self.window_ns
        return max(0.0, hi - lo)

    @property
    def measured_sessions(self) -> List[SessionRecord]:
        return [s for s in self.sessions if s.measured and s.completed]

    @property
    def session_latencies_ns(self) -> List[float]:
        return [s.latency_ns for s in self.measured_sessions]

    def p(self, pct: float) -> float:
        """Per-session latency percentile over the measured window."""
        return percentile(self.session_latencies_ns, pct)

    def analysis(self, git_sha: Optional[str] = None) -> Dict[str, object]:
        """The ``conduit-analysis/v1`` run report for this run's trace
        (:func:`repro.sim.analysis.build_report`): tail-latency blame,
        critical path, pool bottlenecks.  Requires the run to have been
        invoked with ``telemetry=``."""
        if self.telemetry is None:
            raise ValueError(
                "no flight recorder on this result: rerun with "
                "telemetry=TelemetryConfig(...) to enable analysis")
        from repro.sim.analysis import build_report
        return build_report(self.telemetry, git_sha=git_sha)

    def op_p(self, pct: float) -> float:
        """Per-op latency percentile over the measured window."""
        return percentile(self.op_latencies_ns, pct)

    @property
    def offered_rate_per_sec(self) -> float:
        """Arrival rate observed inside the measurement window."""
        span = self.window_span_ns
        if span <= 0.0:
            return 0.0
        lo, hi = self.window_ns
        n = sum(1 for s in self.sessions if lo <= s.arrival_ns <= hi)
        return n / (span / 1e9)

    @property
    def completed_rate_per_sec(self) -> float:
        """Completion throughput inside the window — the number that
        saturates below the offered rate once the drive is overloaded."""
        span = self.window_span_ns
        if span <= 0.0:
            return 0.0
        lo, hi = self.window_ns
        n = sum(1 for s in self.sessions
                if s.completed and lo <= s.done_ns <= hi)
        return n / (span / 1e9)

    # -- Little's law ---------------------------------------------------------

    def little_law_ratio(self) -> float:
        """L / (λ·W) over the measurement window — ≈1.0 on a stable run.

        λ is the measured completion rate and W the mean session latency;
        deviations come from edge sessions straddling the window and from
        the engine's lazy booking (a session's final bookings can end
        after the event that completes it)."""
        lats = self.session_latencies_ns
        if not lats or self.window_span_ns <= 0.0:
            return 1.0
        lam_per_ns = self.completed_rate_per_sec / 1e9
        w = sum(lats) / len(lats)
        lw = lam_per_ns * w
        if lw <= 0.0:
            return 1.0
        return self.mean_in_system / lw

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "policy": self.policy,
            "offered": self.n_offered,
            "completed": self.n_completed,
            "rejected": self.n_rejected,
            "failed": self.n_failed,
            "timed_out": self.n_timed_out,
            "availability": round(self.availability, 4),
            "offered_per_sec": round(self.offered_rate_per_sec, 1),
            "completed_per_sec": round(self.completed_rate_per_sec, 1),
            "session_p50_us": self.p(50) / 1e3,
            "session_p99_us": self.p(99) / 1e3,
            "op_p99_us": self.op_p(99) / 1e3,
            "mean_in_system": round(self.mean_in_system, 3),
            "little_ratio": round(self.little_law_ratio(), 3),
            "max_util": round(max(self.utilization.values(), default=0.0), 3),
        }
        if self.n_cancelled:
            out["cancelled"] = self.n_cancelled
        if self.host_io is not None:
            out.update(self.host_io.summary())
        if self.ftl is not None:
            out.update(self.ftl.summary())
        return out


@dataclasses.dataclass
class FleetSessionRecord:
    """One session's lifecycle as the *fleet* front-end saw it
    (:func:`repro.sim.fleet.simulate_fleet`).

    ``drives`` is the replica set the session was routed to (one entry
    unless replicated/hedged), ``winner`` the drive whose copy reached a
    terminal state first.  ``latency_ns`` is fleet-arrival to first
    completion — under hedging that is the min over the dispatched
    copies, which is the whole point of hedging."""

    sid: int
    kind: str
    arrival_ns: float
    drives: Tuple[int, ...]
    state: SessionState = SessionState.PENDING
    done_ns: float = -1.0
    winner: int = -1                # drive that finished first (-1: none)
    measured: bool = False
    hedged: bool = False            # a duplicate copy was dispatched
    steered: bool = False           # routed away from a degraded primary

    @property
    def completed(self) -> bool:
        return self.state is SessionState.COMPLETED

    @property
    def rejected(self) -> bool:
        return self.state is SessionState.REJECTED

    @property
    def latency_ns(self) -> float:
        if self.state is not SessionState.COMPLETED or self.done_ns < 0.0:
            raise ValueError(
                f"fleet session {self.sid} never completed "
                f"(state={self.state.value}): latency_ns is undefined")
        return self.done_ns - self.arrival_ns


@dataclasses.dataclass
class FleetResult:
    """Result of a fleet serving run (:func:`repro.sim.fleet.simulate_fleet`).

    ``drives`` holds one full :class:`ServingResult` per drive — the
    per-drive breakdown — while ``sessions`` carries the fleet-level
    view (one record per offered session, deduplicated across hedged
    copies).  Every fleet percentile is *sample-merged* via
    :func:`merged_percentile`: per-drive p99s are never averaged."""

    placement: str                   # placement policy name
    policy: str                      # offloading policy (run-wide)
    n_drives: int
    drives: List[ServingResult]
    sessions: List[FleetSessionRecord]
    n_offered: int
    n_fleet_rejected: int            # bounced at the fleet front door
    window_ns: Tuple[float, float]
    makespan_ns: float
    replication: int = 1
    n_hedged: int = 0                # sessions that dispatched a twin
    n_steered: int = 0               # sessions routed off a degraded primary
    n_cancelled: int = 0             # hedge twins revoked while queued
    # list of per-drive FlightRecorders (index = drive id) when the run
    # was invoked with telemetry=...; merge with
    # repro.sim.telemetry.merge_fleet_trace for one Perfetto timeline
    telemetry: Optional[List[object]] = None

    # -- conservation ---------------------------------------------------------

    @property
    def n_completed(self) -> int:
        return sum(1 for s in self.sessions if s.completed)

    @property
    def n_rejected(self) -> int:
        """Sessions that terminated REJECTED — at the fleet front door
        or bounced by every replica's admission control."""
        return sum(1 for s in self.sessions if s.rejected)

    @property
    def n_failed(self) -> int:
        return sum(1 for s in self.sessions
                   if s.state is SessionState.FAILED)

    @property
    def n_timed_out(self) -> int:
        return sum(1 for s in self.sessions
                   if s.state is SessionState.TIMED_OUT)

    @property
    def n_inflight(self) -> int:
        """0 after a drained run: offered == completed + rejected +
        failed + timed-out at the fleet record level (cancels happen to
        *copies*, never to the fleet record itself)."""
        return (self.n_offered - self.n_completed - self.n_rejected
                - self.n_failed - self.n_timed_out)

    @property
    def availability(self) -> float:
        den = self.n_completed + self.n_failed + self.n_timed_out
        if den == 0:
            return 1.0
        return self.n_completed / den

    # -- sample-merged fleet percentiles --------------------------------------

    @property
    def window_span_ns(self) -> float:
        lo, hi = self.window_ns
        return max(0.0, hi - lo)

    @property
    def measured_sessions(self) -> List[FleetSessionRecord]:
        return [s for s in self.sessions if s.measured and s.completed]

    def latency_groups(self) -> List[List[float]]:
        """Measured fleet latencies grouped by winning drive — the
        per-drive sample groups the merged percentile pools.  Group
        sizes are wildly uneven under heat-aware routing or a straggler,
        which is exactly when averaging per-group p99s goes wrong."""
        groups: List[List[float]] = [[] for _ in range(self.n_drives)]
        for s in self.measured_sessions:
            groups[s.winner].append(s.latency_ns)
        return groups

    @property
    def session_latencies_ns(self) -> List[float]:
        return [s.latency_ns for s in self.measured_sessions]

    def p(self, pct: float) -> float:
        """Fleet session-latency percentile, sample-merged across
        drives (never an average of per-drive percentiles)."""
        return merged_percentile(self.latency_groups(), pct)

    def per_drive_p(self, pct: float) -> List[float]:
        """Per-drive percentile breakdown (by winning drive) — for
        straggler hunting, not for re-aggregation."""
        return [percentile(g, pct) for g in self.latency_groups()]

    @property
    def offered_rate_per_sec(self) -> float:
        span = self.window_span_ns
        if span <= 0.0:
            return 0.0
        lo, hi = self.window_ns
        n = sum(1 for s in self.sessions if lo <= s.arrival_ns <= hi)
        return n / (span / 1e9)

    @property
    def completed_rate_per_sec(self) -> float:
        """Fleet completion throughput inside the window — the fleet
        sessions/sec that the saturation search maximises."""
        span = self.window_span_ns
        if span <= 0.0:
            return 0.0
        lo, hi = self.window_ns
        n = sum(1 for s in self.sessions
                if s.completed and lo <= s.done_ns <= hi)
        return n / (span / 1e9)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "placement": self.placement,
            "policy": self.policy,
            "drives": self.n_drives,
            "replication": self.replication,
            "offered": self.n_offered,
            "completed": self.n_completed,
            "rejected": self.n_rejected,
            "fleet_rejected": self.n_fleet_rejected,
            "failed": self.n_failed,
            "timed_out": self.n_timed_out,
            "availability": round(self.availability, 4),
            "offered_per_sec": round(self.offered_rate_per_sec, 1),
            "completed_per_sec": round(self.completed_rate_per_sec, 1),
            "fleet_p50_us": self.p(50) / 1e3,
            "fleet_p99_us": self.p(99) / 1e3,
            "per_drive_p99_us": [round(v / 1e3, 3)
                                 for v in self.per_drive_p(99)],
            "per_drive_completed": [d.n_completed for d in self.drives],
        }
        if self.n_hedged:
            out["hedged"] = self.n_hedged
            out["cancelled"] = self.n_cancelled
        if self.n_steered:
            out["steered"] = self.n_steered
        return out


def jain_fairness(values: List[float]) -> float:
    """Jain's fairness index over per-tenant slowdowns: 1.0 = perfectly
    fair, 1/n = one tenant monopolizes the fabric."""
    if not values:
        return 1.0
    num = sum(values) ** 2
    den = len(values) * sum(v * v for v in values)
    return num / den if den > 0 else 1.0


@dataclasses.dataclass
class MixResult:
    """Result of a multi-tenant run (:func:`repro.sim.tenancy.simulate_mix`).

    ``tenants`` holds one :class:`SimResult` per trace (keyed by
    ``SimResult.tenant``); ``solo_makespan_ns`` the corresponding
    uncontended makespans when ``compute_solo`` was requested, enabling
    the per-tenant *slowdown* interference metric.
    """

    tenants: List[SimResult]
    solo_makespan_ns: Dict[str, float]
    host_io: Optional[HostIOStats]
    fabric_busy_ns: Dict[str, float]
    makespan_ns: float               # end of all tenants + host I/O
    ftl: Optional["FTLStats"] = None  # present when an FTL was configured
    # FlightRecorder when the run was invoked with telemetry=...
    telemetry: Optional[object] = None
    # FaultStats snapshot when the run was invoked with faults=...
    faults: Optional[object] = None

    def tenant(self, name: str) -> SimResult:
        for r in self.tenants:
            if r.tenant == name:
                return r
        raise KeyError(name)

    def analysis(self, git_sha: Optional[str] = None) -> Dict[str, object]:
        """The ``conduit-analysis/v1`` run report for this run's trace
        (:func:`repro.sim.analysis.build_report`).  Requires the run to
        have been invoked with ``telemetry=``."""
        if self.telemetry is None:
            raise ValueError(
                "no flight recorder on this result: rerun with "
                "telemetry=TelemetryConfig(...) to enable analysis")
        from repro.sim.analysis import build_report
        return build_report(self.telemetry, git_sha=git_sha)

    @property
    def slowdowns(self) -> Dict[str, float]:
        """Per-tenant elapsed-time inflation vs. running alone on the SSD
        (elapsed = makespan minus the tenant's arrival offset, so staggered
        arrivals compare like-for-like with their solo runs)."""
        out = {}
        for r in self.tenants:
            solo = self.solo_makespan_ns.get(r.tenant)
            if solo:
                out[r.tenant] = r.elapsed_ns / solo
        return out

    @property
    def fairness(self) -> float:
        return jain_fairness(list(self.slowdowns.values()))

    @property
    def total_energy_nj(self) -> float:
        return sum(r.total_energy_nj for r in self.tenants)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "tenants": len(self.tenants),
            "makespan_ms": self.makespan_ns / 1e6,
            "energy_mj": self.total_energy_nj / 1e6,
            "fairness": round(self.fairness, 4),
            "slowdowns": {k: round(v, 3) for k, v in self.slowdowns.items()},
        }
        if self.host_io is not None:
            out.update(self.host_io.summary())
        if self.ftl is not None:
            out.update(self.ftl.summary())
        return out
