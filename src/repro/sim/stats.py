"""Simulation results: makespan, energy breakdown, latency percentiles,
offloading-decision logs (Figs. 7-10 raw data).

Multi-tenant additions: :class:`MixResult` bundles one :class:`SimResult`
per tenant plus the fairness / interference metrics of the shared-SSD
regime — per-tenant slowdown vs. a solo run, Jain's fairness index over
the slowdowns, and host-I/O tail latency (:class:`HostIOStats`)."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.isa import Resource


def percentile(values: List[float], p: float) -> float:
    """Nearest-rank percentile (p in [0,100])."""
    if not values:
        return 0.0
    s = sorted(values)
    k = max(0, min(len(s) - 1, math.ceil(p / 100.0 * len(s)) - 1))
    return s[k]


@dataclasses.dataclass
class DecisionRecord:
    iid: int
    op: str
    resource: Resource
    t_decide: float
    t_start: float
    t_end: float
    dm_ns: float
    replayed: bool = False


@dataclasses.dataclass
class SimResult:
    policy: str
    workload: str
    makespan_ns: float
    n_instrs: int
    compute_energy_nj: float
    movement_energy_nj: float
    decision_overhead_ns_total: float
    decisions: List[DecisionRecord]
    resource_counts: Dict[Resource, int]
    resource_busy_ns: Dict[str, float]
    coherence_syncs: int
    evictions: int
    replays: int
    colocations: int
    tenant: str = ""                 # tenant id in a simulate_mix run

    @property
    def total_energy_nj(self) -> float:
        return self.compute_energy_nj + self.movement_energy_nj

    @property
    def latencies_ns(self) -> List[float]:
        return [d.t_end - d.t_decide for d in self.decisions]

    def p(self, pct: float) -> float:
        return percentile(self.latencies_ns, pct)

    @property
    def avg_decision_overhead_ns(self) -> float:
        return self.decision_overhead_ns_total / max(1, self.n_instrs)

    def decision_mix(self) -> Dict[Resource, float]:
        total = max(1, sum(self.resource_counts.values()))
        return {r: c / total for r, c in self.resource_counts.items()}

    def summary(self) -> Dict[str, object]:
        mix = self.decision_mix()
        return {
            "policy": self.policy,
            "workload": self.workload,
            "makespan_ms": self.makespan_ns / 1e6,
            "energy_mj": self.total_energy_nj / 1e6,
            "movement_energy_pct": round(
                100 * self.movement_energy_nj / max(1e-9, self.total_energy_nj), 1),
            "p99_us": self.p(99) / 1e3,
            "p9999_us": self.p(99.99) / 1e3,
            "mix": {r.value: round(100 * f, 1) for r, f in mix.items()},
            "avg_overhead_us": self.avg_decision_overhead_ns / 1e3,
            "instrs": self.n_instrs,
        }


@dataclasses.dataclass
class HostIOStats:
    """Latency accounting for the synthetic host read/write I/O stream
    competing with NDP traffic for channels, dies and the PCIe link."""

    n_reads: int
    n_writes: int
    latencies_ns: List[float]

    @property
    def n_requests(self) -> int:
        return self.n_reads + self.n_writes

    @property
    def mean_ns(self) -> float:
        if not self.latencies_ns:
            return 0.0
        return sum(self.latencies_ns) / len(self.latencies_ns)

    def p(self, pct: float) -> float:
        return percentile(self.latencies_ns, pct)

    def summary(self) -> Dict[str, object]:
        return {
            "io_requests": self.n_requests,
            "io_reads": self.n_reads,
            "io_mean_us": self.mean_ns / 1e3,
            "io_p50_us": self.p(50) / 1e3,
            "io_p99_us": self.p(99) / 1e3,
            "io_p999_us": self.p(99.9) / 1e3,
        }


def jain_fairness(values: List[float]) -> float:
    """Jain's fairness index over per-tenant slowdowns: 1.0 = perfectly
    fair, 1/n = one tenant monopolizes the fabric."""
    if not values:
        return 1.0
    num = sum(values) ** 2
    den = len(values) * sum(v * v for v in values)
    return num / den if den > 0 else 1.0


@dataclasses.dataclass
class MixResult:
    """Result of a multi-tenant run (:func:`repro.sim.tenancy.simulate_mix`).

    ``tenants`` holds one :class:`SimResult` per trace (keyed by
    ``SimResult.tenant``); ``solo_makespan_ns`` the corresponding
    uncontended makespans when ``compute_solo`` was requested, enabling
    the per-tenant *slowdown* interference metric.
    """

    tenants: List[SimResult]
    solo_makespan_ns: Dict[str, float]
    host_io: Optional[HostIOStats]
    fabric_busy_ns: Dict[str, float]
    makespan_ns: float               # end of all tenants + host I/O

    def tenant(self, name: str) -> SimResult:
        for r in self.tenants:
            if r.tenant == name:
                return r
        raise KeyError(name)

    @property
    def slowdowns(self) -> Dict[str, float]:
        """Per-tenant makespan inflation vs. running alone on the SSD."""
        out = {}
        for r in self.tenants:
            solo = self.solo_makespan_ns.get(r.tenant)
            if solo:
                out[r.tenant] = r.makespan_ns / solo
        return out

    @property
    def fairness(self) -> float:
        return jain_fairness(list(self.slowdowns.values()))

    @property
    def total_energy_nj(self) -> float:
        return sum(r.total_energy_nj for r in self.tenants)

    def summary(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "tenants": len(self.tenants),
            "makespan_ms": self.makespan_ns / 1e6,
            "energy_mj": self.total_energy_nj / 1e6,
            "fairness": round(self.fairness, 4),
            "slowdowns": {k: round(v, 3) for k, v in self.slowdowns.items()},
        }
        if self.host_io is not None:
            out.update(self.host_io.summary())
        return out
