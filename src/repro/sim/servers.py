"""Server pools: the contention model of the event-driven simulator.

Every contended unit in the SSD — compute resources (ISP core, DRAM bank
groups, flash channels' compute), interconnects (flash channels, DRAM bus,
PCIe link) and the offloader core itself — is a :class:`ServerPool` with k
units.  Work items acquire a unit FIFO; the pool tracks per-unit
free-times, total busy time, and the queue-delay feature (Table 1,
``delay_queue``) the cost function reads.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass
class Acquisition:
    unit: int
    start: float
    end: float


class ServerPool:
    def __init__(self, name: str, units: int):
        assert units >= 1
        self.name = name
        self.units = units
        self.free: List[float] = [0.0] * units
        self.busy_ns: float = 0.0
        self.jobs: int = 0
        # Running counter of enqueued-but-unfinished work (the paper's §4.5
        # footnote 5 incremental queue counter).
        self._pending_work: float = 0.0

    def queue_delay_ns(self, now: float) -> float:
        """Expected wait before a new job could start (Table 1 feature)."""
        waits = [max(0.0, f - now) for f in self.free]
        return min(waits)

    def pending_work_ns(self, now: float) -> float:
        return sum(max(0.0, f - now) for f in self.free)

    def utilization(self, makespan: float) -> float:
        if makespan <= 0:
            return 0.0
        return self.busy_ns / (makespan * self.units)

    def acquire(self, ready: float, dur: float,
                unit: Optional[int] = None) -> Acquisition:
        """FIFO-acquire a unit at the earliest feasible start >= ready."""
        if unit is None:
            unit = min(range(self.units), key=lambda u: self.free[u])
        start = max(ready, self.free[unit])
        end = start + dur
        self.free[unit] = end
        self.busy_ns += dur
        self.jobs += 1
        return Acquisition(unit=unit, start=start, end=end)

    def peek_start(self, ready: float, unit: Optional[int] = None) -> float:
        if unit is None:
            unit = min(range(self.units), key=lambda u: self.free[u])
        return max(ready, self.free[unit])
