"""Server pools: the contention model of the event-driven simulator.

Every contended unit in the SSD — compute resources (ISP core, DRAM bank
groups, flash channels' compute), interconnects (flash channels, DRAM bus,
PCIe link) and the offloader core itself — is a :class:`ServerPool` with k
units.  Work items acquire a unit FIFO; the pool tracks per-unit
free-times, total busy time, and the queue-delay feature (Table 1,
``delay_queue``) the cost function reads.

:class:`Fabric` groups one full SSD's worth of pools so that several
concurrent tenants (and a background host I/O stream) can contend for the
*same* channels, dies, DRAM bus and PCIe link — the multi-tenant regime of
:func:`repro.sim.tenancy.simulate_mix`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Acquisition:
    unit: int
    start: float
    end: float


class ServerPool:
    def __init__(self, name: str, units: int):
        assert units >= 1
        self.name = name
        self.units = units
        self.free: List[float] = [0.0] * units
        self.busy_ns: float = 0.0
        self.jobs: int = 0
        # Running counter of enqueued-but-unfinished work (the paper's §4.5
        # footnote 5 incremental queue counter).
        self._pending_work: float = 0.0

    def queue_delay_ns(self, now: float) -> float:
        """Expected wait before a new job could start (Table 1 feature)."""
        waits = [max(0.0, f - now) for f in self.free]
        return min(waits)

    def pending_work_ns(self, now: float) -> float:
        return sum(max(0.0, f - now) for f in self.free)

    def utilization(self, makespan: float) -> float:
        if makespan <= 0:
            return 0.0
        return self.busy_ns / (makespan * self.units)

    def acquire(self, ready: float, dur: float,
                unit: Optional[int] = None) -> Acquisition:
        """FIFO-acquire a unit at the earliest feasible start >= ready."""
        if unit is None:
            unit = min(range(self.units), key=lambda u: self.free[u])
        start = max(ready, self.free[unit])
        end = start + dur
        self.free[unit] = end
        self.busy_ns += dur
        self.jobs += 1
        return Acquisition(unit=unit, start=start, end=end)

    def peek_start(self, ready: float, unit: Optional[int] = None) -> float:
        if unit is None:
            unit = min(range(self.units), key=lambda u: self.free[u])
        return max(ready, self.free[unit])

    @property
    def horizon_ns(self) -> float:
        """Latest booked completion across units (end of all queued work)."""
        return max(self.free)


class Fabric:
    """One SSD's contended hardware: compute pools plus interconnects.

    A :class:`~repro.sim.machine.Simulation` owns a private Fabric for
    single-trace runs; :func:`repro.sim.tenancy.simulate_mix` builds one
    Fabric and hands it to every tenant so all traces (and the synthetic
    host I/O stream) share channels, dies, the DRAM bus and the PCIe link.
    """

    def __init__(self, spec, pud_units: int = 8):
        # late import: repro.core.isa imports hw specs, no cycle via servers
        from repro.core.isa import Resource
        f = spec.flash
        self.spec = spec
        self.pools: Dict = {
            Resource.ISP: ServerPool("isp", spec.isp.compute_cores),
            Resource.PUD: ServerPool("pud", pud_units),
            # one pool models the dies: IFP execution, read senses and
            # program write-backs all occupy a die (a die cannot sense
            # while programming) — so die congestion is visible to the
            # cost function's queue feature.
            Resource.IFP: ServerPool("ifp_die", f.total_dies),
            Resource.HOST_CPU: ServerPool("cpu", 1),
            Resource.HOST_GPU: ServerPool("gpu", 1),
        }
        # computation mode (§4.4) suspends host I/O: every controller core
        # not used for ISP compute runs offloading/transformation tasks.
        self.offloader = ServerPool(
            "offloader", max(1, spec.isp.cores - spec.isp.compute_cores))
        self.channels = ServerPool("flash_chan", f.channels)
        self.dies = self.pools[Resource.IFP]   # alias: same physical units
        self.dram_bus = ServerPool("dram_bus", 1)
        self.pcie = ServerPool("pcie", 1)

    def all_pools(self) -> List[ServerPool]:
        return list(self.pools.values()) + [
            self.offloader, self.channels, self.dram_bus, self.pcie]

    def busy_ns(self) -> Dict[str, float]:
        return {p.name: p.busy_ns for p in self.all_pools()}

    @property
    def horizon_ns(self) -> float:
        """End of all booked work anywhere in the fabric."""
        return max(p.horizon_ns for p in self.all_pools())
