"""Server pools: the contention model of the event-driven simulator.

Every contended unit in the SSD — compute resources (ISP core, DRAM bank
groups, flash channels' compute), interconnects (flash channels, DRAM bus,
PCIe link) and the offloader core itself — is a :class:`ServerPool` with k
units.  Work items acquire a unit FIFO; the pool tracks per-unit
free-times, total busy time, and the queue-delay feature (Table 1,
``delay_queue``) the cost function reads.

Performance: the channel x die fabrics make ``acquire``/``peek_start``/
``queue_delay_ns`` the innermost loop of the simulator, so the pool keeps
an incrementally maintained min-structure instead of scanning all k units
per call:

* ``_heap`` is a lazy min-heap of ``(free_time, unit)`` entries.  Every
  update of a unit's free time pushes a fresh entry; entries whose value
  no longer matches ``free[unit]`` are stale and skipped on pop.  Free
  times are monotone per unit (FIFO booking never rewinds), so stale
  entries always sort *before* the live entry of the same unit and are
  discarded in O(log k) amortized.  Tie-breaking matches the old linear
  scan exactly: the heap orders by ``(free_time, unit)``, i.e. the
  lowest-indexed unit among equally-free units wins.
* ``_pending_work`` is the running pending-work counter (the paper's §4.5
  footnote 5 incremental queue counter): the sum of all units' booked
  free times, maintained in O(1) per acquire.  ``pending_work_ns(now)``
  subtracts each unit's already-elapsed share (``min(free_u, now)``) from
  the counter, which equals the brute-force ``sum(max(0, free_u - now))``
  for *any* probe time — asserted in ``tests/test_servers_fastpath.py``.

:class:`Fabric` groups one full SSD's worth of pools so that several
concurrent tenants (and a background host I/O stream) can contend for the
*same* channels, dies, DRAM bus and PCIe link — the multi-tenant regime of
:func:`repro.sim.tenancy.simulate_mix`.
"""
from __future__ import annotations

from heapq import heappop, heappush
from typing import Dict, List, NamedTuple, Optional


class Acquisition(NamedTuple):
    unit: int
    start: float
    end: float


class ServerPool:
    __slots__ = ("name", "units", "free", "busy_ns", "jobs", "_heap",
                 "_pending_work", "_single", "tracer")

    def __init__(self, name: str, units: int):
        assert units >= 1
        self.name = name
        self.units = units
        self.free: List[float] = [0.0] * units
        self.busy_ns: float = 0.0
        self.jobs: int = 0
        # lazy min-heap over (free_time, unit); one live entry per unit
        self._heap: List[tuple] = [(0.0, u) for u in range(units)]
        # Running counter of booked work (the paper's §4.5 footnote 5
        # incremental queue counter): the sum of all units' free times,
        # maintained in O(1) on every acquire.  Pending work at time t is
        # this counter minus each unit's elapsed share (pending_work_ns).
        self._pending_work: float = 0.0
        # Single-unit pools (DRAM bus, PCIe, host CPU/GPU) are booked on
        # nearly every page move: they skip the heap entirely — free[0]
        # IS the min — with arithmetic identical to the heap path.  NB
        # the heap is then never maintained for them; every reader below
        # must branch on the flag before touching it.
        self._single: bool = units == 1
        # optional booking observer, set by the flight recorder
        # (repro.sim.telemetry): called (name, unit, start, end) after
        # every acquire.  None (the default) costs one predictable
        # branch per booking.
        self.tracer = None

    # -- min-structure maintenance --------------------------------------------

    def _min_unit(self) -> tuple:
        """(free_time, unit) of the earliest-free unit, lowest index on
        ties — identical to the old ``min(range(units))`` scan."""
        if self._single:
            return self.free[0], 0
        heap = self._heap
        free = self.free
        while True:
            f, u = heap[0]
            if free[u] == f:
                return f, u
            heappop(heap)          # stale: the unit was re-booked since

    # -- queue features --------------------------------------------------------

    def queue_delay_ns(self, now: float) -> float:
        """Expected wait before a new job could start (Table 1 feature)."""
        # inlined _min_unit: this is the cost function's innermost probe
        if self._single:
            d = self.free[0] - now
            return d if d > 0.0 else 0.0
        heap = self._heap
        free = self.free
        while True:
            f, u = heap[0]
            if free[u] == f:
                break
            heappop(heap)
        d = f - now
        return d if d > 0.0 else 0.0

    def pending_work_ns(self, now: float) -> float:
        """Total booked-but-unfinished work across units at ``now``:
        the maintained counter minus each unit's already-elapsed share.

        The counter accumulates incrementally, so the result can differ
        from the direct ``sum(max(0, f - now))`` by float-rounding ulps;
        it is clamped at zero so an idle pool always reads exactly 0.0."""
        pending = self._pending_work
        for f in self.free:
            pending -= f if f < now else now
        return pending if pending > 0.0 else 0.0

    def utilization(self, makespan: float) -> float:
        if makespan <= 0 or self.jobs == 0:
            return 0.0
        return self.busy_ns / (makespan * self.units)

    # -- booking ---------------------------------------------------------------

    def acquire(self, ready: float, dur: float,
                unit: Optional[int] = None) -> Acquisition:
        """FIFO-acquire a unit at the earliest feasible start >= ready."""
        free = self.free
        if self._single:
            f = free[0]
            start = ready if ready > f else f
            end = start + dur
            free[0] = end
            self._pending_work += end - f
            self.busy_ns += dur
            self.jobs += 1
            if self.tracer is not None:
                self.tracer(self.name, 0, start, end)
            return Acquisition(0, start, end)
        if unit is None:
            heap = self._heap
            while True:
                f, u = heap[0]
                if free[u] == f:
                    break
                heappop(heap)
            unit = u
        else:
            f = free[unit]
        start = ready if ready > f else f
        end = start + dur
        free[unit] = end
        heappush(self._heap, (end, unit))
        self._pending_work += end - f
        self.busy_ns += dur
        self.jobs += 1
        if self.tracer is not None:
            self.tracer(self.name, unit, start, end)
        return Acquisition(unit, start, end)

    def acquire_se(self, ready: float, dur: float,
                   unit: Optional[int] = None) -> tuple:
        """:meth:`acquire`, returning a plain ``(start, end)`` tuple.

        For booking sites that need both endpoints but not the unit:
        skips the NamedTuple construction on the per-dispatch path."""
        free = self.free
        if self._single:
            f = free[0]
            start = ready if ready > f else f
            end = start + dur
            free[0] = end
            self._pending_work += end - f
            self.busy_ns += dur
            self.jobs += 1
            if self.tracer is not None:
                self.tracer(self.name, 0, start, end)
            return start, end
        if unit is None:
            heap = self._heap
            while True:
                f, u = heap[0]
                if free[u] == f:
                    break
                heappop(heap)
            unit = u
        else:
            f = free[unit]
        start = ready if ready > f else f
        end = start + dur
        free[unit] = end
        heappush(self._heap, (end, unit))
        self._pending_work += end - f
        self.busy_ns += dur
        self.jobs += 1
        if self.tracer is not None:
            self.tracer(self.name, unit, start, end)
        return start, end

    def acquire_end(self, ready: float, dur: float,
                    unit: Optional[int] = None) -> float:
        """:meth:`acquire`, returning only the completion time.

        The allocation-free fast path for the (majority of) booking sites
        that chain on ``.end`` and never read the unit or start."""
        free = self.free
        if self._single:
            f = free[0]
            end = (ready if ready > f else f) + dur
            free[0] = end
            self._pending_work += end - f
            self.busy_ns += dur
            self.jobs += 1
            if self.tracer is not None:
                self.tracer(self.name, 0, end - dur, end)
            return end
        if unit is None:
            heap = self._heap
            while True:
                f, u = heap[0]
                if free[u] == f:
                    break
                heappop(heap)
            unit = u
        else:
            f = free[unit]
        end = (ready if ready > f else f) + dur
        free[unit] = end
        heappush(self._heap, (end, unit))
        self._pending_work += end - f
        self.busy_ns += dur
        self.jobs += 1
        if self.tracer is not None:
            self.tracer(self.name, unit, end - dur, end)
        return end

    def peek_start(self, ready: float, unit: Optional[int] = None) -> float:
        f = self._min_unit()[0] if unit is None else self.free[unit]
        return ready if ready > f else f

    @property
    def horizon_ns(self) -> float:
        """Latest booked completion across units (end of all queued work);
        0.0 for a pool that never saw a job."""
        return max(self.free) if self.free else 0.0


class Fabric:
    """One SSD's contended hardware: compute pools plus interconnects.

    A :class:`~repro.sim.machine.Simulation` owns a private Fabric for
    single-trace runs; :func:`repro.sim.tenancy.simulate_mix` builds one
    Fabric and hands it to every tenant so all traces (and the synthetic
    host I/O stream) share channels, dies, the DRAM bus and the PCIe link.
    """

    def __init__(self, spec, pud_units: int = 8):
        # late import: repro.core.isa imports hw specs, no cycle via servers
        from repro.core.isa import Resource
        f = spec.flash
        self.spec = spec
        # optional flight recorder (repro.sim.telemetry): set by
        # FlightRecorder.attach; tenant Simulations bound to this fabric
        # read it to route their dispatch hooks
        self.telemetry = None
        # optional fault model (repro.sim.faults): set by the simulate_*
        # wiring when a FaultConfig with active error sources is passed;
        # tenant Simulations and the host I/O model read it to route
        # flash reads through the recovery ladder
        self.faults = None
        # pools that exist only in some configurations (e.g. the ECC
        # soft-decode engines the fault model registers).  Kept out of
        # ``pools`` so ``busy_ns()`` — and hence the golden digests — is
        # unchanged whenever the list is empty.
        self.extra: List[ServerPool] = []
        self.pools: Dict = {
            Resource.ISP: ServerPool("isp", spec.isp.compute_cores),
            Resource.PUD: ServerPool("pud", pud_units),
            # one pool models the dies: IFP execution, read senses and
            # program write-backs all occupy a die (a die cannot sense
            # while programming) — so die congestion is visible to the
            # cost function's queue feature.
            Resource.IFP: ServerPool("ifp_die", f.total_dies),
            Resource.HOST_CPU: ServerPool("cpu", 1),
            Resource.HOST_GPU: ServerPool("gpu", 1),
        }
        # dense tuple indexed by ``Resource.index`` — the dispatch loop's
        # form of the mapping above (enum definition order == index order)
        self.pools_by_index = tuple(self.pools[r] for r in Resource)
        # computation mode (§4.4) suspends host I/O: every controller core
        # not used for ISP compute runs offloading/transformation tasks.
        self.offloader = ServerPool(
            "offloader", max(1, spec.isp.cores - spec.isp.compute_cores))
        self.channels = ServerPool("flash_chan", f.channels)
        self.dies = self.pools[Resource.IFP]   # alias: same physical units
        self.dram_bus = ServerPool("dram_bus", 1)
        self.pcie = ServerPool("pcie", 1)
        # movement-path queue feature: which pools a src->dst page transfer
        # waits on, precomputed for all 16 location pairs (shared by every
        # tenant Simulation bound to this fabric)
        from repro.core.isa import Location
        self.path_pools: Dict = {}
        for src in Location:
            for dst in Location:
                pools: List[ServerPool] = []
                if src != dst:
                    if src is Location.FLASH or dst is Location.FLASH:
                        pools += [self.dies, self.channels]
                    if (Location.DRAM in (src, dst)
                            or Location.CTRL in (src, dst)):
                        pools.append(self.dram_bus)
                    if Location.HOST in (src, dst):
                        pools.append(self.pcie)
                self.path_pools[(src, dst)] = tuple(pools)
        # flat form indexed by ``src.index * N_LOCATIONS + dst.index`` —
        # the dispatch loop probes a movement path per off-home operand,
        # and an int-indexed tuple read beats hashing an enum pair
        from repro.core.isa import N_LOCATIONS
        self.n_locations = N_LOCATIONS
        self.path_pools_by_index = tuple(
            self.path_pools[(s, d)] for s in Location for d in Location)

    def all_pools(self) -> List[ServerPool]:
        return list(self.pools.values()) + [
            self.offloader, self.channels, self.dram_bus, self.pcie] \
            + self.extra

    def busy_ns(self) -> Dict[str, float]:
        return {p.name: p.busy_ns for p in self.all_pools()}

    @property
    def horizon_ns(self) -> float:
        """End of all booked work anywhere in the fabric."""
        return max(p.horizon_ns for p in self.all_pools())
