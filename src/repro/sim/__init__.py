"""Event-ordered SSD NDP simulator (the paper's §5 evaluation vehicle)."""
from repro.sim.machine import SimConfig, Simulation, simulate
from repro.sim.servers import ServerPool
from repro.sim.stats import DecisionRecord, SimResult, percentile

__all__ = ["SimConfig", "Simulation", "simulate", "ServerPool",
           "DecisionRecord", "SimResult", "percentile"]
