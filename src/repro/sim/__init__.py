"""Discrete-event SSD NDP simulator (the paper's §5 evaluation vehicle).

Single-tenant entry point: :func:`simulate` (one trace, one policy).
Multi-tenant entry point: :func:`simulate_mix` (several traces plus an
optional synthetic host I/O stream sharing one fabric).
Open-loop serving entry point: :func:`simulate_serving` (sessions drawn
from a weighted catalog keep arriving mid-run; steady-state throughput /
tail latency, plus :func:`find_saturation` for the max sustainable rate).
Batched sweeps: :func:`batched_find_saturation` runs many saturation
searches in lockstep (policy grids, seed fans) on a vectorized driver.
Fleet entry point: :func:`simulate_fleet` (N :class:`DriveActor` drives
behind a placement layer — replication, read steering, hedging, fleet
admission — plus :func:`find_fleet_saturation` for fleet sessions/sec
at a fleet p99 SLO).
All run on the time-ordered event heap in :mod:`repro.sim.events`.
"""
from repro.sim.analysis import (blame_story, build_report, critical_path,
                                diff_reports, fleet_blame, pool_rankings,
                                session_blame, split_fleet_trace)
from repro.sim.drive import DriveActor, DriveHealth, DrivePoll
from repro.sim.events import EventEngine, EventKind
from repro.sim.faults import FaultConfig, FaultModel, FaultStats
from repro.sim.ftl import (VICTIM_POLICIES, CostBenefitVictim, FTLConfig,
                           FTLModel, GreedyVictim, OutOfPhysicalBlocks,
                           VictimPolicy, WearAwareVictim,
                           drive_zipf_overwrites, make_victim_policy)
from repro.sim.fleet import (DriveProfile, FleetConfig,
                             find_fleet_saturation, simulate_fleet)
from repro.sim.machine import SimConfig, Simulation, simulate
from repro.sim.placement import (ConsistentHashPlacement, HashPlacement,
                                 HeatAwarePlacement, PlacementPolicy,
                                 derive_drive_seed, make_placement)
from repro.sim.servers import Fabric, ServerPool
from repro.sim.serving import (SaturationProbe, SaturationResult,
                               ServingConfig, find_saturation,
                               simulate_serving)
from repro.sim.sweep import (FleetSweepLane, SweepLane, array_backend,
                             batched_find_fleet_saturation,
                             batched_find_saturation,
                             batched_poisson_arrival_times_ns)
from repro.sim.stats import (DecisionRecord, FleetResult,
                             FleetSessionRecord, FTLStats, HostIOStats,
                             MixResult, ServingResult, SessionRecord,
                             SessionState, SimResult, jain_fairness,
                             merged_percentile, percentile)
from repro.sim.telemetry import (CandidateCost, FlightRecorder,
                                 IntervalSample, OffloadAudit,
                                 TelemetryConfig, export_fleet_trace,
                                 merge_fleet_trace, summarize as
                                 summarize_trace, validate_trace)
from repro.sim.tenancy import HostIOStream, clone_trace, simulate_mix
from repro.sim.workgen import (ArrivalProcess, CatalogEntry,
                               DeterministicArrivals, MMPPArrivals,
                               PoissonArrivals, SessionCatalog,
                               SuperposedArrivals, TraceReplayArrivals)

__all__ = ["SimConfig", "Simulation", "simulate", "ServerPool", "Fabric",
           "EventEngine", "EventKind",
           "HostIOStream", "simulate_mix", "clone_trace",
           "FTLConfig", "FTLModel", "FTLStats",
           "VictimPolicy", "GreedyVictim", "CostBenefitVictim",
           "WearAwareVictim", "VICTIM_POLICIES", "make_victim_policy",
           "drive_zipf_overwrites",
           "DecisionRecord", "HostIOStats", "MixResult", "SimResult",
           "jain_fairness", "percentile",
           "ArrivalProcess", "PoissonArrivals", "MMPPArrivals",
           "DeterministicArrivals", "TraceReplayArrivals",
           "SuperposedArrivals", "CatalogEntry", "SessionCatalog",
           "ServingConfig", "ServingResult", "SessionRecord",
           "SessionState", "simulate_serving", "find_saturation",
           "FaultConfig", "FaultModel", "FaultStats",
           "OutOfPhysicalBlocks",
           "SaturationProbe", "SaturationResult",
           "SweepLane", "batched_find_saturation",
           "FleetSweepLane", "batched_find_fleet_saturation",
           "batched_poisson_arrival_times_ns", "array_backend",
           "TelemetryConfig", "FlightRecorder", "OffloadAudit",
           "CandidateCost", "IntervalSample", "validate_trace",
           "summarize_trace",
           "build_report", "session_blame", "critical_path",
           "pool_rankings", "diff_reports", "blame_story",
           "DriveActor", "DriveHealth", "DrivePoll",
           "DriveProfile", "FleetConfig", "simulate_fleet",
           "find_fleet_saturation",
           "PlacementPolicy", "HashPlacement", "ConsistentHashPlacement",
           "HeatAwarePlacement", "make_placement", "derive_drive_seed",
           "FleetResult", "FleetSessionRecord", "merged_percentile",
           "merge_fleet_trace", "export_fleet_trace",
           "split_fleet_trace", "fleet_blame"]
