"""Discrete-event SSD NDP simulator (the paper's §5 evaluation vehicle).

Single-tenant entry point: :func:`simulate` (one trace, one policy).
Multi-tenant entry point: :func:`simulate_mix` (several traces plus an
optional synthetic host I/O stream sharing one fabric).  Both run on the
time-ordered event heap in :mod:`repro.sim.events`.
"""
from repro.sim.events import Event, EventEngine, EventKind
from repro.sim.ftl import FTLConfig, FTLModel
from repro.sim.machine import SimConfig, Simulation, simulate
from repro.sim.servers import Fabric, ServerPool
from repro.sim.stats import (DecisionRecord, FTLStats, HostIOStats,
                             MixResult, SimResult, jain_fairness, percentile)
from repro.sim.tenancy import HostIOStream, simulate_mix

__all__ = ["SimConfig", "Simulation", "simulate", "ServerPool", "Fabric",
           "Event", "EventEngine", "EventKind",
           "HostIOStream", "simulate_mix",
           "FTLConfig", "FTLModel", "FTLStats",
           "DecisionRecord", "HostIOStats", "MixResult", "SimResult",
           "jain_fairness", "percentile"]
