"""Placement policies + per-drive RNG lineage for the SSD fleet.

A placement policy answers two questions, both deterministically:

* **replicas(sid)** — which ``r`` distinct drives hold session ``sid``'s
  data (the *replica set*, primary first).  This is data placement: it
  never depends on load, only on the session id, so the same session
  always lands on the same drives across runs and policies can be
  compared apples-to-apples.
* **route(sid, candidates, health)** — in what order the fleet should
  *prefer* the replica set right now.  Static policies return the
  candidates unchanged; :class:`HeatAwarePlacement` (``needs_health``)
  reorders by a load score from the drives'
  :class:`~repro.sim.drive.DriveHealth` snapshots.

Read steering and hedging are *fleet* mechanisms layered on the route
order (:mod:`repro.sim.fleet`), not policy internals — so every policy
composes with both.

Seed lineage (ISSUE 10 satellite): :func:`derive_drive_seed` gives each
drive of a fleet a deterministic but distinct RNG stream from one fleet
seed.  Two laws, both tested:

* ``derive_drive_seed(seed, 0) == seed`` — drive 0 inherits the fleet
  seed unchanged, which is what makes a 1-drive fleet bit-identical to
  the single-drive entry points.
* The derivation is per-drive pure: adding drive k+1 to a fleet never
  perturbs the draws of drives 0..k (no shared RNG object to advance).
"""
from __future__ import annotations

import bisect
from typing import Dict, List, Optional, Sequence, Tuple

_MASK64 = (1 << 64) - 1


def mix64(x: int) -> int:
    """SplitMix64 finalizer: a cheap, well-distributed 64-bit mix."""
    x &= _MASK64
    x = (x ^ (x >> 30)) * 0xBF58476D1CE4E5B9 & _MASK64
    x = (x ^ (x >> 27)) * 0x94D049BB133111EB & _MASK64
    return x ^ (x >> 31)


def derive_drive_seed(seed: int, drive: int, salt: int = 0) -> int:
    """Per-drive seed from one fleet seed; deterministic and distinct.

    ``drive == 0`` with the default salt returns ``seed`` unchanged —
    the identity that makes the N=1 fleet equivalence law exact.  Other
    drives get independent splitmix-derived streams; ``salt``
    distinguishes stream *kinds* on one drive (0: host-I/O arrivals,
    1: fault draws) so the two never correlate."""
    if drive == 0 and salt == 0:
        return seed
    x = mix64(seed ^ 0x9E3779B97F4A7C15)
    x = mix64(x + drive)           # sequential splitmix-style absorption:
    return mix64(x + (salt << 32))  # every (drive, salt) is a fresh stream


class PlacementPolicy:
    """Deterministic session→drives mapping; see the module docstring."""

    #: registry / display name
    name = "base"
    #: True if :meth:`route` consumes DriveHealth snapshots — forces the
    #: fleet into the lockstep driver loop (static policies pre-partition)
    needs_health = False

    def __init__(self, n_drives: int):
        if n_drives < 1:
            raise ValueError("n_drives must be >= 1")
        self.n_drives = n_drives

    def replicas(self, sid: int, r: int) -> Tuple[int, ...]:
        """``r`` distinct drives holding session ``sid``, primary first."""
        raise NotImplementedError

    def route(self, sid: int, candidates: Sequence[int],
              health: Optional[Dict[int, object]] = None
              ) -> Tuple[int, ...]:
        """Preference order over the replica set; default: placement
        order (primary first), independent of load."""
        return tuple(candidates)


class HashPlacement(PlacementPolicy):
    """Hash the session id; replicas by chained declustering.

    Primary ``mix64(sid) % N``; the ``j``-th replica is the next drive
    modulo N, so each drive's replica load spreads over its neighbours
    (chained declustering) and a retirement fans rebuild reads out
    instead of doubling one mirror's load."""

    name = "hash"

    def replicas(self, sid: int, r: int) -> Tuple[int, ...]:
        r = min(r, self.n_drives)
        p = mix64(sid + 0x5851F42D4C957F2D) % self.n_drives
        return tuple((p + j) % self.n_drives for j in range(r))


class ConsistentHashPlacement(PlacementPolicy):
    """Consistent hashing with virtual nodes.

    Each drive owns ``vnodes`` points on a 64-bit ring; a session maps
    to the first ``r`` *distinct* drives clockwise from its hash.  The
    property bought over plain hashing: resizing the fleet from N to
    N+1 remaps only ~1/(N+1) of sessions, so saturation-vs-N sweeps
    measure contention, not wholesale reshuffling."""

    name = "consistent"

    def __init__(self, n_drives: int, vnodes: int = 64):
        super().__init__(n_drives)
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        points: List[Tuple[int, int]] = []
        for d in range(n_drives):
            for v in range(vnodes):
                points.append((mix64((d << 20) | v | 0xC0FFEE << 40), d))
        points.sort()
        self._ring_keys = [k for k, _ in points]
        self._ring_drives = [d for _, d in points]

    def replicas(self, sid: int, r: int) -> Tuple[int, ...]:
        r = min(r, self.n_drives)
        i = bisect.bisect_right(self._ring_keys,
                                mix64(sid + 0x2545F4914F6CDD1D))
        n = len(self._ring_keys)
        out: List[int] = []
        for step in range(n):
            d = self._ring_drives[(i + step) % n]
            if d not in out:
                out.append(d)
                if len(out) == r:
                    break
        return tuple(out)


class HeatAwarePlacement(HashPlacement):
    """Hash-placed data, heat-routed sessions.

    The replica *set* is still :class:`HashPlacement` (data cannot move
    per request) but :meth:`route` orders the set by a load score from
    live :class:`~repro.sim.drive.DriveHealth` snapshots: queue depth
    plus penalties for active GC, recovery windows and degraded dies.
    Ties preserve placement order, keeping the routing deterministic."""

    name = "heat"
    needs_health = True

    #: score penalties, in units of queued sessions
    GC_PENALTY = 4.0
    RECOVERY_PENALTY = 8.0
    DEGRADED_PENALTY = 2.0

    def route(self, sid: int, candidates: Sequence[int],
              health: Optional[Dict[int, object]] = None
              ) -> Tuple[int, ...]:
        if not health:
            return tuple(candidates)

        def score(d: int) -> float:
            h = health.get(d)
            if h is None:
                return 0.0
            if h.retired:
                return float("inf")
            s = float(h.inflight)
            if h.gc_busy:
                s += self.GC_PENALTY + h.gc_active_dies
            if h.recovering:
                s += self.RECOVERY_PENALTY
            s += self.DEGRADED_PENALTY * (h.read_only_dies + h.failed_dies)
            return s

        # stable sort: equal scores keep placement (primary-first) order
        return tuple(sorted(candidates, key=score))


_REGISTRY = {
    "hash": HashPlacement,
    "consistent": ConsistentHashPlacement,
    "heat": HeatAwarePlacement,
}


def make_placement(name, n_drives: int) -> PlacementPolicy:
    """Resolve a placement by registry name (``hash`` / ``consistent`` /
    ``heat``) or pass a :class:`PlacementPolicy` instance through."""
    if isinstance(name, PlacementPolicy):
        return name
    try:
        return _REGISTRY[name](n_drives)
    except KeyError:
        raise ValueError(
            f"unknown placement {name!r}: expected one of "
            f"{sorted(_REGISTRY)} or a PlacementPolicy instance") from None
