"""Rack-scale fleet serving: N drive actors behind a placement layer.

:func:`simulate_fleet` serves one open-loop session stream on a fleet of
:class:`~repro.sim.drive.DriveActor` drives.  The fleet front-end owns
nothing drive-local: it draws the catalog fleet-wide, routes each
session through a :mod:`repro.sim.placement` policy, and talks to the
drives only through the three actor seams (submit / poll / advance).

Two driver regimes, chosen automatically:

* **static** — placement independent of load (hash / consistent-hash,
  no steering, no hedging, no fleet admission cap, no retirement).  The
  session stream pre-partitions into per-drive plans and every drive
  runs to quiescence independently: embarrassingly parallel, and for
  N=1 *bit-identical* to :func:`~repro.sim.serving.simulate_serving`
  (the tested equivalence law — both are one DriveActor built the same
  way).
* **lockstep** — anything load- or time-dependent.  The fleet walks the
  arrival sequence, advances every drive's engine to just before each
  arrival (:meth:`~repro.sim.drive.DriveActor.advance_before`), reads
  health snapshots, and routes on them.  With hedging on, engines are
  interleaved in global event-time order so a win on one drive can
  cancel the still-queued twin on another before that drive's clock
  passes the cancel instant.

Fleet mechanisms layered on the route order (any placement policy):

* **read steering** (``FleetConfig.steering``) — stable-partition the
  replica preference order so drives that are collecting, recovering,
  degraded (read-only / failed dies) or retired sink to the back.
* **hedging** (``FleetConfig.hedging``) — dispatch the session to the
  two best replicas; first completion wins, the loser's *queued* copy is
  cancelled (cancel-on-first-win), an executing copy drains like a
  timed-out session's in-flight work.
* **fleet admission** (``FleetConfig.max_inflight``) — backpressure at
  the front door: arrivals beyond the fleet-wide in-flight cap are
  rejected before touching any drive.
* **retirement + rebuild** (``FleetConfig.retire``) — at a set instant
  one drive stops accepting sessions; the survivors each pick up a
  rebuild read stream (the reconstruction traffic) as a background
  tenant while placement routes the retiree's sessions to its replicas.

Fleet percentiles are *sample-merged* across drives
(:func:`repro.sim.stats.merged_percentile`) — never averages of
per-drive percentiles.  :func:`find_fleet_saturation` bisects fleet
sessions/sec at a fleet p99 SLO exactly as
:func:`~repro.sim.serving.find_saturation` does for one drive.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.drive import DriveActor
from repro.sim.ftl import FTLConfig
from repro.sim.machine import SimConfig
from repro.sim.placement import (PlacementPolicy, derive_drive_seed,
                                 make_placement)
from repro.sim.serving import (PolicyLike, SaturationProbe,
                               SaturationResult, ServingConfig)
from repro.sim.stats import (FleetResult, FleetSessionRecord, SessionState)
from repro.sim.telemetry import FlightRecorder, TelemetryLike
from repro.sim.tenancy import HostIOStream
from repro.sim.workgen import ArrivalProcess, SessionCatalog


@dataclasses.dataclass(frozen=True)
class DriveProfile:
    """Per-drive overrides — the straggler knob.

    A profile's fields replace the fleet-wide template *verbatim* (no
    reseeding), so a straggler scenario can hand drive 0 a write-heavy
    io_stream + tight FTL while the rest of the fleet derives its
    streams from the fleet seed as usual."""

    io_stream: Optional[HostIOStream] = None
    ftl: Optional[FTLConfig] = None
    faults: Optional[object] = None


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet topology + routing mechanisms; see the module docstring.

    ``retire`` is ``(drive, t_ns)``: at ``t_ns`` the drive stops taking
    sessions and each survivor picks up a rebuild read stream of
    ``rebuild_read_iops / (n_drives - 1)`` IOPS (chained declustering
    spreads reconstruction, it does not double one mirror's load)."""

    n_drives: int = 4
    placement: object = "hash"       # registry name or PlacementPolicy
    replication: int = 1
    steering: bool = False
    hedging: bool = False
    max_inflight: Optional[int] = None
    retire: Optional[Tuple[int, float]] = None
    rebuild_read_iops: float = 20_000.0
    rebuild_reads: int = 128
    profiles: Tuple[Tuple[int, DriveProfile], ...] = ()

    def __post_init__(self) -> None:
        if self.n_drives < 1:
            raise ValueError("n_drives must be >= 1")
        if self.replication < 1:
            raise ValueError("replication must be >= 1")
        if self.replication > self.n_drives:
            raise ValueError(
                f"replication {self.replication} exceeds n_drives "
                f"{self.n_drives}")
        if self.hedging and self.replication < 2:
            raise ValueError("hedging needs replication >= 2 "
                             "(a twin requires a second replica)")
        if self.steering and self.replication < 2:
            raise ValueError("read steering needs replication >= 2 "
                             "(nowhere to steer with one copy)")
        if self.max_inflight is not None and self.max_inflight < 1:
            raise ValueError("max_inflight must be >= 1 (or None)")
        if self.retire is not None:
            d, t = self.retire
            if not (0 <= d < self.n_drives) or t < 0.0:
                raise ValueError(
                    f"retire=({d}, {t}) needs a valid drive and t_ns >= 0")
            if self.n_drives < 2:
                raise ValueError("cannot retire the only drive")
        seen = set()
        for d, _p in self.profiles:
            if not 0 <= d < self.n_drives or d in seen:
                raise ValueError(f"profiles names invalid/duplicate drive {d}")
            seen.add(d)

    def profile(self, d: int) -> Optional[DriveProfile]:
        for k, p in self.profiles:
            if k == d:
                return p
        return None


def _available(h) -> bool:
    """Steering predicate: fit to serve a read right now."""
    return not (h.retired or h.gc_busy or h.recovering
                or h.read_only_dies or h.failed_dies)


def _advance_all(actors: List[DriveActor], t: float,
                 interleaved: bool) -> None:
    """Advance every drive's engine to just before ``t``.

    ``interleaved`` (hedging on) processes the engines in global
    event-time order — one timestamp cluster at a time, ties broken by
    drive id — so a completion on one drive schedules its twin's cancel
    *before* the twin's engine runs past the cancel instant.  Without
    cross-drive messages the per-drive order is free and each engine
    just runs ahead independently."""
    if not interleaved:
        for a in actors:
            a.advance_before(t)
        return
    while True:
        tn, best = None, None
        for a in actors:
            nt = a.engine.next_time()
            if nt is not None and nt < t and (tn is None or nt < tn):
                tn, best = nt, a
        if best is None:
            return
        best.engine.run(until=tn)


def simulate_fleet(catalog: SessionCatalog,
                   arrivals: ArrivalProcess,
                   policy: PolicyLike = "conduit",
                   spec: SSDSpec = DEFAULT_SSD,
                   config: Optional[SimConfig] = None,
                   serving: Optional[ServingConfig] = None,
                   fleet: Optional[FleetConfig] = None,
                   io_stream: Optional[HostIOStream] = None,
                   ftl: Optional[FTLConfig] = None,
                   faults=None,
                   telemetry: TelemetryLike = None) -> FleetResult:
    """Serve an open-loop session stream on an N-drive fleet.

    ``io_stream`` / ``faults`` are fleet-wide *templates*: each drive
    derives its own seed via :func:`~repro.sim.placement.derive_drive_seed`
    (distinct draws per drive, drive 0 identical to the template — the
    N=1 law).  ``ftl`` configs are stateless and shared.  Per-drive
    overrides come from ``FleetConfig.profiles``.

    ``telemetry`` may be ``True`` or a ``TelemetryConfig`` — each drive
    gets its *own* FlightRecorder (returned as ``result.telemetry``, a
    list indexed by drive id; merge with
    :func:`repro.sim.telemetry.merge_fleet_trace`).  Passing one
    FlightRecorder instance is rejected: a recorder records one engine.
    """
    fcfg = fleet or FleetConfig()
    scfg = serving or ServingConfig()
    cfg = dataclasses.replace(config or SimConfig(),
                              record_decisions=scfg.record_decisions)
    if isinstance(telemetry, FlightRecorder):
        raise ValueError(
            "simulate_fleet needs one recorder per drive: pass "
            "telemetry=TelemetryConfig(...) (or True) and read the "
            "per-drive recorders off result.telemetry")
    arrival_times = arrivals.arrival_times_ns()
    if any(t < 0 for t in arrival_times):
        raise ValueError("arrival times must be >= 0")
    if any(b < a for a, b in zip(arrival_times, arrival_times[1:])):
        raise ValueError("arrival times must be non-decreasing")
    if arrival_times and (scfg.warmup_ns > 0.0 or scfg.cooldown_ns > 0.0):
        if arrival_times[-1] - scfg.cooldown_ns <= scfg.warmup_ns:
            raise ValueError(
                "empty measurement window: warmup/cooldown swallow the "
                "arrival span — every steady-state metric would read zero")

    placement = make_placement(fcfg.placement, fcfg.n_drives)
    n = fcfg.n_drives
    lo = scfg.warmup_ns
    hi = max(lo, (arrival_times[-1] - scfg.cooldown_ns)
             if arrival_times else lo)
    window = (lo, hi)

    # fleet-wide catalog draw: one entry per offered session, identical
    # to the per-drive draw of simulate_serving when N=1
    entries = [catalog.draw(i) for i in range(len(arrival_times))]
    frecs = [FleetSessionRecord(sid=i, kind=e.name, arrival_ns=t,
                                drives=(), measured=lo <= t <= hi)
             for i, (t, e) in enumerate(zip(arrival_times, entries))]

    static = (not placement.needs_health and not fcfg.steering
              and not fcfg.hedging and fcfg.max_inflight is None
              and fcfg.retire is None)

    # -- per-drive wiring (derived RNG lineages, profile overrides) ----------
    def drive_args(d: int):
        prof = fcfg.profile(d)
        io_d = prof.io_stream if prof is not None and \
            prof.io_stream is not None else (
                dataclasses.replace(
                    io_stream, seed=derive_drive_seed(io_stream.seed, d))
                if io_stream is not None else None)
        ftl_d = prof.ftl if prof is not None and prof.ftl is not None \
            else ftl
        if prof is not None and prof.faults is not None:
            faults_d = prof.faults
        elif faults is None or d == 0:
            # drive 0 keeps the template verbatim — the N=1 identity;
            # salt=1 keeps later drives' fault draws uncorrelated with
            # their io-stream draws even when the template seeds match
            faults_d = faults
        else:
            faults_d = dataclasses.replace(
                faults, seed=derive_drive_seed(faults.seed, d, salt=1))
        return io_d, ftl_d, faults_d

    # -- routing (shared by both regimes) ------------------------------------
    def route_for(sid: int, health) -> Tuple[Tuple[int, ...],
                                             Tuple[int, ...]]:
        replicas = placement.replicas(sid, fcfg.replication)
        order = list(placement.route(
            sid, replicas, health if placement.needs_health else None))
        if fcfg.steering and health is not None:
            # stable partition: available drives first, stragglers last
            order = ([d for d in order if _available(health[d])]
                     + [d for d in order if not _available(health[d])])
        order = [d for d in order if health is None
                 or not health[d].retired]
        return replicas, tuple(order)

    if static:
        # pre-partition the arrival stream into per-drive plans; each
        # drive then runs to quiescence independently (no cross-drive
        # messages exist in this regime)
        plans: List[List[tuple]] = [[] for _ in range(n)]
        for i, t in enumerate(arrival_times):
            replicas, order = route_for(i, None)
            frecs[i].drives = replicas
            plans[order[0]].append((t, entries[i], i, frecs[i].measured))
        actors = []
        for d in range(n):
            io_d, ftl_d, faults_d = drive_args(d)
            actors.append(DriveActor(
                catalog, policy, spec, cfg, scfg, plan=plans[d],
                window=window, io_stream=io_d, ftl=ftl_d, faults=faults_d,
                telemetry=telemetry, drive_id=d,
                entry_name="simulate_fleet"))
        for a in actors:
            a.drain()
        copies: Dict[int, List[Tuple[int, int]]] = {}
        for d, a in enumerate(actors):
            for i, rec in enumerate(a.driver.records):
                copies.setdefault(rec.sid, []).append((d, i))
        n_fleet_rejected = 0
    else:
        actors = []
        for d in range(n):
            io_d, ftl_d, faults_d = drive_args(d)
            actors.append(DriveActor(
                catalog, policy, spec, cfg, scfg, plan=[],
                window=window, io_stream=io_d, ftl=ftl_d, faults=faults_d,
                telemetry=telemetry, drive_id=d,
                entry_name="simulate_fleet"))

        copies = {}
        won: Dict[int, float] = {}
        inflight = {"n": 0}
        terminal_copies: Dict[int, int] = {}

        def on_term(drive: int, rec) -> None:
            sid = rec.sid
            nc = len(copies.get(sid, ()))
            terminal_copies[sid] = terminal_copies.get(sid, 0) + 1
            if rec.state is SessionState.COMPLETED:
                if sid not in won:
                    won[sid] = rec.done_ns
                    inflight["n"] -= 1
                    # cancel-on-first-win: revoke still-queued twins at
                    # the winner's completion instant (drive time)
                    for d2, i2 in copies.get(sid, ()):
                        if d2 != drive:
                            actors[d2].schedule_cancel(i2, rec.done_ns)
            elif sid not in won and terminal_copies[sid] == nc:
                inflight["n"] -= 1        # every copy ended without a win

        for a in actors:
            a.on_session_terminal = on_term

        retire_pending = fcfg.retire

        def maybe_retire(t: float) -> None:
            nonlocal retire_pending
            if retire_pending is None or t < retire_pending[1]:
                return
            rd, rt = retire_pending
            retire_pending = None
            _advance_all(actors, rt, fcfg.hedging)
            actors[rd].retire()
            # rebuild as a fleet-level background tenant: survivors
            # serve the reconstruction reads of the retiree's share
            survivors = [d for d in range(n) if d != rd]
            for d in survivors:
                actors[d].add_io_stream(HostIOStream(
                    rate_iops=fcfg.rebuild_read_iops / len(survivors),
                    read_fraction=1.0,
                    n_requests=max(1, fcfg.rebuild_reads // len(survivors)),
                    seed=derive_drive_seed(catalog.seed, d, salt=2),
                    start_ns=rt))

        for i, t in enumerate(arrival_times):
            maybe_retire(t)
            _advance_all(actors, t, fcfg.hedging)
            need_health = (placement.needs_health or fcfg.steering
                           or fcfg.retire is not None)
            health = ({d: actors[d].health() for d in range(n)}
                      if need_health else None)
            replicas, order = route_for(i, health)
            frecs[i].drives = replicas
            if not order:
                frecs[i].state = SessionState.REJECTED
                continue
            if (fcfg.max_inflight is not None
                    and inflight["n"] >= fcfg.max_inflight):
                # fleet front-door backpressure: never touches a drive
                frecs[i].state = SessionState.REJECTED
                continue
            targets = (order[:2] if fcfg.hedging and len(order) >= 2
                       else order[:1])
            frecs[i].steered = targets[0] != replicas[0]
            frecs[i].hedged = len(targets) > 1
            sid_copies = copies.setdefault(i, [])
            for d in targets:
                sid_copies.append((d, actors[d].submit(
                    t, entries[i], i, frecs[i].measured)))
            inflight["n"] += 1
        maybe_retire(math.inf)
        _advance_all(actors, math.inf, fcfg.hedging)
        for a in actors:
            a.drain()                     # no-op unless stragglers remain
        n_fleet_rejected = sum(1 for r in frecs
                               if r.rejected and r.sid not in copies)

    # -- fleet record resolution (shared) ------------------------------------
    for frec in frecs:
        if frec.state is not SessionState.PENDING:
            continue
        recs = [(d, actors[d].driver.records[i])
                for d, i in copies.get(frec.sid, ())]
        done = [(r.done_ns, d) for d, r in recs
                if r.state is SessionState.COMPLETED]
        if done:
            frec.done_ns, frec.winner = min(done)
            frec.state = SessionState.COMPLETED
        elif any(r.state is SessionState.FAILED for _, r in recs):
            frec.state = SessionState.FAILED
        elif any(r.state is SessionState.TIMED_OUT for _, r in recs):
            frec.state = SessionState.TIMED_OUT
        else:
            frec.state = SessionState.REJECTED

    results = [a.result() for a in actors]
    recorders = [a.telemetry for a in actors]
    if any(r is not None for r in recorders):
        for d, r in enumerate(recorders):
            if r is not None:
                r.run_meta.setdefault("drive", d)
                r.run_meta.setdefault("n_drives", n)
    else:
        recorders = None
    return FleetResult(
        placement=placement.name,
        policy=policy if isinstance(policy, str) else policy.name,
        n_drives=n,
        drives=results,
        sessions=frecs,
        n_offered=len(frecs),
        n_fleet_rejected=n_fleet_rejected,
        window_ns=window,
        makespan_ns=max([r.makespan_ns for r in results] + [0.0]),
        replication=fcfg.replication,
        n_hedged=sum(1 for r in frecs if r.hedged),
        n_steered=sum(1 for r in frecs if r.steered),
        n_cancelled=sum(r.n_cancelled for r in results),
        telemetry=recorders)


# -- fleet saturation ----------------------------------------------------------

def _fleet_saturation_probe(catalog: SessionCatalog, base: ArrivalProcess,
                            policy: PolicyLike, rate: float,
                            slo_p99_ns: float, scfg: ServingConfig,
                            fcfg: FleetConfig, spec: SSDSpec,
                            config: Optional[SimConfig],
                            io_stream: Optional[HostIOStream],
                            ftl: Optional[FTLConfig],
                            probes: List[SaturationProbe],
                            faults=None,
                            min_availability: float = 1.0) -> bool:
    """One fleet bisection probe; shared verbatim by
    :func:`find_fleet_saturation` and the batched lockstep search in
    :mod:`repro.sim.sweep`.  Sustainable iff nothing was rejected —
    neither at the fleet front door nor by any drive's admission
    control — availability holds, and the *sample-merged* fleet p99
    meets the SLO."""
    res = simulate_fleet(catalog, base.at_rate(rate), policy, spec=spec,
                         config=config, serving=scfg, fleet=fcfg,
                         io_stream=io_stream, ftl=ftl, faults=faults)
    n_rej = res.n_rejected + sum(d.n_rejected for d in res.drives)
    avail = res.availability
    lats = res.session_latencies_ns
    if n_rej > 0 and not lats:
        probes.append(SaturationProbe(
            rate_per_sec=rate, p99_ns=float("nan"), n_rejected=n_rej,
            completed_rate_per_sec=res.completed_rate_per_sec,
            sustainable=False, availability=avail,
            n_failed=res.n_failed, n_timed_out=res.n_timed_out))
        return False
    if not res.measured_sessions and res.n_failed == 0 \
            and res.n_timed_out == 0 and n_rej == 0:
        raise ValueError(
            "no measured sessions at probe rate "
            f"{rate:g}/s: widen the warmup/cooldown window")
    p99 = res.p(99) if lats else float("nan")
    ok = (n_rej == 0 and avail >= min_availability
          and bool(lats) and p99 <= slo_p99_ns)
    probes.append(SaturationProbe(
        rate_per_sec=rate, p99_ns=p99, n_rejected=n_rej,
        completed_rate_per_sec=res.completed_rate_per_sec,
        sustainable=ok, availability=avail,
        n_failed=res.n_failed, n_timed_out=res.n_timed_out))
    return ok


def find_fleet_saturation(catalog: SessionCatalog,
                          base_arrivals: ArrivalProcess,
                          policy: PolicyLike = "conduit",
                          slo_p99_ns: float = 2_000_000.0,
                          rate_lo: float = 50.0,
                          rate_hi: float = 5_000.0,
                          iters: int = 6,
                          spec: SSDSpec = DEFAULT_SSD,
                          config: Optional[SimConfig] = None,
                          serving: Optional[ServingConfig] = None,
                          fleet: Optional[FleetConfig] = None,
                          io_stream: Optional[HostIOStream] = None,
                          ftl: Optional[FTLConfig] = None,
                          faults=None,
                          min_availability: float = 1.0
                          ) -> SaturationResult:
    """Max sustainable *fleet* sessions/sec under a fleet-p99 SLO.

    The single-drive bisection of
    :func:`~repro.sim.serving.find_saturation`, generalized: the probe
    judges the sample-merged fleet p99 and rejections anywhere in the
    fleet (front door or any drive).  Deterministic for fixed inputs."""
    if rate_lo <= 0.0 or rate_hi <= rate_lo:
        raise ValueError("need 0 < rate_lo < rate_hi")
    if iters < 1:
        raise ValueError("iters must be >= 1")
    scfg = serving or ServingConfig()
    fcfg = fleet or FleetConfig()
    probes: List[SaturationProbe] = []

    def probe(rate: float) -> bool:
        return _fleet_saturation_probe(
            catalog, base_arrivals, policy, rate, slo_p99_ns, scfg, fcfg,
            spec, config, io_stream, ftl, probes, faults=faults,
            min_availability=min_availability)

    name = "{}[{}x{}]".format(
        policy if isinstance(policy, str) else policy.name,
        make_placement(fcfg.placement, fcfg.n_drives).name, fcfg.n_drives)
    if not probe(rate_lo):
        return SaturationResult(policy=name, slo_p99_ns=slo_p99_ns,
                                rate_per_sec=0.0,
                                bracket=(0.0, rate_lo), probes=probes)
    if probe(rate_hi):
        return SaturationResult(policy=name, slo_p99_ns=slo_p99_ns,
                                rate_per_sec=rate_hi,
                                bracket=(rate_hi, rate_hi), probes=probes)
    lo_r, hi_r = rate_lo, rate_hi
    for _ in range(iters):
        mid = 0.5 * (lo_r + hi_r)
        if probe(mid):
            lo_r = mid
        else:
            hi_r = mid
    return SaturationResult(policy=name, slo_p99_ns=slo_p99_ns,
                            rate_per_sec=lo_r, bracket=(lo_r, hi_r),
                            probes=probes)
