"""Flash translation layer with event-driven garbage collection.

The seed simulator models an idealized drive: host writes land on hashed
dies with no logical-to-physical mapping, no over-provisioning and no
garbage collection, so firmware background activity — the first-order
obstacle to in-storage processing named by the on-disk-processing
literature — is invisible.  This module adds a page-mapping FTL in the
style of wiscsee/FTL-SIM, scaled down geometrically so event-driven
simulation stays tractable (the real Table-2 geometry lives untouched in
:class:`~repro.hw.ssd_spec.FlashSpec`).

Event flow (mirrors the discipline of :mod:`repro.sim.tenancy`):

* A host write arrives at :class:`~repro.sim.tenancy._HostIOModel`, which
  hashes its LBA to a die and calls :meth:`FTLModel.host_write`.  The FTL
  allocates the next page of that die's *active block* (die-local append
  point), records the L2P mapping, and invalidates the page the LBA
  previously occupied.  The physical program the host model books on the
  die/channel pools is unchanged — with GC disabled the simulation is
  bit-identical to running without an FTL at all (the equivalence law in
  ``tests/test_ftl.py``).
* After each write the host model calls :meth:`FTLModel.maybe_start_gc`.
  If the die's free-page fraction has fallen below the low watermark and
  no collector is active on that die, an :data:`EventKind.GC` event is
  scheduled *now* — GC is one more tenant on the shared
  :class:`~repro.sim.events.EventEngine`.
* The GC handler picks the greedy victim (minimum valid pages among full
  blocks), and for every valid page books a page read, a channel
  round-trip (page buffer -> controller -> destination page buffer: the
  controller re-encodes ECC, so no on-die copyback) and an SLC program on
  the *same* die/channel :class:`~repro.sim.servers.ServerPool`\\ s that
  NDP dispatch and host I/O acquire; then it books the block erase.  The
  lazy-acquire FIFO discipline makes every host request or NDP operand
  fetch behind the collector wait — write amplification directly inflates
  per-tenant slowdown and host-I/O tail latency.
* At the end of the booked cycle the handler re-schedules itself: the
  collector keeps reclaiming blocks until the free fraction recovers to
  the high watermark (or no victim with a free page remains), then sleeps
  until the next watermark crossing.

Mapping state (L2P/valid bitmaps) updates at event-handler time while the
latencies occupy the pools — a simplification shared with FTL-SIM: the
map is sequentially consistent in event order.

With ``gc_enabled=False`` the block pool grows without bound (infinite
over-provisioning): allocation never blocks, nothing is ever erased, and
write amplification is exactly 1.0.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.events import Event, EventEngine, EventKind
from repro.sim.servers import Fabric
from repro.sim.stats import FTLStats

#: physical page address: (die, block-within-die, page-within-block)
PPN = Tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class FTLConfig:
    """Simulation-scale FTL knobs.

    ``blocks_per_die`` / ``pages_per_block`` set the *scaled* geometry the
    mapping operates on; ``op_ratio`` and the watermarks default to the
    firmware parameters in :class:`~repro.hw.ssd_spec.FTLSpec`.
    ``prefill`` writes that fraction of the logical space through the
    allocator at t=0 (state only, no time booked) — the standard
    preconditioning step without which a fresh drive never garbage
    collects."""

    blocks_per_die: int = 16
    pages_per_block: int = 32
    op_ratio: Optional[float] = None          # default: spec.ftl.op_ratio
    gc_low_watermark: Optional[float] = None
    gc_high_watermark: Optional[float] = None
    gc_enabled: bool = True
    prefill: float = 0.0

    def physical_pages(self, spec: SSDSpec = DEFAULT_SSD) -> int:
        return (spec.flash.total_dies * self.blocks_per_die
                * self.pages_per_block)

    def logical_pages(self, spec: SSDSpec = DEFAULT_SSD) -> int:
        """Advertised LBA space: physical capacity net of over-provisioning."""
        op = self.op_ratio if self.op_ratio is not None else spec.ftl.op_ratio
        return max(1, int(self.physical_pages(spec) / (1.0 + op)))


class _DieFTL:
    """One die's block pool: free list, append points, valid accounting."""

    FREE, HOST, GC, USED = "free", "host", "gc", "used"

    def __init__(self, blocks: int, pages_per_block: int):
        self.ppb = pages_per_block
        self.n_blocks = blocks
        self.state: List[str] = [self.FREE] * blocks
        self.free: List[int] = list(range(blocks))
        self.valid_count: List[int] = [0] * blocks
        self.valid: List[List[bool]] = [[False] * pages_per_block
                                        for _ in range(blocks)]
        self.page_lpn: List[List[int]] = [[-1] * pages_per_block
                                          for _ in range(blocks)]
        self.erase_count: List[int] = [0] * blocks
        # (block, next-page) append points; None until first allocation
        self.active: Dict[str, Optional[Tuple[int, int]]] = {
            self.HOST: None, self.GC: None}
        self.grown_blocks = 0          # overflow allocations (infinite OP)
        self.gc_running = False

    # -- capacity -------------------------------------------------------------

    @property
    def physical_pages(self) -> int:
        return self.n_blocks * self.ppb

    def free_pages(self) -> int:
        n = len(self.free) * self.ppb
        for ap in self.active.values():
            if ap is not None:
                n += self.ppb - ap[1]
        return n

    def free_fraction(self) -> float:
        return self.free_pages() / self.physical_pages

    # -- allocation -----------------------------------------------------------

    def _grow(self) -> int:
        """Append a fresh block (infinite-OP / saturation fallback)."""
        b = len(self.state)
        self.state.append(self.FREE)
        self.valid_count.append(0)
        self.valid.append([False] * self.ppb)
        self.page_lpn.append([-1] * self.ppb)
        self.erase_count.append(0)
        self.free.append(b)
        self.grown_blocks += 1
        return b

    def alloc(self, lpn: int, kind: str) -> Tuple[int, int]:
        """Claim the next page of the ``kind`` append point for ``lpn``."""
        ap = self.active[kind]
        if ap is None:
            if not self.free:
                self._grow()
            blk = self.free.pop(0)
            self.state[blk] = kind
            ap = (blk, 0)
        blk, pg = ap
        self.valid[blk][pg] = True
        self.page_lpn[blk][pg] = lpn
        self.valid_count[blk] += 1
        if pg + 1 == self.ppb:
            self.state[blk] = self.USED     # full: eligible GC victim
            self.active[kind] = None
        else:
            self.active[kind] = (blk, pg + 1)
        return blk, pg

    def invalidate(self, blk: int, pg: int) -> None:
        assert self.valid[blk][pg], "double invalidation"
        self.valid[blk][pg] = False
        self.valid_count[blk] -= 1

    # -- garbage collection ---------------------------------------------------

    def pick_victim(self) -> Optional[int]:
        """Greedy policy: the full block with the fewest valid pages."""
        best, best_valid = None, None
        for b, st in enumerate(self.state):
            if st != self.USED:
                continue
            if best_valid is None or self.valid_count[b] < best_valid:
                best, best_valid = b, self.valid_count[b]
        return best

    def erase(self, blk: int) -> None:
        assert self.valid_count[blk] == 0, "erasing block with valid pages"
        self.valid[blk] = [False] * self.ppb
        self.page_lpn[blk] = [-1] * self.ppb
        self.erase_count[blk] += 1
        self.state[blk] = self.FREE
        self.free.append(blk)

    def clone(self) -> "_DieFTL":
        """Deep-enough copy for the prefill snapshot cache."""
        c = _DieFTL.__new__(_DieFTL)
        c.ppb = self.ppb
        c.n_blocks = self.n_blocks
        c.state = list(self.state)
        c.free = list(self.free)
        c.valid_count = list(self.valid_count)
        c.valid = [list(v) for v in self.valid]
        c.page_lpn = [list(p) for p in self.page_lpn]
        c.erase_count = list(self.erase_count)
        c.active = dict(self.active)
        c.grown_blocks = self.grown_blocks
        c.gc_running = self.gc_running
        return c


#: memoized post-prefill (dies, l2p) snapshots — preconditioning a drive is
#: a pure function of the geometry + LBA->die hash, and sweeps precondition
#: the same drive dozens of times (e.g. every GC-off/GC-on pair)
_PREFILL_CACHE: Dict[tuple, Tuple[List["_DieFTL"], Dict[int, PPN]]] = {}
_PREFILL_CACHE_MAX = 8


class FTLModel:
    """Binds an :class:`FTLConfig` to one fabric + event engine.

    ``die_of`` is the LBA->die hash the host I/O model uses for placement —
    passing it in keeps the FTL and the stream bit-consistent (the same
    LBA always lands on the same die, which is what makes the GC-disabled
    run identical to the no-FTL run).  ``prefill_key`` optionally
    identifies that hash (e.g. the I/O seed) so the preconditioning
    snapshot can be memoized across runs; ``None`` disables caching."""

    def __init__(self, cfg: FTLConfig, spec: SSDSpec, fabric: Fabric,
                 engine: EventEngine, die_of: Callable[[int], int],
                 prefill_key: Optional[tuple] = None):
        self.cfg = cfg
        self.spec = spec
        self.fabric = fabric
        self.engine = engine
        self.die_of = die_of
        f = spec.flash
        self.n_dies = f.total_dies
        self.n_logical = cfg.logical_pages(spec)
        self.low_wm = (cfg.gc_low_watermark
                       if cfg.gc_low_watermark is not None
                       else spec.ftl.gc_low_watermark)
        self.high_wm = (cfg.gc_high_watermark
                        if cfg.gc_high_watermark is not None
                        else spec.ftl.gc_high_watermark)
        self.dies = [_DieFTL(cfg.blocks_per_die, cfg.pages_per_block)
                     for _ in range(self.n_dies)]
        self.l2p: Dict[int, PPN] = {}

        # accounting
        self.host_pages_written = 0
        self.gc_pages_copied = 0
        self.blocks_erased = 0
        self.gc_invocations = 0
        self.gc_active_dies = 0
        self.gc_energy_nj = 0.0
        self.host_during_gc_ns: List[float] = []

        n_prefill = int(cfg.prefill * self.n_logical)
        if n_prefill:
            key = None
            if prefill_key is not None:
                key = (prefill_key, cfg.blocks_per_die, cfg.pages_per_block,
                       self.n_dies, n_prefill)
            hit = _PREFILL_CACHE.get(key) if key is not None else None
            if hit is not None:
                dies_snap, l2p_snap = hit
                self.dies = [d.clone() for d in dies_snap]
                self.l2p = dict(l2p_snap)
            else:
                for lpn in range(n_prefill):
                    self._map_write(lpn, die_of(lpn), _DieFTL.HOST)
                if key is not None:
                    if len(_PREFILL_CACHE) >= _PREFILL_CACHE_MAX:
                        _PREFILL_CACHE.pop(next(iter(_PREFILL_CACHE)))
                    _PREFILL_CACHE[key] = ([d.clone() for d in self.dies],
                                           dict(self.l2p))

    # -- mapping --------------------------------------------------------------

    def _map_write(self, lpn: int, die: int, kind: str) -> PPN:
        """Allocate a physical page for ``lpn`` on ``die`` and remap."""
        old = self.l2p.get(lpn)
        if old is not None:
            self.dies[old[0]].invalidate(old[1], old[2])
        blk, pg = self.dies[die].alloc(lpn, kind)
        ppn = (die, blk, pg)
        self.l2p[lpn] = ppn
        return ppn

    def host_write(self, lpn: int, die: int) -> PPN:
        """One host page write through the mapping (caller books the time)."""
        self.host_pages_written += 1
        return self._map_write(lpn, die, _DieFTL.HOST)

    def read_die(self, lpn: int, default: int) -> int:
        """Die physically holding ``lpn`` (``default`` when never written)."""
        ppn = self.l2p.get(lpn)
        return ppn[0] if ppn is not None else default

    # -- garbage collection as a background tenant ----------------------------

    def maybe_start_gc(self, die: int) -> None:
        """Wake the collector on ``die`` if the low watermark is crossed."""
        d = self.dies[die]
        if (not self.cfg.gc_enabled or d.gc_running
                or d.free_fraction() >= self.low_wm):
            return
        d.gc_running = True
        self.gc_active_dies += 1
        self.gc_invocations += 1
        self.engine.schedule(self.engine.now, EventKind.GC,
                             self._on_gc, payload=die)

    def _gc_sleep(self, die: int) -> None:
        d = self.dies[die]
        if d.gc_running:
            d.gc_running = False
            self.gc_active_dies -= 1

    def _on_gc(self, ev: Event) -> None:
        """Reclaim one victim block; re-arm until the high watermark."""
        die = ev.payload
        d = self.dies[die]
        if d.free_fraction() >= self.high_wm:
            self._gc_sleep(die)
            return
        victim = d.pick_victim()
        if victim is None or d.valid_count[victim] >= d.ppb:
            # nothing reclaimable (all-valid blocks): the die is saturated;
            # future allocations overflow-grow rather than deadlock
            self._gc_sleep(die)
            return
        f = self.spec.flash
        nb = self.spec.page_size
        chan = die % f.channels
        xfer = 2.0 * (f.t_dma_ns + nb * f.channel_ns_per_byte)
        t = self.engine.now
        dies_pool = self.fabric.dies
        chan_pool = self.fabric.channels
        for pg in range(d.ppb):
            if not d.valid[victim][pg]:
                continue
            lpn = d.page_lpn[victim][pg]
            t = dies_pool.acquire_end(t, f.t_read_ns, unit=die)
            t = chan_pool.acquire_end(t, xfer, unit=chan)
            t = dies_pool.acquire_end(t, f.t_prog_ns, unit=die)
            self._map_write(lpn, die, _DieFTL.GC)
            self.gc_pages_copied += 1
            self.gc_energy_nj += (f.e_read_nj_per_channel
                                  + 2.0 * f.e_dma_nj_per_channel
                                  + f.e_prog_nj_per_channel)
        t = self.fabric.dies.acquire_end(t, f.t_erase_ns, unit=die)
        d.erase(victim)
        self.blocks_erased += 1
        self.gc_energy_nj += f.e_erase_nj_per_block
        # re-check at cycle completion: keep collecting or go back to sleep
        self.engine.schedule(t, EventKind.GC, self._on_gc, payload=die)

    # -- observability --------------------------------------------------------

    def note_host_latency_during_gc(self, latency_ns: float) -> None:
        self.host_during_gc_ns.append(latency_ns)

    @property
    def gc_busy(self) -> bool:
        return self.gc_active_dies > 0

    def check_invariants(self) -> None:
        """The FTL laws ``tests/test_ftl.py`` asserts mid-run.

        Each live logical page maps to exactly one physical page; the
        reverse map (page_lpn) agrees; per-block valid counts match the
        bitmaps; and the total valid-page count equals the live mapping
        size (conservation across GC cycles)."""
        seen_ppns = set()
        for lpn, (die, blk, pg) in self.l2p.items():
            assert (die, blk, pg) not in seen_ppns, "two LPNs share a PPN"
            seen_ppns.add((die, blk, pg))
            d = self.dies[die]
            assert d.valid[blk][pg], f"lpn {lpn} maps to an invalid page"
            assert d.page_lpn[blk][pg] == lpn, "L2P/P2L disagree"
        total_valid = 0
        for d in self.dies:
            for b in range(len(d.state)):
                n = sum(d.valid[b])
                assert n == d.valid_count[b], "valid count drifted"
                total_valid += n
        assert total_valid == len(self.l2p), "valid pages != live mappings"

    def stats(self) -> FTLStats:
        erase_counts = [c for d in self.dies for c in d.erase_count]
        return FTLStats(
            gc_enabled=self.cfg.gc_enabled,
            n_logical_pages=self.n_logical,
            n_physical_pages=sum(d.physical_pages for d in self.dies),
            host_pages_written=self.host_pages_written,
            gc_pages_copied=self.gc_pages_copied,
            blocks_erased=self.blocks_erased,
            gc_invocations=self.gc_invocations,
            overflow_blocks=sum(d.grown_blocks for d in self.dies),
            gc_energy_nj=self.gc_energy_nj,
            erase_counts=erase_counts,
            host_during_gc_ns=list(self.host_during_gc_ns))
