"""Flash translation layer with event-driven garbage collection.

The seed simulator models an idealized drive: host writes land on hashed
dies with no logical-to-physical mapping, no over-provisioning and no
garbage collection, so firmware background activity — the first-order
obstacle to in-storage processing named by the on-disk-processing
literature — is invisible.  This module adds a page-mapping FTL in the
style of wiscsee/FTL-SIM, scaled down geometrically so event-driven
simulation stays tractable (the real Table-2 geometry lives untouched in
:class:`~repro.hw.ssd_spec.FlashSpec`).

Event flow (mirrors the discipline of :mod:`repro.sim.tenancy`):

* A host write arrives at :class:`~repro.sim.tenancy._HostIOModel`, which
  hashes its LBA to a die and calls :meth:`FTLModel.host_write`.  The FTL
  allocates the next page of that die's *active block* (die-local append
  point), records the L2P mapping, and invalidates the page the LBA
  previously occupied.  The physical program the host model books on the
  die/channel pools is unchanged — with GC disabled the simulation is
  bit-identical to running without an FTL at all (the equivalence law in
  ``tests/test_ftl.py``).
* After each write the host model calls :meth:`FTLModel.maybe_start_gc`.
  If the die's free-page fraction has fallen below the low watermark and
  no collector is active on that die, an :data:`EventKind.GC` event is
  scheduled *now* — GC is one more tenant on the shared
  :class:`~repro.sim.events.EventEngine`.
* The GC handler picks a victim block via the configured
  :class:`VictimPolicy`, and for every valid page books a page read, a
  channel round-trip (page buffer -> controller -> destination page
  buffer: the controller re-encodes ECC, so no on-die copyback) and an
  SLC program on the *same* die/channel
  :class:`~repro.sim.servers.ServerPool`\\ s that NDP dispatch and host
  I/O acquire; then it books the block erase.  The lazy-acquire FIFO
  discipline makes every host request or NDP operand fetch behind the
  collector wait — write amplification directly inflates per-tenant
  slowdown and host-I/O tail latency.
* At the end of the booked cycle the handler re-schedules itself: the
  collector keeps reclaiming blocks until the free fraction recovers to
  the high watermark (or no victim with a free page remains), then sleeps
  until the next watermark crossing.

GC policy suite (each knob defaults to the legacy bit-identical behavior):

* **Victim selection** is a strategy object (:data:`VICTIM_POLICIES`):
  ``greedy`` (minimum valid pages, the default), ``cost_benefit`` (the
  classic age-weighted ``(1-u)/2u`` score of Rosenblum's LFS cleaner,
  paired with its age-sorting rewrite side: still-hot survivors rejoin
  the hot append point instead of re-polluting cold compaction blocks —
  scoring alone measures within noise of greedy), and ``wear_aware``
  (valid-count choice penalized by the block's erase count above the die
  minimum, flattening the
  :attr:`~repro.sim.stats.FTLStats.erase_counts` wear histogram).
* **Hot/cold separation** (``hot_cold=True``) splits the host append
  point in two: LBAs whose lifetime write count reaches
  ``hot_threshold`` land on the HOT append point, the rest on COLD, so
  hot pages die together and Zipf-skewed streams produce nearly-empty
  victims (lower write amplification).
* **GC suspend/throttle** (``gc_suspend=True``) replaces the monolithic
  per-victim booking with one event per page copy: the collector yields
  the die/channel pools between copies (host requests arriving mid-cycle
  book ahead of later copies instead of FIFO-queueing behind the whole
  victim), and while the host has ``gc_suspend_qd`` or more requests
  outstanding it backs off ``gc_backoff_ns`` instead of booking at all —
  latency-critical host reads stop waiting behind a full victim cycle.
* ``gc_reserve_blocks=1`` holds one free block per die back from host
  append-point allocation so a mid-collection copy can never be starved
  into silent overflow growth (``0`` keeps the legacy semantics where
  the host may drain the pool and the collector overflow-grows).

Mapping state (L2P/valid bitmaps) updates at event-handler time while the
latencies occupy the pools — a simplification shared with FTL-SIM: the
map is sequentially consistent in event order.

With ``gc_enabled=False`` the block pool grows without bound (infinite
over-provisioning): allocation never blocks, nothing is ever erased, and
write amplification is exactly 1.0.
"""
from __future__ import annotations

import bisect
import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.events import EventEngine, EventKind
from repro.sim.servers import Fabric
from repro.sim.stats import FTLStats

#: physical page address: (die, block-within-die, page-within-block)
PPN = Tuple[int, int, int]


class OutOfPhysicalBlocks(RuntimeError):
    """A die's free block pool is exhausted and overflow growth is
    forbidden (fault injection active): the drive must degrade to
    read-only instead of silently growing capacity."""


@dataclasses.dataclass(frozen=True)
class FTLConfig:
    """Simulation-scale FTL knobs.

    ``blocks_per_die`` / ``pages_per_block`` set the *scaled* geometry the
    mapping operates on; ``op_ratio``, the watermarks and the policy
    parameters default to the firmware values in
    :class:`~repro.hw.ssd_spec.FTLSpec`.  ``prefill`` writes that fraction
    of the logical space through the allocator at t=0 (state only, no time
    booked) — the standard preconditioning step without which a fresh
    drive never garbage collects.

    The GC policy suite (``victim_policy`` / ``hot_cold`` /
    ``gc_suspend`` / ``gc_reserve_blocks``) defaults to the legacy
    collector: ``greedy`` victims, one host append point, monolithic
    per-victim booking, no reserve — bit-identical to the pre-policy FTL
    (the golden digests in ``tests/test_golden_equivalence.py``)."""

    blocks_per_die: int = 16
    pages_per_block: int = 32
    op_ratio: Optional[float] = None          # default: spec.ftl.op_ratio
    gc_low_watermark: Optional[float] = None
    gc_high_watermark: Optional[float] = None
    gc_enabled: bool = True
    prefill: float = 0.0
    # -- GC policy suite ------------------------------------------------------
    victim_policy: str = "greedy"             # greedy|cost_benefit|wear_aware
    hot_cold: bool = False                    # two host append points by heat
    hot_threshold: Optional[int] = None       # default: spec.ftl.hot_threshold
    wear_alpha: Optional[float] = None        # default: spec.ftl.wear_alpha
    gc_suspend: bool = False                  # per-page-copy yielding/backoff
    gc_suspend_qd: Optional[int] = None       # default: spec.ftl.gc_suspend_qd
    gc_backoff_ns: Optional[float] = None     # default: spec.ftl.gc_backoff_ns
    gc_reserve_blocks: int = 0                # free blocks held back for GC
    # -- wear preconditioning -------------------------------------------------
    # state-only Zipf overwrite churn applied at model build (after
    # prefill): the drive starts the timed run with the wear histogram
    # its own victim policy produces after ``prewear_writes`` writes —
    # the substrate for wear-dependent error injection (repro.sim.faults)
    prewear_writes: int = 0
    prewear_theta: float = 0.99

    def __post_init__(self) -> None:
        if self.victim_policy not in VICTIM_POLICIES:
            raise ValueError(
                f"unknown victim_policy {self.victim_policy!r}; "
                f"choose from {sorted(VICTIM_POLICIES)}")
        if self.gc_reserve_blocks < 0:
            raise ValueError("gc_reserve_blocks must be >= 0")
        if self.prewear_writes < 0:
            raise ValueError("prewear_writes must be >= 0")
        if self.prewear_theta <= 0.0:
            raise ValueError("prewear_theta must be > 0")
        if self.gc_reserve_blocks >= self.blocks_per_die:
            raise ValueError("gc_reserve_blocks must leave host blocks")
        if self.hot_threshold is not None and self.hot_threshold < 2:
            # threshold 1 routes every write hot: no cold stream ever
            # allocates, so the prefill-era HOST append point would be
            # stranded partially filled forever (never a GC victim)
            raise ValueError("hot_threshold must be >= 2 (1 means every "
                             "write is hot: no hot/cold split at all)")
        # qd 0 is always-suspended (0 >= 0 even with no host attached) and
        # a zero backoff re-queues at a frozen timestamp: both livelock
        # the suspend-mode collector, so the engine would never drain
        if self.gc_suspend_qd is not None and self.gc_suspend_qd < 1:
            raise ValueError("gc_suspend_qd must be >= 1")
        if self.gc_backoff_ns is not None and self.gc_backoff_ns <= 0.0:
            raise ValueError("gc_backoff_ns must be > 0")

    def physical_pages(self, spec: SSDSpec = DEFAULT_SSD) -> int:
        return (spec.flash.total_dies * self.blocks_per_die
                * self.pages_per_block)

    def logical_pages(self, spec: SSDSpec = DEFAULT_SSD) -> int:
        """Advertised LBA space: physical capacity net of over-provisioning."""
        op = self.op_ratio if self.op_ratio is not None else spec.ftl.op_ratio
        return max(1, int(self.physical_pages(spec) / (1.0 + op)))


class _DieFTL:
    """One die's block pool: free list, append points, valid accounting."""

    FREE, HOST, GC, USED = "free", "host", "gc", "used"
    HOST_HOT, HOST_COLD = "host_hot", "host_cold"   # hot/cold append points
    RETIRED = "retired"               # bad block: out of the pool forever

    def __init__(self, blocks: int, pages_per_block: int):
        self.ppb = pages_per_block
        self.n_blocks = blocks
        self.state: List[str] = [self.FREE] * blocks
        # FIFO free list; deque so append-point opens are O(1), preserving
        # the exact pop order of the original list.pop(0)
        self.free: Deque[int] = deque(range(blocks))
        self.valid_count: List[int] = [0] * blocks
        self.valid: List[List[bool]] = [[False] * pages_per_block
                                        for _ in range(blocks)]
        self.page_lpn: List[List[int]] = [[-1] * pages_per_block
                                          for _ in range(blocks)]
        self.erase_count: List[int] = [0] * blocks
        # logical write clock (per-die allocation sequence) + per-block
        # last-write stamp: the "age" the cost-benefit score weighs by
        self.write_seq = 0
        self.last_write_seq: List[int] = [0] * blocks
        # (block, next-page) append points; None until first allocation
        self.active: Dict[str, Optional[Tuple[int, int]]] = {
            self.HOST: None, self.GC: None,
            self.HOST_HOT: None, self.HOST_COLD: None}
        self.grown_blocks = 0          # overflow allocations (infinite OP)
        self.gc_grown_blocks = 0       # of which: GC append-point fallbacks
        self.retired_blocks = 0        # bad blocks retired (fault injection)
        # fault injection forbids the infinite-OP escape hatch: an empty
        # pool raises OutOfPhysicalBlocks instead of growing
        self.no_grow = False
        self.gc_running = False
        # free blocks held back from host append points (collector reserve)
        self.reserve = 0
        # suspend-mode collection cursor (victim being copied page by page)
        self.gc_victim: Optional[int] = None
        self.gc_cursor = 0

    # -- capacity -------------------------------------------------------------

    @property
    def physical_pages(self) -> int:
        return self.n_blocks * self.ppb

    def free_pages(self) -> int:
        n = len(self.free) * self.ppb
        for ap in self.active.values():
            if ap is not None:
                n += self.ppb - ap[1]
        return n

    def free_fraction(self) -> float:
        """Host-available free fraction: the collector's reserved blocks
        are not writable capacity, so the GC watermarks must not count
        them — otherwise a reserve the size of the low watermark would
        keep the collector asleep forever while the host overflow-grows.
        Identical to the raw free-page fraction when ``reserve == 0``."""
        return ((self.free_pages() - self.reserve * self.ppb)
                / self.physical_pages)

    # -- allocation -----------------------------------------------------------

    def _grow(self) -> int:
        """Append a fresh block (infinite-OP / saturation fallback)."""
        b = len(self.state)
        self.state.append(self.FREE)
        self.valid_count.append(0)
        self.valid.append([False] * self.ppb)
        self.page_lpn.append([-1] * self.ppb)
        self.erase_count.append(0)
        self.last_write_seq.append(0)
        self.free.append(b)
        self.grown_blocks += 1
        return b

    def _take_free_block(self, kind: str, gc: bool) -> int:
        """Pop the next free block for a ``kind`` append point.

        With ``reserve > 0`` the last ``reserve`` free blocks are the
        collector's: a host append point overflow-grows instead of
        draining them, so a mid-collection copy is never starved by host
        pressure — the silent-growth-during-GC bug the reserve exists to
        close.  ``gc`` marks allocations made *by the collector* (the
        cold GC stream and a segregating cleaner's hot-survivor stream
        alike), which may use the reserve; they can still find the pool
        empty when preconditioning exhausted the die before any reserve
        could be honored (e.g. a hot die prefilled to 100%), and that
        fallback growth is counted separately in ``gc_grown_blocks`` so
        tests can assert it stays zero on sanely-provisioned
        reserve-enabled runs.  ``reserve == 0`` keeps the legacy
        first-come semantics bit-identically."""
        free = self.free
        if gc:
            if free:
                return free.popleft()
            if self.no_grow:
                raise OutOfPhysicalBlocks("collector starved: no free block")
            self.gc_grown_blocks += 1
            self._grow()
            return free.pop()          # the block _grow just appended
        if len(free) > self.reserve:
            return free.popleft()
        if self.no_grow:
            # retirement drained the pool down to (or past) the reserve:
            # the die degrades to read-only rather than silently growing
            raise OutOfPhysicalBlocks("host append point starved: "
                                      f"{len(free)} free <= reserve "
                                      f"{self.reserve}")
        # host overflow growth: the infinite-OP / saturation escape valve —
        # and, with a reserve, what happens *instead of* stealing the
        # collector's block mid-collection
        self._grow()
        return free.pop()              # take the grown block, not the reserve

    def alloc(self, lpn: int, kind: str, gc: bool = False) -> Tuple[int, int]:
        """Claim the next page of the ``kind`` append point for ``lpn``.

        ``gc`` marks a collector-side allocation (GC compaction or
        hot-survivor routing), which may draw on the block reserve."""
        ap = self.active[kind]
        if ap is None:
            if kind == self.HOST_COLD and self.active[self.HOST] is not None:
                # adopt the prefill-era single append point as the cold
                # stream (heat counters start at zero, so preconditioned
                # data is cold by definition)
                ap = self.active[self.HOST]
                self.active[self.HOST] = None
                self.state[ap[0]] = kind
            else:
                blk = self._take_free_block(kind, gc)
                self.state[blk] = kind
                ap = (blk, 0)
        blk, pg = ap
        self.valid[blk][pg] = True
        self.page_lpn[blk][pg] = lpn
        self.valid_count[blk] += 1
        self.write_seq += 1
        self.last_write_seq[blk] = self.write_seq
        if pg + 1 == self.ppb:
            self.state[blk] = self.USED     # full: eligible GC victim
            self.active[kind] = None
        else:
            self.active[kind] = (blk, pg + 1)
        return blk, pg

    def invalidate(self, blk: int, pg: int) -> None:
        assert self.valid[blk][pg], "double invalidation"
        self.valid[blk][pg] = False
        self.valid_count[blk] -= 1

    # -- garbage collection ---------------------------------------------------

    def pick_victim(self) -> Optional[int]:
        """Greedy policy: the full block with the fewest valid pages."""
        best, best_valid = None, None
        for b, st in enumerate(self.state):
            if st != self.USED:
                continue
            if best_valid is None or self.valid_count[b] < best_valid:
                best, best_valid = b, self.valid_count[b]
        return best

    def erase(self, blk: int) -> None:
        assert self.state[blk] != self.RETIRED, "erasing a retired block"
        assert self.valid_count[blk] == 0, "erasing block with valid pages"
        self.valid[blk] = [False] * self.ppb
        self.page_lpn[blk] = [-1] * self.ppb
        self.erase_count[blk] += 1
        self.state[blk] = self.FREE
        self.free.append(blk)

    def clone(self) -> "_DieFTL":
        """Deep-enough copy for the prefill snapshot cache."""
        c = _DieFTL.__new__(_DieFTL)
        c.ppb = self.ppb
        c.n_blocks = self.n_blocks
        c.state = list(self.state)
        c.free = deque(self.free)
        c.valid_count = list(self.valid_count)
        c.valid = [list(v) for v in self.valid]
        c.page_lpn = [list(p) for p in self.page_lpn]
        c.erase_count = list(self.erase_count)
        c.write_seq = self.write_seq
        c.last_write_seq = list(self.last_write_seq)
        c.active = dict(self.active)
        c.grown_blocks = self.grown_blocks
        c.gc_grown_blocks = self.gc_grown_blocks
        c.retired_blocks = self.retired_blocks
        c.no_grow = self.no_grow
        c.gc_running = self.gc_running
        c.reserve = self.reserve
        c.gc_victim = self.gc_victim
        c.gc_cursor = self.gc_cursor
        return c


# -- victim-selection strategies -----------------------------------------------

class VictimPolicy:
    """Strategy object: which full block a die's collector reclaims next.

    ``select`` returns a block index among the die's ``USED`` (full)
    blocks, or ``None``/a fully-valid block when nothing is reclaimable —
    the caller treats both as "go to sleep".  A policy must therefore
    never *prefer* a fully-valid block while a reclaimable one exists
    (the collector would sleep spuriously and the die would silently
    overflow-grow); score-based policies skip fully-valid candidates
    outright, while greedy's minimum-valid choice satisfies the contract
    by construction.

    ``segregates_survivors`` is the cleaner's rewrite side: policies that
    set it route still-hot survivor pages back to the hot append point
    instead of burying them in the cold compaction blocks (the
    age-sorting half of Rosenblum's cost-benefit cleaner — without it,
    victim *scoring* alone cannot beat greedy, because every copied hot
    page re-pollutes a cold block and must be copied again)."""

    name = "base"
    segregates_survivors = False

    def select(self, die: _DieFTL) -> Optional[int]:
        raise NotImplementedError


class GreedyVictim(VictimPolicy):
    """Minimum valid pages (lowest block index on ties) — the legacy
    collector; cheapest copies *right now*, blind to data temperature."""

    name = "greedy"

    def select(self, die: _DieFTL) -> Optional[int]:
        return die.pick_victim()


class CostBenefitVictim(VictimPolicy):
    """The classic LFS/cost-benefit cleaner: maximize ``(1-u)/(2u) * age``.

    ``u`` is the block's valid fraction (copying cost: ``2u`` reads+writes
    per ``1-u`` page reclaimed) and ``age`` the time since the block last
    absorbed a write (measured on the die's allocation clock, so it is
    simulation-deterministic).  Old, stable blocks win over hot blocks of
    equal occupancy — the hot block's pages are about to die on their own,
    so copying them is wasted amplification.  Ties break toward fewer
    valid pages, then the lower block index (greedy's order).

    The policy also enables the cleaner's *age-sorting* half
    (``segregates_survivors``): survivor pages whose LBA is still hot
    rejoin the hot append point instead of being compacted into the cold
    GC blocks.  Rosenblum's measurements — reproduced by the
    ``gc_policies`` sweep — show this is where the cost-benefit cleaner's
    write-amplification win over greedy actually comes from: scoring
    alone re-copies every hot survivor out of a polluted cold block again
    and again, and empirically lands within noise of greedy."""

    name = "cost_benefit"
    segregates_survivors = True

    def select(self, die: _DieFTL) -> Optional[int]:
        best, best_key = None, None
        now = die.write_seq
        ppb = die.ppb
        for b, st in enumerate(die.state):
            if st != die.USED:
                continue
            v = die.valid_count[b]
            if v >= ppb:
                continue                # fully valid: not reclaimable
            age = now - die.last_write_seq[b]
            if v == 0:
                score = float("inf")    # a free win: nothing to copy
            else:
                u = v / ppb
                score = (1.0 - u) / (2.0 * u) * age
            key = (-score, v, b)
            if best_key is None or key < best_key:
                best, best_key = b, key
        return best


class WearAwareVictim(VictimPolicy):
    """Greedy choice penalized by wear: minimize ``valid + alpha * (erase -
    die_min_erase)``.

    Blocks already worn above the die's least-worn block look ``alpha``
    valid pages more expensive per extra erase, so the collector rotates
    reclamation across the pool and the
    :attr:`~repro.sim.stats.FTLStats.erase_counts` histogram flattens
    instead of cycling the same physically-hot blocks (static wear
    leveling folded into victim choice)."""

    name = "wear_aware"

    def __init__(self, alpha: float):
        self.alpha = alpha

    def select(self, die: _DieFTL) -> Optional[int]:
        erase = die.erase_count
        min_erase = min(erase)
        alpha = self.alpha
        ppb = die.ppb
        best, best_key = None, None
        for b, st in enumerate(die.state):
            if st != die.USED:
                continue
            v = die.valid_count[b]
            if v >= ppb:
                continue                # fully valid: not reclaimable
            key = (v + alpha * (erase[b] - min_erase), b)
            if best_key is None or key < best_key:
                best, best_key = b, key
        return best


#: victim_policy name -> factory(cfg_resolved_wear_alpha) registry
VICTIM_POLICIES: Dict[str, Callable[[float], VictimPolicy]] = {
    "greedy": lambda alpha: GreedyVictim(),
    "cost_benefit": lambda alpha: CostBenefitVictim(),
    "wear_aware": lambda alpha: WearAwareVictim(alpha),
}


def make_victim_policy(name: str, wear_alpha: float) -> VictimPolicy:
    """Instantiate a registered victim-selection strategy by name."""
    try:
        return VICTIM_POLICIES[name](wear_alpha)
    except KeyError:
        raise ValueError(f"unknown victim_policy {name!r}; "
                         f"choose from {sorted(VICTIM_POLICIES)}") from None


#: memoized post-prefill (dies, l2p) snapshots — preconditioning a drive is
#: a pure function of the geometry + LBA->die hash, and sweeps precondition
#: the same drive dozens of times (e.g. every GC-off/GC-on pair).  Policy
#: knobs are *not* part of the key: prefill always writes through the
#: single legacy HOST append point (heat counters start at zero, so the
#: preconditioned data is cold), making the snapshot policy-independent.
_PREFILL_CACHE: Dict[tuple, Tuple[List["_DieFTL"], Dict[int, PPN]]] = {}
_PREFILL_CACHE_MAX = 8


class FTLModel:
    """Binds an :class:`FTLConfig` to one fabric + event engine.

    ``die_of`` is the LBA->die hash the host I/O model uses for placement —
    passing it in keeps the FTL and the stream bit-consistent (the same
    LBA always lands on the same die, which is what makes the GC-disabled
    run identical to the no-FTL run).  ``prefill_key`` optionally
    identifies that hash (e.g. the I/O seed) so the preconditioning
    snapshot can be memoized across runs; ``None`` disables caching."""

    def __init__(self, cfg: FTLConfig, spec: SSDSpec, fabric: Fabric,
                 engine: EventEngine, die_of: Callable[[int], int],
                 prefill_key: Optional[tuple] = None):
        self.cfg = cfg
        self.spec = spec
        self.fabric = fabric
        self.engine = engine
        self.die_of = die_of
        f = spec.flash
        self.n_dies = f.total_dies
        self.n_logical = cfg.logical_pages(spec)
        self.low_wm = (cfg.gc_low_watermark
                       if cfg.gc_low_watermark is not None
                       else spec.ftl.gc_low_watermark)
        self.high_wm = (cfg.gc_high_watermark
                        if cfg.gc_high_watermark is not None
                        else spec.ftl.gc_high_watermark)
        self.hot_threshold = (cfg.hot_threshold
                              if cfg.hot_threshold is not None
                              else spec.ftl.hot_threshold)
        if cfg.hot_cold and self.hot_threshold < 2:
            raise ValueError("hot_threshold must be >= 2 (see FTLConfig)")
        wear_alpha = (cfg.wear_alpha if cfg.wear_alpha is not None
                      else spec.ftl.wear_alpha)
        self.suspend_qd = (cfg.gc_suspend_qd
                           if cfg.gc_suspend_qd is not None
                           else spec.ftl.gc_suspend_qd)
        self.backoff_ns = (cfg.gc_backoff_ns
                           if cfg.gc_backoff_ns is not None
                           else spec.ftl.gc_backoff_ns)
        if cfg.gc_suspend and (self.suspend_qd < 1 or self.backoff_ns <= 0):
            raise ValueError("gc_suspend needs gc_suspend_qd >= 1 and "
                             "gc_backoff_ns > 0 (else the throttled "
                             "collector livelocks; see FTLConfig)")
        self.victim = make_victim_policy(cfg.victim_policy, wear_alpha)
        # cleaner-side survivor segregation (the cost-benefit cleaner's
        # age-sorting half): hot survivors rejoin the hot append point
        self._route_survivors = self.victim.segregates_survivors
        self._gc_handler = (self._on_gc_page if cfg.gc_suspend
                            else self._on_gc)
        self.dies = [_DieFTL(cfg.blocks_per_die, cfg.pages_per_block)
                     for _ in range(self.n_dies)]
        self.l2p: Dict[int, PPN] = {}
        # per-LBA lifetime write counts (runtime heat; prefill is cold) —
        # tracked unconditionally: both the hot/cold host split and the
        # cost-benefit cleaner's survivor routing read it
        self.heat: Dict[int, int] = {}
        # the host I/O model attaches itself so the suspend throttle can
        # probe the outstanding-command depth (None: throttle never fires)
        self._host_io = None
        # optional flight recorder (repro.sim.telemetry): GC cycle/copy
        # spans and suspend instants; pure observer, never books time
        self.telemetry = None
        # optional fault model (repro.sim.faults): wear-dependent read
        # errors, bad-block retirement, read-only degradation
        self.faults = None

        # accounting
        self.host_pages_written = 0
        self.hot_pages_written = 0
        self.cold_pages_written = 0
        self.gc_pages_copied = 0
        self.blocks_erased = 0
        self.gc_invocations = 0
        self.pages_relocated = 0       # survivor pages moved by retirement
        self.gc_suspensions = 0
        self.gc_active_dies = 0
        self.gc_energy_nj = 0.0
        self.host_during_gc_ns: List[float] = []
        # latest completion the collector booked on any pool — GC copy and
        # erase work regularly outlives the last host request / session,
        # and a makespan that stops at the last *host* completion would
        # silently exclude that tail (see ServingResult/MixResult)
        self.last_booked_ns = 0.0

        n_prefill = int(cfg.prefill * self.n_logical)
        if n_prefill:
            key = None
            if prefill_key is not None:
                key = (prefill_key, cfg.blocks_per_die, cfg.pages_per_block,
                       self.n_dies, n_prefill)
            hit = _PREFILL_CACHE.get(key) if key is not None else None
            if hit is not None:
                dies_snap, l2p_snap = hit
                self.dies = [d.clone() for d in dies_snap]
                self.l2p = dict(l2p_snap)
            else:
                for lpn in range(n_prefill):
                    self._map_write(lpn, die_of(lpn), _DieFTL.HOST)
                if key is not None:
                    if len(_PREFILL_CACHE) >= _PREFILL_CACHE_MAX:
                        _PREFILL_CACHE.pop(next(iter(_PREFILL_CACHE)))
                    _PREFILL_CACHE[key] = ([d.clone() for d in self.dies],
                                           dict(self.l2p))
        if cfg.prewear_writes:
            self._apply_prewear(prefill_key)
        # the reserve is a per-run policy, not prefill state: apply after
        # any snapshot restore (a cached snapshot may have been taken
        # under a different reserve/GC setting)
        reserve = cfg.gc_reserve_blocks if cfg.gc_enabled else 0
        for d in self.dies:
            d.reserve = reserve

    def _apply_prewear(self, prefill_key: Optional[tuple]) -> None:
        """Build-time wear preconditioning: churn a *private* clone of
        this drive with a seeded Zipf overwrite stream and adopt the
        resulting state (mapping, heat, and — the point — the per-block
        erase histogram the run's own victim policy produces).

        State-only by construction: the churn runs on a throwaway
        fabric/engine, so nothing is booked on the live pools and the
        timed run is unperturbed.  Runtime accounting (WA, erase and GC
        counters) starts at zero — prewear is drive *state*, like
        ``prefill``.  Memoized alongside the prefill snapshots: the
        outcome is a pure function of (LBA->die hash, full FTLConfig)."""
        from repro.sim.tenancy import _zipf_cdf
        cfg = self.cfg
        key = None
        if prefill_key is not None:
            key = ("prewear", prefill_key, cfg)
        hit = _PREFILL_CACHE.get(key) if key is not None else None
        if hit is not None:
            dies_snap, l2p_snap, heat_snap = hit
            self.dies = [d.clone() for d in dies_snap]
            self.l2p = dict(l2p_snap)
            self.heat = dict(heat_snap)
            return
        from repro.sim.machine import _hash01
        sub = dataclasses.replace(cfg, prewear_writes=0, prefill=0.0)
        tmp = FTLModel(sub, self.spec, Fabric(self.spec), EventEngine(),
                       self.die_of)
        tmp.dies = self.dies               # continue from the prefill state
        tmp.l2p = self.l2p
        reserve = cfg.gc_reserve_blocks if cfg.gc_enabled else 0
        for d in tmp.dies:
            d.reserve = reserve
        space = tmp.n_logical
        cdf = _zipf_cdf(space, cfg.prewear_theta)
        lpn_seed = 0x9EA7                  # fixed: prewear replays exactly
        for i in range(cfg.prewear_writes):
            u = min(0.999999, max(0.0, _hash01(i, lpn_seed)))
            lpn = min(space - 1, bisect.bisect_left(cdf, u * cdf[-1]))
            die = tmp.die_of(lpn)
            tmp.host_write(lpn, die)
            tmp.maybe_start_gc(die)
            tmp.engine.run()
        tmp.check_invariants()
        self.dies = tmp.dies
        self.l2p = tmp.l2p
        self.heat = tmp.heat
        if key is not None:
            if len(_PREFILL_CACHE) >= _PREFILL_CACHE_MAX:
                _PREFILL_CACHE.pop(next(iter(_PREFILL_CACHE)))
            _PREFILL_CACHE[key] = ([d.clone() for d in self.dies],
                                   dict(self.l2p), dict(self.heat))

    # -- host I/O attachment ---------------------------------------------------

    def attach_host(self, host_io) -> None:
        """Register the host I/O model whose queue depth throttles GC."""
        self._host_io = host_io

    def attach_faults(self, fm) -> None:
        """Register a :class:`~repro.sim.faults.FaultModel`: its wear/
        retention error model gates every flash read, and uncorrectable
        reads feed block retirement through this FTL.

        Retirement permanently drains free blocks, so a GC-enabled run
        *must* hold a collector reserve — without one, a retirement that
        lands while the host has drained the pool would underflow the
        free list mid-collection.  Rejected loudly here rather than
        failing as a deque underflow deep inside a GC cycle."""
        if self.cfg.gc_enabled and self.cfg.gc_reserve_blocks < 1:
            raise ValueError(
                "fault injection on a GC-enabled FTL requires "
                "gc_reserve_blocks >= 1 (got "
                f"{self.cfg.gc_reserve_blocks}): block retirement drains "
                "the per-die free pool, and without a collector reserve "
                "the free list underflows mid-collection")
        self.faults = fm
        fm.attach_ftl(self)
        # growth stays allowed until a die actually retires a block (see
        # retire_block): an error-free faulted run keeps the legacy
        # overflow-valve dynamics bit-for-bit, and only a drive that is
        # genuinely losing blocks trades the infinite-OP escape hatch
        # for read-only degradation

    def _host_qd(self) -> int:
        h = self._host_io
        if h is None:
            return 0
        return h.outstanding + len(h.pending)   # in-flight + NVMe-QD-deferred

    # -- mapping --------------------------------------------------------------

    def _map_write(self, lpn: int, die: int, kind: str,
                   gc: bool = False) -> PPN:
        """Allocate a physical page for ``lpn`` on ``die`` and remap.

        Allocation happens *before* the old mapping is invalidated (the
        two touch disjoint state) so an :class:`OutOfPhysicalBlocks` from
        a fault-degraded die leaves the mapping untouched."""
        blk, pg = self.dies[die].alloc(lpn, kind, gc)
        old = self.l2p.get(lpn)
        if old is not None:
            self.dies[old[0]].invalidate(old[1], old[2])
        ppn = (die, blk, pg)
        self.l2p[lpn] = ppn
        if self.faults is not None:
            self.faults.on_program(die, blk, pg, self.engine.now)
        return ppn

    def host_write(self, lpn: int, die: int) -> PPN:
        """One host page write through the mapping (caller books the time).

        Raises :class:`OutOfPhysicalBlocks` when fault injection has
        drained the die's pool — the caller surfaces a failed write and
        the die degrades to read-only.  Counters only advance on
        success."""
        heat = self.heat
        n = heat.get(lpn, 0) + 1
        heat[lpn] = n
        kind = _DieFTL.HOST
        if self.cfg.hot_cold:
            if n >= self.hot_threshold:
                kind = _DieFTL.HOST_HOT
            else:
                kind = _DieFTL.HOST_COLD
        ppn = self._map_write(lpn, die, kind)
        self.host_pages_written += 1
        if kind == _DieFTL.HOST_HOT:
            self.hot_pages_written += 1
        elif kind == _DieFTL.HOST_COLD:
            self.cold_pages_written += 1
        return ppn

    def _survivor_kind(self, lpn: int) -> str:
        """Where a GC-copied survivor lands: cold compaction by default;
        under a segregating cleaner, still-hot LBAs rejoin the hot
        append point so they do not re-pollute cold blocks."""
        if (self._route_survivors
                and self.heat.get(lpn, 0) >= self.hot_threshold):
            return _DieFTL.HOST_HOT
        return _DieFTL.GC

    def read_die(self, lpn: int, default: int) -> int:
        """Die physically holding ``lpn`` (``default`` when never written)."""
        ppn = self.l2p.get(lpn)
        return ppn[0] if ppn is not None else default

    def read_ppn(self, lpn: int) -> Optional[PPN]:
        """Full physical address of ``lpn`` (None when never written)."""
        return self.l2p.get(lpn)

    # -- bad-block retirement (fault injection) --------------------------------

    def retire_block(self, die: int, blk: int, t: float) -> float:
        """Retire a bad block: relocate its surviving valid pages through
        the GC machinery (real read/transfer/program bookings starting at
        ``t``) and remove the block from the die's pool forever.

        Returns the completion time of the relocation work.  When the
        die cannot absorb the survivors (:class:`OutOfPhysicalBlocks`)
        the die degrades to read-only and the block stays in place — its
        pages remain readable through the parity-rebuild path."""
        d = self.dies[die]
        if blk >= len(d.state) or d.state[blk] == _DieFTL.RETIRED:
            return t
        fm = self.faults
        if fm is not None and fm.die_dead(die, self.engine.now):
            return t                   # the whole die is already gone
        # the die is now genuinely losing capacity: close the infinite-OP
        # overflow valve so further exhaustion surfaces as read-only
        # degradation instead of silent growth
        d.no_grow = True
        f = self.spec.flash
        nb = self.spec.page_size
        chan = die % f.channels
        xfer = 2.0 * (f.t_dma_ns + nb * f.channel_ns_per_byte)
        dies_pool = self.fabric.dies
        chan_pool = self.fabric.channels
        t0 = t
        relocated = 0
        for pg in range(d.ppb):
            if not d.valid[blk][pg]:
                continue
            lpn = d.page_lpn[blk][pg]
            try:
                # mapping first: a failed allocation must leave the page
                # in place (still rebuildable), not half-moved
                self._map_write(lpn, die, self._survivor_kind(lpn), gc=True)
            except OutOfPhysicalBlocks:
                if fm is not None:
                    fm.mark_read_only(die)
                return t               # block not retired; pages stay put
            t = dies_pool.acquire_end(t, f.t_read_ns, unit=die)
            t = chan_pool.acquire_end(t, xfer, unit=chan)
            t = dies_pool.acquire_end(t, f.t_prog_ns, unit=die)
            relocated += 1
            self.gc_energy_nj += self._copy_energy(f)
        # out of the pool forever: never free, never an append point
        if d.state[blk] == _DieFTL.FREE:
            try:
                d.free.remove(blk)
            except ValueError:
                pass
        for kind, ap in list(d.active.items()):
            if ap is not None and ap[0] == blk:
                d.active[kind] = None
        d.state[blk] = _DieFTL.RETIRED
        d.retired_blocks += 1
        self.pages_relocated += relocated
        if t > self.last_booked_ns:
            self.last_booked_ns = t
        if fm is not None:
            fm.stats_.n_blocks_retired += 1
            fm.stats_.n_pages_relocated += relocated
            fm.uncorrectable.pop((die, blk), None)
        tele = self.telemetry
        if tele is not None:
            tele.on_retirement(die, blk, t0, t, relocated)
        # the pool just shrank: the collector may need to wake
        self.maybe_start_gc(die)
        return t

    # -- garbage collection as a background tenant ----------------------------

    def maybe_start_gc(self, die: int) -> None:
        """Wake the collector on ``die`` if the low watermark is crossed.

        With a block reserve configured, a drained free *list* is a wake
        trigger in its own right: pages left in open append points count
        toward the free fraction but cannot seed a new append point, so a
        die running several streams (hot/cold split, survivor routing)
        can have every free block consumed while the fraction still reads
        above the watermark — and would overflow-grow on the next
        append-point open instead of collecting."""
        d = self.dies[die]
        if not self.cfg.gc_enabled or d.gc_running:
            return
        if (self.faults is not None
                and self.faults.die_dead(die, self.engine.now)):
            return                     # a failed die has nothing to collect
        if (d.free_fraction() >= self.low_wm
                and (d.reserve == 0 or len(d.free) > d.reserve)):
            return
        d.gc_running = True
        self.gc_active_dies += 1
        self.gc_invocations += 1
        self.engine.schedule(self.engine.now, EventKind.GC,
                             self._gc_handler, payload=die)

    def _gc_sleep(self, die: int) -> None:
        d = self.dies[die]
        if d.gc_running:
            d.gc_running = False
            self.gc_active_dies -= 1

    def _collection_done(self, d: _DieFTL) -> bool:
        """Stop condition for a collection burst — the mirror of the
        wake condition in :meth:`maybe_start_gc`.  With a reserve, the
        free list must hold a block beyond the collector's before the
        high watermark counts as recovered: open append points hold
        pages the free *fraction* counts but that cannot seed a new
        append point, and sleeping on the fraction alone would make the
        drained-list wake re-fire on the very next append-point open —
        the collector would thrash wake/sleep without ever reclaiming
        while the host overflow-grows."""
        if d.reserve and len(d.free) <= d.reserve:
            return False
        return d.free_fraction() >= self.high_wm

    def _copy_energy(self, f) -> float:
        return (f.e_read_nj_per_channel + 2.0 * f.e_dma_nj_per_channel
                + f.e_prog_nj_per_channel)

    def _on_gc(self, die: int) -> None:
        """Reclaim one victim block in a single monolithic booking; re-arm
        until the high watermark (the legacy, non-suspend collector)."""
        d = self.dies[die]
        if self._collection_done(d):
            self._gc_sleep(die)
            return
        victim = self.victim.select(d)
        if victim is None or d.valid_count[victim] >= d.ppb:
            # nothing reclaimable (all-valid blocks): the die is saturated;
            # future allocations overflow-grow rather than deadlock
            self._gc_sleep(die)
            return
        f = self.spec.flash
        nb = self.spec.page_size
        chan = die % f.channels
        xfer = 2.0 * (f.t_dma_ns + nb * f.channel_ns_per_byte)
        t = self.engine.now
        tele = self.telemetry
        if tele is not None:
            tele.ctx = f"gc:die{die}"
            tele.ctx_args = {"gc_die": die}
        t0 = t
        pages0 = self.gc_pages_copied
        dies_pool = self.fabric.dies
        chan_pool = self.fabric.channels
        fm = self.faults
        for pg in range(d.ppb):
            if not d.valid[victim][pg]:
                continue
            lpn = d.page_lpn[victim][pg]
            t = dies_pool.acquire_end(t, f.t_read_ns, unit=die)
            if fm is not None:
                t, ok = fm.check_read(t, die, victim, pg)
                if not d.valid[victim][pg]:
                    continue    # check_read retired this very block and
                                # already relocated the page
                if not ok:
                    # unrecoverable mid-GC: the data is gone.  Drop the
                    # mapping (counted in FaultStats.n_failed_reads)
                    # rather than program garbage.
                    d.invalidate(victim, pg)
                    del self.l2p[lpn]
                    continue
            t = chan_pool.acquire_end(t, xfer, unit=chan)
            try:
                self._map_write(lpn, die, self._survivor_kind(lpn), gc=True)
            except OutOfPhysicalBlocks:
                fm.mark_read_only(die)     # no_grow implies fm is attached
                self._gc_sleep(die)
                return
            t = dies_pool.acquire_end(t, f.t_prog_ns, unit=die)
            self.gc_pages_copied += 1
            self.gc_energy_nj += self._copy_energy(f)
        if d.state[victim] == _DieFTL.RETIRED:
            # retirement beat the collector to this block: nothing to erase
            if t > self.last_booked_ns:
                self.last_booked_ns = t
            self.engine.schedule(t, EventKind.GC, self._on_gc, payload=die)
            return
        t = self.fabric.dies.acquire_end(t, f.t_erase_ns, unit=die)
        d.erase(victim)
        if fm is not None:
            fm.on_erase(die, victim)
        self.blocks_erased += 1
        self.gc_energy_nj += f.e_erase_nj_per_block
        if t > self.last_booked_ns:
            self.last_booked_ns = t
        if tele is not None:
            tele.on_gc_cycle(die, victim, t0, t,
                             self.gc_pages_copied - pages0)
        # re-check at cycle completion: keep collecting or go back to sleep
        self.engine.schedule(t, EventKind.GC, self._on_gc, payload=die)

    def _on_gc_page(self, die: int) -> None:
        """Suspend-mode collector: one event per page copy.

        Each copy books the die/channel pools *at its own event time*, so
        host requests arriving between copies book ahead of the remaining
        cycle instead of FIFO-queueing behind a whole victim; and while
        the host queue is ``suspend_qd`` deep or more, the collector backs
        off ``backoff_ns`` without booking anything.  Pages of the victim
        invalidated mid-cycle (the host overwrote the LPN while the
        collector was suspended) are skipped — their copy would have been
        pure amplification."""
        d = self.dies[die]
        engine = self.engine
        if d.gc_victim is None:
            # victim-selection step (between victims: watermark re-check)
            if self._collection_done(d):
                self._gc_sleep(die)
                return
            victim = self.victim.select(d)
            if victim is None or d.valid_count[victim] >= d.ppb:
                self._gc_sleep(die)
                return
            d.gc_victim, d.gc_cursor = victim, 0
        tele = self.telemetry
        # throttle: yield to a deep host queue before booking anything
        if self._host_qd() >= self.suspend_qd:
            self.gc_suspensions += 1
            if tele is not None:
                tele.on_gc_suspend(die, engine.now)
            engine.schedule(engine.now + self.backoff_ns, EventKind.GC,
                            self._on_gc_page, payload=die)
            return
        f = self.spec.flash
        victim = d.gc_victim
        pg = d.gc_cursor
        valid = d.valid[victim]
        while pg < d.ppb and not valid[pg]:
            pg += 1
        if pg < d.ppb:
            # copy exactly one page, then yield the pools
            nb = self.spec.page_size
            chan = die % f.channels
            xfer = 2.0 * (f.t_dma_ns + nb * f.channel_ns_per_byte)
            lpn = d.page_lpn[victim][pg]
            if tele is not None:
                tele.ctx = f"gc:die{die}"
                tele.ctx_args = {"gc_die": die}
            t = self.fabric.dies.acquire_end(engine.now, f.t_read_ns,
                                             unit=die)
            fm = self.faults
            if fm is not None:
                t, ok = fm.check_read(t, die, victim, pg)
                if not d.valid[victim][pg] or not ok:
                    # either check_read retired the block (page already
                    # relocated) or the data is unrecoverable: skip it
                    if d.valid[victim][pg]:
                        d.invalidate(victim, pg)
                        del self.l2p[lpn]
                    d.gc_cursor = pg + 1
                    if t > self.last_booked_ns:
                        self.last_booked_ns = t
                    engine.schedule(t, EventKind.GC, self._on_gc_page,
                                    payload=die)
                    return
            t = self.fabric.channels.acquire_end(t, xfer, unit=chan)
            t = self.fabric.dies.acquire_end(t, f.t_prog_ns, unit=die)
            try:
                self._map_write(lpn, die, self._survivor_kind(lpn), gc=True)
            except OutOfPhysicalBlocks:
                fm.mark_read_only(die)     # no_grow implies fm is attached
                self._gc_sleep(die)
                return
            self.gc_pages_copied += 1
            self.gc_energy_nj += self._copy_energy(f)
            d.gc_cursor = pg + 1
            if t > self.last_booked_ns:
                self.last_booked_ns = t
            if tele is not None:
                tele.on_gc_copy(die, engine.now, t)
            engine.schedule(t, EventKind.GC, self._on_gc_page, payload=die)
            return
        # no valid pages left: erase, then move to the next victim
        if d.state[victim] == _DieFTL.RETIRED:
            # retirement beat the collector to this block: nothing to erase
            d.gc_victim, d.gc_cursor = None, 0
            engine.schedule(engine.now, EventKind.GC, self._on_gc_page,
                            payload=die)
            return
        if tele is not None:
            tele.ctx = f"gc:die{die}"
            tele.ctx_args = {"gc_die": die}
        t = self.fabric.dies.acquire_end(engine.now, f.t_erase_ns, unit=die)
        d.erase(victim)
        if self.faults is not None:
            self.faults.on_erase(die, victim)
        self.blocks_erased += 1
        self.gc_energy_nj += f.e_erase_nj_per_block
        d.gc_victim, d.gc_cursor = None, 0
        if t > self.last_booked_ns:
            self.last_booked_ns = t
        if tele is not None:
            tele.on_gc_copy(die, engine.now, t, kind="erase")
        engine.schedule(t, EventKind.GC, self._on_gc_page, payload=die)

    # -- observability --------------------------------------------------------

    def note_host_latency_during_gc(self, latency_ns: float) -> None:
        self.host_during_gc_ns.append(latency_ns)

    @property
    def gc_busy(self) -> bool:
        return self.gc_active_dies > 0

    def check_invariants(self) -> None:
        """The FTL laws ``tests/test_ftl.py`` asserts mid-run.

        Each live logical page maps to exactly one physical page; the
        reverse map (page_lpn) agrees; per-block valid counts match the
        bitmaps; and the total valid-page count equals the live mapping
        size (conservation across GC cycles)."""
        seen_ppns = set()
        for lpn, (die, blk, pg) in self.l2p.items():
            assert (die, blk, pg) not in seen_ppns, "two LPNs share a PPN"
            seen_ppns.add((die, blk, pg))
            d = self.dies[die]
            assert d.valid[blk][pg], f"lpn {lpn} maps to an invalid page"
            assert d.page_lpn[blk][pg] == lpn, "L2P/P2L disagree"
        total_valid = 0
        for d in self.dies:
            for b in range(len(d.state)):
                n = sum(d.valid[b])
                assert n == d.valid_count[b], "valid count drifted"
                total_valid += n
                if d.state[b] == _DieFTL.RETIRED:
                    assert n == 0, "retired block still holds valid pages"
                    assert b not in d.free, "retired block on the free list"
                    assert all(ap is None or ap[0] != b
                               for ap in d.active.values()), \
                        "retired block is an append point"
        assert total_valid == len(self.l2p), "valid pages != live mappings"

    def stats(self) -> FTLStats:
        erase_counts = [c for d in self.dies for c in d.erase_count]
        return FTLStats(
            gc_enabled=self.cfg.gc_enabled,
            n_logical_pages=self.n_logical,
            n_physical_pages=sum(d.physical_pages for d in self.dies),
            host_pages_written=self.host_pages_written,
            gc_pages_copied=self.gc_pages_copied,
            blocks_erased=self.blocks_erased,
            gc_invocations=self.gc_invocations,
            overflow_blocks=sum(d.grown_blocks for d in self.dies),
            gc_energy_nj=self.gc_energy_nj,
            erase_counts=erase_counts,
            host_during_gc_ns=list(self.host_during_gc_ns),
            victim_policy=self.victim.name,
            hot_cold=self.cfg.hot_cold,
            gc_suspend=self.cfg.gc_suspend,
            gc_suspensions=self.gc_suspensions,
            hot_pages_written=self.hot_pages_written,
            cold_pages_written=self.cold_pages_written,
            gc_overflow_blocks=sum(d.gc_grown_blocks for d in self.dies),
            last_booked_ns=self.last_booked_ns,
            blocks_retired=sum(d.retired_blocks for d in self.dies),
            pages_relocated=self.pages_relocated)


def drive_zipf_overwrites(cfg: FTLConfig, spec: SSDSpec,
                          n_writes: int, theta: float = 0.99,
                          seed: int = 7, check: bool = True) -> FTLStats:
    """Precondition one FTL and churn it with a seeded Zipf overwrite
    stream; return its stats.

    The shared calibration driver behind the ``gc_policies`` bench, its
    example walkthrough and the policy-law tests: LBAs follow the same
    inverse-CDF hashed-uniform discipline as
    :class:`~repro.sim.tenancy.HostIOStream` (identical seeds replay
    identical streams), and the run is *state-only* — WA/wear policy
    comparisons need mapping churn, not pool bookings.  Pass a scaled
    ``spec`` (few dies) to concentrate per-die churn so thousands of GC
    cycles, where victim choice actually matters, simulate in seconds.
    ``check=True`` asserts the FTL invariants after the run."""
    # late import: tenancy imports this module (no cycle at call time)
    from repro.sim.machine import _hash01
    from repro.sim.tenancy import _die_of_lpn, _zipf_cdf

    engine = EventEngine()
    fabric = Fabric(spec)
    dies = spec.flash.total_dies
    model = FTLModel(cfg, spec, fabric, engine,
                     die_of=lambda lpn: _die_of_lpn(lpn, seed, dies))
    space = model.n_logical
    cdf = _zipf_cdf(space, theta)
    lpn_seed = seed ^ 0x1BA5
    for i in range(n_writes):
        u = min(0.999999, max(0.0, _hash01(i, lpn_seed)))
        lpn = min(space - 1, bisect.bisect_left(cdf, u * cdf[-1]))
        die = model.die_of(lpn)
        model.host_write(lpn, die)
        model.maybe_start_gc(die)
        engine.run()
    if check:
        model.check_invariants()
    return model.stats()
