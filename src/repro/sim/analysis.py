"""Trace analysis & attribution: tail-latency blame, critical paths, diffs.

The flight recorder (:mod:`repro.sim.telemetry`) records *what happened*;
this module answers *why the tail is what it is* — pure post-processing
over exported ``conduit-flight-recorder/v1`` traces (or live
:class:`~repro.sim.telemetry.FlightRecorder` objects), never touching the
engine.  Three products:

1. **Tail-latency blame decomposition** (:func:`session_blame`) — for
   every session, wall time is attributed to phases by a priority sweep
   over the per-dispatch phase intervals in ``otherData.ops`` joined
   against the session-lifecycle, GC and reliability spans:

   - ``admission_wait`` — arrival to admission,
   - ``decide`` / ``dep_wait`` / ``dm`` / ``queue`` / ``compute`` — the
     dispatch pipeline phases (queue wait is also split per pool),
   - ``gc`` / ``recovery`` — wait time (queue/dm/dep or uncovered) that
     overlapped garbage collection or the error-recovery ladder: the
     interference components,
   - ``other`` — residual wall time no phase covers.

   The sweep walks elementary segments between *all* interval
   boundaries, so the components sum to the recorded session latency
   **exactly** (the accounting identity; property-tested).  GC/recovery
   interference uses the union of GC / recovery activity anywhere on
   the drive — drive-level interference, documented over-attribution in
   exchange for never missing cross-die blocking.  Each phase priority
   is compute > dm > queue > decide > dep_wait: occupancy beats waiting.

2. **Critical-path extraction** (:func:`critical_path`) — walk the
   worst session's dispatch chain backwards: a hop goes to the gating
   dependency when the op waited on one (``ready > decide_end``), else
   to the program-order predecessor (in-order issue); per-hop resource
   and phase breakdown, plus a per-pool bottleneck ranking
   (:func:`pool_rankings`: time-weighted queue depth, mean utilization,
   utilization at the p99 cohort's completion instants).

3. **Cross-run diff** (:func:`diff_reports`) — compare two runs' blame
   shares, pool utilization and offload-decision mix, refusing
   apples-to-oranges comparisons (different hardware spec, policy or
   entry point) loudly unless forced.

Report schema (``conduit-analysis/v1``)
---------------------------------------

:func:`build_report` emits::

    {
      "schema": "conduit-analysis/v1",
      "meta": {spec_sha, policy, seed?, entry, telemetry: {...},
               git_sha},                    # reproducibility fingerprint
      "sessions": {n, n_timed_out, n_rejected, mean_ns, p50_ns, p99_ns},
      "blame": {components: [...], totals_ns: {comp: ns},
                share: {comp: frac},        # of summed session latency
                p99_cohort: {n, threshold_ns, totals_ns, share}},
      "queue_by_pool_ns": {pool: ns},       # queue blame split by pool
      "critical_path": {tenant, latency_ns, n_hops, hops: [...]},
      "pools": [{pool, queue_depth_ns_tw, util_mean, util_at_p99}, ...],
      "decisions": {n, mix: {resource: n}, replayed, mid_recovery},
      "host_io": {n_requests, n_timeouts}
    }

Traces recorded without spans (``ops`` empty) produce an empty-but-valid
report: every trace ``telemetry validate`` accepts is analyzable.

CLI
---

::

    python -m repro.sim.analysis report TRACE.json [--out R.json] [--json]
    python -m repro.sim.analysis diff  A.json B.json [--tol-rel X]
                                       [--force] [--json]

``diff`` accepts traces or reports on either side (detected by schema
tag).  Exit codes, CI-suitable: 0 ok / comparable-within-tolerance,
1 invalid trace or tolerance breach, 2 unreadable input or refused
comparison (``--force`` downgrades a refusal to a warning).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from typing import (Any, Dict, Iterable, List, Optional, TextIO, Tuple)

from repro.sim.telemetry import (PID_FTL, PID_RELIABILITY, PID_SESSIONS,
                                 PID_HOST_IO, SCHEMA as TRACE_SCHEMA,
                                 validate_trace)

REPORT_SCHEMA = "conduit-analysis/v1"
DIFF_SCHEMA = "conduit-analysis-diff/v1"

#: blame components, in report order; the accounting identity is that
#: these sum to the session's recorded wall time (arrival -> done)
COMPONENTS = ("admission_wait", "decide", "dep_wait", "dm", "queue",
              "compute", "gc", "recovery", "other")

#: meta keys that must match for two runs to be comparable — git_sha is
#: deliberately absent (comparing across commits is the whole point)
_COMPARABLE_KEYS = ("spec_sha", "policy", "entry")

_US_TO_NS = 1e3          # trace ts/dur are microseconds; reports are ns


# -- trace ingestion -----------------------------------------------------------

def _as_trace(obj: Any) -> Dict[str, Any]:
    """Normalize the input: a live FlightRecorder, a trace dict, or a
    path-like is turned into the exported trace object."""
    if hasattr(obj, "chrome_trace"):
        return obj.chrome_trace()
    if isinstance(obj, dict):
        return obj
    raise TypeError(f"expected a trace dict or FlightRecorder, "
                    f"got {type(obj).__name__}")


class _Session:
    """One session lifecycle parsed from the async span stream."""

    __slots__ = ("sid", "kind", "arrival_ns", "admit_ns", "done_ns",
                 "timed_out", "rejected", "cancelled")

    def __init__(self, sid, kind):
        self.sid = sid
        self.kind = kind
        self.arrival_ns = 0.0
        self.admit_ns: Optional[float] = None
        self.done_ns: Optional[float] = None
        self.timed_out = False
        self.rejected = False
        self.cancelled = False

    @property
    def tenant(self) -> str:
        """The dispatch attribution key the serving driver uses."""
        return f"s{self.sid}:{self.kind}"

    @property
    def latency_ns(self) -> float:
        return (self.done_ns or self.arrival_ns) - self.arrival_ns


def _merge(intervals: List[Tuple[float, float]]
           ) -> List[Tuple[float, float]]:
    """Union of possibly-overlapping [t0, t1) intervals."""
    out: List[Tuple[float, float]] = []
    for a, b in sorted(intervals):
        if out and a <= out[-1][1]:
            if b > out[-1][1]:
                out[-1] = (out[-1][0], b)
        else:
            out.append((a, b))
    return out


class _Parsed:
    """Everything the analyses need, pulled out of one trace pass."""

    def __init__(self, trace: Dict[str, Any]):
        other = trace.get("otherData") or {}
        self.meta: Dict[str, Any] = other.get("meta") or {}
        self.ops: List[dict] = other.get("ops") or []
        self.audit: List[dict] = other.get("audit") or []
        self.intervals: List[dict] = other.get("intervals") or []
        self.sessions: List[_Session] = []
        self.gc_union: List[Tuple[float, float]] = []
        self.rec_union: List[Tuple[float, float]] = []
        self.io_requests: set = set()
        self.io_timeouts = 0

        by_sid: Dict[Any, _Session] = {}
        gc_iv: List[Tuple[float, float]] = []
        rec_iv: List[Tuple[float, float]] = []
        for ev in trace.get("traceEvents") or []:
            ph = ev.get("ph")
            pid = ev.get("pid")
            if pid == PID_SESSIONS:
                if ph == "b":
                    name = ev.get("name", "")
                    kind = name.split(":", 1)[1] if ":" in name else name
                    s = by_sid[ev["id"]] = _Session(ev["id"], kind)
                    s.arrival_ns = ev["ts"] * _US_TO_NS
                elif ph == "e":
                    s = by_sid.get(ev["id"])
                    if s is not None:
                        s.done_ns = ev["ts"] * _US_TO_NS
                        args = ev.get("args") or {}
                        s.timed_out = bool(args.get("timed_out"))
                        s.rejected = bool(args.get("rejected"))
                        s.cancelled = bool(args.get("cancelled"))
                elif ph == "i" and ev.get("name", "").startswith("admit s"):
                    sid = int(ev["name"][len("admit s"):])
                    s = by_sid.get(sid)
                    if s is not None:
                        s.admit_ns = ev["ts"] * _US_TO_NS
            elif pid == PID_FTL and ph == "X":
                t0 = ev["ts"] * _US_TO_NS
                gc_iv.append((t0, t0 + ev.get("dur", 0.0) * _US_TO_NS))
            elif pid == PID_RELIABILITY and ph == "X":
                t0 = ev["ts"] * _US_TO_NS
                rec_iv.append((t0, t0 + ev.get("dur", 0.0) * _US_TO_NS))
            elif pid == PID_HOST_IO:
                if ph == "b":
                    self.io_requests.add(ev.get("id"))
                elif ph == "i" and ev.get("name", "").startswith("io-timeout"):
                    self.io_timeouts += 1
        self.sessions = [s for s in by_sid.values() if s.done_ns is not None]
        self.gc_union = _merge(gc_iv)
        self.rec_union = _merge(rec_iv)

        self.ops_by_tenant: Dict[str, List[dict]] = {}
        for o in self.ops:
            self.ops_by_tenant.setdefault(o["tenant"], []).append(o)

    def blame_windows(self) -> List[Tuple[str, float, float, float, bool]]:
        """(tenant-key, arrival, admit, done, timed_out) per analyzable
        window.  Serving traces use real sessions; traces without a
        session stream (single-tenant / mix runs) fall back to one
        pseudo-session per tenant spanning its dispatch activity."""
        if self.sessions:
            return [(s.tenant, s.arrival_ns,
                     s.admit_ns if s.admit_ns is not None else s.arrival_ns,
                     s.done_ns, s.timed_out)
                    for s in self.sessions
                    if not s.rejected and not s.cancelled
                    and s.done_ns > s.arrival_ns]
        out = []
        for tenant, ops in sorted(self.ops_by_tenant.items()):
            arrival = min(o["t_decide_ns"] for o in ops)
            done = max(o["end_ns"] for o in ops)
            if done > arrival:
                out.append((tenant, arrival, arrival, done, False))
        return out


# -- product 1: tail-latency blame ---------------------------------------------

_PHASE_NAMES = ("decide", "dep_wait", "dm", "queue", "compute")
#: occupancy beats waiting: a segment where an op computes is compute
#: time even if another phase interval of the same session overlaps it
_PRIORITY = ("compute", "dm", "queue", "decide", "dep_wait")


def _sweep(ops: List[dict], admit: float, done: float,
           gc_union: List[Tuple[float, float]],
           rec_union: List[Tuple[float, float]]
           ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Blame the [admit, done] window: elementary-segment sweep over the
    ops' phase intervals; returns (components, queue_ns_by_pool).  The
    components (sans admission_wait) sum to ``done - admit`` exactly."""
    comp = {k: 0.0 for k in COMPONENTS if k != "admission_wait"}
    qpool: Dict[str, float] = {}
    if done <= admit:
        return comp, qpool

    # (+1/-1) edge events per phase interval, clipped to the window
    edges: List[Tuple[float, int, str, Optional[str]]] = []
    for o in ops:
        bounds = (o["t_decide_ns"], o["decide_end_ns"], o["ready_ns"],
                  o["move_end_ns"], o["start_ns"], o["end_ns"])
        res = o.get("resource")
        for ph, a, b in zip(_PHASE_NAMES, bounds, bounds[1:]):
            a, b = max(a, admit), min(b, done)
            if b > a:
                edges.append((a, 1, ph, res))
                edges.append((b, -1, ph, res))
    for name, union in (("gc", gc_union), ("recovery", rec_union)):
        for a, b in union:
            a, b = max(a, admit), min(b, done)
            if b > a:
                edges.append((a, 1, name, None))
                edges.append((b, -1, name, None))

    cuts = sorted({admit, done} | {t for t, _, _, _ in edges})
    # edges grouped by timestamp: ends applied before the segment that
    # starts at their timestamp, starts applied before it too (an edge
    # at t affects [t, next) for starts and stops affecting it for ends)
    edges.sort(key=lambda e: (e[0], e[1]))
    active = {ph: 0 for ph in _PRIORITY}
    active["gc"] = active["recovery"] = 0
    qres: Dict[str, int] = {}
    ei, ne = 0, len(edges)
    for i in range(len(cuts) - 1):
        t0, t1 = cuts[i], cuts[i + 1]
        while ei < ne and edges[ei][0] <= t0:
            _, delta, ph, res = edges[ei]
            active[ph] += delta
            if ph == "queue" and res is not None:
                qres[res] = qres.get(res, 0) + delta
            ei += 1
        dt = t1 - t0
        winner = None
        for ph in _PRIORITY:
            if active[ph] > 0:
                winner = ph
                break
        blocked = winner in ("queue", "dm", "dep_wait") or winner is None
        if blocked and active["recovery"] > 0:
            label = "recovery"
        elif blocked and active["gc"] > 0:
            label = "gc"
        elif winner is None:
            label = "other"
        else:
            label = winner
        comp[label] += dt
        if label == "queue":
            pools = sorted(r for r, c in qres.items() if c > 0)
            if pools:
                qpool[pools[0]] = qpool.get(pools[0], 0.0) + dt
    return comp, qpool


def session_blame(trace_or_recorder: Any) -> List[Dict[str, Any]]:
    """Per-session blame rows: ``{tenant, latency_ns, components: {...},
    queue_by_pool_ns: {...}}`` with the accounting identity
    ``sum(components.values()) == latency_ns`` (exact by construction).
    """
    p = _Parsed(_as_trace(trace_or_recorder))
    rows = []
    for tenant, arrival, admit, done, timed_out in p.blame_windows():
        ops = p.ops_by_tenant.get(tenant, [])
        comp, qpool = _sweep(ops, admit, done, p.gc_union, p.rec_union)
        comp = dict(comp)
        comp["admission_wait"] = admit - arrival
        rows.append({"tenant": tenant, "latency_ns": done - arrival,
                     "timed_out": timed_out, "components": comp,
                     "queue_by_pool_ns": qpool})
    return rows


# -- product 2: critical path + pool ranking -----------------------------------

def critical_path(trace_or_recorder: Any, tenant: Optional[str] = None,
                  max_hops: int = 64) -> Dict[str, Any]:
    """Longest dependent chain ending at a tenant's last-finishing op.

    ``tenant=None`` picks the worst blame window (max latency).  A hop
    follows the gating dependency when the op waited on one
    (``ready > decide_end``), else the program-order predecessor — the
    in-order pipeline is itself a dependence.  Each hop carries the
    resource and the phase breakdown, so the path reads as "where the
    tail was built"."""
    p = _Parsed(_as_trace(trace_or_recorder))
    if tenant is None:
        windows = p.blame_windows()
        if not windows:
            return {"tenant": None, "latency_ns": 0.0, "n_hops": 0,
                    "hops": []}
        tenant = max(windows, key=lambda w: w[3] - w[1])[0]
    ops = {o["iid"]: o for o in p.ops_by_tenant.get(tenant, [])}
    if not ops:
        return {"tenant": tenant, "latency_ns": 0.0, "n_hops": 0,
                "hops": []}
    cur = max(ops.values(), key=lambda o: o["end_ns"])
    first = min(ops.values(), key=lambda o: o["t_decide_ns"])
    hops: List[dict] = []
    truncated = False
    while cur is not None:
        if len(hops) >= max_hops:
            truncated = True
            break
        dep_gated = cur["ready_ns"] > cur["decide_end_ns"]
        hops.append({
            "iid": cur["iid"], "op": cur["op"],
            "resource": cur["resource"], "dep_gated": dep_gated,
            "decide_ns": cur["decide_end_ns"] - cur["t_decide_ns"],
            "dep_wait_ns": cur["ready_ns"] - cur["decide_end_ns"],
            "dm_ns": cur["move_end_ns"] - cur["ready_ns"],
            "queue_ns": cur["start_ns"] - cur["move_end_ns"],
            "compute_ns": cur["end_ns"] - cur["start_ns"],
        })
        nxt = None
        if dep_gated:
            deps = [ops[d] for d in cur.get("deps", ()) if d in ops]
            if deps:
                # the dep that released the op: latest end, ties to the
                # smallest iid for determinism
                nxt = max(deps, key=lambda o: (o["end_ns"], -o["iid"]))
        if nxt is None and cur["iid"] - 1 in ops:
            nxt = ops[cur["iid"] - 1]
        cur = nxt
    hops.reverse()
    span_ns = (max(o["end_ns"] for o in ops.values())
               - first["t_decide_ns"])
    return {"tenant": tenant, "latency_ns": span_ns,
            "n_hops": len(hops), "truncated": truncated, "hops": hops}


def pool_rankings(trace_or_recorder: Any,
                  p99_instants_ns: Iterable[float] = ()
                  ) -> List[Dict[str, Any]]:
    """Per-pool bottleneck ranking from the interval sampler stream:
    time-weighted queue depth, mean utilization, and utilization at the
    given instants (pass the p99 cohort's completion times).  Empty when
    the sampler was off — degrade, don't crash."""
    p = _Parsed(_as_trace(trace_or_recorder))
    samples = p.intervals
    if not samples:
        return []
    times = [s["t_ns"] for s in samples]
    # weight sample i by the interval it closed (first: from t=0)
    weights = [times[0]] + [t1 - t0 for t0, t1 in zip(times, times[1:])]
    total_w = sum(weights) or 1.0
    qd: Dict[str, float] = {}
    util: Dict[str, float] = {}
    n_util: Dict[str, float] = {}
    for s, w in zip(samples, weights):
        for pool, v in (s.get("queue_depth_ns") or {}).items():
            qd[pool] = qd.get(pool, 0.0) + v * w
        for pool, v in (s.get("utilization") or {}).items():
            util[pool] = util.get(pool, 0.0) + v * w
            n_util[pool] = n_util.get(pool, 0.0) + w
    at_p99: Dict[str, float] = {}
    instants = sorted(p99_instants_ns)
    if instants:
        counts: Dict[str, int] = {}
        for t in instants:
            # nearest sample to the completion instant
            s = min(samples, key=lambda x: abs(x["t_ns"] - t))
            for pool, v in (s.get("utilization") or {}).items():
                at_p99[pool] = at_p99.get(pool, 0.0) + v
                counts[pool] = counts.get(pool, 0) + 1
        at_p99 = {k: v / counts[k] for k, v in at_p99.items()}
    pools = sorted(qd, key=lambda k: -qd[k])
    return [{"pool": k,
             "queue_depth_ns_tw": qd[k] / total_w,
             "util_mean": (util.get(k, 0.0) / n_util[k]
                           if n_util.get(k) else 0.0),
             "util_at_p99": at_p99.get(k, 0.0)}
            for k in pools]


# -- fleet analysis: split merged traces, blame the fleet tail ------------------

def split_fleet_trace(trace_or_obj: Any) -> Dict[int, Dict[str, Any]]:
    """Invert :func:`repro.sim.telemetry.merge_fleet_trace`: one merged
    fleet trace → ``{drive_id: per-drive trace}`` with base pids
    restored, ``d{k}:`` process prefixes and ``d{k}/`` async-id prefixes
    stripped, and the tagged ``otherData`` record streams filtered back
    to their drives.  Each returned trace is a normal single-drive trace
    every analysis in this module accepts."""
    trace = _as_trace(trace_or_obj)
    other = trace.get("otherData") or {}
    meta = other.get("meta") or {}
    drive_metas = meta.get("drives") or []
    per: Dict[int, Dict[str, Any]] = {}

    def bucket(k: int) -> Dict[str, Any]:
        if k not in per:
            dm = drive_metas[k] if k < len(drive_metas) else {}
            per[k] = {
                "traceEvents": [],
                "displayTimeUnit": "ns",
                "otherData": {
                    "schema": other.get("schema"),
                    # engine event counts are summed fleet-wide by the
                    # merge and not recoverable per drive
                    "event_counts": {},
                    "audit": [], "intervals": [], "breakdown": [],
                    "ops": [], "meta": dict(dm),
                    "dropped_spans": 0, "dropped_audit": 0,
                    "dropped_ops": 0,
                }}
        return per[k]

    for ev in trace.get("traceEvents") or []:
        pid = ev.get("pid")
        if not isinstance(pid, int):
            continue
        k, base = divmod(pid, 10)
        ev = dict(ev)
        ev["pid"] = base
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            name = (ev.get("args") or {}).get("name", "")
            if isinstance(name, str) and name.startswith("d") \
                    and ":" in name:
                ev["args"] = {"name": name.split(":", 1)[1]}
        if ev.get("ph") in ("b", "e"):
            i = ev.get("id")
            prefix = f"d{k}/"
            if isinstance(i, str) and i.startswith(prefix):
                raw = i[len(prefix):]
                # sids / request ids were ints before the merge
                ev["id"] = (int(raw) if raw.lstrip("-").isdigit()
                            else raw)
        bucket(k)["traceEvents"].append(ev)
    for name in ("audit", "intervals", "breakdown", "ops"):
        for rec in other.get(name) or []:
            k = rec.get("drive", 0)
            rec = dict(rec)
            rec.pop("drive", None)
            bucket(k)["otherData"][name].append(rec)
    return per


def fleet_blame(fleet_trace: Any) -> Dict[str, Any]:
    """Which drive — and which component on it — built the fleet tail.

    Accepts a merged fleet trace (dict or path-loaded object) or the
    ``FleetResult.telemetry`` list of per-drive recorders.  The fleet
    p99 is *sample-merged* across drives
    (:func:`repro.sim.stats.merged_percentile`); each drive is then
    scored by its share of the fleet's tail sessions (latency ≥ fleet
    p99), and its tail sessions' blame components
    (:func:`session_blame`) name the mechanism.  The ``straggler`` entry
    is the drive with the largest tail share — ties broken by p99."""
    if isinstance(fleet_trace, (list, tuple)):
        from repro.sim.telemetry import merge_fleet_trace
        fleet_trace = merge_fleet_trace(list(fleet_trace))
    from repro.sim.stats import merged_percentile, percentile
    per = split_fleet_trace(fleet_trace)
    rows_by_drive: Dict[int, List[dict]] = {}
    for k, t in sorted(per.items()):
        rows_by_drive[k] = [r for r in session_blame(t)
                            if not r["timed_out"]]
    fleet_p99 = merged_percentile(
        [[r["latency_ns"] for r in rows] for rows in
         rows_by_drive.values()], 99)
    per_drive: List[Dict[str, Any]] = []
    for k in sorted(rows_by_drive):
        rows = rows_by_drive[k]
        lats = [r["latency_ns"] for r in rows]
        tail = [r for r in rows if r["latency_ns"] >= fleet_p99]
        comp: Dict[str, float] = {}
        for r in tail:
            for c, v in r["components"].items():
                comp[c] = comp.get(c, 0.0) + v
        per_drive.append({
            "drive": k,
            "n_sessions": len(lats),
            "p50_ns": percentile(lats, 50),
            "p99_ns": percentile(lats, 99),
            "tail_sessions": len(tail),
            "dominant_component": (max(sorted(comp), key=comp.get)
                                   if comp else None),
            "tail_components_ns": {c: round(v, 1)
                                   for c, v in sorted(comp.items())},
        })
    n_tail = sum(d["tail_sessions"] for d in per_drive)
    for d in per_drive:
        d["tail_share"] = (d["tail_sessions"] / n_tail) if n_tail else 0.0
    straggler = (max(per_drive,
                     key=lambda d: (d["tail_share"], d["p99_ns"]))
                 if per_drive else None)
    return {
        "schema": "conduit-fleet-analysis/v1",
        "n_drives": len(per_drive),
        "fleet_p99_ns": fleet_p99,
        "per_drive": per_drive,
        "straggler": straggler,
    }


# -- product 3: structured report + cross-run diff -----------------------------

def _git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def _pctl(values: List[float], p: float) -> float:
    from repro.sim.stats import percentile
    return percentile(values, p)


def build_report(trace_or_recorder: Any,
                 git_sha: Optional[str] = None) -> Dict[str, Any]:
    """The full ``conduit-analysis/v1`` run report (see module doc).

    Raises ``ValueError`` on a structurally invalid trace — the
    round-trip law is that everything ``telemetry validate`` accepts is
    analyzable, and nothing it rejects is."""
    trace = _as_trace(trace_or_recorder)
    errors = validate_trace(trace)
    if errors:
        raise ValueError("invalid trace: " + "; ".join(errors[:5]))
    p = _Parsed(trace)
    rows = session_blame(trace)

    lats = [r["latency_ns"] for r in rows]
    p99 = _pctl(lats, 99.0) if lats else 0.0
    cohort = [r for r in rows if r["latency_ns"] >= p99] if lats else []
    cohort_done: List[float] = []
    done_by_tenant = {s.tenant: s.done_ns for s in p.sessions}
    for r in cohort:
        d = done_by_tenant.get(r["tenant"])
        if d is not None:
            cohort_done.append(d)

    def _blame_agg(rs: List[dict]) -> Dict[str, Any]:
        totals = {c: sum(r["components"].get(c, 0.0) for r in rs)
                  for c in COMPONENTS}
        lat_sum = sum(r["latency_ns"] for r in rs)
        share = {c: (v / lat_sum if lat_sum > 0 else 0.0)
                 for c, v in totals.items()}
        return {"totals_ns": totals, "share": share}

    blame = _blame_agg(rows)
    blame["components"] = list(COMPONENTS)
    blame["p99_cohort"] = dict(_blame_agg(cohort), n=len(cohort),
                               threshold_ns=p99)

    qpool: Dict[str, float] = {}
    for r in rows:
        for pool, v in r["queue_by_pool_ns"].items():
            qpool[pool] = qpool.get(pool, 0.0) + v

    mix: Dict[str, int] = {}
    n_replayed = n_midrec = 0
    for a in p.audit:
        mix[a["chosen"]] = mix.get(a["chosen"], 0) + 1
        n_replayed += bool(a.get("replayed"))
        n_midrec += bool(a.get("mid_recovery"))

    meta = dict(p.meta)
    meta["git_sha"] = git_sha if git_sha is not None else _git_sha()
    return {
        "schema": REPORT_SCHEMA,
        "meta": meta,
        "sessions": {
            "n": len(rows),
            "n_timed_out": sum(r["timed_out"] for r in rows),
            "n_rejected": sum(1 for s in p.sessions if s.rejected),
            "mean_ns": sum(lats) / len(lats) if lats else 0.0,
            "p50_ns": _pctl(lats, 50.0) if lats else 0.0,
            "p99_ns": p99,
        },
        "blame": blame,
        "queue_by_pool_ns": qpool,
        "critical_path": critical_path(trace),
        "pools": pool_rankings(trace, cohort_done),
        "decisions": {"n": len(p.audit), "mix": mix,
                      "replayed": n_replayed, "mid_recovery": n_midrec},
        "host_io": {"n_requests": len(p.io_requests),
                    "n_timeouts": p.io_timeouts},
    }


def blame_story(report: Dict[str, Any]) -> str:
    """Name the tail programmatically: which blame component grew most
    from the average session to the p99 cohort — the walkthrough's
    'the GC pause IS the tail' conclusion, as a function."""
    share = report["blame"]["share"]
    p99 = report["blame"]["p99_cohort"]["share"]
    deltas = {c: p99.get(c, 0.0) - share.get(c, 0.0) for c in COMPONENTS}
    worst = max(deltas, key=lambda c: deltas[c])
    lines = [f"  {'component':<16} {'all sessions':>14} {'p99 cohort':>12}"]
    for c in COMPONENTS:
        if share.get(c, 0.0) < 0.005 and p99.get(c, 0.0) < 0.005:
            continue
        mark = " <-- the tail" if c == worst and deltas[worst] > 0.0 else ""
        lines.append(f"  {c:<16} {share.get(c, 0.0):>13.1%} "
                     f"{p99.get(c, 0.0):>11.1%}{mark}")
    if deltas[worst] > 0.0:
        lines.append(
            f"  -> p99 sessions spend {p99.get(worst, 0.0):.1%} of their "
            f"wall time on '{worst}' vs {share.get(worst, 0.0):.1%} for "
            f"the average session: the tail is {worst}-built")
    return "\n".join(lines)


def _load_side(path: str) -> Tuple[Dict[str, Any], str]:
    """Load a diff operand: returns (report, source-kind).  A flight
    recorder trace is analyzed in place; a report passes through."""
    with open(path) as f:
        obj = json.load(f)
    schema = (obj.get("otherData") or {}).get("schema") \
        if "traceEvents" in obj else obj.get("schema")
    if schema == TRACE_SCHEMA:
        return build_report(obj), "trace"
    if obj.get("schema") == REPORT_SCHEMA:
        return obj, "report"
    raise ValueError(f"{path}: neither a {TRACE_SCHEMA} trace nor a "
                     f"{REPORT_SCHEMA} report")


def diff_reports(a: Dict[str, Any], b: Dict[str, Any],
                 tol_rel: Optional[float] = None) -> Dict[str, Any]:
    """Structured diff of two run reports.

    ``refusals`` lists reproducibility-metadata mismatches (hardware
    spec hash, policy, entry point) that make the comparison
    apples-to-oranges; ``breaches`` lists blame-share / p99 movements
    beyond ``tol_rel`` (relative, with a 1-point absolute floor on
    shares so noise in tiny components never gates CI)."""
    refusals = []
    ma, mb = a.get("meta") or {}, b.get("meta") or {}
    for key in _COMPARABLE_KEYS:
        va, vb = ma.get(key), mb.get(key)
        if va != vb:
            refusals.append(f"meta.{key} differs: {va!r} vs {vb!r}")

    sa, sb = a["blame"]["share"], b["blame"]["share"]
    share_delta = {c: sb.get(c, 0.0) - sa.get(c, 0.0) for c in COMPONENTS}
    p99a = a["sessions"]["p99_ns"]
    p99b = b["sessions"]["p99_ns"]
    p99_rel = (p99b - p99a) / p99a if p99a > 0 else 0.0

    ua = {r["pool"]: r["util_mean"] for r in a.get("pools") or []}
    ub = {r["pool"]: r["util_mean"] for r in b.get("pools") or []}
    util_delta = {k: ub.get(k, 0.0) - ua.get(k, 0.0)
                  for k in sorted(set(ua) | set(ub))}

    da, db = a["decisions"], b["decisions"]

    def _mix_share(d):
        n = d.get("n") or 0
        return {k: v / n for k, v in (d.get("mix") or {}).items()} \
            if n else {}

    mixa, mixb = _mix_share(da), _mix_share(db)
    mix_delta = {k: mixb.get(k, 0.0) - mixa.get(k, 0.0)
                 for k in sorted(set(mixa) | set(mixb))}

    breaches = []
    if tol_rel is not None:
        for c, d in share_delta.items():
            base = sa.get(c, 0.0)
            # relative gate with an absolute floor: a component moving
            # within one share-point never breaches
            if abs(d) > max(tol_rel * base, 0.01):
                breaches.append(
                    f"blame share '{c}': {base:.3f} -> {sb.get(c, 0.0):.3f}"
                    f" (|delta| {abs(d):.3f} > "
                    f"max({tol_rel:g}*{base:.3f}, 0.01))")
        if abs(p99_rel) > tol_rel:
            breaches.append(f"sessions.p99_ns moved {p99_rel:+.1%} "
                            f"(tolerance {tol_rel:.1%})")
    return {
        "schema": DIFF_SCHEMA,
        "comparable": not refusals,
        "refusals": refusals,
        "blame_share_delta": share_delta,
        "p99_ns": {"a": p99a, "b": p99b, "rel_delta": p99_rel},
        "pool_util_delta": util_delta,
        "decision_mix_delta": mix_delta,
        "breaches": breaches,
    }


# -- CLI -----------------------------------------------------------------------

def _print_report(r: Dict[str, Any], out: TextIO) -> None:
    s = r["sessions"]
    print(f"run report ({r['schema']}) — policy "
          f"{r['meta'].get('policy', '?')}, entry "
          f"{r['meta'].get('entry', '?')}, spec "
          f"{r['meta'].get('spec_sha', '?')}", file=out)
    print(f"  sessions: {s['n']} analyzed ({s['n_timed_out']} timed out, "
          f"{s['n_rejected']} rejected); mean {s['mean_ns']:.0f} ns, "
          f"p50 {s['p50_ns']:.0f}, p99 {s['p99_ns']:.0f}", file=out)
    print("  blame (share of wall time, all sessions vs p99 cohort):",
          file=out)
    print(blame_story(r), file=out)
    cp = r["critical_path"]
    if cp["n_hops"]:
        drivers = sorted(
            cp["hops"], key=lambda h: -(h["queue_ns"] + h["dep_wait_ns"]
                                        + h["dm_ns"]))[:3]
        dtxt = ", ".join(f"#{h['iid']} {h['op']}@{h['resource']}"
                         for h in drivers)
        print(f"  critical path: {cp['n_hops']} hops on "
              f"{cp['tenant']!r}; top wait hops: {dtxt}", file=out)
    for row in (r.get("pools") or [])[:3]:
        print(f"  bottleneck {row['pool']}: queue "
              f"{row['queue_depth_ns_tw']:.0f} ns (time-weighted), util "
              f"{row['util_mean']:.2f} mean / {row['util_at_p99']:.2f} "
              f"at p99 completions", file=out)
    d = r["decisions"]
    if d["n"]:
        mix = ", ".join(f"{k}:{v}" for k, v in sorted(d["mix"].items()))
        print(f"  decisions: {d['n']} audited ({mix}); "
              f"{d['replayed']} replayed, {d['mid_recovery']} mid-recovery",
              file=out)


def _print_diff(d: Dict[str, Any], out: TextIO) -> None:
    for r in d["refusals"]:
        print(f"REFUSED: {r}", file=out)
    p99 = d["p99_ns"]
    print(f"p99: {p99['a']:.0f} -> {p99['b']:.0f} ns "
          f"({p99['rel_delta']:+.1%})", file=out)
    movers = sorted(d["blame_share_delta"].items(),
                    key=lambda kv: -abs(kv[1]))
    for c, delta in movers[:5]:
        if abs(delta) >= 0.001:
            print(f"  blame '{c}' share {delta:+.1%}", file=out)
    for k, delta in sorted(d["decision_mix_delta"].items()):
        if abs(delta) >= 0.001:
            print(f"  decision mix '{k}' {delta:+.1%}", file=out)
    for b in d["breaches"]:
        print(f"BREACH: {b}", file=out)


def main(argv: Optional[List[str]] = None,
         out: TextIO = sys.stdout) -> int:
    """``python -m repro.sim.analysis report|diff ...`` (see module doc)."""
    ap = argparse.ArgumentParser(
        prog="repro.sim.analysis",
        description=f"Analyze {TRACE_SCHEMA} traces: blame, critical "
                    f"paths, cross-run diffs ({REPORT_SCHEMA})")
    sub = ap.add_subparsers(dest="cmd", required=True)
    pr = sub.add_parser("report", help="build a structured run report")
    pr.add_argument("trace", help="exported trace JSON")
    pr.add_argument("--out", help="also write the report JSON here")
    pr.add_argument("--json", action="store_true",
                    help="print the report as one compact JSON line")
    pd = sub.add_parser("diff", help="compare two runs (traces or reports)")
    pd.add_argument("a", help="baseline trace/report JSON")
    pd.add_argument("b", help="candidate trace/report JSON")
    pd.add_argument("--tol-rel", type=float, default=None,
                    help="gate: max relative blame-share / p99 movement "
                         "(omit = report-only, always exit 0 when "
                         "comparable)")
    pd.add_argument("--force", action="store_true",
                    help="compare despite reproducibility-metadata "
                         "mismatches (refusals become warnings)")
    pd.add_argument("--json", action="store_true",
                    help="print the diff as one compact JSON line")
    args = ap.parse_args(argv)

    if args.cmd == "report":
        try:
            with open(args.trace) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read {args.trace}: {e}", file=out)
            return 2
        try:
            rep = build_report(obj)
        except ValueError as e:
            print(f"error: {e}", file=out)
            return 1
        if args.out:
            with open(args.out, "w") as f:
                json.dump(rep, f, indent=1, sort_keys=True)
        if args.json:
            print(json.dumps(rep, sort_keys=True, separators=(",", ":")),
                  file=out)
        else:
            _print_report(rep, out)
        return 0

    try:
        ra, _ = _load_side(args.a)
        rb, _ = _load_side(args.b)
    except (OSError, json.JSONDecodeError, ValueError) as e:
        print(f"error: {e}", file=out)
        return 2
    d = diff_reports(ra, rb, tol_rel=args.tol_rel)
    if args.json:
        print(json.dumps(d, sort_keys=True, separators=(",", ":")),
              file=out)
    else:
        _print_diff(d, out)
    if d["refusals"] and not args.force:
        print("refusing apples-to-oranges comparison (--force to "
              "override)", file=out)
        return 2
    return 1 if d["breaches"] else 0


if __name__ == "__main__":
    sys.exit(main())
