"""Multi-tenant trace interleaving on one shared SSD (§5 scaled out).

The paper evaluates one trace at a time; the regime the ROADMAP targets —
heavy traffic from many users — means *several* NDP programs plus ordinary
host read/write I/O contending for the same channels, dies, DRAM bus and
PCIe link.  :func:`simulate_mix` builds one shared
:class:`~repro.sim.servers.Fabric`, binds every trace's
:class:`~repro.sim.machine.Simulation` to one
:class:`~repro.sim.events.EventEngine`, and optionally injects a synthetic
:class:`HostIOStream`; dispatches interleave in global time order, so
completion is out-of-order across tenants and the interference is visible
in per-tenant slowdown, Jain fairness and host-I/O tail latency
(:class:`~repro.sim.stats.MixResult`).

API::

    mix = simulate_mix([trace_a, trace_b], "conduit",
                       io_stream=HostIOStream(rate_iops=50_000))
    mix.slowdowns        # {tenant: makespan / solo_makespan}
    mix.host_io.p(99)    # host I/O tail latency under NDP interference

``simulate_mix([trace])`` with no I/O stream reproduces
:func:`~repro.sim.machine.simulate` exactly (the equivalence law in
``tests/test_events.py``).
"""
from __future__ import annotations

import copy
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Union

from repro.core.policies import Policy, make_policy
from repro.core.vectorize import Trace
from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.events import Event, EventEngine, EventKind
from repro.sim.machine import SimConfig, Simulation, _hash01, simulate
from repro.sim.servers import Fabric
from repro.sim.stats import HostIOStats, MixResult

PolicyLike = Union[str, Policy]


@dataclasses.dataclass(frozen=True)
class HostIOStream:
    """Synthetic background host I/O: page-sized NVMe reads/writes.

    Arrivals follow a deterministic pseudo-Poisson process (inverse-CDF
    exponential gaps from a hashed uniform stream), so identical seeds
    replay identical workloads.  Each request occupies a hashed die and
    its channel plus the PCIe link — the same contended units NDP operand
    movement uses."""

    rate_iops: float = 50_000.0      # mean arrival rate (requests / second)
    read_fraction: float = 0.7       # remainder are (SLC-program) writes
    n_requests: int = 256
    seed: int = 0xC0FFEE
    start_ns: float = 0.0

    def arrival_times_ns(self) -> List[float]:
        mean_gap = 1e9 / max(1e-9, self.rate_iops)
        t = self.start_ns
        out = []
        for i in range(self.n_requests):
            u = min(0.999999, max(1e-9, _hash01(i, self.seed)))
            t += -mean_gap * math.log(1.0 - u)
            out.append(t)
        return out


class _HostIOModel:
    """Binds a :class:`HostIOStream` to the engine + fabric."""

    def __init__(self, stream: HostIOStream, fabric: Fabric,
                 spec: SSDSpec, engine: EventEngine):
        self.stream = stream
        self.fabric = fabric
        self.spec = spec
        self.engine = engine
        self.latency_by_req: Dict[int, float] = {}
        self.n_reads = 0
        self.n_writes = 0
        self.last_complete_ns = 0.0
        for i, t in enumerate(stream.arrival_times_ns()):
            engine.schedule(t, EventKind.IO_ARRIVAL, self._on_arrival,
                            payload=i)

    def _on_arrival(self, ev: Event) -> None:
        i = ev.payload
        s, f, h = self.stream, self.spec.flash, self.spec.host
        nb = self.spec.page_size
        die = int(_hash01(i, s.seed ^ 0xD1E) * f.total_dies) % f.total_dies
        chan = die % f.channels
        is_read = _hash01(i, s.seed ^ 0x4EAD) < s.read_fraction
        now = self.engine.now
        xfer = f.t_dma_ns + nb * f.channel_ns_per_byte
        link = nb * h.pcie_ns_per_byte + h.pcie_latency_ns
        if is_read:
            self.n_reads += 1
            t = self.fabric.dies.acquire(now, f.t_read_ns, unit=die).end
            t = self.fabric.channels.acquire(t, xfer, unit=chan).end
            t = self.fabric.pcie.acquire(t, link).end
        else:
            self.n_writes += 1
            t = self.fabric.pcie.acquire(now, link).end
            t = self.fabric.channels.acquire(t, xfer, unit=chan).end
            t = self.fabric.dies.acquire(t, f.t_prog_ns, unit=die).end
        self.engine.schedule(t, EventKind.IO_COMPLETE, self._on_complete,
                             payload=(i, now))

    def _on_complete(self, ev: Event) -> None:
        i, arrival = ev.payload
        self.latency_by_req[i] = self.engine.now - arrival
        self.last_complete_ns = max(self.last_complete_ns, self.engine.now)

    def stats(self) -> HostIOStats:
        # latencies indexed by request id (not completion order), so two
        # runs of the same stream compare request-for-request
        lats = [self.latency_by_req[i] for i in sorted(self.latency_by_req)]
        return HostIOStats(n_reads=self.n_reads, n_writes=self.n_writes,
                           latencies_ns=lats)


def _as_policies(policies: Union[PolicyLike, Sequence[PolicyLike]],
                 n: int, spec: SSDSpec) -> List[Policy]:
    if isinstance(policies, (str, Policy)):
        policies = [policies] * n
    if len(policies) != n:
        raise ValueError(f"{len(policies)} policies for {n} traces")
    return [make_policy(p, spec) if isinstance(p, str) else p
            for p in policies]


def simulate_mix(traces: Sequence[Trace],
                 policies: Union[PolicyLike, Sequence[PolicyLike]] = "conduit",
                 io_stream: Optional[HostIOStream] = None,
                 spec: SSDSpec = DEFAULT_SSD,
                 config: Optional[SimConfig] = None,
                 compute_solo: bool = True,
                 engine: Optional[EventEngine] = None) -> MixResult:
    """Run several traces concurrently on one SSD, plus optional host I/O.

    ``policies`` is one policy (applied to every trace) or one per trace;
    strings go through :func:`make_policy`.  ``compute_solo`` additionally
    runs each (trace, policy) alone on a private fabric to provide the
    solo makespans behind :attr:`MixResult.slowdowns` — disable it for
    large sweeps where only the contended numbers matter.  Pass a
    ``record=True`` :class:`EventEngine` to capture the event timeline.
    """
    traces = list(traces)
    if not traces:
        raise ValueError("simulate_mix needs at least one trace")
    cfg = config or SimConfig()
    pols = _as_policies(policies, len(traces), spec)

    # A Trace owns its PageTable (mutable residency state): tenants must
    # not share one, so duplicate Trace objects get a deep copy.
    seen: set = set()
    tenant_traces: List[Trace] = []
    for tr in traces:
        if id(tr) in seen:
            tr = copy.deepcopy(tr)
        seen.add(id(tr))
        tenant_traces.append(tr)

    names = [f"t{i}:{tr.name or 'trace'}"
             for i, tr in enumerate(tenant_traces)]

    solo: Dict[str, float] = {}
    if compute_solo:
        for name, tr, pol in zip(names, tenant_traces, pols):
            solo[name] = simulate(tr, pol, spec, cfg).makespan_ns

    engine = engine or EventEngine()
    fabric = Fabric(spec, pud_units=cfg.pud_units)
    sims = [Simulation(tr, pol, spec, cfg, fabric=fabric, tenant=name)
            for name, tr, pol in zip(names, tenant_traces, pols)]
    for sim in sims:
        sim.bind(engine)
    io = (_HostIOModel(io_stream, fabric, spec, engine)
          if io_stream is not None else None)
    engine.run()

    results = [sim.result() for sim in sims]
    makespan = max([r.makespan_ns for r in results]
                   + ([io.last_complete_ns] if io else []))
    return MixResult(tenants=results, solo_makespan_ns=solo,
                     host_io=io.stats() if io else None,
                     fabric_busy_ns=fabric.busy_ns(),
                     makespan_ns=makespan)
