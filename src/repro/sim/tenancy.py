"""Multi-tenant trace interleaving on one shared SSD (§5 scaled out).

The paper evaluates one trace at a time; the regime the ROADMAP targets —
heavy traffic from many users — means *several* NDP programs plus ordinary
host read/write I/O contending for the same channels, dies, DRAM bus and
PCIe link.  :func:`simulate_mix` builds one shared
:class:`~repro.sim.servers.Fabric`, binds every trace's
:class:`~repro.sim.machine.Simulation` to one
:class:`~repro.sim.events.EventEngine` (optionally at a staggered
``start_ns`` arrival offset per tenant), and optionally injects a
synthetic :class:`HostIOStream`; dispatches interleave in global time
order, so completion is out-of-order across tenants and the interference
is visible in per-tenant slowdown, Jain fairness and host-I/O tail
latency (:class:`~repro.sim.stats.MixResult`).

Host I/O realism: requests target logical block addresses — uniformly or
Zipf-skewed (``zipf_theta``) — and the LBA hashes to the die, so repeated
writes to a hot LBA always land on (and invalidate pages of) the same
die.  Arrivals are pseudo-Poisson, optionally gated into on/off bursts
(``burst_duty`` / ``burst_len``), and an NVMe queue-depth cap
(``queue_depth``) defers arrivals beyond the outstanding-command limit at
the front end.

Passing ``ftl=FTLConfig(...)`` routes every host write through the
page-mapping flash translation layer of :mod:`repro.sim.ftl`: writes
allocate physical pages in over-provisioned per-die block pools, and the
garbage collector runs as an event-driven background tenant whose page
copies and erases contend for the same die/channel pools (write
amplification shows up in every tenant's slowdown and in
``MixResult.ftl``).

API::

    mix = simulate_mix([trace_a, trace_b], "conduit",
                       io_stream=HostIOStream(rate_iops=50_000),
                       ftl=FTLConfig(op_ratio=0.12, prefill=0.9),
                       start_ns=[0.0, 2e6])
    mix.slowdowns        # {tenant: elapsed / solo_makespan}
    mix.host_io.p(99)    # host I/O tail latency under NDP + GC interference
    mix.ftl.write_amplification

``simulate_mix([trace])`` with no I/O stream reproduces
:func:`~repro.sim.machine.simulate` exactly (the equivalence law in
``tests/test_events.py``), and an FTL with ``gc_enabled=False`` is
bit-identical to no FTL at all (``tests/test_ftl.py``).
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import math
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.policies import Policy, make_policy
from repro.core.vectorize import Trace
from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec
from repro.sim.events import EventEngine, EventKind
from repro.sim.ftl import FTLConfig, FTLModel, OutOfPhysicalBlocks
from repro.sim.machine import SimConfig, Simulation, _hash01, simulate
from repro.sim.servers import Fabric
from repro.sim.stats import HostIOStats, MixResult
from repro.sim.telemetry import TelemetryLike, as_recorder

PolicyLike = Union[str, Policy]

#: seed the FTL's LBA->die hash uses when no I/O stream is configured
DEFAULT_IO_SEED = 0xC0FFEE


def _die_of_lpn(lpn: int, seed: int, total_dies: int) -> int:
    """Stable LBA->die placement hash, shared by the host I/O stream and
    the FTL so the two always agree on where a logical page lives."""
    return int(_hash01(lpn, seed ^ 0xD1E) * total_dies) % total_dies


def build_ftl_model(ftl: FTLConfig, spec: SSDSpec, fabric: "Fabric",
                    engine: EventEngine,
                    io_stream: Optional["HostIOStream"]) -> FTLModel:
    """The one way an FTL is wired to a run (``simulate_mix`` and
    ``simulate_serving`` both call this): the stream's seed keys the
    shared LBA->die hash, so every entry point preconditions — and
    memoizes via the prefill snapshot cache — the same drive state for
    the same stream."""
    io_seed = io_stream.seed if io_stream is not None else DEFAULT_IO_SEED
    total_dies = spec.flash.total_dies
    return FTLModel(
        ftl, spec, fabric, engine,
        die_of=lambda lpn: _die_of_lpn(lpn, io_seed, total_dies),
        prefill_key=(io_seed, total_dies))


@functools.lru_cache(maxsize=8)
def _zipf_cdf(n: int, theta: float) -> Tuple[float, ...]:
    """Cumulative Zipf(theta) weights over ranks 1..n (rank == LBA)."""
    acc, out = 0.0, []
    for r in range(1, n + 1):
        acc += r ** -theta
        out.append(acc)
    return tuple(out)


@functools.lru_cache(maxsize=8)
def _request_plan(stream: "HostIOStream", space: int, total_dies: int
                  ) -> Tuple[Tuple[float, int, bool, int], ...]:
    """Per-request ``(arrival_ns, lpn, is_read, hashed_die)`` for a stream.

    Everything here is a pure function of the (frozen, hashable) stream
    spec, the LBA space and the die count, so sweeps that replay one
    stream against several FTL/fabric configurations (e.g. the GC-off
    vs. GC-on pairs of ``gc_interference``) hash the arrival process once
    instead of re-deriving it per run.  The FTL's dynamic L2P read
    resolution still happens at issue time."""
    seed = stream.seed
    lpn_seed = seed ^ 0x1BA5
    read_seed = seed ^ 0x4EAD
    theta = stream.zipf_theta
    cdf = _zipf_cdf(space, round(theta, 6)) if theta > 0.0 else None
    read_fraction = stream.read_fraction
    plan = []
    for i, t in enumerate(stream.arrival_times_ns()):
        u = min(0.999999, max(0.0, _hash01(i, lpn_seed)))
        if cdf is None:
            lpn = min(space - 1, int(u * space))
        else:
            lpn = min(space - 1, bisect.bisect_left(cdf, u * cdf[-1]))
        is_read = _hash01(i, read_seed) < read_fraction
        die = _die_of_lpn(lpn, seed, total_dies)
        plan.append((t, lpn, is_read, die))
    return tuple(plan)


@dataclasses.dataclass(frozen=True)
class HostIOStream:
    """Synthetic background host I/O: page-sized NVMe reads/writes.

    Arrivals follow a deterministic pseudo-Poisson process (inverse-CDF
    exponential gaps from a hashed uniform stream), so identical seeds
    replay identical workloads.  Each request targets an LBA — uniform
    over ``n_logical_pages`` or Zipf-skewed when ``zipf_theta > 0`` — and
    the LBA hashes to a die and its channel plus the PCIe link: the same
    contended units NDP operand movement and FTL garbage collection use.

    ``burst_duty < 1`` compresses arrivals into on/off bursts (``burst_len``
    requests per ON window at rate/duty, then an OFF pause) at the same
    mean rate; ``queue_depth`` models the NVMe front end's outstanding-
    command limit (excess arrivals queue before touching the fabric)."""

    rate_iops: float = 50_000.0      # mean arrival rate (requests / second)
    read_fraction: float = 0.7       # remainder are (SLC-program) writes
    n_requests: int = 256
    seed: int = DEFAULT_IO_SEED
    start_ns: float = 0.0
    n_logical_pages: int = 1 << 16   # LBA space the stream addresses
    zipf_theta: float = 0.0          # 0 = uniform; ~0.99 = classic hot/cold
    burst_duty: float = 1.0          # ON fraction of the arrival cycle
    burst_len: int = 32              # requests per ON window
    queue_depth: Optional[int] = None  # NVMe QD cap (None = unbounded)

    def __post_init__(self) -> None:
        if self.queue_depth is not None and self.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1 (or None)")
        if self.burst_len < 1:
            raise ValueError("burst_len must be >= 1")
        if self.n_logical_pages < 1:
            raise ValueError("n_logical_pages must be >= 1")

    def arrival_times_ns(self) -> List[float]:
        mean_gap = 1e9 / max(1e-9, self.rate_iops)
        duty = min(1.0, max(1e-3, self.burst_duty))
        on_gap = mean_gap * duty
        off_pause = self.burst_len * mean_gap * (1.0 - duty)
        t = self.start_ns
        out = []
        for i in range(self.n_requests):
            u = min(0.999999, max(1e-9, _hash01(i, self.seed)))
            t += -on_gap * math.log(1.0 - u)
            out.append(t)
            if duty < 1.0 and (i + 1) % self.burst_len == 0:
                t += off_pause
        return out


class _HostIOModel:
    """Binds a :class:`HostIOStream` to the engine + fabric (+ FTL)."""

    def __init__(self, stream: HostIOStream, fabric: Fabric,
                 spec: SSDSpec, engine: EventEngine,
                 ftl: Optional[FTLModel] = None):
        self.stream = stream
        self.fabric = fabric
        self.spec = spec
        self.engine = engine
        self.ftl = ftl
        if ftl is not None:
            ftl.attach_host(self)      # GC suspend throttle probes our QD
        # when an FTL is present its logical space bounds the LBAs (the
        # stream's space folds into it; size them equal for exact studies)
        self.space = ftl.n_logical if ftl is not None \
            else max(1, stream.n_logical_pages)
        self.latency_by_req: Dict[int, float] = {}
        self.n_reads = 0
        self.n_writes = 0
        self.outstanding = 0
        self.pending: Deque[Tuple[int, float]] = deque()
        self.last_complete_ns = 0.0
        # fault subsystem (None when inactive — the common case); the
        # FaultModel is constructed before the host I/O model, so the
        # fabric slot is already populated here
        self.faults = fabric.faults
        self.failed_reqs: set = set()       # ops surfaced as failed
        self.attempts: Dict[int, int] = {}  # req id -> timeout re-issues
        self.n_failed = 0
        # optional flight recorder (repro.sim.telemetry): request spans
        self.telemetry = None
        # hoisted per-request constants (the issue path runs per event)
        f, h = spec.flash, spec.host
        nb = spec.page_size
        self._xfer_ns = f.t_dma_ns + nb * f.channel_ns_per_byte
        self._link_ns = nb * h.pcie_ns_per_byte + h.pcie_latency_ns
        self._qd = stream.queue_depth
        # per-request (arrival, lpn, is_read, hashed_die), memoized across
        # runs replaying the same stream spec.  Arrivals are *chained*:
        # only the first is scheduled here; _on_arrival consumes runs of
        # consecutive arrivals inline (batched) and schedules a real event
        # only for the first arrival that something else could preempt.
        self.plan = _request_plan(stream, self.space, spec.flash.total_dies)
        if self.plan:
            engine.schedule(self.plan[0][0], EventKind.IO_ARRIVAL,
                            self._on_arrival, payload=0)

    def _on_arrival(self, i: int) -> None:
        engine = self.engine
        qd = self._qd
        if qd is not None and self.outstanding >= qd:
            self.pending.append((i, engine.now))  # NVMe QD front-end cap
        else:
            self._issue(i, engine.now)
        # Burst batching: every later arrival that strictly precedes the
        # next pending event cannot interleave with anything — process it
        # here with the same clock updates, processed count and log records
        # the engine's run loop would have applied, and fall back to a real
        # event at the first arrival that ties or follows one.  IO_COMPLETE
        # and GC events scheduled by _issue land in the heap immediately,
        # so they bound the batch exactly as before.
        plan = self.plan
        n = len(plan)
        j = i + 1
        if j >= n:
            return
        record = engine.record
        tele = engine.telemetry
        while True:
            t_j = plan[j][0]
            nt = engine.next_time()
            horizon = engine.horizon
            if (nt is not None and t_j >= nt) or \
                    (horizon is not None and t_j >= horizon):
                # an arrival at/after the run horizon must go back on the
                # heap: the caller of run(until)/run_before() may inject
                # events there (fleet advance-to-time seam)
                engine.schedule(t_j, EventKind.IO_ARRIVAL, self._on_arrival,
                                payload=j)
                return
            if t_j > engine.now:
                engine.now = t_j
            engine.processed += 1
            if record:
                engine.log.append((engine.now, EventKind.IO_ARRIVAL))
            if tele is not None:
                tele.on_event(engine.now, EventKind.IO_ARRIVAL)
            arr = engine.now
            if qd is not None and self.outstanding >= qd:
                self.pending.append((j, arr))
            else:
                self._issue(j, arr)
            j += 1
            if j >= n:
                return

    def _issue(self, i: int, arrival_ns: float) -> None:
        self.outstanding += 1
        f = self.spec.flash
        now = self.engine.now
        _, lpn, is_read, die = self.plan[i]
        during_gc = self.ftl is not None and self.ftl.gc_busy
        tele = self.telemetry
        if tele is not None:
            tele.ctx = f"io#{i}:{'r' if is_read else 'w'}"
            tele.ctx_args = {"io": i, "die": die,
                             "rw": "r" if is_read else "w"}
        xfer = self._xfer_ns
        link = self._link_ns
        fm = self.faults
        retry = i in self.attempts     # timeout re-issue: counters already
        if is_read:                    # advanced on the first attempt
            if not retry:
                self.n_reads += 1
            if self.ftl is not None:
                die = self.ftl.read_die(lpn, die)   # L2P-resolved placement
            chan = die % f.channels
            t = self.fabric.dies.acquire_end(now, f.t_read_ns, unit=die)
            if fm is not None:
                blk = pg = -1
                if self.ftl is not None:
                    ppn = self.ftl.read_ppn(lpn)
                    if ppn is not None:
                        blk, pg = ppn[1], ppn[2]
                t, ok = fm.check_read(t, die, blk, pg)
                if not ok:
                    # unrecoverable read: the command completes with an
                    # error status — surfaced, never silently dropped
                    self.failed_reqs.add(i)
            t = self.fabric.channels.acquire_end(t, xfer, unit=chan)
            t = self.fabric.pcie.acquire_end(t, link)
        else:
            if not retry:
                self.n_writes += 1
            chan = die % f.channels
            rejected = fm is not None and not fm.write_ok(die, now)
            if not rejected and self.ftl is not None:
                try:
                    self.ftl.host_write(lpn, die)   # map + invalidate old PPN
                except OutOfPhysicalBlocks:
                    # retirement drained the die's pool: degrade loudly
                    fm.mark_read_only(die)
                    rejected = True
            if rejected:
                fm.note_failed_write(die)
                self.failed_reqs.add(i)
                # the rejected command still crosses the link (error
                # completion); the flash program never happens
                t = self.fabric.pcie.acquire_end(now, link)
            else:
                t = self.fabric.pcie.acquire_end(now, link)
                t = self.fabric.channels.acquire_end(t, xfer, unit=chan)
                t = self.fabric.dies.acquire_end(t, f.t_prog_ns, unit=die)
                if self.ftl is not None:
                    self.ftl.maybe_start_gc(die)    # watermark check
        if tele is not None:
            tele.on_io_issue(i, arrival_ns, is_read, die)
        self.engine.schedule(t, EventKind.IO_COMPLETE, self._on_complete,
                             payload=(i, arrival_ns, during_gc))

    def _on_complete(self, payload: Tuple[int, float, bool]) -> None:
        i, arrival, during_gc = payload
        now = self.engine.now
        lat = now - arrival
        fm = self.faults
        failed = i in self.failed_reqs
        if fm is not None and not failed and fm.op_deadline_exceeded(lat):
            st = fm.stats_
            st.n_op_timeouts += 1
            attempt = self.attempts.get(i, 0)
            if attempt < fm.cfg.max_op_retries:
                # the host aborts and re-issues after exponential backoff;
                # the recorded latency spans first arrival -> final done
                self.attempts[i] = attempt + 1
                st.n_op_retries += 1
                self.outstanding -= 1
                if self.telemetry is not None:
                    # close this attempt's async span — the retry's
                    # _issue emits a fresh "b" for the same request id,
                    # so without this the b/e balance check would reject
                    # every trace from an op-timeout run
                    self.telemetry.on_io_timeout(i, self.plan[i][2], now)
                self.engine.schedule(now + fm.op_backoff_ns(attempt),
                                     EventKind.IO_ARRIVAL, self._on_retry,
                                     payload=(i, arrival))
                if self.pending:
                    j, arr = self.pending.popleft()
                    self._issue(j, arr)             # aborted slot freed
                return
            st.n_failed_ops += 1                    # retry budget spent
            self.failed_reqs.add(i)
            failed = True
        if failed:
            self.n_failed += 1      # excluded from the latency population
        else:
            self.latency_by_req[i] = lat
        if during_gc:
            self.ftl.note_host_latency_during_gc(lat)
        self.last_complete_ns = max(self.last_complete_ns, now)
        if self.telemetry is not None:
            self.telemetry.on_io_complete(i, self.plan[i][2], now)
        self.outstanding -= 1
        if self.pending:
            j, arr = self.pending.popleft()
            self._issue(j, arr)                     # QD slot freed

    def _on_retry(self, payload: Tuple[int, float]) -> None:
        """Re-issue a timed-out op after its backoff; the retry respects
        the NVMe queue-depth cap exactly like a fresh arrival."""
        i, arrival = payload
        if self._qd is not None and self.outstanding >= self._qd:
            self.pending.append((i, arrival))
        else:
            self._issue(i, arrival)

    def stats(self) -> HostIOStats:
        # latencies indexed by request id (not completion order), so two
        # runs of the same stream compare request-for-request
        lats = [self.latency_by_req[i] for i in sorted(self.latency_by_req)]
        return HostIOStats(n_reads=self.n_reads, n_writes=self.n_writes,
                           latencies_ns=lats, n_failed=self.n_failed)


def clone_trace(tr: Trace) -> Trace:
    """Clone a Trace template for an independent tenant/session.

    A Trace owns its PageTable (mutable residency state): concurrent
    executions must never share one.  Everything else — the instruction
    list, the input/output page-id lists — is immutable during simulation
    and *shared*, which also shares the per-instruction cost-function
    memos: sessions of the same catalog kind in an open-loop serving run
    derive the static features once, not once per admission."""
    return Trace(instrs=tr.instrs, pages=tr.pages.clone(),
                 input_pages=tr.input_pages, output_pages=tr.output_pages,
                 name=tr.name)


def _as_policies(policies: Union[PolicyLike, Sequence[PolicyLike]],
                 n: int, spec: SSDSpec) -> List[Policy]:
    if isinstance(policies, (str, Policy)):
        policies = [policies] * n
    if len(policies) != n:
        raise ValueError(f"{len(policies)} policies for {n} traces")
    return [make_policy(p, spec) if isinstance(p, str) else p
            for p in policies]


def simulate_mix(traces: Sequence[Trace],
                 policies: Union[PolicyLike, Sequence[PolicyLike]] = "conduit",
                 io_stream: Optional[HostIOStream] = None,
                 spec: SSDSpec = DEFAULT_SSD,
                 config: Optional[SimConfig] = None,
                 compute_solo: bool = True,
                 engine: Optional[EventEngine] = None,
                 ftl: Optional[FTLConfig] = None,
                 start_ns: Optional[Sequence[float]] = None,
                 record_decisions: Optional[bool] = None,
                 telemetry: TelemetryLike = None,
                 faults=None) -> MixResult:
    """Run several traces concurrently on one SSD, plus optional host I/O.

    ``policies`` is one policy (applied to every trace) or one per trace;
    strings go through :func:`make_policy`.  ``compute_solo`` additionally
    runs each (trace, policy) alone on a private fabric to provide the
    solo makespans behind :attr:`MixResult.slowdowns` — disable it for
    large sweeps where only the contended numbers matter.  ``start_ns``
    staggers tenant arrivals (one offset per trace; slowdowns compare
    elapsed time from each tenant's own arrival).  ``ftl`` enables the
    flash translation layer of :mod:`repro.sim.ftl` with garbage
    collection as a background tenant.  Pass a ``record=True``
    :class:`EventEngine` to capture the event timeline.
    ``record_decisions=False`` is the fast mode: skip per-dispatch
    DecisionRecord allocation (timing identical; op latencies stay
    available) — overrides the same flag on ``config``.  ``telemetry``
    attaches a :class:`~repro.sim.telemetry.FlightRecorder` to the shared
    engine/fabric/FTL/I-O model (solo reference runs stay unobserved);
    the recorder comes back on ``result.telemetry``.  ``faults`` takes a
    :class:`~repro.sim.faults.FaultConfig`: an active config arms the
    RBER error model, the read-recovery ladder, bad-block retirement and
    the host op-timeout machinery on the shared fabric (solo reference
    runs stay fault-free); ``None`` or an all-off config is bit-identical
    to a build without the fault subsystem.
    """
    traces = list(traces)
    if not traces:
        raise ValueError("simulate_mix needs at least one trace")
    starts = list(start_ns) if start_ns is not None else [0.0] * len(traces)
    if len(starts) != len(traces):
        raise ValueError(f"{len(starts)} start offsets for {len(traces)} traces")
    if any(s < 0 for s in starts):
        raise ValueError("start_ns offsets must be >= 0")
    cfg = config or SimConfig()
    if record_decisions is not None:
        cfg = dataclasses.replace(cfg, record_decisions=record_decisions)
    pols = _as_policies(policies, len(traces), spec)

    # A Trace owns its PageTable (mutable residency state): tenants must
    # not share one, so duplicate Trace objects get an isolated clone
    # (instruction metadata stays shared — see clone_trace).
    seen: set = set()
    tenant_traces: List[Trace] = []
    for tr in traces:
        if id(tr) in seen:
            tr = clone_trace(tr)
        seen.add(id(tr))
        tenant_traces.append(tr)

    names = [f"t{i}:{tr.name or 'trace'}"
             for i, tr in enumerate(tenant_traces)]

    solo: Dict[str, float] = {}
    if compute_solo:
        for name, tr, pol in zip(names, tenant_traces, pols):
            solo[name] = simulate(tr, pol, spec, cfg).makespan_ns

    engine = engine or EventEngine()
    fabric = Fabric(spec, pud_units=cfg.pud_units)
    fm = None
    if faults is not None and faults.active:
        from repro.sim.faults import FaultModel
        fm = FaultModel(faults, spec, fabric, engine)
    tele = as_recorder(telemetry)
    if tele is not None:
        tele.attach(fabric=fabric, engine=engine)
        if fm is not None:
            tele.attach_faults(fm)
        tele.run_meta.setdefault("entry", "simulate_mix")
        tele.run_meta.setdefault(
            "policy", ",".join(sorted({p.name for p in pols})))
    ftl_model = (build_ftl_model(ftl, spec, fabric, engine, io_stream)
                 if ftl is not None else None)
    if ftl_model is not None and fm is not None:
        ftl_model.attach_faults(fm)
    if tele is not None and ftl_model is not None:
        tele.attach_ftl(ftl_model)
    sims = [Simulation(tr, pol, spec, cfg, fabric=fabric, tenant=name,
                       start_ns=st)
            for name, tr, pol, st in zip(names, tenant_traces, pols, starts)]
    for sim in sims:
        sim.bind(engine)
    io = (_HostIOModel(io_stream, fabric, spec, engine, ftl=ftl_model)
          if io_stream is not None else None)
    if tele is not None and io is not None:
        tele.attach_host_io(io)
    engine.run()

    results = [sim.result() for sim in sims]
    # the GC tail counts: collector copy/erase bookings regularly finish
    # after the last session and the last host completion
    makespan = max([r.makespan_ns for r in results]
                   + ([io.last_complete_ns] if io else [])
                   + ([ftl_model.last_booked_ns]
                      if ftl_model is not None else []))
    return MixResult(tenants=results, solo_makespan_ns=solo,
                     host_io=io.stats() if io else None,
                     fabric_busy_ns=fabric.busy_ns(),
                     makespan_ns=makespan,
                     ftl=ftl_model.stats() if ftl_model is not None else None,
                     telemetry=tele,
                     faults=fm.stats() if fm is not None else None)
