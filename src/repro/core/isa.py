"""Conduit vector-instruction IR and per-resource capability model.

The compile-time pass (see :mod:`repro.core.vectorize`) emits a stream of
:class:`VectorInstr` — wide SIMD operations whose vector width matches the
NAND flash page (4096 x 32-bit = 16 KiB, §4.3.1), each carrying the metadata
Table 1 requires (operation type, operand logical pages, element size,
vector length, SSA dependencies).

Each SSD computation resource supports a different subset of operations
(§4.3.2 "Operation Type"):

* ISP  — ~300 ISA ops (ARM + MVE): everything, incl. control/gather.
* PuD  — 16 ops (SIMDRAM/MIMDRAM/Proteus): bitwise, add/sub, mul,
         relational, predication — bit-serial over bit-planes.
* IFP  — 9 ops (Flash-Cosmos MWS + Ares-Flash): AND/OR/XOR/NOT/NAND/NOR +
         add/sub(shift-add)/mul(shift-and-add).

The latency/energy models below implement §5.2 using the Table 2 constants
in :mod:`repro.hw.ssd_spec`; they are the `latency_comp` feature of the cost
function and also drive the event-driven simulator's execution timing.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Sequence, Tuple

from repro.hw.ssd_spec import SSDSpec


class Resource(enum.Enum):
    """A compute-capable resource (paper §2.2) plus host baselines (§5.3)."""

    ISP = "isp"          # SSD controller embedded cores
    PUD = "pud"          # processing-using-DRAM in the SSD
    IFP = "ifp"          # in-flash processing
    HOST_CPU = "cpu"     # outside-storage processing baselines
    HOST_GPU = "gpu"

    @property
    def in_ssd(self) -> bool:
        return self in (Resource.ISP, Resource.PUD, Resource.IFP)


# Dense integer index per resource: hot paths (feature caches, pool tables)
# key flat tuples by ``resource.index`` instead of hashing enum members.
for _i, _r in enumerate(Resource):
    _r.index = _i
N_RESOURCES = len(Resource)

NDP_RESOURCES: Tuple[Resource, ...] = (Resource.ISP, Resource.PUD, Resource.IFP)


class Location(enum.Enum):
    """Where a logical page currently lives (4-bit encoded in the paper)."""

    FLASH = 0
    DRAM = 1
    CTRL = 2     # controller-core registers / SRAM (transient)
    HOST = 3


# Same dense-index trick as Resource: ``loc.index`` is a plain attribute
# read (``loc.value`` pays the DynamicClassAttribute descriptor on every
# access).  Values equal definition order, so index == value.
for _i, _l in enumerate(Location):
    _l.index = _i
N_LOCATIONS = len(Location)


class OpClass(enum.Enum):
    """Operation type feature (Table 1): latency class of the computation."""

    BITWISE = "bitwise"          # and/or/xor/not/shift   (low latency)
    ARITH_ADD = "arith_add"      # add/sub                (medium latency)
    PREDICATION = "predication"  # cmp/select/min/max     (medium latency)
    ARITH_MUL = "arith_mul"      # mul/mac/div-approx     (high latency)
    REDUCTION = "reduction"      # horizontal sum/max     (medium latency)
    COPY = "copy"                # bulk copy / init       (low latency)
    GATHER = "gather"            # indexed access         (control-ish)
    CONTROL = "control"          # non-vectorizable scalar/branchy region


LOW_LATENCY_CLASSES = frozenset({OpClass.BITWISE, OpClass.COPY})
MEDIUM_LATENCY_CLASSES = frozenset(
    {OpClass.ARITH_ADD, OpClass.PREDICATION, OpClass.REDUCTION})
HIGH_LATENCY_CLASSES = frozenset({OpClass.ARITH_MUL})

# Map concrete op mnemonics to their class.  The vectorizer lowers jaxpr
# primitives onto these mnemonics (the "native instruction" namespace).
OP_TO_CLASS = {
    "and": OpClass.BITWISE, "or": OpClass.BITWISE, "xor": OpClass.BITWISE,
    "not": OpClass.BITWISE, "nand": OpClass.BITWISE, "nor": OpClass.BITWISE,
    "shl": OpClass.BITWISE, "shr": OpClass.BITWISE,
    "add": OpClass.ARITH_ADD, "sub": OpClass.ARITH_ADD,
    "mul": OpClass.ARITH_MUL, "mac": OpClass.ARITH_MUL,
    "div": OpClass.ARITH_MUL, "rsqrt": OpClass.ARITH_MUL,
    "exp": OpClass.ARITH_MUL, "tanh": OpClass.ARITH_MUL,
    "logistic": OpClass.ARITH_MUL,
    "cmp": OpClass.PREDICATION, "select": OpClass.PREDICATION,
    "min": OpClass.PREDICATION, "max": OpClass.PREDICATION,
    "ge": OpClass.PREDICATION, "lt": OpClass.PREDICATION,
    "reduce_sum": OpClass.REDUCTION, "reduce_max": OpClass.REDUCTION,
    "copy": OpClass.COPY, "broadcast": OpClass.COPY, "iota": OpClass.COPY,
    "search": OpClass.PREDICATION,   # §7 extensibility: in-flash match
    "gather": OpClass.GATHER, "scatter": OpClass.GATHER,
    "scalar": OpClass.CONTROL, "branch": OpClass.CONTROL,
    "shuffle": OpClass.GATHER,
}

# Per-resource supported op classes (§4.3.2 "Operation Type").
SUPPORTED: dict = {
    Resource.ISP: frozenset(OpClass),  # general purpose: everything
    Resource.PUD: frozenset({
        OpClass.BITWISE, OpClass.ARITH_ADD, OpClass.ARITH_MUL,
        OpClass.PREDICATION, OpClass.REDUCTION, OpClass.COPY,
    }),
    Resource.IFP: frozenset({
        OpClass.BITWISE, OpClass.ARITH_ADD, OpClass.ARITH_MUL,
        OpClass.COPY, OpClass.PREDICATION,   # predication == search/cmp via
        # match lines (§7 extensibility); cost model prices non-search
        # predication high via the bit-serial latch path
    }),
    Resource.HOST_CPU: frozenset(OpClass),
    Resource.HOST_GPU: frozenset(OpClass) - {OpClass.CONTROL},
}

# Native ISA mnemonic prefix per resource — the instruction transformation
# unit (§4.3.2) rewrites `add` -> `mve.vadd` / `bbop_add` / `ares.shift_add`.
NATIVE_PREFIX = {
    Resource.ISP: "mve.v",        # ARM M-Profile Vector Extension
    Resource.PUD: "bbop_",        # SIMDRAM/MIMDRAM/Proteus bulk-bitwise ops
    Resource.IFP: "ifp.",         # Flash-Cosmos MWS / Ares-Flash primitives
    Resource.HOST_CPU: "avx512.",
    Resource.HOST_GPU: "ptx.",
}

IFP_NATIVE = {
    "search": "ifp.mws_match",           # XNOR + wired-AND match lines
    "and": "ifp.mws_and", "or": "ifp.mws_or", "nand": "ifp.mws_nand",
    "nor": "ifp.mws_nor", "xor": "ifp.latch_xor", "not": "ifp.latch_not",
    "add": "ifp.shift_add", "sub": "ifp.shift_sub", "mul": "ifp.shift_and_add_mul",
    "copy": "ifp.page_copy",
}


@dataclasses.dataclass
class VectorInstr:
    """One page-aligned SIMD instruction with compile-time metadata.

    ``srcs``/``dst`` are logical page ids (the FTL's L2P granularity); the
    runtime resolves their physical location via the mapping table.  ``deps``
    are producer instruction ids (SSA edges) — the data-dependence feature.
    """

    iid: int
    op: str                                   # mnemonic, key of OP_TO_CLASS
    vlen: int                                 # number of elements
    elem_bytes: int                           # element size (1=INT8 default)
    srcs: Tuple[int, ...]                     # logical source pages
    dst: int                                  # logical destination page
    deps: Tuple[int, ...] = ()                # producer iids
    tag: str = ""                             # provenance (jaxpr eqn / loop)
    vectorizable: bool = True                 # False -> CONTROL (ISP-only)

    @property
    def op_class(self) -> OpClass:
        # memoized: read on every supports()/cost lookup in the dispatch
        # loop, and (op, vectorizable) never change after construction
        oc = self.__dict__.get("_op_class")
        if oc is None:
            oc = (OpClass.CONTROL if not self.vectorizable
                  else OP_TO_CLASS[self.op])
            self._op_class = oc
        return oc

    @property
    def nbytes(self) -> int:
        return self.vlen * self.elem_bytes

    @property
    def bit_width(self) -> int:
        return self.elem_bytes * 8

    def native(self, resource: Resource) -> str:
        """Instruction transformation (§4.3.2): translate to native ISA."""
        if resource is Resource.IFP and self.op in IFP_NATIVE:
            return IFP_NATIVE[self.op]
        return NATIVE_PREFIX[resource] + self.op


# ---------------------------------------------------------------------------
# Expected computation latency model (latency_comp feature + simulator timing)
# ---------------------------------------------------------------------------

# SIMDRAM-class bit-serial bbop counts per W-bit elementwise op.
_PUD_BBOPS = {
    OpClass.BITWISE: lambda w: 3,                 # AAP sequences for and/or/xor
    OpClass.COPY: lambda w: 1,                    # RowClone
    OpClass.ARITH_ADD: lambda w: 5 * w + 2,       # MAJ-based ripple adder
    OpClass.PREDICATION: lambda w: 2 * w + 4,     # bit-serial compare+select
    OpClass.REDUCTION: lambda w: 6 * w + 8,       # tree of adds (log lanes folded)
    OpClass.ARITH_MUL: lambda w: 2 * w * w + 6 * w,  # shift-add partial products
}

# ISP cycles per SIMD vector (load/compute/store micro-schedule on R8+MVE).
_ISP_CYCLES = {
    OpClass.BITWISE: 5.0, OpClass.COPY: 4.0, OpClass.ARITH_ADD: 5.0,
    OpClass.PREDICATION: 6.0, OpClass.REDUCTION: 6.0, OpClass.ARITH_MUL: 8.0,
    OpClass.GATHER: 8.0, OpClass.CONTROL: 8.0,
}

_HOST_CYCLES = {
    OpClass.BITWISE: 1.0, OpClass.COPY: 1.0, OpClass.ARITH_ADD: 1.0,
    OpClass.PREDICATION: 1.5, OpClass.REDUCTION: 2.0, OpClass.ARITH_MUL: 2.0,
    OpClass.GATHER: 6.0, OpClass.CONTROL: 8.0,
}

_GPU_LAUNCH_NS = 4_000.0   # kernel-launch overhead amortized per fused op


def supports(resource: Resource, instr: VectorInstr) -> bool:
    return instr.op_class in SUPPORTED[resource]


def compute_latency_ns(instr: VectorInstr, resource: Resource,
                       spec: SSDSpec, operands_latched: bool = False) -> float:
    """Expected execution latency of ``instr`` on ``resource`` (ns).

    ``operands_latched``: for IFP, whether source pages are already in the
    plane's page buffer (skips the sensing step — Flash-Cosmos computes
    during the sense, consecutive latch ops reuse it).
    """
    oc = instr.op_class
    nbytes = instr.nbytes
    w = instr.bit_width

    if resource is Resource.IFP:
        f = spec.flash
        # Sensing: one multi-WL sense reads *all* same-block operands at once
        # for MWS AND/OR; other ops sense each operand page.
        if operands_latched:
            sense = 0.0
        elif oc is OpClass.BITWISE and instr.op in ("and", "or", "nand", "nor"):
            sense = f.t_read_ns + f.t_and_or_ns          # MWS: single sense
        else:
            sense = len(instr.srcs) * f.t_read_ns        # per-operand sense
        if instr.op == "search":
            # XNOR sense + match-line AND: one multi-WL sense
            return sense if sense else f.t_read_ns + 2 * f.t_and_or_ns
        if oc is OpClass.BITWISE:
            if instr.op in ("and", "or", "nand", "nor"):
                body = f.t_and_or_ns
            else:
                body = f.t_xor_ns + f.t_latch_transfer_ns
        elif oc is OpClass.COPY:
            body = f.t_latch_transfer_ns
        elif oc is OpClass.ARITH_ADD:
            body = w * f.shift_add_cycle_ns              # bit-serial latch adder
        elif oc is OpClass.ARITH_MUL:
            # Ares-Flash shift-and-add: w partial products, each needs a
            # latch AND + shift + add, PLUS operand staging through the
            # flash controller (the §6.4 "frequent operand transfers").
            body = w * (w * f.shift_add_cycle_ns) + 2 * f.t_dma_ns
        elif oc is OpClass.PREDICATION:
            # non-search predication: bit-serial compare via latches
            body = 2 * w * f.shift_add_cycle_ns
        else:  # unsupported classes are filtered by supports()
            body = float("inf")
        return sense + body

    if resource is Resource.PUD:
        d = spec.dram
        rows = max(1, math.ceil(nbytes / d.row_size))
        # MIMDRAM executes a bbop over a full row in t_bbop; rows spread
        # across banks run concurrently, command bus serializes issue.
        bank_par = min(rows, d.banks)
        serial_rows = math.ceil(rows / bank_par)
        bbops = _PUD_BBOPS[oc](w)
        issue = rows * 6.0                                # command issue per row
        return serial_rows * bbops * d.t_bbop_ns + issue

    if resource is Resource.ISP:
        i = spec.isp
        cyc = _ISP_CYCLES.get(oc, 8.0)
        if oc is OpClass.CONTROL:
            # scalar region: per-element, not per-vector
            return instr.vlen * cyc * i.cycle_ns / i.ipc
        return i.vector_op_ns(nbytes, cyc)

    if resource is Resource.HOST_CPU:
        h = spec.host
        cyc = _HOST_CYCLES.get(oc, 2.0)
        if oc is OpClass.CONTROL:
            # branchy scalar region: per-element on one core
            return instr.vlen * cyc / h.cpu_freq_ghz
        comp = h.cpu_vector_op_ns(nbytes, cyc)
        mem = 3 * nbytes / h.host_dram_bw_GBps            # 2 loads + 1 store
        return max(comp, mem)

    if resource is Resource.HOST_GPU:
        h = spec.host
        cyc = _HOST_CYCLES.get(oc, 2.0)
        comp = h.gpu_vector_op_ns(nbytes, cyc)
        mem = 3 * nbytes / h.gpu_hbm_bw_GBps
        return max(comp, mem) + _GPU_LAUNCH_NS / 16.0     # fused/streamed launches
    raise ValueError(f"unknown resource {resource}")


def compute_energy_nj(instr: VectorInstr, resource: Resource,
                      spec: SSDSpec, latency_ns: Optional[float] = None) -> float:
    """Energy of executing ``instr`` on ``resource`` (nJ), §5.2 model."""
    oc = instr.op_class
    kb = instr.nbytes / 1024.0
    if latency_ns is None:
        latency_ns = compute_latency_ns(instr, resource, spec)

    if resource is Resource.IFP:
        f = spec.flash
        sense_e = f.e_read_nj_per_channel * max(1, len(instr.srcs)) * 0.25
        if oc is OpClass.BITWISE:
            if instr.op in ("and", "or", "nand", "nor"):
                sense_e = f.e_read_nj_per_channel * 0.3   # single MWS sense
                return sense_e + f.e_and_or_nj_per_kb * kb
            return sense_e + f.e_xor_nj_per_kb * kb
        if oc is OpClass.COPY:
            return sense_e * 0.5 + f.e_latch_transfer_nj_per_kb * kb
        if oc is OpClass.ARITH_ADD:
            return sense_e + instr.bit_width * f.e_latch_transfer_nj_per_kb * kb
        if oc is OpClass.ARITH_MUL:
            w = instr.bit_width
            return (sense_e + w * w * f.e_latch_transfer_nj_per_kb * kb * 0.5
                    + 2 * f.e_dma_nj_per_channel)
        return sense_e

    if resource is Resource.PUD:
        d = spec.dram
        rows = max(1, math.ceil(instr.nbytes / d.row_size))
        bbops = _PUD_BBOPS[oc](instr.bit_width)
        return rows * bbops * (d.e_bbop_nj + d.e_act_pre_nj)

    if resource is Resource.ISP:
        return spec.isp.energy_nj(latency_ns) + spec.dram.e_bus_nj_per_kb * 3 * kb

    if resource is Resource.HOST_CPU:
        return spec.host.cpu_power_w * latency_ns + spec.host.e_host_dram_nj_per_kb * 3 * kb

    if resource is Resource.HOST_GPU:
        h = spec.host
        cyc = _HOST_CYCLES.get(oc, 2.0)
        active = max(h.gpu_vector_op_ns(instr.nbytes, cyc),
                     3 * instr.nbytes / h.gpu_hbm_bw_GBps)
        return h.gpu_power_w * active + 2_000.0   # + launch/idle overhead nJ
    raise ValueError(f"unknown resource {resource}")


def class_of(op: str) -> OpClass:
    return OP_TO_CLASS[op]


def latency_band(op_class: OpClass) -> str:
    """Table 3 latency bands used by workload characterization."""
    if op_class in LOW_LATENCY_CLASSES:
        return "low"
    if op_class in HIGH_LATENCY_CLASSES:
        return "high"
    return "medium"
