"""Conduit core: the paper's contribution as a composable library.

Compile-time:  :func:`repro.core.vectorize.vectorize` — programmer-
transparent tracing of a JAX function into page-aligned vector instructions.

Runtime:       :mod:`repro.core.cost` (six-feature cost function, Eqns 1-2),
:mod:`repro.core.policies` (Conduit + all baseline offloading policies),
:mod:`repro.core.mapping` (L2P + lazy coherence).
"""
from repro.core.isa import (NDP_RESOURCES, Location, OpClass, Resource,
                            VectorInstr, compute_energy_nj,
                            compute_latency_ns, supports)
from repro.core.cost import (HOME, Features, SystemView, decision_overhead_ns,
                             dm_energy_nj, dm_latency_ns, exec_energy_nj,
                             exec_latency_ns, features_for, static_features)
from repro.core.mapping import PageEntry, PageTable
from repro.core.policies import (ALL_POLICIES, ConduitPolicy, DMOffloading,
                                 BWOffloading, IdealPolicy, Policy,
                                 make_policy)
from repro.core.vectorize import Trace, TraceStats, vectorize

__all__ = [
    "NDP_RESOURCES", "Location", "OpClass", "Resource", "VectorInstr",
    "compute_energy_nj", "compute_latency_ns", "supports", "HOME",
    "Features", "SystemView", "decision_overhead_ns", "dm_energy_nj",
    "dm_latency_ns", "exec_energy_nj", "exec_latency_ns", "features_for",
    "static_features", "PageEntry", "PageTable",
    "ALL_POLICIES", "ConduitPolicy", "DMOffloading", "BWOffloading",
    "IdealPolicy", "Policy", "make_policy", "Trace", "TraceStats",
    "vectorize",
]
