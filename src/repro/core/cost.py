"""Conduit's holistic cost function (§4.3.2, Table 1, Eqns 1-2).

For each vector instruction and each candidate resource the cost function
combines six features:

  (1) operation type          -> latency_comp model (isa.compute_latency_ns)
  (2) operand location        -> L2P lookups feeding latency_dm
  (3) data dependence delay   -> delay_dd
  (4) resource queueing delay -> delay_queue
  (5) data movement latency   -> latency_dm (precomputed, contention-free)
  (6) expected comp latency   -> latency_comp

  total_latency_r = latency_comp + latency_dm + max(delay_dd, delay_queue)   (1)
  target          = argmin_r total_latency_r                                 (2)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

from repro.core.isa import (Location, Resource, VectorInstr,
                            compute_energy_nj, compute_latency_ns, supports)
from repro.hw.ssd_spec import SSDSpec

# Operand "home" for each compute resource: where operands must reside for
# the resource to execute on them.
HOME: Dict[Resource, Location] = {
    Resource.ISP: Location.DRAM,
    Resource.PUD: Location.DRAM,
    Resource.IFP: Location.FLASH,
    Resource.HOST_CPU: Location.HOST,
    Resource.HOST_GPU: Location.HOST,
}

#: ``HOME`` as a dense tuple indexed by ``resource.index`` (hot-path form).
HOME_BY_INDEX: Tuple[Location, ...] = tuple(HOME[r] for r in Resource)


def dm_latency_ns(src: Location, dst: Location, nbytes: int,
                  spec: SSDSpec) -> float:
    """Contention-free data-movement latency estimate (feature 5).

    Precomputed in the paper and stored in SSD DRAM; we compute it from the
    same Table 2 link constants.  Movement *into* flash requires an
    (expensive) SLC-mode program — the reason good policies rarely move
    DRAM-resident data back into the flash array for IFP.
    """
    if src == dst:
        return 0.0
    # NB: the sums below replicate the original per-pair expressions
    # term-for-term (float addition is not associative) — the fast path
    # only avoids building the full 12-entry table per call.
    f, d, h = spec.flash, spec.dram, spec.host
    if src is Location.FLASH:
        head = f.t_read_ns + f.t_dma_ns + nbytes * f.channel_ns_per_byte
        if dst is Location.CTRL:
            return head
        if dst is Location.DRAM:
            return head + nbytes * d.bus_ns_per_byte
        return head + (nbytes * h.pcie_ns_per_byte + h.pcie_latency_ns)
    chan = nbytes * f.channel_ns_per_byte
    if dst is Location.FLASH:
        if src is Location.CTRL:
            return chan + f.t_dma_ns + f.t_prog_ns
        if src is Location.DRAM:
            return nbytes * d.bus_ns_per_byte + chan + f.t_dma_ns + f.t_prog_ns
        return (nbytes * h.pcie_ns_per_byte + h.pcie_latency_ns
                + chan + f.t_dma_ns + f.t_prog_ns)
    bus = nbytes * d.bus_ns_per_byte
    pcie = nbytes * h.pcie_ns_per_byte + h.pcie_latency_ns
    if Location.HOST not in (src, dst):
        return bus                               # DRAM <-> CTRL
    if src is Location.CTRL or dst is Location.CTRL:
        return pcie                              # CTRL <-> HOST
    return bus + pcie if src is Location.DRAM else pcie + bus


def dm_energy_nj(src: Location, dst: Location, nbytes: int,
                 spec: SSDSpec) -> float:
    """Energy of moving ``nbytes`` between locations (§5.2 energy model)."""
    if src == dst:
        return 0.0
    f, d, h = spec.flash, spec.dram, spec.host
    kb = nbytes / 1024.0
    e = 0.0
    crosses_chan = (Location.FLASH in (src, dst))
    crosses_pcie = (Location.HOST in (src, dst))
    if src == Location.FLASH:
        e += f.e_read_nj_per_channel * 0.3 + f.e_dma_nj_per_channel
    if dst == Location.FLASH:
        e += f.e_prog_nj_per_channel + f.e_dma_nj_per_channel
    if crosses_chan:
        e += 2.0 * kb                      # channel toggling
    if Location.DRAM in (src, dst) or (crosses_pcie and not crosses_chan):
        e += d.e_bus_nj_per_kb * kb
    if crosses_pcie:
        e += h.e_pcie_nj_per_kb * kb
    return e


@dataclasses.dataclass(slots=True)
class Features:
    """Per-(instruction, resource) feature vector — logged for Fig. 9/10."""

    resource: Resource
    latency_comp: float
    latency_dm: float
    delay_dd: float
    delay_queue: float
    supported: bool

    @property
    def total(self) -> float:
        # Eqn 1: dd and queue delays overlap -> max().
        return (self.latency_comp + self.latency_dm
                + max(self.delay_dd, self.delay_queue))


@dataclasses.dataclass
class SystemView:
    """Runtime state snapshot the offloader reads (real-time knowledge the
    SSD controller has of its own resources, §4.3.2)."""

    now_ns: float
    queue_delay_ns: Callable[[Resource], float]
    dep_ready_ns: Callable[[VectorInstr], float]     # abs time operands ready
    location_of: Callable[[int], Location]
    # queueing on the operand-movement path (defaults to zero: the paper's
    # static dm estimate; the simulator wires the real path queues in)
    move_queue_ns: Callable[[Location, Location], float] = lambda s, d: 0.0
    # Multi-tenant plumbing: which trace/tenant this decision serves.  The
    # single-tenant simulator passes the trace name; simulate_mix passes a
    # unique tenant id — a QoS-aware policy can prioritize per tenant.
    tenant: str = ""
    # -- fast-path mirrors (optional; wired by the simulator) ----------------
    # Direct structure references that let ``select_fast`` probe queues and
    # operand locations without a bound-method hop per candidate.  A view
    # that leaves them at their defaults (hand-built views in tests) makes
    # ``select_fast`` fall back to the callable API above — same argmin.
    pools_by_index: Optional[tuple] = None   # ServerPool per Resource.index
    path_pools_flat: Optional[tuple] = None  # src.index*n_locations+dst.index
    n_locations: int = 0
    page_entries: Optional[dict] = None      # pid -> PageEntry (.location)
    dep_ready_abs: float = 0.0               # dep_ready_ns(instr) of the
                                             # instr being dispatched


def static_features(instr: VectorInstr, resource: Resource,
                    spec: SSDSpec) -> Tuple[bool, float, Location,
                                            Tuple[float, float, float, float]]:
    """Compile-time metadata of the cost function, memoized per instruction.

    Returns ``(supported, latency_comp, home, dm_by_location)`` where
    ``dm_by_location[loc.value]`` is the contention-free movement latency
    of one operand page from ``loc`` to the resource's home.  Everything
    here depends only on the instruction and the hardware spec — op type,
    operand sizes, supported-resource masks, link constants — so the
    offloader computes it once per :class:`VectorInstr` instead of
    re-deriving it for every candidate resource at every dispatch.

    The memo lives on the instruction object and pins the spec it was
    computed for (compared by identity, so a different spec for the same
    trace recomputes rather than aliasing).  Slots 1 and 2 are dense lists
    indexed by ``resource.index`` — the dispatch loop reads them for every
    candidate of every instruction, so no dict hashing on that path."""
    cache = instr.__dict__.get("_static_feats")
    if cache is None or cache[0] is not spec:
        n = len(Resource)
        cache = (spec, [None] * n, [None] * n, {})
        instr._static_feats = cache
    per = cache[1][resource.index]
    if per is None:
        ok = supports(resource, instr) and instr.op_class.name != "CONTROL" \
            or resource in (Resource.ISP, Resource.HOST_CPU)
        home = HOME[resource]
        lat = compute_latency_ns(instr, resource, spec) if ok else float("inf")
        nbytes = instr.nbytes
        dm_by_loc = (dm_latency_ns(Location.FLASH, home, nbytes, spec),
                     dm_latency_ns(Location.DRAM, home, nbytes, spec),
                     dm_latency_ns(Location.CTRL, home, nbytes, spec),
                     dm_latency_ns(Location.HOST, home, nbytes, spec))
        per = (ok, lat, home, dm_by_loc)
        cache[1][resource.index] = per
    return per


def candidate_table(instr: VectorInstr, candidates: Tuple[Resource, ...],
                    spec: SSDSpec) -> Tuple:
    """The supported candidates with their static features pre-joined:
    ``((resource, latency_comp, home, dm_by_location), ...)`` in
    ``candidates`` order, memoized per instruction.

    This is the ``select_fast`` inner loop: one cached-tuple read per
    dispatch replaces one :func:`static_features` call (plus the skip of
    unsupported rows) per candidate.  Two cache levels: a single-slot
    ``_cand_tab = (candidates, spec, table)`` triple — two identity checks,
    the steady state when one policy drives one trace — backed by a dict
    keyed by ``id(candidates)`` with an identity check on the stored tuple
    (int hashing instead of hashing an enum tuple per dispatch; the check
    makes a recycled id a recompute, never a wrong table)."""
    d = instr.__dict__
    ct = d.get("_cand_tab")
    if ct is not None and ct[0] is candidates and ct[1] is spec:
        return ct[2]
    cache = d.get("_static_feats")
    if cache is not None and cache[0] is spec:
        ent = cache[3].get(id(candidates))
        if ent is not None and ent[0] is candidates:
            table = ent[1]
            instr._cand_tab = (candidates, spec, table)
            return table
    static_features(instr, candidates[0], spec)      # pins the cache to spec
    cache = instr._static_feats[3]
    table = tuple((r,) + static_features(instr, r, spec)[1:]
                  for r in candidates
                  if static_features(instr, r, spec)[0])
    cache[id(candidates)] = (candidates, table)
    instr._cand_tab = (candidates, spec, table)
    return table


def exec_latency_ns(instr: VectorInstr, resource: Resource, spec: SSDSpec,
                    operands_latched: bool = False) -> float:
    """Memoized :func:`~repro.core.isa.compute_latency_ns` for the
    simulator's execution booking (both operand-latch variants cached
    per instruction alongside the static features)."""
    cache = instr.__dict__.get("_static_feats")
    if not operands_latched:
        if cache is not None and cache[0] is spec:
            per = cache[1][resource.index]
            if per is not None:
                if per[0]:
                    return per[1]
                return compute_latency_ns(instr, resource, spec)
        ok, lat, _, _ = static_features(instr, resource, spec)
        if ok:
            return lat
        return compute_latency_ns(instr, resource, spec)
    static_features(instr, resource, spec)           # pins the cache
    cache = instr._static_feats[2]
    lat = cache[resource.index]
    if lat is None:
        lat = compute_latency_ns(instr, resource, spec,
                                 operands_latched=True)
        cache[resource.index] = lat
    return lat


def exec_energy_nj(instr: VectorInstr, resource: Resource, spec: SSDSpec,
                   latency_ns: float) -> float:
    """Memoized :func:`~repro.core.isa.compute_energy_nj` for the
    simulator's execution booking — a pure function of the instruction,
    resource and (already-memoized) latency."""
    cache = instr.__dict__.get("_static_feats")
    if cache is None or cache[0] is not spec:
        static_features(instr, resource, spec)  # pins the cache to spec
        cache = instr._static_feats
    cache = cache[3]
    key = (resource.index, latency_ns)
    e = cache.get(key)
    if e is None:
        e = compute_energy_nj(instr, resource, spec, latency_ns)
        cache[key] = e
    return e


def features_for(instr: VectorInstr, resource: Resource, view: SystemView,
                 spec: SSDSpec, dep_delay_ns: Optional[float] = None
                 ) -> Features:
    """One (instruction, resource) feature vector.

    ``dep_delay_ns`` lets the policy pass the (resource-independent)
    data-dependence delay it already computed; by default it is derived
    from the view exactly as before."""
    ok, lat, home, dm_by_loc = static_features(instr, resource, spec)
    dm = 0.0
    mq = 0.0
    location_of = view.location_of
    move_queue_ns = view.move_queue_ns
    for s in instr.srcs:
        loc = location_of(s)
        dm += dm_by_loc[loc.index]
        if loc is not home:
            m = move_queue_ns(loc, home)
            if m > mq:
                mq = m
    if dep_delay_ns is None:
        dep_delay_ns = max(0.0, view.dep_ready_ns(instr) - view.now_ns)
    q = view.queue_delay_ns(resource)
    if mq > q:
        q = mq
    return Features(resource, lat, dm, dep_delay_ns, q, ok)


def decision_overhead_ns(instr: VectorInstr, spec: SSDSpec,
                         l2p_lookup: Optional[Callable[[int], float]] = None,
                         has_pending_deps: bool = False) -> float:
    """Runtime latency overhead of one offloading decision (§4.5).

    Components: per-operand L2P lookups (100 ns hit / 30 µs DFTL miss),
    dependence tracking (1 µs when deps are pending), queue-counter reads
    (1 µs), precomputed dm-latency lookup (100 ns), comp-latency lookup
    (150 ns), and instruction transformation (300 ns table lookup).
    Average ≈ 3.77 µs, worst ≈ 33 µs — validated in tests.
    """
    t = 0.0
    for s in instr.srcs:
        t += l2p_lookup(s) if l2p_lookup else spec.l2p_lookup_dram_ns
    if has_pending_deps:
        t += spec.dep_delay_track_ns
    t += spec.queue_delay_track_ns
    t += spec.dm_latency_lookup_ns
    t += spec.comp_latency_lookup_ns
    t += spec.translation_lookup_ns
    return t
