"""Compile-time preprocessing (§4.3.1): programmer-transparent vectorization.

The paper runs a custom LLVM pass (``-force-vector-width=4096
-force-vector-interleave=1``) that turns loops into page-aligned SIMD
operations and embeds metadata in the IR.  Our IR is the **jaxpr**: the user
writes ordinary JAX code; :func:`vectorize` traces it, walks the equations,
and strip-mines every primitive into 16 KiB page-aligned
:class:`~repro.core.isa.VectorInstr` ops — 4096 lanes of 32-bit, or 16384
lanes after the paper's INT8 quantization (§5.4) — with SSA dependency
edges, operand logical pages, and operation-type metadata (Table 1).

Partial vectorization (strip-mining, §4.3.1): array tails that do not fill
a page become shorter-``vlen`` instructions.  Non-vectorizable equations
(data-dependent control flow, sorts, unknown-trip-count loops — the §7
limitations) are emitted as ``CONTROL`` instructions pinned to ISP,
mirroring the paper's treatment of control-intensive regions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.isa import (OP_TO_CLASS, Location, OpClass, VectorInstr,
                            latency_band)
from repro.core.mapping import PageTable
from repro.hw.ssd_spec import DEFAULT_SSD, SSDSpec

# jax moved Literal across versions; resolve robustly.
try:
    from jax.extend.core import Literal  # jax >= 0.4.33
except ImportError:  # pragma: no cover
    from jax.core import Literal  # type: ignore

# -- primitive -> mnemonic table (the auto-vectorizer's pattern match) -------

_ELEMENTWISE = {
    "add": "add", "add_any": "add", "sub": "sub", "mul": "mul",
    "div": "div", "rem": "div", "pow": "mul", "integer_pow": "mul",
    "neg": "sub", "sign": "cmp", "abs": "max",
    "exp": "exp", "exp2": "exp", "log": "exp", "log1p": "exp",
    "expm1": "exp", "tanh": "tanh", "logistic": "logistic",
    "sqrt": "rsqrt", "rsqrt": "rsqrt", "cbrt": "rsqrt",
    "sin": "exp", "cos": "exp", "erf": "exp", "erf_inv": "exp",
    "max": "max", "min": "min",
    "and": "and", "or": "or", "xor": "xor", "not": "not",
    "shift_left": "shl", "shift_right_logical": "shr",
    "shift_right_arithmetic": "shr",
    "lt": "cmp", "le": "cmp", "gt": "cmp", "ge": "cmp",
    "eq": "cmp", "ne": "cmp",
    "floor": "cmp", "ceil": "cmp", "round": "cmp",
    "is_finite": "cmp", "square": "mul",
    "clamp": "select", "select_n": "select", "nextafter": "add",
}

_REDUCTIONS = {
    "reduce_sum": "reduce_sum", "reduce_max": "reduce_max",
    "reduce_min": "reduce_max", "reduce_prod": "reduce_sum",
    "reduce_and": "reduce_max", "reduce_or": "reduce_max",
    "argmax": "reduce_max", "argmin": "reduce_max",
    "reduce_precision": "copy",
}

_COPYLIKE = {
    "broadcast_in_dim": "broadcast", "convert_element_type": "copy",
    "concatenate": "copy", "pad": "copy",
    "dynamic_update_slice": "copy",
    "iota": "iota", "copy": "copy", "device_put": "copy",
}

_SHUFFLE = {"transpose": "shuffle", "rev": "shuffle"}
_GATHERLIKE = {"gather": "gather", "scatter": "scatter",
               "scatter-add": "scatter", "scatter_add": "scatter"}
_FREE = {"reshape", "squeeze", "expand_dims", "stop_gradient",
         "bitcast_convert_type", "copy_p", "sharding_constraint",
         "split", "optimization_barrier"}
_CONTROL = {"sort", "while", "cond", "top_k", "cumsum", "cumlogsumexp",
            "cummax", "approx_top_k"}
_RECURSE = {"pjit", "closed_call", "custom_jvp_call", "custom_vjp_call",
            "custom_vjp_call_jaxpr", "remat", "checkpoint", "custom_jvp_call_jaxpr",
            "remat_call", "named_call", "core_call", "jvp_call"}


@dataclasses.dataclass
class TraceStats:
    """Table 3 workload characterization."""

    total_instrs: int
    vectorizable_pct: float          # fraction of vectorizable instructions
    avg_reuse: float                 # reads per distinct page before overwrite
    band_mix: Dict[str, float]       # {low, medium, high} fractions
    op_mix: Dict[str, int]
    footprint_bytes: int

    def as_row(self) -> Dict[str, Any]:
        return {
            "vectorizable_pct": round(100 * self.vectorizable_pct, 1),
            "avg_reuse": round(self.avg_reuse, 1),
            "low_pct": round(100 * self.band_mix.get("low", 0.0)),
            "medium_pct": round(100 * self.band_mix.get("medium", 0.0)),
            "high_pct": round(100 * self.band_mix.get("high", 0.0)),
            "instrs": self.total_instrs,
        }


@dataclasses.dataclass
class Trace:
    """Output of compile-time preprocessing: the Conduit binary."""

    instrs: List[VectorInstr]
    pages: PageTable
    input_pages: Dict[str, List[int]]
    output_pages: List[List[int]]
    name: str = ""

    def characterize(self) -> TraceStats:
        """Workload characterization (Table 3).

        ``avg_reuse``: operations consuming the same data *version* before
        it is replaced — reads of each page between consecutive writes,
        averaged over versions.
        """
        cur_reads: Dict[int, int] = {}
        version_reads: List[int] = []
        bands: Dict[str, int] = {"low": 0, "medium": 0, "high": 0}
        ops: Dict[str, int] = {}
        nvec = 0
        for ins in self.instrs:
            for s in ins.srcs:
                cur_reads[s] = cur_reads.get(s, 0) + 1
            if ins.dst in cur_reads:
                version_reads.append(cur_reads.pop(ins.dst))
            if ins.vectorizable:
                nvec += 1
                # Band mix counts computation ops only — COPY instructions
                # are data staging, not computation (Table 3 counts ops).
                if ins.op_class is not OpClass.COPY:
                    bands[latency_band(ins.op_class)] += 1
            ops[ins.op] = ops.get(ins.op, 0) + 1
        version_reads.extend(cur_reads.values())   # final live versions
        total = len(self.instrs)
        nbv = max(1, sum(bands.values()))
        avg_reuse = (sum(version_reads) / max(1, len(version_reads)))
        return TraceStats(
            total_instrs=total,
            vectorizable_pct=nvec / max(1, total),
            avg_reuse=avg_reuse,
            band_mix={k: v / nbv for k, v in bands.items()},
            op_mix=ops,
            footprint_bytes=len(self.pages) * self.pages.spec.page_size,
        )


class _Vectorizer:
    def __init__(self, spec: SSDSpec, elem_bytes: int, quantize: bool,
                 max_instrs: int, scan_unroll_limit: int,
                 matmul_k_steps: int = 16):
        self.spec = spec
        self.page_bytes = spec.page_size
        self.elem_bytes = elem_bytes
        self.quantize = quantize
        self.max_instrs = max_instrs
        self.scan_unroll_limit = scan_unroll_limit
        self.matmul_k_steps = matmul_k_steps
        self.pages = PageTable(spec)
        self.instrs: List[VectorInstr] = []
        self.producer: Dict[int, int] = {}      # page id -> producing iid
        self._iid = 0

    # -- helpers --------------------------------------------------------------

    def _ebytes(self, aval) -> int:
        if self.quantize:
            return self.elem_bytes           # INT8 quantization (§5.4)
        return aval.dtype.itemsize

    def _lanes(self, ebytes: int) -> int:
        return self.page_bytes // ebytes

    def _npages(self, aval) -> int:
        return max(1, math.ceil(aval.size * self._ebytes(aval) / self.page_bytes))

    def pages_for(self, env: Dict, atom) -> Optional[List[int]]:
        """Logical pages for a jaxpr atom (None = scalar literal)."""
        if isinstance(atom, Literal):
            if np.ndim(atom.val) == 0 or np.size(atom.val) <= 8:
                return None
            pids = self.pages.alloc_array(
                int(np.size(atom.val)) * self._ebytes(atom.aval), name="lit")
            return pids
        return env[atom]

    def emit(self, op: str, srcs: Sequence[Optional[int]], dst: int,
             vlen: int, ebytes: int, tag: str = "",
             vectorizable: bool = True) -> int:
        if len(self.instrs) >= self.max_instrs:
            raise TraceBudgetExceeded(
                f"trace exceeded max_instrs={self.max_instrs}; "
                f"reduce the workload scale (tag={tag})")
        real_srcs = tuple(s for s in srcs if s is not None)
        deps = tuple(sorted({self.producer[s] for s in real_srcs
                             if s in self.producer}
                            | ({self.producer[dst]} if dst in self.producer
                               else set())))
        iid = self._iid
        self._iid += 1
        self.instrs.append(VectorInstr(
            iid=iid, op=op, vlen=vlen, elem_bytes=ebytes,
            srcs=real_srcs, dst=dst, deps=deps, tag=tag,
            vectorizable=vectorizable))
        self.producer[dst] = iid
        return iid

    def emit_map(self, op: str, in_pages: Sequence[Optional[List[int]]],
                 out_pages: List[int], aval, tag: str,
                 vectorizable: bool = True) -> None:
        """Strip-mine an elementwise op over the output pages."""
        ebytes = self._ebytes(aval)
        lanes = self._lanes(ebytes)
        total = aval.size
        for i, dst in enumerate(out_pages):
            vlen = min(lanes, total - i * lanes) if total > 0 else lanes
            srcs = []
            for pl in in_pages:
                if pl is None:
                    srcs.append(None)
                elif len(pl) == 0:
                    srcs.append(None)
                else:
                    srcs.append(pl[min(i, len(pl) - 1)])  # broadcast reuse
            self.emit(op, srcs, dst, max(1, vlen), ebytes, tag,
                      vectorizable=vectorizable)

    # -- equation dispatch ----------------------------------------------------

    def run(self, jaxpr, env: Dict) -> None:
        for eqn in jaxpr.eqns:
            self.eqn(eqn, env)

    def _bind_outputs(self, eqn, env, pages_list):
        for var, pl in zip(eqn.outvars, pages_list):
            env[var] = pl

    def _out_pages(self, eqn, idx=0, name=""):
        aval = eqn.outvars[idx].aval
        return self.pages.alloc_array(
            aval.size * self._ebytes(aval), name=name or str(eqn.primitive))

    def eqn(self, eqn, env: Dict) -> None:
        prim = eqn.primitive.name
        tag = prim

        if prim in _RECURSE or prim == "pjit":
            inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if inner is None:
                self._fallback_control(eqn, env)
                return
            closed = inner if hasattr(inner, "jaxpr") else None
            inner_jaxpr = closed.jaxpr if closed is not None else inner
            sub_env: Dict = {}
            for iv, atom in zip(inner_jaxpr.invars, eqn.invars):
                sub_env[iv] = self.pages_for(env, atom)
            if closed is not None:
                for cv, val in zip(inner_jaxpr.constvars, closed.consts):
                    sub_env[cv] = self.pages.alloc_array(
                        int(np.size(val)) * self.elem_bytes, name="const")
            self.run(inner_jaxpr, sub_env)
            for ov, innerv in zip(eqn.outvars, inner_jaxpr.outvars):
                if isinstance(innerv, Literal):
                    env[ov] = self.pages.alloc_array(
                        innerv.aval.size * self._ebytes(innerv.aval), "lit")
                else:
                    env[ov] = sub_env[innerv]
            return

        if prim == "scan":
            self._scan(eqn, env)
            return

        if prim == "dot_general":
            self._dot_general(eqn, env)
            return

        if prim in _FREE:
            src = self.pages_for(env, eqn.invars[0])
            out_aval = eqn.outvars[0].aval
            need = self._npages(out_aval)
            if src is None or len(src) < need:
                out = self._out_pages(eqn)
                self.emit_map("copy", [src], out, out_aval, tag)
                env[eqn.outvars[0]] = out
            else:
                env[eqn.outvars[0]] = src[:need]   # aliasing, no data movement
            for extra in eqn.outvars[1:]:
                env[extra] = self.pages.alloc_array(
                    extra.aval.size * self._ebytes(extra.aval), prim)
            return

        if prim in ("slice", "dynamic_slice"):
            # A vectorized load at an offset reads the source pages in place:
            # alias the page sub-range covering the sliced bytes (no copy).
            src = self.pages_for(env, eqn.invars[0])
            in_aval = eqn.invars[0].aval
            out_aval = eqn.outvars[0].aval
            if src is None:
                env[eqn.outvars[0]] = None
                return
            eb = self._ebytes(in_aval)
            if prim == "slice":
                starts = eqn.params["start_indices"]
                limits = eqn.params["limit_indices"]
                acc, flat_start, flat_last = 1, 0, 0
                for dim in range(len(in_aval.shape) - 1, -1, -1):
                    flat_start += starts[dim] * acc
                    flat_last += (limits[dim] - 1) * acc
                    acc *= in_aval.shape[dim]
            else:
                flat_start, flat_last = 0, in_aval.size - 1   # dynamic start
            first = (flat_start * eb) // self.page_bytes
            last = (flat_last * eb) // self.page_bytes
            sub = src[first:last + 1] or src[-1:]
            env[eqn.outvars[0]] = sub
            return

        if prim in _ELEMENTWISE:
            op = _ELEMENTWISE[prim]
            ins = [self.pages_for(env, a) for a in eqn.invars]
            out = self._out_pages(eqn)
            self.emit_map(op, ins, out, eqn.outvars[0].aval, tag)
            self._bind_outputs(eqn, env, [out])
            return

        if prim in _REDUCTIONS:
            self._reduction(eqn, env, _REDUCTIONS[prim])
            return

        if prim in _COPYLIKE:
            op = _COPYLIKE[prim]
            ins = [self.pages_for(env, a) for a in eqn.invars]
            outs = []
            for idx, ov in enumerate(eqn.outvars):
                out = self.pages.alloc_array(
                    ov.aval.size * self._ebytes(ov.aval), prim)
                self.emit_map(op, ins, out, ov.aval, tag)
                outs.append(out)
            self._bind_outputs(eqn, env, outs)
            return

        if prim in _SHUFFLE or prim in _GATHERLIKE:
            op = _SHUFFLE.get(prim) or _GATHERLIKE[prim]
            ins = [self.pages_for(env, a) for a in eqn.invars]
            out = self._out_pages(eqn)
            self.emit_map(op, ins, out, eqn.outvars[0].aval, tag)
            self._bind_outputs(eqn, env, [out])
            return

        if prim == "threefry2x32":
            ins = [self.pages_for(env, a) for a in eqn.invars]
            out = self._out_pages(eqn)
            aval = eqn.outvars[0].aval
            for op in ("xor", "shl", "add", "xor"):   # fused PRNG rounds
                self.emit_map(op, ins, out, aval, tag)
                ins = [out]
            self._bind_outputs(eqn, env, [out])
            return

        if prim in _CONTROL:
            self._fallback_control(eqn, env)
            return

        # Unknown primitive: conservatively non-vectorizable (paper §7).
        self._fallback_control(eqn, env)

    def _fallback_control(self, eqn, env: Dict) -> None:
        ins = [self.pages_for(env, a) for a in eqn.invars]
        outs = []
        for ov in eqn.outvars:
            aval = ov.aval
            out = self.pages.alloc_array(
                aval.size * self._ebytes(aval), str(eqn.primitive))
            # CONTROL region: per-page scalar execution on ISP.
            self.emit_map("scalar", ins, out, aval,
                          tag=str(eqn.primitive), vectorizable=False)
            outs.append(out)
        self._bind_outputs(eqn, env, outs)

    def _scan(self, eqn, env: Dict) -> None:
        """Counted loop: unroll (LLVM vectorizes counted loops, §4.3.1)."""
        length = eqn.params["length"]
        ncarry = eqn.params["num_carry"]
        nconsts = eqn.params["num_consts"]
        closed = eqn.params["jaxpr"]
        body = closed.jaxpr
        if length > self.scan_unroll_limit:
            # unknown/large trip count -> §7 limitation: control fallback
            self._fallback_control(eqn, env)
            return
        consts = [self.pages_for(env, a) for a in eqn.invars[:nconsts]]
        carry = [self.pages_for(env, a)
                 for a in eqn.invars[nconsts:nconsts + ncarry]]
        xs = [self.pages_for(env, a) for a in eqn.invars[nconsts + ncarry:]]
        ys_accum: List[List[int]] = [[] for _ in range(len(eqn.outvars) - ncarry)]
        for t in range(length):
            sub_env: Dict = {}
            bvars = body.invars
            for cv, val in zip(body.constvars, closed.consts):
                sub_env[cv] = self.pages.alloc_array(
                    int(np.size(val)) * self.elem_bytes, "const")
            for v, pl in zip(bvars[:nconsts], consts):
                sub_env[v] = pl
            for v, pl in zip(bvars[nconsts:nconsts + ncarry], carry):
                sub_env[v] = pl
            for v, pl in zip(bvars[nconsts + ncarry:], xs):
                if pl is None:
                    sub_env[v] = None
                else:
                    per = max(1, len(pl) // max(1, length))
                    sub_env[v] = pl[t * per:(t + 1) * per] or pl[-per:]
            self.run(body, sub_env)
            outs = []
            for ov in body.outvars:
                if isinstance(ov, Literal):
                    outs.append(self.pages.alloc_array(
                        max(1, ov.aval.size) * self.elem_bytes, "lit"))
                else:
                    outs.append(sub_env[ov])
            carry = outs[:ncarry]
            for k, ypl in enumerate(outs[ncarry:]):
                ys_accum[k].extend(ypl or [])
        for var, pl in zip(eqn.outvars[:ncarry], carry):
            env[var] = pl
        for var, pl in zip(eqn.outvars[ncarry:], ys_accum):
            env[var] = pl or self.pages.alloc_array(
                var.aval.size * self._ebytes(var.aval), "scan_y")

    def _reduction(self, eqn, env: Dict, op: str) -> None:
        src = self.pages_for(env, eqn.invars[0])
        out_aval = eqn.outvars[0].aval
        out = self.pages.alloc_array(
            max(1, out_aval.size) * self._ebytes(out_aval), op)
        ebytes = self._ebytes(eqn.invars[0].aval)
        lanes = self._lanes(ebytes)
        if src is None:
            self.emit(op, [], out[0], 1, ebytes, op)
        else:
            # accumulate page partials into the (smaller) output; successive
            # accumulations into one page serialize via the producer dep.
            for i, s in enumerate(src):
                dst = out[i % len(out)]
                self.emit(op, [s, dst], dst,
                          min(lanes, eqn.invars[0].aval.size), ebytes, op)
        self._bind_outputs(eqn, env, [out])

    def _dot_general(self, eqn, env: Dict) -> None:
        """Decompose a matmul into page-wide multiply + accumulate chains.

        C[b, m, n] += A[b, m, k] * B[b, k, n]: vectorize over n (lanes);
        each (m, k, n-page) triple becomes a ``mul`` into a scratch page
        followed by an ``add`` into the accumulator page — the two native
        SIMD ops every resource's ISA actually exposes (bbop_mul/bbop_add,
        ifp.shift_and_add / ifp.shift_add, mve.vmul / mve.vadd).

        Contraction steps are grouped into at most ``matmul_k_steps``
        macro-iterations per output page (the vectorizer's interleave
        granularity): each macro-iteration is one page-wide mul+add pair.
        """
        a_aval = eqn.invars[0].aval
        b_aval = eqn.invars[1].aval
        out_aval = eqn.outvars[0].aval
        dnums = eqn.params["dimension_numbers"]
        ((a_contract, b_contract), (a_batch, b_batch)) = dnums
        k = int(np.prod([a_aval.shape[d] for d in a_contract])) or 1
        batch = int(np.prod([a_aval.shape[d] for d in a_batch])) or 1
        m = max(1, a_aval.size // max(1, k * batch))
        n = max(1, b_aval.size // max(1, k * batch))
        ebytes = self._ebytes(out_aval)
        lanes = self._lanes(ebytes)
        n_pages = max(1, math.ceil(n / lanes))

        a_pages = self.pages_for(env, eqn.invars[0]) or []
        b_pages = self.pages_for(env, eqn.invars[1]) or []
        out = self.pages.alloc_array(out_aval.size * ebytes, "dot")

        bp = max(1, len(b_pages))
        ap = max(1, len(a_pages))
        scratch = self.pages.alloc_array(
            min(len(out), 8) * self.page_bytes, "dot_tmp", Location.DRAM)
        k_steps = min(k, self.matmul_k_steps)
        # Vectorize over the flattened OUTPUT (interleaved rows fill a full
        # page-wide vector); the contraction is the serial loop, grouped
        # into k_steps macro-iterations of one page-wide mul + add each.
        total_out = out_aval.size
        for opg, dst in enumerate(out):
            tmp = scratch[opg % len(scratch)]
            vlen = max(1, min(lanes, total_out - opg * lanes))
            for ki in range(k_steps):
                a_pid = a_pages[(opg * k_steps + ki) % ap] if a_pages else None
                b_pid = b_pages[(ki * len(out) + opg) % bp] if b_pages else None
                self.emit("mul", [a_pid, b_pid], tmp, vlen, ebytes,
                          "dot_general")
                self.emit("add", [tmp, dst], dst, vlen, ebytes, "dot_general")
        self._bind_outputs(eqn, env, [out])


class TraceBudgetExceeded(RuntimeError):
    pass


def _compact(instrs: List[VectorInstr], pages: PageTable,
             input_pages: Dict[str, List[int]],
             output_pages: List[List[int]], spec: SSDSpec):
    """Liveness-based page recycling (the buffer-reuse pass every real
    compiler performs: LLVM's vectorized loops update arrays in place, they
    do not allocate fresh SSA storage per operation).

    Input/const pages (live-in data) and trace outputs are pinned; every
    intermediate page is remapped onto a recycled physical pool once its
    last reader has issued.  SSA dependency edges (iids) are untouched —
    only page identities change — so execution ordering is preserved.
    """
    pinned = set()
    for pl in input_pages.values():
        pinned.update(pl)
    for pl in output_pages:
        pinned.update(pl)
    written: set = set()
    for ins in instrs:
        for s in ins.srcs:
            if s not in written:
                pinned.add(s)        # read-before-write: live-in constant
        written.add(ins.dst)

    last_use: Dict[int, int] = {}
    for ins in instrs:
        for p in ins.srcs + (ins.dst,):
            last_use[p] = ins.iid

    new_pages = PageTable(spec)
    mapping: Dict[int, int] = {}
    for vp in sorted(pinned):
        ent = pages[vp]
        npid = new_pages.alloc_array(spec.page_size, name=ent.name,
                                     location=ent.location)[0]
        mapping[vp] = npid

    free: List[int] = []
    release_at: Dict[int, List[int]] = {}
    for vp, iid in last_use.items():
        if vp not in pinned:
            release_at.setdefault(iid, []).append(vp)

    def lookup(vp: int) -> int:
        if vp in mapping:
            return mapping[vp]
        if free:
            npid = free.pop()
        else:
            npid = new_pages.alloc_array(
                spec.page_size, name="tmp", location=Location.DRAM)[0]
        mapping[vp] = npid
        return npid

    for ins in instrs:
        ins.srcs = tuple(lookup(s) for s in ins.srcs)
        ins.dst = lookup(ins.dst)
        for vp in release_at.get(ins.iid, ()):
            if vp in mapping:
                free.append(mapping.pop(vp))

    # pinned pages stay in `mapping` (never released)
    new_inputs = {k: [mapping[p] for p in pl] for k, pl in input_pages.items()}
    new_outputs = [[mapping[p] for p in pl if p in mapping]
                   for pl in output_pages]
    return new_pages, new_inputs, new_outputs


def vectorize(fn: Callable, *example_args,
              spec: SSDSpec = DEFAULT_SSD,
              elem_bytes: int = 1,                 # INT8 quantization (§5.4)
              quantize: bool = True,
              max_instrs: int = 400_000,
              scan_unroll_limit: int = 128,
              matmul_k_steps: int = 16,
              name: str = "") -> Trace:
    """Trace ``fn`` and emit the Conduit vector-instruction binary.

    This is the full compile-time phase: loop auto-vectorization (jaxpr
    equations are already loop-free SSA over arrays — each equation is the
    vectorized loop body), strip-mining into page-aligned instructions, and
    metadata embedding.  Inputs are assumed resident in flash at t=0 (§4.4
    "we assume all application data resides in the SSD").
    """
    closed = jax.make_jaxpr(fn)(*example_args)
    v = _Vectorizer(spec, elem_bytes, quantize, max_instrs, scan_unroll_limit,
                    matmul_k_steps)
    env: Dict = {}
    input_pages: Dict[str, List[int]] = {}
    flat, _ = jax.tree_util.tree_flatten(example_args)
    for i, (var, val) in enumerate(zip(closed.jaxpr.invars, flat)):
        ebytes = v._ebytes(var.aval)
        pids = v.pages.alloc_array(max(1, var.aval.size) * ebytes,
                                   name=f"in{i}")
        env[var] = pids
        input_pages[f"in{i}"] = pids
    for cv, val in zip(closed.jaxpr.constvars, closed.consts):
        env[cv] = v.pages.alloc_array(
            max(1, int(np.size(val))) * v.elem_bytes, name="const")
    v.run(closed.jaxpr, env)
    out_pages = []
    for ov in closed.jaxpr.outvars:
        if isinstance(ov, Literal):
            out_pages.append([])
        else:
            out_pages.append(env[ov] or [])
    new_pages, new_in, new_out = _compact(v.instrs, v.pages, input_pages,
                                          out_pages, spec)
    return Trace(instrs=v.instrs, pages=new_pages, input_pages=new_in,
                 output_pages=new_out,
                 name=name or getattr(fn, "__name__", "fn"))
