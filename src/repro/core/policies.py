"""Offloading policies: Conduit + the six evaluated baselines (§5.3).

Every policy maps a vector instruction (plus the runtime SystemView) to a
target compute resource.  The event-driven simulator (repro.sim) invokes
``select`` once per instruction at dispatch time.

* ``ConduitPolicy``    — the paper's contribution: Eqns 1-2 over six features.
* ``BWOffloading``     — lowest bandwidth/queue utilization [28,38,210-213].
* ``DMOffloading``     — minimize operand data movement [29,36,214,215].
* ``IdealPolicy``      — lowest computation latency; the simulator runs it
                         with contention and movement disabled (§5.3).
* ``StaticPolicy``     — single-resource NDP baselines (ISP, PuD-SSD,
                         Flash-Cosmos, Ares-Flash) with ISP fallback for
                         unsupported ops, as the paper's baselines do.
* ``HostPolicy``       — OSP on host CPU or GPU over NVMe/PCIe.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost import (HOME, Features, SystemView, candidate_table,
                             features_for, static_features)
from repro.core.isa import (NDP_RESOURCES, Location, OpClass, Resource,
                            VectorInstr, compute_latency_ns, supports)
from repro.hw.ssd_spec import SSDSpec


@dataclasses.dataclass
class Decision:
    resource: Resource
    features: Dict[Resource, Features]
    reason: str = ""


class Policy:
    """Base offloading policy.

    Policies are *stateless across dispatches*: ``select`` reads only the
    instruction, the :class:`SystemView` snapshot, and spec-derived
    constants fixed at construction.  One instance can therefore be shared
    by any number of concurrent tenants — including the open-loop serving
    regime (:mod:`repro.sim.serving`) where sessions arrive and depart
    mid-run and rebuilding a policy per admission would be pure churn; use
    :func:`shared_policy` for that."""

    name = "base"
    candidates: Tuple[Resource, ...] = NDP_RESOURCES
    ignores_contention = False      # Ideal: simulator disables contention
    # Dynamic policies evaluate runtime features per instruction inside the
    # SSD controller and pay the §4.5 decision overhead; static policies
    # (single-resource NDP baselines, host execution) are compile-time
    # mapped and only pay a queue-push.
    dynamic = True

    def __init__(self, spec: SSDSpec):
        self.spec = spec

    def _feats(self, instr: VectorInstr, view: SystemView
               ) -> Dict[Resource, Features]:
        # the data-dependence delay is resource-independent: compute it
        # once per dispatch, not once per candidate resource
        dd = view.dep_ready_ns(instr) - view.now_ns
        if dd < 0.0:
            dd = 0.0
        spec = self.spec
        return {r: features_for(instr, r, view, spec, dep_delay_ns=dd)
                for r in self.candidates}

    def _supported(self, instr: VectorInstr,
                   feats: Dict[Resource, Features]) -> List[Resource]:
        # feats[r].supported implies supports(r, instr): the only fallback
        # path to supported=True is ISP/HOST_CPU, whose SUPPORTED mask is
        # the full OpClass set — so the old `and supports(r, instr)`
        # re-check was always redundant
        ok = [r for r in self.candidates if feats[r].supported]
        if instr.op_class is OpClass.CONTROL or not ok:
            # control-intensive regions always fall back to the cores
            fallback = (Resource.ISP if Resource.ISP in self.candidates
                        else self.candidates[0])
            return [fallback]
        return ok

    def _fallback(self) -> Resource:
        return (Resource.ISP if Resource.ISP in self.candidates
                else self.candidates[0])

    def select(self, instr: VectorInstr, view: SystemView) -> Decision:
        raise NotImplementedError

    def select_fast(self, instr: VectorInstr, view: SystemView) -> Resource:
        """Allocation-free ``select``: same argmin, target resource only.

        The simulator's hot dispatch path calls this when nothing reads
        the full per-candidate feature dict (no fault replay configured);
        each override replicates its ``select`` term-for-term — same
        accumulation order, same tie-breaking — so the chosen resource and
        every downstream float are bit-identical to the ``select`` path."""
        return self.select(instr, view).resource


class ConduitPolicy(Policy):
    """The paper's holistic cost function: argmin Eqn 1 over resources."""

    name = "conduit"

    def select(self, instr: VectorInstr, view: SystemView) -> Decision:
        feats = self._feats(instr, view)
        ok = self._supported(instr, feats)
        best = min(ok, key=lambda r: feats[r].total)
        return Decision(best, feats, reason=f"min_total={feats[best].total:.0f}ns")

    def select_fast(self, instr: VectorInstr, view: SystemView) -> Resource:
        pools = view.pools_by_index
        if pools is None:          # hand-built view: no fast-path mirrors
            return self.select(instr, view).resource
        # no CONTROL check: candidate_table keeps only ISP for CONTROL
        # instrs (static_features gate), and the loop then picks it —
        # the same resource select() and _fallback() produce
        now = view.now_ns
        dd = view.dep_ready_abs - now
        if dd < 0.0:
            dd = 0.0
        entries = view.page_entries
        flat = view.path_pools_flat
        nloc = view.n_locations
        locs = [entries[s].location for s in instr.srcs]
        best = prev_home = None
        best_total = dm = mq = 0.0
        for r, lat, home, dm_by_loc in candidate_table(
                instr, self.candidates, self.spec):
            # dm/mq depend only on the home location (same operands):
            # consecutive same-home candidates (ISP, PUD -> DRAM) reuse
            if home is not prev_home:
                prev_home = home
                dm = 0.0
                mq = 0.0
                hbase = home.index
                probed = None
                for loc in locs:
                    dm += dm_by_loc[loc.index]
                    # co-located operands (the common case) share one
                    # path probe: same (loc, home) -> same pool maxima
                    if loc is not home and loc is not probed:
                        probed = loc
                        for p in flat[loc.index * nloc + hbase]:
                            m = p.queue_delay_ns(now)
                            if m > mq:
                                mq = m
            q = pools[r.index].queue_delay_ns(now)
            if mq > q:
                q = mq
            total = lat + dm + (dd if dd > q else q)
            if best is None or total < best_total:
                best, best_total = r, total
        return best if best is not None else self._fallback()


class BWOffloading(Policy):
    """Bandwidth-utilization-based offloading: prefer the least-utilized
    resource, ignoring operand movement cost (§3.2, §5.3)."""

    name = "bw"

    def select(self, instr: VectorInstr, view: SystemView) -> Decision:
        feats = self._feats(instr, view)
        ok = self._supported(instr, feats)
        best = min(ok, key=lambda r: (feats[r].delay_queue,
                                      feats[r].latency_comp))
        return Decision(best, feats, reason="min_queue")

    def select_fast(self, instr: VectorInstr, view: SystemView) -> Resource:
        pools = view.pools_by_index
        if pools is None:          # hand-built view: no fast-path mirrors
            return self.select(instr, view).resource
        now = view.now_ns
        entries = view.page_entries
        flat = view.path_pools_flat
        nloc = view.n_locations
        locs = [entries[s].location for s in instr.srcs]
        best = prev_home = None
        best_q = best_lat = mq = 0.0
        for r, lat, home, _ in candidate_table(
                instr, self.candidates, self.spec):
            if home is not prev_home:
                prev_home = home
                mq = 0.0
                hbase = home.index
                probed = None
                for loc in locs:
                    # co-located operands share one path probe
                    if loc is not home and loc is not probed:
                        probed = loc
                        for p in flat[loc.index * nloc + hbase]:
                            m = p.queue_delay_ns(now)
                            if m > mq:
                                mq = m
            q = pools[r.index].queue_delay_ns(now)
            if mq > q:
                q = mq
            if (best is None or q < best_q
                    or (q == best_q and lat < best_lat)):
                best, best_q, best_lat = r, q, lat
        return best if best is not None else self._fallback()


class DMOffloading(Policy):
    """Data-movement-minimizing offloading: prefer the resource that moves
    the fewest operand BYTES, ignoring contention (§3.2, §5.3)."""

    name = "dm"

    def select(self, instr: VectorInstr, view: SystemView) -> Decision:
        feats = self._feats(instr, view)
        ok = self._supported(instr, feats)

        def moved_bytes(r):
            home = HOME[r]
            return sum(instr.nbytes for s in instr.srcs
                       if view.location_of(s) != home)

        best = min(ok, key=lambda r: (moved_bytes(r), feats[r].latency_comp))
        return Decision(best, feats, reason="min_dm_bytes")

    def select_fast(self, instr: VectorInstr, view: SystemView) -> Resource:
        nbytes = instr.nbytes
        entries = view.page_entries
        if entries is not None:
            locs = [entries[s].location for s in instr.srcs]
        else:
            location_of = view.location_of
            locs = [location_of(s) for s in instr.srcs]
        best = prev_home = None
        best_moved = moved = 0
        best_lat = 0.0
        for r, lat, home, _ in candidate_table(
                instr, self.candidates, self.spec):
            if home is not prev_home:
                prev_home = home
                moved = 0
                for loc in locs:
                    if loc != home:
                        moved += nbytes
            if (best is None or moved < best_moved
                    or (moved == best_moved and lat < best_lat)):
                best, best_moved, best_lat = r, moved, lat
        return best if best is not None else self._fallback()


class IdealPolicy(Policy):
    """Upper bound (§5.3): no queueing, zero movement, fastest resource."""

    name = "ideal"
    ignores_contention = True
    dynamic = False

    def select(self, instr: VectorInstr, view: SystemView) -> Decision:
        feats = self._feats(instr, view)
        ok = self._supported(instr, feats)
        best = min(ok, key=lambda r: feats[r].latency_comp)
        return Decision(best, feats, reason="min_comp")

    def select_fast(self, instr: VectorInstr, view: SystemView) -> Resource:
        best = None
        best_lat = 0.0
        for r, lat, _, _ in candidate_table(
                instr, self.candidates, self.spec):
            if best is None or lat < best_lat:
                best, best_lat = r, lat
        return best if best is not None else self._fallback()


class StaticPolicy(Policy):
    """Single-resource NDP baselines with ISP fallback (§5.3).

    ``ops`` restricts which mnemonics the primary resource accelerates
    (e.g. Flash-Cosmos: MWS AND/OR/NOT only)."""

    dynamic = False

    def __init__(self, spec: SSDSpec, primary: Resource,
                 ops: Optional[Sequence[str]] = None, name: str = ""):
        super().__init__(spec)
        self.primary = primary
        self.ops = frozenset(ops) if ops is not None else None
        self.name = name or primary.value

    def select(self, instr: VectorInstr, view: SystemView) -> Decision:
        feats = self._feats(instr, view)
        ok_primary = (feats[self.primary].supported
                      and supports(self.primary, instr)
                      and instr.op_class is not OpClass.CONTROL
                      and (self.ops is None or instr.op in self.ops))
        if ok_primary and self.primary is Resource.IFP:
            # Flash-Cosmos/Ares-Flash compute on data stored in the flash
            # array (or chained in latches); they never program operands
            # back into flash just to compute on them.
            ok_primary = all(view.location_of(s) == Location.FLASH
                             for s in instr.srcs)
        target = self.primary if ok_primary else Resource.ISP
        return Decision(target, feats, reason="static")

    def select_fast(self, instr: VectorInstr, view: SystemView) -> Resource:
        primary = self.primary
        ok, _, _, _ = static_features(instr, primary, self.spec)
        ok_primary = (ok and supports(primary, instr)
                      and instr.op_class is not OpClass.CONTROL
                      and (self.ops is None or instr.op in self.ops))
        if ok_primary and primary is Resource.IFP:
            ok_primary = all(view.location_of(s) == Location.FLASH
                             for s in instr.srcs)
        return primary if ok_primary else Resource.ISP


class HostPolicy(Policy):
    """Outside-storage processing on host CPU/GPU (§5.3)."""

    ignores_contention = False
    dynamic = False

    def __init__(self, spec: SSDSpec, device: Resource):
        super().__init__(spec)
        assert device in (Resource.HOST_CPU, Resource.HOST_GPU)
        self.device = device
        self.name = device.value
        # GPU baselines run control-intensive regions on the host CPU.
        self.candidates = ((device,) if device is Resource.HOST_CPU
                           else (device, Resource.HOST_CPU))

    def select(self, instr: VectorInstr, view: SystemView) -> Decision:
        feats = self._feats(instr, view)
        target = self.device
        if (instr.op_class is OpClass.CONTROL
                and self.device is Resource.HOST_GPU):
            target = Resource.HOST_CPU
        return Decision(target, feats, reason="host")

    def select_fast(self, instr: VectorInstr, view: SystemView) -> Resource:
        if (instr.op_class is OpClass.CONTROL
                and self.device is Resource.HOST_GPU):
            return Resource.HOST_CPU
        return self.device


# -- factory -----------------------------------------------------------------

FLASH_COSMOS_OPS = ("and", "or", "nand", "nor", "not", "xor")
ARES_FLASH_OPS = FLASH_COSMOS_OPS + ("add", "sub", "mul", "copy")


def make_policy(name: str, spec: SSDSpec) -> Policy:
    name = name.lower()
    if name == "conduit":
        return ConduitPolicy(spec)
    if name in ("bw", "bw_offloading"):
        return BWOffloading(spec)
    if name in ("dm", "dm_offloading"):
        return DMOffloading(spec)
    if name == "ideal":
        return IdealPolicy(spec)
    if name == "isp":
        return StaticPolicy(spec, Resource.ISP, name="isp")
    if name in ("pud", "pud_ssd"):
        return StaticPolicy(spec, Resource.PUD, name="pud")
    if name in ("flash_cosmos", "flashcosmos"):
        return StaticPolicy(spec, Resource.IFP, FLASH_COSMOS_OPS,
                            name="flash_cosmos")
    if name in ("ares_flash", "aresflash", "ifp"):
        return StaticPolicy(spec, Resource.IFP, ARES_FLASH_OPS,
                            name="ares_flash")
    if name == "cpu":
        return HostPolicy(spec, Resource.HOST_CPU)
    if name == "gpu":
        return HostPolicy(spec, Resource.HOST_GPU)
    raise ValueError(f"unknown policy {name!r}")


@functools.lru_cache(maxsize=64)
def shared_policy(name: str, spec: SSDSpec) -> Policy:
    """Process-wide cached policy instance for high-churn callers.

    Safe because policies are stateless across ``select`` calls (see
    :class:`Policy`); the open-loop serving driver admits thousands of
    short sessions per run and must not rebuild the policy — or re-derive
    its spec-pinned tables — per admission.  Callers that mutate a policy
    (none in-tree) must use :func:`make_policy` instead."""
    return make_policy(name, spec)


ALL_POLICIES = ("cpu", "gpu", "isp", "pud", "flash_cosmos", "ares_flash",
                "bw", "dm", "conduit", "ideal")
